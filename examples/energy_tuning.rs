//! Run-time energy/accuracy tuning (the paper's Figure 5 story): sweep
//! the confidence threshold on a fixed 8×2 FoG and watch EDP fall by an
//! order of magnitude before accuracy gives way.
//!
//! Run: `cargo run --release --example energy_tuning [-- --dataset penbase]`

use fog::data::synthetic::DatasetProfile;
use fog::energy::blocks::{AreaBlocks, EnergyBlocks};
use fog::energy::model::{fog_cost, rf_cost, ClassifierKind};
use fog::experiments::suite::{fog_stats, rf_stats, train_suite};
use fog::fog::tuner::{accuracy_optimal_threshold, threshold_sweep};
use fog::fog::FieldOfGroves;
use fog::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let name = args.get_or("dataset", "penbase");
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    eprintln!("training suite on {} ...", profile.name);
    let suite = train_suite(&profile, 42);

    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let rf_report = rf_cost(&rf_stats(&suite), &eb, &ab);

    let fog = FieldOfGroves::from_forest_shuffled(&suite.rf, 2, Some(42)); // 8x2
    let grid = fog::fog::tuner::default_grid();
    let sweep = threshold_sweep(&fog, &suite.data.test, &grid, 42);
    let opt = accuracy_optimal_threshold(&sweep, 0.01);

    println!("== {} @ 8x2: threshold tuning ==", profile.name);
    println!(
        "{:<12}{:>12}{:>12}{:>14}{:>16}{:>12}",
        "threshold", "accuracy%", "avg hops", "energy (nJ)", "EDP (nJ*ns)", "vs RF"
    );
    for p in &sweep {
        let stats = fog_stats(&fog, p.avg_hops, ClassifierKind::FogOpt);
        let rep = fog_cost(&stats, &eb, &ab);
        let marker = if (p.threshold - opt.threshold).abs() < 1e-6 { "  <== FoG_opt" } else { "" };
        println!(
            "{:<12.2}{:>12.1}{:>12.2}{:>14.2}{:>16.1}{:>11.2}x{}",
            p.threshold,
            p.accuracy * 100.0,
            p.avg_hops,
            rep.energy_nj,
            rep.edp(),
            rf_report.energy_nj / rep.energy_nj,
            marker
        );
    }
    println!(
        "\nconventional RF reference: {:.2} nJ, {:.1} ns, {:.2} mm²",
        rf_report.energy_nj, rf_report.latency_ns, rf_report.area_mm2
    );
    println!(
        "FoG_opt at threshold {:.2}: accuracy {:.1}% using {:.2}/{} groves on average",
        opt.threshold,
        opt.accuracy * 100.0,
        opt.avg_hops,
        fog.n_groves()
    );
}
