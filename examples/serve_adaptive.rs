//! Adaptive confidence early-exit serving demo: the same forest served
//! at a sweep of confidence thresholds (arXiv 2205.13838) through a
//! sharded server, so the live accuracy-vs-effort trade-off is visible
//! next to the threshold-tagged cache. The `t = 1.00` row is the
//! conformance anchor — the demo asserts its probability rows are
//! byte-identical to serving without the knob before printing the
//! sweep.
//!
//! Run: `cargo run --release --example serve_adaptive -- \
//!        [--model rf_prob] [--replicas 2] [--dataset demo]`

use fog::api::{Classifier, Estimator, ModelSpec, REGISTRY};
use fog::coordinator::{Response, ShardedServer, ShardedServerConfig};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::util::cli::Args;
use std::sync::Arc;

/// FNV-1a over the responses' probability bit patterns — the same
/// conformance fingerprint `fog serve` prints as `prob_checksum`.
fn prob_checksum(responses: &[Response]) -> u64 {
    let mut hash = 0xCBF29CE484222325u64;
    for r in responses {
        for &p in &r.prob {
            hash = (hash ^ p.to_bits() as u64).wrapping_mul(0x100000001B3);
        }
    }
    hash
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let profile = DatasetProfile::by_name(args.get_or("dataset", "demo")).expect("dataset");
    let model_name = args.get_or("model", "rf_prob");
    let replicas = args.get_usize("replicas", 2);

    let base = ModelSpec::for_shape(model_name, profile.n_features, profile.n_classes)
        .unwrap_or_else(|| panic!("unknown model '{model_name}'; valid: {}", REGISTRY.join(", ")))
        .with_replicas(replicas)
        // Exact-key result cache (the `fog serve` default) so the sweep
        // shows each threshold's generation tag partitioning the keys.
        .with_cache_quant(0.0);

    eprintln!("training {model_name} on {} ...", profile.name);
    let data = generate(&profile, 42);

    // Serve one threshold: fit (same seed → same forest every row, only
    // the exit policy differs), push the test split through the sharded
    // tier, and fold the serving metrics.
    let serve = |adaptive: Option<f32>| {
        let mut spec = base.clone();
        if let Some(t) = adaptive {
            spec = spec.with_adaptive(t);
        }
        let model: Arc<dyn Classifier> = Arc::from(spec.fit(&data.train, 42));
        let cfg = ShardedServerConfig::for_serving(&spec.serving);
        let mut server = ShardedServer::start(model, &cfg);
        let responses = server.classify(&data.test.x).expect("aligned batch");
        let preds: Vec<usize> = responses.iter().map(|r| r.label).collect();
        let acc = fog::util::stats::accuracy(&preds, &data.test.y);
        let snap = server.snapshot();
        let tag = server.cache().map(|c| c.tag());
        server.shutdown();
        (acc, snap, prob_checksum(&responses), tag)
    };

    // Conformance anchor: t = 1.0 must serve the exact bytes the plain
    // server does (the models filter a full threshold out entirely).
    let (_, _, plain_sum, _) = serve(None);
    let (_, _, pinned_sum, _) = serve(Some(1.0));
    assert_eq!(
        plain_sum, pinned_sum,
        "t = 1.0 must be byte-identical to serving without --adaptive-conf"
    );
    println!("conformance  : t=1.00 prob_checksum {pinned_sum:016x} == plain serve");
    println!();
    println!(
        "== adaptive sweep: {model_name} x{replicas} replicas on '{}' ==",
        profile.name
    );
    println!(
        "{:<8}{:>11}{:>17}{:>16}{:>20}",
        "t", "accuracy%", "trees skip/cls", "cmp ops/cls", "cache tag"
    );
    for t in [0.2f32, 0.4, 0.6, 0.8, 1.0] {
        let (acc, snap, _, tag) = serve(Some(t));
        println!(
            "{:<8.2}{:>11.1}{:>17.2}{:>16.1}{:>20}",
            t,
            acc * 100.0,
            snap.trees_skipped_per_class(),
            snap.comparator_ops_per_class(),
            tag.map_or_else(|| "-".to_string(), |g| format!("{g:#010x}"))
        );
    }
    println!();
    println!(
        "comparator ops/class stay at the padded-depth hardware charge at every \
         threshold; the saving is the separate trees-skipped gauge."
    );
}
