//! Serving demo: the FoG ring as a classification service, with the
//! AOT-compiled PJRT backend when artifacts are available (falling back
//! to the native backend otherwise). Reports latency percentiles and
//! throughput — the serving-side view of the accelerator.
//!
//! Run: `make artifacts && cargo run --release --example serve_fog`

use fog::coordinator::{Backend, FogServer, ServerConfig};
use fog::data::normalize::{quantize_split, standardize};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::dt::TreeParams;
use fog::forest::{ForestParams, RandomForest};
use fog::fog::FieldOfGroves;
use fog::util::cli::Args;
use std::time::Duration;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // demo profile matches the grove_step_demo artifact (t=4, d=6, f=8, c=3)
    let profile = DatasetProfile::by_name(args.get_or("dataset", "demo")).expect("dataset");
    eprintln!("training {} ...", profile.name);
    let mut data = generate(&profile, 42);
    standardize(&mut data);
    quantize_split(&mut data.train);
    quantize_split(&mut data.test);
    // Depth 6 so the trained trees bind to the demo artifact (t=4, d=6).
    let params = ForestParams {
        n_trees: 16,
        tree: TreeParams { max_depth: 6, min_samples_leaf: 2, ..Default::default() },
        bootstrap: true,
    };
    let rf = RandomForest::fit(&data.train, &params, 42);
    let per_grove = 4;
    let mut fog = FieldOfGroves::from_forest_shuffled(&rf, per_grove, Some(42));

    // Try PJRT: repad trees to the artifact depth (demo artifact = 6).
    let artifacts = fog::runtime::artifacts::default_dir();
    let want_depth = 6usize;
    let pjrt_ok = artifacts.join("manifest.json").exists() && fog.depth <= want_depth;
    let backend = if pjrt_ok && profile.name == "demo" {
        fog = fog.repad(want_depth);
        println!("backend: PJRT (artifacts at {})", artifacts.display());
        Backend::Pjrt { artifacts_dir: artifacts }
    } else {
        println!("backend: native (no matching artifacts — run `make artifacts`)");
        Backend::Native
    };

    let cfg = ServerConfig {
        threshold: args.get_f64("threshold", 0.3) as f32,
        batch_size: args.get_usize("batch", 16),
        batch_timeout: Duration::from_micros(args.get_u64("batch-timeout-us", 200)),
        seed: 42,
        backend,
        ..Default::default()
    };
    let mut server = FogServer::start(&fog, &cfg).expect("server");

    // Warm-up round (PJRT compilation happens at start; first batch pays
    // buffer setup), then the measured run.
    let _ = server.classify(&data.test.x);
    let rounds = args.get_usize("rounds", 5);
    let t0 = std::time::Instant::now();
    let mut responses = Vec::new();
    for _ in 0..rounds {
        responses = server.classify(&data.test.x);
    }
    let wall = t0.elapsed();
    let n_total = responses.len() * rounds;

    let preds: Vec<usize> = responses.iter().map(|r| r.label).collect();
    let acc = fog::util::stats::accuracy(&preds, &data.test.y);
    let lat = FogServer::latency_summary(&responses);
    let snap = server.metrics().snapshot();
    println!("requests    : {n_total} ({} per round x {rounds})", responses.len());
    println!("accuracy    : {:.1}%", acc * 100.0);
    println!("avg hops    : {:.2} of {} groves", snap.avg_hops(), fog.n_groves());
    println!("avg batch   : {:.1}", snap.avg_batch_size());
    println!("throughput  : {:.0} req/s", n_total as f64 / wall.as_secs_f64());
    println!(
        "latency     : p50 {:.0}µs  p95 {:.0}µs  p99 {:.0}µs  mean {:.0}µs",
        lat.p50_us, lat.p95_us, lat.p99_us, lat.mean_us
    );
    server.shutdown();
}
