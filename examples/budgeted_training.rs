//! Feature-budgeted training (the paper's step-2 substrate, Nan et al.
//! [11]): per-feature acquisition costs come from the PPA library —
//! reading a feature byte into the grove's data queue costs SRAM energy —
//! and training trades impurity gain against acquisition cost under an
//! explicit budget.
//!
//! Run: `cargo run --release --example budgeted_training`

use fog::data::normalize::standardize;
use fog::data::synthetic::{generate, DatasetProfile};
use fog::energy::blocks::EnergyBlocks;
use fog::forest::budgeted::fit_budgeted;
use fog::forest::{ForestParams, VoteMode};

fn main() {
    let profile = DatasetProfile::by_name("penbase").unwrap();
    let mut ds = generate(&profile, 42);
    standardize(&mut ds);

    // PPA-derived acquisition costs (pJ per feature read), with the second
    // half of the features pretending to be expensive remote sensors —
    // the asymmetric-cost setting budgeted RF is designed for.
    let eb = EnergyBlocks::default();
    let base = eb.sram_read_pj_per_byte;
    let costs: Vec<f32> = (0..ds.train.n_features)
        .map(|f| if f >= ds.train.n_features / 2 { (base * 40.0) as f32 } else { base as f32 })
        .collect();

    // Unconstrained reference.
    let free = fit_budgeted(&ds.train, &ForestParams::default(), &costs, f64::INFINITY, 42);
    let free_cost = free.chosen.avg_cost;
    println!("unconstrained: acquisition {:.2} pJ/input, test accuracy {:.1}%", free_cost, free.forest.accuracy(&ds.test, VoteMode::Majority) * 100.0);

    println!("\n{:<14}{:>18}{:>18}{:>14}", "budget (pJ)", "achieved (pJ)", "cost weight", "accuracy%");
    for frac in [1.0, 0.75, 0.5, 0.25] {
        let budget = free_cost * frac;
        let b = fit_budgeted(&ds.train, &ForestParams::default(), &costs, budget, 42);
        println!(
            "{:<14.2}{:>18.2}{:>18.3}{:>14.1}",
            budget,
            b.chosen.avg_cost,
            b.chosen.cost_weight,
            b.forest.accuracy(&ds.test, VoteMode::Majority) * 100.0
        );
    }
    println!("\nsweep points evaluated during the budget search:");
    for p in &free.sweep {
        println!(
            "  weight {:.3}: validation acc {:.1}%, acquisition {:.2} pJ",
            p.cost_weight,
            p.val_accuracy * 100.0,
            p.avg_cost
        );
    }
    println!(
        "\nTighter budgets steer splits toward the cheap feature half; the\n\
         paper plugs exactly this mechanism in before the FoG split (§4.1)."
    );
}
