//! End-to-end validation driver (EXPERIMENTS.md §E2E): exercises every
//! layer of the stack on a real small workload and reports the paper's
//! headline metric.
//!
//! Pipeline:
//!   1. generate + condition the dataset (standardize, Q3.4 quantize),
//!   2. train all six classifiers from scratch,
//!   3. Algorithm-1 split → FoG, FoG_opt threshold search,
//!   4. classify the test set through
//!        a. the software evaluator (Algorithm 2),
//!        b. the cycle-level μarch ring simulator,
//!        c. the threaded serving coordinator (PJRT backend when the
//!           artifact matches, else native),
//!      and assert all three agree,
//!   5. print the Table-1 row + energy ratios for this dataset.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pipeline`

use fog::coordinator::{Backend, FogServer, ServerConfig};
use fog::data::synthetic::DatasetProfile;
use fog::energy::blocks::{AreaBlocks, EnergyBlocks};
use fog::energy::model::ClassifierKind;
use fog::experiments::suite::{evaluate_suite, select_fog, train_suite};
use fog::fog::FogParams;
use fog::uarch::{RingConfig, RingSim};
use fog::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let name = args.get_or("dataset", "penbase");
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    let seed = args.get_u64("seed", 42);

    println!("=== E2E pipeline on '{}' ({} features, {} classes) ===", profile.name, profile.n_features, profile.n_classes);

    // --- 1+2: data + all classifiers ---
    println!("\n[1/5] training all classifiers ...");
    let suite = train_suite(&profile, seed);

    // --- 3: FoG design flow ---
    println!("[2/5] FoG topology + threshold selection ...");
    let sel = select_fog(&suite, seed, 0.01);
    println!(
        "  selected topology {}x{}, FoG_opt threshold {:.2} (accuracy {:.1}%, {:.2} avg hops)",
        sel.topology.0,
        sel.topology.1,
        sel.opt.threshold,
        sel.opt.accuracy * 100.0,
        sel.opt.avg_hops
    );

    // --- 4a: software Algorithm 2 ---
    println!("[3/5] software eval / μarch sim / serving coordinator ...");
    let params = FogParams {
        threshold: sel.opt.threshold,
        max_hops: sel.fog.n_groves(),
        seed,
    };
    let sw = sel.fog.evaluate(&suite.data.test.x, &params);

    // --- 4b: cycle-level ring simulation ---
    let mut sim = RingSim::new(
        &sel.fog,
        RingConfig { threshold: sel.opt.threshold, seed, ..Default::default() },
    );
    sim.load_batch(&suite.data.test.x);
    let sim_out = sim.run().to_vec();

    // --- 4c: serving coordinator ---
    let artifacts = fog::runtime::artifacts::default_dir();
    let manifest_ok = artifacts.join("manifest.json").exists();
    let backend = if manifest_ok {
        match fog::runtime::Manifest::load(&artifacts) {
            Ok(m)
                if m.find_grove_step(
                    sel.topology.1,
                    sel.fog.depth,
                    profile.n_features,
                    profile.n_classes,
                )
                .is_some() =>
            {
                println!("  serving backend: PJRT");
                Backend::Pjrt { artifacts_dir: artifacts }
            }
            _ => {
                println!("  serving backend: native (no artifact for {}x{} d={})", sel.topology.0, sel.topology.1, sel.fog.depth);
                Backend::Native
            }
        }
    } else {
        println!("  serving backend: native (artifacts missing)");
        Backend::Native
    };
    let mut server = FogServer::start(
        &sel.fog,
        &ServerConfig {
            threshold: sel.opt.threshold,
            seed,
            backend,
            ..Default::default()
        },
    )
    .expect("server");
    let t0 = std::time::Instant::now();
    let responses = server.classify(&suite.data.test.x);
    let wall = t0.elapsed();

    // --- agreement checks across the three paths ---
    let mut mismatches = 0;
    for ((o, s), r) in sim_out.iter().zip(&sw.outcomes).zip(&responses) {
        if o.label != s.label || r.label != s.label || o.hops != s.hops || r.hops != s.hops {
            mismatches += 1;
        }
    }
    println!(
        "[4/5] agreement: sw==sim==serving on {}/{} inputs ({} mismatches)",
        sim_out.len() - mismatches,
        sim_out.len(),
        mismatches
    );
    assert_eq!(mismatches, 0, "evaluation paths disagree");

    let lat = FogServer::latency_summary(&responses);
    println!(
        "  serving: {:.0} req/s, p50 {:.0}µs p99 {:.0}µs | sim: {:.1} cycles/input avg, {:.1}% PE util",
        responses.len() as f64 / wall.as_secs_f64(),
        lat.p50_us,
        lat.p99_us,
        sim.stats.avg_latency_cycles(),
        sim.stats.avg_utilization() * 100.0
    );
    server.shutdown();

    // --- 5: headline metrics ---
    println!("[5/5] Table-1 row for '{}':", profile.name);
    let rows = evaluate_suite(&suite, seed);
    println!(
        "  {:<10}{:>11}{:>15}{:>13}{:>11}",
        "clf", "accuracy%", "energy nJ", "latency ns", "area mm2"
    );
    for row in &rows {
        println!(
            "  {:<10}{:>11.1}{:>15.2}{:>13.1}{:>11.2}",
            row.kind.label(),
            row.accuracy * 100.0,
            row.report.energy_nj,
            row.report.latency_ns,
            row.report.area_mm2
        );
    }
    let get = |k: ClassifierKind| rows.iter().find(|r| r.kind == k).unwrap();
    let _ = (EnergyBlocks::default(), AreaBlocks::default());
    println!(
        "\nheadline: RF/FoG_opt = {:.2}x | CNN/FoG_opt = {:.1}x | SVM_rbf/FoG_opt = {:.1}x | FoG_opt/SVM_lr = {:.1}x",
        get(ClassifierKind::RandomForest).report.energy_nj / get(ClassifierKind::FogOpt).report.energy_nj,
        get(ClassifierKind::Cnn).report.energy_nj / get(ClassifierKind::FogOpt).report.energy_nj,
        get(ClassifierKind::SvmRbf).report.energy_nj / get(ClassifierKind::FogOpt).report.energy_nj,
        get(ClassifierKind::FogOpt).report.energy_nj / get(ClassifierKind::SvmLinear).report.energy_nj,
    );
    println!("=== E2E pipeline complete ===");
}
