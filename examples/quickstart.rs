//! Quickstart: train a random forest, split it into a Field of Groves
//! (Algorithm 1), classify with confidence-gated hops (Algorithm 2), and
//! compare accuracy + work against the conventional forest. Finishes with
//! the unified `fog::api` view: every model family trained by registry
//! name and driven through one batch-first `Classifier` interface.
//!
//! Run: `cargo run --release --example quickstart`

use fog::api::{Classifier, Estimator, ModelSpec};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::energy::blocks::{AreaBlocks, EnergyBlocks};
use fog::fog::{FieldOfGroves, FogParams};
use fog::forest::{ForestParams, RandomForest, VoteMode};

fn main() {
    // 1. A small synthetic dataset (8 features, 3 classes).
    let ds = generate(&DatasetProfile::demo(), 42);
    println!(
        "dataset: {} train / {} test, {} features, {} classes",
        ds.train.len(),
        ds.test.len(),
        ds.n_features(),
        ds.n_classes()
    );

    // 2. Conventional random forest (paper §3.1).
    let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 7);
    let rf_acc = rf.accuracy(&ds.test, VoteMode::Majority);
    println!(
        "RF: {} trees, depth ≤ {}, majority-vote accuracy {:.1}%",
        rf.n_trees(),
        rf.max_depth(),
        rf_acc * 100.0
    );

    // 3. Field of Groves: Algorithm 1 — split into groves of 4 (4x4).
    let fog = FieldOfGroves::from_forest(&rf, 4);
    println!("FoG topology: {}x{}", fog.topology().0, fog.topology().1);

    // 4. Algorithm 2 at a few thresholds: accuracy vs average groves used.
    println!("\n{:<12}{:>12}{:>12}{:>14}", "threshold", "accuracy%", "avg hops", "trees used");
    for thr in [0.1f32, 0.3, 0.5, 0.8, 1.01] {
        let res = fog.evaluate(
            &ds.test.x,
            &FogParams { threshold: thr, max_hops: fog.n_groves(), seed: 1 },
        );
        println!(
            "{:<12.2}{:>12.1}{:>12.2}{:>14.1}",
            thr,
            res.accuracy(&ds.test.y) * 100.0,
            res.avg_hops(),
            res.avg_hops() * fog.groves[0].n_trees() as f64,
        );
    }
    println!(
        "\nAt threshold ≈0.3 the FoG matches the forest's accuracy while \
         consulting a fraction of its trees — that fraction is the energy \
         saving the paper reports (Table 1: FoG_opt vs RF)."
    );

    // 5. The unified API: any registry model behind one batch-first trait.
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    println!("\n{:<10}{:>12}{:>14}", "model", "accuracy%", "energy (nJ)");
    for name in ["svm_lr", "rf", "fog_opt"] {
        let spec = ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
            .expect("registry name");
        let model = spec.fit(&ds.train, 7); // Box<dyn fog::api::Classifier>
        let report = model.cost_report(Some(&ds.test), &eb, &ab);
        println!(
            "{:<10}{:>12.1}{:>14.2}",
            name,
            model.accuracy(&ds.test) * 100.0,
            report.energy_nj
        );
    }
    println!(
        "\nSame data, three model families, zero model-specific code — the \
         `fog::api::Classifier` trait is the single dispatch surface."
    );
}
