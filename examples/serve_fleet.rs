//! Fleet serving demo: two FoG operating points (`fog_opt` + `fog_max`)
//! behind one energy-aware admission front end, driven by the seeded
//! open-loop load generator while the energy budget sweeps from loose to
//! tight — the paper's Fig 5 trade-off happening live. Early points
//! serve everything; as the budget drops below `fog_max`'s measured
//! nJ/class its traffic downgrades onto `fog_opt` (or sheds under
//! `--policy strict`), and below `fog_opt`'s cost the fleet sheds
//! outright.
//!
//! Run: `cargo run --release --example serve_fleet -- \
//!        [--dataset demo] [--qps 800] [--secs 1.0] [--points 5] \
//!        [--policy downgrade] [--replicas 4] [--seed 42] [--pace]`

use fog::api::{BackendKind, Classifier, Estimator, FleetPolicyKind, ModelSpec};
use fog::coordinator::{
    loadgen, EnergyBudget, Fleet, FleetConfig, LoadgenConfig, ModelServerConfig,
};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::data::Dataset;
use fog::exec::Backend;
use fog::util::cli::Args;
use std::sync::Arc;

/// Standalone uarch energy per classification over the test split — the
/// calibration the budget sweep is anchored to.
fn tile_energy_nj(model: &Arc<dyn Classifier>, ds: &Dataset) -> f64 {
    let backend = model.exec_backend(BackendKind::Uarch).expect("uarch backend");
    let (_, report) = backend.evaluate_tile(&ds.test.x, ds.test.len());
    report.energy_per_class_nj()
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let profile = DatasetProfile::by_name(args.get_or("dataset", "demo")).expect("dataset");
    let policy = FleetPolicyKind::parse(args.get_or("policy", "downgrade"))
        .unwrap_or_else(|| {
            panic!("unknown policy; valid: {}", FleetPolicyKind::NAMES.join(", "))
        });
    let seed = args.get_u64("seed", 42);
    let qps = args.get_f64("qps", 800.0);
    let secs = args.get_f64("secs", 1.0);
    let points = args.get_usize("points", 5).max(2);

    eprintln!("training fog_opt + fog_max on {} ...", profile.name);
    let ds = generate(&profile, seed);
    let names = ["fog_opt", "fog_max"];
    let models: Vec<Arc<dyn Classifier>> = names
        .iter()
        .map(|name| {
            let spec = ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
                .expect("registry model");
            let model: Arc<dyn Classifier> = Arc::from(spec.fit(&ds.train, seed));
            model
        })
        .collect();
    let e_opt = tile_energy_nj(&models[0], &ds);
    let e_max = tile_energy_nj(&models[1], &ds);
    println!(
        "operating points : fog_opt {e_opt:.2} nJ/class, fog_max {e_max:.2} nJ/class \
         ({:.1}x)",
        e_max / e_opt.max(1e-12)
    );

    let lg = LoadgenConfig {
        qps_start: qps / 5.0,
        qps_end: qps,
        duration_s: secs,
        seed,
        pace: args.get_bool("pace"),
        ..LoadgenConfig::default()
    };
    println!(
        "open-loop load   : ramp {:.0} -> {:.0} qps over {secs:.2}s (seed {seed}, \
         policy {})",
        lg.qps_start,
        lg.qps_end,
        policy.label()
    );
    println!(
        "{:>16} | {:>6} {:>6} {:>6} {:>6} | {:>18} | {:>18}",
        "budget nJ/class", "served", "downgr", "shed", "shed%", "fog_opt p99/nJ", "fog_max p99/nJ"
    );

    // Sweep the budget from comfortably above fog_max (nothing trips)
    // down past fog_opt (everything trips) — the Fig 5 x-axis, walked
    // live. Each point gets a fresh fleet so gauges never carry over,
    // and the identical seed replays the identical arrival schedule.
    for p in 0..points {
        let frac = p as f64 / (points - 1) as f64;
        let budget_nj = (1.25 * e_max) * (1.0 - frac) + (0.75 * e_opt) * frac;
        let cfg = FleetConfig {
            total_replicas: args.get_usize("replicas", 4),
            worker: ModelServerConfig { backend: BackendKind::Uarch, ..Default::default() },
            router_seed: seed,
            budget: EnergyBudget {
                energy_per_class_nj: Some(budget_nj),
                ..EnergyBudget::default()
            },
            policy,
            ..FleetConfig::default()
        };
        let registered = names
            .iter()
            .zip(&models)
            .map(|(n, m)| (n.to_string(), Arc::clone(m)))
            .collect();
        let mut fleet = Fleet::start(registered, &cfg).expect("fleet start");
        let report = loadgen::run(&mut fleet, &ds.test.x, &lg).expect("loadgen run");
        let (opt_m, max_m) = (&report.per_model[0], &report.per_model[1]);
        println!(
            "{budget_nj:>16.2} | {:>6} {:>6} {:>6} {:>5.1}% | {:>10.0}us {:>5.2} | \
             {:>10.0}us {:>5.2}",
            report.served,
            report.downgraded,
            report.shed,
            report.shed_rate * 100.0,
            opt_m.latency.p99_us,
            opt_m.energy_per_class_nj,
            max_m.latency.p99_us,
            max_m.energy_per_class_nj,
        );
        fleet.shutdown();
    }
    println!(
        "reading          : downgr > 0 is fog_max traffic living on fog_opt's budget; \
         shed rises once no operating point is affordable"
    );
}
