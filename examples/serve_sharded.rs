//! Sharded serving demo: N replicas of one registry model behind the
//! shared `ShardRouter` and a quantized `ProbCache` — the scale-out
//! counterpart of `serve_fog.rs`. The second measured round replays the
//! same traffic so the cache hit rate is visible; at quantization step 0
//! every hit is byte-identical to cold evaluation.
//!
//! Run: `cargo run --release --example serve_sharded -- \
//!        [--model rf] [--replicas 4] [--router least_loaded] \
//!        [--cache-quant 0.0] [--rounds 3] [--dataset demo]`

use fog::api::{Classifier, Estimator, ModelSpec, REGISTRY};
use fog::coordinator::{RouterPolicy, ShardedServer, ShardedServerConfig};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::util::cli::Args;
use std::sync::Arc;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let profile = DatasetProfile::by_name(args.get_or("dataset", "demo")).expect("dataset");
    let model_name = args.get_or("model", "rf");
    let router = RouterPolicy::parse(args.get_or("router", "least_loaded"))
        .expect("router: random | round_robin | least_loaded");

    let spec = ModelSpec::for_shape(model_name, profile.n_features, profile.n_classes)
        .unwrap_or_else(|| panic!("unknown model '{model_name}'; valid: {}", REGISTRY.join(", ")))
        .with_replicas(args.get_usize("replicas", 4))
        .with_router(router)
        .with_cache_quant(args.get_f64("cache-quant", 0.0) as f32);

    eprintln!("training {model_name} on {} ...", profile.name);
    let data = generate(&profile, 42);
    let model: Arc<dyn Classifier> = Arc::from(spec.fit(&data.train, 42));
    let offline_acc = model.accuracy(&data.test);

    // Every replica clones the Arc handle: one trained model (and for
    // tree families one ForestArena) however many replicas serve it.
    let cfg = ShardedServerConfig::for_serving(&spec.serving);
    let mut server = ShardedServer::start(Arc::clone(&model), &cfg);

    let rounds = args.get_usize("rounds", 3).max(1);
    let t0 = std::time::Instant::now();
    let mut responses = Vec::new();
    for _ in 0..rounds {
        responses = server.classify(&data.test.x).expect("aligned batch");
    }
    let wall = t0.elapsed().as_secs_f64();
    let n_total = responses.len() * rounds;

    let preds: Vec<usize> = responses.iter().map(|r| r.label).collect();
    let acc = fog::util::stats::accuracy(&preds, &data.test.y);
    let snap = server.snapshot();
    println!(
        "model        : {model_name} x{} replicas ({})",
        server.n_replicas(),
        cfg.router.label()
    );
    println!("requests     : {n_total} ({} per round x {rounds})", responses.len());
    println!("accuracy     : {:.1}% served vs {:.1}% offline", acc * 100.0, offline_acc * 100.0);
    println!("avg batch    : {:.1}", snap.avg_batch_size());
    println!(
        "cache        : {:.1}% hit rate ({} hits / {} misses, quant {})",
        snap.cache_hit_rate() * 100.0,
        snap.cache_hits,
        snap.cache_misses,
        spec.serving.cache_quant.unwrap_or(0.0)
    );
    println!("throughput   : {:.0} req/s", n_total as f64 / wall);
    for r in 0..server.n_replicas() {
        let rs = server.replica_metrics(r).snapshot();
        println!(
            "replica {r}    : {} responses, {} batches ({:.1} avg), {:.0} resp/s",
            rs.responses,
            rs.batches,
            rs.avg_batch_size(),
            rs.responses as f64 / wall
        );
    }
    server.shutdown();
}
