//! Fixed-point quantization for the arena kernel: the [`QuantMode`]
//! serving knob, per-feature threshold-code tables ([`QuantTables`])
//! computed at [`ForestArena`](super::ForestArena) pack time, and the
//! [`QuantizedLane`] trait the integer tile path is generic over
//! (mirroring the arena's crate-private `CursorIdx`).
//!
//! The embedded-energy literature (HOG-vs-CNN, arXiv 1703.05853) ships
//! comparator datapaths as fixed point, not f32; this module is the
//! software analogue. The key trick is that a tree walk never needs the
//! feature *values* — only the outcomes of `x > t` against the finite set
//! of thresholds the forest actually contains. So **exact** mode codes
//! each feature value by its *rank* among that feature's sorted distinct
//! live thresholds ("cuts"):
//!
//! ```text
//! code(v) = #{ cuts strictly below v }        (partition_point)
//! code(t) = rank(t)                           for a live threshold t
//! ⟹  v > t  ⟺  code(v) > code(t)            for every f32 v, incl.
//!                                             NaN (→0, goes left) and
//!                                             ±inf (→0 / len)
//! ```
//!
//! so integer-lane comparisons reproduce the f32 walk **bit for bit** —
//! the conformance suites pin this for every registry model on both
//! execution backends. A feature fits a `u8` lane when it has ≤ 254
//! distinct cuts (`u8::MAX` is reserved as the dead-node sentinel), a
//! `u16` lane up to 65534; wider forests fall back to the f32 lanes.
//! **Lossy** mode trades that guarantee for a fixed `bits`-wide affine
//! code over each feature's live-threshold range — bounded by an
//! accuracy-delta test rather than byte identity.

use std::sync::Arc;

/// How (and whether) the tile kernel quantizes feature lanes.
///
/// Parsed from the CLI / surfaced through
/// [`ServingSpec`](crate::api::spec::ServingSpec) like the other serving
/// knobs ([`RouterPolicy`](crate::coordinator::RouterPolicy) et al.).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QuantMode {
    /// f32 lanes (the pre-quantization kernel).
    #[default]
    Off,
    /// Threshold-rank codes: integer lanes pinned byte-identical to the
    /// f32 walk. Lane width (u8 / u16) is chosen per arena from the cut
    /// counts; arenas too wide for u16 fall back to f32 silently — the
    /// mode is a *permission* to quantize, never a change of answers.
    Exact,
    /// Affine fixed-point codes at `bits` ≤ 16 bits per feature
    /// (`bits` ≤ 8 runs in u8 lanes). Answers may drift within the
    /// accuracy-delta bound pinned by `tests/quant.rs`.
    Lossy { bits: u8 },
}

impl QuantMode {
    /// CLI spellings accepted by [`QuantMode::parse`].
    pub const NAMES: &'static [&'static str] = &["off", "u8", "u16", "exact", "lossy8", "lossy16"];

    /// Parse a CLI spelling. `u8`/`u16`/`exact` all select exact
    /// rank-code quantization (the lane width is an arena property — the
    /// narrowest width whose codes fit — so the spellings are synonyms;
    /// `serve --quant u8` is pinned answer-identical to `--quant off`).
    pub fn parse(s: &str) -> Option<QuantMode> {
        match s {
            "off" => Some(QuantMode::Off),
            "u8" | "u16" | "exact" => Some(QuantMode::Exact),
            "lossy8" => Some(QuantMode::Lossy { bits: 8 }),
            "lossy16" => Some(QuantMode::Lossy { bits: 16 }),
            _ => None,
        }
    }

    /// Canonical label for CLI echo / BENCH_JSON.
    pub fn label(self) -> &'static str {
        match self {
            QuantMode::Off => "off",
            QuantMode::Exact => "exact",
            QuantMode::Lossy { bits } if bits <= 8 => "lossy8",
            QuantMode::Lossy { .. } => "lossy16",
        }
    }

    /// Any quantization requested (exact or lossy)?
    pub fn is_on(self) -> bool {
        self != QuantMode::Off
    }
}

/// Per-feature threshold-code tables, computed once at arena pack time
/// and shared (via `Arc`) by the tile kernel and the serving tier's
/// [`ProbCache`](crate::coordinator::ProbCache) keys — one quantization
/// scheme per model, never two.
#[derive(Clone, Debug, Default)]
pub struct QuantTables {
    n_features: usize,
    /// Prefix offsets: feature `k`'s sorted distinct live thresholds are
    /// `cuts[cut_off[k]..cut_off[k + 1]]`.
    cut_off: Vec<usize>,
    cuts: Vec<f32>,
    /// Largest per-feature cut count — decides the exact lane width.
    max_cuts: usize,
    /// Per-feature live-threshold range for lossy affine codes
    /// (`lo == hi` when a feature has at most one live threshold).
    lo: Vec<f32>,
    hi: Vec<f32>,
}

impl QuantTables {
    /// Build tables from every **live** `(feature, threshold)` node of a
    /// packed forest (the caller filters dead/leaf sentinels).
    pub fn build(n_features: usize, nodes: impl Iterator<Item = (usize, f32)>) -> QuantTables {
        let mut per: Vec<Vec<f32>> = vec![Vec::new(); n_features];
        for (k, t) in nodes {
            per[k].push(t);
        }
        let mut cut_off = Vec::with_capacity(n_features + 1);
        cut_off.push(0usize);
        let mut cuts = Vec::new();
        let mut lo = vec![0.0f32; n_features];
        let mut hi = vec![0.0f32; n_features];
        let mut max_cuts = 0usize;
        for (k, mut v) in per.into_iter().enumerate() {
            // Live thresholds are finite, so total_cmp == partial order.
            v.sort_by(f32::total_cmp);
            v.dedup();
            if let (Some(&a), Some(&b)) = (v.first(), v.last()) {
                lo[k] = a;
                hi[k] = b;
            }
            max_cuts = max_cuts.max(v.len());
            cuts.extend_from_slice(&v);
            cut_off.push(cuts.len());
        }
        QuantTables { n_features, cut_off, cuts, max_cuts, lo, hi }
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Feature `k`'s sorted distinct live thresholds.
    #[inline]
    pub fn cuts(&self, k: usize) -> &[f32] {
        &self.cuts[self.cut_off[k]..self.cut_off[k + 1]]
    }

    /// Largest per-feature distinct-threshold count in the forest.
    pub fn max_cuts(&self) -> usize {
        self.max_cuts
    }

    /// Do exact value codes (0..=cuts) stay below the u8 dead sentinel?
    pub fn fits_u8(&self) -> bool {
        self.max_cuts < u8::MAX as usize
    }

    /// Do exact value codes stay below the u16 dead sentinel?
    pub fn fits_u16(&self) -> bool {
        self.max_cuts < u16::MAX as usize
    }

    /// Exact rank code of a feature value: the number of feature-`k` cuts
    /// strictly below `v`. NaN compares false against every cut, so it
    /// codes to 0 and walks left — exactly like the f32 `>` comparison.
    #[inline]
    pub fn code(&self, k: usize, v: f32) -> usize {
        self.cuts(k).partition_point(|c| *c < v)
    }

    /// Exact rank code of a **live threshold**: its index among the cuts
    /// (the threshold must be present — packing inserts every live one).
    #[inline]
    pub fn thr_code(&self, k: usize, t: f32) -> usize {
        let cuts = self.cuts(k);
        let r = cuts.partition_point(|c| *c < t);
        debug_assert!(r < cuts.len() && cuts[r] == t, "threshold missing from cut table");
        r
    }

    /// Lossy affine code of a feature value at `bits` ≤ 16: `v` clamped
    /// to the feature's live-threshold range, scaled onto
    /// `0..=2^bits - 2` (the lane MAX — `2^bits - 1` at bits = 8/16 —
    /// stays reserved for the dead-node sentinel). NaN saturates to 0
    /// via the `as` cast — left, like the exact path.
    #[inline]
    pub fn lossy_code(&self, k: usize, v: f32, bits: u8) -> usize {
        lossy_affine(self.lo[k], self.hi[k], lossy_levels(bits), v)
    }

    /// Per-feature range minima backing the lossy affine codes — fed to
    /// the vectorized coding pass (`exec::simd::code_lossy_row`) as one
    /// contiguous load per 8 features.
    #[inline]
    pub(crate) fn lo_table(&self) -> &[f32] {
        &self.lo
    }

    /// Per-feature range maxima backing the lossy affine codes.
    #[inline]
    pub(crate) fn hi_table(&self) -> &[f32] {
        &self.hi
    }
}

/// Bucket count for a lossy affine width: `2^bits - 2` codes (lane MAX
/// stays the dead sentinel), at least one.
#[inline]
pub(crate) fn lossy_levels(bits: u8) -> f32 {
    ((1u32 << bits.clamp(1, 16)) - 2).max(1) as f32
}

/// The scalar lossy affine code body, shared verbatim by
/// [`QuantTables::lossy_code`] and the vector coding pass's scalar
/// reference/tail (`exec::simd::code_lossy_row`) so the two can never
/// drift: `(v - lo) / (hi - lo)` clamped to `[0, 1]`, scaled, truncated.
/// NaN falls through the clamp (Rust `clamp` propagates it) and the `as`
/// cast saturates it to 0 — left, like the exact path.
#[inline(always)]
pub(crate) fn lossy_affine(lo: f32, hi: f32, levels: f32, v: f32) -> usize {
    if hi <= lo {
        // Constant (or cut-free) feature: one bucket.
        return if v > lo { 1 } else { 0 };
    }
    let t = ((v - lo) / (hi - lo)).clamp(0.0, 1.0);
    (t * levels) as usize
}

/// An integer lane type the quantized tile kernel runs on — the feature
/// side of the arena's crate-private `CursorIdx`. `MAX` is the dead-node
/// sentinel: value codes never reach it, so `x_q > MAX` is false and
/// dead slots walk left exactly like `x > f32::INFINITY`.
pub trait QuantizedLane: Copy + Ord + Send + Sync + 'static {
    /// Dead-node threshold sentinel (the lane's maximum).
    const DEAD: Self;
    /// Canonical BENCH_JSON / log label for the lane width.
    const LABEL: &'static str;

    fn from_usize(v: usize) -> Self;

    /// Widen a code losslessly — the low half of a packed `(feat, code)`
    /// gather record (see `ForestArena`'s level-major gather tables).
    fn as_u32(self) -> u32;
}

impl QuantizedLane for u8 {
    const DEAD: u8 = u8::MAX;
    const LABEL: &'static str = "u8";

    #[inline]
    fn from_usize(v: usize) -> u8 {
        debug_assert!(v < u8::MAX as usize, "u8 lane overflow");
        v as u8
    }

    #[inline]
    fn as_u32(self) -> u32 {
        self as u32
    }
}

impl QuantizedLane for u16 {
    const DEAD: u16 = u16::MAX;
    const LABEL: &'static str = "u16";

    #[inline]
    fn from_usize(v: usize) -> u16 {
        debug_assert!(v < u16::MAX as usize, "u16 lane overflow");
        v as u16
    }

    #[inline]
    fn as_u32(self) -> u32 {
        self as u32
    }
}

/// Shared handle alias — the tables ride the arena behind an `Arc` so the
/// serving tier (cache keys) and the kernel quantize through one table.
pub type SharedQuantTables = Arc<QuantTables>;

#[cfg(test)]
mod tests {
    use super::*;

    fn tables() -> QuantTables {
        // Feature 0: cuts {1.0, 2.5, 7.0}; feature 1: none; feature 2:
        // one repeated cut {4.0}.
        QuantTables::build(
            3,
            vec![(0, 2.5), (0, 1.0), (0, 7.0), (0, 2.5), (2, 4.0), (2, 4.0)].into_iter(),
        )
    }

    #[test]
    fn rank_codes_order_values_against_every_cut() {
        let t = tables();
        assert_eq!(t.cuts(0), &[1.0, 2.5, 7.0]);
        assert_eq!(t.max_cuts(), 3);
        // v > cut  ⟺  code(v) > thr_code(cut), exhaustively around the
        // cut grid.
        for &cut in t.cuts(0) {
            let r = t.thr_code(0, cut);
            for v in [-1.0f32, 0.0, 1.0, 1.5, 2.5, 3.0, 7.0, 9.0] {
                assert_eq!(v > cut, t.code(0, v) > r, "v={v} cut={cut}");
            }
        }
    }

    #[test]
    fn non_finite_values_walk_like_f32() {
        let t = tables();
        for &cut in t.cuts(0) {
            let r = t.thr_code(0, cut);
            assert!(t.code(0, f32::NAN) <= r, "NaN must go left");
            assert!(t.code(0, f32::NEG_INFINITY) <= r, "-inf must go left");
            assert!(t.code(0, f32::INFINITY) > r, "+inf must go right");
        }
    }

    #[test]
    fn cut_free_and_single_cut_features() {
        let t = tables();
        // No cuts: every value codes to 0 (no comparison can fire).
        assert_eq!(t.cuts(1), &[] as &[f32]);
        assert_eq!(t.code(1, 123.0), 0);
        // Repeated threshold dedups to a single cut.
        assert_eq!(t.cuts(2), &[4.0]);
        assert_eq!(t.thr_code(2, 4.0), 0);
        assert_eq!(t.code(2, 3.9), 0);
        assert_eq!(t.code(2, 4.0), 0);
        assert_eq!(t.code(2, 4.1), 1);
    }

    #[test]
    fn lane_fit_bounds_respect_dead_sentinel() {
        // 254 cuts: codes reach 254 == u8 dead sentinel - 1 → fits.
        let t = QuantTables::build(1, (0..254).map(|i| (0usize, i as f32)));
        assert!(t.fits_u8() && t.fits_u16());
        // 255 cuts: a value above every cut would code to 255 == DEAD.
        let t = QuantTables::build(1, (0..255).map(|i| (0usize, i as f32)));
        assert!(!t.fits_u8() && t.fits_u16());
    }

    #[test]
    fn lossy_codes_clamp_and_saturate() {
        let t = tables();
        assert_eq!(t.lossy_code(0, f32::NEG_INFINITY, 8), 0);
        assert_eq!(t.lossy_code(0, f32::INFINITY, 8), 254, "lane MAX stays the dead sentinel");
        assert_eq!(t.lossy_code(0, f32::NAN, 8), 0, "NaN saturates left");
        // Constant feature: everything at/below the cut is bucket 0.
        assert_eq!(t.lossy_code(2, 4.0, 8), 0);
        assert_eq!(t.lossy_code(2, 5.0, 8), 1);
        // Monotone over the range.
        assert!(t.lossy_code(0, 2.0, 8) <= t.lossy_code(0, 6.0, 8));
    }

    #[test]
    fn mode_labels_roundtrip() {
        for &name in QuantMode::NAMES {
            let m = QuantMode::parse(name).expect("listed name parses");
            assert!(QuantMode::parse(m.label()).is_some());
        }
        assert_eq!(QuantMode::parse("u8"), Some(QuantMode::Exact));
        assert_eq!(QuantMode::parse("bogus"), None);
        assert_eq!(QuantMode::default(), QuantMode::Off);
        assert!(!QuantMode::Off.is_on() && QuantMode::Exact.is_on());
    }
}
