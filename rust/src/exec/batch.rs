//! [`BatchPlan`] — the tiled, level-synchronous batch-prediction kernel
//! over a [`ForestArena`] tree range.
//!
//! A batch is cut into tiles of [`DEFAULT_TILE`] samples. The output
//! `ProbMatrix` is allocated once and split into tile-aligned row chunks
//! across the thread pool ([`par_row_chunks_mut`]); each worker reduces
//! its tiles straight into its output rows, reusing one thread-local
//! cursor buffer across every level, tree and sample of its chunk — the
//! per-sample `Vec` allocations of the old one-row-at-a-time path
//! disappear from the hot loop. Within a tile the traversal is
//! level-synchronous (outer loop over levels, inner loop over samples),
//! so every level touches one contiguous arena region.
//!
//! The floating-point reduction order is *identical* to the per-tree
//! reference paths (`RandomForest::predict_proba`, per-tree majority
//! votes): trees accumulate in index order and the average is applied
//! once at the end, so arena results are bit-identical to per-tree
//! `FlatTree` traversal.

use super::arena::ForestArena;
use crate::api::ProbMatrix;
use crate::util::threadpool::par_row_chunks_mut;

/// Samples per tile. Cursor state is `n_trees × TILE × 4 B` — small
/// enough to stay cache-resident next to the tile's input rows.
pub const DEFAULT_TILE: usize = 64;

/// How per-tree leaves reduce to one distribution per sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduce {
    /// Mean of the per-tree leaf distributions (FoG groves / `rf_prob`).
    ProbAverage,
    /// Per-tree argmax labels counted into vote fractions (conventional
    /// RF; argmax of the row is the majority-vote winner).
    MajorityVote,
}

/// A configured batch evaluation over a tree range of an arena.
#[derive(Clone, Debug)]
pub struct BatchPlan<'a> {
    arena: &'a ForestArena,
    lo: usize,
    hi: usize,
    reduce: Reduce,
    tile: usize,
}

impl<'a> BatchPlan<'a> {
    /// Plan over the whole forest.
    pub fn new(arena: &'a ForestArena, reduce: Reduce) -> BatchPlan<'a> {
        Self::over_range(arena, 0, arena.n_trees(), reduce)
    }

    /// Plan over the tree range `[lo, hi)` (a grove slice).
    pub fn over_range(arena: &'a ForestArena, lo: usize, hi: usize, reduce: Reduce) -> BatchPlan<'a> {
        assert!(lo < hi && hi <= arena.n_trees(), "bad tree range {lo}..{hi}");
        BatchPlan { arena, lo, hi, reduce, tile: DEFAULT_TILE }
    }

    /// Override the tile size (results are tile-size independent).
    pub fn with_tile(mut self, tile: usize) -> BatchPlan<'a> {
        self.tile = tile.max(1);
        self
    }

    /// Evaluate a row-major batch `x: [n, n_features]`. The output matrix
    /// is allocated once; workers write their tiles straight into
    /// disjoint row ranges of it, each reusing one cursor scratch across
    /// every tile of its chunk.
    pub fn execute(&self, x: &[f32], n: usize) -> ProbMatrix {
        let f = self.arena.n_features();
        let c = self.arena.n_classes();
        assert_eq!(x.len(), n * f, "batch shape mismatch");
        let tile = self.tile.max(1).min(n.max(1));
        let t_cnt = self.hi - self.lo;
        // Parallel grain: one chunk per worker, but never coarser than
        // what keeps every worker busy — small batches split below the
        // cache tile rather than running single-threaded (results are
        // grain-independent, see `results_independent_of_tile_size`).
        let block =
            tile.min(n.div_ceil(crate::util::threadpool::num_threads()).max(1));
        let mut data = vec![0.0f32; n * c];
        par_row_chunks_mut(&mut data, c, block, |first_row, chunk| {
            let mut cursors = vec![0u32; t_cnt * tile];
            let rows = chunk.len() / c;
            let mut s0 = 0;
            while s0 < rows {
                let s1 = (s0 + tile).min(rows);
                let m = s1 - s0;
                self.run_tile(
                    &x[(first_row + s0) * f..(first_row + s1) * f],
                    m,
                    &mut cursors[..t_cnt * m],
                    &mut chunk[s0 * c..s1 * c],
                );
                s0 = s1;
            }
        });
        ProbMatrix::new(data, c)
    }

    /// One tile: traverse level-synchronously, then reduce leaves into
    /// `acc` (the tile's zero-initialized output rows).
    fn run_tile(&self, x: &[f32], n: usize, cursors: &mut [u32], acc: &mut [f32]) {
        let a = self.arena;
        let c = a.n_classes();
        let t_cnt = self.hi - self.lo;
        a.traverse_tile(self.lo, self.hi, x, n, cursors);
        let inv = 1.0 / t_cnt as f32;
        match self.reduce {
            Reduce::ProbAverage => {
                for j in 0..t_cnt {
                    for s in 0..n {
                        let leaf = a.leaf_slice(self.lo + j, cursors[j * n + s] as usize);
                        for (o, &p) in acc[s * c..(s + 1) * c].iter_mut().zip(leaf) {
                            *o += p;
                        }
                    }
                }
            }
            Reduce::MajorityVote => {
                for j in 0..t_cnt {
                    for s in 0..n {
                        let leaf = a.leaf_slice(self.lo + j, cursors[j * n + s] as usize);
                        acc[s * c + crate::util::argmax(leaf)] += 1.0;
                    }
                }
            }
        }
        acc.iter_mut().for_each(|v| *v *= inv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest};

    fn setup() -> (RandomForest, ForestArena, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 341);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 2);
        let arena = ForestArena::from_forest(&rf, rf.max_depth());
        (rf, arena, ds)
    }

    #[test]
    fn prob_average_matches_forest_bitwise() {
        let (rf, arena, ds) = setup();
        let n = ds.test.len();
        let probs = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&ds.test.x, n);
        for i in 0..n {
            let reference = rf.predict_proba(ds.test.row(i));
            assert_eq!(probs.row(i), &reference[..], "row {i}");
        }
    }

    #[test]
    fn majority_vote_matches_forest() {
        let (rf, arena, ds) = setup();
        let n = ds.test.len();
        let probs = BatchPlan::new(&arena, Reduce::MajorityVote).execute(&ds.test.x, n);
        let inv = 1.0 / rf.n_trees() as f32;
        for i in 0..n {
            let x = ds.test.row(i);
            let mut votes = vec![0.0f32; ds.n_classes()];
            for tree in &rf.trees {
                votes[tree.predict(x)] += 1.0;
            }
            votes.iter_mut().for_each(|v| *v *= inv);
            assert_eq!(probs.row(i), &votes[..], "row {i}");
        }
    }

    #[test]
    fn results_independent_of_tile_size() {
        let (_, arena, ds) = setup();
        let n = ds.test.len();
        let full = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&ds.test.x, n);
        for tile in [1, 7, 64, 1024] {
            let tiled = BatchPlan::new(&arena, Reduce::ProbAverage)
                .with_tile(tile)
                .execute(&ds.test.x, n);
            assert_eq!(full, tiled, "tile {tile}");
        }
    }

    #[test]
    fn range_plan_matches_sub_forest() {
        let (rf, arena, ds) = setup();
        let probs = BatchPlan::over_range(&arena, 2, 5, Reduce::ProbAverage)
            .execute(&ds.test.x[..10 * ds.n_features()], 10);
        let flats = rf.flatten(rf.max_depth());
        for i in 0..10 {
            let x = ds.test.row(i);
            let mut acc = vec![0.0f32; ds.n_classes()];
            for t in &flats[2..5] {
                for (a, &p) in acc.iter_mut().zip(t.predict_proba(x)) {
                    *a += p;
                }
            }
            acc.iter_mut().for_each(|v| *v *= 1.0 / 3.0);
            assert_eq!(probs.row(i), &acc[..], "row {i}");
        }
    }

    #[test]
    fn empty_batch_is_empty_matrix() {
        let (_, arena, _) = setup();
        let probs = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&[], 0);
        assert_eq!(probs.n_rows(), 0);
    }

    #[test]
    #[should_panic(expected = "bad tree range")]
    fn empty_tree_range_rejected() {
        // A plan over an empty grove slice (lo == hi) must be rejected
        // loudly — it would otherwise divide by a zero tree count.
        let (_, arena, _) = setup();
        let _ = BatchPlan::over_range(&arena, 3, 3, Reduce::ProbAverage);
    }

    #[test]
    fn leaf_only_arena_evaluates_through_plan() {
        // Depth-0 (leaf-only) trees: the tiled kernel runs zero levels
        // and every row gets the per-tree leaf average.
        let mut s = crate::data::Split::new(2, 3);
        for _ in 0..4 {
            s.push(&[0.5, -0.5], 1);
        }
        let mut rng = crate::util::rng::Rng::new(6);
        let tree = crate::dt::builder::fit_tree(
            &s,
            &[0, 1, 2, 3],
            &crate::dt::builder::TreeParams::default(),
            &mut rng,
        );
        assert_eq!(tree.depth, 0);
        let flat = crate::dt::FlatTree::from_tree(&tree, 0);
        let arena = ForestArena::from_flat_trees(&[flat.clone(), flat]);
        let x = [1.0f32, 2.0, -3.0, 4.0]; // 2 rows
        let probs = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&x, 2);
        assert_eq!(probs.n_rows(), 2);
        for i in 0..2 {
            assert_eq!(probs.row(i), &[0.0, 1.0, 0.0], "row {i}");
        }
        let votes = BatchPlan::new(&arena, Reduce::MajorityVote).execute(&x, 2);
        assert_eq!(votes.row(0), &[0.0, 1.0, 0.0]);
    }
}
