//! [`BatchPlan`] — the tiled, level-synchronous batch-prediction kernel
//! over a [`ForestArena`] tree range.
//!
//! A batch is cut into tiles of [`BatchPlan::tile`] samples (chosen by
//! [`BatchPlan::auto_tile`] from the arena shape and thread count unless
//! overridden). The output `ProbMatrix` is allocated once and split into
//! row chunks across the thread pool ([`par_row_chunks_mut`]); each
//! worker reduces its tiles straight into its output rows, reusing one
//! thread-local cursor + transpose scratch across every level, tree and
//! sample of its chunk — no allocation on the hot loop.
//!
//! Kernel structure (the perf levers, in order of leverage):
//!
//! * **Ragged live-depth early exit** — the traversal only walks each
//!   tree to its live depth and finishes shallow trees' cursors in
//!   closed form (see the arena module docs): a mixed-depth forest does
//!   Σ live_depth comparisons per sample instead of trees × padded
//!   depth, which is exactly the comparator-op saving the paper's
//!   energy argument is built on (FoG §4, Table 1).
//! * **Feature-major tiles** — each tile is transposed once into a
//!   contiguous scratch so the inner comparison loop reads feature
//!   columns stride-1 instead of striding `n_features` through row-major
//!   input.
//! * **Narrow cursors** — cursor scratch is `u16` whenever the arena
//!   depth allows (`depth ≤ 15`), halving the hot state, and is sized to
//!   the worker chunk's actual rows, never the full-tile worst case.
//!
//! * **Quantized integer lanes** ([`BatchPlan::with_quant`]) — the tile
//!   transpose runs each feature value through the arena's pack-time
//!   threshold-code tables (`exec::quant`; the fixed-point comparator
//!   datapath of arXiv 1703.05853) so the inner compare loop runs on
//!   u8/u16 columns against `thr_q8`/`thr_q16`. Exact rank codes keep
//!   the walk byte-identical to f32; lossy affine codes trade a bounded
//!   accuracy delta for a fixed lane width.
//! * **SIMD dispatch on the integer lanes** — [`BatchPlan::with_quant`]
//!   also resolves a [`SimdLevel`] (best host ISA, `FOG_FORCE_SCALAR=1`
//!   pins scalar; [`BatchPlan::with_simd`] overrides for benches/tests)
//!   and the per-level compare/advance then runs 8–32 samples per
//!   instruction through `exec::simd` — byte-identical to the scalar
//!   loop, which remains the fallback for f32 lanes, u32 cursors and
//!   vector-width tails.
//! * **Vectorized gather** ([`BatchPlan::with_gather`],
//!   `FOG_FORCE_SCALAR_GATHER=1` pins scalar) — integer-lane plans also
//!   carry the arena's packed `(feat << 16) | code` gather records and
//!   over-allocate the transposed tile by `GATHER_PAD` slack elements,
//!   so the vector kernels' per-sample operand loads become AVX2
//!   `vpgatherdd` index gathers (NEON: a `tbl` threshold lookup on
//!   shallow levels) instead of scalar loops — again byte-identical,
//!   with the scalar gather stage as the everywhere-else fallback.
//! * **Vectorized lossy coding** ([`BatchPlan::with_scalar_coding`]
//!   pins the per-value reference) — lossy plans run the affine
//!   `(x − lo)/(hi − lo) → clamp → scale → truncate` chain through
//!   `exec::simd::code_lossy_row` (8 features/instruction on AVX2, 4 on
//!   NEON) per source row during the tile transpose, byte-identical to
//!   the per-value scalar coding (NaN→left, saturation and degenerate
//!   ranges preserved exactly).
//!
//! The floating-point reduction order is *identical* to the per-tree
//! reference paths (`RandomForest::predict_proba`, per-tree majority
//! votes): trees accumulate in index order and the average is applied
//! once at the end, so arena results are bit-identical to per-tree
//! `FlatTree` traversal — tile size, parallel grain, cursor width and
//! early exit are all pure work-savers ([`BatchPlan::with_padded_walk`]
//! keeps the pre-exit full-depth walk around as the bench/conformance
//! baseline).
//!
//! **Adaptive confidence early exit** ([`BatchPlan::with_adaptive`],
//! Daghero et al., arXiv 2205.13838): an orthogonal, *per-sample* effort
//! knob. Trees still accumulate in index order, but after each tree the
//! running average's confidence margin
//! ([`crate::fog::confidence::max_diff`]) is checked against a threshold
//! `t`; once it crosses, the remaining trees are skipped and the sample's
//! row is the average over the trees actually evaluated. `t = 1.0` (or
//! any `t ≥ 1.0`) disables the mode and routes through the plain tiled
//! kernel, so full-threshold results are byte-identical to non-adaptive
//! evaluation by construction — the conformance pin `rust/tests/adaptive.rs`
//! holds this across models, backends and quant lanes. Each sample's exit
//! point depends only on its own feature row and the tree order, never on
//! tile or batch packing, so adaptive results stay batch-composition
//! independent. Comparator-op *accounting* stays at the padded-depth
//! hardware charge (Table 1 / Fig 4–5 stable); the saved work is
//! reported separately as `ExecReport::trees_skipped`.

use super::arena::{CursorIdx, ForestArena};
use super::quant::{lossy_levels, QuantMode, QuantizedLane};
use super::simd::{code_lossy_row, GatherMode, SimdLane, SimdLevel, GATHER_PAD};
use crate::api::ProbMatrix;
use crate::util::threadpool::{num_threads, par_row_chunks_mut};
use std::borrow::Cow;

/// Historical default tile; [`BatchPlan::auto_tile`] supersedes it but
/// plans fall back to it if the footprint model degenerates.
pub const DEFAULT_TILE: usize = 64;

/// Bounds of the auto-tile search: below 16 rows the per-tile transpose
/// overhead dominates; above 512 the tile state outgrows L2 on every
/// machine we care about.
const MIN_TILE: usize = 16;
const MAX_TILE: usize = 512;

/// Per-worker hot-scratch budget the auto-tiler targets (≈ a
/// conservative private-L2 share) and the total shared-cache budget it
/// divides among workers.
const TILE_CACHE_BUDGET: usize = 192 * 1024;
const CACHE_TOTAL_BUDGET: usize = 4 * 1024 * 1024;

/// Deepest arena whose bottom-level leaf indices still fit a `u16`
/// cursor (`2^15` leaves).
const U16_MAX_DEPTH: usize = 15;

/// Minimum rows per parallel chunk: a tiny batch runs on fewer workers
/// rather than shattering into single-row chunks that pay one thread
/// wake-up per row.
const MIN_GRAIN_ROWS: usize = 8;

/// How per-tree leaves reduce to one distribution per sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduce {
    /// Mean of the per-tree leaf distributions (FoG groves / `rf_prob`).
    ProbAverage,
    /// Per-tree argmax labels counted into vote fractions (conventional
    /// RF; argmax of the row is the majority-vote winner).
    MajorityVote,
}

/// The resolved feature/threshold lane a plan's tiles run on: f32 (no
/// quantization), or integer rank/affine codes. Exact lanes borrow the
/// arena's pack-time tables; lossy lanes own a table built at
/// [`BatchPlan::with_quant`] time.
#[derive(Clone, Debug)]
enum LanePlan<'a> {
    F32,
    U8(Cow<'a, [u8]>),
    U16(Cow<'a, [u16]>),
}

/// A configured batch evaluation over a tree range of an arena.
#[derive(Clone, Debug)]
pub struct BatchPlan<'a> {
    arena: &'a ForestArena,
    lo: usize,
    hi: usize,
    reduce: Reduce,
    tile: usize,
    /// Bench/conformance baseline: walk every padded level instead of
    /// exiting at each tree's live depth (results identical either way).
    padded_walk: bool,
    /// Requested quantization mode (see [`BatchPlan::with_quant`]).
    quant: QuantMode,
    /// Lane resolved from `quant` and the arena's code widths.
    lanes: LanePlan<'a>,
    /// Vector dispatch level for the integer lanes, resolved once at
    /// [`BatchPlan::with_quant`] time (zero per-tile dispatch cost);
    /// always `Scalar` for f32 lanes.
    simd: SimdLevel,
    /// Packed `(feat << 16) | code` gather records matching `lanes`
    /// (exact lanes borrow the arena's pack-time tables, lossy lanes own
    /// a table built beside their threshold codes); empty for f32 lanes.
    nodes: Cow<'a, [u32]>,
    /// Gather-stage mode for the vector kernels, resolved once at
    /// [`BatchPlan::with_quant`] time (`FOG_FORCE_SCALAR_GATHER=1` pins
    /// scalar; [`BatchPlan::with_gather`] overrides for benches/tests).
    gather: GatherMode,
    /// Bench/conformance pin: force the per-value scalar coding closure
    /// in the tile transpose instead of the vectorized lossy-affine row
    /// pass (results identical either way).
    scalar_coding: bool,
    /// Adaptive early-exit confidence threshold, already filtered to the
    /// effective range (see [`BatchPlan::with_adaptive`]): `None` = full
    /// evaluation.
    adaptive: Option<f32>,
}

impl<'a> BatchPlan<'a> {
    /// Plan over the whole forest.
    pub fn new(arena: &'a ForestArena, reduce: Reduce) -> BatchPlan<'a> {
        Self::over_range(arena, 0, arena.n_trees(), reduce)
    }

    /// Plan over the tree range `[lo, hi)` (a grove slice). The tile is
    /// picked by [`BatchPlan::auto_tile`]; override with
    /// [`BatchPlan::with_tile`].
    pub fn over_range(arena: &'a ForestArena, lo: usize, hi: usize, reduce: Reduce) -> BatchPlan<'a> {
        assert!(lo < hi && hi <= arena.n_trees(), "bad tree range {lo}..{hi}");
        let tile = Self::auto_tile(arena, hi - lo);
        BatchPlan {
            arena,
            lo,
            hi,
            reduce,
            tile,
            padded_walk: false,
            quant: QuantMode::Off,
            lanes: LanePlan::F32,
            simd: SimdLevel::Scalar,
            nodes: Cow::Borrowed(&[]),
            gather: GatherMode::Scalar,
            scalar_coding: false,
            adaptive: None,
        }
    }

    /// Pick a tile size from the plan's hot-scratch footprint — cursor
    /// lanes (one per tree, width from the arena depth), the
    /// feature-major transpose, the source rows and the output rows —
    /// against a per-worker cache budget (the shared budget split over
    /// [`num_threads`], clamped to a private-L2 share). Deterministic and
    /// cheap (no timing runs); results are tile-independent, so the
    /// choice is purely a throughput knob.
    pub fn auto_tile(arena: &ForestArena, t_cnt: usize) -> usize {
        let cursor_bytes = if arena.depth() <= U16_MAX_DEPTH { 2 } else { 4 };
        // Hot bytes per tile row: cursors + transposed copy + source row
        // + accumulator row.
        let per_row = t_cnt * cursor_bytes + 8 * arena.n_features() + 4 * arena.n_classes();
        if per_row == 0 {
            return DEFAULT_TILE;
        }
        let budget = (CACHE_TOTAL_BUDGET / num_threads().max(1)).min(TILE_CACHE_BUDGET);
        let tile = (budget / per_row).clamp(MIN_TILE, MAX_TILE);
        tile & !7 // keep row counts 8-aligned for tidy vector tails
    }

    /// Override the tile size (results are tile-size independent).
    pub fn with_tile(mut self, tile: usize) -> BatchPlan<'a> {
        self.tile = tile.max(1);
        self
    }

    /// Force the pre-exit padded walk (every tree × every level). Only
    /// benches and conformance tests want this: answers are identical,
    /// the dead-level work is not.
    pub fn with_padded_walk(mut self, padded: bool) -> BatchPlan<'a> {
        self.padded_walk = padded;
        self
    }

    /// Run the tiles on quantized integer feature lanes. `Exact` picks
    /// the narrowest lane whose pack-time rank codes fit this arena (u8,
    /// then u16) and is byte-identical to the f32 walk — when neither
    /// width fits, the plan silently keeps f32 lanes: the mode is a
    /// *permission* to quantize, never a change of answers. `Lossy`
    /// builds an owned affine threshold table here and may move answers
    /// within the accuracy-delta bound pinned by `tests/quant.rs`.
    pub fn with_quant(mut self, mode: QuantMode) -> BatchPlan<'a> {
        self.quant = mode;
        self.lanes = match mode {
            QuantMode::Off => LanePlan::F32,
            QuantMode::Exact => {
                if let Some(t) = self.arena.thr_q8() {
                    LanePlan::U8(Cow::Borrowed(t))
                } else if let Some(t) = self.arena.thr_q16() {
                    LanePlan::U16(Cow::Borrowed(t))
                } else {
                    LanePlan::F32
                }
            }
            QuantMode::Lossy { bits } => {
                if bits <= 8 {
                    LanePlan::U8(Cow::Owned(self.arena.lossy_thr::<u8>(bits)))
                } else {
                    LanePlan::U16(Cow::Owned(self.arena.lossy_thr::<u16>(bits)))
                }
            }
        };
        // Integer lanes get the best vector kernel this host supports
        // (`FOG_FORCE_SCALAR=1` pins the scalar reference); f32 lanes
        // have no vector form. Resolved here, once per plan.
        self.simd = match self.lanes {
            LanePlan::F32 => SimdLevel::Scalar,
            _ => SimdLevel::detect(),
        };
        // Matching packed gather records: exact lanes borrow the arena's
        // pack-time tables, lossy lanes pack their own codes once here.
        // Empty (no vector gather, scalar stage only) when the arena
        // built none — e.g. > 2^16 features.
        self.nodes = match &self.lanes {
            LanePlan::F32 => Cow::Borrowed(&[]),
            LanePlan::U8(t) => match mode {
                QuantMode::Exact => Cow::Borrowed(self.arena.gather_q8()),
                _ => Cow::Owned(self.arena.pack_gather(t.as_ref())),
            },
            LanePlan::U16(t) => match mode {
                QuantMode::Exact => Cow::Borrowed(self.arena.gather_q16()),
                _ => Cow::Owned(self.arena.pack_gather(t.as_ref())),
            },
        };
        self.gather = match self.lanes {
            LanePlan::F32 => GatherMode::Scalar,
            _ => GatherMode::detect(),
        };
        self
    }

    /// Override the vector dispatch level — a bench/conformance knob:
    /// the `quant_wide` bench times native dispatch against
    /// forced-scalar tiles in-process, and test suites pin every
    /// supported level against `Scalar`. Apply *after*
    /// [`BatchPlan::with_quant`], which (re)resolves the level. Levels
    /// this host can't execute — and any level on f32 lanes, which have
    /// no vector kernel — clamp to `Scalar`, so the `unsafe` kernels
    /// stay unreachable where they would fault.
    pub fn with_simd(mut self, level: SimdLevel) -> BatchPlan<'a> {
        self.simd = if level.supported() && !matches!(self.lanes, LanePlan::F32) {
            level
        } else {
            SimdLevel::Scalar
        };
        self
    }

    /// The vector ISA level the plan's tiles actually run at: `Scalar`
    /// unless integer lanes are active, cursors are u16
    /// (`depth ≤ U16_MAX_DEPTH`), and the plan is non-adaptive (the
    /// adaptive path is a per-sample scalar walk). This is the
    /// observability surface behind the serve/fleet `simd` label.
    pub fn simd_level(&self) -> SimdLevel {
        if self.adaptive.is_some()
            || self.arena.depth() > U16_MAX_DEPTH
            || matches!(self.lanes, LanePlan::F32)
        {
            SimdLevel::Scalar
        } else {
            self.simd
        }
    }

    /// [`BatchPlan::simd_level`] as its BENCH_JSON label.
    pub fn simd_label(&self) -> &'static str {
        self.simd_level().label()
    }

    /// Override the gather-stage mode — a bench/conformance knob mirroring
    /// [`BatchPlan::with_simd`]: the `quant_wide` bench times the native
    /// index-gather against the scalar gather stage in-process, and the
    /// plan-equality tests pin the two byte-identical. Apply *after*
    /// [`BatchPlan::with_quant`], which (re)resolves the mode. f32 lanes
    /// (no vector kernel, hence no gather stage) clamp to `Scalar`.
    pub fn with_gather(mut self, mode: GatherMode) -> BatchPlan<'a> {
        self.gather = if matches!(self.lanes, LanePlan::F32) { GatherMode::Scalar } else { mode };
        self
    }

    /// The ISA whose *index-gather* kernel the plan's tiles actually
    /// dispatch: `Avx2` (`vpgatherdd`, both lane widths) or `Neon` (the
    /// `tbl` threshold lookup, u8 lanes — it covers levels of ≤ 16
    /// nodes, deeper ones keep the in-kernel scalar stage), and `Scalar`
    /// everywhere a vector gather can't or was pinned not to run
    /// (forced-scalar gather, SSE2, f32 lanes, missing record tables,
    /// adaptive/deep-arena scalar plans). This is the observability
    /// surface behind the serve/fleet `gather` label, and the
    /// `gather_speedup_x` floor arms only when it is non-scalar.
    pub fn gather_level(&self) -> SimdLevel {
        if self.gather != GatherMode::Vector || self.nodes.is_empty() {
            return SimdLevel::Scalar;
        }
        match (self.simd_level(), &self.lanes) {
            (SimdLevel::Avx2, LanePlan::U8(_) | LanePlan::U16(_)) => SimdLevel::Avx2,
            (SimdLevel::Neon, LanePlan::U8(_)) => SimdLevel::Neon,
            _ => SimdLevel::Scalar,
        }
    }

    /// [`BatchPlan::gather_level`] as its BENCH_JSON label.
    pub fn gather_label(&self) -> &'static str {
        self.gather_level().label()
    }

    /// Pin the tile transpose to the per-value scalar coding closure —
    /// the reference the vectorized lossy-affine row pass is benched and
    /// conformance-tested against (results identical either way; exact
    /// lanes are unaffected, their rank coding is not an affine pass).
    pub fn with_scalar_coding(mut self, scalar: bool) -> BatchPlan<'a> {
        self.scalar_coding = scalar;
        self
    }

    /// The ISA the lossy affine coding pass actually runs at: the plan's
    /// resolved vector level for lossy integer-lane plans (AVX2/NEON
    /// have coding kernels; SSE2 codes scalar), `Scalar` for everything
    /// else — exact/f32 lanes (no affine pass), a pinned
    /// [`BatchPlan::with_scalar_coding`], or the adaptive per-sample
    /// walk (which never builds a tile).
    pub fn coding_level(&self) -> SimdLevel {
        if self.scalar_coding
            || self.adaptive.is_some()
            || !matches!(self.quant, QuantMode::Lossy { .. })
            || matches!(self.lanes, LanePlan::F32)
        {
            return SimdLevel::Scalar;
        }
        match self.simd {
            SimdLevel::Avx2 => SimdLevel::Avx2,
            SimdLevel::Neon => SimdLevel::Neon,
            _ => SimdLevel::Scalar,
        }
    }

    /// [`BatchPlan::coding_level`] as its BENCH_JSON label.
    pub fn coding_label(&self) -> &'static str {
        self.coding_level().label()
    }

    /// Enable Daghero-style adaptive early exit (arXiv 2205.13838):
    /// accumulate tree votes in index order and stop a sample once the
    /// running average's confidence margin
    /// ([`crate::fog::confidence::max_diff`]) reaches `t`. Thresholds
    /// `≥ 1.0` (and non-finite values) are filtered out here, so the
    /// full-threshold plan *is* the plain tiled kernel — `t = 1.0`
    /// results are byte-identical to non-adaptive evaluation by
    /// construction, the house conformance pin. Adaptive plans walk the
    /// f32 thresholds per sample regardless of the quant lane: exact
    /// rank codes answer identically anyway, and lossy modes evaluate
    /// exactly under adaptive (the per-sample walk has no integer tile).
    pub fn with_adaptive(mut self, t: Option<f32>) -> BatchPlan<'a> {
        self.adaptive = t.filter(|v| v.is_finite() && *v < 1.0);
        self
    }

    /// The effective adaptive threshold (`None` when the plan runs the
    /// plain full-evaluation kernel — including when `with_adaptive` was
    /// called with `t ≥ 1.0`).
    pub fn adaptive_threshold(&self) -> Option<f32> {
        self.adaptive
    }

    /// The lane the tiles actually run on (`"f32"`, `"u8"`, `"u16"`) —
    /// the BENCH_JSON / serve-log label.
    pub fn lane_label(&self) -> &'static str {
        match &self.lanes {
            LanePlan::F32 => "f32",
            LanePlan::U8(_) => "u8",
            LanePlan::U16(_) => "u16",
        }
    }

    /// Does an `n`-row batch skip the quantized transpose scratch?
    /// Exact codes answer byte-identically on f32 lanes, so below the
    /// parallel-grain clamp the per-tile quantizing transpose costs more
    /// than it saves and the plan falls back to f32. Lossy lanes *are*
    /// the answer, so they always quantize — results must not depend on
    /// batch composition (a sharded replica sees arbitrary batch sizes).
    fn quant_skipped_for_tiny_batch(&self, n: usize) -> bool {
        !matches!(self.quant, QuantMode::Lossy { .. }) && n < MIN_GRAIN_ROWS
    }

    /// The tile size this plan will cut batches into.
    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Effective tile for an `n`-row batch.
    fn effective_tile(&self, n: usize) -> usize {
        self.tile.max(1).min(n.max(1))
    }

    /// Parallel grain in rows: one chunk per worker, clamped so tiny
    /// batches run on fewer workers instead of shattering into
    /// single-row chunks (results are grain-independent — pinned by
    /// `tiny_batches_do_not_shatter` and `results_independent_of_tile_size`).
    fn grain_rows(&self, n: usize) -> usize {
        self.effective_tile(n).min(n.div_ceil(num_threads()).max(MIN_GRAIN_ROWS))
    }

    /// Evaluate a row-major batch `x: [n, n_features]`. The output matrix
    /// is allocated once; workers write their tiles straight into
    /// disjoint row ranges of it, each reusing one cursor + transpose
    /// scratch across every tile of its chunk.
    pub fn execute(&self, x: &[f32], n: usize) -> ProbMatrix {
        self.execute_counting(x, n).0
    }

    /// [`BatchPlan::execute`] plus the adaptive early-exit work counter:
    /// the second element is the total number of trees *not* evaluated
    /// because samples crossed the confidence threshold (always 0 for
    /// non-adaptive plans, where every sample walks the full tree range).
    pub fn execute_counting(&self, x: &[f32], n: usize) -> (ProbMatrix, u64) {
        match self.adaptive {
            Some(t) => self.execute_adaptive(x, n, t),
            None => (self.execute_plain(x, n), 0),
        }
    }

    /// The full-evaluation tiled kernel (every sample walks every tree
    /// of the range).
    fn execute_plain(&self, x: &[f32], n: usize) -> ProbMatrix {
        if self.arena.depth() <= U16_MAX_DEPTH {
            self.execute_cursor::<u16>(x, n)
        } else {
            self.execute_cursor::<u32>(x, n)
        }
    }

    /// The adaptive early-exit kernel: a per-sample scalar walk in tree
    /// index order (confidence gating is inherently per-sample, like
    /// Algorithm 2's grove walk). After each tree — once past a warm-up
    /// floor of a quarter of the range, Daghero's patience guard against
    /// a single pure leaf faking certainty — the running average is
    /// checked and the sample exits at the first tree where
    /// `max_diff ≥ t` (ties on the threshold exit deterministically via
    /// `≥`). The margin sequence is a pure function of the feature row
    /// and the tree order, so raising `t` can only move the exit later
    /// (monotonicity) and results never depend on tile or batch packing.
    fn execute_adaptive(&self, x: &[f32], n: usize, t: f32) -> (ProbMatrix, u64) {
        use crate::fog::confidence::max_diff;
        use std::sync::atomic::{AtomicU64, Ordering};
        let f = self.arena.n_features();
        let c = self.arena.n_classes();
        assert_eq!(x.len(), n * f, "batch shape mismatch");
        let t_cnt = self.hi - self.lo;
        let min_evals = (t_cnt / 4).max(1);
        let skipped = AtomicU64::new(0);
        let block = self.grain_rows(n);
        let mut data = vec![0.0f32; n * c];
        par_row_chunks_mut(&mut data, c, block, |first_row, chunk| {
            let mut local_skipped = 0u64;
            let mut acc = vec![0.0f32; c];
            let mut norm = vec![0.0f32; c];
            for (s, out) in chunk.chunks_exact_mut(c).enumerate() {
                let row = &x[(first_row + s) * f..(first_row + s + 1) * f];
                acc.iter_mut().for_each(|v| *v = 0.0);
                let mut k = 0usize;
                while k < t_cnt {
                    let tree = self.lo + k;
                    let leaf = self.arena.leaf_slice(tree, self.arena.leaf_index(tree, row));
                    match self.reduce {
                        Reduce::ProbAverage => {
                            for (a, &p) in acc.iter_mut().zip(leaf) {
                                *a += p;
                            }
                        }
                        Reduce::MajorityVote => acc[crate::util::argmax(leaf)] += 1.0,
                    }
                    k += 1;
                    if k >= min_evals && k < t_cnt {
                        let inv = 1.0 / k as f32;
                        for (v, &a) in norm.iter_mut().zip(&acc) {
                            *v = a * inv;
                        }
                        if max_diff(&norm) >= t {
                            break;
                        }
                    }
                }
                local_skipped += (t_cnt - k) as u64;
                // Same reduction order as the tiled kernel: accumulate in
                // tree index order, one final multiply — a sample that
                // walks every tree produces the byte-identical row.
                let inv = 1.0 / k as f32;
                for (o, &a) in out.iter_mut().zip(&acc) {
                    *o = a * inv;
                }
            }
            skipped.fetch_add(local_skipped, Ordering::Relaxed);
        });
        (ProbMatrix::new(data, c), skipped.into_inner())
    }

    /// Dispatch on the resolved lane: the transpose loop doubles as the
    /// quantization pass (one coding of each feature value per tile,
    /// straight into the feature-major scratch — never a second
    /// full-batch pass). Exact lanes fall back to f32 below the parallel
    /// grain ([`BatchPlan::quant_skipped_for_tiny_batch`]).
    fn execute_cursor<C: CursorIdx>(&self, x: &[f32], n: usize) -> ProbMatrix {
        let q = self.arena.quant_tables();
        let nodes = self.nodes.as_ref();
        match (&self.lanes, self.quant) {
            (LanePlan::U8(t), QuantMode::Lossy { bits }) => {
                // The lossy affine pass codes whole source rows through
                // `code_lossy_row` unless pinned scalar, in which case
                // the per-value closure (the reference body) runs.
                let rowwise = (!self.scalar_coding)
                    .then(|| (q.lo_table(), q.hi_table(), lossy_levels(bits)));
                self.execute_with::<C, u8, _>(x, n, t, nodes, rowwise, |k, v| {
                    u8::from_usize(q.lossy_code(k, v, bits))
                })
            }
            (LanePlan::U8(t), _) if !self.quant_skipped_for_tiny_batch(n) => {
                self.execute_with::<C, u8, _>(x, n, t, nodes, None, |k, v| {
                    u8::from_usize(q.code(k, v))
                })
            }
            (LanePlan::U16(t), QuantMode::Lossy { bits }) => {
                let rowwise = (!self.scalar_coding)
                    .then(|| (q.lo_table(), q.hi_table(), lossy_levels(bits)));
                self.execute_with::<C, u16, _>(x, n, t, nodes, rowwise, |k, v| {
                    u16::from_usize(q.lossy_code(k, v, bits))
                })
            }
            (LanePlan::U16(t), _) if !self.quant_skipped_for_tiny_batch(n) => {
                self.execute_with::<C, u16, _>(x, n, t, nodes, None, |k, v| {
                    u16::from_usize(q.code(k, v))
                })
            }
            _ => {
                self.execute_with::<C, f32, _>(x, n, self.arena.thr_table(), &[], None, |_, v| v)
            }
        }
    }

    /// `rowwise` carries the lossy affine coding tables `(lo, hi,
    /// levels)` when the transpose should code whole rows through the
    /// vectorized pass; `None` codes per value through `code` (the
    /// exact/f32 paths, and the pinned scalar-coding reference).
    #[allow(clippy::too_many_arguments)]
    fn execute_with<C, L, Q>(
        &self,
        x: &[f32],
        n: usize,
        thr_tab: &[L],
        nodes_tab: &[u32],
        rowwise: Option<(&[f32], &[f32], f32)>,
        code: Q,
    ) -> ProbMatrix
    where
        C: CursorIdx,
        L: SimdLane + Default + Send + Sync,
        Q: Fn(usize, f32) -> L + Sync,
    {
        let f = self.arena.n_features();
        let c = self.arena.n_classes();
        assert_eq!(x.len(), n * f, "batch shape mismatch");
        let tile = self.effective_tile(n);
        let t_cnt = self.hi - self.lo;
        let block = self.grain_rows(n);
        let coding_level = self.coding_level();
        let mut data = vec![0.0f32; n * c];
        par_row_chunks_mut(&mut data, c, block, |first_row, chunk| {
            let rows = chunk.len() / c;
            // Scratch sized to what this chunk can actually use — a
            // chunk smaller than the tile never pays full-tile buffers.
            // GATHER_PAD slack elements past the transposed tile keep
            // the dword index-gathers in bounds at the buffer's end
            // (pad contents never reach a compare — the kernels mask
            // gathered dwords to the lane width).
            let t = tile.min(rows.max(1));
            let mut cursors = vec![C::ZERO; t_cnt * t];
            let mut xt = vec![L::default(); f * t + GATHER_PAD];
            let mut rowbuf = vec![0u32; if rowwise.is_some() { f } else { 0 }];
            let mut s0 = 0;
            while s0 < rows {
                let s1 = (s0 + tile).min(rows);
                let m = s1 - s0;
                // Transpose the tile feature-major (coding each value
                // into the plan's lane) so each level's compare loop
                // reads stride-1 columns.
                let src = &x[(first_row + s0) * f..(first_row + s1) * f];
                match rowwise {
                    Some((lo_t, hi_t, levels)) => {
                        for (r, row) in src.chunks_exact(f).enumerate() {
                            code_lossy_row(coding_level, lo_t, hi_t, levels, row, &mut rowbuf);
                            for (k, &cv) in rowbuf.iter().enumerate() {
                                xt[k * m + r] = L::from_code(cv);
                            }
                        }
                    }
                    None => {
                        for (r, row) in src.chunks_exact(f).enumerate() {
                            for (k, &v) in row.iter().enumerate() {
                                xt[k * m + r] = code(k, v);
                            }
                        }
                    }
                }
                self.run_tile::<C, L>(
                    &xt[..f * m + GATHER_PAD],
                    m,
                    &mut cursors[..t_cnt * m],
                    &mut chunk[s0 * c..s1 * c],
                    thr_tab,
                    nodes_tab,
                );
                s0 = s1;
            }
        });
        ProbMatrix::new(data, c)
    }

    /// One tile: traverse level-synchronously over the feature-major
    /// tile `xt` (any lane type; carries `GATHER_PAD` slack elements
    /// past `n_features · n`), then reduce leaves into `acc` (the
    /// tile's zero-initialized output rows).
    #[allow(clippy::too_many_arguments)]
    fn run_tile<C: CursorIdx, L: SimdLane>(
        &self,
        xt: &[L],
        n: usize,
        cursors: &mut [C],
        acc: &mut [f32],
        thr_tab: &[L],
        nodes_tab: &[u32],
    ) {
        let a = self.arena;
        let c = a.n_classes();
        let t_cnt = self.hi - self.lo;
        a.traverse_tile_lanes(
            self.lo,
            self.hi,
            xt,
            n,
            cursors,
            thr_tab,
            nodes_tab,
            self.gather,
            self.padded_walk,
            self.simd,
        );
        let inv = 1.0 / t_cnt as f32;
        match self.reduce {
            Reduce::ProbAverage => {
                for j in 0..t_cnt {
                    for s in 0..n {
                        let leaf = a.leaf_slice(self.lo + j, cursors[j * n + s].as_usize());
                        for (o, &p) in acc[s * c..(s + 1) * c].iter_mut().zip(leaf) {
                            *o += p;
                        }
                    }
                }
            }
            Reduce::MajorityVote => {
                for j in 0..t_cnt {
                    for s in 0..n {
                        let leaf = a.leaf_slice(self.lo + j, cursors[j * n + s].as_usize());
                        acc[s * c + crate::util::argmax(leaf)] += 1.0;
                    }
                }
            }
        }
        acc.iter_mut().for_each(|v| *v *= inv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::dt::builder::TreeParams;
    use crate::dt::FlatTree;
    use crate::forest::{ForestParams, RandomForest};

    fn setup() -> (RandomForest, ForestArena, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 341);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 2);
        let arena = ForestArena::from_forest(&rf, rf.max_depth());
        (rf, arena, ds)
    }

    /// A mixed-depth (ragged) arena: deep and depth-capped trees packed
    /// together, homogenized to the deepest.
    fn ragged_arena() -> (ForestArena, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 351);
        let deep = RandomForest::fit(&ds.train, &ForestParams::small(), 3);
        let shallow_params = ForestParams {
            tree: TreeParams { max_depth: 2, ..TreeParams::default() },
            ..ForestParams::small()
        };
        let shallow = RandomForest::fit(&ds.train, &shallow_params, 4);
        let mut trees = deep.flatten(deep.max_depth());
        trees.extend(shallow.flatten(shallow.max_depth()));
        (ForestArena::from_flat_trees(&trees), ds)
    }

    #[test]
    fn prob_average_matches_forest_bitwise() {
        let (rf, arena, ds) = setup();
        let n = ds.test.len();
        let probs = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&ds.test.x, n);
        for i in 0..n {
            let reference = rf.predict_proba(ds.test.row(i));
            assert_eq!(probs.row(i), &reference[..], "row {i}");
        }
    }

    #[test]
    fn majority_vote_matches_forest() {
        let (rf, arena, ds) = setup();
        let n = ds.test.len();
        let probs = BatchPlan::new(&arena, Reduce::MajorityVote).execute(&ds.test.x, n);
        let inv = 1.0 / rf.n_trees() as f32;
        for i in 0..n {
            let x = ds.test.row(i);
            let mut votes = vec![0.0f32; ds.n_classes()];
            for tree in &rf.trees {
                votes[tree.predict(x)] += 1.0;
            }
            votes.iter_mut().for_each(|v| *v *= inv);
            assert_eq!(probs.row(i), &votes[..], "row {i}");
        }
    }

    #[test]
    fn results_independent_of_tile_size() {
        let (_, arena, ds) = setup();
        let n = ds.test.len();
        let full = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&ds.test.x, n);
        for tile in [1, 7, 64, 1024] {
            let tiled = BatchPlan::new(&arena, Reduce::ProbAverage)
                .with_tile(tile)
                .execute(&ds.test.x, n);
            assert_eq!(full, tiled, "tile {tile}");
        }
    }

    #[test]
    fn ragged_arena_matches_padded_walk_bitwise() {
        // The live-depth early exit is a pure work-saver: on a forest
        // mixing depth-2 and deep trees, the ragged kernel's output is
        // byte-identical to the full padded walk, for both reductions.
        let (arena, ds) = ragged_arena();
        assert!(
            arena.skipped_ops_per_eval_range(0, arena.n_trees()) > 0,
            "fixture must actually skip levels"
        );
        let n = ds.test.len();
        for reduce in [Reduce::ProbAverage, Reduce::MajorityVote] {
            let ragged = BatchPlan::new(&arena, reduce).execute(&ds.test.x, n);
            let padded = BatchPlan::new(&arena, reduce)
                .with_padded_walk(true)
                .execute(&ds.test.x, n);
            assert_eq!(ragged, padded, "{reduce:?}");
        }
    }

    #[test]
    fn deep_arena_uses_u32_cursors_and_matches() {
        // Re-pad past the u16 depth bound: the plan must switch to u32
        // cursors and keep byte-identical results.
        let (_, arena, ds) = setup();
        let deep: Vec<FlatTree> =
            (0..arena.n_trees()).map(|t| arena.tree(t).repad(16)).collect();
        let deep_arena = ForestArena::from_flat_trees(&deep);
        assert!(deep_arena.depth() > 15);
        let n = 16.min(ds.test.len());
        let want = BatchPlan::new(&arena, Reduce::ProbAverage)
            .execute(&ds.test.x[..n * arena.n_features()], n);
        let got = BatchPlan::new(&deep_arena, Reduce::ProbAverage)
            .execute(&ds.test.x[..n * arena.n_features()], n);
        assert_eq!(want, got);
    }

    #[test]
    fn auto_tile_bounded_and_deterministic() {
        let (_, arena, _) = setup();
        let tile = BatchPlan::auto_tile(&arena, arena.n_trees());
        assert!((MIN_TILE..=MAX_TILE).contains(&tile), "tile {tile}");
        assert_eq!(tile % 8, 0, "tile {tile} not 8-aligned");
        assert_eq!(tile, BatchPlan::new(&arena, Reduce::ProbAverage).tile());
        // More trees → more cursor state per row → never a larger tile.
        let few = BatchPlan::auto_tile(&arena, 1);
        assert!(tile <= few, "tile grew with tree count ({tile} > {few})");
    }

    #[test]
    fn tiny_batches_do_not_shatter() {
        // Satellite regression: the parallel grain is clamped to
        // MIN_GRAIN_ROWS, so a tiny batch stays in one chunk instead of
        // splitting into per-row thread wake-ups — and results equal the
        // full-batch rows bitwise (grain independence).
        let (_, arena, ds) = setup();
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage);
        for n in [1usize, 2, 3, MIN_GRAIN_ROWS - 1] {
            assert!(
                plan.grain_rows(n) >= n,
                "batch of {n} rows split below the grain clamp ({})",
                plan.grain_rows(n)
            );
        }
        assert!(plan.grain_rows(10_000) >= MIN_GRAIN_ROWS);
        let full = plan.execute(&ds.test.x, ds.test.len());
        for n in [1usize, 3, 5] {
            let small = plan.execute(&ds.test.x[..n * arena.n_features()], n);
            for i in 0..n {
                assert_eq!(small.row(i), full.row(i), "n {n} row {i}");
            }
        }
    }

    #[test]
    fn range_plan_matches_sub_forest() {
        let (rf, arena, ds) = setup();
        let probs = BatchPlan::over_range(&arena, 2, 5, Reduce::ProbAverage)
            .execute(&ds.test.x[..10 * ds.n_features()], 10);
        let flats = rf.flatten(rf.max_depth());
        for i in 0..10 {
            let x = ds.test.row(i);
            let mut acc = vec![0.0f32; ds.n_classes()];
            for t in &flats[2..5] {
                for (a, &p) in acc.iter_mut().zip(t.predict_proba(x)) {
                    *a += p;
                }
            }
            acc.iter_mut().for_each(|v| *v *= 1.0 / 3.0);
            assert_eq!(probs.row(i), &acc[..], "row {i}");
        }
    }

    #[test]
    fn empty_batch_is_empty_matrix() {
        let (_, arena, _) = setup();
        let probs = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&[], 0);
        assert_eq!(probs.n_rows(), 0);
    }

    #[test]
    #[should_panic(expected = "bad tree range")]
    fn empty_tree_range_rejected() {
        // A plan over an empty grove slice (lo == hi) must be rejected
        // loudly — it would otherwise divide by a zero tree count.
        let (_, arena, _) = setup();
        let _ = BatchPlan::over_range(&arena, 3, 3, Reduce::ProbAverage);
    }

    #[test]
    fn leaf_only_arena_evaluates_through_plan() {
        // Depth-0 (leaf-only) trees: the tiled kernel runs zero levels
        // and every row gets the per-tree leaf average.
        let mut s = crate::data::Split::new(2, 3);
        for _ in 0..4 {
            s.push(&[0.5, -0.5], 1);
        }
        let mut rng = crate::util::rng::Rng::new(6);
        let tree = crate::dt::builder::fit_tree(
            &s,
            &[0, 1, 2, 3],
            &crate::dt::builder::TreeParams::default(),
            &mut rng,
        );
        assert_eq!(tree.depth, 0);
        let flat = crate::dt::FlatTree::from_tree(&tree, 0);
        let arena = ForestArena::from_flat_trees(&[flat.clone(), flat]);
        let x = [1.0f32, 2.0, -3.0, 4.0]; // 2 rows
        let probs = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&x, 2);
        assert_eq!(probs.n_rows(), 2);
        for i in 0..2 {
            assert_eq!(probs.row(i), &[0.0, 1.0, 0.0], "row {i}");
        }
        let votes = BatchPlan::new(&arena, Reduce::MajorityVote).execute(&x, 2);
        assert_eq!(votes.row(0), &[0.0, 1.0, 0.0]);
    }

    #[test]
    fn exact_quant_plan_matches_f32_bitwise() {
        // Tentpole conformance at the plan level: exact rank-code lanes
        // replay the identical walk, so probabilities are byte-for-byte
        // the f32 kernel's — for both reductions and a ragged arena.
        let (arena, ds) = ragged_arena();
        assert_eq!(arena.quant_lane(), Some("u8"), "demo fixture should fit u8");
        let n = ds.test.len();
        for reduce in [Reduce::ProbAverage, Reduce::MajorityVote] {
            let f32_plan = BatchPlan::new(&arena, reduce).execute(&ds.test.x, n);
            let q = BatchPlan::new(&arena, reduce)
                .with_quant(QuantMode::Exact)
                .execute(&ds.test.x, n);
            assert_eq!(f32_plan, q, "{reduce:?}");
        }
    }

    #[test]
    fn quant_lane_labels_reflect_mode() {
        let (_, arena, _) = setup();
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage);
        assert_eq!(plan.lane_label(), "f32");
        assert_eq!(plan.with_quant(QuantMode::Exact).lane_label(), "u8");
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage);
        assert_eq!(plan.with_quant(QuantMode::Lossy { bits: 12 }).lane_label(), "u16");
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage);
        assert_eq!(plan.with_quant(QuantMode::Off).lane_label(), "f32");
    }

    #[test]
    fn simd_dispatch_is_byte_identical_to_forced_scalar() {
        // The in-process form of the FOG_FORCE_SCALAR conformance leg:
        // native vector dispatch answers byte-for-byte the forced-scalar
        // plan — for exact and lossy lanes, both reductions, and every
        // level this host supports.
        let (arena, ds) = ragged_arena();
        let n = ds.test.len();
        for mode in [QuantMode::Exact, QuantMode::Lossy { bits: 8 }, QuantMode::Lossy { bits: 12 }]
        {
            for reduce in [Reduce::ProbAverage, Reduce::MajorityVote] {
                let scalar = BatchPlan::new(&arena, reduce)
                    .with_quant(mode)
                    .with_simd(SimdLevel::Scalar)
                    .execute(&ds.test.x, n);
                let native =
                    BatchPlan::new(&arena, reduce).with_quant(mode).execute(&ds.test.x, n);
                assert_eq!(native, scalar, "native dispatch {mode:?} {reduce:?}");
                for level in [SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon] {
                    if !level.supported() {
                        continue;
                    }
                    let vec = BatchPlan::new(&arena, reduce)
                        .with_quant(mode)
                        .with_simd(level)
                        .execute(&ds.test.x, n);
                    assert_eq!(vec, scalar, "{} {mode:?} {reduce:?}", level.label());
                }
            }
        }
    }

    #[test]
    fn simd_level_reports_the_effective_path() {
        let (_, arena, _) = setup();
        // f32 lanes never report a vector level, whatever is requested.
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage);
        assert_eq!(plan.simd_level(), SimdLevel::Scalar);
        assert_eq!(plan.simd_label(), "scalar");
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage).with_simd(SimdLevel::detect());
        assert_eq!(plan.simd_level(), SimdLevel::Scalar, "no vector kernel on f32 lanes");
        // Integer lanes resolve to a level the host can execute.
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage).with_quant(QuantMode::Exact);
        assert!(plan.simd_level().supported());
        assert_eq!(plan.simd_label(), plan.simd_level().label());
        // Foreign levels clamp to Scalar (at most one of x86/arm wins).
        for level in [SimdLevel::Avx2, SimdLevel::Neon] {
            let plan = BatchPlan::new(&arena, Reduce::ProbAverage)
                .with_quant(QuantMode::Exact)
                .with_simd(level);
            if level.supported() {
                assert_eq!(plan.simd_level(), level);
            } else {
                assert_eq!(plan.simd_level(), SimdLevel::Scalar);
            }
        }
        // The adaptive path is a per-sample scalar walk.
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage)
            .with_quant(QuantMode::Exact)
            .with_adaptive(Some(0.5));
        assert_eq!(plan.simd_level(), SimdLevel::Scalar);
        // Deep arenas use u32 cursors, which no vector kernel advances.
        let deep: Vec<FlatTree> =
            (0..arena.n_trees()).map(|t| arena.tree(t).repad(16)).collect();
        let deep_arena = ForestArena::from_flat_trees(&deep);
        let plan = BatchPlan::new(&deep_arena, Reduce::ProbAverage).with_quant(QuantMode::Exact);
        assert_eq!(plan.simd_level(), SimdLevel::Scalar);
    }

    #[test]
    fn tiny_batches_skip_quant_transpose_and_stay_identical() {
        // Satellite regression: below the parallel grain the exact path
        // skips quantized transpose scratch (f32 fallback — identical
        // answers by the exactness proof), while lossy always quantizes
        // so shard splits can't change its answers.
        let (_, arena, ds) = setup();
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage).with_quant(QuantMode::Exact);
        for n in 1..MIN_GRAIN_ROWS {
            assert!(plan.quant_skipped_for_tiny_batch(n), "n {n}");
        }
        assert!(!plan.quant_skipped_for_tiny_batch(MIN_GRAIN_ROWS));
        let lossy =
            BatchPlan::new(&arena, Reduce::ProbAverage).with_quant(QuantMode::Lossy { bits: 8 });
        assert!(!lossy.quant_skipped_for_tiny_batch(1), "lossy must never skip");
        // Batch-size independence across the skip boundary, bitwise.
        let full = plan.execute(&ds.test.x, ds.test.len());
        for n in [1usize, MIN_GRAIN_ROWS - 1, MIN_GRAIN_ROWS, MIN_GRAIN_ROWS + 5] {
            let small = plan.execute(&ds.test.x[..n * arena.n_features()], n);
            for i in 0..n {
                assert_eq!(small.row(i), full.row(i), "n {n} row {i}");
            }
        }
        let lossy_full = lossy.execute(&ds.test.x, ds.test.len());
        for n in [1usize, 3] {
            let small = lossy.execute(&ds.test.x[..n * arena.n_features()], n);
            for i in 0..n {
                assert_eq!(small.row(i), lossy_full.row(i), "lossy n {n} row {i}");
            }
        }
    }

    #[test]
    fn adaptive_full_threshold_is_plain_kernel() {
        // The conformance pin at the plan level: `with_adaptive(1.0)` (and
        // anything ≥ 1.0 or non-finite) filters to None, so the plan runs
        // the plain tiled kernel — byte-identical rows, zero skip count —
        // for both reductions on a ragged arena.
        let (arena, ds) = ragged_arena();
        let n = ds.test.len();
        for reduce in [Reduce::ProbAverage, Reduce::MajorityVote] {
            let plain = BatchPlan::new(&arena, reduce).execute(&ds.test.x, n);
            for t in [1.0f32, 1.5, f32::INFINITY, f32::NAN] {
                let plan = BatchPlan::new(&arena, reduce).with_adaptive(Some(t));
                assert_eq!(plan.adaptive_threshold(), None, "t {t} not filtered");
                let (probs, skipped) = plan.execute_counting(&ds.test.x, n);
                assert_eq!(probs, plain, "{reduce:?} t {t}");
                assert_eq!(skipped, 0, "{reduce:?} t {t}");
            }
            let (_, skipped) =
                BatchPlan::new(&arena, reduce).with_adaptive(None).execute_counting(&ds.test.x, n);
            assert_eq!(skipped, 0);
        }
    }

    #[test]
    fn adaptive_skips_work_and_keeps_valid_rows() {
        let (arena, ds) = ragged_arena();
        let n = ds.test.len();
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage).with_adaptive(Some(0.6));
        assert_eq!(plan.adaptive_threshold(), Some(0.6));
        let (probs, skipped) = plan.execute_counting(&ds.test.x, n);
        assert!(skipped > 0, "demo forest should early-exit at t = 0.6");
        for i in 0..n {
            let row = probs.row(i);
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {i} sums to {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)), "row {i}");
        }
    }

    #[test]
    fn adaptive_trees_evaluated_monotone_in_threshold() {
        // Satellite property: each sample's margin sequence is fixed, so
        // raising `t` can only move its exit later — total trees skipped
        // is non-increasing in the threshold.
        let (arena, ds) = ragged_arena();
        let n = ds.test.len();
        let mut last = u64::MAX;
        for t in [0.1f32, 0.3, 0.5, 0.7, 0.9] {
            let (_, skipped) = BatchPlan::new(&arena, Reduce::ProbAverage)
                .with_adaptive(Some(t))
                .execute_counting(&ds.test.x, n);
            assert!(skipped <= last, "t {t}: skipped {skipped} rose past {last}");
            last = skipped;
        }
    }

    #[test]
    fn adaptive_results_independent_of_batch_packing() {
        // Satellite conformance: a sample exits at the same tree count
        // whether it arrives alone, in a small batch, or in the full
        // split, and whatever the tile size — rows byte-identical, skip
        // totals additive.
        let (arena, ds) = ragged_arena();
        let f = arena.n_features();
        let n = ds.test.len();
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage).with_adaptive(Some(0.5));
        let (full, full_skipped) = plan.execute_counting(&ds.test.x, n);
        for tile in [1usize, 7, 256] {
            let tiled = BatchPlan::new(&arena, Reduce::ProbAverage)
                .with_adaptive(Some(0.5))
                .with_tile(tile)
                .execute_counting(&ds.test.x, n);
            assert_eq!(tiled.0, full, "tile {tile}");
            assert_eq!(tiled.1, full_skipped, "tile {tile} skip count");
        }
        let mut summed = 0u64;
        for i in 0..n {
            let (one, skipped) = plan.execute_counting(&ds.test.x[i * f..(i + 1) * f], 1);
            assert_eq!(one.row(0), full.row(i), "row {i}");
            summed += skipped;
        }
        assert_eq!(summed, full_skipped, "per-row skips don't sum to the batch total");
    }

    #[test]
    fn adaptive_warmup_floor_prevents_single_tree_exit() {
        // A pure (one-hot) leaf has margin 1.0; without the quarter-range
        // warm-up floor every such sample would exit after one tree. The
        // floor forces at least ceil-free t_cnt/4 (≥ 1) evaluations.
        let (arena, ds) = ragged_arena();
        let t_cnt = arena.n_trees() as u64;
        let min_evals = (t_cnt / 4).max(1);
        let n = ds.test.len() as u64;
        let (_, skipped) = BatchPlan::new(&arena, Reduce::ProbAverage)
            .with_adaptive(Some(1e-6))
            .execute_counting(&ds.test.x, n as usize);
        // Even at a near-zero threshold no sample skips past the floor.
        assert!(skipped <= n * (t_cnt - min_evals), "warm-up floor violated");
        assert!(skipped > 0, "near-zero threshold should exit at the floor");
    }

    #[test]
    fn vector_gather_plan_is_byte_identical_to_scalar_gather() {
        // The in-process form of the FOG_FORCE_SCALAR_GATHER conformance
        // leg: a plan with the vector gather stage answers byte-for-byte
        // the scalar-gather plan — exact and lossy lanes, both
        // reductions, every level this host supports. (On hosts whose
        // best level has no gather kernel both plans run the same code;
        // the assert is then trivially true, never wrong.)
        let (arena, ds) = ragged_arena();
        let n = ds.test.len();
        for mode in [QuantMode::Exact, QuantMode::Lossy { bits: 8 }, QuantMode::Lossy { bits: 12 }]
        {
            for reduce in [Reduce::ProbAverage, Reduce::MajorityVote] {
                let scalar = BatchPlan::new(&arena, reduce)
                    .with_quant(mode)
                    .with_gather(GatherMode::Scalar)
                    .execute(&ds.test.x, n);
                let vector = BatchPlan::new(&arena, reduce)
                    .with_quant(mode)
                    .with_gather(GatherMode::Vector)
                    .execute(&ds.test.x, n);
                assert_eq!(vector, scalar, "gather {mode:?} {reduce:?}");
                for level in [SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon] {
                    if !level.supported() {
                        continue;
                    }
                    let vec = BatchPlan::new(&arena, reduce)
                        .with_quant(mode)
                        .with_simd(level)
                        .with_gather(GatherMode::Vector)
                        .execute(&ds.test.x, n);
                    assert_eq!(vec, scalar, "gather {} {mode:?} {reduce:?}", level.label());
                }
            }
        }
    }

    #[test]
    fn vector_coding_plan_is_byte_identical_to_scalar_coding() {
        // The vectorized lossy-affine row pass against the per-value
        // scalar coding closure, at every supported level and lane
        // width — byte identity is the house rule for every fast path.
        let (arena, ds) = ragged_arena();
        let n = ds.test.len();
        for mode in [QuantMode::Lossy { bits: 8 }, QuantMode::Lossy { bits: 12 }] {
            for reduce in [Reduce::ProbAverage, Reduce::MajorityVote] {
                let scalar = BatchPlan::new(&arena, reduce)
                    .with_quant(mode)
                    .with_scalar_coding(true)
                    .execute(&ds.test.x, n);
                let vector =
                    BatchPlan::new(&arena, reduce).with_quant(mode).execute(&ds.test.x, n);
                assert_eq!(vector, scalar, "coding {mode:?} {reduce:?}");
                for level in [SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon] {
                    if !level.supported() {
                        continue;
                    }
                    let vec = BatchPlan::new(&arena, reduce)
                        .with_quant(mode)
                        .with_simd(level)
                        .execute(&ds.test.x, n);
                    assert_eq!(vec, scalar, "coding {} {mode:?} {reduce:?}", level.label());
                }
            }
        }
    }

    #[test]
    fn gather_level_reports_the_effective_path() {
        let (_, arena, _) = setup();
        // f32 lanes: no vector kernel, no gather stage.
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage);
        assert_eq!(plan.gather_level(), SimdLevel::Scalar);
        assert_eq!(plan.gather_label(), "scalar");
        let plan =
            BatchPlan::new(&arena, Reduce::ProbAverage).with_gather(GatherMode::Vector);
        assert_eq!(plan.gather_level(), SimdLevel::Scalar, "f32 lanes clamp the gather");
        // A pinned scalar gather always reports scalar.
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage)
            .with_quant(QuantMode::Exact)
            .with_gather(GatherMode::Scalar);
        assert_eq!(plan.gather_level(), SimdLevel::Scalar);
        // The adaptive per-sample walk has no tile, hence no gather.
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage)
            .with_quant(QuantMode::Exact)
            .with_gather(GatherMode::Vector)
            .with_adaptive(Some(0.5));
        assert_eq!(plan.gather_level(), SimdLevel::Scalar);
        // With vector gather requested, the level tracks the dispatch:
        // AVX2 gathers both widths, NEON only u8, SSE2/Scalar neither.
        for level in [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon] {
            if !level.supported() {
                continue;
            }
            let plan = BatchPlan::new(&arena, Reduce::ProbAverage)
                .with_quant(QuantMode::Exact)
                .with_simd(level)
                .with_gather(GatherMode::Vector);
            let want = match level {
                SimdLevel::Avx2 => SimdLevel::Avx2,
                SimdLevel::Neon if plan.lane_label() == "u8" => SimdLevel::Neon,
                _ => SimdLevel::Scalar,
            };
            assert_eq!(plan.gather_level(), want, "{}", level.label());
            assert_eq!(plan.gather_label(), want.label());
        }
    }

    #[test]
    fn coding_level_reports_the_effective_path() {
        let (_, arena, _) = setup();
        // Exact and f32 plans have no affine pass.
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage);
        assert_eq!(plan.coding_level(), SimdLevel::Scalar);
        assert_eq!(plan.coding_label(), "scalar");
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage).with_quant(QuantMode::Exact);
        assert_eq!(plan.coding_level(), SimdLevel::Scalar);
        // A pinned scalar coding always reports scalar.
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage)
            .with_quant(QuantMode::Lossy { bits: 8 })
            .with_scalar_coding(true);
        assert_eq!(plan.coding_level(), SimdLevel::Scalar);
        // The adaptive walk never builds a tile, hence never codes rows.
        let plan = BatchPlan::new(&arena, Reduce::ProbAverage)
            .with_quant(QuantMode::Lossy { bits: 8 })
            .with_adaptive(Some(0.5));
        assert_eq!(plan.coding_level(), SimdLevel::Scalar);
        // Lossy plans track the resolved level where a coding kernel
        // exists (AVX2/NEON); SSE2 codes scalar.
        for level in [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon] {
            if !level.supported() {
                continue;
            }
            let plan = BatchPlan::new(&arena, Reduce::ProbAverage)
                .with_quant(QuantMode::Lossy { bits: 8 })
                .with_simd(level);
            let want = match level {
                SimdLevel::Avx2 => SimdLevel::Avx2,
                SimdLevel::Neon => SimdLevel::Neon,
                _ => SimdLevel::Scalar,
            };
            assert_eq!(plan.coding_level(), want, "{}", level.label());
            assert_eq!(plan.coding_label(), want.label());
        }
    }

    #[test]
    fn lossy_plan_yields_valid_distributions() {
        let (_, arena, ds) = setup();
        let n = ds.test.len();
        let probs = BatchPlan::new(&arena, Reduce::ProbAverage)
            .with_quant(QuantMode::Lossy { bits: 8 })
            .execute(&ds.test.x, n);
        for i in 0..n {
            let row = probs.row(i);
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {i} sums to {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)), "row {i}");
        }
    }
}
