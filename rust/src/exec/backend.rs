//! [`Backend`] — pluggable execution backends behind every tree-based
//! prediction path.
//!
//! The paper's headline metric is *energy per classification* (§1,
//! §4.2), yet a software serving tier naturally reports only throughput.
//! This module closes that gap by making the execution engine behind a
//! prediction path a first-class, swappable object:
//!
//! * [`SoftwareBackend`] — today's kernels, unchanged: the tiled
//!   level-synchronous [`BatchPlan`] for whole-forest reductions and
//!   Algorithm 2's confidence-gated per-sample arena walk for FoG
//!   operating points. Reports arena-derived comparator-op counts; no
//!   cycle or energy accounting (software has no hardware clock).
//! * [`UarchBackend`] — hardware in the loop: the same sample tiles are
//!   streamed through the cycle-level grove-ring simulator
//!   (`uarch::{pe, ring, queue, handshake, stats}`), and the collected
//!   [`SimStats`] are folded through the PPA block library
//!   ([`crate::energy::model::event_energy_nj`]) into per-tile cycle and
//!   joule estimates. `fog serve --backend uarch` surfaces these as live
//!   energy-per-classification next to throughput.
//!
//! **Conformance invariant** (pinned by `rust/tests/backend.rs`): a
//! backend changes *accounting*, never *answers*. [`UarchBackend`]
//! probability rows are byte-identical to [`SoftwareBackend`] for every
//! tree-based registry model — the simulator is driven with the model's
//! own content-hashed start groves and its PE runs the very same
//! arena-slice arithmetic — and its comparator-op counts equal the
//! arena-derived accounting (`ops_per_eval_range` = trees × padded
//! depth per visited grove), so Table 1 / Fig 4–5 numbers are unchanged.
//!
//! Serving integration: replicas resolve a backend once at start-up via
//! [`Classifier::exec_backend`](crate::api::Classifier::exec_backend)
//! and dispatch every assembled batch through
//! [`Backend::evaluate_tile`], folding the returned [`ExecReport`] into
//! their [`Metrics`](crate::coordinator::Metrics) — the request path is
//! `Router → Replica → Backend → Arena` (see `ARCHITECTURE.md`).

use super::arena::ForestArena;
use super::batch::{BatchPlan, Reduce};
use super::quant::QuantMode;
use crate::api::ProbMatrix;
use crate::energy::blocks::EnergyBlocks;
use crate::fog::eval::content_start_grove;
use crate::fog::{FieldOfGroves, FogParams, Grove};
use crate::uarch::pe::PeModel;
use crate::uarch::{RingConfig, RingSim, SimStats};
use crate::util::threadpool::par_map;
use std::sync::Arc;

/// Execution accounting for one evaluated tile (or an aggregate of
/// tiles — see [`ExecReport::merge`]). Counter semantics follow
/// [`SimStats`]; `energy_nj` is *dynamic* evaluation energy (static /
/// leakage stays in the analytical [`crate::energy::model`] path).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExecReport {
    /// Classifications evaluated.
    pub samples: u64,
    /// Comparator operations (arena-derived: trees × padded depth per
    /// visited grove).
    pub comparator_ops: u64,
    /// Simulated clock cycles spent on the tile (0 for software).
    pub cycles: u64,
    /// Data-queue traffic charged by the simulator.
    pub queue_bytes_read: u64,
    pub queue_bytes_written: u64,
    /// Completed inter-grove transfers.
    pub handshakes: u64,
    /// Groves consulted, summed over samples (1 per sample for whole-
    /// forest reductions).
    pub hops_total: u64,
    /// Dead padded levels the ragged software kernel *did not* walk
    /// (live-depth early exit), summed over trees and samples — the
    /// comparator ops saved relative to `comparator_ops`, which stays at
    /// the padded-depth hardware number. 0 for the μarch backend: the
    /// simulated PE is depth-bound and walks the padding.
    pub levels_skipped: u64,
    /// Whole trees the adaptive confidence early exit
    /// ([`BatchPlan::with_adaptive`]) *did not* evaluate, summed over
    /// samples. Like `levels_skipped` this is a savings gauge reported
    /// beside — never subtracted from — `comparator_ops`, which stays at
    /// the paper-faithful padded-depth charge at every threshold. 0 for
    /// full evaluation and for FoG plans (their effort knob is the hop
    /// count, already visible as `hops_total`).
    pub trees_skipped: u64,
    /// Dynamic evaluation energy in nanojoules (0 for software).
    pub energy_nj: f64,
}

impl ExecReport {
    /// Fold cycle-level simulator counters through the PPA block library
    /// into a report (the `uarch::Stats → energy::model` bridge).
    pub fn from_stats(s: &SimStats, eb: &EnergyBlocks) -> ExecReport {
        ExecReport {
            samples: s.classified,
            comparator_ops: s.comparator_ops,
            cycles: s.cycles,
            queue_bytes_read: s.queue_bytes_read,
            queue_bytes_written: s.queue_bytes_written,
            handshakes: s.handshakes,
            hops_total: s.total_hops,
            // The simulated PE is depth-bound: hardware clocks through
            // padding, so the μarch backend never skips a level, and the
            // simulator has no adaptive-exit notion (the forest arm
            // overlays the software kernel's tree-skip count on top).
            levels_skipped: 0,
            trees_skipped: 0,
            energy_nj: s.dynamic_energy_nj(eb),
        }
    }

    /// Accumulate another tile's counters (saturating adds, so long-lived
    /// servers can never wrap a counter into a bogus rate).
    pub fn merge(&mut self, other: &ExecReport) {
        self.samples = self.samples.saturating_add(other.samples);
        self.comparator_ops = self.comparator_ops.saturating_add(other.comparator_ops);
        self.cycles = self.cycles.saturating_add(other.cycles);
        self.queue_bytes_read = self.queue_bytes_read.saturating_add(other.queue_bytes_read);
        self.queue_bytes_written =
            self.queue_bytes_written.saturating_add(other.queue_bytes_written);
        self.handshakes = self.handshakes.saturating_add(other.handshakes);
        self.hops_total = self.hops_total.saturating_add(other.hops_total);
        self.levels_skipped = self.levels_skipped.saturating_add(other.levels_skipped);
        self.trees_skipped = self.trees_skipped.saturating_add(other.trees_skipped);
        self.energy_nj += other.energy_nj;
    }

    /// Dynamic energy per evaluated classification, nJ (0 when nothing
    /// was evaluated or the backend does not simulate energy).
    pub fn energy_per_class_nj(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.energy_nj / self.samples as f64
        }
    }

    /// Simulated cycles per evaluated classification.
    pub fn cycles_per_class(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.cycles as f64 / self.samples as f64
        }
    }

    /// Comparator operations per evaluated classification.
    pub fn comparator_ops_per_class(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.comparator_ops as f64 / self.samples as f64
        }
    }

    /// Dead padded levels skipped per evaluated classification by the
    /// ragged kernel's live-depth early exit.
    pub fn levels_skipped_per_class(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.levels_skipped as f64 / self.samples as f64
        }
    }

    /// Trees skipped per evaluated classification by the adaptive
    /// confidence early exit (0 when adaptive mode is off).
    pub fn trees_skipped_per_class(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.trees_skipped as f64 / self.samples as f64
        }
    }
}

/// A pluggable execution engine over a compiled forest: evaluates
/// row-major sample tiles and accounts for the work done. Backends are
/// bound to their model (arena / grove ring) at construction, so the
/// serving tier can hold them as trait objects and dispatch every batch
/// through one call.
pub trait Backend: Send + Sync {
    /// CLI / `BENCH_JSON` label (`"software"` / `"uarch"`).
    fn name(&self) -> &'static str;

    /// Evaluate one row-major sample tile `x: [n, n_features]`; returns
    /// the probability rows and the tile's execution report. Rows are
    /// evaluated independently, so results are tile-composition
    /// independent (the conformance suite pins this).
    fn evaluate_tile(&self, x: &[f32], n: usize) -> (ProbMatrix, ExecReport);
}

/// What a backend evaluates: a whole-forest reduction over an arena, or
/// a FoG operating point over its grove ring.
#[derive(Clone, Debug)]
enum TilePlan {
    Forest {
        arena: Arc<ForestArena>,
        reduce: Reduce,
        quant: QuantMode,
        adaptive: Option<f32>,
    },
    Fog { fog: FieldOfGroves, params: FogParams },
}

/// The software forest kernel entry point: every whole-forest prediction
/// path (`RfModel::predict_proba_batch`, software replicas) runs this
/// exact call, so backend and direct results are identical by
/// construction.
pub(crate) fn forest_tile(
    arena: &ForestArena,
    reduce: Reduce,
    x: &[f32],
    n: usize,
) -> (ProbMatrix, ExecReport) {
    forest_tile_quant(arena, reduce, QuantMode::Off, x, n)
}

/// [`forest_tile`] with an integer-lane selection: the [`BatchPlan`]
/// codes the feature tile through the arena's per-feature rank tables
/// ([`super::quant::QuantTables`]) and compares on u8/u16 lanes. Exact
/// mode is answer-identical to the f32 kernel; accounting stays the
/// padded-depth comparator count either way — quantization changes the
/// lane width, never the number of comparisons.
pub(crate) fn forest_tile_quant(
    arena: &ForestArena,
    reduce: Reduce,
    quant: QuantMode,
    x: &[f32],
    n: usize,
) -> (ProbMatrix, ExecReport) {
    forest_tile_adaptive(arena, reduce, quant, None, x, n)
}

/// [`forest_tile_quant`] with an adaptive confidence early-exit
/// threshold: `Some(t < 1.0)` switches the plan to the per-sample
/// vote-accumulation walk ([`BatchPlan::with_adaptive`]) and surfaces
/// the trees it did not evaluate as `ExecReport::trees_skipped`.
/// `comparator_ops` / `levels_skipped` stay the padded-depth accounting
/// numbers at every threshold — the μarch suites and Table 1 / Fig 4–5
/// pin them, so adaptive savings are reported beside, never subtracted.
pub(crate) fn forest_tile_adaptive(
    arena: &ForestArena,
    reduce: Reduce,
    quant: QuantMode,
    adaptive: Option<f32>,
    x: &[f32],
    n: usize,
) -> (ProbMatrix, ExecReport) {
    let (probs, trees_skipped) = BatchPlan::new(arena, reduce)
        .with_quant(quant)
        .with_adaptive(adaptive)
        .execute_counting(x, n);
    // `comparator_ops` stays the padded-depth accounting number (the
    // μarch suites pin it); the ragged kernel's saving is reported
    // separately as `levels_skipped`, the adaptive exit's as
    // `trees_skipped`.
    let report = ExecReport {
        samples: n as u64,
        comparator_ops: (n as u64)
            .saturating_mul(arena.ops_per_eval_range(0, arena.n_trees()) as u64),
        levels_skipped: (n as u64)
            .saturating_mul(arena.skipped_ops_per_eval_range(0, arena.n_trees()) as u64),
        trees_skipped,
        hops_total: n as u64,
        ..Default::default()
    };
    (probs, report)
}

/// The software FoG kernel entry point: Algorithm 2 with content-hashed
/// start groves (`FogModel::predict_proba_batch` and software replicas
/// both run this call). Comparator ops charge every visited grove's
/// arena-derived `ops_per_eval`.
pub(crate) fn fog_tile(
    fog: &FieldOfGroves,
    params: &FogParams,
    x: &[f32],
    n: usize,
) -> (ProbMatrix, ExecReport) {
    let f = fog.n_features;
    assert_eq!(x.len(), n * f, "tile shape mismatch");
    let n_groves = fog.n_groves();
    let outcomes = par_map(n, |i| {
        let row = &x[i * f..(i + 1) * f];
        let start = content_start_grove(params.seed, row, n_groves);
        let o = fog.evaluate_one(row, start, params.threshold, params.max_hops);
        (o.prob, o.hops, start)
    });
    let mut report = ExecReport { samples: n as u64, ..Default::default() };
    let mut rows = Vec::with_capacity(n);
    for (prob, hops, start) in outcomes {
        for j in 0..hops {
            let g = &fog.groves[(start + j) % n_groves];
            report.comparator_ops =
                report.comparator_ops.saturating_add(g.ops_per_eval() as u64);
            report.levels_skipped =
                report.levels_skipped.saturating_add(g.skipped_ops_per_eval() as u64);
        }
        report.hops_total = report.hops_total.saturating_add(hops as u64);
        rows.push(prob);
    }
    (ProbMatrix::from_rows(rows, fog.n_classes), report)
}

/// The software execution backend: today's level-synchronous kernels,
/// unchanged and bit-identical to the models' direct batch paths, with
/// arena-derived comparator-op accounting (no cycles, no joules).
#[derive(Clone, Debug)]
pub struct SoftwareBackend {
    plan: TilePlan,
}

impl SoftwareBackend {
    /// Whole-forest reduction over `[0, n_trees)` of `arena`.
    pub fn forest(arena: Arc<ForestArena>, reduce: Reduce) -> SoftwareBackend {
        SoftwareBackend {
            plan: TilePlan::Forest { arena, reduce, quant: QuantMode::Off, adaptive: None },
        }
    }

    /// A FoG operating point (threshold + hop cap + start-grove seed).
    pub fn fog(fog: FieldOfGroves, params: FogParams) -> SoftwareBackend {
        SoftwareBackend { plan: TilePlan::Fog { fog, params } }
    }

    /// Run forest tiles on quantized integer lanes (no-op for FoG plans
    /// — the per-sample grove walk stays f32).
    pub fn with_quant(mut self, mode: QuantMode) -> SoftwareBackend {
        if let TilePlan::Forest { quant, .. } = &mut self.plan {
            *quant = mode;
        }
        self
    }

    /// Enable adaptive confidence early exit on forest tiles (no-op for
    /// FoG plans — their early exit already lives in `FogParams`, see
    /// `FogModel::with_adaptive`). Same effective-range filter as
    /// [`BatchPlan::with_adaptive`]: `t ≥ 1.0` keeps full evaluation.
    pub fn with_adaptive(mut self, t: Option<f32>) -> SoftwareBackend {
        if let TilePlan::Forest { adaptive, .. } = &mut self.plan {
            *adaptive = t;
        }
        self
    }
}

impl Backend for SoftwareBackend {
    fn name(&self) -> &'static str {
        "software"
    }

    fn evaluate_tile(&self, x: &[f32], n: usize) -> (ProbMatrix, ExecReport) {
        match &self.plan {
            TilePlan::Forest { arena, reduce, quant, adaptive } => {
                forest_tile_adaptive(arena, *reduce, *quant, *adaptive, x, n)
            }
            TilePlan::Fog { fog, params } => fog_tile(fog, params, x, n),
        }
    }
}

/// The hardware-in-the-loop execution backend: answers are byte-identical
/// to [`SoftwareBackend`] (forest tiles run the identical kernel; FoG
/// tiles run the grove-ring simulator, whose PE performs the same
/// arena-slice arithmetic in the same order, driven with the model's own
/// content-hashed start groves), while the accounting comes from the
/// cycle-level machinery: PE latency, queue traffic, handshake stalls,
/// injection backpressure — folded through the PPA block library into
/// per-tile cycles and nanojoules.
#[derive(Clone, Debug)]
pub struct UarchBackend {
    plan: TilePlan,
    eb: EnergyBlocks,
}

impl UarchBackend {
    /// Whole-forest reduction, modeled as the paper's §3.1 RF
    /// accelerator: all trees evaluate in parallel, samples stream
    /// serially through one PE tile.
    pub fn forest(arena: Arc<ForestArena>, reduce: Reduce) -> UarchBackend {
        UarchBackend {
            plan: TilePlan::Forest { arena, reduce, quant: QuantMode::Off, adaptive: None },
            eb: EnergyBlocks::default(),
        }
    }

    /// Run forest tiles on quantized integer lanes. Exact mode mirrors
    /// the fixed-point datapath the paper's comparator hardware would
    /// ship (arXiv 1703.05853); answers and accounting are unchanged.
    pub fn with_quant(mut self, mode: QuantMode) -> UarchBackend {
        if let TilePlan::Forest { quant, .. } = &mut self.plan {
            *quant = mode;
        }
        self
    }

    /// Enable adaptive confidence early exit on forest tiles (no-op for
    /// FoG plans). Answers come from the identical software kernel, so
    /// both backends agree on probabilities *and* `trees_skipped` at
    /// every threshold; the cycle/energy accounting stays the
    /// depth-bound accelerator model.
    pub fn with_adaptive(mut self, t: Option<f32>) -> UarchBackend {
        if let TilePlan::Forest { adaptive, .. } = &mut self.plan {
            *adaptive = t;
        }
        self
    }

    /// A FoG operating point driven through the grove ring (§3.2.2,
    /// Figure 3).
    pub fn fog(fog: FieldOfGroves, params: FogParams) -> UarchBackend {
        UarchBackend { plan: TilePlan::Fog { fog, params }, eb: EnergyBlocks::default() }
    }

    /// Override the PPA block library the energy fold uses.
    pub fn with_energy_blocks(mut self, eb: EnergyBlocks) -> UarchBackend {
        self.eb = eb;
        self
    }
}

impl Backend for UarchBackend {
    fn name(&self) -> &'static str {
        "uarch"
    }

    fn evaluate_tile(&self, x: &[f32], n: usize) -> (ProbMatrix, ExecReport) {
        match &self.plan {
            TilePlan::Forest { arena, reduce, quant, adaptive } => {
                // Answers from the identical software kernel; accounting
                // from the single-tile RF accelerator model: every sample
                // walks all trees in parallel (PE latency is depth-bound),
                // moving one Γ-byte queue word in and out.
                let (probs, sw) = forest_tile_adaptive(arena, *reduce, *quant, *adaptive, x, n);
                let grove = Grove::from_arena(Arc::clone(arena), 0, arena.n_trees());
                let lat = PeModel::default().latency(&grove).max(1);
                let gamma = (1 + arena.n_features() + 1 + arena.n_classes()) as u64;
                let nn = n as u64;
                let stats = SimStats {
                    cycles: nn.saturating_mul(lat),
                    classified: nn,
                    comparator_ops: sw.comparator_ops,
                    queue_bytes_read: nn.saturating_mul(gamma),
                    queue_bytes_written: nn.saturating_mul(gamma),
                    handshakes: 0,
                    stall_cycles: 0,
                    total_latency_cycles: nn.saturating_mul(lat),
                    total_hops: nn,
                    grove_busy_cycles: vec![nn.saturating_mul(lat)],
                };
                let mut report = ExecReport::from_stats(&stats, &self.eb);
                // The simulator knows nothing of the adaptive exit;
                // overlay the software kernel's count so both backends
                // report identical savings (the conformance suite pins
                // this).
                report.trees_skipped = sw.trees_skipped;
                (probs, report)
            }
            TilePlan::Fog { fog, params } => {
                let f = fog.n_features;
                assert_eq!(x.len(), n * f, "tile shape mismatch");
                let n_groves = fog.n_groves();
                let starts: Vec<usize> = (0..n)
                    .map(|i| content_start_grove(params.seed, &x[i * f..(i + 1) * f], n_groves))
                    .collect();
                let cfg = RingConfig {
                    threshold: params.threshold,
                    max_hops: params.max_hops,
                    seed: params.seed,
                    // Serving streams tile entries back-to-back; the
                    // injector's bubble rule still prevents deadlock.
                    inject_interval: 1,
                    ..Default::default()
                };
                let mut sim = RingSim::new(fog, cfg);
                sim.load_batch_with_starts(x, &starts);
                let rows: Vec<Vec<f32>> = sim.run().iter().map(|o| o.prob.clone()).collect();
                let probs = ProbMatrix::from_rows(rows, fog.n_classes);
                (probs, ExecReport::from_stats(&sim.stats, &self.eb))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest};

    fn setup() -> (Arc<ForestArena>, FieldOfGroves, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 911);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 3);
        let arena = Arc::new(ForestArena::from_forest(&rf, rf.max_depth()));
        let fog = FieldOfGroves::from_forest(&rf, 2);
        (arena, fog, ds)
    }

    #[test]
    fn software_forest_matches_batch_plan() {
        let (arena, _, ds) = setup();
        let n = ds.test.len();
        let direct = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&ds.test.x, n);
        let backend = SoftwareBackend::forest(Arc::clone(&arena), Reduce::ProbAverage);
        let (probs, report) = backend.evaluate_tile(&ds.test.x, n);
        assert_eq!(probs, direct);
        assert_eq!(report.samples, n as u64);
        assert_eq!(
            report.comparator_ops,
            (n * arena.ops_per_eval_range(0, arena.n_trees())) as u64
        );
        assert_eq!(
            report.levels_skipped,
            (n * arena.skipped_ops_per_eval_range(0, arena.n_trees())) as u64
        );
        assert_eq!(report.cycles, 0);
        assert_eq!(report.energy_nj, 0.0);
    }

    #[test]
    fn uarch_forest_same_answers_with_accounting() {
        let (arena, _, ds) = setup();
        let n = ds.test.len();
        let sw = SoftwareBackend::forest(Arc::clone(&arena), Reduce::MajorityVote);
        let ua = UarchBackend::forest(Arc::clone(&arena), Reduce::MajorityVote);
        let (p_sw, r_sw) = sw.evaluate_tile(&ds.test.x, n);
        let (p_ua, r_ua) = ua.evaluate_tile(&ds.test.x, n);
        assert_eq!(p_sw, p_ua, "uarch backend changed an answer");
        assert_eq!(r_sw.comparator_ops, r_ua.comparator_ops);
        assert!(r_ua.cycles > 0 && r_ua.energy_nj > 0.0);
        assert!(r_ua.energy_per_class_nj() > 0.0);
    }

    #[test]
    fn uarch_fog_same_answers_with_accounting() {
        let (_, fog, ds) = setup();
        let params = FogParams { threshold: 0.35, max_hops: fog.n_groves(), seed: 9 };
        let sw = SoftwareBackend::fog(fog.clone(), params);
        let ua = UarchBackend::fog(fog.clone(), params);
        let n = ds.test.len();
        let (p_sw, r_sw) = sw.evaluate_tile(&ds.test.x, n);
        let (p_ua, r_ua) = ua.evaluate_tile(&ds.test.x, n);
        assert_eq!(p_sw, p_ua, "simulated FoG answers diverged from Algorithm 2");
        assert_eq!(r_sw.comparator_ops, r_ua.comparator_ops, "op accounting diverged");
        assert_eq!(r_sw.hops_total, r_ua.hops_total);
        assert!(r_ua.cycles > 0 && r_ua.energy_nj > 0.0);
        assert_eq!(r_sw.cycles, 0);
    }

    #[test]
    fn quantized_backends_keep_answers_and_accounting() {
        // Exact lanes on both backends: probabilities and the padded-
        // depth comparator accounting are byte-identical to QuantMode::Off.
        let (arena, _, ds) = setup();
        let n = ds.test.len();
        let (p_off, r_off) = SoftwareBackend::forest(Arc::clone(&arena), Reduce::ProbAverage)
            .evaluate_tile(&ds.test.x, n);
        let (p_q, r_q) = SoftwareBackend::forest(Arc::clone(&arena), Reduce::ProbAverage)
            .with_quant(QuantMode::Exact)
            .evaluate_tile(&ds.test.x, n);
        assert_eq!(p_off, p_q, "exact quantization changed a software answer");
        assert_eq!(r_off, r_q, "quantization changed software accounting");
        let (u_off, ur_off) = UarchBackend::forest(Arc::clone(&arena), Reduce::ProbAverage)
            .evaluate_tile(&ds.test.x, n);
        let (u_q, ur_q) = UarchBackend::forest(Arc::clone(&arena), Reduce::ProbAverage)
            .with_quant(QuantMode::Exact)
            .evaluate_tile(&ds.test.x, n);
        assert_eq!(u_off, u_q, "exact quantization changed a uarch answer");
        assert_eq!(ur_off, ur_q, "quantization changed uarch accounting");
    }

    #[test]
    fn adaptive_backends_agree_and_keep_accounting() {
        // Adaptive early exit changes neither the comparator-op charge
        // nor backend agreement: software and uarch report identical
        // probabilities and trees_skipped, and the padded-depth
        // accounting is byte-equal to the full-evaluation report.
        let (arena, _, ds) = setup();
        let n = ds.test.len();
        let (_, full) = SoftwareBackend::forest(Arc::clone(&arena), Reduce::ProbAverage)
            .evaluate_tile(&ds.test.x, n);
        assert_eq!(full.trees_skipped, 0);
        let (p_sw, r_sw) = SoftwareBackend::forest(Arc::clone(&arena), Reduce::ProbAverage)
            .with_adaptive(Some(0.5))
            .evaluate_tile(&ds.test.x, n);
        let (p_ua, r_ua) = UarchBackend::forest(Arc::clone(&arena), Reduce::ProbAverage)
            .with_adaptive(Some(0.5))
            .evaluate_tile(&ds.test.x, n);
        assert_eq!(p_sw, p_ua, "adaptive answers diverged across backends");
        assert!(r_sw.trees_skipped > 0, "demo forest should early-exit at t = 0.5");
        assert_eq!(r_sw.trees_skipped, r_ua.trees_skipped, "skip accounting diverged");
        assert_eq!(r_sw.comparator_ops, full.comparator_ops, "adaptive changed the charge");
        assert_eq!(r_sw.levels_skipped, full.levels_skipped);
        assert!((r_sw.trees_skipped_per_class() - r_sw.trees_skipped as f64 / n as f64).abs()
            < 1e-12);
        // t = 1.0 routes to the plain kernel: whole report byte-equal.
        let (p_one, r_one) = SoftwareBackend::forest(Arc::clone(&arena), Reduce::ProbAverage)
            .with_adaptive(Some(1.0))
            .evaluate_tile(&ds.test.x, n);
        let (p_full, _) = SoftwareBackend::forest(Arc::clone(&arena), Reduce::ProbAverage)
            .evaluate_tile(&ds.test.x, n);
        assert_eq!(p_one, p_full, "t = 1.0 must be byte-identical to full evaluation");
        assert_eq!(r_one, full);
    }

    #[test]
    fn reports_merge_saturating() {
        let mut a = ExecReport {
            samples: u64::MAX - 1,
            comparator_ops: 10,
            energy_nj: 1.5,
            ..Default::default()
        };
        let b = ExecReport { samples: 5, comparator_ops: 2, energy_nj: 0.5, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.samples, u64::MAX);
        assert_eq!(a.comparator_ops, 12);
        assert!((a.energy_nj - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_tile_is_empty() {
        let (arena, _, _) = setup();
        let backend = SoftwareBackend::forest(arena, Reduce::ProbAverage);
        let (probs, report) = backend.evaluate_tile(&[], 0);
        assert_eq!(probs.n_rows(), 0);
        assert_eq!(report.samples, 0);
        assert_eq!(report.energy_per_class_nj(), 0.0);
        assert_eq!(report.cycles_per_class(), 0.0);
    }
}
