//! [`ForestArena`] — every flat tree of a forest packed into one
//! contiguous structure-of-arrays allocation.
//!
//! Layout (all trees padded to one common complete-tree `depth`):
//!
//! * **Nodes are level-major.** Level `ℓ` of the whole forest occupies
//!   `feat[level_off[ℓ] ..]` / `thr[level_off[ℓ] ..]`; within a level,
//!   trees are consecutive, so the node of tree `t` with local index `i`
//!   (`0 ≤ i < 2^ℓ`) sits at `level_off[ℓ] + t·2^ℓ + i`. A
//!   level-synchronous kernel therefore touches one contiguous region per
//!   level instead of hopping between per-tree heap allocations.
//! * **Leaves are tree-major.** Tree `t`'s `2^depth × n_classes` leaf
//!   distributions start at `tree_leaf_off[t]`.
//! * **Groves are tree ranges.** `grove_off` partitions `0..n_trees` into
//!   consecutive grove slices (the paper's `a×b` topology); a grove never
//!   needs its own tree storage again.
//!
//! The traversal arithmetic is the same as [`FlatTree`]'s
//! (`i ← 2i + (x[feat] > thr)` per level), so a walk through the arena
//! reaches bit-identically the same leaf as the tree it was packed from.
//! (Like every flat path — the Pallas kernel, the grove PE, FoG
//! evaluation — the comparison is `>`-routed: a NaN feature routes left,
//! where the sparse CART walk's `<=` would route right. Inputs are
//! finite everywhere in this crate; flat routing is the layout's
//! canonical semantics.)
//!
//! ## Ragged live depth: early-exit traversal
//!
//! The paper's energy argument is comparator ops *not executed* (§4,
//! Table 1): complete-tree padding exists for the kernel layout, not to
//! be walked. Packing therefore also records a per-tree **live depth**
//! table — `live_depth[t]` = 1 + the deepest level of tree `t` holding a
//! live split (0 for leaf-only trees). Every node at a level
//! `≥ live_depth[t]` is a dead padding slot (`+inf`-sentinel threshold),
//! and a dead slot routes left unconditionally, so a cursor `i` that has
//! walked the `live_depth[t]` live levels lands — in closed form, without
//! touching another node — on bottom-level leaf `i << (depth −
//! live_depth[t])`. Every traversal entry point below (per-sample
//! [`leaf_index`](ForestArena::leaf_index) and
//! [`walk_tree`](ForestArena::walk_tree), tiled
//! [`traverse_tile`](ForestArena::traverse_tile)) exits at the live depth
//! and applies the shift, which is *function-preserving and
//! byte-identical* to the padded walk (pinned by `rust/tests/exec.rs` on
//! forests mixing depth-0 and deep trees). Comparator-op **accounting**
//! ([`ops_per_eval_range`](ForestArena::ops_per_eval_range)) deliberately
//! stays at trees × padded depth — the μarch PE is depth-bound hardware —
//! while [`live_ops_per_eval_range`](ForestArena::live_ops_per_eval_range)
//! / [`skipped_ops_per_eval_range`](ForestArena::skipped_ops_per_eval_range)
//! expose what the software kernel actually walks vs. skips.

//!
//! ## Quantized fixed-point lanes
//!
//! Packing also computes per-feature threshold-code tables
//! ([`QuantTables`], see `exec::quant` — the fixed-point datapath the
//! embedded comparator hardware actually ships, arXiv 1703.05853) and
//! emits parallel integer threshold arrays `thr_q8`/`thr_q16` alongside
//! `thr` whenever the codes fit the lane width. The tiled kernel core
//! (the crate-private `ForestArena::traverse_tile_lanes`) is generic
//! over the lane type, so the same stride-1 inner compare loop runs on
//! f32, u8 or u16 columns; exact rank codes make the integer walk
//! byte-identical to the f32 walk (pinned by `rust/tests/quant.rs`). A
//! per-grove **depth-sorted visit order** (stable permutation
//! [`visit_order`](ForestArena::visit_order) + inverse
//! [`visit_rank`](ForestArena::visit_rank), rebuilt whenever the grove
//! partition changes) turns each grove's per-level live set into a prefix
//! range, dropping the per-tree live-depth branch from the inner loop;
//! cursor rows stay indexed by original tree, so leaf/prob accumulation
//! order — and therefore every f32 sum — is unchanged.
//!
//! The integer lanes optionally run under explicit vector kernels
//! (`exec::simd`): `traverse_tile_lanes` takes a pre-resolved
//! [`SimdLevel`] and `step_level` hands the whole per-tree level slice
//! to the matching u8/u16 compare/advance kernel, falling back to the
//! scalar loop for f32 lanes, u32 cursors, and hosts without vector
//! support. The vector path is pinned byte-identical to the scalar one
//! (same tree paths, same accumulation order).
//!
//! For the kernels' *gather* stage, packing additionally emits
//! level-major **packed gather node records** beside each integer code
//! table: one `u32` per node slot, `(feat << 16) | code`, sharing
//! `level_off` so a level's records are the same contiguous window as
//! its codes. One AVX2 dword index-gather over that window fetches both
//! operands of the per-level compare (threshold code in the low half,
//! feature id — hence the transposed-column address — in the high
//! half); the layout also keeps the scalar gather's operand pair on one
//! cache line per node. The tables are empty when a lane has no code
//! table or feature ids overflow the packed high half (> 2^16
//! features); `traverse_tile_lanes` then keeps the scalar gather stage,
//! byte-identically.

use super::quant::{QuantTables, QuantizedLane};
use super::simd::{GatherMode, SimdLane, SimdLevel, GATHER_PAD};
use crate::dt::FlatTree;
use crate::forest::RandomForest;
use std::sync::Arc;

/// Threshold sentinel check shared with `Grove`'s storage accounting: a
/// node is *live* (a real trained split, not complete-tree padding) iff
/// its threshold is finite and below the `sanitize_inf` ceiling.
#[inline]
fn is_live(thr: f32) -> bool {
    thr.is_finite() && thr < 1e37
}

/// Rank-code the level-major threshold array into lane `L`: live splits
/// get their per-feature cut rank, dead padding the lane's `DEAD`
/// sentinel (codes never reach it, so dead slots route left exactly like
/// `x > +inf`). Empty when the codes don't fit the lane.
fn quantize_thresholds<L: QuantizedLane>(
    feat: &[i32],
    thr: &[f32],
    q: &QuantTables,
    fits: bool,
) -> Vec<L> {
    if !fits {
        return Vec::new();
    }
    feat.iter()
        .zip(thr)
        .map(|(&k, &t)| {
            if is_live(t) {
                L::from_usize(q.thr_code(k as usize, t))
            } else {
                L::DEAD
            }
        })
        .collect()
}

/// Pack the level-major `(feature, threshold-code)` pairs into one u32
/// gather record per node slot — `(feat << 16) | code` — so one AVX2
/// dword gather fetches both operands of the per-level compare. Empty
/// when the lane has no code table or a feature id would overflow the
/// packed high half.
fn pack_gather_nodes<L: QuantizedLane>(feat: &[i32], codes: &[L], n_features: usize) -> Vec<u32> {
    if codes.is_empty() || n_features > (1usize << 16) {
        return Vec::new();
    }
    feat.iter().zip(codes).map(|(&k, &c)| ((k as u32) << 16) | c.as_u32()).collect()
}

/// One tree-level step of the tiled walk over lane type `L`: advance the
/// tile's cursors through this tree's `w = 2^lvl` node slots. With a
/// vector `simd` level and an integer lane, the whole slice goes to the
/// `exec::simd` kernel (byte-identical by construction); otherwise —
/// f32 lanes, u32 cursors, `Scalar` — the scalar loop below runs.
/// `nodes` is the matching packed-gather-record window (empty unless
/// `vector_gather`, which asserts the caller proved the gather-safety
/// contract — see `SimdLane`).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn step_level<C: CursorIdx, L: SimdLane>(
    simd: SimdLevel,
    xt: &[L],
    n: usize,
    feat: &[i32],
    thr: &[L],
    nodes: &[u32],
    vector_gather: bool,
    cur: &mut [C],
) {
    if simd != SimdLevel::Scalar && L::step_simd(simd, xt, n, feat, thr, nodes, vector_gather, cur)
    {
        return;
    }
    for (s, ci) in cur.iter_mut().enumerate() {
        let i = ci.as_usize();
        // Feature-major tile: the column of feat[i] is the contiguous
        // run xt[feat[i]·n ..][..n], so samples sharing a cursor (all of
        // them at level 0, most at shallow levels) read stride-1.
        let go_right = xt[feat[i] as usize * n + s] > thr[i];
        *ci = C::from_usize(2 * i + go_right as usize);
    }
}

/// Cursor integer of the tiled traversal scratch: `u16` halves the hot
/// cache footprint whenever the arena is shallow enough (`depth ≤ 15`,
/// checked by [`crate::exec::BatchPlan`]); `u32` covers every depth the
/// arena can physically allocate.
pub(crate) trait CursorIdx: Copy + Send + Sync + 'static {
    const ZERO: Self;
    fn as_usize(self) -> usize;
    /// `v` must fit the cursor width — callers guarantee `v < 2^depth`
    /// with the width chosen from the arena depth.
    fn from_usize(v: usize) -> Self;
    /// View the cursor slice as u16 lanes when `Self` *is* u16 — the
    /// only width the `exec::simd` vector kernels advance. Stands in
    /// for specialization: the kernel asks at runtime, monomorphization
    /// makes the answer a constant.
    fn as_u16_mut(cur: &mut [Self]) -> Option<&mut [u16]>;
}

impl CursorIdx for u16 {
    const ZERO: Self = 0;
    #[inline]
    fn as_usize(self) -> usize {
        self as usize
    }
    #[inline]
    fn from_usize(v: usize) -> Self {
        debug_assert!(v <= u16::MAX as usize);
        v as u16
    }
    #[inline]
    fn as_u16_mut(cur: &mut [Self]) -> Option<&mut [u16]> {
        Some(cur)
    }
}

impl CursorIdx for u32 {
    const ZERO: Self = 0;
    #[inline]
    fn as_usize(self) -> usize {
        self as usize
    }
    #[inline]
    fn from_usize(v: usize) -> Self {
        debug_assert!(v <= u32::MAX as usize);
        v as u32
    }
    #[inline]
    fn as_u16_mut(_cur: &mut [Self]) -> Option<&mut [u16]> {
        None
    }
}

/// A forest of complete trees in one structure-of-arrays allocation.
#[derive(Clone, Debug)]
pub struct ForestArena {
    depth: usize,
    n_features: usize,
    n_classes: usize,
    n_trees: usize,
    /// Level-major split feature ids: `n_trees · (2^depth − 1)` entries.
    feat: Vec<i32>,
    /// Level-major split thresholds; `+inf` for dead (padding) slots.
    thr: Vec<f32>,
    /// Tree-major leaf distributions: `n_trees · 2^depth · n_classes`.
    leaf: Vec<f32>,
    /// Node-table base offset of each level (`level_off[ℓ] = n_trees·(2^ℓ−1)`).
    level_off: Vec<usize>,
    /// Leaf-table base offset of each tree.
    tree_leaf_off: Vec<usize>,
    /// Grove partition: grove `g` owns trees `grove_off[g] .. grove_off[g+1]`.
    grove_off: Vec<usize>,
    /// Per-tree live depth: `live_depth[t]` = 1 + deepest level of tree
    /// `t` with a live split (0 for leaf-only trees). Levels ≥ this hold
    /// only dead padding slots, so traversal exits here and shifts the
    /// cursor into the bottom level in closed form (`i << remaining`).
    live_depth: Vec<u16>,
    /// Per-feature threshold-code tables (exact rank codes + lossy
    /// ranges), shared with the serving tier's cache keys via the `Arc`.
    quant: Arc<QuantTables>,
    /// Level-major u8 rank codes of `thr` (`u8::MAX` = dead slot);
    /// empty when some feature has too many distinct cuts for u8.
    thr_q8: Vec<u8>,
    /// Level-major u16 rank codes of `thr` (`u16::MAX` = dead slot);
    /// empty when the forest overflows u16 codes.
    thr_q16: Vec<u16>,
    /// Packed `(feat << 16) | code` gather records parallel to `thr_q8`
    /// — one dword index-gather fetches both per-level compare operands.
    gather_q8: Vec<u32>,
    /// Packed gather records parallel to `thr_q16`.
    gather_q16: Vec<u32>,
    /// Per-grove stable descending-live-depth tree permutation: grove
    /// `g`'s segment `visit[grove_off[g]..grove_off[g+1]]` lists that
    /// grove's tree ids deepest-first, so the tile kernel's per-level
    /// live set is a prefix of each segment.
    visit: Vec<u32>,
    /// Inverse of `visit`: `visit_rank[t]` = position of tree `t` in the
    /// visit permutation (callers that need "when does tree t run").
    visit_rank: Vec<u32>,
}

impl ForestArena {
    /// Pack a slice of flat trees. Trees shallower than the deepest are
    /// re-padded (function-preserving, see [`FlatTree::repad`]) so the
    /// arena is depth-homogeneous. Starts with a single grove covering
    /// the whole forest; see [`ForestArena::with_grove_sizes`].
    pub fn from_flat_trees(trees: &[FlatTree]) -> ForestArena {
        assert!(!trees.is_empty(), "empty forest");
        let f = trees[0].n_features;
        let c = trees[0].n_classes;
        let depth = trees.iter().map(|t| t.depth).max().unwrap();
        let n_trees = trees.len();
        let n_internal = (1usize << depth) - 1;
        let n_leaves = 1usize << depth;

        let mut feat = vec![0i32; n_trees * n_internal];
        let mut thr = vec![f32::INFINITY; n_trees * n_internal];
        let mut leaf = vec![0.0f32; n_trees * n_leaves * c];
        let mut live_depth = vec![0u16; n_trees];
        let level_off: Vec<usize> =
            (0..depth).map(|l| n_trees * ((1usize << l) - 1)).collect();
        let tree_leaf_off: Vec<usize> = (0..n_trees).map(|t| t * n_leaves * c).collect();

        for (ti, t) in trees.iter().enumerate() {
            assert_eq!(
                (t.n_features, t.n_classes),
                (f, c),
                "inhomogeneous forest (tree {ti})"
            );
            // Validate every split's feature id once here (cold path):
            // the traversal hot paths read features unchecked, and
            // `FlatTree`'s fields are public, so the invariant must be
            // enforced at packing time, not assumed.
            for (s, &fi) in t.feat.iter().enumerate() {
                assert!(
                    (0..f as i32).contains(&fi),
                    "tree {ti} slot {s}: feature id {fi} out of range (n_features {f})"
                );
            }
            let padded;
            let t = if t.depth == depth {
                t
            } else {
                padded = t.repad(depth);
                &padded
            };
            // FlatTree stores nodes level-order; peel its levels apart,
            // recording the deepest level that still holds a live split.
            for lvl in 0..depth {
                let w = 1usize << lvl;
                let src = w - 1; // level ℓ starts at slot 2^ℓ − 1
                let dst = level_off[lvl] + ti * w;
                feat[dst..dst + w].copy_from_slice(&t.feat[src..src + w]);
                thr[dst..dst + w].copy_from_slice(&t.thr[src..src + w]);
                if t.thr[src..src + w].iter().any(|&v| is_live(v)) {
                    live_depth[ti] = (lvl + 1) as u16;
                }
            }
            leaf[tree_leaf_off[ti]..tree_leaf_off[ti] + n_leaves * c]
                .copy_from_slice(&t.leaf);
        }
        // Per-feature cut tables over every live split, then the parallel
        // integer threshold arrays for each lane width the codes fit.
        let quant = Arc::new(QuantTables::build(
            f,
            feat.iter().zip(&thr).filter(|(_, t)| is_live(**t)).map(|(&k, &t)| (k as usize, t)),
        ));
        let thr_q8 = quantize_thresholds::<u8>(&feat, &thr, &quant, quant.fits_u8());
        let thr_q16 = quantize_thresholds::<u16>(&feat, &thr, &quant, quant.fits_u16());
        let gather_q8 = pack_gather_nodes(&feat, &thr_q8, f);
        let gather_q16 = pack_gather_nodes(&feat, &thr_q16, f);
        let mut arena = ForestArena {
            depth,
            n_features: f,
            n_classes: c,
            n_trees,
            feat,
            thr,
            leaf,
            level_off,
            tree_leaf_off,
            grove_off: vec![0, n_trees],
            live_depth,
            quant,
            thr_q8,
            thr_q16,
            gather_q8,
            gather_q16,
            visit: Vec::new(),
            visit_rank: Vec::new(),
        };
        arena.rebuild_visit_order();
        arena
    }

    /// Pack a trained forest (flattened at `pad_depth`, clamped up to the
    /// forest's own maximum depth).
    pub fn from_forest(rf: &RandomForest, pad_depth: usize) -> ForestArena {
        Self::from_flat_trees(&rf.flatten(pad_depth))
    }

    /// Record a grove partition: `sizes` are consecutive tree counts and
    /// must sum to the forest size.
    pub fn with_grove_sizes(mut self, sizes: &[usize]) -> ForestArena {
        assert!(!sizes.is_empty(), "no groves");
        assert!(sizes.iter().all(|&s| s > 0), "empty grove");
        assert_eq!(
            sizes.iter().sum::<usize>(),
            self.n_trees,
            "grove sizes must partition the forest"
        );
        let mut off = Vec::with_capacity(sizes.len() + 1);
        off.push(0usize);
        for &s in sizes {
            off.push(off.last().unwrap() + s);
        }
        self.grove_off = off;
        // The depth-sorted visit order is per grove, so a new partition
        // invalidates it.
        self.rebuild_visit_order();
        self
    }

    /// Recompute the per-grove stable descending-live-depth visit
    /// permutation and its inverse. Stability keeps equal-depth trees in
    /// original order, so the permutation is deterministic.
    fn rebuild_visit_order(&mut self) {
        let mut visit: Vec<u32> = (0..self.n_trees as u32).collect();
        for g in 0..self.n_groves() {
            let (lo, hi) = self.grove_range(g);
            visit[lo..hi].sort_by_key(|&t| std::cmp::Reverse(self.live_depth[t as usize]));
        }
        let mut rank = vec![0u32; self.n_trees];
        for (pos, &t) in visit.iter().enumerate() {
            rank[t as usize] = pos as u32;
        }
        self.visit = visit;
        self.visit_rank = rank;
    }

    // --- shape accessors ---------------------------------------------------

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn n_trees(&self) -> usize {
        self.n_trees
    }

    pub fn n_internal_per_tree(&self) -> usize {
        (1usize << self.depth) - 1
    }

    pub fn n_leaves_per_tree(&self) -> usize {
        1usize << self.depth
    }

    pub fn n_groves(&self) -> usize {
        self.grove_off.len() - 1
    }

    /// Tree range `[lo, hi)` of grove `g`.
    pub fn grove_range(&self, g: usize) -> (usize, usize) {
        (self.grove_off[g], self.grove_off[g + 1])
    }

    /// Levels tree `t` actually has to walk: 1 + the deepest level with a
    /// live split (0 for leaf-only trees). Levels past this hold only
    /// dead padding, which routes left unconditionally.
    pub fn live_depth(&self, t: usize) -> usize {
        self.live_depth[t] as usize
    }

    /// Deepest live depth over the tree range `[lo, hi)` — the number of
    /// level iterations the ragged tile kernel runs for that range.
    pub fn max_live_depth_range(&self, lo: usize, hi: usize) -> usize {
        self.live_depth[lo..hi].iter().map(|&d| d as usize).max().unwrap_or(0)
    }

    /// The per-feature threshold-code tables computed at pack time
    /// (shared with the serving tier's cache keys through the `Arc`).
    pub fn quant_tables(&self) -> &Arc<QuantTables> {
        &self.quant
    }

    /// Narrowest integer lane whose exact rank codes fit this arena
    /// (`"u8"` / `"u16"`), or `None` when only f32 lanes are exact.
    pub fn quant_lane(&self) -> Option<&'static str> {
        if !self.thr_q8.is_empty() {
            Some("u8")
        } else if !self.thr_q16.is_empty() {
            Some("u16")
        } else {
            None
        }
    }

    /// Level-major u8 rank codes of the threshold table, when they fit.
    pub(crate) fn thr_q8(&self) -> Option<&[u8]> {
        (!self.thr_q8.is_empty()).then_some(&self.thr_q8[..])
    }

    /// Level-major u16 rank codes of the threshold table, when they fit.
    pub(crate) fn thr_q16(&self) -> Option<&[u16]> {
        (!self.thr_q16.is_empty()).then_some(&self.thr_q16[..])
    }

    /// Packed `(feat << 16) | code` gather records parallel to
    /// [`thr_q8`](ForestArena::thr_q8); empty when that lane has no
    /// codes (or > 2^16 features overflow the packed high half).
    pub(crate) fn gather_q8(&self) -> &[u32] {
        &self.gather_q8
    }

    /// Packed gather records parallel to [`thr_q16`](ForestArena::thr_q16).
    pub(crate) fn gather_q16(&self) -> &[u32] {
        &self.gather_q16
    }

    /// Pack caller-built level-major codes (the owned lossy tables) into
    /// gather records under this arena's feature layout.
    pub(crate) fn pack_gather<L: QuantizedLane>(&self, codes: &[L]) -> Vec<u32> {
        debug_assert_eq!(codes.len(), self.thr.len(), "codes not level-major");
        pack_gather_nodes(&self.feat, codes, self.n_features)
    }

    /// Build an owned lossy threshold table at `bits` (affine codes over
    /// each feature's live-threshold range; dead slots keep the lane's
    /// sentinel so they still route left).
    pub(crate) fn lossy_thr<L: QuantizedLane>(&self, bits: u8) -> Vec<L> {
        self.feat
            .iter()
            .zip(&self.thr)
            .map(|(&k, &t)| {
                if is_live(t) {
                    L::from_usize(self.quant.lossy_code(k as usize, t, bits))
                } else {
                    L::DEAD
                }
            })
            .collect()
    }

    /// The level-major f32 threshold table (the f32 lane's `thr_tab`).
    pub(crate) fn thr_table(&self) -> &[f32] {
        &self.thr
    }

    /// The per-grove stable descending-live-depth visit permutation.
    pub fn visit_order(&self) -> &[u32] {
        &self.visit
    }

    /// Inverse of [`visit_order`](ForestArena::visit_order):
    /// `visit_rank(t)` = position of tree `t` within the permutation.
    pub fn visit_rank(&self, t: usize) -> usize {
        self.visit_rank[t] as usize
    }

    /// The grove-partition span `[glo, ghi)` exactly covering the tree
    /// range `[lo, hi)`, or `None` when the range is not grove-aligned
    /// (the kernel then keeps the per-tree live-depth branch).
    fn grove_span(&self, lo: usize, hi: usize) -> Option<(usize, usize)> {
        let glo = self.grove_off.binary_search(&lo).ok()?;
        let ghi = self.grove_off.binary_search(&hi).ok()?;
        (glo < ghi).then_some((glo, ghi))
    }

    // --- traversal ---------------------------------------------------------

    /// Walk tree `t` on one sample; returns the local leaf index
    /// (`0..2^depth`). Same comparisons, in the same order, as
    /// [`FlatTree::predict_proba`] on the packed tree — except that the
    /// walk exits at the tree's live depth and reaches the bottom-level
    /// leaf in closed form (`i << remaining`): the skipped levels hold
    /// only dead padding that routes left, so the result is
    /// byte-identical to the full padded walk.
    ///
    /// Perf note: this is the Algorithm-2 per-sample hot loop (grove hop
    /// evaluation, μarch PE). Like `FlatTree::predict_proba` (§Perf
    /// iteration 1 there), the three indexings are unchecked: bounds
    /// checks cost ~3× on this sub-100 ns path, and construction
    /// guarantees the invariants (asserted in debug builds).
    #[inline]
    pub fn leaf_index(&self, t: usize, x: &[f32]) -> usize {
        // Release asserts: `t` and `x` are caller-supplied on a safe pub
        // fn, so they must be validated once up front — the unchecked
        // accesses below are per-level, these are per-call.
        assert!(t < self.n_trees, "tree {t} out of range");
        assert!(x.len() >= self.n_features, "sample shorter than n_features");
        let live = self.live_depth[t] as usize;
        let mut i = 0usize;
        for lvl in 0..live {
            // SAFETY: lvl < depth = level_off.len(); the node offset is
            // level_off[lvl] + t·2^lvl + i with t < n_trees and i < 2^lvl
            // by the recurrence, so it stays below n_trees·(2^depth − 1) =
            // |feat| = |thr|.
            let off = unsafe { *self.level_off.get_unchecked(lvl) } + (t << lvl) + i;
            let (f, thr) = unsafe {
                (*self.feat.get_unchecked(off) as usize, *self.thr.get_unchecked(off))
            };
            debug_assert!(f < x.len());
            // SAFETY: feat values are validated < n_features at tree
            // construction (`fit_tree`/`from_tree`/`repad` never emit an
            // out-of-range feature id).
            let go_right = unsafe { *x.get_unchecked(f) } > thr;
            i = 2 * i + go_right as usize;
        }
        // Dead padding routes left every remaining level: i ← 2i.
        i << (self.depth - live)
    }

    /// Leaf distribution of tree `t` at local leaf index `local`.
    #[inline]
    pub fn leaf_slice(&self, t: usize, local: usize) -> &[f32] {
        let c = self.n_classes;
        let start = self.tree_leaf_off[t] + local * c;
        &self.leaf[start..start + c]
    }

    /// Walk tree `t` on one sample and return the reached leaf
    /// distribution.
    #[inline]
    pub fn leaf_dist(&self, t: usize, x: &[f32]) -> &[f32] {
        self.leaf_slice(t, self.leaf_index(t, x))
    }

    /// Walk tree `t` on `x`, calling `visit(feature, live)` at every
    /// *walked* level (`live` = real trained split, not complete-tree
    /// padding). The walk exits at the tree's live depth — the levels it
    /// skips are all-dead padding, so no live split is ever missed — and
    /// returns the closed-form bottom-level leaf index. Used by the
    /// feature-acquisition cost accounting in `forest::budgeted`, whose
    /// totals only charge live splits and are therefore unchanged by the
    /// early exit.
    pub fn walk_tree<F: FnMut(usize, bool)>(&self, t: usize, x: &[f32], mut visit: F) -> usize {
        let live = self.live_depth[t] as usize;
        let mut i = 0usize;
        for lvl in 0..live {
            let off = self.level_off[lvl] + (t << lvl) + i;
            let f = self.feat[off] as usize;
            let thr = self.thr[off];
            visit(f, is_live(thr));
            i = 2 * i + (x[f] > thr) as usize;
        }
        i << (self.depth - live)
    }

    /// Level-synchronous traversal of a sample tile over the tree range
    /// `[lo, hi)`: outer loop over levels, inner loop over the tile's
    /// samples (the hardware PE's evaluation order). On return,
    /// `cursors[j·n + s]` holds the local leaf index reached by tree
    /// `lo + j` on sample `s`.
    ///
    /// Ragged: delegates to the feature-major kernel core
    /// ([`ForestArena::traverse_tile_transposed`]) after transposing the
    /// tile once, so every caller — including `Grove`'s hop path — gets
    /// the live-depth early exit and stride-1 inner loop from the one
    /// kernel implementation. Byte-identical to the padded walk, cheaper
    /// by exactly the skipped dead levels.
    pub fn traverse_tile(&self, lo: usize, hi: usize, x: &[f32], n: usize, cursors: &mut [u32]) {
        let f = self.n_features;
        assert_eq!(x.len(), n * f, "tile shape mismatch");
        let mut xt = vec![0.0f32; x.len()];
        for (r, row) in x.chunks_exact(f).enumerate() {
            for (k, &v) in row.iter().enumerate() {
                xt[k * n + r] = v;
            }
        }
        self.traverse_tile_transposed(lo, hi, &xt, n, cursors, false);
    }

    /// The tiled-kernel core behind [`crate::exec::BatchPlan`]: same
    /// ragged level-synchronous traversal as
    /// [`traverse_tile`](ForestArena::traverse_tile), but over a
    /// **feature-major** (transposed) tile `xt: [n_features, n]` so the
    /// inner comparison loop reads each feature column stride-1, with the
    /// cursor width `C` chosen by the caller (`u16` when `depth ≤ 15`
    /// halves the hot scratch). `padded_walk` forces the pre-exit
    /// full-depth walk — the results are identical either way (the
    /// bench/conformance baseline); only the work differs.
    pub(crate) fn traverse_tile_transposed<C: CursorIdx>(
        &self,
        lo: usize,
        hi: usize,
        xt: &[f32],
        n: usize,
        cursors: &mut [C],
        padded_walk: bool,
    ) {
        // f32 lanes have no vector kernel; `Scalar` keeps the call site
        // honest about which path runs.
        self.traverse_tile_lanes(
            lo,
            hi,
            xt,
            n,
            cursors,
            &self.thr,
            &[],
            GatherMode::Scalar,
            padded_walk,
            SimdLevel::Scalar,
        );
    }

    /// The lane-generic kernel core: identical traversal over any
    /// `PartialOrd` lane type `L` — f32 columns against `thr`, or
    /// integer rank-code columns against `thr_q8`/`thr_q16` (same
    /// level-major layout, `L::MAX` dead sentinel). Exact rank codes
    /// preserve every `>` outcome, so the integer walk is byte-identical
    /// to the f32 walk.
    ///
    /// Grove-aligned non-padded ranges iterate each grove's trees in the
    /// depth-sorted [`visit_order`](ForestArena::visit_order): the live
    /// set at level `ℓ` is then a prefix of the grove segment (one
    /// `partition_point` per level, no per-tree live-depth branch in the
    /// inner loop). Other ranges keep the original order with the
    /// branch; cursor rows are written per original tree either way, so
    /// downstream leaf/prob accumulation order never changes.
    ///
    /// `simd` is the pre-resolved vector level for the integer lanes
    /// (see `exec::simd`); pass [`SimdLevel::Scalar`] for the reference
    /// scalar walk. Dispatch happens per `step_level` slice, so the
    /// choice costs nothing on the per-tile path.
    ///
    /// `nodes_tab` / `gather` arm the kernels' index-gather stage: when
    /// `gather` is [`GatherMode::Vector`], the packed records are
    /// present, the tile carries [`GATHER_PAD`] slack elements past
    /// `n_features · n` (dword gathers over-read at the buffer's end)
    /// and the transposed addresses fit `i32`, per-level record windows
    /// flow to `step_level` with the vector-gather flag set — this is
    /// where the kernels' gather-safety contract is proved. Any failed
    /// precondition (exactly-sized tiles included) silently keeps the
    /// scalar gather stage, which is byte-identical.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn traverse_tile_lanes<C: CursorIdx, L: SimdLane>(
        &self,
        lo: usize,
        hi: usize,
        xt: &[L],
        n: usize,
        cursors: &mut [C],
        thr_tab: &[L],
        nodes_tab: &[u32],
        gather: GatherMode,
        padded_walk: bool,
        simd: SimdLevel,
    ) {
        debug_assert!(lo <= hi && hi <= self.n_trees, "bad tree range {lo}..{hi}");
        let t_cnt = hi - lo;
        assert!(xt.len() >= n * self.n_features, "tile shape mismatch");
        assert_eq!(cursors.len(), t_cnt * n, "cursor buffer shape mismatch");
        assert_eq!(thr_tab.len(), self.thr.len(), "threshold table shape mismatch");
        let vector_gather = gather == GatherMode::Vector
            && !nodes_tab.is_empty()
            && nodes_tab.len() == thr_tab.len()
            && xt.len() >= n * self.n_features + GATHER_PAD
            && n * self.n_features <= i32::MAX as usize;
        cursors.iter_mut().for_each(|ci| *ci = C::ZERO);
        let live = |j: usize| {
            if padded_walk {
                self.depth
            } else {
                self.live_depth[lo + j] as usize
            }
        };
        let max_depth = if padded_walk { self.depth } else { self.max_live_depth_range(lo, hi) };
        let span = if padded_walk { None } else { self.grove_span(lo, hi) };
        for lvl in 0..max_depth {
            let w = 1usize << lvl;
            let base = self.level_off[lvl];
            if let Some((glo, ghi)) = span {
                for g in glo..ghi {
                    let (g_lo, g_hi) = self.grove_range(g);
                    let order = &self.visit[g_lo..g_hi];
                    // Descending live depth ⇒ the still-live trees are
                    // exactly this prefix of the grove's visit segment.
                    let cnt =
                        order.partition_point(|&t| self.live_depth[t as usize] as usize > lvl);
                    for &t in &order[..cnt] {
                        let t = t as usize;
                        let off = base + t * w;
                        step_level(
                            simd,
                            xt,
                            n,
                            &self.feat[off..off + w],
                            &thr_tab[off..off + w],
                            if vector_gather { &nodes_tab[off..off + w] } else { &[] },
                            vector_gather,
                            &mut cursors[(t - lo) * n..(t - lo + 1) * n],
                        );
                    }
                }
            } else {
                for j in 0..t_cnt {
                    if live(j) <= lvl {
                        continue; // only dead padding from here down
                    }
                    let off = base + (lo + j) * w;
                    step_level(
                        simd,
                        xt,
                        n,
                        &self.feat[off..off + w],
                        &thr_tab[off..off + w],
                        if vector_gather { &nodes_tab[off..off + w] } else { &[] },
                        vector_gather,
                        &mut cursors[j * n..(j + 1) * n],
                    );
                }
            }
        }
        for j in 0..t_cnt {
            let shift = self.depth - live(j);
            if shift > 0 {
                for ci in &mut cursors[j * n..(j + 1) * n] {
                    *ci = C::from_usize(ci.as_usize() << shift);
                }
            }
        }
    }

    // --- accounting (drives the μarch PE and energy models) ----------------

    /// Comparator ops per evaluation of the tree range: every complete
    /// tree is charged exactly `depth` levels. This is the *hardware*
    /// number — the μarch PE is depth-bound (§3.2.2) — and it must stay
    /// numerically identical across kernel changes so Table 1 / Fig 4–5
    /// are stable; the software kernel's early exit is accounted
    /// separately by [`skipped_ops_per_eval_range`](ForestArena::skipped_ops_per_eval_range).
    pub fn ops_per_eval_range(&self, lo: usize, hi: usize) -> usize {
        (hi - lo) * self.depth
    }

    /// Comparator ops the ragged software kernel actually executes per
    /// evaluation of the tree range: Σ live_depth over its trees.
    pub fn live_ops_per_eval_range(&self, lo: usize, hi: usize) -> usize {
        self.live_depth[lo..hi].iter().map(|&d| d as usize).sum()
    }

    /// Dead padded levels the ragged kernel skips per evaluation of the
    /// tree range (= [`ops_per_eval_range`](ForestArena::ops_per_eval_range)
    /// − [`live_ops_per_eval_range`](ForestArena::live_ops_per_eval_range)).
    pub fn skipped_ops_per_eval_range(&self, lo: usize, hi: usize) -> usize {
        self.ops_per_eval_range(lo, hi) - self.live_ops_per_eval_range(lo, hi)
    }

    /// VMEM bytes of one packed tree: feat (i32) + thr (f32) + leaves (f32).
    pub fn tree_vmem_bytes(&self) -> usize {
        self.n_internal_per_tree() * 8 + self.n_leaves_per_tree() * self.n_classes * 4
    }

    /// VMEM bytes of a tree range.
    pub fn vmem_bytes_range(&self, lo: usize, hi: usize) -> usize {
        (hi - lo) * self.tree_vmem_bytes()
    }

    /// Total VMEM bytes of the arena (equals the sum over its trees).
    pub fn vmem_bytes(&self) -> usize {
        self.vmem_bytes_range(0, self.n_trees)
    }

    /// Live (finite-threshold) internal nodes of tree `t`.
    pub fn live_nodes(&self, t: usize) -> usize {
        (0..self.depth)
            .map(|lvl| {
                let w = 1usize << lvl;
                let off = self.level_off[lvl] + t * w;
                self.thr[off..off + w].iter().filter(|v| is_live(**v)).count()
            })
            .sum()
    }

    /// Bytes of *sparse* node storage the hardware would provision for a
    /// tree range: live internal nodes at 6 B each + one byte per
    /// leaf-class slot of the live leaves (complete-tree padding is a
    /// kernel-layout artifact, not real storage).
    pub fn sparse_storage_bytes_range(&self, lo: usize, hi: usize) -> usize {
        (lo..hi)
            .map(|t| {
                let live = self.live_nodes(t);
                live * 6 + (live + 1) * self.n_classes
            })
            .sum()
    }

    // --- materialization (cold paths: export, dropout, tests) --------------

    /// Reconstruct one tree as a standalone [`FlatTree`] (bit-identical
    /// to the tree packed in, modulo the homogenizing re-pad).
    pub fn tree(&self, t: usize) -> FlatTree {
        assert!(t < self.n_trees, "tree {t} out of range");
        let n_internal = self.n_internal_per_tree();
        let mut feat = Vec::with_capacity(n_internal);
        let mut thr = Vec::with_capacity(n_internal);
        for lvl in 0..self.depth {
            let w = 1usize << lvl;
            let off = self.level_off[lvl] + t * w;
            feat.extend_from_slice(&self.feat[off..off + w]);
            thr.extend_from_slice(&self.thr[off..off + w]);
        }
        let c = self.n_classes;
        let lo = self.tree_leaf_off[t];
        let leaf = self.leaf[lo..lo + self.n_leaves_per_tree() * c].to_vec();
        FlatTree {
            depth: self.depth,
            n_features: self.n_features,
            n_classes: self.n_classes,
            feat,
            thr,
            leaf,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::ForestParams;

    fn flats() -> (Vec<FlatTree>, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 331);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 1);
        (rf.flatten(rf.max_depth()), ds)
    }

    #[test]
    fn roundtrip_materialization() {
        let (trees, _) = flats();
        let arena = ForestArena::from_flat_trees(&trees);
        assert_eq!(arena.n_trees(), trees.len());
        for (t, orig) in trees.iter().enumerate() {
            assert_eq!(&arena.tree(t), orig, "tree {t} changed in the arena");
        }
    }

    #[test]
    fn leaf_dist_matches_flat_traversal() {
        let (trees, ds) = flats();
        let arena = ForestArena::from_flat_trees(&trees);
        for i in 0..40.min(ds.test.len()) {
            let x = ds.test.row(i);
            for (t, tree) in trees.iter().enumerate() {
                assert_eq!(arena.leaf_dist(t, x), tree.predict_proba(x), "tree {t} row {i}");
            }
        }
    }

    #[test]
    fn traverse_tile_matches_per_sample() {
        let (trees, ds) = flats();
        let arena = ForestArena::from_flat_trees(&trees);
        let n = 17.min(ds.test.len());
        let f = arena.n_features();
        let t_cnt = arena.n_trees();
        let mut cursors = vec![0u32; t_cnt * n];
        arena.traverse_tile(0, t_cnt, &ds.test.x[..n * f], n, &mut cursors);
        for s in 0..n {
            let x = ds.test.row(s);
            for j in 0..t_cnt {
                assert_eq!(cursors[j * n + s] as usize, arena.leaf_index(j, x));
            }
        }
    }

    #[test]
    fn byte_totals_equal_sum_over_trees() {
        // Satellite invariant: the arena reports exactly the per-tree sums.
        let (trees, _) = flats();
        let arena = ForestArena::from_flat_trees(&trees);
        let per_tree: usize = trees.iter().map(|t| t.vmem_bytes()).sum();
        assert_eq!(arena.vmem_bytes(), per_tree);
        let live_sum: usize = trees
            .iter()
            .map(|t| {
                let live = t.thr.iter().filter(|v| v.is_finite() && **v < 1e37).count();
                live * 6 + (live + 1) * t.n_classes
            })
            .sum();
        assert_eq!(arena.sparse_storage_bytes_range(0, arena.n_trees()), live_sum);
    }

    #[test]
    fn repad_grows_vmem_not_sparse_storage() {
        // Satellite invariant: re-padding adds dead slots (VMEM grows)
        // but provisions no new real storage.
        let (trees, _) = flats();
        let arena = ForestArena::from_flat_trees(&trees);
        let deeper: Vec<FlatTree> = trees.iter().map(|t| t.repad(t.depth + 2)).collect();
        let deeper_arena = ForestArena::from_flat_trees(&deeper);
        assert!(deeper_arena.vmem_bytes() > arena.vmem_bytes());
        assert_eq!(
            deeper_arena.sparse_storage_bytes_range(0, deeper_arena.n_trees()),
            arena.sparse_storage_bytes_range(0, arena.n_trees()),
        );
    }

    #[test]
    fn mixed_depths_are_homogenized() {
        let (trees, ds) = flats();
        let mut mixed = trees.clone();
        mixed[0] = mixed[0].repad(mixed[0].depth + 1);
        let arena = ForestArena::from_flat_trees(&mixed);
        assert_eq!(arena.depth(), trees[0].depth + 1);
        // Function is preserved for every tree despite the re-pad.
        for i in 0..10.min(ds.test.len()) {
            let x = ds.test.row(i);
            for (t, tree) in trees.iter().enumerate() {
                assert_eq!(arena.leaf_dist(t, x), tree.predict_proba(x));
            }
        }
    }

    #[test]
    fn grove_partition_recorded() {
        let (trees, _) = flats();
        let n = trees.len();
        let arena = ForestArena::from_flat_trees(&trees).with_grove_sizes(&[3, 3, 2]);
        assert_eq!(arena.n_groves(), 3);
        assert_eq!(arena.grove_range(0), (0, 3));
        assert_eq!(arena.grove_range(2), (6, n));
        assert_eq!(arena.ops_per_eval_range(0, 3), 3 * arena.depth());
    }

    #[test]
    #[should_panic]
    fn bad_grove_sizes_panic() {
        let (trees, _) = flats();
        let _ = ForestArena::from_flat_trees(&trees).with_grove_sizes(&[1, 1]);
    }

    #[test]
    #[should_panic(expected = "empty grove")]
    fn zero_size_grove_slice_rejected() {
        // A grove partition may never contain an empty tree-range slice,
        // even when the sizes still sum to the forest.
        let (trees, _) = flats();
        let n = trees.len();
        let _ = ForestArena::from_flat_trees(&trees).with_grove_sizes(&[n, 0]);
    }

    #[test]
    fn leaf_only_trees_pack_and_predict() {
        // Depth-0 forest: every tree is a bare leaf (pure-class training
        // data). The arena must pack it with an empty node table and
        // still answer through every accessor.
        let mut s = crate::data::Split::new(2, 3);
        for _ in 0..6 {
            s.push(&[0.0, 1.0], 2);
        }
        let mut rng = crate::util::rng::Rng::new(5);
        let tree = crate::dt::builder::fit_tree(
            &s,
            &[0, 1, 2, 3, 4, 5],
            &crate::dt::builder::TreeParams::default(),
            &mut rng,
        );
        assert_eq!(tree.depth, 0, "pure-class fit should be a single leaf");
        let flat = FlatTree::from_tree(&tree, 0);
        let arena = ForestArena::from_flat_trees(&[flat.clone(), flat]);
        assert_eq!(arena.depth(), 0);
        assert_eq!(arena.n_internal_per_tree(), 0);
        assert_eq!(arena.n_leaves_per_tree(), 1);
        assert_eq!(arena.ops_per_eval_range(0, 2), 0, "no levels, no comparator ops");
        for t in 0..2 {
            assert_eq!(arena.leaf_index(t, &[9.9, -9.9]), 0);
            assert_eq!(arena.leaf_dist(t, &[0.5, 0.5]), &[0.0, 0.0, 1.0]);
            assert_eq!(arena.live_nodes(t), 0);
            let visited = arena.walk_tree(t, &[1.0, 2.0], |_, _| panic!("no levels to visit"));
            assert_eq!(visited, 0);
        }
        // Materialization round-trips the degenerate shape.
        assert_eq!(arena.tree(0).depth, 0);
        assert_eq!(arena.tree(0).leaf, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn max_depth_padding_slots_are_dead_but_function_preserving() {
        // Re-pad two levels past the trained depth: live-node accounting
        // and live depth are unchanged, the walk exits at the live depth
        // (never touching the two all-dead bottom levels), and the
        // reached distribution equals the original tree's.
        let (trees, ds) = flats();
        let orig = ForestArena::from_flat_trees(&trees);
        let deeper: Vec<FlatTree> = trees.iter().map(|t| t.repad(t.depth + 2)).collect();
        let arena = ForestArena::from_flat_trees(&deeper);
        assert_eq!(arena.depth(), orig.depth() + 2);
        assert_eq!(
            arena.skipped_ops_per_eval_range(0, arena.n_trees()),
            orig.skipped_ops_per_eval_range(0, orig.n_trees()) + 2 * arena.n_trees(),
            "each tree must skip exactly the two new dead levels"
        );
        let x = ds.test.row(0);
        for t in 0..arena.n_trees() {
            assert_eq!(arena.live_nodes(t), orig.live_nodes(t), "padding became live");
            assert_eq!(arena.live_depth(t), orig.live_depth(t), "re-pad moved the live depth");
            let mut visited = 0;
            let leaf = arena.walk_tree(t, x, |_, _| visited += 1);
            assert_eq!(visited, arena.live_depth(t), "walk must exit at the live depth");
            assert_eq!(
                arena.leaf_slice(t, leaf),
                orig.leaf_dist(t, x),
                "tree {t}: early-exit walk reached a different distribution"
            );
        }
    }

    /// Build a deliberately ragged forest: the trained trees, plus
    /// re-trained shallow and leaf-only companions, all packed into one
    /// arena (homogenized to the deepest).
    fn ragged_flats() -> (Vec<FlatTree>, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 337);
        let deep = RandomForest::fit(&ds.train, &ForestParams::small(), 1);
        let shallow_params = ForestParams {
            tree: crate::dt::builder::TreeParams {
                max_depth: 2,
                ..crate::dt::builder::TreeParams::default()
            },
            ..ForestParams::small()
        };
        let shallow = RandomForest::fit(&ds.train, &shallow_params, 2);
        let mut trees = deep.flatten(deep.max_depth());
        trees.extend(shallow.flatten(shallow.max_depth()));
        // A leaf-only tree: depth 0, packs as pure padding below level 0.
        let mut s = crate::data::Split::new(ds.n_features(), ds.n_classes());
        for _ in 0..4 {
            s.push(&vec![0.25; ds.n_features()], 1);
        }
        let mut rng = crate::util::rng::Rng::new(9);
        let leaf_tree = crate::dt::builder::fit_tree(
            &s,
            &[0, 1, 2, 3],
            &crate::dt::builder::TreeParams::default(),
            &mut rng,
        );
        assert_eq!(leaf_tree.depth, 0, "pure-class fit should be a single leaf");
        trees.push(FlatTree::from_tree(&leaf_tree, 0));
        (trees, ds)
    }

    #[test]
    fn live_depth_table_tracks_deepest_live_split() {
        let (trees, _) = ragged_flats();
        let arena = ForestArena::from_flat_trees(&trees);
        let depth = arena.depth();
        let mut saw_shallow = false;
        for (t, tree) in trees.iter().enumerate() {
            // Reference: deepest level of the original (pre-homogenize)
            // tree holding a live split.
            let mut want = 0usize;
            for lvl in 0..tree.depth {
                let w = 1usize << lvl;
                let src = w - 1;
                if tree.thr[src..src + w].iter().any(|&v| v.is_finite() && v < 1e37) {
                    want = lvl + 1;
                }
            }
            assert_eq!(arena.live_depth(t), want, "tree {t}");
            assert!(arena.live_depth(t) <= depth);
            saw_shallow |= arena.live_depth(t) < depth;
        }
        assert!(saw_shallow, "fixture must actually be ragged");
        assert_eq!(arena.live_depth(trees.len() - 1), 0, "leaf-only tree");
        assert_eq!(arena.max_live_depth_range(0, arena.n_trees()), depth);
        assert_eq!(
            arena.live_ops_per_eval_range(0, arena.n_trees())
                + arena.skipped_ops_per_eval_range(0, arena.n_trees()),
            arena.ops_per_eval_range(0, arena.n_trees()),
        );
        assert!(arena.skipped_ops_per_eval_range(0, arena.n_trees()) > 0);
    }

    #[test]
    fn visit_order_is_a_stable_descending_permutation_per_grove() {
        let (trees, _) = ragged_flats();
        let n = trees.len();
        let arena = ForestArena::from_flat_trees(&trees).with_grove_sizes(&[3, 3, n - 6]);
        for g in 0..arena.n_groves() {
            let (lo, hi) = arena.grove_range(g);
            let seg = &arena.visit_order()[lo..hi];
            let mut sorted: Vec<u32> = seg.to_vec();
            sorted.sort_unstable();
            assert_eq!(sorted, (lo as u32..hi as u32).collect::<Vec<_>>(), "grove {g}");
            for w in seg.windows(2) {
                let (a, b) = (w[0] as usize, w[1] as usize);
                let (da, db) = (arena.live_depth(a), arena.live_depth(b));
                assert!(
                    da > db || (da == db && a < b),
                    "grove {g}: visit order not stable-descending at {a}→{b}"
                );
            }
        }
        // visit_rank is the inverse permutation.
        for t in 0..n {
            assert_eq!(arena.visit_order()[arena.visit_rank(t)] as usize, t);
        }
    }

    #[test]
    fn quantized_lanes_match_f32_walk_bitwise() {
        // The in-module pin of the rank-code guarantee: quantizing the
        // transposed tile through the pack-time tables and walking the
        // u8 threshold codes reaches exactly the f32 walk's cursors.
        let (trees, ds) = ragged_flats();
        let arena = ForestArena::from_flat_trees(&trees);
        let thr_q = arena.thr_q8().expect("demo forest fits u8 rank codes");
        assert_eq!(arena.quant_lane(), Some("u8"));
        let q = arena.quant_tables();
        let n = 13.min(ds.test.len());
        let f = arena.n_features();
        let t_cnt = arena.n_trees();
        let mut xt = vec![0.0f32; n * f];
        for s in 0..n {
            for k in 0..f {
                xt[k * n + s] = ds.test.x[s * f + k];
            }
        }
        let mut c_f32 = vec![0u16; t_cnt * n];
        arena.traverse_tile_transposed(0, t_cnt, &xt, n, &mut c_f32, false);
        let mut xq = vec![0u8; n * f];
        for k in 0..f {
            for s in 0..n {
                xq[k * n + s] = u8::try_from(q.code(k, xt[k * n + s])).unwrap();
            }
        }
        let mut c_q = vec![0u16; t_cnt * n];
        arena.traverse_tile_lanes(
            0,
            t_cnt,
            &xq,
            n,
            &mut c_q,
            thr_q,
            &[],
            GatherMode::Scalar,
            false,
            SimdLevel::Scalar,
        );
        assert_eq!(c_q, c_f32, "u8 lanes diverged from the f32 walk");
    }

    /// Quantize a row-major test slice into a feature-major u8 tile.
    fn quantized_tile_u8(arena: &ForestArena, x: &[f32], n: usize) -> Vec<u8> {
        let f = arena.n_features();
        let q = arena.quant_tables();
        let mut xq = vec![0u8; n * f];
        for s in 0..n {
            for k in 0..f {
                xq[k * n + s] = u8::try_from(q.code(k, x[s * f + k])).unwrap();
            }
        }
        xq
    }

    #[test]
    fn simd_levels_match_scalar_walk_bitwise() {
        // Whole-kernel pin of the vector path: for every level this host
        // supports, the u8-lane walk over the ragged fixture (deep +
        // shallow + leaf-only trees, grove-aligned and straddling
        // ranges, padded and ragged) reaches exactly the scalar lane's
        // cursors — including tile widths that exercise the vector
        // kernels' scalar tails.
        let (trees, ds) = ragged_flats();
        let n_trees = trees.len();
        let arena = ForestArena::from_flat_trees(&trees).with_grove_sizes(&[2, 2, n_trees - 4]);
        let thr_q = arena.thr_q8().expect("demo forest fits u8 rank codes");
        let f = arena.n_features();
        for level in [SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon] {
            if !level.supported() {
                continue;
            }
            for n in [1usize, 7, 16, 19.min(ds.test.len())] {
                let xq = quantized_tile_u8(&arena, &ds.test.x[..n * f], n);
                for (lo, hi) in [(0usize, n_trees), (0, 4), (1, 3)] {
                    for padded in [false, true] {
                        let t_cnt = hi - lo;
                        let mut c_ref = vec![0u16; t_cnt * n];
                        arena.traverse_tile_lanes(
                            lo,
                            hi,
                            &xq,
                            n,
                            &mut c_ref,
                            thr_q,
                            &[],
                            GatherMode::Scalar,
                            padded,
                            SimdLevel::Scalar,
                        );
                        let mut c_vec = vec![0u16; t_cnt * n];
                        arena.traverse_tile_lanes(
                            lo,
                            hi,
                            &xq,
                            n,
                            &mut c_vec,
                            thr_q,
                            &[],
                            GatherMode::Scalar,
                            padded,
                            level,
                        );
                        assert_eq!(
                            c_vec,
                            c_ref,
                            "{} diverged: n={n} range {lo}..{hi} padded={padded}",
                            level.label()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn simd_on_depth_zero_forest_is_identical() {
        // Leaf-only arena: no levels to step, so every dispatch level
        // must agree trivially (and not touch the cursor buffer shape).
        let mut s = crate::data::Split::new(2, 3);
        for _ in 0..6 {
            s.push(&[0.0, 1.0], 2);
        }
        let mut rng = crate::util::rng::Rng::new(5);
        let tree = crate::dt::builder::fit_tree(
            &s,
            &[0, 1, 2, 3, 4, 5],
            &crate::dt::builder::TreeParams::default(),
            &mut rng,
        );
        let flat = FlatTree::from_tree(&tree, 0);
        let arena = ForestArena::from_flat_trees(&[flat.clone(), flat]);
        assert_eq!(arena.depth(), 0);
        // No internal nodes ⇒ the (empty) u8 threshold table is `&[]`.
        let thr_q: &[u8] = &[];
        let n = 5;
        let xq = vec![0u8; n * arena.n_features()];
        for level in [SimdLevel::Scalar, SimdLevel::detect()] {
            let mut cur = vec![7u16; 2 * n];
            arena.traverse_tile_lanes(
                0,
                2,
                &xq,
                n,
                &mut cur,
                thr_q,
                &[],
                GatherMode::Scalar,
                false,
                level,
            );
            assert_eq!(cur, vec![0u16; 2 * n], "{}", level.label());
        }
    }

    #[test]
    fn vector_gather_matches_scalar_gather_bitwise() {
        // The gather-stage pin: for every level this host supports, the
        // index-gathered walk over the packed (feat, code) records — on
        // a GATHER_PAD-padded tile — reaches exactly the cursors of the
        // scalar-gather walk, over grove-aligned and straddling ranges.
        // An exactly-sized tile under GatherMode::Vector must silently
        // keep the scalar gather stage and still agree.
        let (trees, ds) = ragged_flats();
        let n_trees = trees.len();
        let arena = ForestArena::from_flat_trees(&trees).with_grove_sizes(&[2, 2, n_trees - 4]);
        let thr_q = arena.thr_q8().expect("demo forest fits u8 rank codes");
        assert_eq!(arena.gather_q8().len(), thr_q.len(), "gather records track the code table");
        assert_eq!(
            arena.gather_q16().len(),
            arena.thr_q16().map_or(0, <[u16]>::len),
            "u16 gather records track the u16 code table"
        );
        let f = arena.n_features();
        for level in [SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon] {
            if !level.supported() {
                continue;
            }
            for n in [1usize, 7, 16, 19.min(ds.test.len())] {
                let xq = quantized_tile_u8(&arena, &ds.test.x[..n * f], n);
                let mut padded_xq = xq.clone();
                padded_xq.resize(n * f + GATHER_PAD, 0);
                for (lo, hi) in [(0usize, n_trees), (0, 4), (1, 3)] {
                    let t_cnt = hi - lo;
                    let mut c_ref = vec![0u16; t_cnt * n];
                    arena.traverse_tile_lanes(
                        lo,
                        hi,
                        &xq,
                        n,
                        &mut c_ref,
                        thr_q,
                        &[],
                        GatherMode::Scalar,
                        false,
                        SimdLevel::Scalar,
                    );
                    let mut c_vec = vec![0u16; t_cnt * n];
                    arena.traverse_tile_lanes(
                        lo,
                        hi,
                        &padded_xq,
                        n,
                        &mut c_vec,
                        thr_q,
                        arena.gather_q8(),
                        GatherMode::Vector,
                        false,
                        level,
                    );
                    assert_eq!(
                        c_vec,
                        c_ref,
                        "{} gather diverged: n={n} range {lo}..{hi}",
                        level.label()
                    );
                    // Unpadded tile: Vector request degrades to the
                    // scalar gather stage, never to wrong answers.
                    let mut c_un = vec![0u16; t_cnt * n];
                    arena.traverse_tile_lanes(
                        lo,
                        hi,
                        &xq,
                        n,
                        &mut c_un,
                        thr_q,
                        arena.gather_q8(),
                        GatherMode::Vector,
                        false,
                        level,
                    );
                    assert_eq!(
                        c_un,
                        c_ref,
                        "{} unpadded-gather fallback diverged: n={n} range {lo}..{hi}",
                        level.label()
                    );
                }
            }
        }
    }

    #[test]
    fn grove_aligned_and_fallback_ranges_agree() {
        // Tree range (0, 4) spans groves 0–1 exactly (prefix-live visit
        // path); (1, 3) straddles a grove boundary (per-tree-branch
        // fallback). Both must reach the per-sample leaf indices.
        let (trees, ds) = ragged_flats();
        let n_trees = trees.len();
        let arena = ForestArena::from_flat_trees(&trees).with_grove_sizes(&[2, 2, n_trees - 4]);
        let n = 9.min(ds.test.len());
        let f = arena.n_features();
        for (lo, hi) in [(0usize, 4usize), (1, 3)] {
            let mut cursors = vec![0u32; (hi - lo) * n];
            arena.traverse_tile(lo, hi, &ds.test.x[..n * f], n, &mut cursors);
            for s in 0..n {
                let x = ds.test.row(s);
                for j in 0..hi - lo {
                    assert_eq!(
                        cursors[j * n + s] as usize,
                        arena.leaf_index(lo + j, x),
                        "range {lo}..{hi} tree {j} row {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn ragged_walks_match_flat_traversal_bitwise() {
        // Early exit on a mixed-depth arena: per-sample, tiled row-major
        // and tiled transposed walks all reach byte-identically the leaf
        // the padded per-tree FlatTree traversal reaches.
        let (trees, ds) = ragged_flats();
        let arena = ForestArena::from_flat_trees(&trees);
        let depth = arena.depth();
        let padded: Vec<FlatTree> = trees.iter().map(|t| t.repad(depth)).collect();
        let n = 19.min(ds.test.len());
        let f = arena.n_features();
        let t_cnt = arena.n_trees();

        let mut cursors = vec![0u32; t_cnt * n];
        arena.traverse_tile(0, t_cnt, &ds.test.x[..n * f], n, &mut cursors);

        // Transposed tile (feature-major) with both cursor widths.
        let mut xt = vec![0.0f32; n * f];
        for s in 0..n {
            for k in 0..f {
                xt[k * n + s] = ds.test.x[s * f + k];
            }
        }
        let mut c16 = vec![0u16; t_cnt * n];
        arena.traverse_tile_transposed(0, t_cnt, &xt, n, &mut c16, false);
        let mut c32p = vec![0u32; t_cnt * n];
        arena.traverse_tile_transposed(0, t_cnt, &xt, n, &mut c32p, true);

        for s in 0..n {
            let x = ds.test.row(s);
            for (t, tree) in padded.iter().enumerate() {
                let want = tree.predict_proba(x);
                let leaf = arena.leaf_index(t, x);
                assert_eq!(arena.leaf_slice(t, leaf), want, "leaf_index tree {t} row {s}");
                assert_eq!(cursors[t * n + s] as usize, leaf, "tile tree {t} row {s}");
                assert_eq!(c16[t * n + s] as usize, leaf, "u16 tile tree {t} row {s}");
                assert_eq!(c32p[t * n + s] as usize, leaf, "padded tile tree {t} row {s}");
            }
        }
    }
}
