//! `exec` — the SoA compiled-forest engine behind every tree-based
//! prediction path.
//!
//! The paper's energy win comes from groves of *complete* trees traversed
//! level-synchronously in hardware (§3.2); this module is the software
//! twin of that layout discipline. Instead of a `Vec<FlatTree>` of
//! per-tree heap objects walked one sample at a time, the whole forest is
//! packed once into a [`ForestArena`] — contiguous level-major
//! `feat`/`thr` node tables plus tree-major leaf distributions, with
//! per-tree and per-grove offset tables — and batches are evaluated by a
//! [`BatchPlan`]: a tiled traversal kernel whose outer loop is the tree
//! *level* and whose inner loop is the samples of a tile, exactly the
//! order the grove PE evaluates in hardware.
//!
//! The packing also records per-tree **live-depth** tables (deepest level
//! holding a live split): every traversal exits at a tree's live depth
//! and computes the bottom-level leaf in closed form (`i << remaining` —
//! dead padding routes left), so mixed-depth (*ragged*) forests cost
//! Σ live_depth comparisons per sample instead of trees × padded depth,
//! byte-identically. Tiles are transposed feature-major and cursors
//! shrink to `u16` on shallow arenas; [`BatchPlan::auto_tile`] sizes the
//! tile from the arena shape and thread count. Comparator-op
//! *accounting* stays at the depth-bound hardware charge (Table 1 /
//! Fig 4–5 stable); the skipped work is reported via
//! [`ExecReport::levels_skipped`](backend::ExecReport).
//!
//! Every tree-based predictor in the crate owns (or slices) an arena:
//!
//! * `api::RfModel` packs its forest and serves both vote modes through
//!   one [`BatchPlan`];
//! * `fog::FieldOfGroves` packs all trees into one shared arena and its
//!   `Grove`s become disjoint tree-range slices of it: the coordinator's
//!   grove workers batch each hop through the tile kernel
//!   (`Grove::accumulate_proba_tile`), while Algorithm 2's offline
//!   per-sample evaluation walks the same arena arrays one row at a time
//!   (confidence gating is inherently per-sample);
//! * `forest::budgeted` measures validation accuracy and feature
//!   acquisition cost on the arena;
//! * the μarch PE / energy models derive comparator counts and
//!   VMEM/sparse-storage bytes from the arena layout (numerically
//!   identical to the per-`FlatTree` accounting they replaced).
//!
//! **Sharing discipline:** arenas are immutable after packing and always
//! held behind an `Arc` by their owners (`RfModel`, `FieldOfGroves`).
//! Scale-out consumers — the replicas of a
//! [`ShardedServer`](crate::coordinator::ShardedServer), grove workers,
//! parallel benches — must clone the `Arc<ForestArena>` handle, never
//! re-pack or materialize trees: N replicas of a forest model cost one
//! arena allocation, and every [`BatchPlan`] they build borrows the same
//! level-major arrays.
//!
//! **Execution backends:** the engine behind a prediction path is
//! swappable ([`backend::Backend`]): [`SoftwareBackend`] runs the
//! kernels above unchanged, [`UarchBackend`] streams the same tiles
//! through the cycle-level grove-ring simulator and folds its event
//! counts into per-classification cycle/energy estimates. Backends
//! change *accounting*, never *answers* — `rust/tests/backend.rs` pins
//! byte-identical probabilities across backends for every tree-based
//! registry model.
//!
//! **Quantized lanes:** pack time also builds per-feature threshold
//! rank tables ([`quant::QuantTables`]) and parallel u8/u16 threshold
//! arrays; a [`BatchPlan`] with [`QuantMode`] on codes each feature
//! tile through the tables during the transpose and runs the inner
//! compare loop on integer lanes — exactly (rank codes replay the f32
//! walk bit-for-bit) or lossily (affine codes at a chosen bit width).
//! See the "Quantized fixed-point lanes" section of [`arena`].
//!
//! **SIMD dispatch:** the integer lanes run under explicit vector
//! kernels ([`simd`]) when the host supports them — AVX2/SSE2 on
//! x86_64, NEON on aarch64, 8–32 samples per compare/advance
//! instruction — selected once per [`BatchPlan`] as a [`SimdLevel`]
//! (`FOG_FORCE_SCALAR=1` pins the scalar reference lane). The vector
//! kernels' per-sample operand loads run as AVX2 `vpgatherdd` index
//! gathers over the arena's packed level-major `(feat, code)` records
//! (NEON: a `tbl` threshold lookup on shallow levels), selected as a
//! [`GatherMode`] with its own `FOG_FORCE_SCALAR_GATHER=1` pin, and the
//! lossy affine coding pass inside the tile transpose is vectorized the
//! same way (`simd::code_lossy_row`). Every vector path is
//! conformance-pinned byte-identical to the scalar loop, all intrinsic
//! `unsafe` lives in `exec/simd.rs`, and comparator-op/energy
//! accounting is dispatch-invariant.

pub mod arena;
pub mod backend;
pub mod batch;
pub mod quant;
pub mod simd;

pub use arena::ForestArena;
pub use backend::{Backend, ExecReport, SoftwareBackend, UarchBackend};
pub use batch::{BatchPlan, Reduce, DEFAULT_TILE};
pub use quant::{QuantMode, QuantTables};
pub use simd::{GatherMode, SimdLevel};
