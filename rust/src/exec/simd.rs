//! Runtime-dispatched SIMD kernels for the quantized rank-code lanes.
//!
//! The exact u8/u16 rank codes (see `exec::quant`) turn the per-level
//! comparator loop into stride-1 unsigned integer compares; this module
//! replaces the body of that loop (`arena`'s `step_level`) with explicit
//! vector kernels that process 8–32 samples per instruction:
//!
//! 1. **Gather** (scalar): each sample's cursor names a different node,
//!    so its threshold code `thr[cur]` and transposed feature code
//!    `xt[feat[cur] * n + s]` are loaded with plain bounds-checked
//!    indexing into small stack arrays.
//! 2. **Compare** (vector): unsigned `>` over a full register. x86 has
//!    no unsigned byte/word compare, so both sides are sign-biased
//!    (`x ^ MIN`) and compared signed; NEON compares unsigned natively.
//! 3. **Advance** (vector): `cur' = 2*cur + (x > thr)` becomes
//!    `2*cur - mask` — an all-ones u16 mask is `-1` mod 2^16, and
//!    cursors stay below 2^15 at depth ≤ 15 so the doubling never
//!    wraps. Byte masks are sign-extended (not zero-extended) to u16
//!    lanes so the subtract sees `0xFFFF`, in sample order.
//!
//! Dispatch: [`SimdLevel::detect`] probes the host once (cached) —
//! AVX2 else SSE2 on x86_64 via `is_x86_feature_detected!`, NEON on
//! aarch64 (baseline), scalar elsewhere — honoring `FOG_FORCE_SCALAR=1`
//! for conformance runs. `BatchPlan::with_quant` resolves the level
//! once per plan, so the per-tile path pays zero dispatch cost. The
//! scalar loop remains the always-available fallback: f32 lanes, u32
//! cursors (depth > 15), vector-width tails, and unsupported levels
//! all take it via [`SimdLane::step_simd`] returning `false`.
//!
//! Conformance: every kernel is pinned byte-identical to the scalar
//! lane — identical tree paths, and the caller accumulates
//! probabilities in original tree order either way, so FP reductions
//! stay bit-stable. Dead-slot sentinel codes (`u8::MAX`/`u16::MAX`)
//! route left under `>` exactly as in the scalar loop. All
//! intrinsic-touching `unsafe` lives in this module, behind safe
//! wrappers: the `#[target_feature]` kernels are only reachable through
//! a `SimdLevel` the host was probed to support.

use super::arena::CursorIdx;
use std::sync::OnceLock;

/// Vector ISA tier the quantized kernel runs at. Resolved once per
/// `BatchPlan` (at `with_quant` time); `Scalar` is always available.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// Plain scalar loop — the reference every vector path is pinned to.
    Scalar,
    /// x86_64 SSE2 (baseline): 16 u8 / 8 u16 codes per compare.
    Sse2,
    /// x86_64 AVX2: 32 u8 / 16 u16 codes per compare.
    Avx2,
    /// aarch64 NEON (baseline): 16 u8 / 8 u16 codes per compare.
    Neon,
}

impl SimdLevel {
    /// Stable numeric rank for metrics plumbing (atomic max-merge
    /// across replicas; decode with [`SimdLevel::label_of_rank`]).
    pub fn rank(self) -> u64 {
        match self {
            SimdLevel::Scalar => 0,
            SimdLevel::Sse2 => 1,
            SimdLevel::Avx2 => 2,
            SimdLevel::Neon => 3,
        }
    }

    /// Human-readable label for BENCH_JSON and log lines.
    pub fn label(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Decode a [`SimdLevel::rank`] back to its label; unknown ranks
    /// (e.g. a zeroed metrics snapshot) read as `"scalar"`.
    pub fn label_of_rank(rank: u64) -> &'static str {
        match rank {
            1 => "sse2",
            2 => "avx2",
            3 => "neon",
            _ => "scalar",
        }
    }

    /// Best level this host supports, honoring `FOG_FORCE_SCALAR`
    /// (nonempty and not `"0"` forces the scalar reference lane).
    /// Probed once per process and cached.
    pub fn detect() -> SimdLevel {
        static DETECTED: OnceLock<SimdLevel> = OnceLock::new();
        *DETECTED.get_or_init(|| SimdLevel::resolve(env_force_scalar(), SimdLevel::native()))
    }

    /// Pure dispatch rule behind [`SimdLevel::detect`], split out so
    /// tests cover it without mutating the process environment.
    pub(crate) fn resolve(force_scalar: bool, native: SimdLevel) -> SimdLevel {
        if force_scalar {
            SimdLevel::Scalar
        } else {
            native
        }
    }

    /// Whether the running host can execute this level's kernels.
    /// `BatchPlan::with_simd` clamps unsupported requests to `Scalar`,
    /// so the `unsafe` kernels stay unreachable on hosts that would
    /// fault on them.
    pub fn supported(self) -> bool {
        match self {
            SimdLevel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Sse2 => true,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => true,
            _ => false,
        }
    }

    /// Best level the host CPU supports, ignoring the env override.
    fn native() -> SimdLevel {
        #[cfg(target_arch = "x86_64")]
        {
            return if std::arch::is_x86_feature_detected!("avx2") {
                SimdLevel::Avx2
            } else {
                SimdLevel::Sse2
            };
        }
        #[cfg(target_arch = "aarch64")]
        {
            return SimdLevel::Neon;
        }
        #[allow(unreachable_code)]
        SimdLevel::Scalar
    }
}

/// `FOG_FORCE_SCALAR` set to anything nonempty other than `"0"`.
fn env_force_scalar() -> bool {
    match std::env::var("FOG_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// Lane types `step_level` can hand to a vector kernel. `step_simd`
/// returns `true` when a vector kernel fully handled the level
/// (including its scalar tail), `false` when the caller must run the
/// scalar loop instead (f32 lanes, u32 cursors, `Scalar` level, or a
/// level this host/arch has no kernel for).
pub(crate) trait SimdLane: Copy + PartialOrd {
    fn step_simd<C: CursorIdx>(
        level: SimdLevel,
        xt: &[Self],
        n: usize,
        feat: &[i32],
        thr: &[Self],
        cur: &mut [C],
    ) -> bool;
}

impl SimdLane for f32 {
    #[inline(always)]
    fn step_simd<C: CursorIdx>(
        _level: SimdLevel,
        _xt: &[f32],
        _n: usize,
        _feat: &[i32],
        _thr: &[f32],
        _cur: &mut [C],
    ) -> bool {
        false
    }
}

impl SimdLane for u8 {
    #[inline(always)]
    fn step_simd<C: CursorIdx>(
        level: SimdLevel,
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        cur: &mut [C],
    ) -> bool {
        match C::as_u16_mut(cur) {
            Some(c16) => step_u8(level, xt, n, feat, thr, c16),
            None => false,
        }
    }
}

impl SimdLane for u16 {
    #[inline(always)]
    fn step_simd<C: CursorIdx>(
        level: SimdLevel,
        xt: &[u16],
        n: usize,
        feat: &[i32],
        thr: &[u16],
        cur: &mut [C],
    ) -> bool {
        match C::as_u16_mut(cur) {
            Some(c16) => step_u16(level, xt, n, feat, thr, c16),
            None => false,
        }
    }
}

/// Dispatch one u8-lane level step to the host kernel for `level`.
fn step_u8(
    level: SimdLevel,
    xt: &[u8],
    n: usize,
    feat: &[i32],
    thr: &[u8],
    cur: &mut [u16],
) -> bool {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => {
            // SAFETY: SSE2 is baseline on x86_64.
            unsafe { x86::step_u8_sse2(xt, n, feat, thr, cur) };
            true
        }
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            // SAFETY: `level` only reaches Avx2 through `detect()` or a
            // `supported()`-clamped override, both of which probed AVX2.
            unsafe { x86::step_u8_avx2(xt, n, feat, thr, cur) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            // SAFETY: NEON is baseline on aarch64.
            unsafe { neon::step_u8_neon(xt, n, feat, thr, cur) };
            true
        }
        _ => {
            let _ = (xt, n, feat, thr, cur);
            false
        }
    }
}

/// Dispatch one u16-lane level step to the host kernel for `level`.
fn step_u16(
    level: SimdLevel,
    xt: &[u16],
    n: usize,
    feat: &[i32],
    thr: &[u16],
    cur: &mut [u16],
) -> bool {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => {
            // SAFETY: SSE2 is baseline on x86_64.
            unsafe { x86::step_u16_sse2(xt, n, feat, thr, cur) };
            true
        }
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            // SAFETY: `level` only reaches Avx2 through `detect()` or a
            // `supported()`-clamped override, both of which probed AVX2.
            unsafe { x86::step_u16_avx2(xt, n, feat, thr, cur) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            // SAFETY: NEON is baseline on aarch64.
            unsafe { neon::step_u16_neon(xt, n, feat, thr, cur) };
            true
        }
        _ => {
            let _ = (xt, n, feat, thr, cur);
            false
        }
    }
}

/// Scalar gather for one vector block starting at sample `s`: cursors
/// diverge per sample, so the per-sample threshold/feature code loads
/// stay scalar (bounds-checked) and feed the vector compare from small
/// stack arrays. Returns `(feature codes, threshold codes)`.
#[inline(always)]
fn gather<L: Copy + Default, const V: usize>(
    xt: &[L],
    n: usize,
    feat: &[i32],
    thr: &[L],
    cur: &[u16],
    s: usize,
) -> ([L; V], [L; V]) {
    let mut tf = [L::default(); V];
    let mut tt = [L::default(); V];
    for j in 0..V {
        let i = cur[s + j] as usize;
        tt[j] = thr[i];
        tf[j] = xt[feat[i] as usize * n + s + j];
    }
    (tf, tt)
}

/// Scalar remainder for the samples past the last full vector block —
/// the same body as the arena's scalar loop, so tails are
/// byte-identical to the reference lane.
#[inline(always)]
fn scalar_tail<L: Copy + PartialOrd>(
    xt: &[L],
    n: usize,
    feat: &[i32],
    thr: &[L],
    cur: &mut [u16],
    from: usize,
) {
    for (s, ci) in cur.iter_mut().enumerate().skip(from) {
        let i = *ci as usize;
        let go_right = xt[feat[i] as usize * n + s] > thr[i];
        *ci = (2 * i + go_right as usize) as u16;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! x86_64 kernels. x86 integer compares are signed, so unsigned
    //! rank codes are sign-biased (`x ^ MIN`) on both sides first; the
    //! dead-slot sentinel (`MAX`) biases to the largest signed value,
    //! so `>` stays false and dead lanes route left like the scalar
    //! loop. Advance uses `add(c, c)` for the doubling (no
    //! immediate-operand shift needed) and subtracts the compare mask.

    use super::{gather, scalar_tail};
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must ensure SSE2 (baseline on x86_64).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn step_u8_sse2(
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        cur: &mut [u16],
    ) {
        const V: usize = 16;
        let len = cur.len();
        let bias = _mm_set1_epi8(i8::MIN);
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u8, V>(xt, n, feat, thr, cur, s);
            let a = _mm_xor_si128(_mm_loadu_si128(tf.as_ptr() as *const __m128i), bias);
            let b = _mm_xor_si128(_mm_loadu_si128(tt.as_ptr() as *const __m128i), bias);
            let gt = _mm_cmpgt_epi8(a, b);
            // Duplicating each mask byte widens it to a u16 lane of
            // 0x0000/0xFFFF, preserving sample order across halves.
            let m_lo = _mm_unpacklo_epi8(gt, gt);
            let m_hi = _mm_unpackhi_epi8(gt, gt);
            let p = cur.as_mut_ptr().add(s) as *mut __m128i;
            let c_lo = _mm_loadu_si128(p);
            let c_hi = _mm_loadu_si128(p.add(1));
            _mm_storeu_si128(p, _mm_sub_epi16(_mm_add_epi16(c_lo, c_lo), m_lo));
            _mm_storeu_si128(p.add(1), _mm_sub_epi16(_mm_add_epi16(c_hi, c_hi), m_hi));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure SSE2 (baseline on x86_64).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn step_u16_sse2(
        xt: &[u16],
        n: usize,
        feat: &[i32],
        thr: &[u16],
        cur: &mut [u16],
    ) {
        const V: usize = 8;
        let len = cur.len();
        let bias = _mm_set1_epi16(i16::MIN);
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u16, V>(xt, n, feat, thr, cur, s);
            let a = _mm_xor_si128(_mm_loadu_si128(tf.as_ptr() as *const __m128i), bias);
            let b = _mm_xor_si128(_mm_loadu_si128(tt.as_ptr() as *const __m128i), bias);
            let gt = _mm_cmpgt_epi16(a, b);
            let p = cur.as_mut_ptr().add(s) as *mut __m128i;
            let c = _mm_loadu_si128(p);
            _mm_storeu_si128(p, _mm_sub_epi16(_mm_add_epi16(c, c), gt));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure AVX2 (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn step_u8_avx2(
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        cur: &mut [u16],
    ) {
        const V: usize = 32;
        let len = cur.len();
        let bias = _mm256_set1_epi8(i8::MIN);
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u8, V>(xt, n, feat, thr, cur, s);
            let a = _mm256_xor_si256(_mm256_loadu_si256(tf.as_ptr() as *const __m256i), bias);
            let b = _mm256_xor_si256(_mm256_loadu_si256(tt.as_ptr() as *const __m256i), bias);
            let gt = _mm256_cmpgt_epi8(a, b);
            // Sign-extend each mask byte to a u16 lane in sample order
            // (256-bit unpack would interleave within 128-bit halves).
            let m_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(gt));
            let m_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(gt));
            let p = cur.as_mut_ptr().add(s) as *mut __m256i;
            let c_lo = _mm256_loadu_si256(p);
            let c_hi = _mm256_loadu_si256(p.add(1));
            _mm256_storeu_si256(p, _mm256_sub_epi16(_mm256_add_epi16(c_lo, c_lo), m_lo));
            _mm256_storeu_si256(p.add(1), _mm256_sub_epi16(_mm256_add_epi16(c_hi, c_hi), m_hi));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure AVX2 (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn step_u16_avx2(
        xt: &[u16],
        n: usize,
        feat: &[i32],
        thr: &[u16],
        cur: &mut [u16],
    ) {
        const V: usize = 16;
        let len = cur.len();
        let bias = _mm256_set1_epi16(i16::MIN);
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u16, V>(xt, n, feat, thr, cur, s);
            let a = _mm256_xor_si256(_mm256_loadu_si256(tf.as_ptr() as *const __m256i), bias);
            let b = _mm256_xor_si256(_mm256_loadu_si256(tt.as_ptr() as *const __m256i), bias);
            let gt = _mm256_cmpgt_epi16(a, b);
            let p = cur.as_mut_ptr().add(s) as *mut __m256i;
            let c = _mm256_loadu_si256(p);
            _mm256_storeu_si256(p, _mm256_sub_epi16(_mm256_add_epi16(c, c), gt));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! aarch64 kernels. NEON compares unsigned natively (`vcgtq_u8` /
    //! `vcgtq_u16`), so no sign-bias is needed; byte masks are
    //! sign-extended to u16 lanes (`vmovl_s8` — the unsigned widen
    //! would zero-extend `0xFF` to `0x00FF` and break the
    //! subtract-mask advance).

    use super::{gather, scalar_tail};
    use std::arch::aarch64::*;

    /// # Safety
    /// Caller must ensure NEON (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn step_u8_neon(
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        cur: &mut [u16],
    ) {
        const V: usize = 16;
        let len = cur.len();
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u8, V>(xt, n, feat, thr, cur, s);
            let gt = vcgtq_u8(vld1q_u8(tf.as_ptr()), vld1q_u8(tt.as_ptr()));
            let gs = vreinterpretq_s8_u8(gt);
            let m_lo = vreinterpretq_u16_s16(vmovl_s8(vget_low_s8(gs)));
            let m_hi = vreinterpretq_u16_s16(vmovl_s8(vget_high_s8(gs)));
            let c_lo = vld1q_u16(cur.as_ptr().add(s));
            let c_hi = vld1q_u16(cur.as_ptr().add(s + 8));
            vst1q_u16(cur.as_mut_ptr().add(s), vsubq_u16(vaddq_u16(c_lo, c_lo), m_lo));
            vst1q_u16(cur.as_mut_ptr().add(s + 8), vsubq_u16(vaddq_u16(c_hi, c_hi), m_hi));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure NEON (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn step_u16_neon(
        xt: &[u16],
        n: usize,
        feat: &[i32],
        thr: &[u16],
        cur: &mut [u16],
    ) {
        const V: usize = 8;
        let len = cur.len();
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u16, V>(xt, n, feat, thr, cur, s);
            let gt = vcgtq_u16(vld1q_u16(tf.as_ptr()), vld1q_u16(tt.as_ptr()));
            let c = vld1q_u16(cur.as_ptr().add(s));
            vst1q_u16(cur.as_mut_ptr().add(s), vsubq_u16(vaddq_u16(c, c), gt));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(state: &mut u64) -> u64 {
        *state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        *state >> 33
    }

    /// Every vector level this host can actually run.
    fn vector_levels() -> Vec<SimdLevel> {
        [SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon]
            .into_iter()
            .filter(|l| l.supported())
            .collect()
    }

    /// One synthetic tree level: `w` nodes over `f` features, `n`
    /// samples, cursors spread across the nodes.
    fn level_case_u8(
        w: usize,
        f: usize,
        n: usize,
        seed: u64,
    ) -> (Vec<u8>, Vec<i32>, Vec<u8>, Vec<u16>) {
        let mut st = seed;
        let xt: Vec<u8> = (0..f * n).map(|_| lcg(&mut st) as u8).collect();
        let feat: Vec<i32> = (0..w).map(|_| (lcg(&mut st) as usize % f) as i32).collect();
        let thr: Vec<u8> = (0..w).map(|_| lcg(&mut st) as u8).collect();
        let cur: Vec<u16> = (0..n).map(|_| (lcg(&mut st) as usize % w) as u16).collect();
        (xt, feat, thr, cur)
    }

    /// u16-lane variant with codes past the u8 range (255-cut shape).
    fn level_case_u16(
        w: usize,
        f: usize,
        n: usize,
        seed: u64,
    ) -> (Vec<u16>, Vec<i32>, Vec<u16>, Vec<u16>) {
        let mut st = seed;
        let xt: Vec<u16> = (0..f * n).map(|_| (lcg(&mut st) % 1021) as u16).collect();
        let feat: Vec<i32> = (0..w).map(|_| (lcg(&mut st) as usize % f) as i32).collect();
        let thr: Vec<u16> = (0..w).map(|_| (lcg(&mut st) % 1021) as u16).collect();
        let cur: Vec<u16> = (0..n).map(|_| (lcg(&mut st) as usize % w) as u16).collect();
        (xt, feat, thr, cur)
    }

    /// The scalar reference body (same as the arena's loop).
    fn step_ref<L: Copy + PartialOrd>(
        xt: &[L],
        n: usize,
        feat: &[i32],
        thr: &[L],
        cur: &mut [u16],
    ) {
        for (s, ci) in cur.iter_mut().enumerate() {
            let i = *ci as usize;
            let go_right = xt[feat[i] as usize * n + s] > thr[i];
            *ci = (2 * i + go_right as usize) as u16;
        }
    }

    const WIDTHS: [usize; 14] = [1, 3, 7, 8, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100];

    #[test]
    fn u8_kernels_match_scalar_at_every_width() {
        for level in vector_levels() {
            for &n in &WIDTHS {
                let (xt, feat, thr, cur0) = level_case_u8(16, 5, n, 0x5eed + n as u64);
                let mut want = cur0.clone();
                step_ref(&xt, n, &feat, &thr, &mut want);
                let mut got = cur0.clone();
                assert!(u8::step_simd(level, &xt, n, &feat, &thr, &mut got));
                assert_eq!(got, want, "u8 {} n={n}", level.label());
            }
        }
    }

    #[test]
    fn u16_kernels_match_scalar_at_every_width() {
        for level in vector_levels() {
            for &n in &WIDTHS {
                let (xt, feat, thr, cur0) = level_case_u16(16, 5, n, 0xfeed + n as u64);
                let mut want = cur0.clone();
                step_ref(&xt, n, &feat, &thr, &mut want);
                let mut got = cur0.clone();
                assert!(u16::step_simd(level, &xt, n, &feat, &thr, &mut got));
                assert_eq!(got, want, "u16 {} n={n}", level.label());
            }
        }
    }

    #[test]
    fn dead_slot_sentinels_route_left() {
        for level in vector_levels() {
            let n = 40;
            let (xt, feat, _, cur0) = level_case_u8(8, 4, n, 99);
            let thr = vec![u8::MAX; 8];
            let mut got = cur0.clone();
            assert!(u8::step_simd(level, &xt, n, &feat, &thr, &mut got));
            for (s, &c) in got.iter().enumerate() {
                assert_eq!(c, 2 * cur0[s], "{} sentinel s={s}", level.label());
            }
            let (xt, feat, _, cur0) = level_case_u16(8, 4, n, 99);
            let thr = vec![u16::MAX; 8];
            let mut got = cur0.clone();
            assert!(u16::step_simd(level, &xt, n, &feat, &thr, &mut got));
            for (s, &c) in got.iter().enumerate() {
                assert_eq!(c, 2 * cur0[s], "{} u16 sentinel s={s}", level.label());
            }
        }
    }

    #[test]
    fn boundary_equal_codes_route_left() {
        // `>` must stay strict in the vector form: equal code pairs
        // (the common case — rank codes collide exactly on cut values)
        // go left.
        for level in vector_levels() {
            let n = 33;
            let xt = vec![7u8; n];
            let feat = vec![0i32; 4];
            let thr = vec![7u8; 4];
            let mut cur: Vec<u16> = (0..n).map(|s| (s % 4) as u16).collect();
            let want: Vec<u16> = cur.iter().map(|&c| 2 * c).collect();
            assert!(u8::step_simd(level, &xt, n, &feat, &thr, &mut cur));
            assert_eq!(cur, want, "{} equal codes", level.label());
        }
    }

    #[test]
    fn u32_cursors_and_f32_lanes_fall_back_to_scalar() {
        let n = 32;
        let (xt, feat, thr, cur0) = level_case_u8(8, 4, n, 7);
        let mut cur32: Vec<u32> = cur0.iter().map(|&c| c as u32).collect();
        for level in vector_levels() {
            assert!(!u8::step_simd(level, &xt, n, &feat, &thr, &mut cur32));
        }
        let xf: Vec<f32> = xt.iter().map(|&v| v as f32).collect();
        let tf: Vec<f32> = thr.iter().map(|&v| v as f32).collect();
        let mut c16 = cur0.clone();
        assert!(!f32::step_simd(SimdLevel::detect(), &xf, n, &feat, &tf, &mut c16));
        assert_eq!(c16, cur0, "fallback must not touch cursors");
    }

    #[test]
    fn scalar_level_is_never_vector_handled() {
        let n = 24;
        let (xt, feat, thr, cur0) = level_case_u8(8, 4, n, 3);
        let mut cur = cur0;
        assert!(!u8::step_simd(SimdLevel::Scalar, &xt, n, &feat, &thr, &mut cur));
    }

    #[test]
    fn resolve_honors_force_scalar() {
        assert_eq!(SimdLevel::resolve(true, SimdLevel::Avx2), SimdLevel::Scalar);
        assert_eq!(SimdLevel::resolve(false, SimdLevel::Avx2), SimdLevel::Avx2);
        assert_eq!(SimdLevel::resolve(false, SimdLevel::Scalar), SimdLevel::Scalar);
    }

    #[test]
    fn detect_returns_a_supported_level() {
        assert!(SimdLevel::detect().supported());
        // Cached: a second call agrees.
        assert_eq!(SimdLevel::detect(), SimdLevel::detect());
    }

    #[test]
    fn rank_label_roundtrip() {
        for l in [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon] {
            assert_eq!(SimdLevel::label_of_rank(l.rank()), l.label());
        }
        assert_eq!(SimdLevel::label_of_rank(99), "scalar");
    }
}
