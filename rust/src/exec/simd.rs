//! Runtime-dispatched SIMD kernels for the quantized rank-code lanes.
//!
//! The exact u8/u16 rank codes (see `exec::quant`) turn the per-level
//! comparator loop into stride-1 unsigned integer compares; this module
//! replaces the body of that loop (`arena`'s `step_level`) with explicit
//! vector kernels that process 8–32 samples per instruction:
//!
//! 1. **Gather**: each sample's cursor names a different node, so its
//!    threshold code `thr[cur]` and transposed feature code
//!    `xt[feat[cur] * n + s]` are indexed loads. On AVX2 both become
//!    `vpgatherdd` index gathers over the arena's level-major packed
//!    `(feat << 16) | code` node records ([`GatherMode`], one gather
//!    fetches both operands per 8 samples); NEON uses a `tbl` register
//!    lookup for the threshold side on shallow (≤ 16-node) levels; SSE2
//!    and every fallback keep the scalar bounds-checked gather into
//!    small stack arrays.
//! 2. **Compare** (vector): unsigned `>` over a full register. x86 has
//!    no unsigned byte/word compare, so both sides are sign-biased
//!    (`x ^ MIN`) and compared signed (the gathered path compares at
//!    i32 width, where zero-extended codes are non-negative and signed
//!    `>` equals unsigned); NEON compares unsigned natively.
//! 3. **Advance** (vector): `cur' = 2*cur + (x > thr)` becomes
//!    `2*cur - mask` — an all-ones u16 mask is `-1` mod 2^16, and
//!    cursors stay below 2^15 at depth ≤ 15 so the doubling never
//!    wraps. Byte masks are sign-extended (not zero-extended) to u16
//!    lanes so the subtract sees `0xFFFF`, in sample order.
//!
//! The module also vectorizes the **lossy affine coding pass**
//! ([`code_lossy_row`]): the `(x - lo) / (hi - lo) → clamp → scale →
//! truncate` chain of `QuantTables::lossy_code` runs 8 features per
//! instruction on AVX2 (4 on NEON) during `BatchPlan`'s tile transpose,
//! with NaN→left, ±inf saturation and the degenerate `hi <= lo` bucket
//! preserved exactly (the scalar tail shares `quant::lossy_affine`
//! verbatim).
//!
//! Dispatch: [`SimdLevel::detect`] probes the host once (cached) —
//! AVX2 else SSE2 on x86_64 via `is_x86_feature_detected!`, NEON on
//! aarch64 (baseline), scalar elsewhere — honoring `FOG_FORCE_SCALAR=1`
//! for conformance runs; [`GatherMode::detect`] independently honors
//! `FOG_FORCE_SCALAR_GATHER=1` to pin the vector-compare kernels to the
//! scalar gather stage. `BatchPlan::with_quant` resolves both once per
//! plan, so the per-tile path pays zero dispatch cost. The scalar loop
//! remains the always-available fallback: f32 lanes, u32 cursors
//! (depth > 15), vector-width tails, and unsupported levels all take it
//! via [`SimdLane::step_simd`] returning `false`.
//!
//! Conformance: every kernel is pinned byte-identical to the scalar
//! lane — identical tree paths, and the caller accumulates
//! probabilities in original tree order either way, so FP reductions
//! stay bit-stable. Dead-slot sentinel codes (`u8::MAX`/`u16::MAX`)
//! route left under `>` exactly as in the scalar loop. All
//! intrinsic-touching `unsafe` lives in this module, behind safe
//! wrappers: the `#[target_feature]` kernels are only reachable through
//! a `SimdLevel` the host was probed to support.

use super::arena::CursorIdx;
use super::quant::lossy_affine;
use std::sync::OnceLock;

/// Vector ISA tier the quantized kernel runs at. Resolved once per
/// `BatchPlan` (at `with_quant` time); `Scalar` is always available.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// Plain scalar loop — the reference every vector path is pinned to.
    Scalar,
    /// x86_64 SSE2 (baseline): 16 u8 / 8 u16 codes per compare.
    Sse2,
    /// x86_64 AVX2: 32 u8 / 16 u16 codes per compare.
    Avx2,
    /// aarch64 NEON (baseline): 16 u8 / 8 u16 codes per compare.
    Neon,
}

impl SimdLevel {
    /// Stable numeric rank for metrics plumbing (atomic max-merge
    /// across replicas; decode with [`SimdLevel::label_of_rank`]).
    pub fn rank(self) -> u64 {
        match self {
            SimdLevel::Scalar => 0,
            SimdLevel::Sse2 => 1,
            SimdLevel::Avx2 => 2,
            SimdLevel::Neon => 3,
        }
    }

    /// Human-readable label for BENCH_JSON and log lines.
    pub fn label(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Decode a [`SimdLevel::rank`] back to its label; unknown ranks
    /// (e.g. a zeroed metrics snapshot) read as `"scalar"`.
    pub fn label_of_rank(rank: u64) -> &'static str {
        match rank {
            1 => "sse2",
            2 => "avx2",
            3 => "neon",
            _ => "scalar",
        }
    }

    /// Best level this host supports, honoring `FOG_FORCE_SCALAR`
    /// (nonempty and not `"0"` forces the scalar reference lane).
    /// Probed once per process and cached.
    pub fn detect() -> SimdLevel {
        static DETECTED: OnceLock<SimdLevel> = OnceLock::new();
        *DETECTED.get_or_init(|| SimdLevel::resolve(env_force_scalar(), SimdLevel::native()))
    }

    /// Pure dispatch rule behind [`SimdLevel::detect`], split out so
    /// tests cover it without mutating the process environment.
    pub(crate) fn resolve(force_scalar: bool, native: SimdLevel) -> SimdLevel {
        if force_scalar {
            SimdLevel::Scalar
        } else {
            native
        }
    }

    /// Whether the running host can execute this level's kernels.
    /// `BatchPlan::with_simd` clamps unsupported requests to `Scalar`,
    /// so the `unsafe` kernels stay unreachable on hosts that would
    /// fault on them.
    pub fn supported(self) -> bool {
        match self {
            SimdLevel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Sse2 => true,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => true,
            _ => false,
        }
    }

    /// Best level the host CPU supports, ignoring the env override.
    fn native() -> SimdLevel {
        #[cfg(target_arch = "x86_64")]
        {
            return if std::arch::is_x86_feature_detected!("avx2") {
                SimdLevel::Avx2
            } else {
                SimdLevel::Sse2
            };
        }
        #[cfg(target_arch = "aarch64")]
        {
            return SimdLevel::Neon;
        }
        #[allow(unreachable_code)]
        SimdLevel::Scalar
    }
}

/// How the per-level operand loads feeding the vector compare are
/// performed. `Vector` is a *request*: an index-gather kernel actually
/// dispatches only where one exists (AVX2 `vpgatherdd`; the NEON `tbl`
/// threshold lookup on ≤ 16-node levels) and the caller proved the
/// gather-safety preconditions (packed node records present, transposed
/// tile padded by [`GATHER_PAD`]); everywhere else the vector compare
/// kernels keep their scalar gather stage, byte-identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GatherMode {
    /// Scalar bounds-checked loads feed the vector compare — the
    /// reference gather stage every index-gather path is pinned to.
    Scalar,
    /// Index-gather the `(feat, code)` node records and transposed
    /// feature codes where the host ISA can.
    Vector,
}

impl GatherMode {
    /// Human-readable label for log lines (the *effective* per-plan
    /// gather label in BENCH_JSON is an ISA name — see
    /// `BatchPlan::gather_label`).
    pub fn label(self) -> &'static str {
        match self {
            GatherMode::Scalar => "scalar",
            GatherMode::Vector => "vector",
        }
    }

    /// Default gather mode, honoring `FOG_FORCE_SCALAR_GATHER`
    /// (nonempty and not `"0"` pins the scalar gather stage while the
    /// compare/advance stay vector). Probed once per process and cached.
    pub fn detect() -> GatherMode {
        static DETECTED: OnceLock<GatherMode> = OnceLock::new();
        *DETECTED.get_or_init(|| GatherMode::resolve(env_force_scalar_gather()))
    }

    /// Pure rule behind [`GatherMode::detect`], split out for tests.
    pub(crate) fn resolve(force_scalar_gather: bool) -> GatherMode {
        if force_scalar_gather {
            GatherMode::Scalar
        } else {
            GatherMode::Vector
        }
    }
}

/// `FOG_FORCE_SCALAR` set to anything nonempty other than `"0"`.
fn env_force_scalar() -> bool {
    match std::env::var("FOG_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// `FOG_FORCE_SCALAR_GATHER` set to anything nonempty other than `"0"`.
fn env_force_scalar_gather() -> bool {
    match std::env::var("FOG_FORCE_SCALAR_GATHER") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// Slack elements the index-gather kernels need past the last
/// addressable transposed-tile element: `vpgatherdd` reads a full dword
/// at `base + index`, so gathering the final u8/u16 code would
/// otherwise read past the buffer. `BatchPlan` over-allocates its tile
/// scratch by this much; callers that pass exactly-sized tiles simply
/// keep the scalar gather stage (checked per call, never unsafe).
pub(crate) const GATHER_PAD: usize = 4;

/// Lane types `step_level` can hand to a vector kernel. `step_simd`
/// returns `true` when a vector kernel fully handled the level
/// (including its scalar tail), `false` when the caller must run the
/// scalar loop instead (f32 lanes, u32 cursors, `Scalar` level, or a
/// level this host/arch has no kernel for).
///
/// `nodes` is the level's window of packed `(feat << 16) | code` gather
/// records (parallel to `thr`; empty when the arena built none) and
/// `vector_gather` asks for the index-gather stage. Callers must only
/// pass `vector_gather = true` after proving the gather-safety
/// contract: every record encodes `feat < n_features`,
/// `xt.len() >= n_features * n + GATHER_PAD`, and
/// `n_features * n <= i32::MAX` (see `ForestArena::traverse_tile_lanes`
/// — the only production call site).
pub(crate) trait SimdLane: Copy + PartialOrd {
    #[allow(clippy::too_many_arguments)]
    fn step_simd<C: CursorIdx>(
        level: SimdLevel,
        xt: &[Self],
        n: usize,
        feat: &[i32],
        thr: &[Self],
        nodes: &[u32],
        vector_gather: bool,
        cur: &mut [C],
    ) -> bool;

    /// Narrow a lossy affine code produced by [`code_lossy_row`] back
    /// into this lane. Codes stay below the lane's dead sentinel by
    /// construction (`lossy_levels` caps them at `MAX - 1`); f32 lanes
    /// never take the rowwise coding path, so their impl is a plain
    /// cast kept only for symmetry.
    fn from_code(code: u32) -> Self;
}

impl SimdLane for f32 {
    #[inline(always)]
    fn step_simd<C: CursorIdx>(
        _level: SimdLevel,
        _xt: &[f32],
        _n: usize,
        _feat: &[i32],
        _thr: &[f32],
        _nodes: &[u32],
        _vector_gather: bool,
        _cur: &mut [C],
    ) -> bool {
        false
    }

    #[inline(always)]
    fn from_code(code: u32) -> f32 {
        code as f32
    }
}

impl SimdLane for u8 {
    #[inline(always)]
    fn step_simd<C: CursorIdx>(
        level: SimdLevel,
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        nodes: &[u32],
        vector_gather: bool,
        cur: &mut [C],
    ) -> bool {
        match C::as_u16_mut(cur) {
            Some(c16) => step_u8(level, xt, n, feat, thr, nodes, vector_gather, c16),
            None => false,
        }
    }

    #[inline(always)]
    fn from_code(code: u32) -> u8 {
        debug_assert!(code < u8::MAX as u32, "u8 lane overflow");
        code as u8
    }
}

impl SimdLane for u16 {
    #[inline(always)]
    fn step_simd<C: CursorIdx>(
        level: SimdLevel,
        xt: &[u16],
        n: usize,
        feat: &[i32],
        thr: &[u16],
        nodes: &[u32],
        vector_gather: bool,
        cur: &mut [C],
    ) -> bool {
        match C::as_u16_mut(cur) {
            Some(c16) => step_u16(level, xt, n, feat, thr, nodes, vector_gather, c16),
            None => false,
        }
    }

    #[inline(always)]
    fn from_code(code: u32) -> u16 {
        debug_assert!(code < u16::MAX as u32, "u16 lane overflow");
        code as u16
    }
}

/// Dispatch one u8-lane level step to the host kernel for `level`.
#[allow(clippy::too_many_arguments)]
fn step_u8(
    level: SimdLevel,
    xt: &[u8],
    n: usize,
    feat: &[i32],
    thr: &[u8],
    nodes: &[u32],
    vector_gather: bool,
    cur: &mut [u16],
) -> bool {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => {
            // SAFETY: SSE2 is baseline on x86_64. (No gather instruction
            // at this tier — the scalar gather stage is the kernel.)
            unsafe { x86::step_u8_sse2(xt, n, feat, thr, cur) };
            true
        }
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            if vector_gather && nodes.len() == thr.len() {
                // SAFETY: AVX2 probed (as below); the caller vouched for
                // the gather contract on `nodes`/`xt` (see `SimdLane`).
                unsafe { x86::step_u8_avx2_gather(xt, n, feat, thr, nodes, cur) };
            } else {
                // SAFETY: `level` only reaches Avx2 through `detect()`
                // or a `supported()`-clamped override, both of which
                // probed AVX2.
                unsafe { x86::step_u8_avx2(xt, n, feat, thr, cur) };
            }
            true
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            if vector_gather && !thr.is_empty() && thr.len() <= 16 {
                // SAFETY: NEON is baseline on aarch64; the ≤ 16-node
                // window fits one `tbl` table register.
                unsafe { neon::step_u8_neon_tbl(xt, n, feat, thr, cur) };
            } else {
                // SAFETY: NEON is baseline on aarch64.
                unsafe { neon::step_u8_neon(xt, n, feat, thr, cur) };
            }
            true
        }
        _ => {
            let _ = (xt, n, feat, thr, nodes, vector_gather, cur);
            false
        }
    }
}

/// Dispatch one u16-lane level step to the host kernel for `level`.
#[allow(clippy::too_many_arguments)]
fn step_u16(
    level: SimdLevel,
    xt: &[u16],
    n: usize,
    feat: &[i32],
    thr: &[u16],
    nodes: &[u32],
    vector_gather: bool,
    cur: &mut [u16],
) -> bool {
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => {
            // SAFETY: SSE2 is baseline on x86_64.
            unsafe { x86::step_u16_sse2(xt, n, feat, thr, cur) };
            true
        }
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            if vector_gather && nodes.len() == thr.len() {
                // SAFETY: AVX2 probed (as below); the caller vouched for
                // the gather contract on `nodes`/`xt` (see `SimdLane`).
                unsafe { x86::step_u16_avx2_gather(xt, n, feat, thr, nodes, cur) };
            } else {
                // SAFETY: `level` only reaches Avx2 through `detect()`
                // or a `supported()`-clamped override, both of which
                // probed AVX2.
                unsafe { x86::step_u16_avx2(xt, n, feat, thr, cur) };
            }
            true
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            // No u16 `tbl` variant: byte-pair index expansion costs more
            // than the scalar gather it would replace.
            let _ = (nodes, vector_gather);
            // SAFETY: NEON is baseline on aarch64.
            unsafe { neon::step_u16_neon(xt, n, feat, thr, cur) };
            true
        }
        _ => {
            let _ = (xt, n, feat, thr, nodes, vector_gather, cur);
            false
        }
    }
}

/// Lossy affine coding for one row-major sample row: `out[k]` gets
/// `lossy_affine(lo[k], hi[k], levels, row[k])` for every feature `k`.
/// AVX2 codes 8 features per instruction, NEON 4; every other level
/// (including SSE2 — no 8-wide divide worth the shuffle there) runs the
/// scalar body, and the vector paths are pinned byte-identical to it
/// (NaN→0, ±inf clamped, `hi <= lo` degenerate bucket, truncating
/// narrow — see the module tests).
pub(crate) fn code_lossy_row(
    level: SimdLevel,
    lo: &[f32],
    hi: &[f32],
    levels: f32,
    row: &[f32],
    out: &mut [u32],
) {
    debug_assert!(
        lo.len() >= row.len() && hi.len() >= row.len() && out.len() >= row.len(),
        "coding tables shorter than the feature row"
    );
    match level {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => {
            // SAFETY: `level` only reaches Avx2 through `detect()` or a
            // `supported()`-clamped override, both of which probed AVX2.
            unsafe { x86::code_lossy_row_avx2(lo, hi, levels, row, out) }
        }
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => {
            // SAFETY: NEON is baseline on aarch64.
            unsafe { neon::code_lossy_row_neon(lo, hi, levels, row, out) }
        }
        _ => {
            for (j, &v) in row.iter().enumerate() {
                out[j] = lossy_affine(lo[j], hi[j], levels, v) as u32;
            }
        }
    }
}

/// Scalar gather for one vector block starting at sample `s`: cursors
/// diverge per sample, so the per-sample threshold/feature code loads
/// stay scalar (bounds-checked) and feed the vector compare from small
/// stack arrays. Returns `(feature codes, threshold codes)`.
#[inline(always)]
fn gather<L: Copy + Default, const V: usize>(
    xt: &[L],
    n: usize,
    feat: &[i32],
    thr: &[L],
    cur: &[u16],
    s: usize,
) -> ([L; V], [L; V]) {
    let mut tf = [L::default(); V];
    let mut tt = [L::default(); V];
    for j in 0..V {
        let i = cur[s + j] as usize;
        tt[j] = thr[i];
        tf[j] = xt[feat[i] as usize * n + s + j];
    }
    (tf, tt)
}

/// Scalar remainder for the samples past the last full vector block —
/// the same body as the arena's scalar loop, so tails are
/// byte-identical to the reference lane.
#[inline(always)]
fn scalar_tail<L: Copy + PartialOrd>(
    xt: &[L],
    n: usize,
    feat: &[i32],
    thr: &[L],
    cur: &mut [u16],
    from: usize,
) {
    for (s, ci) in cur.iter_mut().enumerate().skip(from) {
        let i = *ci as usize;
        let go_right = xt[feat[i] as usize * n + s] > thr[i];
        *ci = (2 * i + go_right as usize) as u16;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! x86_64 kernels. x86 integer compares are signed, so unsigned
    //! rank codes are sign-biased (`x ^ MIN`) on both sides first; the
    //! dead-slot sentinel (`MAX`) biases to the largest signed value,
    //! so `>` stays false and dead lanes route left like the scalar
    //! loop. Advance uses `add(c, c)` for the doubling (no
    //! immediate-operand shift needed) and subtracts the compare mask.
    //!
    //! The `_gather` variants replace the scalar gather stage with
    //! `vpgatherdd`: one dword gather over the arena's packed
    //! `(feat << 16) | code` node records fetches both operands for 8
    //! samples, a second gathers the transposed feature codes at the
    //! computed `feat * n + s` offsets. They run the compare at i32
    //! width (zero-extended codes are non-negative, so signed `>` is
    //! unsigned `>` — no bias needed; the `MAX` sentinel is just the
    //! largest code) and pack the two 8-lane masks back to u16 lanes in
    //! sample order for the same subtract-mask advance.

    use super::{gather, lossy_affine, scalar_tail};
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must ensure SSE2 (baseline on x86_64).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn step_u8_sse2(
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        cur: &mut [u16],
    ) {
        const V: usize = 16;
        let len = cur.len();
        let bias = _mm_set1_epi8(i8::MIN);
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u8, V>(xt, n, feat, thr, cur, s);
            let a = _mm_xor_si128(_mm_loadu_si128(tf.as_ptr() as *const __m128i), bias);
            let b = _mm_xor_si128(_mm_loadu_si128(tt.as_ptr() as *const __m128i), bias);
            let gt = _mm_cmpgt_epi8(a, b);
            // Duplicating each mask byte widens it to a u16 lane of
            // 0x0000/0xFFFF, preserving sample order across halves.
            let m_lo = _mm_unpacklo_epi8(gt, gt);
            let m_hi = _mm_unpackhi_epi8(gt, gt);
            let p = cur.as_mut_ptr().add(s) as *mut __m128i;
            let c_lo = _mm_loadu_si128(p);
            let c_hi = _mm_loadu_si128(p.add(1));
            _mm_storeu_si128(p, _mm_sub_epi16(_mm_add_epi16(c_lo, c_lo), m_lo));
            _mm_storeu_si128(p.add(1), _mm_sub_epi16(_mm_add_epi16(c_hi, c_hi), m_hi));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure SSE2 (baseline on x86_64).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn step_u16_sse2(
        xt: &[u16],
        n: usize,
        feat: &[i32],
        thr: &[u16],
        cur: &mut [u16],
    ) {
        const V: usize = 8;
        let len = cur.len();
        let bias = _mm_set1_epi16(i16::MIN);
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u16, V>(xt, n, feat, thr, cur, s);
            let a = _mm_xor_si128(_mm_loadu_si128(tf.as_ptr() as *const __m128i), bias);
            let b = _mm_xor_si128(_mm_loadu_si128(tt.as_ptr() as *const __m128i), bias);
            let gt = _mm_cmpgt_epi16(a, b);
            let p = cur.as_mut_ptr().add(s) as *mut __m128i;
            let c = _mm_loadu_si128(p);
            _mm_storeu_si128(p, _mm_sub_epi16(_mm_add_epi16(c, c), gt));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure AVX2 (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn step_u8_avx2(
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        cur: &mut [u16],
    ) {
        const V: usize = 32;
        let len = cur.len();
        let bias = _mm256_set1_epi8(i8::MIN);
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u8, V>(xt, n, feat, thr, cur, s);
            let a = _mm256_xor_si256(_mm256_loadu_si256(tf.as_ptr() as *const __m256i), bias);
            let b = _mm256_xor_si256(_mm256_loadu_si256(tt.as_ptr() as *const __m256i), bias);
            let gt = _mm256_cmpgt_epi8(a, b);
            // Sign-extend each mask byte to a u16 lane in sample order
            // (256-bit unpack would interleave within 128-bit halves).
            let m_lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(gt));
            let m_hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(gt));
            let p = cur.as_mut_ptr().add(s) as *mut __m256i;
            let c_lo = _mm256_loadu_si256(p);
            let c_hi = _mm256_loadu_si256(p.add(1));
            _mm256_storeu_si256(p, _mm256_sub_epi16(_mm256_add_epi16(c_lo, c_lo), m_lo));
            _mm256_storeu_si256(p.add(1), _mm256_sub_epi16(_mm256_add_epi16(c_hi, c_hi), m_hi));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure AVX2 (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn step_u16_avx2(
        xt: &[u16],
        n: usize,
        feat: &[i32],
        thr: &[u16],
        cur: &mut [u16],
    ) {
        const V: usize = 16;
        let len = cur.len();
        let bias = _mm256_set1_epi16(i16::MIN);
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u16, V>(xt, n, feat, thr, cur, s);
            let a = _mm256_xor_si256(_mm256_loadu_si256(tf.as_ptr() as *const __m256i), bias);
            let b = _mm256_xor_si256(_mm256_loadu_si256(tt.as_ptr() as *const __m256i), bias);
            let gt = _mm256_cmpgt_epi16(a, b);
            let p = cur.as_mut_ptr().add(s) as *mut __m256i;
            let c = _mm256_loadu_si256(p);
            _mm256_storeu_si256(p, _mm256_sub_epi16(_mm256_add_epi16(c, c), gt));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// One full index-gathered block of 16 samples at i32 lane width:
    /// widen 16 u16 cursors, `vpgatherdd` the node records and feature
    /// codes, compare, and pack the masks back to u16 lanes in sample
    /// order. Shared by the u8/u16 gather kernels (`MASK` selects the
    /// code width, `SCALE` the xt element size).
    ///
    /// # Safety
    /// AVX2, plus the `SimdLane` gather contract: every record's
    /// `feat < n_features`, the xt buffer extends `GATHER_PAD` elements
    /// past `n_features * n`, and `n_features * n <= i32::MAX`.
    #[target_feature(enable = "avx2")]
    unsafe fn gather_block<const MASK: i32, const SCALE: i32>(
        xt_ptr: *const i32,
        n: usize,
        nodes: *const i32,
        cur: *mut u16,
        s: usize,
    ) {
        let code_mask = _mm256_set1_epi32(MASK);
        let iota = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
        let nv = _mm256_set1_epi32(n as i32);
        let p = cur.add(s) as *mut __m256i;
        let c = _mm256_loadu_si256(p);
        let idx_lo = _mm256_cvtepu16_epi32(_mm256_castsi256_si128(c));
        let idx_hi = _mm256_cvtepu16_epi32(_mm256_extracti128_si256::<1>(c));
        // One gather fetches both operands' halves: thr code in the low
        // 16 bits, feature id in the high 16.
        let rec_lo = _mm256_i32gather_epi32::<4>(nodes, idx_lo);
        let rec_hi = _mm256_i32gather_epi32::<4>(nodes, idx_hi);
        let col_lo = _mm256_add_epi32(_mm256_set1_epi32(s as i32), iota);
        let col_hi = _mm256_add_epi32(_mm256_set1_epi32((s + 8) as i32), iota);
        let row_lo = _mm256_mullo_epi32(_mm256_srli_epi32::<16>(rec_lo), nv);
        let row_hi = _mm256_mullo_epi32(_mm256_srli_epi32::<16>(rec_hi), nv);
        let addr_lo = _mm256_add_epi32(row_lo, col_lo);
        let addr_hi = _mm256_add_epi32(row_hi, col_hi);
        let x_lo = _mm256_and_si256(_mm256_i32gather_epi32::<SCALE>(xt_ptr, addr_lo), code_mask);
        let x_hi = _mm256_and_si256(_mm256_i32gather_epi32::<SCALE>(xt_ptr, addr_hi), code_mask);
        let t_lo = _mm256_and_si256(rec_lo, code_mask);
        let t_hi = _mm256_and_si256(rec_hi, code_mask);
        // Zero-extended codes are non-negative i32s: signed > is
        // unsigned >, and the MAX sentinel stays the largest code.
        let gt_lo = _mm256_cmpgt_epi32(x_lo, t_lo);
        let gt_hi = _mm256_cmpgt_epi32(x_hi, t_hi);
        // packs interleaves 128-bit lanes ([lo0..3, hi0..3 | lo4..7,
        // hi4..7]); permute the 64-bit quarters back to sample order.
        let mask = _mm256_permute4x64_epi64::<0b1101_1000>(_mm256_packs_epi32(gt_lo, gt_hi));
        _mm256_storeu_si256(p, _mm256_sub_epi16(_mm256_add_epi16(c, c), mask));
    }

    /// # Safety
    /// AVX2 plus the `SimdLane` gather contract (see [`gather_block`]).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn step_u8_avx2_gather(
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        nodes: &[u32],
        cur: &mut [u16],
    ) {
        const V: usize = 16;
        let len = cur.len();
        let mut s = 0;
        while s + V <= len {
            // SCALE = 1: u8 element offsets are byte offsets.
            gather_block::<0xFF, 1>(
                xt.as_ptr() as *const i32,
                n,
                nodes.as_ptr() as *const i32,
                cur.as_mut_ptr(),
                s,
            );
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// AVX2 plus the `SimdLane` gather contract (see [`gather_block`]).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn step_u16_avx2_gather(
        xt: &[u16],
        n: usize,
        feat: &[i32],
        thr: &[u16],
        nodes: &[u32],
        cur: &mut [u16],
    ) {
        const V: usize = 16;
        let len = cur.len();
        let mut s = 0;
        while s + V <= len {
            // SCALE = 2: element offsets over u16 storage.
            gather_block::<0xFFFF, 2>(
                xt.as_ptr() as *const i32,
                n,
                nodes.as_ptr() as *const i32,
                cur.as_mut_ptr(),
                s,
            );
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure AVX2; `lo`/`hi`/`out` must be at least
    /// `row.len()` long (debug-asserted by the safe dispatcher).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn code_lossy_row_avx2(
        lo: &[f32],
        hi: &[f32],
        levels: f32,
        row: &[f32],
        out: &mut [u32],
    ) {
        const V: usize = 8;
        let f = row.len();
        let zero = _mm256_setzero_ps();
        let one = _mm256_set1_ps(1.0);
        let lv = _mm256_set1_ps(levels);
        let onei = _mm256_set1_epi32(1);
        let mut k = 0;
        while k + V <= f {
            let l = _mm256_loadu_ps(lo.as_ptr().add(k));
            let h = _mm256_loadu_ps(hi.as_ptr().add(k));
            let x = _mm256_loadu_ps(row.as_ptr().add(k));
            let t = _mm256_div_ps(_mm256_sub_ps(x, l), _mm256_sub_ps(h, l));
            // max(t, 0) first: maxps yields its *second* operand on NaN,
            // so a NaN ratio collapses to 0 — the same code the scalar
            // `clamp → * levels → as` chain produces for NaN.
            let t = _mm256_min_ps(_mm256_max_ps(t, zero), one);
            let code = _mm256_cvttps_epi32(_mm256_mul_ps(t, lv));
            // Degenerate `hi <= lo` features take the scalar one-bucket
            // rule `(v > lo) as code` instead, per lane.
            let degen = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LE_OQ>(h, l));
            let above = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_GT_OQ>(x, l));
            let sel = _mm256_blendv_epi8(code, _mm256_and_si256(above, onei), degen);
            _mm256_storeu_si256(out.as_mut_ptr().add(k) as *mut __m256i, sel);
            k += V;
        }
        for j in k..f {
            out[j] = lossy_affine(lo[j], hi[j], levels, row[j]) as u32;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! aarch64 kernels. NEON compares unsigned natively (`vcgtq_u8` /
    //! `vcgtq_u16`), so no sign-bias is needed; byte masks are
    //! sign-extended to u16 lanes (`vmovl_s8` — the unsigned widen
    //! would zero-extend `0xFF` to `0x00FF` and break the
    //! subtract-mask advance).
    //!
    //! NEON has no index-gather instruction; the `_tbl` variant covers
    //! the threshold side of shallow levels instead: a ≤ 16-entry u8
    //! threshold window fits one `tbl` table register, so the per-sample
    //! `thr[cur]` loads become a single register lookup (the transposed
    //! feature loads stay scalar).

    use super::{gather, lossy_affine, scalar_tail};
    use std::arch::aarch64::*;

    /// # Safety
    /// Caller must ensure NEON (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn step_u8_neon(
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        cur: &mut [u16],
    ) {
        const V: usize = 16;
        let len = cur.len();
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u8, V>(xt, n, feat, thr, cur, s);
            let gt = vcgtq_u8(vld1q_u8(tf.as_ptr()), vld1q_u8(tt.as_ptr()));
            let gs = vreinterpretq_s8_u8(gt);
            let m_lo = vreinterpretq_u16_s16(vmovl_s8(vget_low_s8(gs)));
            let m_hi = vreinterpretq_u16_s16(vmovl_s8(vget_high_s8(gs)));
            let c_lo = vld1q_u16(cur.as_ptr().add(s));
            let c_hi = vld1q_u16(cur.as_ptr().add(s + 8));
            vst1q_u16(cur.as_mut_ptr().add(s), vsubq_u16(vaddq_u16(c_lo, c_lo), m_lo));
            vst1q_u16(cur.as_mut_ptr().add(s + 8), vsubq_u16(vaddq_u16(c_hi, c_hi), m_hi));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// Shallow-level variant: the whole ≤ 16-entry threshold window
    /// rides in one table register and `tbl` replaces the per-sample
    /// `thr[cur]` loads (cursors < 16 narrow losslessly to u8 indices).
    ///
    /// # Safety
    /// Caller must ensure NEON and `1 <= thr.len() <= 16`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn step_u8_neon_tbl(
        xt: &[u8],
        n: usize,
        feat: &[i32],
        thr: &[u8],
        cur: &mut [u16],
    ) {
        const V: usize = 16;
        let len = cur.len();
        let mut tab = [0u8; 16];
        tab[..thr.len()].copy_from_slice(thr);
        let table = vld1q_u8(tab.as_ptr());
        let mut s = 0;
        while s + V <= len {
            let c_lo = vld1q_u16(cur.as_ptr().add(s));
            let c_hi = vld1q_u16(cur.as_ptr().add(s + 8));
            let idx = vcombine_u8(vmovn_u16(c_lo), vmovn_u16(c_hi));
            let tt = vqtbl1q_u8(table, idx);
            let mut tf = [0u8; V];
            for (j, slot) in tf.iter_mut().enumerate() {
                let i = cur[s + j] as usize;
                *slot = xt[feat[i] as usize * n + s + j];
            }
            let gt = vcgtq_u8(vld1q_u8(tf.as_ptr()), tt);
            let gs = vreinterpretq_s8_u8(gt);
            let m_lo = vreinterpretq_u16_s16(vmovl_s8(vget_low_s8(gs)));
            let m_hi = vreinterpretq_u16_s16(vmovl_s8(vget_high_s8(gs)));
            vst1q_u16(cur.as_mut_ptr().add(s), vsubq_u16(vaddq_u16(c_lo, c_lo), m_lo));
            vst1q_u16(cur.as_mut_ptr().add(s + 8), vsubq_u16(vaddq_u16(c_hi, c_hi), m_hi));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure NEON (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn step_u16_neon(
        xt: &[u16],
        n: usize,
        feat: &[i32],
        thr: &[u16],
        cur: &mut [u16],
    ) {
        const V: usize = 8;
        let len = cur.len();
        let mut s = 0;
        while s + V <= len {
            let (tf, tt) = gather::<u16, V>(xt, n, feat, thr, cur, s);
            let gt = vcgtq_u16(vld1q_u16(tf.as_ptr()), vld1q_u16(tt.as_ptr()));
            let c = vld1q_u16(cur.as_ptr().add(s));
            vst1q_u16(cur.as_mut_ptr().add(s), vsubq_u16(vaddq_u16(c, c), gt));
            s += V;
        }
        scalar_tail(xt, n, feat, thr, cur, s);
    }

    /// # Safety
    /// Caller must ensure NEON; `lo`/`hi`/`out` must be at least
    /// `row.len()` long (debug-asserted by the safe dispatcher).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn code_lossy_row_neon(
        lo: &[f32],
        hi: &[f32],
        levels: f32,
        row: &[f32],
        out: &mut [u32],
    ) {
        const V: usize = 4;
        let f = row.len();
        let zero = vdupq_n_f32(0.0);
        let one = vdupq_n_f32(1.0);
        let lv = vdupq_n_f32(levels);
        let onei = vdupq_n_u32(1);
        let mut k = 0;
        while k + V <= f {
            let l = vld1q_f32(lo.as_ptr().add(k));
            let h = vld1q_f32(hi.as_ptr().add(k));
            let x = vld1q_f32(row.as_ptr().add(k));
            let t = vdivq_f32(vsubq_f32(x, l), vsubq_f32(h, l));
            // FMIN/FMAX propagate NaN; `fcvtzu` then maps NaN to 0 and
            // saturates — exactly the scalar `clamp → * levels → as`.
            let t = vminq_f32(vmaxq_f32(t, zero), one);
            let code = vcvtq_u32_f32(vmulq_f32(t, lv));
            let degen = vcleq_f32(h, l);
            let dcode = vandq_u32(vcgtq_f32(x, l), onei);
            vst1q_u32(out.as_mut_ptr().add(k), vbslq_u32(degen, dcode, code));
            k += V;
        }
        for j in k..f {
            out[j] = lossy_affine(lo[j], hi[j], levels, row[j]) as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(state: &mut u64) -> u64 {
        *state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        *state >> 33
    }

    /// Every vector level this host can actually run.
    fn vector_levels() -> Vec<SimdLevel> {
        [SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon]
            .into_iter()
            .filter(|l| l.supported())
            .collect()
    }

    /// One synthetic tree level: `w` nodes over `f` features, `n`
    /// samples, cursors spread across the nodes.
    fn level_case_u8(
        w: usize,
        f: usize,
        n: usize,
        seed: u64,
    ) -> (Vec<u8>, Vec<i32>, Vec<u8>, Vec<u16>) {
        let mut st = seed;
        let xt: Vec<u8> = (0..f * n).map(|_| lcg(&mut st) as u8).collect();
        let feat: Vec<i32> = (0..w).map(|_| (lcg(&mut st) as usize % f) as i32).collect();
        let thr: Vec<u8> = (0..w).map(|_| lcg(&mut st) as u8).collect();
        let cur: Vec<u16> = (0..n).map(|_| (lcg(&mut st) as usize % w) as u16).collect();
        (xt, feat, thr, cur)
    }

    /// u16-lane variant with codes past the u8 range (255-cut shape).
    fn level_case_u16(
        w: usize,
        f: usize,
        n: usize,
        seed: u64,
    ) -> (Vec<u16>, Vec<i32>, Vec<u16>, Vec<u16>) {
        let mut st = seed;
        let xt: Vec<u16> = (0..f * n).map(|_| (lcg(&mut st) % 1021) as u16).collect();
        let feat: Vec<i32> = (0..w).map(|_| (lcg(&mut st) as usize % f) as i32).collect();
        let thr: Vec<u16> = (0..w).map(|_| (lcg(&mut st) % 1021) as u16).collect();
        let cur: Vec<u16> = (0..n).map(|_| (lcg(&mut st) as usize % w) as u16).collect();
        (xt, feat, thr, cur)
    }

    /// Packed `(feat << 16) | code` gather records for a level window —
    /// the same layout `ForestArena` builds at pack time.
    fn nodes_of<L: crate::exec::quant::QuantizedLane>(feat: &[i32], thr: &[L]) -> Vec<u32> {
        feat.iter()
            .zip(thr)
            .map(|(&f, &c)| ((f as u32) << 16) | c.as_u32())
            .collect()
    }

    /// The scalar reference body (same as the arena's loop).
    fn step_ref<L: Copy + PartialOrd>(
        xt: &[L],
        n: usize,
        feat: &[i32],
        thr: &[L],
        cur: &mut [u16],
    ) {
        for (s, ci) in cur.iter_mut().enumerate() {
            let i = *ci as usize;
            let go_right = xt[feat[i] as usize * n + s] > thr[i];
            *ci = (2 * i + go_right as usize) as u16;
        }
    }

    const WIDTHS: [usize; 14] = [1, 3, 7, 8, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100];

    #[test]
    fn u8_kernels_match_scalar_at_every_width() {
        for level in vector_levels() {
            for &n in &WIDTHS {
                let (xt, feat, thr, cur0) = level_case_u8(16, 5, n, 0x5eed + n as u64);
                let mut want = cur0.clone();
                step_ref(&xt, n, &feat, &thr, &mut want);
                let mut got = cur0.clone();
                assert!(u8::step_simd(level, &xt, n, &feat, &thr, &[], false, &mut got));
                assert_eq!(got, want, "u8 {} n={n}", level.label());
            }
        }
    }

    #[test]
    fn u16_kernels_match_scalar_at_every_width() {
        for level in vector_levels() {
            for &n in &WIDTHS {
                let (xt, feat, thr, cur0) = level_case_u16(16, 5, n, 0xfeed + n as u64);
                let mut want = cur0.clone();
                step_ref(&xt, n, &feat, &thr, &mut want);
                let mut got = cur0.clone();
                assert!(u16::step_simd(level, &xt, n, &feat, &thr, &[], false, &mut got));
                assert_eq!(got, want, "u16 {} n={n}", level.label());
            }
        }
    }

    #[test]
    fn gather_kernels_match_scalar_at_every_width() {
        // Exhaustive width sweep 1..=100: every non-multiple-of-V tail
        // for both lane widths, with the index-gather stage requested.
        // The xt buffer carries the GATHER_PAD slack the vector gathers
        // require (as `BatchPlan`'s tile scratch does).
        for level in vector_levels() {
            for n in 1..=100usize {
                let (mut xt, feat, thr, cur0) = level_case_u8(16, 5, n, 0xa11 + n as u64);
                let nodes = nodes_of(&feat, &thr);
                let mut want = cur0.clone();
                step_ref(&xt, n, &feat, &thr, &mut want);
                xt.resize(xt.len() + GATHER_PAD, 0);
                let mut got = cur0.clone();
                assert!(u8::step_simd(level, &xt, n, &feat, &thr, &nodes, true, &mut got));
                assert_eq!(got, want, "u8 gather {} n={n}", level.label());

                let (mut xt, feat, thr, cur0) = level_case_u16(16, 5, n, 0xb22 + n as u64);
                let nodes = nodes_of(&feat, &thr);
                let mut want = cur0.clone();
                step_ref(&xt, n, &feat, &thr, &mut want);
                xt.resize(xt.len() + GATHER_PAD, 0);
                let mut got = cur0.clone();
                assert!(u16::step_simd(level, &xt, n, &feat, &thr, &nodes, true, &mut got));
                assert_eq!(got, want, "u16 gather {} n={n}", level.label());
            }
        }
    }

    #[test]
    fn gather_dead_slot_sentinels_route_left_at_block_boundaries() {
        // Dead-slot sentinel codes placed so sentinel-holding samples
        // land exactly on the gather blocks' lane boundaries (sample
        // positions 0, 7, 8, 15, 16, ... for the 8-lane gathers).
        for level in vector_levels() {
            let n = 41;
            let w = 8;
            let (mut xt, feat, _, _) = level_case_u8(w, 4, n, 7);
            let mut thr = vec![3u8; w];
            for dead in [0usize, 3, 7] {
                thr[dead] = u8::MAX;
            }
            // Cursor pattern pinning sentinels to boundary samples.
            let cur0: Vec<u16> =
                (0..n).map(|s| if s % 8 == 0 || s % 8 == 7 { 0 } else { (s % w) as u16 }).collect();
            let nodes = nodes_of(&feat, &thr);
            let mut want = cur0.clone();
            step_ref(&xt, n, &feat, &thr, &mut want);
            xt.resize(xt.len() + GATHER_PAD, 0);
            let mut got = cur0.clone();
            assert!(u8::step_simd(level, &xt, n, &feat, &thr, &nodes, true, &mut got));
            assert_eq!(got, want, "{} sentinel boundaries", level.label());
            for (s, &c) in got.iter().enumerate() {
                if thr[cur0[s] as usize] == u8::MAX {
                    assert_eq!(c, 2 * cur0[s], "{} dead slot s={s}", level.label());
                }
            }
        }
    }

    #[test]
    fn gather_request_without_tables_keeps_scalar_gather() {
        // An unpadded tile / missing node table must silently keep the
        // scalar gather stage (mismatched `nodes` length) and stay
        // byte-identical — this is the safety valve `traverse_tile_lanes`
        // relies on.
        for level in vector_levels() {
            let n = 50;
            let (xt, feat, thr, cur0) = level_case_u8(16, 5, n, 0xc0de);
            let mut want = cur0.clone();
            step_ref(&xt, n, &feat, &thr, &mut want);
            let mut got = cur0.clone();
            assert!(u8::step_simd(level, &xt, n, &feat, &thr, &[], true, &mut got));
            assert_eq!(got, want, "{} gather w/o tables", level.label());
        }
    }

    #[test]
    fn dead_slot_sentinels_route_left() {
        for level in vector_levels() {
            let n = 40;
            let (xt, feat, _, cur0) = level_case_u8(8, 4, n, 99);
            let thr = vec![u8::MAX; 8];
            let mut got = cur0.clone();
            assert!(u8::step_simd(level, &xt, n, &feat, &thr, &[], false, &mut got));
            for (s, &c) in got.iter().enumerate() {
                assert_eq!(c, 2 * cur0[s], "{} sentinel s={s}", level.label());
            }
            let (xt, feat, _, cur0) = level_case_u16(8, 4, n, 99);
            let thr = vec![u16::MAX; 8];
            let mut got = cur0.clone();
            assert!(u16::step_simd(level, &xt, n, &feat, &thr, &[], false, &mut got));
            for (s, &c) in got.iter().enumerate() {
                assert_eq!(c, 2 * cur0[s], "{} u16 sentinel s={s}", level.label());
            }
        }
    }

    #[test]
    fn boundary_equal_codes_route_left() {
        // `>` must stay strict in the vector form: equal code pairs
        // (the common case — rank codes collide exactly on cut values)
        // go left.
        for level in vector_levels() {
            let n = 33;
            let xt = vec![7u8; n];
            let feat = vec![0i32; 4];
            let thr = vec![7u8; 4];
            let mut cur: Vec<u16> = (0..n).map(|s| (s % 4) as u16).collect();
            let want: Vec<u16> = cur.iter().map(|&c| 2 * c).collect();
            assert!(u8::step_simd(level, &xt, n, &feat, &thr, &[], false, &mut cur));
            assert_eq!(cur, want, "{} equal codes", level.label());
            // Same strictness through the index-gather stage.
            let nodes = nodes_of(&feat, &thr);
            let mut xt = xt.clone();
            xt.resize(n + GATHER_PAD, 0);
            let mut cur: Vec<u16> = (0..n).map(|s| (s % 4) as u16).collect();
            assert!(u8::step_simd(level, &xt, n, &feat, &thr, &nodes, true, &mut cur));
            assert_eq!(cur, want, "{} equal codes (gather)", level.label());
        }
    }

    #[test]
    fn u32_cursors_and_f32_lanes_fall_back_to_scalar() {
        let n = 32;
        let (xt, feat, thr, cur0) = level_case_u8(8, 4, n, 7);
        let mut cur32: Vec<u32> = cur0.iter().map(|&c| c as u32).collect();
        for level in vector_levels() {
            assert!(!u8::step_simd(level, &xt, n, &feat, &thr, &[], false, &mut cur32));
        }
        let xf: Vec<f32> = xt.iter().map(|&v| v as f32).collect();
        let tf: Vec<f32> = thr.iter().map(|&v| v as f32).collect();
        let mut c16 = cur0.clone();
        assert!(!f32::step_simd(SimdLevel::detect(), &xf, n, &feat, &tf, &[], false, &mut c16));
        assert_eq!(c16, cur0, "fallback must not touch cursors");
    }

    #[test]
    fn scalar_level_is_never_vector_handled() {
        let n = 24;
        let (xt, feat, thr, cur0) = level_case_u8(8, 4, n, 3);
        let mut cur = cur0;
        assert!(!u8::step_simd(SimdLevel::Scalar, &xt, n, &feat, &thr, &[], false, &mut cur));
    }

    #[test]
    fn resolve_honors_force_scalar() {
        assert_eq!(SimdLevel::resolve(true, SimdLevel::Avx2), SimdLevel::Scalar);
        assert_eq!(SimdLevel::resolve(false, SimdLevel::Avx2), SimdLevel::Avx2);
        assert_eq!(SimdLevel::resolve(false, SimdLevel::Scalar), SimdLevel::Scalar);
    }

    #[test]
    fn gather_mode_resolve_honors_force_scalar_gather() {
        assert_eq!(GatherMode::resolve(true), GatherMode::Scalar);
        assert_eq!(GatherMode::resolve(false), GatherMode::Vector);
        assert_eq!(GatherMode::Scalar.label(), "scalar");
        assert_eq!(GatherMode::Vector.label(), "vector");
        // Cached: a second probe agrees.
        assert_eq!(GatherMode::detect(), GatherMode::detect());
    }

    #[test]
    fn detect_returns_a_supported_level() {
        assert!(SimdLevel::detect().supported());
        // Cached: a second call agrees.
        assert_eq!(SimdLevel::detect(), SimdLevel::detect());
    }

    #[test]
    fn rank_label_roundtrip() {
        for l in [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon] {
            assert_eq!(SimdLevel::label_of_rank(l.rank()), l.label());
        }
        assert_eq!(SimdLevel::label_of_rank(99), "scalar");
    }

    /// Feature-value edge cases the lossy coding chain must map exactly
    /// like the scalar body: non-finite, signed zero, denormal,
    /// out-of-range, and boundary values.
    const CODING_EDGE_VALUES: [f32; 12] = [
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        -0.0,
        0.0,
        1.0e-42, // denormal
        -3.0e38,
        3.0e38,
        -1.5,
        0.5,
        7.0,
        123456.0,
    ];

    #[test]
    fn lossy_coding_vector_matches_scalar() {
        // Rows mixing normal features, a degenerate `hi == lo` feature,
        // an inverted `hi < lo` pair and a huge range, against every
        // edge value, at several widths (vector blocks + scalar tails)
        // and bit depths — byte-identical to `lossy_affine` everywhere.
        let lo_pat = [0.0f32, -1.0, 5.0, 5.0, -3.0e38, 0.25, 2.0, -7.5];
        let hi_pat = [1.0f32, 2.0, 5.0, 4.0, 3.0e38, 0.75, 2.0 + 1.0e-6, 8.25];
        for f in [1usize, 4, 7, 8, 9, 16, 23, 64] {
            let lo: Vec<f32> = (0..f).map(|k| lo_pat[k % lo_pat.len()]).collect();
            let hi: Vec<f32> = (0..f).map(|k| hi_pat[k % hi_pat.len()]).collect();
            for bits in [1u8, 4, 8, 12, 16] {
                let levels = crate::exec::quant::lossy_levels(bits);
                for (vi, &v) in CODING_EDGE_VALUES.iter().enumerate() {
                    // Rotate the edge value across lanes so every lane
                    // position sees every edge case.
                    let row: Vec<f32> = (0..f)
                        .map(|k| {
                            if k % CODING_EDGE_VALUES.len() == vi {
                                v
                            } else {
                                CODING_EDGE_VALUES[k % CODING_EDGE_VALUES.len()]
                            }
                        })
                        .collect();
                    let want: Vec<u32> = (0..f)
                        .map(|k| lossy_affine(lo[k], hi[k], levels, row[k]) as u32)
                        .collect();
                    for level in vector_levels() {
                        let mut got = vec![u32::MAX; f];
                        code_lossy_row(level, &lo, &hi, levels, &row, &mut got);
                        assert_eq!(
                            got,
                            want,
                            "{} f={f} bits={bits} edge={v}",
                            level.label()
                        );
                    }
                    let mut got = vec![u32::MAX; f];
                    code_lossy_row(SimdLevel::Scalar, &lo, &hi, levels, &row, &mut got);
                    assert_eq!(got, want, "scalar f={f} bits={bits}");
                }
            }
        }
    }

    #[test]
    fn lossy_coding_agrees_with_quant_tables() {
        // `code_lossy_row` over the tables' own lo/hi must reproduce
        // `QuantTables::lossy_code` for arbitrary values.
        let t = crate::exec::quant::QuantTables::build(
            3,
            vec![(0, 2.5), (0, 1.0), (0, 7.0), (2, 4.0)].into_iter(),
        );
        let mut st = 0xdecaf_u64;
        let row: Vec<f32> = (0..3).map(|_| (lcg(&mut st) % 1000) as f32 / 37.0 - 9.0).collect();
        for bits in [8u8, 16] {
            let levels = crate::exec::quant::lossy_levels(bits);
            let mut got = vec![0u32; 3];
            code_lossy_row(SimdLevel::detect(), t.lo_table(), t.hi_table(), levels, &row, &mut got);
            for k in 0..3 {
                assert_eq!(got[k] as usize, t.lossy_code(k, row[k], bits), "k={k} bits={bits}");
            }
        }
    }
}
