//! [`Fleet`] — the multi-model serving tier: several registry models
//! behind one request path, with the paper's energy budget promoted to a
//! **live admission signal**.
//!
//! Paper anchor: Fig 5 plots accuracy against energy per classification
//! and frames FoG as the winning classifier *under a tight energy
//! budget*. The offline suite sweeps that budget as a plot axis; this
//! tier enforces it at serving time. A `Fleet` registers N models (e.g.
//! `fog_opt`, `fog_max`, `rf`) — each an independent
//! [`ShardedServer`](super::ShardedServer) slice of a shared replica
//! pool — and consults the **rolling** per-model
//! [`ExecReport`](crate::exec::ExecReport) aggregates (nanojoules per
//! classification from the `uarch` backend, per-batch p99) before every
//! batch: a model whose gauge exceeds its [`EnergyBudget`] is *over
//! budget*, and the [`FleetPolicy`] decides what happens to its traffic
//! — [`StrictShed`] rejects it, [`DowngradeFallback`] re-routes it to
//! the cheapest still-admissible model in registration order (the Fig 5
//! move: trade accuracy for energy, live). Every request resolves to an
//! explicit [`FleetOutcome`]:
//!
//! ```text
//! FleetRequest { model, features }
//!        │ admission: FleetPolicy × EnergyBudget
//!        │            (rolling energy/p99 gauges, updated per classify tick)
//!        ▼
//!     Fleet ──► entry m: ShardedServer ──► ShardRouter ──► Replica ──► Backend ──► Arena
//!        │
//!        └──► FleetOutcome::{ Served{model} | Downgraded{from,to} | Shed{requested} }
//! ```
//!
//! Determinism: gauges advance only inside [`Fleet::classify`] (one
//! *tick* per call), and the sharded tier is closed-loop — workers fold
//! their `ExecReport`s into replica metrics *before* responding, and
//! `classify` returns only after every response — so the gauge values a
//! tick observes are a pure function of the traffic served so far.
//! Replaying the same request sequence (e.g. from a seeded
//! [`loadgen`](super::loadgen) schedule) reproduces the same
//! `Served`/`Downgraded`/`Shed` counts.
//!
//! Conformance: a fleet with one model and an unlimited budget routes
//! every request straight through its single `ShardedServer`, so
//! probability rows and the deterministic metric totals are
//! byte-identical to serving that `ShardedServer` directly (pinned by
//! `rust/tests/fleet.rs`).

use super::cache::CacheConfig;
use super::messages::Response;
use super::metrics::{LatencySummary, Metrics, MetricsSnapshot};
use super::model_server::ModelServerConfig;
use super::router::RouterPolicy;
use super::shard::{ShardedServer, ShardedServerConfig};
use crate::api::spec::{FleetPolicyKind, ServingSpec};
use crate::api::Classifier;
use crate::util::error::Result;
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Live admission budget per registered model. `None` axes are
/// unlimited; a model is **over budget** as soon as any configured axis
/// is exhausted.
///
/// The energy axis compares against a *rolling* gauge (nJ per evaluated
/// classification over the last [`EnergyBudget::window_ticks`] classify
/// ticks) with `>=`, so a budget of `0.0` sheds every request even
/// before any energy is measured — the Fig-5 degenerate point where no
/// classification is affordable — while `f64::INFINITY` (or `None`)
/// never sheds. Window eviction lets a model recover once its expensive
/// traffic ages out, so budgets gate sustained cost, not one hot batch.
#[derive(Clone, Copy, Debug)]
pub struct EnergyBudget {
    /// Rolling energy per evaluated classification, nanojoules
    /// (`uarch`-backend fleets; the software backend reports no energy,
    /// so its gauge stays 0 and only a `0.0` budget ever trips).
    pub energy_per_class_nj: Option<f64>,
    /// Per-batch p99 latency bound, µs, over each entry's pooled replica
    /// reservoirs. Wall-clock — useful live, but not deterministic in
    /// tests the way the energy axis is.
    pub p99_us: Option<f64>,
    /// Classify ticks the rolling energy gauge averages over.
    pub window_ticks: usize,
}

impl Default for EnergyBudget {
    fn default() -> Self {
        EnergyBudget { energy_per_class_nj: None, p99_us: None, window_ticks: 32 }
    }
}

impl EnergyBudget {
    /// No limits on any axis: every request is admissible.
    pub fn unlimited() -> EnergyBudget {
        EnergyBudget::default()
    }

    /// Is a rolling energy gauge of `rolling_nj` over this budget?
    /// (`>=`, so a zero budget trips on the zero gauge.)
    pub fn energy_exhausted(&self, rolling_nj: f64) -> bool {
        matches!(self.energy_per_class_nj, Some(b) if rolling_nj >= b)
    }

    /// Is a live batch p99 of `p99_us` over this budget?
    pub fn latency_exhausted(&self, p99_us: f64) -> bool {
        matches!(self.p99_us, Some(b) if p99_us > b)
    }
}

/// What the fleet does with a request whose model is over budget.
/// Implementations are consulted once per request with the live
/// admissibility of every registered model.
pub trait FleetPolicy: Send + Sync {
    /// CLI / BENCH_JSON label.
    fn label(&self) -> &'static str;

    /// Pick the model that evaluates a request for `requested`, given
    /// `within_budget[m]` for every registered model, or `None` to shed.
    fn decide(&self, requested: usize, within_budget: &[bool]) -> Option<usize>;
}

/// Shed (reject) every request whose model is over budget; never
/// re-routes. The hard-realtime reading of the Fig 5 budget: an answer
/// from the wrong operating point is worse than no answer.
pub struct StrictShed;

impl FleetPolicy for StrictShed {
    fn label(&self) -> &'static str {
        "strict"
    }

    fn decide(&self, requested: usize, within_budget: &[bool]) -> Option<usize> {
        within_budget.get(requested).copied().unwrap_or(false).then_some(requested)
    }
}

/// Fall back in fleet registration order: an over-budget model's
/// traffic goes to the first *other* registered model still within
/// budget (register `fog_opt` before `fog_max` and exhausted `fog_max`
/// traffic downgrades onto the cheaper operating point — the live Fig 5
/// trade). Sheds only when every model is over budget.
pub struct DowngradeFallback;

impl FleetPolicy for DowngradeFallback {
    fn label(&self) -> &'static str {
        "downgrade"
    }

    fn decide(&self, requested: usize, within_budget: &[bool]) -> Option<usize> {
        if within_budget.get(requested).copied().unwrap_or(false) {
            return Some(requested);
        }
        (0..within_budget.len()).find(|&m| m != requested && within_budget[m])
    }
}

impl FleetPolicyKind {
    /// Materialize the policy object the fleet consults per request.
    pub fn build(self) -> Box<dyn FleetPolicy> {
        match self {
            FleetPolicyKind::Strict => Box::new(StrictShed),
            FleetPolicyKind::Downgrade => Box::new(DowngradeFallback),
        }
    }
}

/// One classification request addressed to a registered model (index in
/// fleet registration order).
#[derive(Clone, Debug)]
pub struct FleetRequest {
    pub model: usize,
    pub features: Vec<f32>,
}

impl FleetRequest {
    /// Expand a row-major `[n, n_features]` batch into per-row requests
    /// for one model; friendly error on a ragged buffer.
    pub fn batch(model: usize, x: &[f32], n_features: usize) -> Result<Vec<FleetRequest>> {
        let n = super::model_server::check_aligned(x.len(), n_features)?;
        Ok((0..n)
            .map(|i| FleetRequest {
                model,
                features: x[i * n_features..(i + 1) * n_features].to_vec(),
            })
            .collect())
    }
}

/// The admission decision a request resolved to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetOutcome {
    /// Evaluated by the model it asked for.
    Served { model: usize },
    /// Evaluated by a fallback model after `from` exhausted its budget.
    Downgraded { from: usize, to: usize },
    /// Rejected: every admissible model was over budget.
    Shed { requested: usize },
}

impl FleetOutcome {
    /// BENCH_JSON / log label.
    pub fn label(&self) -> &'static str {
        match self {
            FleetOutcome::Served { .. } => "served",
            FleetOutcome::Downgraded { .. } => "downgraded",
            FleetOutcome::Shed { .. } => "shed",
        }
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, FleetOutcome::Shed { .. })
    }
}

/// One request's result: the fleet-level id (input order), the admission
/// outcome, and the evaluated response (`None` when shed).
#[derive(Clone, Debug)]
pub struct FleetResponse {
    pub id: u64,
    pub outcome: FleetOutcome,
    pub response: Option<Response>,
}

/// Configuration for a multi-model fleet.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Total replica capacity shared across the registered models
    /// (partitioned evenly, earlier registrations get the remainder,
    /// every model keeps at least one replica).
    pub total_replicas: usize,
    /// Per-replica queue/batch/worker/backend settings (shared by every
    /// entry; the `uarch` backend is what makes the energy gauges live).
    pub worker: ModelServerConfig,
    /// Replica-selection policy inside each entry.
    pub router: RouterPolicy,
    /// Seed for entry 0's router stream (entry m uses `seed + m`, so a
    /// single-model fleet matches a plain `ShardedServer` bit-for-bit).
    pub router_seed: u64,
    /// Per-entry result cache; `None` serves every request cold.
    pub cache: Option<CacheConfig>,
    /// Admission budget applied to every registered model.
    pub budget: EnergyBudget,
    /// What happens to traffic for an over-budget model.
    pub policy: FleetPolicyKind,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            total_replicas: 2,
            worker: ModelServerConfig::default(),
            router: RouterPolicy::LeastLoaded,
            router_seed: 0,
            cache: None,
            budget: EnergyBudget::default(),
            policy: FleetPolicyKind::default(),
        }
    }
}

impl FleetConfig {
    /// Build from the serving knobs a [`ServingSpec`] carries
    /// (`replicas` is read as the fleet-wide total).
    pub fn for_serving(s: &ServingSpec) -> FleetConfig {
        let shard = ShardedServerConfig::for_serving(s);
        FleetConfig {
            total_replicas: shard.replicas,
            worker: shard.worker,
            router: shard.router,
            router_seed: shard.router_seed,
            cache: shard.cache,
            budget: EnergyBudget {
                energy_per_class_nj: s.energy_budget_nj,
                ..EnergyBudget::default()
            },
            policy: s.fleet_policy,
        }
    }
}

/// Split `total` replicas across `n` models: evenly, remainder to the
/// earliest registrations, floor of one replica per model (capacity is
/// shared, but no registered model is ever starved outright).
pub(crate) fn partition_replicas(total: usize, n: usize) -> Vec<usize> {
    assert!(n > 0, "partition_replicas over zero models");
    let base = total / n;
    let rem = total % n;
    (0..n).map(|m| (base + usize::from(m < rem)).max(1)).collect()
}

/// Rolling budget gauges for one fleet entry, advanced once per
/// [`Fleet::classify`] tick.
#[derive(Debug, Default)]
struct ModelGauges {
    /// Entry snapshot at the last tick (deltas feed the window).
    last: MetricsSnapshot,
    /// Per-tick `(evaluated samples, energy fJ)` deltas, newest last.
    window: VecDeque<(u64, u64)>,
    /// Pooled-replica batch p99 at the last tick (µs); only refreshed
    /// when the budget has a latency axis.
    p99_live: f64,
}

/// Rolling nJ per evaluated classification over the gauge window (0.0
/// until the window has seen an evaluated sample).
fn rolling_energy_per_class_nj(g: &ModelGauges) -> f64 {
    let (samples, fj) = g
        .window
        .iter()
        .fold((0u64, 0u64), |(s, e), &(ds, de)| (s.saturating_add(ds), e.saturating_add(de)));
    if samples == 0 {
        0.0
    } else {
        fj as f64 * 1e-6 / samples as f64
    }
}

fn over_budget(budget: &EnergyBudget, g: &ModelGauges) -> bool {
    budget.energy_exhausted(rolling_energy_per_class_nj(g))
        || budget.latency_exhausted(g.p99_live)
}

struct FleetEntry {
    name: String,
    server: ShardedServer,
    gauges: ModelGauges,
}

/// A running multi-model fleet: per-model [`ShardedServer`] entries
/// behind one admission front end. See the module docs for the request
/// path and determinism contract.
pub struct Fleet {
    entries: Vec<FleetEntry>,
    policy: Box<dyn FleetPolicy>,
    budget: EnergyBudget,
    /// Fleet-front counters: `requests` plus the
    /// `fleet_served`/`fleet_downgraded`/`fleet_shed` outcomes (entry
    /// servers keep their own front/replica metrics one tier down).
    front: Metrics,
    n_features: usize,
    next_id: u64,
    /// Per-model outcome counters (`classify` holds `&mut self`, so
    /// plain integers suffice): requests addressed to m / served by the
    /// model they asked for / shed.
    requested: Vec<u64>,
    served: Vec<u64>,
    shed: Vec<u64>,
    /// Flat `[from * n + to]` downgrade matrix.
    downgrades: Vec<u64>,
}

impl Fleet {
    /// Spin up one `ShardedServer` entry per `(name, model)` over a
    /// shared replica pool of `cfg.total_replicas`. Friendly errors on
    /// an empty registration list or models with mismatched feature
    /// counts (one fleet serves one feature space; requests re-route
    /// across models under `Downgrade`, so rows must fit every entry).
    pub fn start(
        models: Vec<(String, Arc<dyn Classifier>)>,
        cfg: &FleetConfig,
    ) -> Result<Fleet> {
        crate::ensure!(!models.is_empty(), "fleet needs at least one registered model");
        let n_features = models[0].1.n_features();
        for (name, model) in &models {
            crate::ensure!(
                model.n_features() == n_features,
                "fleet models disagree on feature count: '{}' expects {} features, \
                 '{}' expects {}",
                models[0].0,
                n_features,
                name,
                model.n_features()
            );
        }
        let n = models.len();
        let replicas = partition_replicas(cfg.total_replicas, n);
        let entries = models
            .into_iter()
            .zip(&replicas)
            .enumerate()
            .map(|(m, ((name, model), &r))| {
                let shard_cfg = ShardedServerConfig {
                    replicas: r,
                    worker: cfg.worker.clone(),
                    router: cfg.router,
                    router_seed: cfg.router_seed.wrapping_add(m as u64),
                    cache: cfg.cache.clone(),
                };
                FleetEntry {
                    name,
                    server: ShardedServer::start(model, &shard_cfg),
                    gauges: ModelGauges::default(),
                }
            })
            .collect();
        Ok(Fleet {
            entries,
            policy: cfg.policy.build(),
            budget: cfg.budget,
            front: Metrics::default(),
            n_features,
            next_id: 0,
            requested: vec![0; n],
            served: vec![0; n],
            shed: vec![0; n],
            downgrades: vec![0; n * n],
        })
    }

    pub fn n_models(&self) -> usize {
        self.entries.len()
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Registration-order name of model `m`.
    pub fn model_name(&self, m: usize) -> &str {
        &self.entries[m].name
    }

    /// Look a registered model up by name.
    pub fn resolve(&self, name: &str) -> Option<usize> {
        self.entries.iter().position(|e| e.name == name)
    }

    /// The admission budget every model is held to.
    pub fn budget(&self) -> &EnergyBudget {
        &self.budget
    }

    /// The admission policy's CLI label.
    pub fn policy_label(&self) -> &'static str {
        self.policy.label()
    }

    /// Entry `m`'s sharded server (replica counts, router, cache).
    pub fn server(&self, m: usize) -> &ShardedServer {
        &self.entries[m].server
    }

    /// Fleet-front counters (requests + admission outcomes).
    pub fn metrics(&self) -> &Metrics {
        &self.front
    }

    /// Advance the rolling gauges one tick: fold each entry's snapshot
    /// delta into its window and refresh the latency gauge when the
    /// budget watches it.
    fn tick(&mut self) {
        let window_ticks = self.budget.window_ticks.max(1);
        let watch_p99 = self.budget.p99_us.is_some();
        for e in &mut self.entries {
            let snap = e.server.snapshot();
            let ds = snap.exec_samples.saturating_sub(e.gauges.last.exec_samples);
            let de = snap.exec_energy_fj.saturating_sub(e.gauges.last.exec_energy_fj);
            e.gauges.last = snap;
            e.gauges.window.push_back((ds, de));
            while e.gauges.window.len() > window_ticks {
                e.gauges.window.pop_front();
            }
            if watch_p99 {
                let samples: Vec<f64> = (0..e.server.n_replicas())
                    .flat_map(|r| e.server.replica_metrics(r).batch_latency_samples_us())
                    .collect();
                e.gauges.p99_live = LatencySummary::from_us(samples).p99_us;
            }
        }
    }

    /// Admit, route and evaluate a request batch; returns one
    /// [`FleetResponse`] per request, in input order. Gauges tick once
    /// at the start of the call, so every request in the batch sees the
    /// same admission state (and replays deterministically — see the
    /// module docs).
    pub fn classify(&mut self, requests: &[FleetRequest]) -> Result<Vec<FleetResponse>> {
        let n_models = self.entries.len();
        for (i, req) in requests.iter().enumerate() {
            crate::ensure!(
                req.model < n_models,
                "request {i}: model index {} out of range (fleet registers {} models)",
                req.model,
                n_models
            );
            crate::ensure!(
                req.features.len() == self.n_features,
                "request {i}: {} features, fleet models expect {}",
                req.features.len(),
                self.n_features
            );
        }
        self.tick();
        let within: Vec<bool> =
            self.entries.iter().map(|e| !over_budget(&self.budget, &e.gauges)).collect();

        let base_id = self.next_id;
        self.next_id += requests.len() as u64;
        // Decide every request against this tick's gauges, grouping the
        // admitted rows into one batch per target model.
        let mut decisions: Vec<Option<usize>> = Vec::with_capacity(requests.len());
        let mut rows: Vec<Vec<f32>> = vec![Vec::new(); n_models];
        let mut origins: Vec<Vec<usize>> = vec![Vec::new(); n_models];
        for (i, req) in requests.iter().enumerate() {
            self.front.requests.fetch_add(1, Ordering::Relaxed);
            self.requested[req.model] += 1;
            let target = self.policy.decide(req.model, &within);
            match target {
                Some(t) => {
                    rows[t].extend_from_slice(&req.features);
                    origins[t].push(i);
                }
                None => {
                    self.front.fleet_shed.fetch_add(1, Ordering::Relaxed);
                    self.shed[req.model] += 1;
                }
            }
            decisions.push(target);
        }

        let mut out: Vec<Option<FleetResponse>> = requests.iter().map(|_| None).collect();
        for m in 0..n_models {
            if origins[m].is_empty() {
                continue;
            }
            let responses = self.entries[m].server.classify(&rows[m])?;
            for (mut resp, &i) in responses.into_iter().zip(&origins[m]) {
                let requested = requests[i].model;
                let outcome = if requested == m {
                    self.front.fleet_served.fetch_add(1, Ordering::Relaxed);
                    self.served[requested] += 1;
                    FleetOutcome::Served { model: m }
                } else {
                    self.front.fleet_downgraded.fetch_add(1, Ordering::Relaxed);
                    self.downgrades[requested * n_models + m] += 1;
                    FleetOutcome::Downgraded { from: requested, to: m }
                };
                let id = base_id + i as u64;
                resp.id = id;
                out[i] = Some(FleetResponse { id, outcome, response: Some(resp) });
            }
        }
        for (i, decision) in decisions.iter().enumerate() {
            if decision.is_none() {
                out[i] = Some(FleetResponse {
                    id: base_id + i as u64,
                    outcome: FleetOutcome::Shed { requested: requests[i].model },
                    response: None,
                });
            }
        }
        Ok(out.into_iter().map(|r| r.expect("every request resolved")).collect())
    }

    /// One structured snapshot: the merged fleet totals plus per-model
    /// keyed aggregates, so energy numbers from different arenas never
    /// blend (a `fog_max` entry's nJ/class stays its own — the satellite
    /// regression `tests/fleet.rs` pins).
    pub fn snapshot(&self) -> FleetSnapshot {
        let n = self.entries.len();
        let mut total = self.front.snapshot();
        let mut per_model = Vec::with_capacity(n);
        for (m, e) in self.entries.iter().enumerate() {
            let snap = e.server.snapshot();
            total.merge_worker(&snap);
            // `merge_worker` deliberately skips front-end-owned
            // counters; the entry's cache counters are front-end state
            // one tier down, so fold them into the fleet total here.
            total.cache_hits = total.cache_hits.saturating_add(snap.cache_hits);
            total.cache_misses = total.cache_misses.saturating_add(snap.cache_misses);
            let samples: Vec<f64> = (0..e.server.n_replicas())
                .flat_map(|r| e.server.replica_metrics(r).batch_latency_samples_us())
                .collect();
            per_model.push(FleetModelStats {
                name: e.name.clone(),
                requested: self.requested[m],
                served: self.served[m],
                shed: self.shed[m],
                downgraded_away: (0..n).map(|to| self.downgrades[m * n + to]).sum(),
                downgraded_into: (0..n).map(|from| self.downgrades[from * n + m]).sum(),
                rolling_energy_per_class_nj: rolling_energy_per_class_nj(&e.gauges),
                batch_latency: LatencySummary::from_us(samples),
                snapshot: snap,
            });
        }
        let mut downgrades = Vec::new();
        for from in 0..n {
            for to in 0..n {
                let count = self.downgrades[from * n + to];
                if count > 0 {
                    downgrades.push(((from, to), count));
                }
            }
        }
        FleetSnapshot { total, per_model, downgrades }
    }

    /// Drop every entry's queues and join their workers.
    pub fn shutdown(self) {
        for e in self.entries {
            e.server.shutdown();
        }
    }
}

/// Per-model aggregates of one fleet snapshot, keyed by registration
/// order. `requested == served + downgraded_away + shed` for every
/// model — each addressed request resolves exactly once.
#[derive(Clone, Debug)]
pub struct FleetModelStats {
    pub name: String,
    /// Requests addressed to this model.
    pub requested: u64,
    /// ... evaluated by it (asked and answered).
    pub served: u64,
    /// ... rejected outright.
    pub shed: u64,
    /// ... re-routed to a fallback model.
    pub downgraded_away: u64,
    /// Requests this model absorbed from over-budget peers.
    pub downgraded_into: u64,
    /// This entry's own merged counters (per-model energy/cycles stay
    /// keyed here; use `snapshot.energy_per_class_nj()` etc.).
    pub snapshot: MetricsSnapshot,
    /// Pooled-replica per-batch latency percentiles.
    pub batch_latency: LatencySummary,
    /// The admission gauge as of the last classify tick.
    pub rolling_energy_per_class_nj: f64,
}

/// Point-in-time fleet state: merged totals, per-model keyed stats, and
/// the sparse `(from, to) -> count` downgrade matrix.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    pub total: MetricsSnapshot,
    pub per_model: Vec<FleetModelStats>,
    pub downgrades: Vec<((usize, usize), u64)>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Estimator, ModelSpec};
    use crate::data::synthetic::{generate, DatasetProfile};

    #[test]
    fn partition_shares_capacity_with_floor() {
        assert_eq!(partition_replicas(6, 2), vec![3, 3]);
        assert_eq!(partition_replicas(5, 2), vec![3, 2]);
        assert_eq!(partition_replicas(4, 3), vec![2, 1, 1]);
        // Floor: over-subscribed fleets still give every model a replica.
        assert_eq!(partition_replicas(1, 3), vec![1, 1, 1]);
        assert_eq!(partition_replicas(0, 2), vec![1, 1]);
    }

    #[test]
    fn budget_axes() {
        let unlimited = EnergyBudget::unlimited();
        assert!(!unlimited.energy_exhausted(1e12));
        assert!(!unlimited.latency_exhausted(1e12));
        let zero = EnergyBudget { energy_per_class_nj: Some(0.0), ..Default::default() };
        assert!(zero.energy_exhausted(0.0), "budget 0 must trip on the zero gauge");
        let b = EnergyBudget { energy_per_class_nj: Some(5.0), ..Default::default() };
        assert!(!b.energy_exhausted(4.9));
        assert!(b.energy_exhausted(5.0));
        let inf =
            EnergyBudget { energy_per_class_nj: Some(f64::INFINITY), ..Default::default() };
        assert!(!inf.energy_exhausted(1e300));
        let p = EnergyBudget { p99_us: Some(100.0), ..Default::default() };
        assert!(!p.latency_exhausted(100.0));
        assert!(p.latency_exhausted(100.5));
    }

    #[test]
    fn strict_policy_never_reroutes() {
        let p = StrictShed;
        assert_eq!(p.decide(0, &[true, true]), Some(0));
        assert_eq!(p.decide(0, &[false, true]), None);
        assert_eq!(p.decide(1, &[true, false]), None);
        assert_eq!(p.decide(2, &[true, true]), None, "out-of-range request sheds");
    }

    #[test]
    fn downgrade_policy_falls_back_in_registration_order() {
        let p = DowngradeFallback;
        assert_eq!(p.decide(1, &[true, true, true]), Some(1), "within budget: no move");
        assert_eq!(p.decide(1, &[true, false, true]), Some(0), "earliest admissible wins");
        assert_eq!(p.decide(0, &[false, false, true]), Some(2));
        assert_eq!(p.decide(0, &[false, false, false]), None, "all exhausted: shed");
        assert_eq!(
            p.decide(2, &[true, true]),
            Some(0),
            "unknown requested index still lands on an admissible model"
        );
    }

    #[test]
    fn policy_kind_builds_matching_object() {
        assert_eq!(FleetPolicyKind::Strict.build().label(), "strict");
        assert_eq!(FleetPolicyKind::Downgrade.build().label(), "downgrade");
    }

    #[test]
    fn rolling_gauge_averages_window() {
        let mut g = ModelGauges::default();
        assert_eq!(rolling_energy_per_class_nj(&g), 0.0);
        g.window.push_back((4, 2_000_000)); // 4 samples, 2e6 fJ = 2 nJ
        g.window.push_back((0, 0)); // an idle tick dilutes nothing
        assert!((rolling_energy_per_class_nj(&g) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fleet_serves_and_budget_zero_sheds() {
        let ds = generate(&DatasetProfile::demo(), 711);
        let spec = ModelSpec::for_shape("rf", ds.n_features(), ds.n_classes())
            .unwrap()
            .fast();
        let model: Arc<dyn Classifier> = Arc::from(spec.fit(&ds.train, 11));

        // Unlimited budget: every request Served by its model.
        let mut fleet = Fleet::start(
            vec![("rf".to_string(), Arc::clone(&model))],
            &FleetConfig::default(),
        )
        .expect("fleet start");
        let reqs =
            FleetRequest::batch(0, &ds.test.x, ds.n_features()).expect("aligned batch");
        let responses = fleet.classify(&reqs).expect("classify");
        assert_eq!(responses.len(), ds.test.len());
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.outcome, FleetOutcome::Served { model: 0 });
            assert!(r.response.is_some());
        }
        let snap = fleet.snapshot();
        assert_eq!(snap.total.fleet_served as usize, ds.test.len());
        assert_eq!(snap.total.fleet_shed, 0);
        assert_eq!(snap.total.requests as usize, ds.test.len());
        assert_eq!(snap.total.responses as usize, ds.test.len());
        let m0 = &snap.per_model[0];
        assert_eq!(m0.requested, m0.served + m0.downgraded_away + m0.shed);
        assert_eq!(m0.served as usize, ds.test.len());
        fleet.shutdown();

        // Budget 0 under Strict: everything sheds, nothing evaluates.
        let cfg = FleetConfig {
            budget: EnergyBudget {
                energy_per_class_nj: Some(0.0),
                ..Default::default()
            },
            policy: FleetPolicyKind::Strict,
            ..Default::default()
        };
        let mut starved = Fleet::start(vec![("rf".to_string(), model)], &cfg).unwrap();
        let responses = starved.classify(&reqs).expect("classify");
        assert!(responses.iter().all(|r| r.outcome.is_shed() && r.response.is_none()));
        let snap = starved.snapshot();
        assert_eq!(snap.total.fleet_shed as usize, ds.test.len());
        assert_eq!(snap.total.responses, 0, "shed requests must not be evaluated");
        assert!((snap.total.shed_rate() - 1.0).abs() < 1e-12);
        starved.shutdown();
    }

    #[test]
    fn mismatched_feature_counts_are_a_friendly_error() {
        let ds = generate(&DatasetProfile::demo(), 712);
        let spec = ModelSpec::for_shape("svm_lr", ds.n_features(), ds.n_classes())
            .unwrap()
            .fast();
        let a: Arc<dyn Classifier> = Arc::from(spec.fit(&ds.train, 1));
        let wider =
            DatasetProfile { n_features: ds.n_features() + 1, ..DatasetProfile::demo() };
        let ds2 = generate(&wider, 713);
        let spec2 = ModelSpec::for_shape("svm_lr", ds2.n_features(), ds2.n_classes())
            .unwrap()
            .fast();
        let b: Arc<dyn Classifier> = Arc::from(spec2.fit(&ds2.train, 2));
        let err = Fleet::start(
            vec![("a".to_string(), a), ("b".to_string(), b)],
            &FleetConfig::default(),
        )
        .expect_err("mismatched feature counts must not start");
        assert!(err.to_string().contains("feature count"), "unhelpful error: {err}");
    }
}
