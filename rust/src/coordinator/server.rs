//! The FoG server: spins up the grove-worker ring, routes requests to
//! random starting groves, collects responses, and enforces an in-flight
//! cap (the injection-side backpressure that keeps the ring
//! deadlock-free — ring-internal channels are unbounded, so forwarding
//! never blocks; total memory is bounded by the cap).

use super::accel;
use super::messages::{Msg, Request, Response, WorkItem};
use super::metrics::{LatencySummary, Metrics};
use super::worker::{run_worker, AccelGrove, GroveBackend, NativeGrove, WorkerConfig};
use crate::fog::FieldOfGroves;
use crate::util::error::Result;
use crate::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Evaluation backend selection.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Pure-rust tree walks inside each worker.
    Native,
    /// AOT-compiled PJRT executables behind the accelerator thread.
    Pjrt { artifacts_dir: PathBuf },
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub threshold: f32,
    pub max_hops: usize,
    pub batch_size: usize,
    pub batch_timeout: Duration,
    /// Max requests in flight before `classify` waits for completions.
    pub max_in_flight: usize,
    pub seed: u64,
    pub backend: Backend,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            threshold: 0.3,
            max_hops: usize::MAX,
            batch_size: 16,
            batch_timeout: Duration::from_micros(200),
            max_in_flight: 256,
            seed: 0,
            backend: Backend::Native,
        }
    }
}

/// A running FoG classification service.
pub struct FogServer {
    grove_txs: Vec<Sender<Msg>>,
    resp_rx: Receiver<Response>,
    metrics: Arc<Metrics>,
    n_groves: usize,
    n_classes: usize,
    n_features: usize,
    seed: u64,
    max_in_flight: usize,
    next_id: u64,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl FogServer {
    /// Start workers for every grove of `fog`.
    pub fn start(fog: &FieldOfGroves, cfg: &ServerConfig) -> Result<FogServer> {
        let n = fog.n_groves();
        crate::ensure!(n > 0, "empty fog");
        let metrics = Arc::new(Metrics::default());
        let (resp_tx, resp_rx) = channel::<Response>();

        let accel_handle = match &cfg.backend {
            Backend::Native => None,
            Backend::Pjrt { artifacts_dir } => {
                Some(accel::spawn(fog, artifacts_dir.clone())?)
            }
        };

        // Ring channels.
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<Msg>();
            txs.push(tx);
            rxs.push(Some(rx));
        }
        let wcfg = WorkerConfig {
            threshold: cfg.threshold,
            max_hops: cfg.max_hops.clamp(1, n),
            batch_size: cfg.batch_size.max(1),
            batch_timeout: cfg.batch_timeout,
        };
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = rxs[i].take().unwrap();
            let next = txs[(i + 1) % n].clone();
            let responses = resp_tx.clone();
            let m = Arc::clone(&metrics);
            let grove = fog.groves[i].clone();
            let backend: Box<dyn GroveBackend> = match &accel_handle {
                None => Box::new(NativeGrove(grove)),
                Some(h) => Box::new(AccelGrove {
                    handle: h.clone(),
                    grove,
                    grove_idx: i,
                }),
            };
            let wc = wcfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("fog-grove-{i}"))
                    .spawn(move || run_worker(backend, rx, next, responses, m, wc))
                    .expect("spawn worker"),
            );
        }
        Ok(FogServer {
            grove_txs: txs,
            resp_rx,
            metrics,
            n_groves: n,
            n_classes: fog.n_classes,
            n_features: fog.n_features,
            seed: cfg.seed,
            max_in_flight: cfg.max_in_flight.max(1),
            next_id: 0,
            workers,
        })
    }

    /// Classify a row-major batch; returns responses sorted by input
    /// order. Blocks until every input is answered.
    pub fn classify(&mut self, x: &[f32]) -> Vec<Response> {
        let f = self.n_features;
        assert_eq!(x.len() % f, 0, "ragged batch");
        let n = x.len() / f;
        let base_id = self.next_id;
        self.next_id += n as u64;

        let mut responses: Vec<Option<Response>> = vec![None; n];
        let mut injected = 0usize;
        let mut completed = 0usize;
        while completed < n {
            // Inject while under the in-flight cap.
            while injected < n && injected - completed < self.max_in_flight {
                let id = base_id + injected as u64;
                // Same per-input stream as Algorithm 2 / the μarch sim.
                let mut rng =
                    Rng::new(self.seed ^ (injected as u64).wrapping_mul(0x9E3779B97F4A7C15));
                let start = rng.gen_range(self.n_groves);
                let req = Request {
                    id,
                    features: x[injected * f..(injected + 1) * f].to_vec(),
                };
                self.metrics.requests.fetch_add(1, Ordering::Relaxed);
                let item = WorkItem::fresh(req, self.n_classes);
                self.grove_txs[start].send(Msg::Work(item)).expect("ring alive");
                injected += 1;
            }
            // Collect one response.
            let resp = self.resp_rx.recv().expect("workers alive");
            let idx = (resp.id - base_id) as usize;
            responses[idx] = Some(resp);
            completed += 1;
        }
        responses.into_iter().map(|r| r.unwrap()).collect()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Latency summary over a slice of responses.
    pub fn latency_summary(responses: &[Response]) -> LatencySummary {
        LatencySummary::from_us(responses.iter().map(|r| r.latency_us as f64).collect())
    }

    /// Tear down the ring: broadcast the shutdown sentinel (ring workers
    /// hold senders to each other, so plain channel disconnection never
    /// happens), then join.
    pub fn shutdown(self) {
        for tx in &self.grove_txs {
            let _ = tx.send(Msg::Shutdown);
        }
        drop(self.grove_txs);
        drop(self.resp_rx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::fog::FogParams;
    use crate::forest::{ForestParams, RandomForest};

    fn setup() -> (FieldOfGroves, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 201);
        let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 1);
        (FieldOfGroves::from_forest(&rf, 4), ds)
    }

    #[test]
    fn serving_matches_algorithm2() {
        let (fog, ds) = setup();
        let threshold = 0.35;
        let seed = 23;
        let sw = fog.evaluate(
            &ds.test.x,
            &FogParams { threshold, max_hops: fog.n_groves(), seed },
        );
        let cfg = ServerConfig { threshold, seed, ..Default::default() };
        let mut server = FogServer::start(&fog, &cfg).unwrap();
        let responses = server.classify(&ds.test.x);
        assert_eq!(responses.len(), ds.test.len());
        for (r, s) in responses.iter().zip(&sw.outcomes) {
            assert_eq!(r.label, s.label, "id {}", r.id);
            assert_eq!(r.hops, s.hops);
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.responses as usize, ds.test.len());
        assert_eq!(snap.forwards, snap.hops_total - snap.responses);
        server.shutdown();
    }

    #[test]
    fn multiple_batches_share_server() {
        let (fog, ds) = setup();
        let cfg = ServerConfig { threshold: 0.5, seed: 1, ..Default::default() };
        let mut server = FogServer::start(&fog, &cfg).unwrap();
        let f = fog.n_features;
        let r1 = server.classify(&ds.test.x[..10 * f]);
        let r2 = server.classify(&ds.test.x[10 * f..20 * f]);
        assert_eq!(r1.len(), 10);
        assert_eq!(r2.len(), 10);
        // ids are globally unique and ordered per batch
        assert!(r1.iter().enumerate().all(|(i, r)| r.id == i as u64));
        assert!(r2.iter().enumerate().all(|(i, r)| r.id == 10 + i as u64));
        server.shutdown();
    }

    #[test]
    fn small_in_flight_cap_still_completes() {
        let (fog, ds) = setup();
        let cfg = ServerConfig {
            threshold: 0.8,
            max_in_flight: 2,
            seed: 3,
            ..Default::default()
        };
        let mut server = FogServer::start(&fog, &cfg).unwrap();
        let responses = server.classify(&ds.test.x);
        assert_eq!(responses.len(), ds.test.len());
        server.shutdown();
    }

    #[test]
    fn batching_takes_effect() {
        let (fog, ds) = setup();
        let cfg = ServerConfig {
            threshold: 1.01, // force full circulation → lots of traffic
            batch_size: 32,
            batch_timeout: Duration::from_millis(2),
            seed: 4,
            ..Default::default()
        };
        let mut server = FogServer::start(&fog, &cfg).unwrap();
        server.classify(&ds.test.x);
        let snap = server.metrics().snapshot();
        assert!(
            snap.avg_batch_size() > 1.5,
            "expected batching, got {}",
            snap.avg_batch_size()
        );
        server.shutdown();
    }

    #[test]
    fn accuracy_matches_offline_eval() {
        let (fog, ds) = setup();
        let cfg = ServerConfig { threshold: 0.4, seed: 5, ..Default::default() };
        let mut server = FogServer::start(&fog, &cfg).unwrap();
        let responses = server.classify(&ds.test.x);
        let preds: Vec<usize> = responses.iter().map(|r| r.label).collect();
        let acc = crate::util::stats::accuracy(&preds, &ds.test.y);
        let sw = fog.evaluate(
            &ds.test.x,
            &FogParams { threshold: 0.4, max_hops: fog.n_groves(), seed: 5 },
        );
        assert!((acc - sw.accuracy(&ds.test.y)).abs() < 1e-9);
        server.shutdown();
    }
}
