//! [`ProbCache`] — a bounded, sharded LRU cache of probability rows
//! keyed by quantized feature vectors.
//!
//! The paper's headline metric is energy per classification; a serving
//! deployment in front of the accelerator can spend *zero* grove energy
//! on a repeated (or near-repeated) input by answering from a cache of
//! recent [`ProbMatrix`](crate::api::ProbMatrix) rows. Keys are the
//! feature vector quantized at a configurable step:
//!
//! * **step 0** — exact-hit semantics: the key is the raw f32 bit
//!   pattern, so a hit returns byte-identical results to cold evaluation
//!   (the conformance tests pin this).
//! * **step q > 0** — each feature is bucketed to `round(v / q)`; nearby
//!   inputs share a bucket and the cached row is an approximation, the
//!   serving-tier analogue of the paper's accuracy-for-energy knob
//!   (coarser buckets = more hits = fewer grove evaluations per answer).
//!
//! The cache is sharded by key hash: each shard is an independently
//! locked LRU map, so concurrent worker threads filling completed
//! batches contend only 1/N of the time. Eviction is least-recently-used
//! within a shard (a recency tick bumped on every hit).
//!
//! When the model serves on quantized kernel lanes, the cache can share
//! the arena's per-feature rank tables ([`ProbCache::with_tables`]):
//! keys become the same threshold-rank codes the kernel compares on, so
//! the serving tier quantizes each request once, and two rows that the
//! exact-quantized kernel cannot distinguish share an entry (semantically
//! lossless for rank-code-pure models).
//!
//! A cached row is only as reusable as the evaluation mode that produced
//! it: rows computed under an adaptive early-exit threshold `t < 1.0`
//! are approximations at that specific `t`, so the serving tier folds a
//! generation tag ([`ProbCache::with_tag`], the threshold's bit pattern)
//! into every key — a request served at a different threshold can never
//! be answered with a stale row. Full evaluation (no knob, or `t = 1.0`)
//! keeps tag 0 and shares rows freely, which is correct because those
//! modes are byte-identical.

use crate::exec::QuantTables;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache configuration carried by the sharded-server config.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    /// Total entry budget across every shard (0 disables the cache).
    pub capacity: usize,
    /// Lock shards (clamped to `capacity`).
    pub n_shards: usize,
    /// Feature quantization step; 0.0 = exact bit-pattern keys.
    pub quant_step: f32,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { capacity: 4096, n_shards: 8, quant_step: 0.0 }
    }
}

/// A quantized feature vector plus its precomputed hash. Equality
/// compares the full quantized vector, so hash collisions can never
/// return another input's row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CacheKey {
    quant: Vec<u64>,
    hash: u64,
}

impl Hash for CacheKey {
    fn hash<H: Hasher>(&self, state: &mut H) {
        state.write_u64(self.hash);
    }
}

/// Bucket codes beyond this magnitude are clamped so the tag-shifted
/// code below stays injective (any practical bucket count is far
/// smaller; beyond it the approximation merely coarsens).
const MAX_BUCKET: f32 = 1e18;

/// Quantize one feature value at `step` (0.0 = exact bit pattern).
/// Finite values bucket to `round(v / step)`; non-finite values always
/// key by their exact bit pattern (a NaN must never share a bucket with
/// real values — float→int casts saturate NaN to 0). The low bit tags
/// which key space a code belongs to, so a finite bucket can never alias
/// a bit-pattern key either.
#[inline]
fn quantize(v: f32, step: f32) -> u64 {
    if step > 0.0 && v.is_finite() {
        let code = (v / step).round().clamp(-MAX_BUCKET, MAX_BUCKET) as i64;
        (code as u64) << 1
    } else {
        ((v.to_bits() as u64) << 1) | 1
    }
}

struct Entry {
    prob: Vec<f32>,
    tick: u64,
}

#[derive(Default)]
struct Shard {
    map: HashMap<CacheKey, Entry>,
    tick: u64,
}

/// Point-in-time cache occupancy/eviction counters (hit/miss accounting
/// lives in the serving tier's [`Metrics`](super::Metrics)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub entries: u64,
    pub insertions: u64,
    pub evictions: u64,
}

/// The sharded LRU probability-row cache.
pub struct ProbCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_cap: usize,
    quant_step: f32,
    /// When set, keys are the arena's per-feature threshold-rank codes
    /// instead of `quant_step` buckets (one quantization scheme shared
    /// with the kernel).
    tables: Option<Arc<QuantTables>>,
    /// Evaluation-mode generation tag folded into every key (0 = full
    /// evaluation); see [`ProbCache::with_tag`].
    tag: u64,
    insertions: AtomicU64,
    evictions: AtomicU64,
}

impl ProbCache {
    pub fn new(cfg: &CacheConfig) -> ProbCache {
        let n_shards = cfg.n_shards.clamp(1, cfg.capacity.max(1));
        ProbCache {
            shards: (0..n_shards).map(|_| Mutex::new(Shard::default())).collect(),
            // Floor division so shard caps never sum above the configured
            // total budget (n_shards ≤ capacity keeps this ≥ 1).
            per_shard_cap: cfg.capacity / n_shards,
            quant_step: cfg.quant_step,
            tables: None,
            tag: 0,
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Key on the model's per-feature threshold-rank codes (the same
    /// tables the quantized kernel compares on) instead of `quant_step`
    /// buckets. Hit/miss mechanics — step-0 exactness of the returned
    /// row, LRU, sharding — are unchanged; only the key function is.
    pub fn with_tables(mut self, tables: Option<Arc<QuantTables>>) -> ProbCache {
        self.tables = tables;
        self
    }

    pub fn quant_step(&self) -> f32 {
        self.quant_step
    }

    /// Fold an evaluation-mode generation tag into every key (part of
    /// key *equality*, not just the hash, so aliasing is impossible).
    /// The serving tier passes the adaptive threshold's bit pattern, so
    /// rows computed under one `t < 1.0` never answer a request at
    /// another; the default tag 0 (full evaluation) keeps the plain and
    /// `t = 1.0` modes sharing rows — they are byte-identical.
    pub fn with_tag(mut self, tag: u64) -> ProbCache {
        self.tag = tag;
        self
    }

    /// The active evaluation-mode tag (0 = full evaluation).
    pub fn tag(&self) -> u64 {
        self.tag
    }

    /// Quantize a feature row into its cache key (FNV-1a over the
    /// per-feature codes: shared rank codes when the arena's tables are
    /// attached, `quant_step` buckets otherwise).
    pub fn key(&self, row: &[f32]) -> CacheKey {
        let mut quant: Vec<u64> = match &self.tables {
            Some(t) => {
                row.iter().enumerate().map(|(k, &v)| t.code(k, v) as u64).collect()
            }
            None => row.iter().map(|&v| quantize(v, self.quant_step)).collect(),
        };
        // The tag rides in the code vector itself so it participates in
        // both the hash and the equality check.
        quant.push(self.tag);
        let mut hash = 0xCBF29CE484222325u64;
        for &q in &quant {
            hash = (hash ^ q).wrapping_mul(0x100000001B3);
        }
        CacheKey { quant, hash }
    }

    fn shard(&self, key: &CacheKey) -> &Mutex<Shard> {
        &self.shards[(key.hash % self.shards.len() as u64) as usize]
    }

    /// Look up a row, bumping its recency on a hit. Returns a clone of
    /// the cached probability distribution.
    pub fn get(&self, key: &CacheKey) -> Option<Vec<f32>> {
        let mut shard = self.shard(key).lock().ok()?;
        shard.tick += 1;
        let tick = shard.tick;
        let entry = shard.map.get_mut(key)?;
        entry.tick = tick;
        Some(entry.prob.clone())
    }

    /// Insert (or refresh) a computed row, evicting the shard's
    /// least-recently-used entry when the shard is at capacity.
    pub fn insert(&self, key: CacheKey, prob: Vec<f32>) {
        if self.per_shard_cap == 0 {
            return;
        }
        let Ok(mut shard) = self.shard(&key).lock() else { return };
        shard.tick += 1;
        let tick = shard.tick;
        if !shard.map.contains_key(&key) && shard.map.len() >= self.per_shard_cap {
            // Linear min-tick scan: shards are small (capacity /
            // n_shards), so eviction stays cheap without an intrusive
            // list.
            if let Some(oldest) =
                shard.map.iter().min_by_key(|(_, e)| e.tick).map(|(k, _)| k.clone())
            {
                shard.map.remove(&oldest);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.insertions.fetch_add(1, Ordering::Relaxed);
        shard.map.insert(key, Entry { prob, tick });
    }

    /// Entries currently cached (sums shard occupancy; racy but exact
    /// when writers are quiescent).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().map(|g| g.map.len()).unwrap_or(0)).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            entries: self.len() as u64,
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(capacity: usize, quant_step: f32) -> ProbCache {
        ProbCache::new(&CacheConfig { capacity, n_shards: 4, quant_step })
    }

    #[test]
    fn exact_keys_roundtrip() {
        let c = cache(64, 0.0);
        let row = [1.0f32, -2.5, 0.0, 3.25];
        let key = c.key(&row);
        assert!(c.get(&key).is_none());
        c.insert(key.clone(), vec![0.1, 0.9]);
        assert_eq!(c.get(&key), Some(vec![0.1, 0.9]));
        // A one-bit perturbation misses at step 0.
        let mut near = row;
        near[3] = f32::from_bits(near[3].to_bits() + 1);
        assert!(c.get(&c.key(&near)).is_none());
    }

    #[test]
    fn quantized_keys_bucket_nearby_inputs() {
        let c = cache(64, 0.5);
        let key_a = c.key(&[1.0, 2.0]);
        let key_b = c.key(&[1.1, 2.1]); // same 0.5-wide buckets
        let key_far = c.key(&[1.4, 2.0]); // 1.4/0.5 rounds to 3, not 2
        assert_eq!(key_a, key_b);
        assert_ne!(key_a, key_far);
        c.insert(key_a, vec![1.0]);
        assert_eq!(c.get(&key_b), Some(vec![1.0]));
        assert!(c.get(&key_far).is_none());
    }

    #[test]
    fn capacity_is_bounded_and_lru_evicts() {
        let c = ProbCache::new(&CacheConfig { capacity: 8, n_shards: 1, quant_step: 0.0 });
        for i in 0..32 {
            c.insert(c.key(&[i as f32]), vec![i as f32]);
        }
        assert_eq!(c.len(), 8);
        assert_eq!(c.stats().evictions, 24);
        // The most recent inserts survive.
        assert!(c.get(&c.key(&[31.0f32])).is_some());
        assert!(c.get(&c.key(&[0.0f32])).is_none());
        // A get refreshes recency: 24 stays alive through 8 more inserts.
        assert!(c.get(&c.key(&[24.0f32])).is_some());
        for i in 100..107 {
            c.insert(c.key(&[i as f32]), vec![0.0]);
        }
        assert!(c.get(&c.key(&[24.0f32])).is_some(), "refreshed entry was evicted");
    }

    #[test]
    fn hash_collisions_cannot_alias() {
        // Equality is the full quantized vector, so even a forced hash
        // collision cannot return another input's row.
        let a = CacheKey { quant: vec![1, 2], hash: 7 };
        let b = CacheKey { quant: vec![2, 1], hash: 7 };
        assert_ne!(a, b);
        let c = cache(16, 0.0);
        c.insert(a.clone(), vec![0.25]);
        c.insert(b.clone(), vec![0.75]);
        assert_eq!(c.get(&a), Some(vec![0.25]));
        assert_eq!(c.get(&b), Some(vec![0.75]));
    }

    #[test]
    fn non_finite_values_never_alias_real_buckets() {
        // NaN would saturate to bucket 0 under a bare float→int cast and
        // answer with a cached near-zero row; it must key by bit pattern,
        // and the tag bit must keep bit-pattern keys disjoint from every
        // finite bucket (INFINITY's bits are 2139095040 — a reachable
        // bucket index for finite inputs at a fine step).
        let c = cache(16, 0.5);
        let zeroish = c.key(&[0.1f32, 0.0]);
        assert_ne!(c.key(&[f32::NAN, 0.0]), zeroish);
        assert_ne!(c.key(&[f32::INFINITY, 0.0]), zeroish);
        assert_ne!(c.key(&[f32::INFINITY, 0.0]), c.key(&[f32::NEG_INFINITY, 0.0]));
        c.insert(zeroish, vec![0.9, 0.1]);
        assert!(c.get(&c.key(&[f32::NAN, 0.0])).is_none());
        // Cross-space aliasing probe: a finite value whose bucket index
        // equals INFINITY's bit pattern must still key differently.
        let fine = cache(16, 1e-3);
        let bucket_of_inf_bits = f32::INFINITY.to_bits() as f32 * 1e-3;
        assert_ne!(
            fine.key(&[bucket_of_inf_bits, 0.0]),
            fine.key(&[f32::INFINITY, 0.0]),
            "finite bucket aliased a non-finite bit-pattern key"
        );
    }

    #[test]
    fn shard_caps_never_exceed_total_budget() {
        // capacity 9 over 8 shards must hold ≤ 9 entries, not ceil-split
        // into 16.
        let c = ProbCache::new(&CacheConfig { capacity: 9, n_shards: 8, quant_step: 0.0 });
        for i in 0..64 {
            c.insert(c.key(&[i as f32]), vec![0.0]);
        }
        assert!(c.len() <= 9, "over budget: {} entries", c.len());
    }

    #[test]
    fn no_eviction_until_exactly_past_capacity() {
        // Boundary: filling to *exact* capacity keeps every entry; the
        // first insert beyond it evicts exactly one (the LRU).
        let c = ProbCache::new(&CacheConfig { capacity: 4, n_shards: 1, quant_step: 0.0 });
        for i in 0..4 {
            c.insert(c.key(&[i as f32]), vec![i as f32]);
        }
        assert_eq!(c.len(), 4);
        assert_eq!(c.stats().evictions, 0, "evicted below capacity");
        for i in 0..4 {
            assert_eq!(c.get(&c.key(&[i as f32])), Some(vec![i as f32]));
        }
        c.insert(c.key(&[99.0f32]), vec![99.0]);
        assert_eq!(c.len(), 4, "capacity exceeded");
        assert_eq!(c.stats().evictions, 1);
        // The LRU victim is entry 0 (every other entry was just touched
        // by the gets above — in insertion order, so 0 is oldest).
        assert!(c.get(&c.key(&[0.0f32])).is_none(), "LRU entry survived");
        assert!(c.get(&c.key(&[99.0f32])).is_some());
    }

    #[test]
    fn quantization_collisions_map_to_one_entry() {
        // Boundary: at step 1.0 the rows 0.2, -0.2 and 0.4 all round to
        // bucket 0 per feature — one key, one entry, last insert wins.
        let c = cache(16, 1.0);
        let k_a = c.key(&[0.2f32, 0.2]);
        let k_b = c.key(&[-0.2f32, 0.4]);
        let k_far = c.key(&[0.6f32, 0.2]); // 0.6 rounds to bucket 1
        assert_eq!(k_a, k_b, "colliding buckets must share a key");
        assert_ne!(k_a, k_far);
        c.insert(k_a.clone(), vec![0.9, 0.1]);
        // The collision returns the cached approximation...
        assert_eq!(c.get(&k_b), Some(vec![0.9, 0.1]));
        // ...and re-inserting through the colliding key replaces, not
        // duplicates.
        c.insert(k_b, vec![0.2, 0.8]);
        assert_eq!(c.get(&k_a), Some(vec![0.2, 0.8]));
        let occupied: usize = c.len();
        assert_eq!(occupied, 1, "collision created a duplicate entry");
    }

    #[test]
    fn rank_code_keys_follow_kernel_equivalence() {
        // Satellite pin: with the arena's tables attached, keys are the
        // kernel's rank codes — rows the exact-quantized kernel cannot
        // distinguish share an entry, rows it separates never collide —
        // and step-0 hit mechanics (a hit returns the inserted row
        // byte-identically) are unchanged.
        let tables =
            Arc::new(QuantTables::build(2, [(0usize, 1.0f32), (0, 3.0), (1, 0.5)].into_iter()));
        let c = cache(64, 0.0).with_tables(Some(Arc::clone(&tables)));
        // 0.2 and 0.9 sit below every feature-0 cut → same codes.
        let k_a = c.key(&[0.2, 0.1]);
        let k_b = c.key(&[0.9, 0.3]);
        assert_eq!(k_a, k_b, "kernel-indistinguishable rows must share a key");
        // 2.0 crosses the cut at 1.0 → the kernel separates these rows.
        assert_ne!(k_a, c.key(&[2.0, 0.1]));
        // NaN codes to 0 exactly like the kernel's rank coder.
        assert_eq!(c.key(&[f32::NAN, 0.1]), k_a);
        c.insert(k_a.clone(), vec![0.3, 0.7]);
        assert_eq!(c.get(&k_b), Some(vec![0.3, 0.7]));
        // Without tables the same config keys by bit pattern (unchanged
        // baseline behavior).
        let plain = cache(64, 0.0);
        assert_ne!(plain.key(&[0.2, 0.1]), plain.key(&[0.9, 0.3]));
    }

    #[test]
    fn generation_tags_partition_the_key_space() {
        // Rows cached under one evaluation-mode tag (adaptive threshold
        // bit pattern) must never answer a request keyed under another —
        // and equality, not just the hash, must differ.
        let row = [1.0f32, -2.5, 0.75];
        let plain = cache(64, 0.0);
        let t06 = cache(64, 0.0).with_tag(0.6f32.to_bits() as u64);
        let t08 = cache(64, 0.0).with_tag(0.8f32.to_bits() as u64);
        assert_eq!(plain.tag(), 0);
        assert_ne!(t06.key(&row), t08.key(&row));
        assert_ne!(plain.key(&row), t06.key(&row));
        // Tag 0 is the untagged semantics: full-evaluation instances
        // (no knob, or t = 1.0 filtered to None) produce equal keys.
        assert_eq!(plain.key(&row), cache(64, 0.0).with_tag(0).key(&row));
        // Within one instance, hit mechanics are unchanged.
        let key = t06.key(&row);
        t06.insert(key.clone(), vec![0.2, 0.8]);
        assert_eq!(t06.get(&key), Some(vec![0.2, 0.8]));
        // Tags compose with rank-code tables too.
        let tables =
            Arc::new(QuantTables::build(2, [(0usize, 1.0f32), (1, 0.5)].into_iter()));
        let a = cache(64, 0.0).with_tables(Some(Arc::clone(&tables))).with_tag(1);
        let b = cache(64, 0.0).with_tables(Some(tables)).with_tag(2);
        assert_ne!(a.key(&[0.2, 0.1]), b.key(&[0.2, 0.1]));
    }

    #[test]
    fn zero_capacity_disables() {
        let c = ProbCache::new(&CacheConfig { capacity: 0, n_shards: 8, quant_step: 0.0 });
        let key = c.key(&[1.0]);
        c.insert(key.clone(), vec![1.0]);
        assert!(c.get(&key).is_none());
        assert!(c.is_empty());
    }
}
