//! `loadgen` — a seeded open-loop load generator for the fleet tier.
//!
//! Paper anchor: Fig 5's budget trade-off only matters under load — an
//! idle fleet never exhausts a budget. Closed-loop drivers (send, wait,
//! send) hide overload by slowing the offered rate to whatever the
//! server sustains, a classic coordinated-omission trap; the IoT
//! profiling methodology this repo follows (Abdel Magid et al., arXiv
//! 1902.11119) measures with **open-loop arrival times** instead. This
//! module pre-computes a deterministic arrival schedule — a Poisson
//! process whose rate ramps linearly from `qps_start` to `qps_end`,
//! seeded through the crate [`Rng`] — and a driver that replays it
//! against a [`Fleet`] in virtual-time ticks:
//!
//! * [`schedule`] — `LoadgenConfig` → `Vec<Arrival>` (time, model, row),
//!   bit-reproducible from the seed;
//! * [`run`] / [`run_schedule`] — group arrivals into `tick_us` virtual
//!   ticks, optionally pace each tick to its wall-clock due time, feed
//!   one [`Fleet::classify`] batch per tick, and fold the outcome /
//!   energy deltas into a [`LoadgenReport`].
//!
//! The driver itself stays closed-loop *per tick* (it waits for each
//! batch), which is what makes the fleet's admission gauges — and hence
//! the `Served`/`Downgraded`/`Shed` counts — a pure function of the
//! schedule: replaying the same seed reproduces the same report
//! counters, the acceptance pin of `rust/tests/fleet.rs`. Pacing only
//! changes wall-clock latency numbers, never outcomes.

use super::fleet::{Fleet, FleetRequest};
use super::metrics::LatencySummary;
use crate::util::error::Result;
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Open-loop traffic shape: a linear QPS ramp over a fixed duration,
/// replayed deterministically from `seed`.
#[derive(Clone, Copy, Debug)]
pub struct LoadgenConfig {
    /// Arrival rate at t = 0 (requests/second).
    pub qps_start: f64,
    /// Arrival rate at t = `duration_s` (the ramp target).
    pub qps_end: f64,
    /// Schedule length in (virtual) seconds.
    pub duration_s: f64,
    /// Seed of the arrival stream (times, model choices, row choices).
    pub seed: u64,
    /// Virtual-time tick width: arrivals inside one tick form one
    /// `Fleet::classify` batch.
    pub tick_us: u64,
    /// Sleep each tick until its wall-clock due time (true open-loop
    /// pacing; off for deterministic CI runs where only outcome counts
    /// matter).
    pub pace: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            qps_start: 100.0,
            qps_end: 500.0,
            duration_s: 2.0,
            seed: 42,
            tick_us: 20_000,
            pace: false,
        }
    }
}

impl LoadgenConfig {
    /// Parse the CLI spec `QPS:SECS` (e.g. `400:10`): ramp from
    /// `QPS / 5` up to `QPS` over `SECS` seconds, paced, default seed.
    pub fn parse_spec(spec: &str) -> Result<LoadgenConfig> {
        let parts: Vec<&str> = spec.split(':').collect();
        let parsed = match parts.as_slice() {
            [qps, secs] => match (qps.parse::<f64>(), secs.parse::<f64>()) {
                (Ok(q), Ok(s)) => Some((q, s)),
                _ => None,
            },
            _ => None,
        };
        let Some((qps, secs)) = parsed else {
            crate::bail!(
                "bad --loadgen spec '{spec}': expected QPS:SECS (e.g. 400:10, \
                 a ramp from QPS/5 to QPS over SECS seconds)"
            );
        };
        crate::ensure!(
            qps.is_finite() && qps > 0.0 && secs.is_finite() && secs > 0.0,
            "bad --loadgen spec '{spec}': QPS:SECS values must be positive"
        );
        Ok(LoadgenConfig {
            qps_start: qps / 5.0,
            qps_end: qps,
            duration_s: secs,
            pace: true,
            ..LoadgenConfig::default()
        })
    }
}

/// One scheduled request: virtual arrival time, target model (fleet
/// registration index), and a row index into the driver's feature pool
/// (reduced modulo the pool size at replay time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Arrival {
    pub t_us: u64,
    pub model: usize,
    pub row: usize,
}

/// Draw the deterministic arrival schedule: exponential inter-arrival
/// gaps at the (linearly ramping) instantaneous rate, each arrival
/// addressed to a uniformly-drawn model. Sorted by time by
/// construction.
pub fn schedule(cfg: &LoadgenConfig, n_models: usize) -> Vec<Arrival> {
    assert!(n_models > 0, "loadgen schedule over zero models");
    if !(cfg.duration_s > 0.0) || (cfg.qps_start <= 0.0 && cfg.qps_end <= 0.0) {
        return Vec::new();
    }
    let mut rng = Rng::new(cfg.seed);
    let mut arrivals = Vec::new();
    let mut t = 0.0f64;
    loop {
        let frac = (t / cfg.duration_s).clamp(0.0, 1.0);
        let rate = (cfg.qps_start + (cfg.qps_end - cfg.qps_start) * frac).max(1e-9);
        // Exponential gap: -ln(1 - U) / rate, floored so a pathological
        // U = 0 draw cannot stall the clock.
        let gap = (-(1.0 - rng.gen_f64()).ln() / rate).max(1e-9);
        t += gap;
        if t >= cfg.duration_s {
            return arrivals;
        }
        arrivals.push(Arrival {
            t_us: (t * 1e6) as u64,
            model: rng.gen_range(n_models),
            row: rng.next_u64() as usize,
        });
    }
}

/// Per-model outcome and energy deltas of one loadgen run.
#[derive(Clone, Debug)]
pub struct LoadgenModelReport {
    pub name: String,
    /// Requests the schedule addressed to this model.
    pub requested: u64,
    /// ... evaluated by it.
    pub served: u64,
    /// ... re-routed to a fallback model.
    pub downgraded_away: u64,
    /// Requests absorbed from over-budget peers.
    pub downgraded_into: u64,
    /// ... rejected.
    pub shed: u64,
    /// This entry's evaluation energy over the run, nJ per evaluated
    /// classification (0 under the software backend).
    pub energy_per_class_nj: f64,
    /// Service latency of the answered requests addressed to this model
    /// (µs, request-level: queue + batch + evaluation).
    pub latency: LatencySummary,
}

/// Fleet-wide outcome of one loadgen run (deltas over the run only, so
/// back-to-back runs against one fleet don't blend).
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// Requests the schedule offered.
    pub offered: u64,
    pub served: u64,
    pub downgraded: u64,
    pub shed: u64,
    /// `shed / offered` (0.0 on an empty schedule).
    pub shed_rate: f64,
    /// Classify ticks driven.
    pub ticks: u64,
    /// Virtual schedule span actually replayed, seconds.
    pub duration_s: f64,
    pub per_model: Vec<LoadgenModelReport>,
}

/// Generate the schedule for `cfg` and replay it against `fleet`,
/// drawing request rows from the row-major `pool`.
pub fn run(fleet: &mut Fleet, pool: &[f32], cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    let arrivals = schedule(cfg, fleet.n_models());
    run_schedule(fleet, pool, &arrivals, cfg)
}

/// Replay a pre-computed arrival schedule against `fleet`: one
/// `Fleet::classify` batch per `tick_us` of virtual time, paced to wall
/// clock when `cfg.pace` is set. `pool` must be a row-major
/// `[n, fleet.n_features()]` batch with at least one row.
pub fn run_schedule(
    fleet: &mut Fleet,
    pool: &[f32],
    arrivals: &[Arrival],
    cfg: &LoadgenConfig,
) -> Result<LoadgenReport> {
    let n_models = fleet.n_models();
    let f = fleet.n_features();
    let n_rows = super::model_server::check_aligned(pool.len(), f)?;
    crate::ensure!(n_rows > 0, "loadgen needs a non-empty feature-row pool");
    let tick_us = cfg.tick_us.max(1);
    let before = fleet.snapshot();
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    let mut ticks = 0u64;
    let start = Instant::now();
    let mut i = 0;
    while i < arrivals.len() {
        let due_us = arrivals[i].t_us;
        let boundary = (due_us / tick_us + 1) * tick_us;
        let mut batch = Vec::new();
        while i < arrivals.len() && arrivals[i].t_us < boundary {
            let a = &arrivals[i];
            let row = a.row % n_rows;
            batch.push(FleetRequest {
                model: a.model,
                features: pool[row * f..(row + 1) * f].to_vec(),
            });
            i += 1;
        }
        if cfg.pace {
            let due = Duration::from_micros(due_us);
            let elapsed = start.elapsed();
            if elapsed < due {
                std::thread::sleep(due - elapsed);
            }
        }
        let responses = fleet.classify(&batch)?;
        for (req, resp) in batch.iter().zip(&responses) {
            if let Some(r) = &resp.response {
                latencies[req.model].push(r.latency_us as f64);
            }
        }
        ticks += 1;
    }
    let after = fleet.snapshot();

    let per_model = (0..n_models)
        .map(|m| {
            let (a, b) = (&after.per_model[m], &before.per_model[m]);
            let d_samples = a.snapshot.exec_samples.saturating_sub(b.snapshot.exec_samples);
            let d_fj =
                a.snapshot.exec_energy_fj.saturating_sub(b.snapshot.exec_energy_fj);
            LoadgenModelReport {
                name: a.name.clone(),
                requested: a.requested - b.requested,
                served: a.served - b.served,
                downgraded_away: a.downgraded_away - b.downgraded_away,
                downgraded_into: a.downgraded_into - b.downgraded_into,
                shed: a.shed - b.shed,
                energy_per_class_nj: if d_samples == 0 {
                    0.0
                } else {
                    d_fj as f64 * 1e-6 / d_samples as f64
                },
                latency: LatencySummary::from_us(std::mem::take(&mut latencies[m])),
            }
        })
        .collect();
    let offered = after.total.requests - before.total.requests;
    let shed = after.total.fleet_shed - before.total.fleet_shed;
    Ok(LoadgenReport {
        offered,
        served: after.total.fleet_served - before.total.fleet_served,
        downgraded: after.total.fleet_downgraded - before.total.fleet_downgraded,
        shed,
        shed_rate: if offered == 0 { 0.0 } else { shed as f64 / offered as f64 },
        ticks,
        duration_s: arrivals.last().map_or(0.0, |a| a.t_us as f64 * 1e-6),
        per_model,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Classifier, Estimator, ModelSpec};
    use crate::coordinator::fleet::{FleetConfig, FleetOutcome};
    use crate::data::synthetic::{generate, DatasetProfile};
    use std::sync::Arc;

    #[test]
    fn schedule_is_seed_deterministic() {
        let cfg = LoadgenConfig { qps_start: 200.0, qps_end: 800.0, ..Default::default() };
        let a = schedule(&cfg, 3);
        let b = schedule(&cfg, 3);
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed must replay bit-identically");
        let c = schedule(&LoadgenConfig { seed: 43, ..cfg }, 3);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn schedule_is_sorted_in_range_and_ramps() {
        let cfg = LoadgenConfig {
            qps_start: 50.0,
            qps_end: 500.0,
            duration_s: 2.0,
            ..Default::default()
        };
        let arrivals = schedule(&cfg, 2);
        let dur_us = (cfg.duration_s * 1e6) as u64;
        let mut prev = 0;
        let (mut first_half, mut second_half) = (0usize, 0usize);
        for a in &arrivals {
            assert!(a.t_us >= prev, "arrivals out of order");
            assert!(a.t_us < dur_us);
            assert!(a.model < 2);
            prev = a.t_us;
            if a.t_us < dur_us / 2 {
                first_half += 1;
            } else {
                second_half += 1;
            }
        }
        assert!(
            second_half > first_half,
            "ramp 50→500 qps must concentrate arrivals late \
             ({first_half} vs {second_half})"
        );
    }

    #[test]
    fn parse_spec_accepts_qps_secs() {
        let cfg = LoadgenConfig::parse_spec("400:10").expect("valid spec");
        assert!((cfg.qps_end - 400.0).abs() < 1e-12);
        assert!((cfg.qps_start - 80.0).abs() < 1e-12);
        assert!((cfg.duration_s - 10.0).abs() < 1e-12);
        assert!(cfg.pace);
        for bad in ["", "400", "400:10:2", "x:10", "400:y", "-5:10", "400:0"] {
            let err = LoadgenConfig::parse_spec(bad).expect_err(bad);
            assert!(err.to_string().contains("QPS:SECS"), "unhelpful error for '{bad}'");
        }
    }

    #[test]
    fn driver_replays_schedule_and_reports_outcomes() {
        let ds = generate(&DatasetProfile::demo(), 721);
        let spec = ModelSpec::for_shape("rf", ds.n_features(), ds.n_classes())
            .unwrap()
            .fast();
        let model: Arc<dyn Classifier> = Arc::from(spec.fit(&ds.train, 21));
        let mut fleet = Fleet::start(
            vec![("rf".to_string(), model)],
            &FleetConfig::default(),
        )
        .expect("fleet start");
        let cfg = LoadgenConfig {
            qps_start: 300.0,
            qps_end: 600.0,
            duration_s: 0.5,
            pace: false,
            ..Default::default()
        };
        let arrivals = schedule(&cfg, fleet.n_models());
        let report = run_schedule(&mut fleet, &ds.test.x, &arrivals, &cfg).expect("run");
        assert_eq!(report.offered as usize, arrivals.len());
        assert_eq!(report.served as usize, arrivals.len(), "unlimited budget serves all");
        assert_eq!(report.shed, 0);
        assert_eq!(report.shed_rate, 0.0);
        assert!(report.ticks > 0);
        let m0 = &report.per_model[0];
        assert_eq!(m0.requested, m0.served + m0.downgraded_away + m0.shed);
        assert!(m0.latency.p99_us >= m0.latency.p50_us);
        // Replies carry real fleet responses, visible through classify
        // too — smoke the Served outcome path end to end.
        let reqs = FleetRequest::batch(0, &ds.test.x[..ds.n_features()], ds.n_features())
            .unwrap();
        let r = fleet.classify(&reqs).unwrap();
        assert_eq!(r[0].outcome, FleetOutcome::Served { model: 0 });
        fleet.shutdown();
    }
}
