//! Generic model serving: batch any [`Classifier`] trait object behind
//! the same request/response plumbing the FoG ring uses.
//!
//! Where [`super::server::FogServer`] is the paper-faithful grove ring
//! (hop forwarding, confidence gating), `ModelServer` is the
//! multi-backend front-end the unified API enables: *any* registry model
//! — an SVM, the CNN, a plain forest, or a FoG at a fixed operating
//! point — serves traffic through one code path with dynamic batching
//! and shared metrics. Worker threads pull from a shared queue, assemble
//! row-major batches, and answer through the batch-first
//! [`Classifier::predict_proba_batch`] hot path; there is no
//! per-model-type dispatch anywhere in the serving loop.
//!
//! The queue-plus-worker-pool unit is factored out as a crate-internal
//! `Replica`: a `ModelServer` is exactly one replica, and the
//! scale-out [`super::ShardedServer`] runs N of them behind a
//! [`super::ShardRouter`] and a [`super::ProbCache`] — same worker loop,
//! same metrics, no duplicated batching logic.
//!
//! Each replica resolves an execution backend
//! ([`Classifier::exec_backend`]) once at start-up and dispatches every
//! assembled batch through it — `Router → Replica → Backend → Arena`.
//! The default [`BackendKind::Software`] runs the arena kernels
//! unchanged; [`BackendKind::Uarch`] streams the same tiles through the
//! cycle-level grove-ring simulator, folding per-tile cycle and energy
//! reports into the replica's [`Metrics`] (answers are byte-identical
//! either way — the backend conformance suite pins it).

use super::cache::{CacheKey, ProbCache};
use super::messages::Response;
use super::metrics::Metrics;
use super::router::ShardRouter;
use crate::api::{BackendKind, Classifier};
use crate::exec::Backend as ExecBackend;
use crate::util::error::Result;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One enqueued classification request.
pub(crate) struct Job {
    pub id: u64,
    pub features: Vec<f32>,
    pub enqueued: Instant,
    /// Cache slot to fill with the computed row (sharded tier only; the
    /// front-end quantizes once and the worker fills on completion).
    pub cache_key: Option<CacheKey>,
}

/// Configuration for a generic model server (per replica in the sharded
/// tier).
#[derive(Clone, Debug)]
pub struct ModelServerConfig {
    /// Max items per evaluation batch.
    pub batch_size: usize,
    /// How long a worker waits for more items once one is in hand.
    pub batch_timeout: Duration,
    /// Worker threads sharing the queue.
    pub n_workers: usize,
    /// Execution backend workers dispatch batches through. Resolved once
    /// per replica via [`Classifier::exec_backend`]; models without a
    /// backend for the kind (dense baselines) fall back to
    /// [`Classifier::predict_proba_batch`]. `Uarch` adds live
    /// cycle/energy accounting to the replica's [`Metrics`] without
    /// changing any answer.
    pub backend: BackendKind,
}

impl Default for ModelServerConfig {
    fn default() -> Self {
        ModelServerConfig {
            batch_size: 32,
            batch_timeout: Duration::from_micros(200),
            n_workers: 2,
            backend: BackendKind::Software,
        }
    }
}

/// Side channels a replica's workers report into besides the response
/// stream: per-replica metrics, the execution backend evaluating
/// batches, the shared cache to fill on completion, and the router gauge
/// to decrement per retired job.
pub(crate) struct ReplicaCtx {
    pub metrics: Arc<Metrics>,
    /// Resolved execution backend (`None` = fall back to the model's own
    /// batch path — dense baselines have no arena engine).
    pub backend: Option<Arc<dyn ExecBackend>>,
    pub cache: Option<Arc<ProbCache>>,
    /// `(router, this replica's index)` — completions are reported so
    /// `LeastLoaded` sees live queue depths.
    pub router: Option<(Arc<ShardRouter>, usize)>,
}

/// One model replica: a job queue plus the worker pool draining it. The
/// building block shared by [`ModelServer`] (one replica) and
/// [`super::ShardedServer`] (N replicas behind a router).
pub(crate) struct Replica {
    job_tx: Option<Sender<Job>>,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Replica {
    /// Spin up `cfg.n_workers` threads serving `model`, answering on
    /// `resp_tx`. `name` prefixes the worker thread names.
    pub fn start(
        model: Arc<dyn Classifier>,
        cfg: &ModelServerConfig,
        resp_tx: Sender<Response>,
        cache: Option<Arc<ProbCache>>,
        router: Option<(Arc<ShardRouter>, usize)>,
        name: &str,
    ) -> Replica {
        let metrics = Arc::new(Metrics::default());
        let (job_tx, job_rx) = channel::<Job>();
        let shared_rx = Arc::new(Mutex::new(job_rx));
        let n_workers = cfg.n_workers.max(1);
        let batch_size = cfg.batch_size.max(1);
        // Resolve the execution backend once; every worker dispatches
        // through the same engine (request path: Router → Replica →
        // Backend → Arena).
        let backend = model.exec_backend(cfg.backend);

        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let rx = Arc::clone(&shared_rx);
            let tx = resp_tx.clone();
            let ctx = ReplicaCtx {
                metrics: Arc::clone(&metrics),
                backend: backend.clone(),
                cache: cache.clone(),
                router: router.clone(),
            };
            let model = Arc::clone(&model);
            let timeout = cfg.batch_timeout;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("{name}-{w}"))
                    .spawn(move || run_replica_worker(model, rx, tx, ctx, batch_size, timeout))
                    .expect("spawn model worker"),
            );
        }
        Replica { job_tx: Some(job_tx), metrics, workers }
    }

    /// Enqueue one job (counts it into the replica's request gauge).
    pub fn send(&self, job: Job) {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.job_tx.as_ref().expect("replica running").send(job).expect("workers alive");
    }

    /// Drop the queue (workers exit on disconnect) and join them.
    pub fn shutdown(&mut self) {
        self.job_tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Validate a row-major batch length against the feature count; returns
/// the row count, or the friendly ragged-batch error every serving
/// front-end shares.
pub(crate) fn check_aligned(len: usize, n_features: usize) -> Result<usize> {
    crate::ensure!(
        len % n_features == 0,
        "ragged batch: {len} values do not divide into rows of {n_features} features; \
         pass a row-major [n, {n_features}] batch"
    );
    Ok(len / n_features)
}

/// How long `collect_in_order` waits between responses before declaring
/// the workers dead. Orders of magnitude above any single batch
/// evaluation; its only job is turning a worker panic in a multi-replica
/// server — where surviving senders keep the channel connected forever —
/// into a loud failure instead of a silent hang.
const WORKER_STALL_TIMEOUT: Duration = Duration::from_secs(60);

/// Receive `pending` responses and slot each by `id - base_id` into the
/// (possibly cache-prefilled) `responses`; returns the completed,
/// input-ordered list. Shared by every queue-backed front-end so the id
/// contract lives in one place.
pub(crate) fn collect_in_order(
    rx: &Receiver<Response>,
    mut responses: Vec<Option<Response>>,
    base_id: u64,
    pending: usize,
) -> Vec<Response> {
    for _ in 0..pending {
        let resp = match rx.recv_timeout(WORKER_STALL_TIMEOUT) {
            Ok(resp) => resp,
            Err(e) => panic!("serving workers died or stalled mid-batch: {e:?}"),
        };
        let idx = (resp.id - base_id) as usize;
        responses[idx] = Some(resp);
    }
    responses.into_iter().map(|r| r.expect("all answered")).collect()
}

/// A running classification service over one trained model.
pub struct ModelServer {
    replica: Replica,
    resp_rx: Receiver<Response>,
    n_features: usize,
    next_id: u64,
}

impl ModelServer {
    /// Spin up `cfg.n_workers` threads serving `model`.
    pub fn start(model: Arc<dyn Classifier>, cfg: &ModelServerConfig) -> ModelServer {
        let (resp_tx, resp_rx) = channel::<Response>();
        let n_features = model.n_features();
        let replica = Replica::start(model, cfg, resp_tx, None, None, "model-server");
        ModelServer { replica, resp_rx, n_features, next_id: 0 }
    }

    /// Classify a row-major batch; returns responses in input order, or a
    /// friendly error when the batch is ragged (its length does not
    /// divide into feature rows).
    pub fn classify(&mut self, x: &[f32]) -> Result<Vec<Response>> {
        let f = self.n_features;
        let n = check_aligned(x.len(), f)?;
        let base_id = self.next_id;
        self.next_id += n as u64;
        for i in 0..n {
            self.replica.send(Job {
                id: base_id + i as u64,
                features: x[i * f..(i + 1) * f].to_vec(),
                enqueued: Instant::now(),
                cache_key: None,
            });
        }
        let responses = (0..n).map(|_| None).collect();
        Ok(collect_in_order(&self.resp_rx, responses, base_id, n))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.replica.metrics
    }

    /// Drop the queue (workers exit on disconnect) and join them.
    pub fn shutdown(mut self) {
        self.replica.shutdown();
    }
}

pub(crate) fn run_replica_worker(
    model: Arc<dyn Classifier>,
    rx: Arc<Mutex<Receiver<Job>>>,
    responses: Sender<Response>,
    ctx: ReplicaCtx,
    batch_size: usize,
    batch_timeout: Duration,
) {
    let f = model.n_features();
    loop {
        // Hold the queue lock only while assembling one batch.
        let batch = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(_) => return, // a sibling worker panicked
            };
            let first = match guard.recv() {
                Ok(job) => job,
                Err(_) => return, // server shut down
            };
            let mut batch = vec![first];
            while batch.len() < batch_size {
                match guard.recv_timeout(batch_timeout) {
                    Ok(job) => batch.push(job),
                    Err(_) => break,
                }
            }
            batch
        };
        ctx.metrics.batches.fetch_add(1, Ordering::Relaxed);
        ctx.metrics.evals.fetch_add(batch.len() as u64, Ordering::Relaxed);

        // One batch-first prediction for the whole assembly, dispatched
        // through the replica's execution backend when one exists
        // (answers are backend-independent; only the accounting differs).
        let mut x = Vec::with_capacity(batch.len() * f);
        for job in &batch {
            x.extend_from_slice(&job.features);
        }
        let t_eval = Instant::now();
        let probs = match &ctx.backend {
            Some(backend) => {
                let (probs, report) = backend.evaluate_tile(&x, batch.len());
                ctx.metrics.record_exec(&report);
                probs
            }
            None => model.predict_proba_batch(&x, batch.len()),
        };
        ctx.metrics.record_batch_latency_us(t_eval.elapsed().as_micros() as u64);
        let labels = probs.argmax_rows();

        for (i, job) in batch.into_iter().enumerate() {
            let prob = probs.row(i).to_vec();
            // Fill the cache before answering so a caller that sees the
            // response and immediately re-asks hits.
            if let (Some(cache), Some(key)) = (&ctx.cache, job.cache_key) {
                cache.insert(key, prob.clone());
            }
            if let Some((router, idx)) = &ctx.router {
                router.note_completed(*idx);
            }
            ctx.metrics.responses.fetch_add(1, Ordering::Relaxed);
            ctx.metrics.hops_total.fetch_add(1, Ordering::Relaxed);
            if responses
                .send(Response {
                    id: job.id,
                    label: labels[i],
                    prob,
                    hops: 1,
                    latency_us: job.enqueued.elapsed().as_micros() as u64,
                })
                .is_err()
            {
                return; // caller gone
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Estimator, ModelSpec};
    use crate::data::synthetic::{generate, DatasetProfile};

    fn serve(name: &str, cfg: &ModelServerConfig) {
        let ds = generate(&DatasetProfile::demo(), 221);
        let spec = ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
            .unwrap()
            .fast();
        let model: Arc<dyn Classifier> = Arc::from(spec.fit(&ds.train, 5));
        let offline = model.predict_batch(&ds.test.x, ds.test.len());

        let mut server = ModelServer::start(Arc::clone(&model), cfg);
        let responses = server.classify(&ds.test.x).expect("aligned batch");
        assert_eq!(responses.len(), ds.test.len());
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.label, offline[i], "{name} row {i}");
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.responses as usize, ds.test.len());
        server.shutdown();
    }

    #[test]
    fn serves_linear_svm_matching_offline() {
        serve("svm_lr", &ModelServerConfig::default());
    }

    #[test]
    fn serves_forest_matching_offline() {
        serve("rf", &ModelServerConfig { n_workers: 4, ..Default::default() });
    }

    #[test]
    fn serves_fog_matching_offline() {
        // The FoG model's content-hashed start groves make batched and
        // per-request serving agree no matter how batches form.
        serve("fog_opt", &ModelServerConfig { batch_size: 4, ..Default::default() });
    }

    #[test]
    fn uarch_backend_serving_matches_offline() {
        use crate::api::BackendKind;
        let ds = generate(&DatasetProfile::demo(), 224);
        let spec = ModelSpec::for_shape("fog_opt", ds.n_features(), ds.n_classes())
            .unwrap()
            .fast();
        let model: Arc<dyn Classifier> = Arc::from(spec.fit(&ds.train, 9));
        let offline = model.predict_proba_batch(&ds.test.x, ds.test.len());
        let cfg = ModelServerConfig { backend: BackendKind::Uarch, ..Default::default() };
        let mut server = ModelServer::start(Arc::clone(&model), &cfg);
        let responses = server.classify(&ds.test.x).expect("aligned batch");
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(&r.prob[..], offline.row(i), "uarch-served row {i} diverged");
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.exec_samples as usize, ds.test.len());
        assert!(snap.energy_per_class_nj() > 0.0, "no live energy reported");
        assert!(snap.cycles_per_class() > 0.0);
        let lat = server.metrics().batch_latency_summary();
        assert!(lat.p99_us >= lat.p50_us);
        server.shutdown();
    }

    #[test]
    fn multiple_batches_unique_ids() {
        let ds = generate(&DatasetProfile::demo(), 222);
        let spec = ModelSpec::for_shape("svm_lr", ds.n_features(), ds.n_classes())
            .unwrap()
            .fast();
        let model: Arc<dyn Classifier> = Arc::from(spec.fit(&ds.train, 6));
        let mut server = ModelServer::start(model, &ModelServerConfig::default());
        let f = ds.n_features();
        let r1 = server.classify(&ds.test.x[..8 * f]).expect("aligned batch");
        let r2 = server.classify(&ds.test.x[8 * f..16 * f]).expect("aligned batch");
        assert!(r1.iter().enumerate().all(|(i, r)| r.id == i as u64));
        assert!(r2.iter().enumerate().all(|(i, r)| r.id == 8 + i as u64));
        server.shutdown();
    }

    #[test]
    fn ragged_batch_is_a_friendly_error() {
        let ds = generate(&DatasetProfile::demo(), 223);
        let spec = ModelSpec::for_shape("svm_lr", ds.n_features(), ds.n_classes())
            .unwrap()
            .fast();
        let model: Arc<dyn Classifier> = Arc::from(spec.fit(&ds.train, 7));
        let mut server = ModelServer::start(model, &ModelServerConfig::default());
        let err = server
            .classify(&ds.test.x[..ds.n_features() + 1])
            .expect_err("ragged batch must not panic");
        let msg = err.to_string();
        assert!(msg.contains("ragged batch"), "unhelpful message: {msg}");
        // The server must stay usable after a rejected batch.
        let ok = server.classify(&ds.test.x[..ds.n_features()]).expect("aligned batch");
        assert_eq!(ok.len(), 1);
        server.shutdown();
    }
}
