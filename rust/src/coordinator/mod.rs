//! The serving coordinator: a threaded front-end that turns the FoG ring
//! into a classification service — the L3 "system" layer of the stack.
//!
//! Topology mirrors the hardware (Figure 3): one worker thread per grove,
//! connected in a ring by bounded channels (the data queues); an injector
//! that routes fresh requests to a random grove (Algorithm 2 line 3); and
//! a collector that returns responses to callers. Confidence gating and
//! hop forwarding are identical to the μarch simulator; this layer adds
//! dynamic batching, backpressure and metrics — what a deployment around
//! the accelerator would need.
//!
//! Two evaluation backends:
//! * **Native** — each worker walks its grove's flat trees directly
//!   (pure rust hot path).
//! * **Pjrt** — workers forward batches to a dedicated accelerator
//!   thread owning the AOT-compiled `grove_step` executables (PJRT
//!   handles are thread-affine). Python is never involved at runtime.
//!
//! Besides the paper-faithful grove ring ([`FogServer`]), the module
//! provides two generic serving tiers over the unified API:
//!
//! * [`ModelServer`] — one queue plus a worker pool serving *any*
//!   [`crate::api::Classifier`] trait object with dynamic batching;
//! * [`ShardedServer`] — the scale-out tier: N `ModelServer`-style
//!   replicas of one model behind a shared [`ShardRouter`]
//!   (`Random`/`RoundRobin`/`LeastLoaded` replica selection) and a
//!   bounded [`ProbCache`] of probability rows keyed by quantized
//!   feature vectors, checked before enqueue and filled on batch
//!   completion;
//! * [`Fleet`] — the multi-model tier above the sharded one: several
//!   registry models behind one request path, sharing replica capacity,
//!   with the paper's Fig-5 energy budget enforced live — over-budget
//!   models shed or downgrade their traffic ([`FleetPolicy`]) and every
//!   request resolves to an explicit [`FleetOutcome`]. The seeded
//!   open-loop load generator driving it lives in [`loadgen`].
//!
//! See `ARCHITECTURE.md` at the repo root for the full request-path
//! diagram through fleet admission, router, replica queues, the batch
//! kernel and the cache fill.

pub mod accel;
pub mod cache;
pub mod fleet;
pub mod loadgen;
pub mod messages;
pub mod metrics;
pub mod model_server;
pub mod router;
pub mod server;
pub mod shard;
pub mod worker;

pub use cache::{CacheConfig, CacheStats, ProbCache};
pub use fleet::{
    DowngradeFallback, EnergyBudget, Fleet, FleetConfig, FleetModelStats, FleetOutcome,
    FleetPolicy, FleetRequest, FleetResponse, FleetSnapshot, StrictShed,
};
pub use loadgen::{Arrival, LoadgenConfig, LoadgenModelReport, LoadgenReport};
pub use messages::{Request, Response};
pub use metrics::{Metrics, MetricsSnapshot};
pub use model_server::{ModelServer, ModelServerConfig};
pub use router::{Router, RouterPolicy, ShardRouter};
pub use server::{Backend, FogServer, ServerConfig};
pub use shard::{ShardedServer, ShardedServerConfig};
