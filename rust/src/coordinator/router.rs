//! Request routing policies for the serving front-end.
//!
//! Algorithm 2 starts each input at a *random* grove "to avoid bias"
//! (line 3) — that is the paper-faithful default and the one every parity
//! test uses. A deployment may prefer other policies; this module
//! provides the standard three behind one [`ShardRouter`] abstraction
//! that serves two tiers of the stack:
//!
//! * **grove-start selection** — which grove of the ring an input enters
//!   at ([`Router`] is the historical alias used by the `ablate`
//!   experiment, which measures the load-balance effect of each policy);
//! * **replica selection** — which [`ModelServer`](super::ModelServer)
//!   replica of a [`ShardedServer`](super::ShardedServer) a request is
//!   enqueued on (the scale-out tier added by the sharding PR).
//!
//! `LeastLoaded` breaks ties by a rotating start offset: a plain
//! "first minimum wins" scan resolves every all-idle tie to replica 0,
//! starving high-index replicas under uniform load (the serving batch
//! drains faster than injection refills it, so loads are frequently all
//! zero). The rotation makes the idle-tie case degrade to round-robin.

use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

/// Re-exported from the api layer (the policy enum lives next to
/// [`ServingSpec`](crate::api::ServingSpec) so the model registry never
/// depends upward on the serving tier).
pub use crate::api::spec::RouterPolicy;

/// Shared router state: picks one of `n_targets` destinations per
/// request. The caller maintains the in-flight gauges on
/// inject/complete; the router never blocks and never locks.
pub struct ShardRouter {
    policy: RouterPolicy,
    n_targets: usize,
    seed: u64,
    rr_next: AtomicU64,
    /// Rotating tie-break offset for `LeastLoaded` (see module docs).
    tie_next: AtomicU64,
    /// In-flight per target (maintained by the caller on inject/complete).
    pub in_flight: Vec<AtomicU64>,
}

/// Historical name: the grove-start router of the FoG ring. Same state,
/// same policies — grove-start selection is replica selection with
/// groves as the targets.
pub type Router = ShardRouter;

impl ShardRouter {
    pub fn new(policy: RouterPolicy, n_targets: usize, seed: u64) -> ShardRouter {
        assert!(n_targets > 0, "router needs at least one target");
        ShardRouter {
            policy,
            n_targets,
            seed,
            rr_next: AtomicU64::new(0),
            tie_next: AtomicU64::new(0),
            in_flight: (0..n_targets).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn n_targets(&self) -> usize {
        self.n_targets
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Pick the target for input `index`.
    pub fn route(&self, index: u64) -> usize {
        match self.policy {
            RouterPolicy::Random => {
                let mut rng =
                    Rng::new(self.seed ^ index.wrapping_mul(0x9E3779B97F4A7C15));
                rng.gen_range(self.n_targets)
            }
            RouterPolicy::RoundRobin => {
                (self.rr_next.fetch_add(1, Ordering::Relaxed) % self.n_targets as u64)
                    as usize
            }
            RouterPolicy::LeastLoaded => {
                // Strict-minimum scan from a rotating start offset: ties
                // resolve to the first tied target at/after the offset,
                // so an all-idle fleet degrades to round-robin instead of
                // pinning target 0.
                let n = self.n_targets;
                let start =
                    (self.tie_next.fetch_add(1, Ordering::Relaxed) % n as u64) as usize;
                let mut best = start;
                let mut best_load = self.in_flight[start].load(Ordering::Relaxed);
                for k in 1..n {
                    let i = (start + k) % n;
                    let load = self.in_flight[i].load(Ordering::Relaxed);
                    if load < best_load {
                        best = i;
                        best_load = load;
                    }
                }
                best
            }
        }
    }

    pub fn note_injected(&self, target: usize) {
        self.in_flight[target].fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_completed(&self, target: usize) {
        self.in_flight[target].fetch_sub(1, Ordering::Relaxed);
    }

    /// Load-imbalance metric: max/mean of a per-target assignment count.
    pub fn imbalance(counts: &[u64]) -> f64 {
        if counts.is_empty() {
            return 0.0;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let mean = counts.iter().sum::<u64>() as f64 / counts.len() as f64;
        if mean == 0.0 {
            0.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_is_uniform() {
        let r = Router::new(RouterPolicy::RoundRobin, 4, 0);
        let mut counts = vec![0u64; 4];
        for i in 0..400 {
            counts[r.route(i)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
        assert!((Router::imbalance(&counts) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn random_matches_algorithm2_stream() {
        // Must be the exact stream evaluate()/RingSim/FogServer use.
        let r = Router::new(RouterPolicy::Random, 8, 42);
        for i in 0..50u64 {
            let mut rng = Rng::new(42 ^ i.wrapping_mul(0x9E3779B97F4A7C15));
            assert_eq!(r.route(i), rng.gen_range(8));
        }
    }

    #[test]
    fn random_is_roughly_balanced() {
        let r = Router::new(RouterPolicy::Random, 8, 7);
        let mut counts = vec![0u64; 8];
        for i in 0..8000 {
            counts[r.route(i)] += 1;
        }
        assert!(Router::imbalance(&counts) < 1.15, "{counts:?}");
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let r = Router::new(RouterPolicy::LeastLoaded, 3, 0);
        r.note_injected(0);
        r.note_injected(0);
        r.note_injected(1);
        // Loads [2, 1, 0]: target 2 is the unique minimum.
        assert_eq!(r.route(0), 2);
        r.note_injected(2);
        r.note_injected(2);
        // Loads [2, 1, 2]: target 1 is the unique minimum.
        assert_eq!(r.route(1), 1);
    }

    #[test]
    fn least_loaded_ties_rotate() {
        // Regression: an all-idle fleet must not pin target 0. With no
        // in-flight updates every route call is a full tie; the rotating
        // offset must spread them round-robin.
        let n = 5usize;
        let r = Router::new(RouterPolicy::LeastLoaded, n, 0);
        let mut counts = vec![0u64; n];
        for i in 0..(100 * n as u64) {
            counts[r.route(i)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100), "idle ties must rotate: {counts:?}");
        assert!((Router::imbalance(&counts) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn least_loaded_balances_steady_state() {
        // Inject/complete churn with FIFO completions: no target may be
        // starved, and the assignment stays near-uniform.
        let n = 4usize;
        let r = Router::new(RouterPolicy::LeastLoaded, n, 0);
        let mut counts = vec![0u64; n];
        let mut in_flight = std::collections::VecDeque::new();
        for i in 0..4000u64 {
            let t = r.route(i);
            counts[t] += 1;
            r.note_injected(t);
            in_flight.push_back(t);
            if in_flight.len() > 2 * n {
                r.note_completed(in_flight.pop_front().unwrap());
            }
        }
        assert!(counts.iter().all(|&c| c > 0), "starved target: {counts:?}");
        assert!(Router::imbalance(&counts) < 1.1, "{counts:?}");
    }
}
