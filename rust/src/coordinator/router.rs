//! Request routing policies for the serving front-end.
//!
//! Algorithm 2 starts each input at a *random* grove "to avoid bias"
//! (line 3) — that is the paper-faithful default and the one every parity
//! test uses. A deployment may prefer other policies; this module
//! provides the standard three and measures their load-balance effect
//! (used by the `ablate` experiment).

use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

/// Start-grove selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Per-input deterministic random stream (Algorithm 2 line 3).
    Random,
    /// Strict rotation.
    RoundRobin,
    /// Fewest in-flight items (greedy least-loaded).
    LeastLoaded,
}

/// Router state shared with the injection loop.
pub struct Router {
    policy: RouterPolicy,
    n_groves: usize,
    seed: u64,
    rr_next: AtomicU64,
    /// In-flight per grove (maintained by the caller on inject/complete).
    pub in_flight: Vec<AtomicU64>,
}

impl Router {
    pub fn new(policy: RouterPolicy, n_groves: usize, seed: u64) -> Router {
        Router {
            policy,
            n_groves,
            seed,
            rr_next: AtomicU64::new(0),
            in_flight: (0..n_groves).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Pick the start grove for input `index`.
    pub fn route(&self, index: u64) -> usize {
        match self.policy {
            RouterPolicy::Random => {
                let mut rng =
                    Rng::new(self.seed ^ index.wrapping_mul(0x9E3779B97F4A7C15));
                rng.gen_range(self.n_groves)
            }
            RouterPolicy::RoundRobin => {
                (self.rr_next.fetch_add(1, Ordering::Relaxed) % self.n_groves as u64) as usize
            }
            RouterPolicy::LeastLoaded => self
                .in_flight
                .iter()
                .enumerate()
                .min_by_key(|(_, c)| c.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .unwrap_or(0),
        }
    }

    pub fn note_injected(&self, grove: usize) {
        self.in_flight[grove].fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_completed(&self, grove: usize) {
        self.in_flight[grove].fetch_sub(1, Ordering::Relaxed);
    }

    /// Load-imbalance metric: max/mean of a per-grove assignment count.
    pub fn imbalance(counts: &[u64]) -> f64 {
        if counts.is_empty() {
            return 0.0;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let mean = counts.iter().sum::<u64>() as f64 / counts.len() as f64;
        if mean == 0.0 {
            0.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_is_uniform() {
        let r = Router::new(RouterPolicy::RoundRobin, 4, 0);
        let mut counts = vec![0u64; 4];
        for i in 0..400 {
            counts[r.route(i)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
        assert!((Router::imbalance(&counts) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn random_matches_algorithm2_stream() {
        // Must be the exact stream evaluate()/RingSim/FogServer use.
        let r = Router::new(RouterPolicy::Random, 8, 42);
        for i in 0..50u64 {
            let mut rng = Rng::new(42 ^ i.wrapping_mul(0x9E3779B97F4A7C15));
            assert_eq!(r.route(i), rng.gen_range(8));
        }
    }

    #[test]
    fn random_is_roughly_balanced() {
        let r = Router::new(RouterPolicy::Random, 8, 7);
        let mut counts = vec![0u64; 8];
        for i in 0..8000 {
            counts[r.route(i)] += 1;
        }
        assert!(Router::imbalance(&counts) < 1.15, "{counts:?}");
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let r = Router::new(RouterPolicy::LeastLoaded, 3, 0);
        r.note_injected(0);
        r.note_injected(0);
        r.note_injected(1);
        assert_eq!(r.route(0), 2);
        r.note_completed(0);
        r.note_completed(0);
        assert_eq!(r.route(1), 0);
    }
}
