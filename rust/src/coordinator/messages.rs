//! Message types flowing through the serving ring.

use std::time::Instant;

/// A classification request from a client.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub features: Vec<f32>,
}

/// A completed classification.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub label: usize,
    /// Normalized probability distribution at stop time.
    pub prob: Vec<f32>,
    /// Groves that contributed.
    pub hops: usize,
    /// Wall-clock service latency.
    pub latency_us: u64,
}

/// Ring channel message: work, or a shutdown sentinel. The sentinel is
/// needed because ring workers hold `Sender`s to each other, so the
/// channels never disconnect on their own — the server broadcasts
/// `Shutdown` to every worker at teardown.
#[derive(Clone, Debug)]
pub enum Msg {
    Work(WorkItem),
    Shutdown,
}

/// An in-flight item moving around the ring (the Γ-word of the hardware:
/// hops + payload + probability array).
#[derive(Clone, Debug)]
pub struct WorkItem {
    pub id: u64,
    pub features: Vec<f32>,
    /// Running probability *sum* (one mass unit per grove so far).
    pub prob_sum: Vec<f32>,
    pub hops: u32,
    pub injected: Instant,
    /// Last normalized distribution (scratch reused between hop and
    /// response to avoid recomputation).
    pub scratch_norm: Vec<f32>,
}

impl WorkItem {
    pub fn fresh(req: Request, n_classes: usize) -> WorkItem {
        WorkItem {
            id: req.id,
            features: req.features,
            prob_sum: vec![0.0; n_classes],
            hops: 0,
            injected: Instant::now(),
            scratch_norm: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_item_zeroed() {
        let w = WorkItem::fresh(Request { id: 7, features: vec![1.0, 2.0] }, 3);
        assert_eq!(w.id, 7);
        assert_eq!(w.prob_sum, vec![0.0; 3]);
        assert_eq!(w.hops, 0);
    }
}
