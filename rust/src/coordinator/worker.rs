//! Grove worker: one thread per grove, draining its queue in dynamic
//! batches, gating on confidence, forwarding the unconfident to the next
//! grove (the software twin of the hardware tile in `uarch::ring`).

use super::accel::AccelHandle;
use super::messages::{Msg, Response};
use super::metrics::Metrics;
use crate::fog::confidence::max_diff;
use crate::fog::Grove;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

/// How a worker evaluates its grove.
pub enum EvalBackend {
    /// Walk the flat trees directly in this thread.
    Native(Grove),
    /// Ship batches to the PJRT accelerator thread.
    Accel { handle: AccelHandle, grove: Grove, grove_idx: usize },
}

impl EvalBackend {
    fn n_classes(&self) -> usize {
        match self {
            EvalBackend::Native(g) => g.n_classes,
            EvalBackend::Accel { grove, .. } => grove.n_classes,
        }
    }
}

/// Worker configuration.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub threshold: f32,
    pub max_hops: usize,
    /// Max items per evaluation batch.
    pub batch_size: usize,
    /// How long to wait for more items once one is in hand.
    pub batch_timeout: Duration,
}

/// Worker main loop. Exits when the inbound channel disconnects.
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    backend: EvalBackend,
    rx: Receiver<Msg>,
    next: Sender<Msg>,
    responses: Sender<Response>,
    metrics: Arc<Metrics>,
    cfg: WorkerConfig,
) {
    let n_classes = backend.n_classes();
    loop {
        // Block for the first item.
        let first = match rx.recv() {
            Ok(Msg::Work(item)) => item,
            Ok(Msg::Shutdown) | Err(_) => return, // server shut down
        };
        // Opportunistically batch more items.
        let mut batch = vec![first];
        while batch.len() < cfg.batch_size {
            match rx.recv_timeout(cfg.batch_timeout) {
                Ok(Msg::Work(item)) => batch.push(item),
                Ok(Msg::Shutdown) => return,
                Err(_) => break,
            }
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.evals.fetch_add(batch.len() as u64, Ordering::Relaxed);

        // Evaluate the batch.
        let confs: Vec<f32> = match &backend {
            EvalBackend::Native(grove) => batch
                .iter_mut()
                .map(|item| {
                    grove.accumulate_proba(&item.features, &mut item.prob_sum);
                    item.hops += 1;
                    let inv = 1.0 / item.hops as f32;
                    let norm: Vec<f32> =
                        item.prob_sum.iter().map(|p| p * inv).collect();
                    let c = max_diff(&norm);
                    item.scratch_norm = norm;
                    c
                })
                .collect(),
            EvalBackend::Accel { handle, grove_idx, grove } => {
                let n = batch.len();
                let f = grove.n_features;
                let mut x = Vec::with_capacity(n * f);
                let mut prob = Vec::with_capacity(n * n_classes);
                let mut hops = Vec::with_capacity(n);
                for item in &batch {
                    x.extend_from_slice(&item.features);
                    prob.extend_from_slice(&item.prob_sum);
                    hops.push((item.hops + 1) as f32);
                }
                match handle.step(*grove_idx, x, prob, hops) {
                    Ok(out) => {
                        for (i, item) in batch.iter_mut().enumerate() {
                            item.hops += 1;
                            item.prob_sum
                                .copy_from_slice(&out.new_sum[i * n_classes..(i + 1) * n_classes]);
                            item.scratch_norm =
                                out.norm[i * n_classes..(i + 1) * n_classes].to_vec();
                        }
                        out.conf
                    }
                    Err(e) => {
                        eprintln!("accel error: {e}; falling back to native");
                        batch
                            .iter_mut()
                            .map(|item| {
                                grove.accumulate_proba(&item.features, &mut item.prob_sum);
                                item.hops += 1;
                                let inv = 1.0 / item.hops as f32;
                                let norm: Vec<f32> =
                                    item.prob_sum.iter().map(|p| p * inv).collect();
                                let c = max_diff(&norm);
                                item.scratch_norm = norm;
                                c
                            })
                            .collect()
                    }
                }
            }
        };

        // Route each item: respond or forward.
        for (item, conf) in batch.into_iter().zip(confs) {
            let done = conf >= cfg.threshold || item.hops as usize >= cfg.max_hops;
            if done {
                let label = crate::util::argmax(&item.scratch_norm);
                metrics.responses.fetch_add(1, Ordering::Relaxed);
                metrics.hops_total.fetch_add(item.hops as u64, Ordering::Relaxed);
                let _ = responses.send(Response {
                    id: item.id,
                    label,
                    prob: item.scratch_norm,
                    hops: item.hops as usize,
                    latency_us: item.injected.elapsed().as_micros() as u64,
                });
            } else {
                metrics.forwards.fetch_add(1, Ordering::Relaxed);
                if next.send(Msg::Work(item)).is_err() {
                    return; // ring torn down
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Worker behaviour is covered end-to-end in `server.rs` tests (the
    // worker loop needs the full ring plumbing to exercise).
}
