//! Grove worker: one thread per grove, draining its queue in dynamic
//! batches, gating on confidence, forwarding the unconfident to the next
//! grove (the software twin of the hardware tile in `uarch::ring`).
//!
//! Evaluation is dispatched through the [`GroveBackend`] trait object —
//! the worker loop itself contains no backend- or model-type match arms,
//! so new evaluation backends plug in without touching routing logic.

use super::accel::AccelHandle;
use super::messages::{Msg, Response, WorkItem};
use super::metrics::Metrics;
use crate::fog::confidence::max_diff;
use crate::fog::Grove;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

/// One hop's evaluation for a batch of in-flight items: accumulate this
/// grove's probabilities into each item, bump its hop count, refresh its
/// normalized distribution, and return the per-item MaxDiff confidence.
pub trait GroveBackend: Send {
    fn n_classes(&self) -> usize;

    fn step_batch(&self, batch: &mut [WorkItem]) -> Vec<f32>;
}

/// Walk the grove's arena slice directly on the worker thread (pure-rust
/// hot path).
pub struct NativeGrove(pub Grove);

/// Shared by the native backend and the accelerator fallback path: one
/// hop for the whole batch through the grove's level-synchronous arena
/// tile kernel. Per-item results are bit-identical to a per-item
/// `accumulate_proba` walk (same per-tree accumulation order).
///
/// The batch is packed into contiguous `x`/`acc` buffers per hop — a
/// deliberate copy (n·(f+2c) floats, a few KB at serving batch sizes)
/// that buys the tile kernel's contiguous level-major traversal; item
/// features stay owned by the `WorkItem` because they keep circulating
/// the ring.
fn native_step(grove: &Grove, batch: &mut [WorkItem]) -> Vec<f32> {
    let n = batch.len();
    let f = grove.n_features;
    let c = grove.n_classes;
    let mut x = Vec::with_capacity(n * f);
    let mut acc = Vec::with_capacity(n * c);
    for item in batch.iter() {
        x.extend_from_slice(&item.features);
        acc.extend_from_slice(&item.prob_sum);
    }
    grove.accumulate_proba_tile(&x, n, &mut acc);
    batch
        .iter_mut()
        .enumerate()
        .map(|(i, item)| {
            item.prob_sum.copy_from_slice(&acc[i * c..(i + 1) * c]);
            item.hops += 1;
            let inv = 1.0 / item.hops as f32;
            let norm: Vec<f32> = item.prob_sum.iter().map(|p| p * inv).collect();
            let conf = max_diff(&norm);
            item.scratch_norm = norm;
            conf
        })
        .collect()
}

impl GroveBackend for NativeGrove {
    fn n_classes(&self) -> usize {
        self.0.n_classes
    }

    fn step_batch(&self, batch: &mut [WorkItem]) -> Vec<f32> {
        native_step(&self.0, batch)
    }
}

/// Ship batches to the PJRT accelerator thread; fall back to the native
/// walk when the accelerator errors.
pub struct AccelGrove {
    pub handle: AccelHandle,
    pub grove: Grove,
    pub grove_idx: usize,
}

impl GroveBackend for AccelGrove {
    fn n_classes(&self) -> usize {
        self.grove.n_classes
    }

    fn step_batch(&self, batch: &mut [WorkItem]) -> Vec<f32> {
        let n = batch.len();
        let f = self.grove.n_features;
        let c = self.grove.n_classes;
        let mut x = Vec::with_capacity(n * f);
        let mut prob = Vec::with_capacity(n * c);
        let mut hops = Vec::with_capacity(n);
        for item in batch.iter() {
            x.extend_from_slice(&item.features);
            prob.extend_from_slice(&item.prob_sum);
            hops.push((item.hops + 1) as f32);
        }
        match self.handle.step(self.grove_idx, x, prob, hops) {
            Ok(out) => {
                for (i, item) in batch.iter_mut().enumerate() {
                    item.hops += 1;
                    item.prob_sum.copy_from_slice(&out.new_sum[i * c..(i + 1) * c]);
                    item.scratch_norm = out.norm[i * c..(i + 1) * c].to_vec();
                }
                out.conf
            }
            Err(e) => {
                eprintln!("accel error: {e}; falling back to native");
                native_step(&self.grove, batch)
            }
        }
    }
}

/// Worker configuration.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub threshold: f32,
    pub max_hops: usize,
    /// Max items per evaluation batch.
    pub batch_size: usize,
    /// How long to wait for more items once one is in hand.
    pub batch_timeout: Duration,
}

/// Worker main loop. Exits when the inbound channel disconnects.
pub fn run_worker(
    backend: Box<dyn GroveBackend>,
    rx: Receiver<Msg>,
    next: Sender<Msg>,
    responses: Sender<Response>,
    metrics: Arc<Metrics>,
    cfg: WorkerConfig,
) {
    loop {
        // Block for the first item.
        let first = match rx.recv() {
            Ok(Msg::Work(item)) => item,
            Ok(Msg::Shutdown) | Err(_) => return, // server shut down
        };
        // Opportunistically batch more items.
        let mut batch = vec![first];
        while batch.len() < cfg.batch_size {
            match rx.recv_timeout(cfg.batch_timeout) {
                Ok(Msg::Work(item)) => batch.push(item),
                Ok(Msg::Shutdown) => return,
                Err(_) => break,
            }
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.evals.fetch_add(batch.len() as u64, Ordering::Relaxed);

        // Evaluate the batch through the backend trait object.
        let confs = backend.step_batch(&mut batch);

        // Route each item: respond or forward.
        for (item, conf) in batch.into_iter().zip(confs) {
            let done = conf >= cfg.threshold || item.hops as usize >= cfg.max_hops;
            if done {
                let label = crate::util::argmax(&item.scratch_norm);
                metrics.responses.fetch_add(1, Ordering::Relaxed);
                metrics.hops_total.fetch_add(item.hops as u64, Ordering::Relaxed);
                let _ = responses.send(Response {
                    id: item.id,
                    label,
                    prob: item.scratch_norm,
                    hops: item.hops as usize,
                    latency_us: item.injected.elapsed().as_micros() as u64,
                });
            } else {
                metrics.forwards.fetch_add(1, Ordering::Relaxed);
                if next.send(Msg::Work(item)).is_err() {
                    return; // ring torn down
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest};
    use std::time::Instant;

    #[test]
    fn native_backend_one_hop_normalizes() {
        let ds = generate(&DatasetProfile::demo(), 211);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 1);
        let fog = crate::fog::FieldOfGroves::from_forest(&rf, 4);
        let backend = NativeGrove(fog.groves[0].clone());
        let mut batch = vec![WorkItem {
            id: 0,
            features: ds.test.row(0).to_vec(),
            prob_sum: vec![0.0; backend.n_classes()],
            hops: 0,
            injected: Instant::now(),
            scratch_norm: Vec::new(),
        }];
        let confs = backend.step_batch(&mut batch);
        assert_eq!(confs.len(), 1);
        assert_eq!(batch[0].hops, 1);
        let sum: f32 = batch[0].scratch_norm.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "norm sums to {sum}");
        assert!((max_diff(&batch[0].scratch_norm) - confs[0]).abs() < 1e-6);
    }

    // Ring behaviour is covered end-to-end in `server.rs` tests (the
    // worker loop needs the full ring plumbing to exercise).
}
