//! [`ShardedServer`] — the scale-out serving tier: N replicas of one
//! registry model behind a shared [`ShardRouter`] and a quantized
//! [`ProbCache`].
//!
//! The paper argues energy-per-classification at scale (§1: "millions of
//! classifications per day"); a single [`ModelServer`](super::ModelServer)
//! queue is the wrong shape for that traffic. This tier runs **N
//! replicas** — each its own job queue plus worker pool over a *shared*
//! `Arc<dyn Classifier>` (replicas clone the handle, never the trees:
//! tree-family models keep one [`ForestArena`](crate::exec::ForestArena)
//! allocation however many replicas serve it) — behind two front-end
//! stages:
//!
//! 1. **Cache** — each request row is quantized
//!    ([`ProbCache::key`]) and looked up before any queue is touched; a
//!    hit answers immediately with zero evaluation energy (`hops = 0`).
//!    At quantization step 0 hits are exact-bit matches, so cached
//!    answers are byte-identical to cold evaluation.
//! 2. **Router** — misses are routed to a replica by the shared
//!    [`ShardRouter`] (`Random`, `RoundRobin`, or `LeastLoaded` over the
//!    live in-flight gauges), enqueued, and batch-evaluated by that
//!    replica's workers through the replica's execution backend
//!    ([`crate::exec::Backend`], `software | uarch`); workers fill the
//!    cache on completion and fold the backend's per-tile
//!    [`ExecReport`](crate::exec::ExecReport) (simulated cycles,
//!    nanojoules) into the replica's [`Metrics`].
//!
//! Request path (see `ARCHITECTURE.md` at the repo root for the full
//! stack):
//!
//! ```text
//! classify(x) ──► ProbCache ──hit──► Response (hops = 0)
//!                   │ miss
//!                   ▼
//!               ShardRouter ──► replica queue ──► worker batch
//!                                                   │
//!                                    exec::Backend (software | uarch)
//!                                                   │
//!                     cache fill ◄── ProbMatrix ◄───┤
//!                                     ExecReport ───┴──► Metrics
//! ```
//!
//! Every replica is batch-composition independent (the arena kernel and
//! `batch_from_scores` evaluate rows independently; FoG start groves
//! hash the input content), so a sharded server returns byte-identical
//! probability rows to a single `ModelServer` — the conformance suite in
//! `tests/shard.rs` pins this for every registry model.

use super::cache::{CacheConfig, ProbCache};
use super::messages::Response;
use super::metrics::{Metrics, MetricsSnapshot};
use super::model_server::{Job, ModelServerConfig, Replica};
use super::router::{RouterPolicy, ShardRouter};
use crate::api::spec::ServingSpec;
use crate::api::Classifier;
use crate::util::error::Result;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Instant;

/// Configuration for a sharded multi-replica server.
#[derive(Clone, Debug)]
pub struct ShardedServerConfig {
    /// Model replicas, each with its own queue and worker pool.
    pub replicas: usize,
    /// Per-replica queue/batch/worker settings.
    pub worker: ModelServerConfig,
    /// Replica-selection policy.
    pub router: RouterPolicy,
    /// Seed for the `Random` policy's per-request stream.
    pub router_seed: u64,
    /// Result cache; `None` serves every request cold.
    pub cache: Option<CacheConfig>,
}

impl Default for ShardedServerConfig {
    fn default() -> Self {
        ShardedServerConfig {
            replicas: 2,
            worker: ModelServerConfig::default(),
            router: RouterPolicy::LeastLoaded,
            router_seed: 0,
            cache: None,
        }
    }
}

impl ShardedServerConfig {
    /// Build from the serving knobs a [`ServingSpec`] carries (the
    /// `ModelSpec` builder surface: replicas, router policy, cache
    /// quantization).
    pub fn for_serving(s: &ServingSpec) -> ShardedServerConfig {
        // Capacity 0 means caching off entirely (no dead cache paying
        // key quantization and a guaranteed miss per request).
        let cache = match s.cache_quant {
            Some(q) if s.cache_capacity > 0 => Some(CacheConfig {
                capacity: s.cache_capacity,
                quant_step: q,
                ..Default::default()
            }),
            _ => None,
        };
        ShardedServerConfig {
            replicas: s.replicas.max(1),
            worker: ModelServerConfig { backend: s.backend, ..Default::default() },
            router: s.router,
            router_seed: 0,
            cache,
        }
    }
}

/// A running sharded classification service over one trained model.
pub struct ShardedServer {
    replicas: Vec<Replica>,
    resp_rx: Receiver<Response>,
    router: Arc<ShardRouter>,
    cache: Option<Arc<ProbCache>>,
    /// Front-end counters: total requests, cache hits/misses, and the
    /// responses answered from cache (replica counters live per replica).
    front: Arc<Metrics>,
    n_features: usize,
    next_id: u64,
}

impl ShardedServer {
    /// Spin up `cfg.replicas` replicas serving `model`. Replicas share
    /// the model storage (the `Arc` is cloned, not the model), the
    /// response channel, the router and the cache.
    pub fn start(model: Arc<dyn Classifier>, cfg: &ShardedServerConfig) -> ShardedServer {
        let n_replicas = cfg.replicas.max(1);
        let (resp_tx, resp_rx) = channel::<Response>();
        let router = Arc::new(ShardRouter::new(cfg.router, n_replicas, cfg.router_seed));
        // A zero-capacity cache config means caching off, not a cache
        // that misses every lookup. Quantized models hand the cache their
        // arena's rank tables so request rows are coded once, with the
        // same per-feature codes the kernel compares on. Adaptive models
        // tag every key with their threshold's bit pattern: rows computed
        // under one early-exit threshold must never answer a request at
        // another (full evaluation keeps tag 0 and shares rows, which is
        // safe — t = 1.0 is byte-identical to no knob at all).
        let cache = cfg.cache.as_ref().filter(|c| c.capacity > 0).map(|c| {
            Arc::new(
                ProbCache::new(c)
                    .with_tables(model.quant_tables())
                    .with_tag(model.adaptive_conf().map_or(0, |t| t.to_bits() as u64)),
            )
        });
        let n_features = model.n_features();
        let replicas = (0..n_replicas)
            .map(|r| {
                Replica::start(
                    Arc::clone(&model),
                    &cfg.worker,
                    resp_tx.clone(),
                    cache.clone(),
                    Some((Arc::clone(&router), r)),
                    &format!("shard-replica-{r}"),
                )
            })
            .collect();
        let front = Arc::new(Metrics::default());
        // Record the model's vector dispatch and gather levels once, on
        // the front-end gauges: every replica clones the same model, so
        // the per-replica levels are identical by construction.
        front.record_simd_level(model.simd_level());
        front.record_gather_level(model.gather_level());
        ShardedServer { replicas, resp_rx, router, cache, front, n_features, next_id: 0 }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Classify a row-major batch; returns responses in input order, or
    /// a friendly error when the batch is ragged. Each row is answered
    /// from the cache when possible, otherwise routed to a replica.
    pub fn classify(&mut self, x: &[f32]) -> Result<Vec<Response>> {
        let f = self.n_features;
        let n = super::model_server::check_aligned(x.len(), f)?;
        let base_id = self.next_id;
        self.next_id += n as u64;
        let mut responses: Vec<Option<Response>> = (0..n).map(|_| None).collect();
        let mut pending = 0usize;
        for i in 0..n {
            let id = base_id + i as u64;
            let row = &x[i * f..(i + 1) * f];
            self.front.requests.fetch_add(1, Ordering::Relaxed);
            let cache_key = match &self.cache {
                Some(cache) => {
                    let key = cache.key(row);
                    if let Some(prob) = cache.get(&key) {
                        // Cache hit: answer without touching any queue.
                        // `hops = 0` — no grove/model evaluation energy
                        // was spent on this response.
                        self.front.cache_hits.fetch_add(1, Ordering::Relaxed);
                        self.front.responses.fetch_add(1, Ordering::Relaxed);
                        let label = crate::util::argmax(&prob);
                        responses[i] =
                            Some(Response { id, label, prob, hops: 0, latency_us: 0 });
                        continue;
                    }
                    self.front.cache_misses.fetch_add(1, Ordering::Relaxed);
                    Some(key)
                }
                None => None,
            };
            let r = self.router.route(id);
            self.router.note_injected(r);
            self.replicas[r].send(Job {
                id,
                features: row.to_vec(),
                enqueued: Instant::now(),
                cache_key,
            });
            pending += 1;
        }
        Ok(super::model_server::collect_in_order(&self.resp_rx, responses, base_id, pending))
    }

    /// Front-end counters (requests, cache hits/misses, cache-answered
    /// responses).
    pub fn metrics(&self) -> &Metrics {
        &self.front
    }

    /// Per-replica counters (requests routed, batches, evals, responses).
    pub fn replica_metrics(&self, r: usize) -> &Metrics {
        &self.replicas[r].metrics
    }

    /// The shared replica router (in-flight gauges are live).
    pub fn router(&self) -> &ShardRouter {
        &self.router
    }

    /// The shared result cache, when configured.
    pub fn cache(&self) -> Option<&ProbCache> {
        self.cache.as_deref()
    }

    /// One merged snapshot: front-end counters plus the saturating sum
    /// of every replica's worker-side counters (so `responses` covers
    /// both cached and evaluated answers, and the `exec_*` aggregates
    /// carry the fleet's hardware-in-the-loop cycle/energy totals).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut total = self.front.snapshot();
        for replica in &self.replicas {
            total.merge_worker(&replica.metrics.snapshot());
        }
        total
    }

    /// Drop every queue (workers exit on disconnect) and join them.
    pub fn shutdown(mut self) {
        for replica in &mut self.replicas {
            replica.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Estimator, ModelSpec};
    use crate::data::synthetic::{generate, DatasetProfile};

    fn model(name: &str, seed: u64) -> (Arc<dyn Classifier>, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 600 + seed);
        let spec = ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
            .unwrap()
            .fast();
        (Arc::from(spec.fit(&ds.train, seed)), ds)
    }

    #[test]
    fn sharded_matches_offline_predictions() {
        let (m, ds) = model("rf", 31);
        let offline = m.predict_proba_batch(&ds.test.x, ds.test.len());
        let cfg = ShardedServerConfig { replicas: 3, ..Default::default() };
        let mut server = ShardedServer::start(Arc::clone(&m), &cfg);
        let responses = server.classify(&ds.test.x).expect("aligned batch");
        assert_eq!(responses.len(), ds.test.len());
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(&r.prob[..], offline.row(i), "row {i} prob drifted");
        }
        let snap = server.snapshot();
        assert_eq!(snap.requests as usize, ds.test.len());
        assert_eq!(snap.responses as usize, ds.test.len());
        server.shutdown();
    }

    #[test]
    fn cache_answers_repeat_rows_identically() {
        let (m, ds) = model("svm_lr", 32);
        let cfg = ShardedServerConfig {
            replicas: 2,
            cache: Some(CacheConfig::default()), // quant_step 0 = exact
            ..Default::default()
        };
        let mut server = ShardedServer::start(m, &cfg);
        let cold = server.classify(&ds.test.x).expect("aligned");
        let warm = server.classify(&ds.test.x).expect("aligned");
        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.label, w.label);
            assert_eq!(c.prob, w.prob, "cached row differs from cold evaluation");
            assert_eq!(w.hops, 0, "second pass should be all cache hits");
        }
        let snap = server.metrics().snapshot();
        assert_eq!(snap.cache_hits as usize, ds.test.len());
        assert!(snap.cache_hit_rate() > 0.49 && snap.cache_hit_rate() < 0.51);
        server.shutdown();
    }

    #[test]
    fn every_replica_sees_traffic_under_least_loaded() {
        let (m, ds) = model("rf", 33);
        let cfg = ShardedServerConfig {
            replicas: 4,
            router: RouterPolicy::LeastLoaded,
            ..Default::default()
        };
        let mut server = ShardedServer::start(m, &cfg);
        // Several passes so even fast-draining replicas accumulate work.
        for _ in 0..3 {
            server.classify(&ds.test.x).expect("aligned");
        }
        for r in 0..server.n_replicas() {
            let evals = server.replica_metrics(r).snapshot().evals;
            assert!(evals > 0, "replica {r} starved under LeastLoaded ties");
        }
        server.shutdown();
    }

    #[test]
    fn zero_capacity_spec_disables_cache() {
        // `with_cache_capacity(0)` documents "disables caching outright".
        let spec = crate::api::ModelSpec::by_name("rf")
            .unwrap()
            .with_cache_quant(0.0)
            .with_cache_capacity(0);
        let cfg = ShardedServerConfig::for_serving(&spec.serving);
        assert!(cfg.cache.is_none());
        // And a hand-built zero-capacity config is normalized off too.
        let (m, _) = model("svm_lr", 35);
        let server = ShardedServer::start(
            m,
            &ShardedServerConfig {
                cache: Some(CacheConfig { capacity: 0, ..Default::default() }),
                ..Default::default()
            },
        );
        assert!(server.cache().is_none());
        server.shutdown();
    }

    #[test]
    fn uarch_fleet_matches_software_fleet_and_reports_energy() {
        use crate::api::BackendKind;
        let (m, ds) = model("fog_opt", 36);
        let serve = |backend: BackendKind| {
            let cfg = ShardedServerConfig {
                replicas: 2,
                worker: ModelServerConfig { backend, ..Default::default() },
                ..Default::default()
            };
            let mut server = ShardedServer::start(Arc::clone(&m), &cfg);
            let responses = server.classify(&ds.test.x).expect("aligned batch");
            let snap = server.snapshot();
            server.shutdown();
            (responses, snap)
        };
        let (sw, _) = serve(BackendKind::Software);
        let (ua, snap) = serve(BackendKind::Uarch);
        for (a, b) in sw.iter().zip(&ua) {
            assert_eq!(a.prob, b.prob, "uarch replica answer diverged from software");
        }
        assert_eq!(snap.exec_samples as usize, ds.test.len());
        assert!(snap.energy_per_class_nj() > 0.0, "fleet reported no live energy");
        assert!(snap.cycles_per_class() > 0.0);
        assert!(snap.comparator_ops_per_class() > 0.0);
    }

    #[test]
    fn no_cache_flag_equals_zero_capacity() {
        // Satellite boundary: a spec with caching never enabled
        // (`--no-cache`: cache_quant stays None) and a spec with an
        // explicit zero entry budget must produce the same cache-less
        // serving config.
        let never = crate::api::ModelSpec::by_name("rf").unwrap();
        assert!(ShardedServerConfig::for_serving(&never.serving).cache.is_none());
        let zero_cap = crate::api::ModelSpec::by_name("rf")
            .unwrap()
            .with_cache_quant(0.0)
            .with_cache_capacity(0);
        assert!(ShardedServerConfig::for_serving(&zero_cap.serving).cache.is_none());
    }

    #[test]
    fn ragged_batch_is_a_friendly_error() {
        let (m, ds) = model("svm_lr", 34);
        let mut server = ShardedServer::start(m, &ShardedServerConfig::default());
        let err = server
            .classify(&ds.test.x[..ds.n_features() + 1])
            .expect_err("ragged batch must not panic");
        assert!(err.to_string().contains("ragged batch"));
        let ok = server.classify(&ds.test.x[..ds.n_features()]).expect("aligned");
        assert_eq!(ok.len(), 1);
        server.shutdown();
    }
}
