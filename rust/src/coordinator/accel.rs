//! The accelerator proxy: one dedicated thread owning every PJRT
//! executable (PJRT handles are thread-affine in the `xla` crate), fed
//! by grove workers through a channel — the software analogue of "one
//! accelerator, many queues".

use crate::dt::export::{sanitize_inf, FlatBundle};
use crate::fog::FieldOfGroves;
use crate::runtime::{GroveStepExec, Manifest, Runtime, StepOutput};
use crate::util::error::Result;
use std::path::PathBuf;
use std::sync::mpsc;

/// One batched grove-step evaluation request.
pub struct AccelRequest {
    pub grove_idx: usize,
    pub x: Vec<f32>,
    pub prob_sum: Vec<f32>,
    pub hops: Vec<f32>,
    pub reply: mpsc::Sender<Result<StepOutput>>,
}

/// Cloneable handle to the accelerator thread.
#[derive(Clone)]
pub struct AccelHandle {
    tx: mpsc::Sender<AccelRequest>,
}

impl AccelHandle {
    /// Synchronous round trip: evaluate one batch on `grove_idx`.
    pub fn step(
        &self,
        grove_idx: usize,
        x: Vec<f32>,
        prob_sum: Vec<f32>,
        hops: Vec<f32>,
    ) -> Result<StepOutput> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(AccelRequest { grove_idx, x, prob_sum, hops, reply })
            .map_err(|_| crate::err!("accelerator thread gone"))?;
        rx.recv().map_err(|_| crate::err!("accelerator dropped reply"))?
    }
}

/// Spawn the accelerator thread for `fog`, loading `grove_step` artifacts
/// from `artifacts_dir`. Fails fast (before returning) if the artifacts
/// are missing or shape-incompatible.
pub fn spawn(fog: &FieldOfGroves, artifacts_dir: PathBuf) -> Result<AccelHandle> {
    // Snapshot the grove bundles (the thread owns its own copy).
    let bundles: Vec<FlatBundle> = fog
        .groves
        .iter()
        .map(|g| {
            let mut b = FlatBundle::new(g.trees());
            sanitize_inf(&mut b);
            b
        })
        .collect();
    let (t, depth, f, c) = (
        fog.groves[0].n_trees(),
        fog.depth,
        fog.n_features,
        fog.n_classes,
    );

    let (tx, rx) = mpsc::channel::<AccelRequest>();
    let (init_tx, init_rx) = mpsc::channel::<Result<()>>();

    std::thread::Builder::new()
        .name("fog-accel".into())
        .spawn(move || {
            // Everything PJRT stays on this thread.
            let init = (|| -> Result<Vec<GroveStepExec>> {
                let rt = Runtime::cpu()?;
                let manifest = Manifest::load(&artifacts_dir)?;
                let meta = manifest
                    .find_grove_step(t, depth, f, c)
                    .ok_or_else(|| {
                        crate::err!(
                            "no grove_step artifact for t={t} depth={depth} f={f} c={c}; \
                             run: make artifacts SHAPES=ring:{t},{depth},{f},{c},32"
                        )
                    })?
                    .clone();
                bundles
                    .iter()
                    .map(|b| GroveStepExec::new(&rt, &manifest, &meta, b))
                    .collect()
            })();
            match init {
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                }
                Ok(execs) => {
                    let _ = init_tx.send(Ok(()));
                    while let Ok(req) = rx.recv() {
                        let result =
                            execs[req.grove_idx].step(&req.x, &req.prob_sum, &req.hops);
                        let _ = req.reply.send(result);
                    }
                }
            }
        })
        .expect("spawn accel thread");

    init_rx
        .recv()
        .map_err(|_| crate::err!("accelerator thread died during init"))??;
    Ok(AccelHandle { tx })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest};
    use crate::runtime::artifacts::default_dir;

    #[test]
    fn spawn_fails_cleanly_without_artifacts() {
        let ds = generate(&DatasetProfile::demo(), 191);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 1);
        let fog = crate::fog::FieldOfGroves::from_forest(&rf, 4);
        let r = spawn(&fog, PathBuf::from("/nonexistent/artifacts"));
        assert!(r.is_err());
    }

    #[test]
    fn accel_step_matches_native() {
        let dir = default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping accel test: run `make artifacts`");
            return;
        }
        // Build a fog matching the demo artifact (t=4, depth 6, f=8, c=3).
        let ds = generate(&DatasetProfile::demo(), 192);
        let params = ForestParams {
            n_trees: 8,
            tree: crate::dt::TreeParams { max_depth: 6, ..Default::default() },
            bootstrap: true,
        };
        let rf = RandomForest::fit(&ds.train, &params, 2);
        let mut fog = crate::fog::FieldOfGroves::from_forest(&rf, 4);
        if fog.depth != 6 {
            // Forest happened to train shallower/deeper: repad to 6 only
            // when shallower; skip otherwise (artifact is depth-6).
            if fog.depth > 6 {
                eprintln!("skipping: trained depth {} > artifact 6", fog.depth);
                return;
            }
            fog = fog.repad(6);
        }
        let handle = spawn(&fog, dir).unwrap();
        let n = 8usize;
        let out = handle
            .step(
                0,
                ds.test.x[..n * 8].to_vec(),
                vec![0.0; n * 3],
                vec![1.0; n],
            )
            .unwrap();
        for i in 0..n {
            let native = fog.groves[0].predict_proba(ds.test.row(i));
            for (a, b) in out.norm[i * 3..(i + 1) * 3].iter().zip(&native) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }
}
