//! Serving metrics: lock-free counters updated by workers, a bounded
//! per-batch latency reservoir, and execution-backend aggregates.
//!
//! Paper anchor: these are the deployment-side observables of the §4.2
//! energy claims — `avg_hops` is the Figure-5 x-axis driver (groves
//! consulted per classification), the cache hit/miss counters track how
//! many classifications the sharded tier answered with *zero* grove
//! evaluations, and the `exec_*` counters carry the hardware-in-the-loop
//! [`ExecReport`](crate::exec::ExecReport)s (simulated cycles and
//! nanojoules per classification, §4.2 / Table 1's headline metric) that
//! `fog serve --backend uarch` surfaces live. One `Metrics` instance
//! serves a whole [`super::FogServer`] or [`super::ModelServer`]; a
//! [`super::ShardedServer`] keeps one per replica plus a front-end
//! instance for request/cache accounting, merged with *saturating* adds
//! by [`MetricsSnapshot::merge_worker`].

use crate::exec::ExecReport;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Bounded size of the per-batch latency reservoir; once full, new
/// samples overwrite round-robin so the summary tracks recent traffic.
const BATCH_LATENCY_CAP: usize = 4096;

/// Shared atomic counters.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub hops_total: AtomicU64,
    pub forwards: AtomicU64,
    /// Batches evaluated (per-backend batching effectiveness).
    pub batches: AtomicU64,
    /// Items evaluated (≥ responses; includes re-circulated items).
    pub evals: AtomicU64,
    /// Requests answered straight from the probability cache.
    pub cache_hits: AtomicU64,
    /// Requests that missed the cache and went to a replica queue.
    pub cache_misses: AtomicU64,
    /// Fleet-tier admission outcomes (front-end owned, like `requests`):
    /// requests served by the model they asked for.
    pub fleet_served: AtomicU64,
    /// Requests served by a fallback model after their requested model's
    /// budget was exhausted (`Downgraded{from, to}`).
    pub fleet_downgraded: AtomicU64,
    /// Requests rejected because every admissible model was over budget.
    pub fleet_shed: AtomicU64,
    /// Classifications evaluated through an execution backend.
    pub exec_samples: AtomicU64,
    /// Comparator ops reported by the backend (arena-derived, padded
    /// depth — the Table 1-stable accounting number).
    pub exec_comparator_ops: AtomicU64,
    /// Dead padded levels the ragged software kernel skipped (live-depth
    /// early exit; 0 under the depth-bound μarch backend).
    pub exec_levels_skipped: AtomicU64,
    /// Whole trees the adaptive confidence early exit did not evaluate
    /// (0 with the knob off and for FoG models, whose effort gauge is
    /// `hops_total`). Both backends report the same count — the μarch
    /// forest arm overlays the software kernel's number.
    pub exec_trees_skipped: AtomicU64,
    /// Simulated clock cycles (0 under the software backend).
    pub exec_cycles: AtomicU64,
    /// Simulated dynamic energy in femtojoules (1 fJ = 1e-6 nJ; integer
    /// so workers can accumulate it lock-free).
    pub exec_energy_fj: AtomicU64,
    /// Highest vector ISA rank the serving models dispatch their
    /// quantized kernels to ([`SimdLevel::rank`](crate::exec::SimdLevel)
    /// — 0 = scalar). A gauge, not a counter: recorded once per model at
    /// server start and max-merged across replicas, so recorded
    /// trajectory points stay comparable across hosts.
    pub exec_simd_level: AtomicU64,
    /// Highest index-gather ISA rank the serving models dispatch to
    /// (same rank scale as `exec_simd_level`; 0 = scalar gather stage).
    /// Recorded and merged the same way — once per model at server
    /// start, max across replicas.
    pub exec_gather_level: AtomicU64,
    /// Per-batch evaluation latency samples (µs), bounded reservoir.
    batch_latency_us: Mutex<Vec<u64>>,
    /// Overwrite cursor once the latency reservoir is full.
    latency_ticks: AtomicU64,
}

impl Metrics {
    /// Fold one tile's execution report into the counters. (Cross-replica
    /// aggregation saturates in [`MetricsSnapshot::merge_worker`]; the
    /// per-instance atomics use plain adds — u64 wrap is centuries away
    /// at serving rates.)
    pub fn record_exec(&self, r: &ExecReport) {
        self.exec_samples.fetch_add(r.samples, Ordering::Relaxed);
        self.exec_comparator_ops.fetch_add(r.comparator_ops, Ordering::Relaxed);
        self.exec_levels_skipped.fetch_add(r.levels_skipped, Ordering::Relaxed);
        self.exec_trees_skipped.fetch_add(r.trees_skipped, Ordering::Relaxed);
        self.exec_cycles.fetch_add(r.cycles, Ordering::Relaxed);
        let fj = (r.energy_nj * 1e6).max(0.0).round() as u64;
        self.exec_energy_fj.fetch_add(fj, Ordering::Relaxed);
    }

    /// Record the vector ISA level a serving model dispatches to
    /// (`fetch_max`, so a mixed fleet reports its best lane).
    pub fn record_simd_level(&self, level: crate::exec::SimdLevel) {
        self.exec_simd_level.fetch_max(level.rank(), Ordering::Relaxed);
    }

    /// Record the index-gather ISA level a serving model dispatches to
    /// (`fetch_max`, mirroring [`Metrics::record_simd_level`]).
    pub fn record_gather_level(&self, level: crate::exec::SimdLevel) {
        self.exec_gather_level.fetch_max(level.rank(), Ordering::Relaxed);
    }

    /// Record one batch evaluation's wall-clock latency.
    pub fn record_batch_latency_us(&self, us: u64) {
        let Ok(mut v) = self.batch_latency_us.lock() else { return };
        if v.len() < BATCH_LATENCY_CAP {
            v.push(us);
        } else {
            let i = (self.latency_ticks.fetch_add(1, Ordering::Relaxed) as usize)
                % BATCH_LATENCY_CAP;
            v[i] = us;
        }
    }

    /// Raw per-batch latency samples (µs) currently in the reservoir —
    /// bounded by the reservoir cap, arrival order not meaningful. The
    /// fleet tier pools these across an entry's replicas so a per-model
    /// percentile summary covers the whole replica set.
    pub fn batch_latency_samples_us(&self) -> Vec<f64> {
        self.batch_latency_us
            .lock()
            .map(|v| v.iter().map(|&u| u as f64).collect())
            .unwrap_or_default()
    }

    /// Percentile summary of the recorded per-batch latencies.
    pub fn batch_latency_summary(&self) -> LatencySummary {
        LatencySummary::from_us(self.batch_latency_samples_us())
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            hops_total: self.hops_total.load(Ordering::Relaxed),
            forwards: self.forwards.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            evals: self.evals.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            fleet_served: self.fleet_served.load(Ordering::Relaxed),
            fleet_downgraded: self.fleet_downgraded.load(Ordering::Relaxed),
            fleet_shed: self.fleet_shed.load(Ordering::Relaxed),
            exec_samples: self.exec_samples.load(Ordering::Relaxed),
            exec_comparator_ops: self.exec_comparator_ops.load(Ordering::Relaxed),
            exec_levels_skipped: self.exec_levels_skipped.load(Ordering::Relaxed),
            exec_trees_skipped: self.exec_trees_skipped.load(Ordering::Relaxed),
            exec_cycles: self.exec_cycles.load(Ordering::Relaxed),
            exec_energy_fj: self.exec_energy_fj.load(Ordering::Relaxed),
            exec_simd_level: self.exec_simd_level.load(Ordering::Relaxed),
            exec_gather_level: self.exec_gather_level.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub hops_total: u64,
    pub forwards: u64,
    pub batches: u64,
    pub evals: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub fleet_served: u64,
    pub fleet_downgraded: u64,
    pub fleet_shed: u64,
    pub exec_samples: u64,
    pub exec_comparator_ops: u64,
    pub exec_levels_skipped: u64,
    pub exec_trees_skipped: u64,
    pub exec_cycles: u64,
    pub exec_energy_fj: u64,
    /// Highest [`SimdLevel::rank`](crate::exec::SimdLevel) gauge (0 =
    /// scalar); render with [`MetricsSnapshot::simd_label`].
    pub exec_simd_level: u64,
    /// Highest index-gather ISA rank gauge (0 = scalar gather stage);
    /// render with [`MetricsSnapshot::gather_label`].
    pub exec_gather_level: u64,
}

impl MetricsSnapshot {
    /// Merge a replica's worker-side counters into an aggregate snapshot
    /// with *saturating* adds (a wrapped aggregate would report a bogus
    /// rate). Front-end-owned counters — `requests`, `cache_hits`,
    /// `cache_misses`, and the fleet admission outcomes
    /// (`fleet_served`/`fleet_downgraded`/`fleet_shed`) — are
    /// deliberately not merged: the front end counts each client row
    /// once, while a replica's `requests` gauge counts the jobs routed to
    /// it; adding them would double-count.
    pub fn merge_worker(&mut self, other: &MetricsSnapshot) {
        self.responses = self.responses.saturating_add(other.responses);
        self.hops_total = self.hops_total.saturating_add(other.hops_total);
        self.forwards = self.forwards.saturating_add(other.forwards);
        self.batches = self.batches.saturating_add(other.batches);
        self.evals = self.evals.saturating_add(other.evals);
        self.exec_samples = self.exec_samples.saturating_add(other.exec_samples);
        self.exec_comparator_ops =
            self.exec_comparator_ops.saturating_add(other.exec_comparator_ops);
        self.exec_levels_skipped =
            self.exec_levels_skipped.saturating_add(other.exec_levels_skipped);
        self.exec_trees_skipped =
            self.exec_trees_skipped.saturating_add(other.exec_trees_skipped);
        self.exec_cycles = self.exec_cycles.saturating_add(other.exec_cycles);
        self.exec_energy_fj = self.exec_energy_fj.saturating_add(other.exec_energy_fj);
        // Gauges, not counters: the aggregate reports the best lane /
        // gather stage any replica dispatches to.
        self.exec_simd_level = self.exec_simd_level.max(other.exec_simd_level);
        self.exec_gather_level = self.exec_gather_level.max(other.exec_gather_level);
    }

    /// The vector ISA label for the recorded dispatch gauge
    /// (`"scalar"` when nothing recorded — dense baselines, f32 lanes).
    pub fn simd_label(&self) -> &'static str {
        crate::exec::SimdLevel::label_of_rank(self.exec_simd_level)
    }

    /// The index-gather ISA label for the recorded dispatch gauge
    /// (`"scalar"` when nothing recorded or no vector gather ran).
    pub fn gather_label(&self) -> &'static str {
        crate::exec::SimdLevel::label_of_rank(self.exec_gather_level)
    }

    pub fn avg_hops(&self) -> f64 {
        if self.responses == 0 {
            0.0
        } else {
            self.hops_total as f64 / self.responses as f64
        }
    }

    pub fn avg_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.evals as f64 / self.batches as f64
        }
    }

    /// Fraction of fleet-admitted requests that were shed (0.0 outside
    /// the fleet tier, where no admission decision is ever taken).
    pub fn shed_rate(&self) -> f64 {
        let decided = self.fleet_served + self.fleet_downgraded + self.fleet_shed;
        if decided == 0 {
            0.0
        } else {
            self.fleet_shed as f64 / decided as f64
        }
    }

    /// Fraction of cache lookups that hit (0.0 when caching is off or no
    /// lookups happened yet).
    pub fn cache_hit_rate(&self) -> f64 {
        let lookups = self.cache_hits + self.cache_misses;
        if lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / lookups as f64
        }
    }

    /// Simulated dynamic energy per *evaluated* classification, nJ
    /// (0 when no backend reported — software backend or cache-only
    /// traffic).
    pub fn energy_per_class_nj(&self) -> f64 {
        if self.exec_samples == 0 {
            0.0
        } else {
            self.exec_energy_fj as f64 * 1e-6 / self.exec_samples as f64
        }
    }

    /// Simulated dynamic energy amortized over every *response* — cache
    /// hits are classifications at zero evaluation energy, so this is
    /// what the deployment actually spends per answer.
    pub fn energy_per_response_nj(&self) -> f64 {
        if self.responses == 0 {
            0.0
        } else {
            self.exec_energy_fj as f64 * 1e-6 / self.responses as f64
        }
    }

    /// Simulated clock cycles per evaluated classification.
    pub fn cycles_per_class(&self) -> f64 {
        if self.exec_samples == 0 {
            0.0
        } else {
            self.exec_cycles as f64 / self.exec_samples as f64
        }
    }

    /// Comparator operations per evaluated classification.
    pub fn comparator_ops_per_class(&self) -> f64 {
        if self.exec_samples == 0 {
            0.0
        } else {
            self.exec_comparator_ops as f64 / self.exec_samples as f64
        }
    }

    /// Dead padded levels skipped per evaluated classification by the
    /// ragged kernel's live-depth early exit (0 under the μarch backend,
    /// whose PE is depth-bound).
    pub fn levels_skipped_per_class(&self) -> f64 {
        if self.exec_samples == 0 {
            0.0
        } else {
            self.exec_levels_skipped as f64 / self.exec_samples as f64
        }
    }

    /// Trees skipped per evaluated classification by the adaptive
    /// confidence early exit (0 with the knob off; FoG models report
    /// their saving through `avg_hops` instead).
    pub fn trees_skipped_per_class(&self) -> f64 {
        if self.exec_samples == 0 {
            0.0
        } else {
            self.exec_trees_skipped as f64 / self.exec_samples as f64
        }
    }
}

/// Latency summary computed from response records.
#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
}

impl LatencySummary {
    pub fn from_us(mut samples: Vec<f64>) -> LatencySummary {
        if samples.is_empty() {
            return LatencySummary { p50_us: 0.0, p95_us: 0.0, p99_us: 0.0, mean_us: 0.0 };
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySummary {
            p50_us: crate::util::stats::percentile(&samples, 50.0),
            p95_us: crate::util::stats::percentile(&samples, 95.0),
            p99_us: crate::util::stats::percentile(&samples, 99.0),
            mean_us: crate::util::stats::mean(&samples),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_averages() {
        let m = Metrics::default();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.responses.fetch_add(10, Ordering::Relaxed);
        m.hops_total.fetch_add(25, Ordering::Relaxed);
        m.batches.fetch_add(5, Ordering::Relaxed);
        m.evals.fetch_add(20, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.avg_hops(), 2.5);
        assert_eq!(s.avg_batch_size(), 4.0);
    }

    #[test]
    fn cache_hit_rate() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().cache_hit_rate(), 0.0);
        m.cache_hits.fetch_add(3, Ordering::Relaxed);
        m.cache_misses.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.snapshot().cache_hit_rate(), 0.75);
    }

    #[test]
    fn latency_summary() {
        let s = LatencySummary::from_us((1..=100).map(|i| i as f64).collect());
        assert!((s.p50_us - 50.5).abs() < 1.0);
        assert!(s.p95_us > s.p50_us);
        assert!(s.p99_us >= s.p95_us);
        let empty = LatencySummary::from_us(vec![]);
        assert_eq!(empty.mean_us, 0.0);
    }

    #[test]
    fn exec_reports_fold_into_per_class_rates() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.energy_per_class_nj(), 0.0);
        assert_eq!(s.cycles_per_class(), 0.0);
        let r = ExecReport {
            samples: 4,
            comparator_ops: 400,
            levels_skipped: 40,
            trees_skipped: 8,
            cycles: 100,
            energy_nj: 2.0,
            ..Default::default()
        };
        m.record_exec(&r);
        m.record_exec(&r);
        m.responses.fetch_add(16, Ordering::Relaxed); // 8 evaluated + 8 cached
        let s = m.snapshot();
        assert_eq!(s.exec_samples, 8);
        assert!((s.energy_per_class_nj() - 0.5).abs() < 1e-9);
        assert!((s.energy_per_response_nj() - 0.25).abs() < 1e-9);
        assert!((s.cycles_per_class() - 25.0).abs() < 1e-12);
        assert!((s.comparator_ops_per_class() - 100.0).abs() < 1e-12);
        assert!((s.levels_skipped_per_class() - 10.0).abs() < 1e-12);
        assert!((s.trees_skipped_per_class() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_worker_saturates_and_skips_front_end_counters() {
        let mut a = MetricsSnapshot { responses: u64::MAX - 1, ..Default::default() };
        let b = MetricsSnapshot {
            responses: 5,
            batches: 3,
            evals: 7,
            requests: 11,     // front-end-owned: must not merge
            cache_hits: 13,   // front-end-owned: must not merge
            fleet_served: 17, // front-end-owned: must not merge
            fleet_shed: 19,   // front-end-owned: must not merge
            exec_samples: 2,
            exec_energy_fj: 1000,
            ..Default::default()
        };
        a.merge_worker(&b);
        assert_eq!(a.responses, u64::MAX, "responses must saturate, not wrap");
        assert_eq!(a.batches, 3);
        assert_eq!(a.evals, 7);
        assert_eq!(a.exec_samples, 2);
        assert_eq!(a.exec_energy_fj, 1000);
        assert_eq!(a.requests, 0, "requests double-counted");
        assert_eq!(a.cache_hits, 0, "cache hits double-counted");
        assert_eq!(a.fleet_served, 0, "fleet outcomes double-counted");
        assert_eq!(a.fleet_shed, 0, "fleet outcomes double-counted");
    }

    #[test]
    fn simd_level_gauge_maxes_and_labels() {
        use crate::exec::SimdLevel;
        let m = Metrics::default();
        assert_eq!(m.snapshot().simd_label(), "scalar");
        m.record_simd_level(SimdLevel::detect());
        let s = m.snapshot();
        assert_eq!(s.simd_label(), SimdLevel::detect().label());
        // Recording Scalar afterwards never downgrades the gauge.
        m.record_simd_level(SimdLevel::Scalar);
        assert_eq!(m.snapshot().exec_simd_level, s.exec_simd_level);
        // merge_worker takes the max across replicas.
        let mut a = MetricsSnapshot::default();
        a.merge_worker(&s);
        assert_eq!(a.exec_simd_level, s.exec_simd_level);
        // Unknown ranks render as the safe fallback label.
        let weird = MetricsSnapshot { exec_simd_level: 99, ..Default::default() };
        assert_eq!(weird.simd_label(), "scalar");
    }

    #[test]
    fn gather_level_gauge_maxes_and_labels() {
        use crate::exec::SimdLevel;
        let m = Metrics::default();
        assert_eq!(m.snapshot().gather_label(), "scalar");
        m.record_gather_level(SimdLevel::Avx2);
        let s = m.snapshot();
        assert_eq!(s.gather_label(), "avx2");
        // Recording Scalar afterwards never downgrades the gauge, and
        // the simd gauge is untouched — the two are independent.
        m.record_gather_level(SimdLevel::Scalar);
        assert_eq!(m.snapshot().exec_gather_level, s.exec_gather_level);
        assert_eq!(m.snapshot().exec_simd_level, 0);
        // merge_worker takes the max across replicas.
        let mut a = MetricsSnapshot::default();
        a.merge_worker(&s);
        assert_eq!(a.exec_gather_level, s.exec_gather_level);
        let weird = MetricsSnapshot { exec_gather_level: 99, ..Default::default() };
        assert_eq!(weird.gather_label(), "scalar");
    }

    #[test]
    fn shed_rate_counts_fleet_outcomes_only() {
        let s = MetricsSnapshot::default();
        assert_eq!(s.shed_rate(), 0.0, "no fleet tier => no shed");
        let s = MetricsSnapshot {
            fleet_served: 6,
            fleet_downgraded: 2,
            fleet_shed: 2,
            ..Default::default()
        };
        assert!((s.shed_rate() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn batch_latency_reservoir_summarizes_and_stays_bounded() {
        let m = Metrics::default();
        assert_eq!(m.batch_latency_summary().mean_us, 0.0);
        for us in [10u64, 20, 30, 40] {
            m.record_batch_latency_us(us);
        }
        let s = m.batch_latency_summary();
        assert!((s.mean_us - 25.0).abs() < 1e-9);
        assert!(s.p99_us >= s.p50_us && s.p50_us > 0.0);
        // Reservoir never grows past its cap.
        for us in 0..(2 * super::BATCH_LATENCY_CAP as u64) {
            m.record_batch_latency_us(us);
        }
        let len = m.batch_latency_us.lock().unwrap().len();
        assert_eq!(len, super::BATCH_LATENCY_CAP);
    }
}
