//! Serving metrics: lock-free counters updated by workers, plus a
//! latency reservoir the collector fills (reservoirs need no locks on
//! the hot path because only the collector thread touches them).
//!
//! Paper anchor: these are the deployment-side observables of the §4.2
//! energy claims — `avg_hops` is the Figure-5 x-axis driver (groves
//! consulted per classification), and the cache hit/miss counters track
//! how many classifications the sharded tier answered with *zero* grove
//! evaluations. One `Metrics` instance serves a whole [`super::FogServer`]
//! or [`super::ModelServer`]; a [`super::ShardedServer`] keeps one per
//! replica plus a front-end instance for request/cache accounting.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared atomic counters.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub hops_total: AtomicU64,
    pub forwards: AtomicU64,
    /// Batches evaluated (per-backend batching effectiveness).
    pub batches: AtomicU64,
    /// Items evaluated (≥ responses; includes re-circulated items).
    pub evals: AtomicU64,
    /// Requests answered straight from the probability cache.
    pub cache_hits: AtomicU64,
    /// Requests that missed the cache and went to a replica queue.
    pub cache_misses: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            hops_total: self.hops_total.load(Ordering::Relaxed),
            forwards: self.forwards.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            evals: self.evals.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub hops_total: u64,
    pub forwards: u64,
    pub batches: u64,
    pub evals: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl MetricsSnapshot {
    pub fn avg_hops(&self) -> f64 {
        if self.responses == 0 {
            0.0
        } else {
            self.hops_total as f64 / self.responses as f64
        }
    }

    pub fn avg_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.evals as f64 / self.batches as f64
        }
    }

    /// Fraction of cache lookups that hit (0.0 when caching is off or no
    /// lookups happened yet).
    pub fn cache_hit_rate(&self) -> f64 {
        let lookups = self.cache_hits + self.cache_misses;
        if lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / lookups as f64
        }
    }
}

/// Latency summary computed from response records.
#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
}

impl LatencySummary {
    pub fn from_us(mut samples: Vec<f64>) -> LatencySummary {
        if samples.is_empty() {
            return LatencySummary { p50_us: 0.0, p95_us: 0.0, p99_us: 0.0, mean_us: 0.0 };
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySummary {
            p50_us: crate::util::stats::percentile(&samples, 50.0),
            p95_us: crate::util::stats::percentile(&samples, 95.0),
            p99_us: crate::util::stats::percentile(&samples, 99.0),
            mean_us: crate::util::stats::mean(&samples),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_averages() {
        let m = Metrics::default();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.responses.fetch_add(10, Ordering::Relaxed);
        m.hops_total.fetch_add(25, Ordering::Relaxed);
        m.batches.fetch_add(5, Ordering::Relaxed);
        m.evals.fetch_add(20, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.avg_hops(), 2.5);
        assert_eq!(s.avg_batch_size(), 4.0);
    }

    #[test]
    fn cache_hit_rate() {
        let m = Metrics::default();
        assert_eq!(m.snapshot().cache_hit_rate(), 0.0);
        m.cache_hits.fetch_add(3, Ordering::Relaxed);
        m.cache_misses.fetch_add(1, Ordering::Relaxed);
        assert_eq!(m.snapshot().cache_hit_rate(), 0.75);
    }

    #[test]
    fn latency_summary() {
        let s = LatencySummary::from_us((1..=100).map(|i| i as f64).collect());
        assert!((s.p50_us - 50.5).abs() < 1.0);
        assert!(s.p95_us > s.p50_us);
        assert!(s.p99_us >= s.p95_us);
        let empty = LatencySummary::from_us(vec![]);
        assert_eq!(empty.mean_us, 0.0);
    }
}
