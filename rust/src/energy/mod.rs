//! Energy/PPA modelling — the substrate replacing the paper's
//! Aladdin + Cadence + Synopsys 40 nm flow.
//!
//! * [`blocks`] — per-operation energy/area/delay of the basic
//!   computational blocks (comparator, adder, multiplier, MAC, sigmoid
//!   LUT, SRAM, registers) at 40 nm / 1 GHz.
//! * [`aladdin`] — a pre-RTL design-space explorer in the spirit of
//!   Aladdin [16]: sweeps bitwidth / parallelism / pipelining for an op
//!   mix and extracts the Pareto frontier; used to pick each classifier's
//!   minimum-EDP datapath (§4.1 steps 1 & 3).
//! * [`model`] — per-classifier energy models: op counts measured from
//!   the *trained* classifiers (tree depths actually traversed, support
//!   vector counts, layer shapes) × block energies + leakage × latency.
//! * [`edp`] — energy-delay-product helpers.
//!
//! Absolute nJ values are calibrated to land in the paper's ranges (their
//! testbed is a synthesized ASIC we don't have); the *ratios* between
//! classifiers — the claims of Table 1 — emerge from op-count structure.

pub mod aladdin;
pub mod blocks;
pub mod edp;
pub mod model;

pub use blocks::EnergyBlocks;
pub use model::{ClassifierKind, CostReport};
