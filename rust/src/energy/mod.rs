//! Energy/PPA modelling — the substrate replacing the paper's
//! Aladdin + Cadence + Synopsys 40 nm flow.
//!
//! * [`blocks`] — per-operation energy/area/delay of the basic
//!   computational blocks (comparator, adder, multiplier, MAC, sigmoid
//!   LUT, SRAM, registers) at 40 nm / 1 GHz.
//! * [`aladdin`] — a pre-RTL design-space explorer in the spirit of
//!   Aladdin [16]: sweeps bitwidth / parallelism / pipelining for an op
//!   mix and extracts the Pareto frontier; used to pick each classifier's
//!   minimum-EDP datapath (§4.1 steps 1 & 3).
//! * [`model`] — per-classifier energy models: op counts measured from
//!   the *trained* classifiers (tree depths actually traversed, support
//!   vector counts, layer shapes) × block energies + leakage × latency.
//! * [`edp`] — energy-delay-product helpers.
//!
//! Absolute nJ values are calibrated to land in the paper's ranges (their
//! testbed is a synthesized ASIC we don't have); the *ratios* between
//! classifiers — the claims of Table 1 — emerge from op-count structure.
//!
//! **Paper anchors:** §4.1 (methodology steps 1–3: block
//! characterization, Aladdin-style DSE, per-classifier assembly), §4.2 /
//! Table 1 (energy, latency and area rows), Figure 5 (energy
//! proportionality in the hop count). Beyond the offline harnesses, the
//! same block energies drive *serving-time* accounting: the
//! [`model::event_energy_nj`] fold turns the μarch simulator's event
//! counters into the per-classification nanojoules that
//! `fog serve --backend uarch` reports live (see
//! [`crate::exec::backend`]).

pub mod aladdin;
pub mod blocks;
pub mod edp;
pub mod model;

pub use blocks::EnergyBlocks;
pub use model::{ClassifierKind, CostReport};
