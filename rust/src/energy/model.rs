//! Per-classifier energy/latency/area models (regenerates Table 1's
//! bottom half and area row).
//!
//! Each model charges, per classification:
//!
//! 1. **dynamic compute** — op counts measured from the *trained*
//!    classifier (actual traversed depths, actual support-vector counts,
//!    actual layer shapes) × per-op block energies;
//! 2. **memory traffic** — node-table/weight/feature bytes moved, with a
//!    32 KB on-chip capacity: working sets beyond it stream at a higher
//!    per-byte cost (the reason RBF-SVM and CNN blow up on MNIST-sized
//!    inputs, exactly the effect the paper's Table 1 shows);
//! 3. **static energy** — (leakage + clock) power × classifier area ×
//!    classification latency. Idle FoG groves are power-gated, so FoG
//!    charges only *active* grove area — the mechanism that makes
//!    FoG_opt cheaper than conventional RF at equal accuracy.
//!
//! Latency models: tree traversal is serial per level (fetch node →
//! compare → next address: [`TREE_CYCLES_PER_LEVEL`] cycles), GEMM
//! engines run [`GEMM_LANES`] MACs/cycle, queue copies move 4 B/cycle.

use super::blocks::{AreaBlocks, EnergyBlocks};

/// On-chip buffer capacity; larger working sets stream from off-chip.
pub const ONCHIP_BYTES: f64 = 32.0 * 1024.0;
/// Energy per byte streamed from off-chip (pJ/B) — LPDDR-class.
pub const STREAM_PJ_PER_BYTE: f64 = 0.8;
/// Serial cycles per tree level (SRAM fetch, compare, address update).
pub const TREE_CYCLES_PER_LEVEL: f64 = 3.0;
/// MAC lanes of the GEMM-style engines (SVM-RBF / MLP / CNN).
pub const GEMM_LANES: f64 = 256.0;
/// MAC lanes of the small linear-SVM engine.
pub const LINEAR_LANES: f64 = 32.0;
/// Fixed IO/queue overhead cycles per classification.
pub const IO_OVERHEAD_CYCLES: f64 = 30.0;
/// Bytes per tree node entry (weight + feature offset + control).
pub const NODE_BYTES: f64 = 4.0;

/// Which classifier a report describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClassifierKind {
    SvmLinear,
    SvmRbf,
    Mlp,
    Cnn,
    /// A single decision tree (not a Table-1 column; used by the unified
    /// `fog::api` layer for tree-level models).
    Tree,
    RandomForest,
    FogMax,
    FogOpt,
}

impl ClassifierKind {
    pub fn label(&self) -> &'static str {
        match self {
            ClassifierKind::SvmLinear => "SVM_lr",
            ClassifierKind::SvmRbf => "SVM_rbf",
            ClassifierKind::Mlp => "MLP",
            ClassifierKind::Cnn => "CNN",
            ClassifierKind::Tree => "DT",
            ClassifierKind::RandomForest => "RF",
            ClassifierKind::FogMax => "FoG_max",
            ClassifierKind::FogOpt => "FoG_opt",
        }
    }
}

/// PPA result for one classifier on one dataset.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub kind: ClassifierKind,
    pub energy_nj: f64,
    pub latency_ns: f64,
    pub area_mm2: f64,
}

impl CostReport {
    pub fn edp(&self) -> f64 {
        self.energy_nj * self.latency_ns
    }
}

/// Dynamic energy (nJ) of counted μarch execution events — the single
/// per-report fold shared by the ring simulator's
/// [`SimStats::dynamic_energy_nj`](crate::uarch::SimStats) and the
/// serving tier's per-tile [`ExecReport`](crate::exec::ExecReport)s, so
/// offline simulation and hardware-in-the-loop serving charge identical
/// block energies per event.
pub fn event_energy_nj(
    eb: &EnergyBlocks,
    comparator_ops: f64,
    queue_bytes_read: f64,
    queue_bytes_written: f64,
    handshakes: f64,
) -> f64 {
    eb.comparisons_nj(comparator_ops)
        + eb.sram_read_nj(queue_bytes_read)
        + eb.sram_write_nj(queue_bytes_written)
        + handshakes * eb.handshake_pj * 1e-3
}

fn stream_overflow_nj(working_set_bytes: f64) -> f64 {
    if working_set_bytes > ONCHIP_BYTES {
        (working_set_bytes - ONCHIP_BYTES) * STREAM_PJ_PER_BYTE * 1e-3
    } else {
        0.0
    }
}

fn onchip_bytes(working_set_bytes: f64) -> f64 {
    working_set_bytes.min(ONCHIP_BYTES)
}

/// Measured statistics of a trained forest.
#[derive(Clone, Debug)]
pub struct RfStats {
    pub n_trees: usize,
    /// Mean total comparisons per input across all trees (measured).
    pub avg_comparisons: f64,
    pub max_depth: usize,
    pub n_features: usize,
    pub n_classes: usize,
    /// Total node-table bytes (reprogrammable FF storage).
    pub node_storage_bytes: f64,
}

/// Conventional RF accelerator (paper §3.1): all trees evaluate in
/// parallel; traversal is serial per level; node weights live in
/// reprogrammable register storage (§3.2.2 "Reprogrammability").
pub fn rf_cost(s: &RfStats, eb: &EnergyBlocks, ab: &AreaBlocks) -> CostReport {
    // --- area ---
    let tree_logic_um2 = ab.comp8_um2 + ab.add16_um2; // comparator + addr adder
    let node_storage_um2 = s.node_storage_bytes * ab.reg_um2_per_byte;
    let input_buf_um2 = (s.n_features as f64) * ab.sram_um2_per_byte * s.n_trees as f64;
    let vote_um2 = (s.n_classes as f64) * ab.add16_um2;
    let area_um2 = s.n_trees as f64 * tree_logic_um2
        + node_storage_um2
        + input_buf_um2
        + vote_um2
        + ab.control_um2 * 2.0;
    let area_mm2 = AreaBlocks::um2_to_mm2(area_um2);

    // --- latency: trees run in parallel, levels serial ---
    let cycles = s.max_depth as f64 * TREE_CYCLES_PER_LEVEL + IO_OVERHEAD_CYCLES;
    let latency_ns = eb.cycles_to_ns(cycles);

    // --- dynamic ---
    let comp_nj = eb.comparisons_nj(s.avg_comparisons);
    let node_fetch_nj = eb.sram_read_nj(s.avg_comparisons * NODE_BYTES);
    let feat_fetch_nj = eb.sram_read_nj(s.avg_comparisons); // 1 B/feature read
    // Input vector broadcast into every tree's local buffer.
    let input_load_nj = eb.sram_write_nj(s.n_features as f64 * s.n_trees as f64);
    let leaf_nj = eb.sram_read_nj(s.n_trees as f64 * s.n_classes as f64);
    let vote_nj = s.n_trees as f64 * s.n_classes as f64 * eb.add16_pj * 1e-3;
    let dynamic = comp_nj + node_fetch_nj + feat_fetch_nj + input_load_nj + leaf_nj + vote_nj;

    let energy_nj = dynamic + eb.leakage_nj(area_mm2, cycles);
    CostReport { kind: ClassifierKind::RandomForest, energy_nj, latency_ns, area_mm2 }
}

/// Measured statistics of a FoG configuration at a given threshold.
#[derive(Clone, Debug)]
pub struct FogStats {
    pub n_groves: usize,
    pub trees_per_grove: usize,
    /// Padded flat-tree depth (every traversal walks exactly this).
    pub depth: usize,
    /// Mean groves consulted per input (measured, 1..=n_groves).
    pub avg_hops: f64,
    pub n_features: usize,
    pub n_classes: usize,
    /// Node-table bytes per grove.
    pub grove_storage_bytes: f64,
    pub kind: ClassifierKind,
}

impl FogStats {
    /// Queue word length Γ (paper §3.2.2): hops byte + features + id +
    /// one byte per class of the probability array.
    pub fn gamma(&self) -> f64 {
        1.0 + self.n_features as f64 + 1.0 + self.n_classes as f64
    }
}

/// FoG accelerator (paper §3.2.2, Figure 3). Dynamic energy scales with
/// the measured hop count; idle groves are power-gated so static energy
/// charges active-grove area only. The ring's queue traffic (Γ-byte word
/// per hop) and req/ack handshakes are charged explicitly.
pub fn fog_cost(s: &FogStats, eb: &EnergyBlocks, ab: &AreaBlocks) -> CostReport {
    let gamma = s.gamma();

    // --- area (whole FoG: all groves + queues + IO ring) ---
    let tree_logic_um2 = ab.comp8_um2 + ab.add16_um2;
    let grove_um2 = s.trees_per_grove as f64 * tree_logic_um2
        + s.grove_storage_bytes * ab.reg_um2_per_byte
        + 6.0 * 1024.0 * ab.sram_um2_per_byte  // 6 kB data queue (paper)
        + (s.n_classes as f64) * ab.add16_um2   // prob accumulator
        + ab.control_um2;                        // DQC + handshake + PE ctl
    let io_um2 = 2.0 * ab.control_um2 + gamma * 8.0 * ab.sram_um2_per_byte;
    let total_area_um2 = s.n_groves as f64 * grove_um2 + io_um2;
    let area_mm2 = AreaBlocks::um2_to_mm2(total_area_um2);
    let grove_area_mm2 = AreaBlocks::um2_to_mm2(grove_um2);

    // --- per-hop work ---
    let comps_per_hop = (s.trees_per_grove * s.depth) as f64;
    let hop_dyn_nj = eb.comparisons_nj(comps_per_hop)
        + eb.sram_read_nj(comps_per_hop * NODE_BYTES)
        + eb.sram_read_nj(comps_per_hop)
        + eb.sram_read_nj((s.trees_per_grove * s.n_classes) as f64) // leaves
        + (s.trees_per_grove * s.n_classes) as f64 * eb.add16_pj * 1e-3 // averaging
        + eb.sram_read_nj(gamma) + eb.sram_write_nj(gamma); // queue word r/w
    // Queue copy moves Γ bytes over a 16-byte port, overlapped with the
    // next input's PE start in hardware; we charge it fully (conservative).
    let hop_cycles = s.depth as f64 * TREE_CYCLES_PER_LEVEL + gamma / 16.0 + 5.0;

    // --- handshake + inter-grove copy on every forwarded input ---
    let forwards = (s.avg_hops - 1.0).max(0.0);
    let forward_nj = forwards * (eb.handshake_pj * 1e-3 + eb.sram_write_nj(gamma));

    // --- totals ---
    let input_load_nj = eb.sram_write_nj(gamma); // processor → input queue
    let dynamic = s.avg_hops * hop_dyn_nj + forward_nj + input_load_nj;
    let cycles = s.avg_hops * hop_cycles + IO_OVERHEAD_CYCLES;
    let latency_ns = eb.cycles_to_ns(cycles);
    // Power gating: only the grove processing the input is awake, plus a
    // 10% ring overhead that can't be gated.
    let active_area = grove_area_mm2 + 0.1 * area_mm2;
    let energy_nj = dynamic + eb.leakage_nj(active_area, cycles);
    CostReport { kind: s.kind, energy_nj, latency_ns, area_mm2 }
}

/// Linear SVM: `n_classes` dot products over `n_features`.
pub fn svm_linear_cost(n_features: usize, n_classes: usize, eb: &EnergyBlocks, ab: &AreaBlocks) -> CostReport {
    let macs = (n_features * n_classes) as f64;
    let weight_bytes = macs; // 1 B/weight fixed-point
    let area_um2 = LINEAR_LANES * ab.mac16_um2
        + onchip_bytes(weight_bytes) * ab.sram_um2_per_byte
        + ab.control_um2;
    let area_mm2 = AreaBlocks::um2_to_mm2(area_um2);
    let cycles = (macs / LINEAR_LANES).ceil() + IO_OVERHEAD_CYCLES;
    let dynamic = eb.macs_nj(macs)
        + eb.sram_read_nj(onchip_bytes(weight_bytes))
        + stream_overflow_nj(weight_bytes)
        + eb.sram_read_nj(n_features as f64);
    CostReport {
        kind: ClassifierKind::SvmLinear,
        energy_nj: dynamic + eb.leakage_nj(area_mm2, cycles),
        latency_ns: eb.cycles_to_ns(cycles),
        area_mm2,
    }
}

/// RBF-kernel SVM: `n_sv` squared-distance evaluations + exp LUT + class
/// accumulation. Support-vector storage beyond on-chip streams per
/// classification — the dominant term for big datasets (paper: 1020 nJ on
/// MNIST vs 18 nJ on Pendigits).
pub fn svm_rbf_cost(n_sv: usize, n_features: usize, n_classes: usize, eb: &EnergyBlocks, ab: &AreaBlocks) -> CostReport {
    let dist_ops = (n_sv * n_features) as f64; // sub+sq+acc ≈ 1 MAC each
    let kernel_ops = n_sv as f64; // exp LUT
    let acc_ops = (n_sv * n_classes) as f64 * 0.0 + n_sv as f64; // coefficient MAC
    let macs = dist_ops + acc_ops;
    let sv_bytes = (n_sv * n_features) as f64;
    let area_um2 = GEMM_LANES * ab.mac16_um2
        + ab.sigmoid_um2
        + onchip_bytes(sv_bytes) * ab.sram_um2_per_byte
        + ab.control_um2;
    let area_mm2 = AreaBlocks::um2_to_mm2(area_um2);
    let cycles = (macs / GEMM_LANES).ceil() + kernel_ops + IO_OVERHEAD_CYCLES;
    let dynamic = eb.macs_nj(macs)
        + kernel_ops * eb.sigmoid_pj * 1e-3
        + eb.sram_read_nj(onchip_bytes(sv_bytes))
        + stream_overflow_nj(sv_bytes)
        + eb.sram_read_nj(n_features as f64);
    CostReport {
        kind: ClassifierKind::SvmRbf,
        energy_nj: dynamic + eb.leakage_nj(area_mm2, cycles),
        latency_ns: eb.cycles_to_ns(cycles),
        area_mm2,
    }
}

/// MLP: dense layers with sigmoid/ReLU activations.
pub fn mlp_cost(layer_dims: &[usize], eb: &EnergyBlocks, ab: &AreaBlocks) -> CostReport {
    assert!(layer_dims.len() >= 2);
    let mut macs = 0.0;
    let mut acts = 0.0;
    for w in layer_dims.windows(2) {
        macs += (w[0] * w[1]) as f64;
        acts += w[1] as f64;
    }
    let weight_bytes = macs;
    let area_um2 = GEMM_LANES * ab.mac16_um2
        + ab.sigmoid_um2 * 4.0
        + onchip_bytes(weight_bytes) * ab.sram_um2_per_byte
        + ab.control_um2;
    let area_mm2 = AreaBlocks::um2_to_mm2(area_um2);
    let cycles = (macs / GEMM_LANES).ceil() + acts + IO_OVERHEAD_CYCLES;
    let dynamic = eb.macs_nj(macs)
        + acts * eb.sigmoid_pj * 1e-3
        + eb.sram_read_nj(onchip_bytes(weight_bytes))
        + stream_overflow_nj(weight_bytes)
        + eb.sram_read_nj(layer_dims[0] as f64);
    CostReport {
        kind: ClassifierKind::Mlp,
        energy_nj: dynamic + eb.leakage_nj(area_mm2, cycles),
        latency_ns: eb.cycles_to_ns(cycles),
        area_mm2,
    }
}

/// CNN: caller supplies measured MAC count, weight bytes and activation
/// traffic (computed by the CNN baseline from its architecture).
pub fn cnn_cost(macs: f64, weight_bytes: f64, act_bytes: f64, eb: &EnergyBlocks, ab: &AreaBlocks) -> CostReport {
    let area_um2 = 2.0 * GEMM_LANES * ab.mac16_um2
        + ab.sigmoid_um2 * 8.0
        + onchip_bytes(weight_bytes + act_bytes) * ab.sram_um2_per_byte
        + 2.0 * ab.control_um2;
    let area_mm2 = AreaBlocks::um2_to_mm2(area_um2);
    let cycles = (macs / (2.0 * GEMM_LANES)).ceil() + IO_OVERHEAD_CYCLES;
    let traffic = weight_bytes + act_bytes;
    let dynamic = eb.macs_nj(macs)
        + eb.sram_read_nj(onchip_bytes(traffic))
        + stream_overflow_nj(traffic);
    CostReport {
        kind: ClassifierKind::Cnn,
        energy_nj: dynamic + eb.leakage_nj(area_mm2, cycles),
        latency_ns: eb.cycles_to_ns(cycles),
        area_mm2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eb() -> EnergyBlocks {
        EnergyBlocks::default()
    }
    fn ab() -> AreaBlocks {
        AreaBlocks::default()
    }

    fn penbase_rf() -> RfStats {
        RfStats {
            n_trees: 16,
            avg_comparisons: 16.0 * 7.0,
            max_depth: 8,
            n_features: 16,
            n_classes: 10,
            node_storage_bytes: 16.0 * (255.0 * 4.0 + 256.0 * 10.0),
        }
    }

    fn penbase_fog(avg_hops: f64, kind: ClassifierKind) -> FogStats {
        FogStats {
            n_groves: 8,
            trees_per_grove: 2,
            depth: 8,
            avg_hops,
            n_features: 16,
            n_classes: 10,
            grove_storage_bytes: 2.0 * (255.0 * 4.0 + 256.0 * 10.0),
            kind,
        }
    }

    #[test]
    fn fog_opt_cheaper_than_rf() {
        let rf = rf_cost(&penbase_rf(), &eb(), &ab());
        let fog = fog_cost(&penbase_fog(2.5, ClassifierKind::FogOpt), &eb(), &ab());
        assert!(
            fog.energy_nj < rf.energy_nj,
            "fog {} rf {}",
            fog.energy_nj,
            rf.energy_nj
        );
        // Paper: ≈1.5-2.3x advantage at the optimal point.
        let ratio = rf.energy_nj / fog.energy_nj;
        assert!(ratio > 1.1 && ratio < 6.0, "ratio {ratio}");
    }

    #[test]
    fn fog_max_close_to_rf() {
        let rf = rf_cost(&penbase_rf(), &eb(), &ab());
        let fog = fog_cost(&penbase_fog(8.0, ClassifierKind::FogMax), &eb(), &ab());
        let ratio = fog.energy_nj / rf.energy_nj;
        assert!(ratio > 0.5 && ratio < 2.5, "fog_max/rf = {ratio}");
    }

    #[test]
    fn fog_area_larger_than_rf() {
        // Paper Table 1: FoG 1.9 mm² > RF 1.38 mm² (queues + handshake).
        let rf = rf_cost(&penbase_rf(), &eb(), &ab());
        let fog = fog_cost(&penbase_fog(2.5, ClassifierKind::FogOpt), &eb(), &ab());
        assert!(fog.area_mm2 > rf.area_mm2);
    }

    #[test]
    fn svm_linear_cheapest() {
        let lr = svm_linear_cost(16, 10, &eb(), &ab());
        let rf = rf_cost(&penbase_rf(), &eb(), &ab());
        let rbf = svm_rbf_cost(800, 16, 10, &eb(), &ab());
        assert!(lr.energy_nj < rf.energy_nj);
        assert!(lr.energy_nj < rbf.energy_nj);
    }

    #[test]
    fn rbf_explodes_on_large_features() {
        // Streaming support vectors: MNIST-sized RBF ≫ Pendigits-sized.
        let small = svm_rbf_cost(800, 16, 10, &eb(), &ab());
        let large = svm_rbf_cost(1500, 784, 10, &eb(), &ab());
        assert!(large.energy_nj > 20.0 * small.energy_nj);
    }

    #[test]
    fn cnn_most_expensive() {
        let cnn = cnn_cost(1.7e6, 120_000.0, 400_000.0, &eb(), &ab());
        let rf = rf_cost(&penbase_rf(), &eb(), &ab());
        let mlp = mlp_cost(&[784, 128, 10], &eb(), &ab());
        assert!(cnn.energy_nj > rf.energy_nj);
        assert!(cnn.energy_nj > mlp.energy_nj);
    }

    #[test]
    fn event_energy_fold_charges_every_block() {
        let b = eb();
        // 1000 comparisons alone = 0.06 nJ (block library unit test's
        // anchor); adding traffic and handshakes only increases it.
        let base = event_energy_nj(&b, 1000.0, 0.0, 0.0, 0.0);
        assert!((base - 0.06).abs() < 1e-9);
        let full = event_energy_nj(&b, 1000.0, 100.0, 100.0, 10.0);
        let expected = b.comparisons_nj(1000.0)
            + b.sram_read_nj(100.0)
            + b.sram_write_nj(100.0)
            + 10.0 * b.handshake_pj * 1e-3;
        assert!((full - expected).abs() < 1e-12);
        assert!(full > base);
    }

    #[test]
    fn gamma_matches_paper_example() {
        // Paper example: 5 features, 3 classes → Γ = 1+5+1+3 = 10.
        let s = FogStats {
            n_groves: 4,
            trees_per_grove: 4,
            depth: 4,
            avg_hops: 1.0,
            n_features: 5,
            n_classes: 3,
            grove_storage_bytes: 100.0,
            kind: ClassifierKind::FogOpt,
        };
        assert_eq!(s.gamma(), 10.0);
    }

    #[test]
    fn fog_energy_monotone_in_hops() {
        let e1 = fog_cost(&penbase_fog(1.0, ClassifierKind::FogOpt), &eb(), &ab()).energy_nj;
        let e2 = fog_cost(&penbase_fog(4.0, ClassifierKind::FogOpt), &eb(), &ab()).energy_nj;
        let e3 = fog_cost(&penbase_fog(8.0, ClassifierKind::FogMax), &eb(), &ab()).energy_nj;
        assert!(e1 < e2 && e2 < e3);
    }

    #[test]
    fn reports_have_positive_ppa() {
        for r in [
            rf_cost(&penbase_rf(), &eb(), &ab()),
            svm_linear_cost(617, 26, &eb(), &ab()),
            svm_rbf_cost(1200, 617, 26, &eb(), &ab()),
            mlp_cost(&[617, 256, 26], &eb(), &ab()),
            cnn_cost(5e5, 8e4, 2e5, &eb(), &ab()),
        ] {
            assert!(r.energy_nj > 0.0);
            assert!(r.latency_ns > 0.0);
            assert!(r.area_mm2 > 0.0);
            assert!(r.edp() > 0.0);
        }
    }
}
