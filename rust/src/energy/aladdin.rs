//! Aladdin-style pre-RTL design-space exploration (paper §4.1 steps 1+3,
//! citing Shao et al., ISCA'14 [16]).
//!
//! Given an *op mix* (how many comparisons, MACs, memory bytes one
//! classification needs), sweep the micro-architectural knobs — datapath
//! bitwidth, lane parallelism, pipeline depth — and produce
//! (energy, delay, area) for each configuration. The energy/area scaling
//! rules are the standard ones: multiplier energy/area quadratic in
//! width, adder/comparator linear; parallel lanes multiply area and
//! divide cycle count; pipelining raises achievable clock (up to the
//! 1 GHz target) at a register overhead.

use super::blocks::{AreaBlocks, EnergyBlocks};
use super::edp::{pareto, DesignPoint};

/// Operation mix of one classification, the DSE input.
#[derive(Clone, Debug, Default)]
pub struct OpMix {
    pub comparisons: f64,
    pub macs: f64,
    pub sigmoids: f64,
    pub sram_read_bytes: f64,
    pub sram_write_bytes: f64,
    /// Working-set bytes that must be resident (weights, node tables).
    pub storage_bytes: f64,
    /// Fraction of ops on the critical path (serial chain), 0..1. Trees
    /// are almost fully serial per level (≈1); GEMMs are highly parallel
    /// (≈0 beyond the reduction depth).
    pub serial_fraction: f64,
}

/// One swept configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub bitwidth: u32,
    pub lanes: u32,
    pub pipeline: u32,
}

/// A configuration with its evaluated PPA.
#[derive(Clone, Debug)]
pub struct Evaluated {
    pub config: Config,
    pub point: DesignPoint,
}

/// The knob grid the paper sweeps ("bitwidth precision, parallelization,
/// pipelining").
pub fn knob_grid() -> Vec<Config> {
    let mut out = Vec::new();
    for &bitwidth in &[8u32, 16, 32] {
        for &lanes in &[1u32, 2, 4, 8, 16] {
            for &pipeline in &[1u32, 2, 4] {
                out.push(Config { bitwidth, lanes, pipeline });
            }
        }
    }
    out
}

/// Evaluate one configuration for one op mix.
pub fn evaluate(mix: &OpMix, cfg: &Config, eb: &EnergyBlocks, ab: &AreaBlocks) -> DesignPoint {
    let w = cfg.bitwidth as f64 / 16.0; // scale relative to 16-bit reference
    // Energy scaling: linear ops linear in width, multipliers quadratic.
    let comp_pj = eb.comp8_pj * (cfg.bitwidth as f64 / 8.0);
    let mac_pj = eb.mac16_pj * w * w;
    let sig_pj = eb.sigmoid_pj * w;
    // Pipelining adds register energy per op stage.
    let pipe_pj = eb.reg_pj * (cfg.pipeline as f64 - 1.0) * 0.5;

    let dynamic_nj = (mix.comparisons * (comp_pj + pipe_pj)
        + mix.macs * (mac_pj + pipe_pj)
        + mix.sigmoids * sig_pj) * 1e-3
        + eb.sram_read_nj(mix.sram_read_bytes)
        + eb.sram_write_nj(mix.sram_write_bytes);

    // Delay: parallel portion divides over lanes; serial portion doesn't.
    let total_ops = mix.comparisons + mix.macs + mix.sigmoids;
    let serial_ops = total_ops * mix.serial_fraction;
    let parallel_ops = total_ops - serial_ops;
    // Deeper pipelines close timing at higher effective clock until 1 GHz.
    let clock_scale = (cfg.pipeline as f64).min(2.0) / 2.0; // 1-stage = 0.5 GHz for wide mults
    let eff_clock = (eb.clock_ghz * clock_scale).min(eb.clock_ghz) * if cfg.bitwidth <= 16 { 2.0 } else { 1.0 };
    let eff_clock = eff_clock.min(eb.clock_ghz);
    let cycles = serial_ops + (parallel_ops / cfg.lanes as f64).ceil() + cfg.pipeline as f64;
    let delay_ns = cycles / eff_clock;

    // Area: lanes multiply compute blocks, storage fixed, pipeline regs.
    let lane_um2 = ab.comp8_um2 * (cfg.bitwidth as f64 / 8.0)
        + ab.mac16_um2 * w * w
        + if mix.sigmoids > 0.0 { ab.sigmoid_um2 * w } else { 0.0 };
    let area_um2 = lane_um2 * cfg.lanes as f64 * (1.0 + 0.1 * (cfg.pipeline as f64 - 1.0))
        + mix.storage_bytes * ab.sram_um2_per_byte
        + ab.control_um2;
    let area_mm2 = AreaBlocks::um2_to_mm2(area_um2);

    // Accuracy penalty for narrow datapaths (quantization): 8-bit trees are
    // fine (comparisons), 8-bit GEMMs lose a little. Encoded as a small
    // relative penalty the caller can fold into model accuracy.
    let acc = match cfg.bitwidth {
        8 => {
            if mix.macs > 0.0 {
                0.99
            } else {
                1.0
            }
        }
        _ => 1.0,
    };

    DesignPoint {
        energy_nj: dynamic_nj + eb.leakage_nj(area_mm2, cycles),
        delay_ns,
        area_mm2,
        accuracy: acc,
    }
}

/// Sweep the full knob grid and return all evaluated points.
pub fn sweep(mix: &OpMix, eb: &EnergyBlocks, ab: &AreaBlocks) -> Vec<Evaluated> {
    knob_grid()
        .into_iter()
        .map(|config| Evaluated { config, point: evaluate(mix, &config, eb, ab) })
        .collect()
}

/// Pareto-optimal subset of a sweep.
pub fn pareto_front(evals: &[Evaluated]) -> Vec<Evaluated> {
    let pts: Vec<DesignPoint> = evals.iter().map(|e| e.point).collect();
    let front = pareto(&pts);
    evals
        .iter()
        .filter(|e| front.iter().any(|p| *p == e.point))
        .cloned()
        .collect()
}

/// The paper's selection rule: minimum EDP among max-accuracy designs.
pub fn select_min_edp(evals: &[Evaluated]) -> Evaluated {
    let best_acc = evals.iter().map(|e| e.point.accuracy).fold(f64::NEG_INFINITY, f64::max);
    evals
        .iter()
        .filter(|e| e.point.accuracy >= best_acc - 1e-9)
        .min_by(|a, b| a.point.edp().partial_cmp(&b.point.edp()).unwrap())
        .cloned()
        .expect("non-empty sweep")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree_mix() -> OpMix {
        OpMix {
            comparisons: 128.0,
            macs: 0.0,
            sigmoids: 0.0,
            sram_read_bytes: 1024.0,
            sram_write_bytes: 64.0,
            storage_bytes: 6144.0,
            serial_fraction: 0.3,
        }
    }

    fn gemm_mix() -> OpMix {
        OpMix {
            comparisons: 10.0,
            macs: 100_000.0,
            sigmoids: 100.0,
            sram_read_bytes: 100_000.0,
            sram_write_bytes: 100.0,
            storage_bytes: 100_000.0,
            serial_fraction: 0.001,
        }
    }

    #[test]
    fn sweep_covers_grid() {
        let evals = sweep(&tree_mix(), &EnergyBlocks::default(), &AreaBlocks::default());
        assert_eq!(evals.len(), 3 * 5 * 3);
        assert!(evals.iter().all(|e| e.point.energy_nj > 0.0 && e.point.delay_ns > 0.0));
    }

    #[test]
    fn more_lanes_faster_bigger() {
        let eb = EnergyBlocks::default();
        let ab = AreaBlocks::default();
        let m = gemm_mix();
        let slow = evaluate(&m, &Config { bitwidth: 16, lanes: 1, pipeline: 2 }, &eb, &ab);
        let fast = evaluate(&m, &Config { bitwidth: 16, lanes: 16, pipeline: 2 }, &eb, &ab);
        assert!(fast.delay_ns < slow.delay_ns);
        assert!(fast.area_mm2 > slow.area_mm2);
    }

    #[test]
    fn wider_datapath_costs_energy() {
        let eb = EnergyBlocks::default();
        let ab = AreaBlocks::default();
        let m = gemm_mix();
        let narrow = evaluate(&m, &Config { bitwidth: 16, lanes: 4, pipeline: 2 }, &eb, &ab);
        let wide = evaluate(&m, &Config { bitwidth: 32, lanes: 4, pipeline: 2 }, &eb, &ab);
        assert!(wide.energy_nj > narrow.energy_nj);
    }

    #[test]
    fn pareto_smaller_than_sweep() {
        let evals = sweep(&gemm_mix(), &EnergyBlocks::default(), &AreaBlocks::default());
        let front = pareto_front(&evals);
        assert!(!front.is_empty());
        assert!(front.len() < evals.len());
    }

    #[test]
    fn selection_is_max_accuracy() {
        let evals = sweep(&gemm_mix(), &EnergyBlocks::default(), &AreaBlocks::default());
        let sel = select_min_edp(&evals);
        let best_acc = evals.iter().map(|e| e.point.accuracy).fold(f64::NEG_INFINITY, f64::max);
        assert!(sel.point.accuracy >= best_acc - 1e-9);
    }

    #[test]
    fn tree_mix_prefers_narrow_cheap_designs() {
        // For a comparator-only workload the selected design should not be
        // the widest datapath.
        let evals = sweep(&tree_mix(), &EnergyBlocks::default(), &AreaBlocks::default());
        let sel = select_min_edp(&evals);
        assert!(sel.config.bitwidth <= 16, "selected {:?}", sel.config);
    }
}
