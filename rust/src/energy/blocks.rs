//! 40 nm PPA library: per-operation energy (pJ), per-block area (µm²) and
//! latency (cycles at 1 GHz) for the basic computational blocks every
//! classifier is built from (paper §4.1 step 1).
//!
//! The numbers are anchored to published 40/45 nm measurements (Horowitz,
//! "Computing's energy problem", ISSCC'14: 32-bit int add ≈ 0.1 pJ, 32-bit
//! int multiply ≈ 3.1 pJ, 8 KB SRAM read ≈ 10 pJ/word; scaled to the 8/16
//! bit fixed-point datapaths the paper's accelerator uses, quadratic in
//! width for multipliers, linear for adders/comparators/memories). Leakage
//! is charged per mm² per ns, which makes *latency* part of the energy
//! story exactly as in the paper's EDP-driven design flow.

/// Per-op energies in picojoules, areas in µm², clock in GHz.
#[derive(Clone, Debug)]
pub struct EnergyBlocks {
    /// 8-bit fixed-point comparator (the DT node primitive).
    pub comp8_pj: f64,
    /// 16-bit fixed-point adder.
    pub add16_pj: f64,
    /// 16-bit fixed-point multiplier.
    pub mult16_pj: f64,
    /// 16-bit multiply-accumulate (mult + add, shared routing).
    pub mac16_pj: f64,
    /// Sigmoid / exp piecewise-linear LUT evaluation.
    pub sigmoid_pj: f64,
    /// SRAM read, per byte (small 4–8 KB banks).
    pub sram_read_pj_per_byte: f64,
    /// SRAM write, per byte.
    pub sram_write_pj_per_byte: f64,
    /// Register-file access (per 2-byte operand).
    pub reg_pj: f64,
    /// One req/ack handshake event between neighbouring groves.
    pub handshake_pj: f64,
    /// Static power (leakage + clock network), mW per mm², charged over
    /// the classification latency for the *active* area.
    pub leak_mw_per_mm2: f64,
    /// Clock frequency (the paper fixes 1 GHz for every classifier).
    pub clock_ghz: f64,
}

impl Default for EnergyBlocks {
    fn default() -> Self {
        EnergyBlocks {
            comp8_pj: 0.06,
            add16_pj: 0.06,
            mult16_pj: 0.4,
            mac16_pj: 0.45,
            sigmoid_pj: 0.5,
            sram_read_pj_per_byte: 0.15,
            sram_write_pj_per_byte: 0.25,
            reg_pj: 0.05,
            handshake_pj: 2.0,
            leak_mw_per_mm2: 110.0,
            clock_ghz: 1.0,
        }
    }
}

impl EnergyBlocks {
    /// Energy of `n` comparator ops, in nJ.
    pub fn comparisons_nj(&self, n: f64) -> f64 {
        n * self.comp8_pj * 1e-3
    }

    /// Energy of `n` MAC ops, in nJ.
    pub fn macs_nj(&self, n: f64) -> f64 {
        n * self.mac16_pj * 1e-3
    }

    /// Energy of reading `bytes` from SRAM, in nJ.
    pub fn sram_read_nj(&self, bytes: f64) -> f64 {
        bytes * self.sram_read_pj_per_byte * 1e-3
    }

    /// Energy of writing `bytes` to SRAM, in nJ.
    pub fn sram_write_nj(&self, bytes: f64) -> f64 {
        bytes * self.sram_write_pj_per_byte * 1e-3
    }

    /// Leakage energy in nJ for `area_mm2` over `cycles` at the block clock.
    pub fn leakage_nj(&self, area_mm2: f64, cycles: f64) -> f64 {
        // mW * ns = pJ; convert to nJ.
        let ns = cycles / self.clock_ghz;
        self.leak_mw_per_mm2 * area_mm2 * ns * 1e-3
    }

    /// Latency in ns for a cycle count.
    pub fn cycles_to_ns(&self, cycles: f64) -> f64 {
        cycles / self.clock_ghz
    }
}

/// Area of the basic blocks, µm² at 40 nm (synthesized standard-cell
/// estimates; SRAM from bit-cell area × overhead).
#[derive(Clone, Debug)]
pub struct AreaBlocks {
    pub comp8_um2: f64,
    pub add16_um2: f64,
    pub mult16_um2: f64,
    pub mac16_um2: f64,
    pub sigmoid_um2: f64,
    /// Per byte of SRAM.
    pub sram_um2_per_byte: f64,
    /// Per byte of register storage.
    pub reg_um2_per_byte: f64,
    /// Fixed per-unit control overhead (FSMs, decoders).
    pub control_um2: f64,
}

impl Default for AreaBlocks {
    fn default() -> Self {
        AreaBlocks {
            comp8_um2: 60.0,
            add16_um2: 120.0,
            mult16_um2: 1_600.0,
            mac16_um2: 1_900.0,
            sigmoid_um2: 900.0,
            sram_um2_per_byte: 2.4,
            reg_um2_per_byte: 18.0,
            control_um2: 6_000.0,
        }
    }
}

impl AreaBlocks {
    pub fn um2_to_mm2(um2: f64) -> f64 {
        um2 * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversions() {
        let b = EnergyBlocks::default();
        // 1000 comparisons at 0.06 pJ = 0.06 nJ.
        assert!((b.comparisons_nj(1000.0) - 0.06).abs() < 1e-9);
        // mult dominates add (standard at these widths).
        assert!(b.mult16_pj > 5.0 * b.add16_pj);
        // MAC ≈ mult + add.
        assert!(b.mac16_pj >= b.mult16_pj);
    }

    #[test]
    fn leakage_scales_with_area_and_time() {
        let b = EnergyBlocks::default();
        let e1 = b.leakage_nj(1.0, 100.0);
        let e2 = b.leakage_nj(2.0, 100.0);
        let e3 = b.leakage_nj(1.0, 200.0);
        assert!((e2 - 2.0 * e1).abs() < 1e-12);
        assert!((e3 - 2.0 * e1).abs() < 1e-12);
        // 1 mm² for 100 ns at 110 mW = 11 nJ.
        assert!((e1 - 11.0).abs() < 1e-9);
    }

    #[test]
    fn area_conversion() {
        assert_eq!(AreaBlocks::um2_to_mm2(1e6), 1.0);
    }

    #[test]
    fn comparator_cheapest_block() {
        let b = EnergyBlocks::default();
        assert!(b.comp8_pj <= b.add16_pj);
        assert!(b.comp8_pj < b.mac16_pj / 5.0);
        assert!(b.comp8_pj < b.sigmoid_pj);
    }
}
