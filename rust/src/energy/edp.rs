//! Energy-delay-product helpers. The paper designs every classifier for
//! minimum EDP at maximum accuracy (§4.1) and uses EDP as the budget
//! metric during training (step 2).

/// EDP in nJ·ns.
#[inline]
pub fn edp(energy_nj: f64, delay_ns: f64) -> f64 {
    energy_nj * delay_ns
}

/// A point in (energy, delay, area, accuracy) design space.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DesignPoint {
    pub energy_nj: f64,
    pub delay_ns: f64,
    pub area_mm2: f64,
    pub accuracy: f64,
}

impl DesignPoint {
    pub fn edp(&self) -> f64 {
        edp(self.energy_nj, self.delay_ns)
    }

    /// `self` dominates `other` when it is no worse in energy, delay and
    /// area, and strictly better in at least one (accuracy ties broken
    /// separately by the caller).
    pub fn dominates(&self, other: &DesignPoint) -> bool {
        let no_worse = self.energy_nj <= other.energy_nj
            && self.delay_ns <= other.delay_ns
            && self.area_mm2 <= other.area_mm2;
        let better = self.energy_nj < other.energy_nj
            || self.delay_ns < other.delay_ns
            || self.area_mm2 < other.area_mm2;
        no_worse && better
    }
}

/// Pareto frontier (non-dominated subset), preserving input order.
pub fn pareto(points: &[DesignPoint]) -> Vec<DesignPoint> {
    points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .copied()
        .collect()
}

/// Minimum-EDP point among designs whose accuracy is within `tol` of the
/// best accuracy — the paper's "minimum EDP at maximum accuracy" rule.
pub fn min_edp_at_max_accuracy(points: &[DesignPoint], tol: f64) -> Option<DesignPoint> {
    let best_acc = points.iter().map(|p| p.accuracy).fold(f64::NEG_INFINITY, f64::max);
    points
        .iter()
        .filter(|p| p.accuracy >= best_acc - tol)
        .min_by(|a, b| a.edp().partial_cmp(&b.edp()).unwrap())
        .copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(e: f64, d: f64, a: f64, acc: f64) -> DesignPoint {
        DesignPoint { energy_nj: e, delay_ns: d, area_mm2: a, accuracy: acc }
    }

    #[test]
    fn dominance() {
        assert!(p(1.0, 1.0, 1.0, 0.9).dominates(&p(2.0, 2.0, 2.0, 0.9)));
        assert!(!p(1.0, 3.0, 1.0, 0.9).dominates(&p(2.0, 2.0, 2.0, 0.9)));
        assert!(!p(1.0, 1.0, 1.0, 0.9).dominates(&p(1.0, 1.0, 1.0, 0.9)));
    }

    #[test]
    fn pareto_filters_dominated() {
        let pts = vec![
            p(1.0, 4.0, 1.0, 0.9),
            p(2.0, 2.0, 1.0, 0.9),
            p(4.0, 1.0, 1.0, 0.9),
            p(3.0, 3.0, 1.0, 0.9), // dominated by (2,2)
        ];
        let front = pareto(&pts);
        assert_eq!(front.len(), 3);
        assert!(!front.contains(&pts[3]));
    }

    #[test]
    fn min_edp_respects_accuracy() {
        let pts = vec![
            p(1.0, 1.0, 1.0, 0.5),  // cheap but inaccurate
            p(10.0, 2.0, 1.0, 0.95),
            p(8.0, 2.0, 1.0, 0.94), // within 0.02 of best, cheaper EDP
        ];
        let best = min_edp_at_max_accuracy(&pts, 0.02).unwrap();
        assert_eq!(best.energy_nj, 8.0);
    }

    #[test]
    fn edp_multiplies() {
        assert_eq!(edp(3.0, 4.0), 12.0);
    }
}
