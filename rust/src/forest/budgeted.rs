//! Feature-budgeted random forest training — the substrate the paper's
//! training step builds on (step 2 of §4.1, citing Nan/Wang/Saligrama,
//! "Feature-Budgeted Random Forest", ICML'15 [11]).
//!
//! The idea: each feature has an acquisition cost (for the paper this is
//! the PPA energy of reading + comparing it); trees are grown to maximize
//! impurity reduction *per unit cost*, and a validation-measured budget
//! constraint selects the operating design. We implement the greedy
//! cost-penalized split rule (see [`crate::dt::builder`]) plus the budget
//! search loop: grow forests at increasing cost weights, measure
//! (cost, accuracy) on validation data, and return the best
//! accuracy design under the budget.

use super::rf::{ForestParams, RandomForest};
use crate::api::ProbMatrix;
use crate::data::split::stratified_holdout;
use crate::data::Split;
use crate::exec::{BatchPlan, ForestArena, Reduce};

/// One evaluated design point of the budget sweep.
#[derive(Clone, Debug)]
pub struct BudgetPoint {
    pub cost_weight: f32,
    /// Mean acquisition cost per prediction on validation data.
    pub avg_cost: f64,
    pub val_accuracy: f64,
}

/// Result of budgeted training. The chosen forest is packed into a
/// [`ForestArena`] so the budgeted design serves batches through the same
/// tiled kernel as every other tree-based path.
pub struct BudgetedForest {
    pub forest: RandomForest,
    pub arena: ForestArena,
    pub chosen: BudgetPoint,
    pub sweep: Vec<BudgetPoint>,
}

impl BudgetedForest {
    /// Batch-tiled probability-average prediction on the chosen design.
    pub fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix {
        BatchPlan::new(&self.arena, Reduce::ProbAverage).execute(x, n)
    }
}

/// Mean per-prediction feature-acquisition cost of a packed forest:
/// every *distinct* feature read while routing a sample through all trees
/// is charged once (sensor/feature acquisition semantics of [11]). Dead
/// complete-tree padding slots are skipped — only live trained splits
/// acquire features, so the totals equal the sparse-tree walk this
/// replaced. The arena walk itself now exits at each tree's live depth
/// (`ForestArena::walk_tree`), so on depth-heterogeneous budget sweeps
/// the measurement pass is cheaper while charging identical costs.
pub fn avg_acquisition_cost(arena: &ForestArena, split: &Split, feature_cost: &[f32]) -> f64 {
    if split.is_empty() {
        return 0.0;
    }
    let mut total = 0.0f64;
    let mut seen = vec![false; arena.n_features()];
    for i in 0..split.len() {
        let x = split.row(i);
        seen.iter_mut().for_each(|s| *s = false);
        for t in 0..arena.n_trees() {
            arena.walk_tree(t, x, |f, live| {
                if live && !seen[f] {
                    seen[f] = true;
                    total += feature_cost[f] as f64;
                }
            });
        }
    }
    total / split.len() as f64
}

/// Train under a feature-acquisition budget.
///
/// * `feature_cost[f]` — cost of acquiring feature `f` (energy units).
/// * `budget` — maximum admissible `avg_acquisition_cost` on validation.
///
/// Sweeps cost weights from 0 (unconstrained RF) upward; returns the
/// highest-validation-accuracy design whose measured cost fits the budget
/// (falling back to the cheapest design if none fits — graceful, matching
/// the paper's "if several designs meet the constraint choose the most
/// accurate" rule).
pub fn fit_budgeted(
    data: &Split,
    base: &ForestParams,
    feature_cost: &[f32],
    budget: f64,
    seed: u64,
) -> BudgetedForest {
    assert_eq!(feature_cost.len(), data.n_features);
    let (train, val) = stratified_holdout(data, 0.2, seed ^ 0xB0D6E7);
    let weights = [0.0f32, 0.001, 0.004, 0.016, 0.064, 0.25];

    let mut sweep = Vec::with_capacity(weights.len());
    let mut candidates: Vec<(BudgetPoint, RandomForest)> = Vec::new();
    for &w in &weights {
        let mut params = base.clone();
        params.tree.feature_cost = feature_cost.to_vec();
        params.tree.cost_weight = w;
        let rf = RandomForest::fit(&train, &params, seed);
        // Both validation measurements run on the packed arena: the
        // batch-kernel probabilities are bit-identical to
        // `RandomForest::predict_proba`, and the acquisition walk skips
        // dead padding slots, so the sweep numbers are unchanged.
        let arena = ForestArena::from_forest(&rf, rf.max_depth());
        let probs = BatchPlan::new(&arena, Reduce::ProbAverage).execute(&val.x, val.len());
        let point = BudgetPoint {
            cost_weight: w,
            avg_cost: avg_acquisition_cost(&arena, &val, feature_cost),
            val_accuracy: crate::util::stats::accuracy(&probs.argmax_rows(), &val.y),
        };
        sweep.push(point.clone());
        candidates.push((point, rf));
    }

    // Most accurate within budget, else cheapest.
    let within: Vec<&(BudgetPoint, RandomForest)> =
        candidates.iter().filter(|(p, _)| p.avg_cost <= budget).collect();
    let chosen_idx = if !within.is_empty() {
        let best = within
            .iter()
            .max_by(|a, b| a.0.val_accuracy.partial_cmp(&b.0.val_accuracy).unwrap())
            .unwrap();
        candidates
            .iter()
            .position(|(p, _)| p.cost_weight == best.0.cost_weight)
            .unwrap()
    } else {
        candidates
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.0.avg_cost.partial_cmp(&b.0.avg_cost).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    };

    // Refit the chosen design on the full training split.
    let mut params = base.clone();
    params.tree.feature_cost = feature_cost.to_vec();
    params.tree.cost_weight = candidates[chosen_idx].0.cost_weight;
    let forest = RandomForest::fit(data, &params, seed);
    let arena = ForestArena::from_forest(&forest, forest.max_depth());
    BudgetedForest { forest, arena, chosen: candidates[chosen_idx].0.clone(), sweep }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    #[test]
    fn unconstrained_sweep_point_is_plain_rf() {
        let ds = generate(&DatasetProfile::demo(), 71);
        let costs = vec![1.0f32; ds.train.n_features];
        let b = fit_budgeted(&ds.train, &ForestParams::small(), &costs, f64::INFINITY, 1);
        assert_eq!(b.sweep[0].cost_weight, 0.0);
        // With infinite budget the best-accuracy point is chosen.
        let best_acc =
            b.sweep.iter().map(|p| p.val_accuracy).fold(f64::NEG_INFINITY, f64::max);
        assert!((b.chosen.val_accuracy - best_acc).abs() < 1e-9);
    }

    #[test]
    fn tight_budget_reduces_cost() {
        let ds = generate(&DatasetProfile::demo(), 72);
        let costs = vec![1.0f32; ds.train.n_features];
        let loose = fit_budgeted(&ds.train, &ForestParams::small(), &costs, f64::INFINITY, 2);
        // Budget = half of the unconstrained cost.
        let tight_budget = loose.sweep[0].avg_cost * 0.5;
        let tight = fit_budgeted(&ds.train, &ForestParams::small(), &costs, tight_budget, 2);
        assert!(
            tight.chosen.avg_cost <= loose.chosen.avg_cost + 1e-9,
            "tight {} loose {}",
            tight.chosen.avg_cost,
            loose.chosen.avg_cost
        );
    }

    #[test]
    fn cost_weight_monotone_cost_trend() {
        let ds = generate(&DatasetProfile::demo(), 73);
        let costs = vec![1.0f32; ds.train.n_features];
        let b = fit_budgeted(&ds.train, &ForestParams::small(), &costs, f64::INFINITY, 3);
        // Strong penalty should not *increase* acquisition cost vs none.
        let first = b.sweep.first().unwrap().avg_cost;
        let last = b.sweep.last().unwrap().avg_cost;
        assert!(last <= first + 1e-6, "first {first} last {last}");
    }

    #[test]
    fn acquisition_cost_counts_distinct_features_once() {
        let ds = generate(&DatasetProfile::demo(), 74);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 4);
        let arena = ForestArena::from_forest(&rf, rf.max_depth());
        let costs = vec![1.0f32; ds.train.n_features];
        let c = avg_acquisition_cost(&arena, &ds.test, &costs);
        // Can't exceed the number of features when each costs 1.
        assert!(c <= ds.train.n_features as f64);
        assert!(c > 0.0);
    }

    #[test]
    fn arena_acquisition_cost_matches_sparse_walk() {
        // The arena walk skips dead padding slots, so it must charge
        // exactly what the original sparse-tree walk charged.
        let ds = generate(&DatasetProfile::demo(), 75);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 5);
        let arena = ForestArena::from_forest(&rf, rf.max_depth());
        let costs: Vec<f32> = (0..ds.train.n_features).map(|f| 1.0 + f as f32 * 0.1).collect();
        let via_arena = avg_acquisition_cost(&arena, &ds.test, &costs);

        let mut total = 0.0f64;
        let mut seen = vec![false; rf.n_features];
        for i in 0..ds.test.len() {
            let x = ds.test.row(i);
            seen.iter_mut().for_each(|s| *s = false);
            for tree in &rf.trees {
                let mut idx = 0usize;
                loop {
                    let n = &tree.nodes[idx];
                    if n.is_leaf() {
                        break;
                    }
                    let f = n.feature as usize;
                    if !seen[f] {
                        seen[f] = true;
                        total += costs[f] as f64;
                    }
                    idx = if x[f] <= n.threshold {
                        n.left as usize
                    } else {
                        n.left as usize + 1
                    };
                }
            }
        }
        let via_sparse = total / ds.test.len() as f64;
        assert!(
            (via_arena - via_sparse).abs() < 1e-9,
            "arena {via_arena} vs sparse {via_sparse}"
        );
    }

    #[test]
    fn budgeted_arena_serves_chosen_forest() {
        let ds = generate(&DatasetProfile::demo(), 76);
        let costs = vec![1.0f32; ds.train.n_features];
        let b = fit_budgeted(&ds.train, &ForestParams::small(), &costs, f64::INFINITY, 6);
        let probs = b.predict_proba_batch(&ds.test.x, ds.test.len());
        for i in (0..ds.test.len()).step_by(9) {
            let reference = b.forest.predict_proba(ds.test.row(i));
            assert_eq!(probs.row(i), &reference[..], "row {i}");
        }
    }
}
