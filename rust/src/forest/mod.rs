//! Random forests: bagged CART ensembles with majority voting (the
//! conventional design of paper §3.1) plus the feature-budgeted training
//! mode the paper builds on ([11], Nan/Wang/Saligrama ICML'15).

pub mod budgeted;
pub mod rf;

pub use rf::{ForestParams, RandomForest, VoteMode};
