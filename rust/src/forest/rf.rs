//! Conventional random forest (paper §3.1).
//!
//! Bagging + per-node feature subsampling over CART trees. Two aggregation
//! modes, mirroring the paper's explicit contrast (§3.2.1): conventional RF
//! puts hard per-tree labels to a **majority vote**, while FoG averages
//! per-tree **probability distributions** — `VoteMode` selects between
//! them so the contrast is testable.

use crate::data::Split;
use crate::dt::builder::{fit_tree, TreeParams};
use crate::dt::{DecisionTree, FlatTree};
use crate::util::rng::Rng;
use crate::util::threadpool::{num_threads, par_map, par_map_with};

/// Aggregation rule across trees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VoteMode {
    /// Hard per-tree argmax labels, majority vote (conventional RF).
    Majority,
    /// Average of per-tree probability distributions (what FoG groves do).
    ProbAverage,
}

/// Forest hyper-parameters.
#[derive(Clone, Debug)]
pub struct ForestParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Bootstrap-sample the training set per tree (true = classic bagging).
    pub bootstrap: bool,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams { n_trees: 16, tree: TreeParams::default(), bootstrap: true }
    }
}

impl ForestParams {
    /// Small fast forest for tests/doc examples.
    pub fn small() -> Self {
        ForestParams {
            n_trees: 8,
            tree: TreeParams { max_depth: 6, ..Default::default() },
            bootstrap: true,
        }
    }
}

/// A trained random forest.
#[derive(Clone, Debug)]
pub struct RandomForest {
    pub trees: Vec<DecisionTree>,
    pub n_features: usize,
    pub n_classes: usize,
    pub params: ForestParams,
}

impl RandomForest {
    /// Train with bagging; trees are fit in parallel, each from a forked
    /// deterministic RNG stream, so results are reproducible regardless of
    /// thread count. Uses the pool's default thread count (the
    /// `FOG_THREADS` env var is consulted only here, at pool
    /// construction); use [`RandomForest::fit_with_threads`] to pin an
    /// explicit count.
    pub fn fit(data: &Split, params: &ForestParams, seed: u64) -> RandomForest {
        Self::fit_with_threads(data, params, seed, num_threads())
    }

    /// [`RandomForest::fit`] with an explicit training thread count —
    /// the deterministic-parallelism tests pass it directly instead of
    /// mutating `FOG_THREADS` process-wide (which races the parallel test
    /// harness).
    pub fn fit_with_threads(
        data: &Split,
        params: &ForestParams,
        seed: u64,
        n_threads: usize,
    ) -> RandomForest {
        assert!(params.n_trees > 0);
        assert!(!data.is_empty());
        let mut root = Rng::new(seed);
        let tree_seeds: Vec<u64> = (0..params.n_trees).map(|_| root.next_u64()).collect();
        let trees = par_map_with(n_threads, params.n_trees, |t| {
            let mut rng = Rng::new(tree_seeds[t]);
            let samples: Vec<usize> = if params.bootstrap {
                rng.bootstrap(data.len())
            } else {
                (0..data.len()).collect()
            };
            fit_tree(data, &samples, &params.tree, &mut rng)
        });
        RandomForest {
            trees,
            n_features: data.n_features,
            n_classes: data.n_classes,
            params: params.clone(),
        }
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Maximum tree depth in the forest (determines the flat-pad depth).
    pub fn max_depth(&self) -> usize {
        self.trees.iter().map(|t| t.depth).max().unwrap_or(0)
    }

    /// Averaged class-probability prediction over all trees.
    pub fn predict_proba(&self, x: &[f32]) -> Vec<f32> {
        let mut acc = vec![0.0f32; self.n_classes];
        for t in &self.trees {
            for (a, &p) in acc.iter_mut().zip(t.predict_proba(x)) {
                *a += p;
            }
        }
        let inv = 1.0 / self.trees.len() as f32;
        acc.iter_mut().for_each(|a| *a *= inv);
        acc
    }

    /// Predict one sample under the given aggregation mode.
    pub fn predict_with(&self, x: &[f32], mode: VoteMode) -> usize {
        match mode {
            VoteMode::ProbAverage => crate::util::argmax(&self.predict_proba(x)),
            VoteMode::Majority => {
                let mut votes = vec![0usize; self.n_classes];
                for t in &self.trees {
                    votes[t.predict(x)] += 1;
                }
                votes
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &v)| v)
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            }
        }
    }

    /// Majority-vote prediction (the paper's conventional RF).
    pub fn predict(&self, x: &[f32]) -> usize {
        self.predict_with(x, VoteMode::Majority)
    }

    /// Batch accuracy under a vote mode.
    pub fn accuracy(&self, split: &Split, mode: VoteMode) -> f64 {
        let preds = par_map(split.len(), |i| self.predict_with(split.row(i), mode));
        crate::util::stats::accuracy(&preds, &split.y)
    }

    /// Average comparator ops per input (drives the energy model):
    /// sum over trees of traversed depth.
    pub fn avg_comparisons(&self, split: &Split) -> f64 {
        if split.is_empty() {
            return 0.0;
        }
        let totals = par_map(split.len(), |i| {
            let mut ops = 0usize;
            for t in &self.trees {
                let (_, c) = t.predict_proba_counted(split.row(i));
                ops += c;
            }
            ops
        });
        totals.iter().sum::<usize>() as f64 / split.len() as f64
    }

    /// Flatten every tree to the common padded depth (for the accelerator
    /// path and for FoG grove export).
    pub fn flatten(&self, pad_depth: usize) -> Vec<FlatTree> {
        let d = pad_depth.max(self.max_depth());
        self.trees.iter().map(|t| FlatTree::from_tree(t, d)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    #[test]
    fn forest_beats_single_tree() {
        let ds = generate(&DatasetProfile::demo(), 61);
        let params = ForestParams::small();
        let rf = RandomForest::fit(&ds.train, &params, 1);
        let forest_acc = rf.accuracy(&ds.test, VoteMode::Majority);

        let mut rng = Rng::new(2);
        let idx: Vec<usize> = (0..ds.train.len()).collect();
        let single = fit_tree(&ds.train, &idx, &params.tree, &mut rng);
        let preds: Vec<usize> =
            (0..ds.test.len()).map(|i| single.predict(ds.test.row(i))).collect();
        let single_acc = crate::util::stats::accuracy(&preds, &ds.test.y);

        assert!(
            forest_acc >= single_acc - 0.02,
            "forest {forest_acc} vs single {single_acc}"
        );
        assert!(forest_acc > 0.6, "forest acc {forest_acc}");
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // Explicit thread counts — no process-wide FOG_THREADS mutation,
        // which raced the other tests running in parallel.
        let ds = generate(&DatasetProfile::demo(), 62);
        let rf1 = RandomForest::fit(&ds.train, &ForestParams::small(), 7);
        for n_threads in [1, 2, 5] {
            let rf2 =
                RandomForest::fit_with_threads(&ds.train, &ForestParams::small(), 7, n_threads);
            for (a, b) in rf1.trees.iter().zip(&rf2.trees) {
                assert_eq!(a.nodes.len(), b.nodes.len());
                for (na, nb) in a.nodes.iter().zip(&b.nodes) {
                    assert_eq!(na.feature, nb.feature);
                    assert_eq!(na.threshold, nb.threshold);
                }
            }
        }
    }

    #[test]
    fn proba_normalized() {
        let ds = generate(&DatasetProfile::demo(), 63);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 3);
        for i in 0..20.min(ds.test.len()) {
            let p = rf.predict_proba(ds.test.row(i));
            let s: f32 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "sum {s}");
        }
    }

    #[test]
    fn vote_modes_mostly_agree() {
        let ds = generate(&DatasetProfile::demo(), 64);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 4);
        let a = rf.accuracy(&ds.test, VoteMode::Majority);
        let b = rf.accuracy(&ds.test, VoteMode::ProbAverage);
        assert!((a - b).abs() < 0.1, "majority {a} vs prob-avg {b}");
    }

    #[test]
    fn avg_comparisons_bounded_by_depth() {
        let ds = generate(&DatasetProfile::demo(), 65);
        let params = ForestParams {
            n_trees: 4,
            tree: TreeParams { max_depth: 5, ..Default::default() },
            bootstrap: true,
        };
        let rf = RandomForest::fit(&ds.train, &params, 5);
        let avg = rf.avg_comparisons(&ds.test);
        assert!(avg > 0.0);
        assert!(avg <= (4 * 5) as f64, "avg {avg}");
    }

    #[test]
    fn flatten_preserves_predictions() {
        let ds = generate(&DatasetProfile::demo(), 66);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 6);
        let flats = rf.flatten(rf.max_depth());
        for i in 0..30.min(ds.test.len()) {
            let x = ds.test.row(i);
            for (t, f) in rf.trees.iter().zip(&flats) {
                assert_eq!(t.predict(x), f.predict(x));
            }
        }
    }
}
