//! CART training: greedy recursive partitioning with gini or entropy
//! impurity, random feature subsampling per node (the "random" in random
//! forest), and optional per-feature acquisition costs for budgeted
//! training (the paper trains with the feature-budgeted RF of [11]).

use super::tree::{DecisionTree, Node};
use crate::data::Split;
use crate::util::rng::Rng;

/// Impurity criterion for split selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Criterion {
    Gini,
    Entropy,
}

/// Training hyper-parameters for a single tree.
#[derive(Clone, Debug)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub min_samples_leaf: usize,
    /// Features examined per node; 0 = sqrt(n_features) (RF default).
    pub max_features: usize,
    pub criterion: Criterion,
    /// Per-feature acquisition cost (empty = free). A candidate split on a
    /// feature not yet used along the current path is penalized by
    /// `cost_weight * feature_cost[f]` — the mechanism of budgeted RF [11].
    pub feature_cost: Vec<f32>,
    pub cost_weight: f32,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 8,
            min_samples_split: 2,
            min_samples_leaf: 1,
            max_features: 0,
            criterion: Criterion::Gini,
            feature_cost: Vec::new(),
            cost_weight: 0.0,
        }
    }
}

impl TreeParams {
    fn mtry(&self, n_features: usize) -> usize {
        if self.max_features == 0 {
            ((n_features as f64).sqrt().ceil() as usize).clamp(1, n_features)
        } else {
            self.max_features.min(n_features)
        }
    }
}

fn impurity(counts: &[usize], total: usize, criterion: Criterion) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    match criterion {
        Criterion::Gini => {
            let mut g = 1.0;
            for &c in counts {
                let p = c as f64 / t;
                g -= p * p;
            }
            g
        }
        Criterion::Entropy => {
            let mut h = 0.0;
            for &c in counts {
                if c > 0 {
                    let p = c as f64 / t;
                    h -= p * p.log2();
                }
            }
            h
        }
    }
}

struct BestSplit {
    feature: usize,
    threshold: f32,
    gain: f64,
}

/// Work item: node index in the output vec + the sample indices reaching it.
struct WorkItem {
    node_idx: usize,
    samples: Vec<usize>,
    depth: usize,
    /// Features already paid for along this path (budgeted training).
    path_features: Vec<usize>,
}

/// Train a CART tree on `data` restricted to `samples` (bootstrap indices;
/// pass `0..n` for the full set).
pub fn fit_tree(data: &Split, samples: &[usize], params: &TreeParams, rng: &mut Rng) -> DecisionTree {
    assert!(!samples.is_empty(), "fit_tree: no samples");
    let n_classes = data.n_classes;
    let mut nodes: Vec<Node> = Vec::new();
    nodes.push(Node { feature: u32::MAX, threshold: 0.0, left: 0, dist: vec![] });

    let mut max_depth_seen = 0usize;
    let mut stack = vec![WorkItem {
        node_idx: 0,
        samples: samples.to_vec(),
        depth: 0,
        path_features: Vec::new(),
    }];

    // Reusable scratch for split search.
    let mut order: Vec<(f32, usize)> = Vec::new();

    while let Some(item) = stack.pop() {
        max_depth_seen = max_depth_seen.max(item.depth);
        let counts = class_counts(data, &item.samples, n_classes);
        let total = item.samples.len();
        let node_impurity = impurity(&counts, total, params.criterion);

        let make_leaf = item.depth >= params.max_depth
            || total < params.min_samples_split
            || node_impurity <= 1e-12;

        let best = if make_leaf {
            None
        } else {
            find_best_split(data, &item.samples, &counts, params, rng, &mut order, &item.path_features)
        };

        match best {
            None => {
                nodes[item.node_idx] = Node {
                    feature: u32::MAX,
                    threshold: 0.0,
                    left: 0,
                    dist: to_dist(&counts, total),
                };
            }
            Some(b) => {
                // Partition samples.
                let mut left_samples = Vec::with_capacity(total / 2);
                let mut right_samples = Vec::with_capacity(total / 2);
                for &s in &item.samples {
                    if data.row(s)[b.feature] <= b.threshold {
                        left_samples.push(s);
                    } else {
                        right_samples.push(s);
                    }
                }
                debug_assert!(!left_samples.is_empty() && !right_samples.is_empty());
                let left_idx = nodes.len();
                nodes.push(Node { feature: u32::MAX, threshold: 0.0, left: 0, dist: vec![] });
                nodes.push(Node { feature: u32::MAX, threshold: 0.0, left: 0, dist: vec![] });
                nodes[item.node_idx] = Node {
                    feature: b.feature as u32,
                    threshold: b.threshold,
                    left: left_idx as u32,
                    dist: vec![],
                };
                let mut path = item.path_features.clone();
                if !path.contains(&b.feature) {
                    path.push(b.feature);
                }
                stack.push(WorkItem {
                    node_idx: left_idx,
                    samples: left_samples,
                    depth: item.depth + 1,
                    path_features: path.clone(),
                });
                stack.push(WorkItem {
                    node_idx: left_idx + 1,
                    samples: right_samples,
                    depth: item.depth + 1,
                    path_features: path,
                });
            }
        }
    }

    let tree = DecisionTree {
        nodes,
        n_features: data.n_features,
        n_classes,
        depth: max_depth_seen,
    };
    debug_assert!(tree.validate().is_ok(), "{:?}", tree.validate());
    tree
}

fn class_counts(data: &Split, samples: &[usize], n_classes: usize) -> Vec<usize> {
    let mut counts = vec![0usize; n_classes];
    for &s in samples {
        counts[data.y[s]] += 1;
    }
    counts
}

fn to_dist(counts: &[usize], total: usize) -> Vec<f32> {
    let t = total.max(1) as f32;
    counts.iter().map(|&c| c as f32 / t).collect()
}

#[allow(clippy::too_many_arguments)]
fn find_best_split(
    data: &Split,
    samples: &[usize],
    parent_counts: &[usize],
    params: &TreeParams,
    rng: &mut Rng,
    order: &mut Vec<(f32, usize)>,
    path_features: &[usize],
) -> Option<BestSplit> {
    let n_classes = data.n_classes;
    let total = samples.len();
    let parent_imp = impurity(parent_counts, total, params.criterion);
    let mtry = params.mtry(data.n_features);
    let candidates = rng.sample_indices(data.n_features, mtry);

    let mut best: Option<BestSplit> = None;
    let mut left_counts = vec![0usize; n_classes];

    for &f in &candidates {
        // Sort samples by feature value.
        order.clear();
        order.extend(samples.iter().map(|&s| (data.row(s)[f], data.y[s])));
        order.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        if order[0].0 == order[total - 1].0 {
            continue; // constant feature in this node
        }
        // Budgeted-training penalty for acquiring a new feature.
        let penalty = if params.cost_weight > 0.0
            && !params.feature_cost.is_empty()
            && !path_features.contains(&f)
        {
            (params.cost_weight * params.feature_cost[f]) as f64
        } else {
            0.0
        };

        left_counts.iter_mut().for_each(|c| *c = 0);
        let mut n_left = 0usize;
        for w in 0..total - 1 {
            left_counts[order[w].1] += 1;
            n_left += 1;
            // Only split between distinct values.
            if order[w].0 == order[w + 1].0 {
                continue;
            }
            let n_right = total - n_left;
            if n_left < params.min_samples_leaf || n_right < params.min_samples_leaf {
                continue;
            }
            let mut right_counts_imp = 0.0;
            // impurity of right side from parent - left
            let mut right_counts = [0usize; 64];
            let use_stack = n_classes <= 64;
            let imp_l = impurity(&left_counts, n_left, params.criterion);
            let imp_r = if use_stack {
                for c in 0..n_classes {
                    right_counts[c] = parent_counts[c] - left_counts[c];
                }
                impurity(&right_counts[..n_classes], n_right, params.criterion)
            } else {
                let rc: Vec<usize> =
                    parent_counts.iter().zip(&left_counts).map(|(p, l)| p - l).collect();
                right_counts_imp = impurity(&rc, n_right, params.criterion);
                right_counts_imp
            };
            let _ = right_counts_imp;
            let wl = n_left as f64 / total as f64;
            let gain = parent_imp - wl * imp_l - (1.0 - wl) * imp_r - penalty;
            if gain > best.as_ref().map(|b| b.gain).unwrap_or(1e-9) {
                // Midpoint threshold, robust to fp: guaranteed to separate
                // the two sorted values.
                let thr = 0.5 * (order[w].0 + order[w + 1].0);
                let thr = if thr > order[w].0 { thr } else { order[w].0 };
                best = Some(BestSplit { feature: f, threshold: thr, gain });
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    fn all_idx(n: usize) -> Vec<usize> {
        (0..n).collect()
    }

    #[test]
    fn perfectly_separable_reaches_zero_error() {
        // Two clouds far apart on feature 0.
        let mut s = Split::new(2, 2);
        let mut rng = Rng::new(1);
        for i in 0..100 {
            let y = i % 2;
            let x0 = if y == 0 { -5.0 } else { 5.0 };
            s.push(&[x0 + rng.gen_normal() * 0.1, rng.gen_normal()], y);
        }
        let t = fit_tree(&s, &all_idx(100), &TreeParams::default(), &mut rng);
        for i in 0..100 {
            assert_eq!(t.predict(s.row(i)), s.y[i]);
        }
        assert!(t.depth <= 3, "depth {}", t.depth);
    }

    #[test]
    fn respects_max_depth() {
        let ds = generate(&DatasetProfile::demo(), 31);
        let mut rng = Rng::new(2);
        let params = TreeParams { max_depth: 3, ..Default::default() };
        let t = fit_tree(&ds.train, &all_idx(ds.train.len()), &params, &mut rng);
        assert!(t.depth <= 3);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn respects_min_samples_leaf() {
        let ds = generate(&DatasetProfile::demo(), 32);
        let mut rng = Rng::new(3);
        let params = TreeParams { min_samples_leaf: 20, max_depth: 12, ..Default::default() };
        let t = fit_tree(&ds.train, &all_idx(ds.train.len()), &params, &mut rng);
        // Count samples per leaf by rerouting train data.
        let mut leaf_counts = std::collections::HashMap::new();
        for i in 0..ds.train.len() {
            let mut idx = 0usize;
            loop {
                let n = &t.nodes[idx];
                if n.is_leaf() {
                    *leaf_counts.entry(idx).or_insert(0usize) += 1;
                    break;
                }
                idx = if ds.train.row(i)[n.feature as usize] <= n.threshold {
                    n.left as usize
                } else {
                    n.left as usize + 1
                };
            }
        }
        for (_, &c) in leaf_counts.iter() {
            assert!(c >= 20, "leaf with {c} samples");
        }
    }

    #[test]
    fn entropy_also_works() {
        let ds = generate(&DatasetProfile::demo(), 33);
        let mut rng = Rng::new(4);
        let params = TreeParams { criterion: Criterion::Entropy, ..Default::default() };
        let t = fit_tree(&ds.train, &all_idx(ds.train.len()), &params, &mut rng);
        assert!(t.validate().is_ok());
        // Better than chance on train.
        let preds: Vec<usize> = (0..ds.train.len()).map(|i| t.predict(ds.train.row(i))).collect();
        let acc = crate::util::stats::accuracy(&preds, &ds.train.y);
        assert!(acc > 0.6, "train acc {acc}");
    }

    #[test]
    fn feature_cost_discourages_expensive_features() {
        // Feature 0 and 1 are equally predictive; make feature 0 costly.
        let mut s = Split::new(2, 2);
        let mut rng = Rng::new(5);
        for i in 0..200 {
            let y = i % 2;
            let v = if y == 0 { -3.0 } else { 3.0 };
            s.push(&[v + rng.gen_normal() * 0.5, v + rng.gen_normal() * 0.5], y);
        }
        let params = TreeParams {
            max_depth: 1,
            max_features: 2,
            feature_cost: vec![10.0, 0.0],
            cost_weight: 0.04,
            ..Default::default()
        };
        let mut used0 = 0;
        for seed in 0..10 {
            let mut r = Rng::new(seed);
            let t = fit_tree(&s, &(0..200).collect::<Vec<_>>(), &params, &mut r);
            if t.used_features().contains(&0) {
                used0 += 1;
            }
        }
        assert!(used0 <= 2, "expensive feature chosen {used0}/10 times");
    }

    #[test]
    fn single_class_becomes_leaf() {
        let mut s = Split::new(2, 3);
        for _ in 0..10 {
            s.push(&[1.0, 2.0], 1);
        }
        let mut rng = Rng::new(6);
        let t = fit_tree(&s, &all_idx(10), &TreeParams::default(), &mut rng);
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.predict(&[0.0, 0.0]), 1);
    }
}
