//! Decision trees: CART training, sparse in-memory representation,
//! flattened complete-tree arrays (the layout shared with the Pallas
//! kernel and the grove micro-architecture), and serialization.

pub mod builder;
pub mod export;
pub mod flat;
pub mod tree;

pub use builder::TreeParams;
pub use flat::FlatTree;
pub use tree::DecisionTree;
