//! Serialization of flattened forests.
//!
//! The JSON bundle written here is the interchange format between the rust
//! coordinator and the build-time python path: `aot.py` reads the same
//! shapes when lowering the Pallas kernel, and the runtime feeds these
//! arrays as PJRT literals into the compiled executable. The format is
//! deliberately dumb — three arrays per tree — so both sides agree
//! trivially.

use super::flat::FlatTree;
use crate::util::error::Result;
use crate::util::json::{parse, Json};
use std::path::Path;

/// A bundle of equally-shaped flat trees (a grove or a whole forest).
#[derive(Clone, Debug, PartialEq)]
pub struct FlatBundle {
    pub depth: usize,
    pub n_features: usize,
    pub n_classes: usize,
    pub trees: Vec<FlatTree>,
}

impl FlatBundle {
    pub fn new(trees: Vec<FlatTree>) -> FlatBundle {
        assert!(!trees.is_empty());
        let d = trees[0].depth;
        let f = trees[0].n_features;
        let c = trees[0].n_classes;
        for t in &trees {
            assert_eq!((t.depth, t.n_features, t.n_classes), (d, f, c), "inhomogeneous bundle");
        }
        FlatBundle { depth: d, n_features: f, n_classes: c, trees }
    }

    /// Stacked tensors in the layout the PJRT executable expects:
    /// `feat i32[t, 2^d-1]`, `thr f32[t, 2^d-1]`, `leaf f32[t, 2^d, c]`.
    pub fn stacked(&self) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
        let mut feat = Vec::new();
        let mut thr = Vec::new();
        let mut leaf = Vec::new();
        for t in &self.trees {
            feat.extend_from_slice(&t.feat);
            thr.extend_from_slice(&t.thr);
            leaf.extend_from_slice(&t.leaf);
        }
        (feat, thr, leaf)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("depth", Json::Num(self.depth as f64)),
            ("n_features", Json::Num(self.n_features as f64)),
            ("n_classes", Json::Num(self.n_classes as f64)),
            ("n_trees", Json::Num(self.trees.len() as f64)),
            (
                "trees",
                Json::Arr(
                    self.trees
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("feat", Json::arr_i64(&t.feat.iter().map(|&v| v as i64).collect::<Vec<_>>())),
                                ("thr", Json::arr_f32(&t.thr)),
                                ("leaf", Json::arr_f32(&t.leaf)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FlatBundle> {
        let depth = v.get("depth").as_usize().ok_or_else(|| crate::err!("missing depth"))?;
        let n_features =
            v.get("n_features").as_usize().ok_or_else(|| crate::err!("missing n_features"))?;
        let n_classes =
            v.get("n_classes").as_usize().ok_or_else(|| crate::err!("missing n_classes"))?;
        let trees_json =
            v.get("trees").as_arr().ok_or_else(|| crate::err!("missing trees"))?;
        let mut trees = Vec::with_capacity(trees_json.len());
        for tj in trees_json {
            let feat: Vec<i32> = tj
                .get("feat")
                .to_i64_vec()
                .ok_or_else(|| crate::err!("missing feat"))?
                .into_iter()
                .map(|v| v as i32)
                .collect();
            let thr = tj.get("thr").to_f32_vec().ok_or_else(|| crate::err!("missing thr"))?;
            let leaf = tj.get("leaf").to_f32_vec().ok_or_else(|| crate::err!("missing leaf"))?;
            crate::ensure!(feat.len() == (1 << depth) - 1, "feat len");
            crate::ensure!(thr.len() == (1 << depth) - 1, "thr len");
            crate::ensure!(leaf.len() == (1 << depth) * n_classes, "leaf len");
            trees.push(FlatTree { depth, n_features, n_classes, feat, thr, leaf });
        }
        crate::ensure!(!trees.is_empty(), "empty bundle");
        Ok(FlatBundle { depth, n_features, n_classes, trees })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<FlatBundle> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| crate::err!("read {}: {e}", path.display()))?;
        FlatBundle::from_json(&parse(&text)?)
    }
}

/// JSON thresholds round-trip through f64 text; infinity needs special
/// care. We encode ±inf as ±1e38 sentinels (outside any normalized feature
/// range, same routing behaviour).
pub fn sanitize_inf(bundle: &mut FlatBundle) {
    for t in &mut bundle.trees {
        for v in &mut t.thr {
            if v.is_infinite() {
                *v = if *v > 0.0 { 1e38 } else { -1e38 };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::dt::builder::{fit_tree, TreeParams};
    use crate::util::rng::Rng;

    fn bundle() -> FlatBundle {
        let ds = generate(&DatasetProfile::demo(), 51);
        let mut rng = Rng::new(11);
        let idx: Vec<usize> = (0..ds.train.len()).collect();
        let params = TreeParams { max_depth: 4, ..Default::default() };
        let trees: Vec<FlatTree> = (0..4)
            .map(|_| FlatTree::from_tree(&fit_tree(&ds.train, &idx, &params, &mut rng), 4))
            .collect();
        FlatBundle::new(trees)
    }

    #[test]
    fn roundtrip() {
        let mut b = bundle();
        sanitize_inf(&mut b);
        let j = b.to_json().to_string();
        let b2 = FlatBundle::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(b, b2);
    }

    #[test]
    fn save_load_file() {
        let mut b = bundle();
        sanitize_inf(&mut b);
        let path = std::env::temp_dir().join(format!("fog_bundle_{}.json", std::process::id()));
        b.save(&path).unwrap();
        let b2 = FlatBundle::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(b, b2);
    }

    #[test]
    fn stacked_shapes() {
        let b = bundle();
        let (feat, thr, leaf) = b.stacked();
        assert_eq!(feat.len(), 4 * 15);
        assert_eq!(thr.len(), 4 * 15);
        assert_eq!(leaf.len(), 4 * 16 * b.n_classes);
    }

    #[test]
    fn sanitize_preserves_function() {
        let mut b = bundle();
        let ds = generate(&DatasetProfile::demo(), 51);
        let before: Vec<usize> = (0..ds.test.len())
            .map(|i| b.trees[0].predict(ds.test.row(i)))
            .collect();
        sanitize_inf(&mut b);
        let after: Vec<usize> = (0..ds.test.len())
            .map(|i| b.trees[0].predict(ds.test.row(i)))
            .collect();
        assert_eq!(before, after);
    }

    #[test]
    #[should_panic]
    fn inhomogeneous_rejected() {
        let b = bundle();
        let mut trees = b.trees.clone();
        let mut t = trees[0].clone();
        t.depth = 2;
        t.feat.truncate(3);
        t.thr.truncate(3);
        t.leaf.truncate(4 * t.n_classes);
        trees.push(t);
        FlatBundle::new(trees);
    }
}
