//! Sparse binary decision tree: the in-memory product of CART training.
//!
//! Nodes live in a flat `Vec` with explicit child indices (no pointers, no
//! recursion on the prediction path). A node is either an internal split
//! `x[feature] <= threshold ? left : right` or a leaf holding a class
//! probability distribution — the paper's FoG evaluation (Algorithm 2)
//! averages these distributions across groves, in contrast to conventional
//! RF majority voting over hard labels (§3.2.1).

/// One tree node. `feature == u32::MAX` marks a leaf.
#[derive(Clone, Debug)]
pub struct Node {
    /// Split feature index, or `u32::MAX` for leaves.
    pub feature: u32,
    /// Split threshold (`x <= thr` goes left).
    pub threshold: f32,
    /// Index of the left child; right child is `left + 1` (children are
    /// allocated together, which keeps traversal cache-friendly).
    pub left: u32,
    /// Leaf class distribution (empty for internal nodes).
    pub dist: Vec<f32>,
}

impl Node {
    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.feature == u32::MAX
    }
}

/// A trained decision tree.
#[derive(Clone, Debug)]
pub struct DecisionTree {
    pub nodes: Vec<Node>,
    pub n_features: usize,
    pub n_classes: usize,
    /// Maximum root-to-leaf depth (root = depth 0 tree has depth 0).
    pub depth: usize,
}

impl DecisionTree {
    /// Class-probability prediction for one sample. Returns a reference to
    /// the leaf's distribution — no allocation on the hot path.
    #[inline]
    pub fn predict_proba<'a>(&'a self, x: &[f32]) -> &'a [f32] {
        let mut i = 0usize;
        loop {
            let n = &self.nodes[i];
            if n.is_leaf() {
                return &n.dist;
            }
            i = if x[n.feature as usize] <= n.threshold {
                n.left as usize
            } else {
                n.left as usize + 1
            };
        }
    }

    /// Hard-label prediction.
    pub fn predict(&self, x: &[f32]) -> usize {
        crate::util::argmax(self.predict_proba(x))
    }

    /// Prediction plus the number of comparator operations performed (the
    /// traversed depth) — the quantity the energy model charges per input.
    pub fn predict_proba_counted<'a>(&'a self, x: &[f32]) -> (&'a [f32], usize) {
        let mut i = 0usize;
        let mut comparisons = 0usize;
        loop {
            let n = &self.nodes[i];
            if n.is_leaf() {
                return (&n.dist, comparisons);
            }
            comparisons += 1;
            i = if x[n.feature as usize] <= n.threshold {
                n.left as usize
            } else {
                n.left as usize + 1
            };
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Set of features actually referenced by splits (budgeted training
    /// cares about acquisition cost of distinct features).
    pub fn used_features(&self) -> Vec<usize> {
        let mut used: Vec<usize> = self
            .nodes
            .iter()
            .filter(|n| !n.is_leaf())
            .map(|n| n.feature as usize)
            .collect();
        used.sort_unstable();
        used.dedup();
        used
    }

    /// Structural invariant check (used by tests and proptests): children
    /// in bounds, leaves have normalized distributions, acyclic by
    /// construction (children always have larger indices).
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("empty tree".into());
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if n.is_leaf() {
                if n.dist.len() != self.n_classes {
                    return Err(format!("leaf {i}: dist len {}", n.dist.len()));
                }
                let s: f32 = n.dist.iter().sum();
                if (s - 1.0).abs() > 1e-4 {
                    return Err(format!("leaf {i}: dist sums to {s}"));
                }
                if n.dist.iter().any(|&p| !(0.0..=1.0 + 1e-6).contains(&p)) {
                    return Err(format!("leaf {i}: dist out of range"));
                }
            } else {
                if n.feature as usize >= self.n_features {
                    return Err(format!("node {i}: feature {} oob", n.feature));
                }
                let l = n.left as usize;
                if l <= i || l + 1 >= self.nodes.len() + 1 && l + 1 > self.nodes.len() {
                    return Err(format!("node {i}: bad children"));
                }
                if l + 1 >= self.nodes.len() + 1 {
                    return Err(format!("node {i}: child oob"));
                }
                if l >= self.nodes.len() || l + 1 >= self.nodes.len() {
                    return Err(format!("node {i}: child index oob"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built stump: x[0] <= 0 → class 0, else class 1.
    fn stump() -> DecisionTree {
        DecisionTree {
            nodes: vec![
                Node { feature: 0, threshold: 0.0, left: 1, dist: vec![] },
                Node { feature: u32::MAX, threshold: 0.0, left: 0, dist: vec![1.0, 0.0] },
                Node { feature: u32::MAX, threshold: 0.0, left: 0, dist: vec![0.0, 1.0] },
            ],
            n_features: 1,
            n_classes: 2,
            depth: 1,
        }
    }

    #[test]
    fn stump_predicts() {
        let t = stump();
        assert_eq!(t.predict(&[-1.0]), 0);
        assert_eq!(t.predict(&[1.0]), 1);
        assert_eq!(t.predict(&[0.0]), 0); // boundary goes left
    }

    #[test]
    fn counted_ops() {
        let t = stump();
        let (dist, ops) = t.predict_proba_counted(&[2.0]);
        assert_eq!(ops, 1);
        assert_eq!(dist, &[0.0, 1.0]);
    }

    #[test]
    fn validate_ok_and_detects_bad_dist() {
        let mut t = stump();
        assert!(t.validate().is_ok());
        t.nodes[1].dist = vec![0.5, 0.4];
        assert!(t.validate().is_err());
    }

    #[test]
    fn leaf_count() {
        let t = stump();
        assert_eq!(t.n_leaves(), 2);
        assert_eq!(t.n_nodes(), 3);
        assert_eq!(t.used_features(), vec![0]);
    }
}
