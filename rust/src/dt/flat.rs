//! Flattened complete-tree representation.
//!
//! This is the layout shared by all three layers of the stack:
//!
//! * the **Pallas kernel** (L1) traverses it level-synchronously with
//!   arithmetic indexing `next = 2*i + 1 + (x[feat[i]] > thr[i])`,
//! * the **JAX model** (L2) receives it as runtime tensors so one
//!   shape-specialized HLO artifact serves any forest that fits,
//! * the **grove PE** in the μarch simulator (L3) walks the same arrays,
//!   charging one comparator op per level.
//!
//! A sparse CART tree of depth ≤ `d` is padded to the complete binary tree
//! of depth exactly `d`: dead internal slots get `feat = 0, thr = +inf`
//! (every input routes left) and leaf distributions are replicated down to
//! the bottom level, so the padded tree computes *exactly* the same
//! function as the sparse one — verified by the `padding_preserves` test
//! in this module.

use super::tree::DecisionTree;

/// A complete binary tree of depth `depth`: `2^depth - 1` internal slots,
/// `2^depth` leaves, each leaf holding an `n_classes` distribution.
#[derive(Clone, Debug, PartialEq)]
pub struct FlatTree {
    pub depth: usize,
    pub n_features: usize,
    pub n_classes: usize,
    /// `2^depth - 1` split feature indices (level order).
    pub feat: Vec<i32>,
    /// `2^depth - 1` split thresholds; `+inf` for dead slots.
    pub thr: Vec<f32>,
    /// `2^depth * n_classes` leaf distributions, row-major.
    pub leaf: Vec<f32>,
}

impl FlatTree {
    pub fn n_internal(&self) -> usize {
        (1usize << self.depth) - 1
    }

    pub fn n_leaves(&self) -> usize {
        1usize << self.depth
    }

    /// Convert a sparse CART tree, padding to `depth` levels. `depth` must
    /// be ≥ the sparse tree's depth.
    pub fn from_tree(tree: &DecisionTree, depth: usize) -> FlatTree {
        assert!(
            depth >= tree.depth,
            "pad depth {depth} < tree depth {}",
            tree.depth
        );
        let n_internal = (1usize << depth) - 1;
        let n_leaves = 1usize << depth;
        let c = tree.n_classes;
        let mut feat = vec![0i32; n_internal];
        let mut thr = vec![f32::INFINITY; n_internal];
        let mut leaf = vec![0.0f32; n_leaves * c];

        // Walk sparse and complete trees together. Complete-tree slots are
        // level-order: slot s has children 2s+1, 2s+2; slots ≥ n_internal
        // are leaves with index s - n_internal.
        // When the sparse tree reaches a leaf early, the distribution is
        // replicated to every complete-tree leaf under the current slot
        // (dead internal slots keep feat=0/thr=+inf: always route left —
        // the replication makes the routing choice irrelevant).
        let mut stack: Vec<(usize, usize)> = vec![(0usize, 0usize)]; // (sparse idx, slot)
        while let Some((si, slot)) = stack.pop() {
            let node = &tree.nodes[si];
            if node.is_leaf() {
                fill_subtree_leaves(&mut leaf, slot, n_internal, c, &node.dist);
            } else {
                debug_assert!(slot < n_internal, "internal node below pad depth");
                feat[slot] = node.feature as i32;
                thr[slot] = node.threshold;
                stack.push((node.left as usize, 2 * slot + 1));
                stack.push((node.left as usize + 1, 2 * slot + 2));
            }
        }

        FlatTree { depth, n_features: tree.n_features, n_classes: c, feat, thr, leaf }
    }

    /// Level-synchronous traversal — the same index arithmetic the Pallas
    /// kernel uses. Returns the leaf distribution slice.
    ///
    /// Perf note (§Perf iteration 1): the bounds checks on the three
    /// array indexings cost ~3× on this sub-100 ns path. Construction
    /// guarantees `feat[i] < n_features`, `|feat| = |thr| = 2^d − 1` and
    /// `|leaf| = 2^d·c`, and the index recurrence `i ← 2i+1+{0,1}` stays
    /// below `2^(d+1) − 1` for `d` levels, so the unchecked accesses are
    /// sound (invariants asserted in debug builds).
    #[inline]
    pub fn predict_proba(&self, x: &[f32]) -> &[f32] {
        debug_assert!(self.feat.len() == self.n_internal());
        debug_assert!(self.thr.len() == self.n_internal());
        debug_assert!(self.leaf.len() == self.n_leaves() * self.n_classes);
        let mut i = 0usize;
        for _ in 0..self.depth {
            // SAFETY: i < 2^depth − 1 by the recurrence; feat[i] is
            // validated < n_features at construction (from_tree/repad).
            let (f, t) = unsafe {
                (*self.feat.get_unchecked(i) as usize, *self.thr.get_unchecked(i))
            };
            debug_assert!(f < x.len());
            let go_right = unsafe { *x.get_unchecked(f) } > t;
            i = 2 * i + 1 + go_right as usize;
        }
        let leaf_idx = i - self.n_internal();
        let start = leaf_idx * self.n_classes;
        // SAFETY: leaf_idx < 2^depth, so the slice is in bounds.
        unsafe { self.leaf.get_unchecked(start..start + self.n_classes) }
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        crate::util::argmax(self.predict_proba(x))
    }

    /// VMEM footprint in bytes if resident on the accelerator: feat (i32) +
    /// thr (f32) + leaves (f32). Used by the DESIGN.md §Perf estimates.
    pub fn vmem_bytes(&self) -> usize {
        self.feat.len() * 4 + self.thr.len() * 4 + self.leaf.len() * 4
    }

    /// Re-pad to a deeper complete tree (`depth >= self.depth`): each new
    /// bottom level gets dead internal slots (`feat=0, thr=+inf`, route
    /// left) and pairwise-replicated leaf distributions, so the function
    /// computed is unchanged. Needed when binding a shallow trained tree
    /// to a deeper AOT-compiled artifact shape.
    pub fn repad(&self, depth: usize) -> FlatTree {
        assert!(depth >= self.depth, "repad {} < depth {}", depth, self.depth);
        let mut cur = self.clone();
        while cur.depth < depth {
            let d_new = cur.depth + 1;
            let n_int_new = (1usize << d_new) - 1;
            let mut feat = vec![0i32; n_int_new];
            let mut thr = vec![f32::INFINITY; n_int_new];
            feat[..cur.n_internal()].copy_from_slice(&cur.feat);
            thr[..cur.n_internal()].copy_from_slice(&cur.thr);
            let c = cur.n_classes;
            let mut leaf = vec![0.0f32; (1usize << d_new) * c];
            for li in 0..cur.n_leaves() {
                let dist = &cur.leaf[li * c..(li + 1) * c];
                leaf[(2 * li) * c..(2 * li + 1) * c].copy_from_slice(dist);
                leaf[(2 * li + 1) * c..(2 * li + 2) * c].copy_from_slice(dist);
            }
            cur = FlatTree {
                depth: d_new,
                n_features: cur.n_features,
                n_classes: cur.n_classes,
                feat,
                thr,
                leaf,
            };
        }
        cur
    }
}

/// Replicate `dist` into every bottom-level leaf of the complete subtree
/// rooted at `slot`.
fn fill_subtree_leaves(leaf: &mut [f32], slot: usize, n_internal: usize, c: usize, dist: &[f32]) {
    if slot >= n_internal {
        let li = slot - n_internal;
        leaf[li * c..(li + 1) * c].copy_from_slice(dist);
        return;
    }
    // Iterative frontier expansion to avoid deep recursion.
    let mut frontier = vec![slot];
    while let Some(s) = frontier.pop() {
        if s >= n_internal {
            let li = s - n_internal;
            leaf[li * c..(li + 1) * c].copy_from_slice(dist);
        } else {
            frontier.push(2 * s + 1);
            frontier.push(2 * s + 2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::dt::builder::{fit_tree, TreeParams};
    use crate::util::rng::Rng;

    #[test]
    fn padding_preserves() {
        let ds = generate(&DatasetProfile::demo(), 41);
        let mut rng = Rng::new(7);
        let idx: Vec<usize> = (0..ds.train.len()).collect();
        let params = TreeParams { max_depth: 5, ..Default::default() };
        let tree = fit_tree(&ds.train, &idx, &params, &mut rng);
        for pad in [tree.depth, tree.depth + 1, 8] {
            let flat = FlatTree::from_tree(&tree, pad);
            for i in 0..ds.test.len() {
                let x = ds.test.row(i);
                let sparse = tree.predict_proba(x);
                let flat_p = flat.predict_proba(x);
                for (a, b) in sparse.iter().zip(flat_p) {
                    assert!((a - b).abs() < 1e-6, "pad {pad}: {sparse:?} vs {flat_p:?}");
                }
            }
        }
    }

    #[test]
    fn shapes() {
        let ds = generate(&DatasetProfile::demo(), 42);
        let mut rng = Rng::new(8);
        let idx: Vec<usize> = (0..ds.train.len()).collect();
        let tree = fit_tree(&ds.train, &idx, &TreeParams::default(), &mut rng);
        let flat = FlatTree::from_tree(&tree, 8);
        assert_eq!(flat.feat.len(), 255);
        assert_eq!(flat.thr.len(), 255);
        assert_eq!(flat.leaf.len(), 256 * ds.train.n_classes);
        assert!(flat.vmem_bytes() > 0);
    }

    #[test]
    fn depth_zero_tree() {
        // A single-leaf tree pads to any depth and always returns its dist.
        let mut s = crate::data::Split::new(2, 2);
        for _ in 0..5 {
            s.push(&[0.0, 0.0], 1);
        }
        let mut rng = Rng::new(9);
        let tree = fit_tree(&s, &[0, 1, 2, 3, 4], &TreeParams::default(), &mut rng);
        assert_eq!(tree.depth, 0);
        let flat = FlatTree::from_tree(&tree, 3);
        assert_eq!(flat.predict(&[9.9, -9.9]), 1);
        // All leaves identical.
        for li in 0..flat.n_leaves() {
            assert_eq!(&flat.leaf[li * 2..li * 2 + 2], &[0.0, 1.0]);
        }
    }

    #[test]
    fn repad_preserves_function() {
        let ds = generate(&DatasetProfile::demo(), 43);
        let mut rng = Rng::new(11);
        let idx: Vec<usize> = (0..ds.train.len()).collect();
        let params = TreeParams { max_depth: 4, ..Default::default() };
        let tree = fit_tree(&ds.train, &idx, &params, &mut rng);
        let flat = FlatTree::from_tree(&tree, tree.depth.max(1));
        let deeper = flat.repad(flat.depth + 3);
        assert_eq!(deeper.depth, flat.depth + 3);
        for i in 0..ds.test.len() {
            let x = ds.test.row(i);
            let a = flat.predict_proba(x);
            let b = deeper.predict_proba(x);
            for (p, q) in a.iter().zip(b) {
                assert!((p - q).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn dead_slots_route_left() {
        let mut s = crate::data::Split::new(1, 2);
        for i in 0..10 {
            s.push(&[i as f32], (i >= 5) as usize);
        }
        let mut rng = Rng::new(10);
        let params = TreeParams { max_depth: 1, ..Default::default() };
        let tree = fit_tree(&s, &(0..10).collect::<Vec<_>>(), &params, &mut rng);
        let flat = FlatTree::from_tree(&tree, 3);
        // Dead slots must have +inf thresholds.
        let dead = flat.thr.iter().filter(|t| t.is_infinite()).count();
        assert!(dead > 0);
        // And function is preserved.
        for i in 0..10 {
            assert_eq!(flat.predict(s.row(i)), tree.predict(s.row(i)));
        }
    }
}
