//! Figure 4 — accuracy and EDP as a function of FoG topology
//! (number of groves × decision trees per grove) at a fixed total tree
//! count, per dataset.

use super::suite::{fog_stats, train_suite, TrainedSuite};
use crate::data::synthetic::DatasetProfile;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{fog_cost, ClassifierKind};
use crate::fog::tuner::{accuracy_optimal_threshold, threshold_sweep};
use crate::fog::{topology, FieldOfGroves};

/// One topology's operating point.
#[derive(Clone, Debug)]
pub struct TopoPoint {
    pub n_groves: usize,
    pub trees_per_grove: usize,
    pub accuracy: f64,
    pub avg_hops: f64,
    pub edp_nj_ns: f64,
    pub energy_nj: f64,
}

/// Sweep all factorizations of the trained forest for one dataset.
pub fn run_dataset(suite: &TrainedSuite, seed: u64) -> Vec<TopoPoint> {
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let grid: Vec<f32> = (1..=10).map(|i| i as f32 * 0.1).collect();
    topology::factorizations(suite.rf.n_trees())
        .into_iter()
        .map(|(n_groves, per_grove)| {
            let fog = FieldOfGroves::from_forest_shuffled(&suite.rf, per_grove, Some(seed));
            let sweep = threshold_sweep(&fog, &suite.data.test, &grid, seed);
            let opt = accuracy_optimal_threshold(&sweep, 0.01);
            let stats = fog_stats(&fog, opt.avg_hops, ClassifierKind::FogOpt);
            let report = fog_cost(&stats, &eb, &ab);
            TopoPoint {
                n_groves,
                trees_per_grove: per_grove,
                accuracy: opt.accuracy,
                avg_hops: opt.avg_hops,
                edp_nj_ns: report.edp(),
                energy_nj: report.energy_nj,
            }
        })
        .collect()
}

/// Run Figure 4 for a set of profiles and print the series.
pub fn run(profiles: &[DatasetProfile], seed: u64) -> Vec<(String, Vec<TopoPoint>)> {
    profiles
        .iter()
        .map(|p| {
            eprintln!("[fig4] {} ...", p.name);
            let suite = train_suite(p, seed);
            (p.name.to_string(), run_dataset(&suite, seed))
        })
        .collect()
}

pub fn print_series(all: &[(String, Vec<TopoPoint>)]) {
    println!("== Figure 4: accuracy & EDP vs FoG topology (groves x trees/grove) ==");
    for (name, points) in all {
        println!("\n-- {name} --");
        println!(
            "{:<10}{:>12}{:>12}{:>16}{:>14}",
            "topology", "accuracy%", "avg hops", "EDP (nJ*ns)", "energy (nJ)"
        );
        for p in points {
            println!(
                "{:<10}{:>12.1}{:>12.2}{:>16.1}{:>14.2}",
                format!("{}x{}", p.n_groves, p.trees_per_grove),
                p.accuracy * 100.0,
                p.avg_hops,
                p.edp_nj_ns,
                p.energy_nj
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_topology_sweep() {
        let suite = train_suite(&DatasetProfile::demo(), 41);
        let points = run_dataset(&suite, 41);
        // 16 trees → 5 factorizations.
        assert_eq!(points.len(), 5);
        // Every point positive and hops within bounds.
        for p in &points {
            assert!(p.edp_nj_ns > 0.0);
            assert!(p.avg_hops >= 1.0 && p.avg_hops <= p.n_groves as f64);
            assert!(p.accuracy > 0.4);
        }
        // Accuracy across topologies stays in a sane band (same forest).
        let max = points.iter().map(|p| p.accuracy).fold(f64::MIN, f64::max);
        let min = points.iter().map(|p| p.accuracy).fold(f64::MAX, f64::min);
        assert!(max - min < 0.25, "accuracy spread {max}-{min}");
    }
}
