//! Table 1 — accuracy (top), energy per classification in nJ (bottom)
//! and area (mm²) for SVM-LR/RBF, MLP, CNN, RF, FoG_max, FoG_opt across
//! the five datasets; plus the §1/§5 headline energy ratios.

use super::suite::{evaluate_suite, train_suite, Row};
use crate::data::synthetic::DatasetProfile;
use crate::energy::model::ClassifierKind;

pub const COLUMNS: [ClassifierKind; 7] = [
    ClassifierKind::SvmLinear,
    ClassifierKind::SvmRbf,
    ClassifierKind::Mlp,
    ClassifierKind::Cnn,
    ClassifierKind::RandomForest,
    ClassifierKind::FogMax,
    ClassifierKind::FogOpt,
];

/// One dataset's worth of results.
pub struct DatasetResult {
    pub name: String,
    pub rows: Vec<Row>,
}

impl DatasetResult {
    pub fn get(&self, kind: ClassifierKind) -> &Row {
        self.rows.iter().find(|r| r.kind == kind).expect("row")
    }
}

/// Run the Table-1 experiment over `profiles`.
pub fn run(profiles: &[DatasetProfile], seed: u64) -> Vec<DatasetResult> {
    profiles
        .iter()
        .map(|p| {
            eprintln!("[table1] training suite on {} ...", p.name);
            let suite = train_suite(p, seed);
            let rows = evaluate_suite(&suite, seed);
            DatasetResult { name: p.name.to_string(), rows }
        })
        .collect()
}

/// Geometric-mean energy ratio of `a` over `b` across datasets.
pub fn energy_ratio(results: &[DatasetResult], a: ClassifierKind, b: ClassifierKind) -> f64 {
    let mut log_sum = 0.0;
    for r in results {
        log_sum += (r.get(a).report.energy_nj / r.get(b).report.energy_nj).ln();
    }
    (log_sum / results.len() as f64).exp()
}

/// Mean accuracy difference (percentage points) of `a` minus `b`.
pub fn accuracy_gap(results: &[DatasetResult], a: ClassifierKind, b: ClassifierKind) -> f64 {
    results
        .iter()
        .map(|r| (r.get(a).accuracy - r.get(b).accuracy) * 100.0)
        .sum::<f64>()
        / results.len() as f64
}

/// Print the full table in the paper's layout.
pub fn print_table(results: &[DatasetResult]) {
    let header = || {
        print!("{:<14}", "Dataset");
        for k in COLUMNS {
            print!("{:>9}", k.label());
        }
        println!();
    };
    println!("== Table 1 (top): accuracy % ==");
    header();
    for r in results {
        print!("{:<14}", r.name);
        for k in COLUMNS {
            print!("{:>9.0}", r.get(k).accuracy * 100.0);
        }
        println!();
    }
    println!("\n== Table 1 (bottom): energy per classification, nJ ==");
    header();
    for r in results {
        print!("{:<14}", r.name);
        for k in COLUMNS {
            let e = r.get(k).report.energy_nj;
            if e >= 100.0 {
                print!("{:>9.0}", e);
            } else {
                print!("{:>9.1}", e);
            }
        }
        println!();
    }
    println!("\n== Table 1: area, mm^2 (mean across datasets) ==");
    header();
    print!("{:<14}", "Area");
    for k in COLUMNS {
        let mean: f64 =
            results.iter().map(|r| r.get(k).report.area_mm2).sum::<f64>() / results.len() as f64;
        print!("{:>9.2}", mean);
    }
    println!();
}

/// Print the headline ratios the abstract/conclusion claims.
pub fn print_headline(results: &[DatasetResult]) {
    use ClassifierKind::*;
    println!("\n== Headline ratios (paper: §1/§5; geometric mean across datasets) ==");
    let pairs = [
        (RandomForest, FogOpt, "RF / FoG_opt", "≈1.48x"),
        (SvmRbf, FogOpt, "SVM_rbf / FoG_opt", "≈24x"),
        (Mlp, FogOpt, "MLP / FoG_opt", "≈2.5x"),
        (Cnn, FogOpt, "CNN / FoG_opt", "≈34.7x"),
        (FogOpt, SvmLinear, "FoG_opt / SVM_lr", "≈6.5-10x"),
        (SvmRbf, RandomForest, "SVM_rbf / RF", "≈15x"),
        (Cnn, RandomForest, "CNN / RF", "≈23.5x"),
    ];
    for (a, b, label, paper) in pairs {
        println!(
            "  {label:<22} measured {:>8.2}x   (paper {paper})",
            energy_ratio(results, a, b)
        );
    }
    println!(
        "  FoG_opt accuracy vs SVM_lr: {:+.1} pts (paper ≈ +15-18)",
        accuracy_gap(results, FogOpt, SvmLinear)
    );
    println!(
        "  FoG_opt accuracy vs RF:     {:+.1} pts (paper ≈ -3.2)",
        accuracy_gap(results, FogOpt, RandomForest)
    );
    println!(
        "  FoG_opt accuracy vs CNN:    {:+.1} pts (paper ≈ -4)",
        accuracy_gap(results, FogOpt, ClassifierKind::Cnn)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_table_runs_and_orders() {
        let results = run(&[DatasetProfile::demo()], 7);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.rows.len(), 7);
        // Energy ordering that must reproduce: LR < FoG_opt < RF, CNN worst
        // among the GEMM family.
        let lr = r.get(ClassifierKind::SvmLinear).report.energy_nj;
        let fog = r.get(ClassifierKind::FogOpt).report.energy_nj;
        let rf = r.get(ClassifierKind::RandomForest).report.energy_nj;
        let cnn = r.get(ClassifierKind::Cnn).report.energy_nj;
        assert!(lr < fog && fog < rf, "lr {lr} fog {fog} rf {rf}");
        // On the 8-feature demo profile the CNN is tiny, so the paper's
        // CNN≫MLP gap only appears at realistic feature counts (covered
        // by the penbase/mnist runs); here we just require CNN > SVM_lr.
        assert!(cnn > lr, "cnn {cnn} lr {lr}");
        // Ratios are finite and positive.
        assert!(energy_ratio(&results, ClassifierKind::RandomForest, ClassifierKind::FogOpt) > 1.0);
    }
}
