//! Figure 5 — run-time tuning: accuracy and EDP as a function of the
//! confidence threshold for fixed topologies (the paper shows 8×2 and
//! 4×4), across all datasets.

use super::suite::{fog_stats, train_suite, TrainedSuite};
use crate::data::synthetic::DatasetProfile;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{fog_cost, ClassifierKind};
use crate::fog::tuner::threshold_sweep;
use crate::fog::FieldOfGroves;
use crate::util::error::Result;

/// One (threshold, accuracy, EDP) point.
#[derive(Clone, Debug)]
pub struct ThresholdPoint {
    pub threshold: f32,
    pub accuracy: f64,
    pub avg_hops: f64,
    pub edp_nj_ns: f64,
    pub energy_nj: f64,
}

/// Threshold sweep for one dataset at a fixed topology `(groves, trees)`.
pub fn run_dataset(
    suite: &TrainedSuite,
    topo: (usize, usize),
    thresholds: &[f32],
    seed: u64,
) -> Result<Vec<ThresholdPoint>> {
    crate::ensure!(
        topo.0 * topo.1 == suite.rf.n_trees(),
        "topology {}x{} != {} trees",
        topo.0,
        topo.1,
        suite.rf.n_trees()
    );
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let fog = FieldOfGroves::from_forest_shuffled(&suite.rf, topo.1, Some(seed));
    let sweep = threshold_sweep(&fog, &suite.data.test, thresholds, seed);
    Ok(sweep
        .into_iter()
        .map(|p| {
            let stats = fog_stats(&fog, p.avg_hops, ClassifierKind::FogOpt);
            let report = fog_cost(&stats, &eb, &ab);
            ThresholdPoint {
                threshold: p.threshold,
                accuracy: p.accuracy,
                avg_hops: p.avg_hops,
                edp_nj_ns: report.edp(),
                energy_nj: report.energy_nj,
            }
        })
        .collect())
}

/// Full Figure 5: both topologies over all profiles.
pub fn run(
    profiles: &[DatasetProfile],
    topo: (usize, usize),
    seed: u64,
) -> Vec<(String, Vec<ThresholdPoint>)> {
    let grid = crate::fog::tuner::default_grid();
    profiles
        .iter()
        .map(|p| {
            eprintln!("[fig5] {} @ {}x{} ...", p.name, topo.0, topo.1);
            let suite = train_suite(p, seed);
            let pts = run_dataset(&suite, topo, &grid, seed).expect("topology divides forest");
            (p.name.to_string(), pts)
        })
        .collect()
}

pub fn print_series(topo: (usize, usize), all: &[(String, Vec<ThresholdPoint>)]) {
    println!(
        "== Figure 5: run-time tuning via threshold, topology {}x{} ==",
        topo.0, topo.1
    );
    for (name, points) in all {
        println!("\n-- {name} --");
        println!(
            "{:<12}{:>12}{:>12}{:>16}{:>14}",
            "threshold", "accuracy%", "avg hops", "EDP (nJ*ns)", "energy (nJ)"
        );
        for p in points {
            println!(
                "{:<12.2}{:>12.1}{:>12.2}{:>16.1}{:>14.2}",
                p.threshold,
                p.accuracy * 100.0,
                p.avg_hops,
                p.edp_nj_ns,
                p.energy_nj
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_sweep_monotone_energy() {
        let suite = train_suite(&DatasetProfile::demo(), 51);
        let pts =
            run_dataset(&suite, (8, 2), &[0.1, 0.3, 0.5, 0.7, 0.9], 51).unwrap();
        assert_eq!(pts.len(), 5);
        // Energy/EDP monotone nondecreasing in threshold (more hops).
        for w in pts.windows(2) {
            assert!(w[1].energy_nj + 1e-9 >= w[0].energy_nj);
            assert!(w[1].avg_hops + 1e-9 >= w[0].avg_hops);
        }
        // Tunability: high threshold costs strictly more than low.
        assert!(pts[4].energy_nj > pts[0].energy_nj * 1.2);
    }

    #[test]
    fn wrong_topology_rejected() {
        let suite = train_suite(&DatasetProfile::demo(), 52);
        assert!(run_dataset(&suite, (3, 4), &[0.5], 52).is_err());
    }
}
