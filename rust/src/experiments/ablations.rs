//! Ablations on the design choices DESIGN.md calls out:
//!
//! * **vote mode** — FoG's probability averaging vs conventional RF
//!   majority voting (the §3.2.1 contrast);
//! * **max_hops** — the second run-time knob (the figures only sweep
//!   `threshold`; this sweeps the hop cap at fixed threshold);
//! * **grove dropout** — the §3.1 graceful-degradation claim,
//!   quantified;
//! * **router policy** — Algorithm 2's random start vs round-robin vs
//!   least-loaded, measured on ring load imbalance.

use super::suite::{fog_stats, TrainedSuite};
use crate::coordinator::router::{Router, RouterPolicy};
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{fog_cost, ClassifierKind};
use crate::fog::dropout::degradation_curve;
use crate::fog::{FieldOfGroves, FogParams};
use crate::forest::VoteMode;

/// Vote-mode ablation result.
pub struct VoteAblation {
    pub majority: f64,
    pub prob_average: f64,
}

pub fn vote_mode(suite: &TrainedSuite) -> VoteAblation {
    VoteAblation {
        majority: suite.rf.accuracy(&suite.data.test, VoteMode::Majority),
        prob_average: suite.rf.accuracy(&suite.data.test, VoteMode::ProbAverage),
    }
}

/// max_hops sweep at fixed threshold.
pub struct HopPoint {
    pub max_hops: usize,
    pub accuracy: f64,
    pub avg_hops: f64,
    pub energy_nj: f64,
}

pub fn max_hops_sweep(
    suite: &TrainedSuite,
    fog: &FieldOfGroves,
    threshold: f32,
    seed: u64,
) -> Vec<HopPoint> {
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    (1..=fog.n_groves())
        .map(|max_hops| {
            let res = fog.evaluate(
                &suite.data.test.x,
                &FogParams { threshold, max_hops, seed },
            );
            let stats = fog_stats(fog, res.avg_hops(), ClassifierKind::FogOpt);
            HopPoint {
                max_hops,
                accuracy: res.accuracy(&suite.data.test.y),
                avg_hops: res.avg_hops(),
                energy_nj: fog_cost(&stats, &eb, &ab).energy_nj,
            }
        })
        .collect()
}

/// Grove-dropout degradation curve (k disabled groves → accuracy).
pub fn dropout_curve(
    suite: &TrainedSuite,
    fog: &FieldOfGroves,
    threshold: f32,
    seed: u64,
) -> Vec<(usize, f64)> {
    let params = FogParams { threshold, max_hops: fog.n_groves(), seed };
    degradation_curve(fog, &suite.data.test.x, &suite.data.test.y, &params, seed)
}

/// Router policy load imbalance over `n` synthetic injections.
pub fn router_imbalance(n_groves: usize, n: u64, seed: u64) -> Vec<(RouterPolicy, f64)> {
    [RouterPolicy::Random, RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded]
        .into_iter()
        .map(|policy| {
            let r = Router::new(policy, n_groves, seed);
            let mut counts = vec![0u64; n_groves];
            // Steady-state completion model: keep ~2·n_groves in flight,
            // retiring the *oldest* injection (FIFO), as the ring does.
            let mut in_flight = std::collections::VecDeque::new();
            for i in 0..n {
                let g = r.route(i);
                counts[g] += 1;
                r.note_injected(g);
                in_flight.push_back(g);
                if in_flight.len() > 2 * n_groves {
                    r.note_completed(in_flight.pop_front().unwrap());
                }
            }
            (policy, Router::imbalance(&counts))
        })
        .collect()
}

/// Print all ablations for one trained suite.
pub fn print_all(suite: &TrainedSuite, seed: u64) {
    let fog = FieldOfGroves::from_forest_shuffled(&suite.rf, 2, Some(seed)); // 8x2

    println!("== ablation: vote mode (paper §3.2.1 contrast) ==");
    let v = vote_mode(suite);
    println!(
        "  majority vote {:.1}%   probability average {:.1}%   (Δ {:+.1} pts)",
        v.majority * 100.0,
        v.prob_average * 100.0,
        (v.prob_average - v.majority) * 100.0
    );

    println!("\n== ablation: max_hops cap @ threshold 0.5 (run-time knob #2) ==");
    println!("  {:<10}{:>12}{:>12}{:>14}", "max_hops", "accuracy%", "avg hops", "energy nJ");
    for p in max_hops_sweep(suite, &fog, 0.5, seed) {
        println!(
            "  {:<10}{:>12.1}{:>12.2}{:>14.2}",
            p.max_hops,
            p.accuracy * 100.0,
            p.avg_hops,
            p.energy_nj
        );
    }

    println!("\n== ablation: grove dropout (graceful degradation, §3.1) ==");
    println!("  {:<14}{:>12}", "disabled", "accuracy%");
    for (k, acc) in dropout_curve(suite, &fog, 0.5, seed) {
        println!("  {:<14}{:>12.1}", format!("{k}/{}", fog.n_groves()), acc * 100.0);
    }

    println!("\n== ablation: router policy load imbalance (max/mean, 10k injections) ==");
    for (policy, imb) in router_imbalance(fog.n_groves(), 10_000, seed) {
        println!("  {policy:?}: {imb:.3}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetProfile;
    use crate::experiments::suite::train_suite;

    #[test]
    fn ablations_run_on_demo() {
        let suite = train_suite(&DatasetProfile::demo(), 61);
        let fog = FieldOfGroves::from_forest_shuffled(&suite.rf, 2, Some(61));

        let v = vote_mode(&suite);
        assert!(v.majority > 0.5 && v.prob_average > 0.5);

        let hops = max_hops_sweep(&suite, &fog, 0.5, 61);
        assert_eq!(hops.len(), 8);
        // Energy monotone nondecreasing in the cap; avg_hops too.
        for w in hops.windows(2) {
            assert!(w[1].avg_hops + 1e-9 >= w[0].avg_hops);
            assert!(w[1].energy_nj + 1e-9 >= w[0].energy_nj);
        }
        // Cap of 1 = single-grove evaluation.
        assert!((hops[0].avg_hops - 1.0).abs() < 1e-9);

        let curve = dropout_curve(&suite, &fog, 0.5, 61);
        assert_eq!(curve.len(), fog.n_groves());

        let imb = router_imbalance(8, 4000, 61);
        assert_eq!(imb.len(), 3);
        // Round-robin is perfectly balanced.
        let rr = imb.iter().find(|(p, _)| *p == RouterPolicy::RoundRobin).unwrap();
        assert!((rr.1 - 1.0).abs() < 1e-9);
    }
}
