//! Shared experiment machinery: train every classifier the paper
//! compares (§4.1) on one dataset profile, with the paper's design flow:
//!
//! 1. standardize + fixed-point-quantize features (hardware input path),
//! 2. train all classifiers "for their maximum accuracy" (§4.2),
//! 3. split the RF into groves, pick the minimum-EDP topology whose
//!    accuracy is within tolerance of the best (Figure 4's selection),
//! 4. find the FoG_opt threshold (accuracy-optimal point, §4.2).

use crate::baselines::{
    cnn::CnnParams, mlp::MlpParams, svm_linear::LinearSvmParams, svm_rbf::RbfSvmParams,
    Classifier, Cnn, LinearSvm, Mlp, RbfSvm,
};
use crate::data::normalize::{quantize_split, standardize};
use crate::data::synthetic::{generate, DatasetProfile};
use crate::data::Dataset;
use crate::dt::TreeParams;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{
    fog_cost, rf_cost, ClassifierKind, CostReport, FogStats, RfStats,
};
use crate::fog::tuner::{accuracy_optimal_threshold, threshold_sweep, SweepPoint};
use crate::fog::{topology, FieldOfGroves, FogParams};
use crate::forest::{ForestParams, RandomForest, VoteMode};

/// Per-dataset training hyper-parameters, scaled so the big profiles
/// (ISOLET/MNIST) stay tractable without changing the comparison.
pub struct TrainConfig {
    pub forest: ForestParams,
    pub linear: LinearSvmParams,
    pub rbf: RbfSvmParams,
    pub mlp: MlpParams,
    pub cnn: CnnParams,
}

impl TrainConfig {
    pub fn for_profile(p: &DatasetProfile) -> TrainConfig {
        let big = p.n_features > 100;
        let many_classes = p.n_classes > 10;
        TrainConfig {
            forest: ForestParams {
                n_trees: 16,
                tree: TreeParams {
                    max_depth: if big || many_classes { 12 } else { 8 },
                    min_samples_leaf: 2,
                    max_features: if big { 64 } else { 0 },
                    ..Default::default()
                },
                bootstrap: true,
            },
            linear: LinearSvmParams { epochs: if big { 8 } else { 14 }, ..Default::default() },
            rbf: RbfSvmParams { max_support: if big { 700 } else { 800 }, ..Default::default() },
            mlp: MlpParams {
                hidden: vec![if big { 96 } else { 64 }],
                epochs: if big { 12 } else { 30 },
                ..Default::default()
            },
            cnn: CnnParams {
                // Paper-comparable capacity: the paper's CNN is by far the
                // largest design (2.1 mm², ~0.2-1.3 µJ/classification);
                // channel counts are sized so conv MACs dominate at every
                // feature count.
                conv1_channels: if big { 16 } else { 32 },
                conv2_channels: if big { 32 } else { 64 },
                pool1: if big { 4 } else { 2 },
                epochs: if big { 5 } else { 20 },
                ..Default::default()
            },
        }
    }
}

/// Everything trained on one dataset.
pub struct TrainedSuite {
    pub profile: DatasetProfile,
    pub data: Dataset,
    pub rf: RandomForest,
    pub svm_lr: LinearSvm,
    pub svm_rbf: RbfSvm,
    pub mlp: Mlp,
    pub cnn: Cnn,
}

/// Train the full suite on a profile (standardized + quantized data).
pub fn train_suite(profile: &DatasetProfile, seed: u64) -> TrainedSuite {
    let mut data = generate(profile, seed);
    standardize(&mut data);
    // Hardware input conditioning: Q3.4 bytes in the data queue.
    quantize_split(&mut data.train);
    quantize_split(&mut data.test);
    let cfg = TrainConfig::for_profile(profile);
    let rf = RandomForest::fit(&data.train, &cfg.forest, seed ^ 1);
    let svm_lr = LinearSvm::fit(&data.train, &cfg.linear, seed ^ 2);
    let svm_rbf = RbfSvm::fit(&data.train, &cfg.rbf, seed ^ 3);
    let mlp = Mlp::fit(&data.train, &cfg.mlp, seed ^ 4);
    let cnn = Cnn::fit(&data.train, &cfg.cnn, seed ^ 5);
    TrainedSuite { profile: profile.clone(), data, rf, svm_lr, svm_rbf, mlp, cnn }
}

/// The selected FoG design for a suite: topology + thresholds + stats.
pub struct FogSelection {
    pub fog: FieldOfGroves,
    pub topology: (usize, usize),
    pub sweep: Vec<SweepPoint>,
    pub opt: SweepPoint,
    /// Accuracy at threshold=max (== RF prob-average accuracy).
    pub max_accuracy: f64,
}

/// Figure-4 style topology selection: among all factorizations of the
/// forest, pick the minimum-EDP design whose FoG_opt accuracy is within
/// `tol` of the best (the paper's "minimum EDP at maximum accuracy").
pub fn select_fog(suite: &TrainedSuite, seed: u64, tol: f64) -> FogSelection {
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let mut best: Option<(f64, FogSelection)> = None;
    let mut best_acc = f64::NEG_INFINITY;
    let mut candidates = Vec::new();
    for topo in topology::factorizations(suite.rf.n_trees()) {
        let (n_groves, per_grove) = topo;
        if n_groves < 2 {
            continue; // 1 grove = plain RF, not a FoG
        }
        let fog = FieldOfGroves::from_forest_shuffled(&suite.rf, per_grove, Some(seed));
        let sweep = threshold_sweep(&fog, &suite.data.test, &grid_coarse(), seed);
        let opt = accuracy_optimal_threshold(&sweep, tol).clone();
        let stats = fog_stats(&fog, opt.avg_hops, ClassifierKind::FogOpt);
        let edp = fog_cost(&stats, &eb, &ab).edp();
        best_acc = best_acc.max(opt.accuracy);
        candidates.push((edp, fog, sweep, opt, topo));
    }
    for (edp, fog, sweep, opt, topo) in candidates {
        if opt.accuracy < best_acc - tol {
            continue;
        }
        let max_accuracy = sweep.last().map(|p| p.accuracy).unwrap_or(opt.accuracy);
        if best.as_ref().map(|(e, _)| edp < *e).unwrap_or(true) {
            best = Some((
                edp,
                FogSelection { fog, topology: topo, sweep, opt, max_accuracy },
            ));
        }
    }
    best.expect("at least one multi-grove topology").1
}

fn grid_coarse() -> Vec<f32> {
    (1..=10).map(|i| i as f32 * 0.1).collect()
}

/// Measured FogStats for an evaluated operating point.
pub fn fog_stats(fog: &FieldOfGroves, avg_hops: f64, kind: ClassifierKind) -> FogStats {
    let per_grove = fog.groves[0].n_trees();
    let depth = fog.depth;
    // Storage sized to the *sparse* trained trees (the hardware stores
    // real nodes, not the complete-tree padding the kernels use).
    let storage = fog.groves[0].sparse_storage_bytes() as f64;
    FogStats {
        n_groves: fog.n_groves(),
        trees_per_grove: per_grove,
        depth,
        avg_hops,
        n_features: fog.n_features,
        n_classes: fog.n_classes,
        grove_storage_bytes: storage,
        kind,
    }
}

/// Measured RfStats for a trained forest.
pub fn rf_stats(suite: &TrainedSuite) -> RfStats {
    let rf = &suite.rf;
    let depth = rf.max_depth().max(1);
    // 6 bytes per sparse node: weight + feature offset + control
    // (§3.2.2 "Reprogrammability"), plus one byte per leaf-class slot.
    let nodes: usize = rf.trees.iter().map(|t| t.n_nodes()).sum();
    let leaves: usize = rf.trees.iter().map(|t| t.n_leaves()).sum();
    let storage = nodes as f64 * 6.0 + (leaves * rf.n_classes) as f64;
    RfStats {
        n_trees: rf.n_trees(),
        avg_comparisons: rf.avg_comparisons(&suite.data.test),
        max_depth: depth,
        n_features: rf.n_features,
        n_classes: rf.n_classes,
        node_storage_bytes: storage,
    }
}

/// One Table-1 row: a classifier's accuracy and PPA on one dataset.
pub struct Row {
    pub kind: ClassifierKind,
    pub accuracy: f64,
    pub report: CostReport,
}

/// Evaluate the full suite (baselines + RF + FoG_max + FoG_opt) and
/// return rows in the paper's column order.
pub fn evaluate_suite(suite: &TrainedSuite, seed: u64) -> Vec<Row> {
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let test = &suite.data.test;
    let mut rows = Vec::new();

    rows.push(Row {
        kind: ClassifierKind::SvmLinear,
        accuracy: suite.svm_lr.accuracy(test),
        report: suite.svm_lr.cost_report(&eb, &ab),
    });
    rows.push(Row {
        kind: ClassifierKind::SvmRbf,
        accuracy: suite.svm_rbf.accuracy(test),
        report: suite.svm_rbf.cost_report(&eb, &ab),
    });
    rows.push(Row {
        kind: ClassifierKind::Mlp,
        accuracy: suite.mlp.accuracy(test),
        report: suite.mlp.cost_report(&eb, &ab),
    });
    rows.push(Row {
        kind: ClassifierKind::Cnn,
        accuracy: suite.cnn.accuracy(test),
        report: suite.cnn.cost_report(&eb, &ab),
    });
    rows.push(Row {
        kind: ClassifierKind::RandomForest,
        accuracy: suite.rf.accuracy(test, VoteMode::Majority),
        report: rf_cost(&rf_stats(suite), &eb, &ab),
    });

    let sel = select_fog(suite, seed, 0.01);
    // FoG_max: threshold at maximum — every grove contributes.
    let max_params = FogParams::fog_max(sel.fog.n_groves());
    let max_res = sel.fog.evaluate(&test.x, &max_params);
    let max_stats = fog_stats(&sel.fog, max_res.avg_hops(), ClassifierKind::FogMax);
    rows.push(Row {
        kind: ClassifierKind::FogMax,
        accuracy: max_res.accuracy(&test.y),
        report: fog_cost(&max_stats, &eb, &ab),
    });
    // FoG_opt: accuracy-optimal threshold.
    let opt_stats = fog_stats(&sel.fog, sel.opt.avg_hops, ClassifierKind::FogOpt);
    rows.push(Row {
        kind: ClassifierKind::FogOpt,
        accuracy: sel.opt.accuracy,
        report: fog_cost(&opt_stats, &eb, &ab),
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_suite() -> TrainedSuite {
        train_suite(&DatasetProfile::demo(), 31)
    }

    #[test]
    fn suite_trains_everything() {
        let s = demo_suite();
        let test = &s.data.test;
        assert!(s.rf.accuracy(test, VoteMode::Majority) > 0.6);
        assert!(s.svm_rbf.accuracy(test) > 0.5);
        assert!(s.mlp.accuracy(test) > 0.5);
    }

    #[test]
    fn select_fog_prefers_multi_grove() {
        let s = demo_suite();
        let sel = select_fog(&s, 1, 0.02);
        assert!(sel.topology.0 >= 2, "topology {:?}", sel.topology);
        assert_eq!(sel.topology.0 * sel.topology.1, 16);
        assert!(sel.opt.threshold > 0.0);
    }

    #[test]
    fn evaluate_suite_full_rows() {
        let s = demo_suite();
        let rows = evaluate_suite(&s, 2);
        assert_eq!(rows.len(), 7);
        // The paper's qualitative ordering that must emerge:
        let get = |k: ClassifierKind| rows.iter().find(|r| r.kind == k).unwrap();
        let rf = get(ClassifierKind::RandomForest);
        let fog_opt = get(ClassifierKind::FogOpt);
        let lr = get(ClassifierKind::SvmLinear);
        // FoG_opt cheaper than RF.
        assert!(
            fog_opt.report.energy_nj < rf.report.energy_nj,
            "fog {} rf {}",
            fog_opt.report.energy_nj,
            rf.report.energy_nj
        );
        // FoG accuracy within a few points of RF.
        assert!(fog_opt.accuracy > rf.accuracy - 0.08);
        // Linear SVM cheapest.
        assert!(lr.report.energy_nj < rf.report.energy_nj);
    }
}
