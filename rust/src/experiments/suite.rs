//! Shared experiment machinery: train every classifier the paper
//! compares (§4.1) on one dataset profile, with the paper's design flow:
//!
//! 1. standardize + fixed-point-quantize features (hardware input path),
//! 2. train all classifiers "for their maximum accuracy" (§4.2) — the
//!    baselines through the [`crate::api`] registry, so everything
//!    downstream handles `Box<dyn Classifier>` uniformly,
//! 3. split the RF into groves, pick the minimum-EDP topology whose
//!    accuracy is within tolerance of the best (Figure 4's selection),
//! 4. find the FoG_opt threshold (accuracy-optimal point, §4.2),
//! 5. evaluate *every* model — baselines, RF, FoG_max, FoG_opt — through
//!    one batch-first [`Classifier`] loop: accuracy plus a cost report
//!    with op counts measured on the test split. No per-model-type
//!    dispatch remains on the prediction path.

use crate::api::spec::{
    cnn_params_for, forest_params_for, linear_params_for, mlp_params_for, rbf_params_for,
};
use crate::api::{Classifier, Estimator, FogModel, ModelConfig, ModelSpec, RfModel};
use crate::baselines::{
    cnn::CnnParams, mlp::MlpParams, svm_linear::LinearSvmParams, svm_rbf::RbfSvmParams,
};
use crate::data::normalize::{quantize_split, standardize};
use crate::data::synthetic::{generate, DatasetProfile};
use crate::data::Dataset;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{fog_cost, ClassifierKind, CostReport, FogStats, RfStats};
use crate::fog::tuner::{accuracy_optimal_threshold, threshold_sweep, SweepPoint};
use crate::fog::{topology, FieldOfGroves, FogParams};
use crate::forest::{ForestParams, RandomForest, VoteMode};

/// Per-dataset training hyper-parameters, scaled so the big profiles
/// (ISOLET/MNIST) stay tractable without changing the comparison. The
/// scaling rules live in [`crate::api::spec`] so the registry and the
/// suite stay in sync.
pub struct TrainConfig {
    pub forest: ForestParams,
    pub linear: LinearSvmParams,
    pub rbf: RbfSvmParams,
    pub mlp: MlpParams,
    pub cnn: CnnParams,
}

impl TrainConfig {
    pub fn for_shape(n_features: usize, n_classes: usize) -> TrainConfig {
        TrainConfig {
            forest: forest_params_for(n_features, n_classes),
            linear: linear_params_for(n_features),
            rbf: rbf_params_for(n_features),
            mlp: mlp_params_for(n_features),
            cnn: cnn_params_for(n_features),
        }
    }

    pub fn for_profile(p: &DatasetProfile) -> TrainConfig {
        Self::for_shape(p.n_features, p.n_classes)
    }
}

/// Generate + condition one profile's data (standardize, Q3.4 quantize —
/// the hardware input path).
pub fn prepare_data(profile: &DatasetProfile, seed: u64) -> Dataset {
    let mut data = generate(profile, seed);
    standardize(&mut data);
    quantize_split(&mut data.train);
    quantize_split(&mut data.test);
    data
}

/// Everything trained on one dataset: the forest (shared by the FoG
/// design flow) plus the four baselines behind the unified API.
pub struct TrainedSuite {
    pub profile: DatasetProfile,
    pub data: Dataset,
    pub rf: RandomForest,
    /// SVM_lr, SVM_rbf, MLP, CNN — Table-1 column order.
    pub baselines: Vec<Box<dyn Classifier>>,
}

impl TrainedSuite {
    /// Look up a baseline by its Table-1 column.
    pub fn baseline(&self, kind: ClassifierKind) -> Option<&dyn Classifier> {
        self.baselines.iter().map(|b| b.as_ref()).find(|b| b.kind() == kind)
    }
}

/// Train the full suite on a profile (standardized + quantized data).
pub fn train_suite(profile: &DatasetProfile, seed: u64) -> TrainedSuite {
    let data = prepare_data(profile, seed);
    let cfg = TrainConfig::for_profile(profile);
    let rf = RandomForest::fit(&data.train, &cfg.forest, seed ^ 1);
    let specs = [
        ModelSpec::new("svm_lr", ModelConfig::SvmLinear(cfg.linear.clone())),
        ModelSpec::new("svm_rbf", ModelConfig::SvmRbf(cfg.rbf.clone())),
        ModelSpec::new("mlp", ModelConfig::Mlp(cfg.mlp.clone())),
        ModelSpec::new("cnn", ModelConfig::Cnn(cfg.cnn.clone())),
    ];
    let baselines = specs
        .into_iter()
        .enumerate()
        .map(|(i, spec)| spec.fit(&data.train, seed ^ (i as u64 + 2)))
        .collect();
    TrainedSuite { profile: profile.clone(), data, rf, baselines }
}

/// The selected FoG design for a suite: topology + thresholds + stats.
pub struct FogSelection {
    pub fog: FieldOfGroves,
    pub topology: (usize, usize),
    pub sweep: Vec<SweepPoint>,
    pub opt: SweepPoint,
    /// Accuracy at threshold=max (== RF prob-average accuracy).
    pub max_accuracy: f64,
}

/// Figure-4 style topology selection: among all factorizations of the
/// forest, pick the minimum-EDP design whose FoG_opt accuracy is within
/// `tol` of the best (the paper's "minimum EDP at maximum accuracy").
pub fn select_fog(suite: &TrainedSuite, seed: u64, tol: f64) -> FogSelection {
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let mut best: Option<(f64, FogSelection)> = None;
    let mut best_acc = f64::NEG_INFINITY;
    let mut candidates = Vec::new();
    for topo in topology::factorizations(suite.rf.n_trees()) {
        let (n_groves, per_grove) = topo;
        if n_groves < 2 {
            continue; // 1 grove = plain RF, not a FoG
        }
        let fog = FieldOfGroves::from_forest_shuffled(&suite.rf, per_grove, Some(seed));
        let sweep = threshold_sweep(&fog, &suite.data.test, &grid_coarse(), seed);
        let opt = accuracy_optimal_threshold(&sweep, tol).clone();
        let stats = fog_stats(&fog, opt.avg_hops, ClassifierKind::FogOpt);
        let edp = fog_cost(&stats, &eb, &ab).edp();
        best_acc = best_acc.max(opt.accuracy);
        candidates.push((edp, fog, sweep, opt, topo));
    }
    for (edp, fog, sweep, opt, topo) in candidates {
        if opt.accuracy < best_acc - tol {
            continue;
        }
        let max_accuracy = sweep.last().map(|p| p.accuracy).unwrap_or(opt.accuracy);
        if best.as_ref().map(|(e, _)| edp < *e).unwrap_or(true) {
            best = Some((
                edp,
                FogSelection { fog, topology: topo, sweep, opt, max_accuracy },
            ));
        }
    }
    best.expect("at least one multi-grove topology").1
}

fn grid_coarse() -> Vec<f32> {
    (1..=10).map(|i| i as f32 * 0.1).collect()
}

/// Measured FogStats for an evaluated operating point (delegates to the
/// `api` layer so one implementation feeds both paths).
pub fn fog_stats(fog: &FieldOfGroves, avg_hops: f64, kind: ClassifierKind) -> FogStats {
    crate::api::measured_fog_stats(fog, avg_hops, kind)
}

/// Measured RfStats for a trained forest.
pub fn rf_stats(suite: &TrainedSuite) -> RfStats {
    crate::api::measured_rf_stats(&suite.rf, Some(&suite.data.test))
}

/// One Table-1 row: a classifier's accuracy and PPA on one dataset.
pub struct Row {
    pub kind: ClassifierKind,
    pub accuracy: f64,
    pub report: CostReport,
}

/// Evaluate the full suite (baselines + RF + FoG_max + FoG_opt) and
/// return rows in the paper's column order — one uniform pass over
/// `&dyn Classifier`, with per-classification op counts measured on the
/// test split. No per-model-type dispatch.
pub fn evaluate_suite(suite: &TrainedSuite, seed: u64) -> Vec<Row> {
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let test = &suite.data.test;

    // FoG design flow (topology + threshold selection).
    let sel = select_fog(suite, seed, 0.01);
    let n_groves = sel.fog.n_groves();
    let rf_model = RfModel::new(suite.rf.clone(), VoteMode::Majority);
    let fog_max = FogModel::fog_max(sel.fog.clone(), seed);
    let fog_opt = FogModel::new(
        sel.fog,
        FogParams { threshold: sel.opt.threshold, max_hops: n_groves, seed },
        ClassifierKind::FogOpt,
    );

    let mut models: Vec<&dyn Classifier> = suite.baselines.iter().map(|b| b.as_ref()).collect();
    models.push(&rf_model);
    models.push(&fog_max);
    models.push(&fog_opt);

    models
        .into_iter()
        .map(|m| Row {
            kind: m.kind(),
            accuracy: m.accuracy(test),
            report: m.cost_report(Some(test), &eb, &ab),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_suite() -> TrainedSuite {
        train_suite(&DatasetProfile::demo(), 31)
    }

    #[test]
    fn suite_trains_everything() {
        let s = demo_suite();
        let test = &s.data.test;
        assert!(s.rf.accuracy(test, VoteMode::Majority) > 0.6);
        assert!(s.baseline(ClassifierKind::SvmRbf).unwrap().accuracy(test) > 0.5);
        assert!(s.baseline(ClassifierKind::Mlp).unwrap().accuracy(test) > 0.5);
        assert_eq!(s.baselines.len(), 4);
    }

    #[test]
    fn select_fog_prefers_multi_grove() {
        let s = demo_suite();
        let sel = select_fog(&s, 1, 0.02);
        assert!(sel.topology.0 >= 2, "topology {:?}", sel.topology);
        assert_eq!(sel.topology.0 * sel.topology.1, 16);
        assert!(sel.opt.threshold > 0.0);
    }

    #[test]
    fn evaluate_suite_full_rows() {
        let s = demo_suite();
        let rows = evaluate_suite(&s, 2);
        assert_eq!(rows.len(), 7);
        // The paper's qualitative ordering that must emerge:
        let get = |k: ClassifierKind| rows.iter().find(|r| r.kind == k).unwrap();
        let rf = get(ClassifierKind::RandomForest);
        let fog_opt = get(ClassifierKind::FogOpt);
        let lr = get(ClassifierKind::SvmLinear);
        // FoG_opt cheaper than RF.
        assert!(
            fog_opt.report.energy_nj < rf.report.energy_nj,
            "fog {} rf {}",
            fog_opt.report.energy_nj,
            rf.report.energy_nj
        );
        // FoG accuracy within a few points of RF.
        assert!(fog_opt.accuracy > rf.accuracy - 0.08);
        // Linear SVM cheapest.
        assert!(lr.report.energy_nj < rf.report.energy_nj);
    }

    #[test]
    fn rows_come_from_trait_objects_uniformly() {
        // Regression guard for the api refactor: the Table-1 column order
        // must be reproducible straight from the trait objects.
        let s = demo_suite();
        let rows = evaluate_suite(&s, 3);
        let kinds: Vec<ClassifierKind> = rows.iter().map(|r| r.kind).collect();
        assert_eq!(
            kinds,
            vec![
                ClassifierKind::SvmLinear,
                ClassifierKind::SvmRbf,
                ClassifierKind::Mlp,
                ClassifierKind::Cnn,
                ClassifierKind::RandomForest,
                ClassifierKind::FogMax,
                ClassifierKind::FogOpt,
            ]
        );
    }
}
