//! Experiment harnesses regenerating every table and figure of the
//! paper's evaluation (§4):
//!
//! * [`suite`]  — shared training/evaluation of all seven classifiers on
//!   one dataset, with the paper's design flow (budgeted RF training,
//!   FoG topology selection at minimum EDP, FoG_opt threshold search).
//! * [`table1`] — Table 1: accuracy (top), energy/classification
//!   (bottom), area row, and the §1/§5 headline ratios.
//! * [`fig4`]   — Figure 4: accuracy & EDP vs (groves × trees/grove).
//! * [`fig5`]   — Figure 5: accuracy & EDP vs confidence threshold for
//!   the 8×2 and 4×4 topologies.
//! * [`ablations`] — vote-mode / max_hops / grove-dropout / router-policy
//!   ablations on the design choices.

pub mod ablations;
pub mod fig4;
pub mod fig5;
pub mod suite;
pub mod table1;
