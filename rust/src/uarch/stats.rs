//! Event counters collected by the ring simulator, convertible to energy
//! via the PPA block library — the μarch-level counterpart of the
//! analytical model in [`crate::energy::model`].

use crate::energy::blocks::EnergyBlocks;

/// Aggregate simulation statistics.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub cycles: u64,
    pub classified: u64,
    /// Comparator operations across all PEs.
    pub comparator_ops: u64,
    /// Data-queue traffic in bytes (reads + writes tracked separately).
    pub queue_bytes_read: u64,
    pub queue_bytes_written: u64,
    /// Completed inter-grove transfers.
    pub handshakes: u64,
    /// Cycles a sender stalled on a full neighbour queue.
    pub stall_cycles: u64,
    /// Sum over classified inputs of (completion - injection) cycles.
    pub total_latency_cycles: u64,
    /// Sum of hop counts over classified inputs.
    pub total_hops: u64,
    /// Per-grove busy cycles (PE actively evaluating).
    pub grove_busy_cycles: Vec<u64>,
}

impl SimStats {
    /// Accumulate another run's (or tile's) counters into this one —
    /// saturating adds, so tile-by-tile serving accumulation can never
    /// wrap. Per-grove busy vectors align by index, extending as needed.
    pub fn merge(&mut self, other: &SimStats) {
        self.cycles = self.cycles.saturating_add(other.cycles);
        self.classified = self.classified.saturating_add(other.classified);
        self.comparator_ops = self.comparator_ops.saturating_add(other.comparator_ops);
        self.queue_bytes_read = self.queue_bytes_read.saturating_add(other.queue_bytes_read);
        self.queue_bytes_written =
            self.queue_bytes_written.saturating_add(other.queue_bytes_written);
        self.handshakes = self.handshakes.saturating_add(other.handshakes);
        self.stall_cycles = self.stall_cycles.saturating_add(other.stall_cycles);
        self.total_latency_cycles =
            self.total_latency_cycles.saturating_add(other.total_latency_cycles);
        self.total_hops = self.total_hops.saturating_add(other.total_hops);
        if self.grove_busy_cycles.len() < other.grove_busy_cycles.len() {
            self.grove_busy_cycles.resize(other.grove_busy_cycles.len(), 0);
        }
        for (a, &b) in self.grove_busy_cycles.iter_mut().zip(&other.grove_busy_cycles) {
            *a = a.saturating_add(b);
        }
    }

    pub fn avg_latency_cycles(&self) -> f64 {
        if self.classified == 0 {
            return 0.0;
        }
        self.total_latency_cycles as f64 / self.classified as f64
    }

    pub fn avg_hops(&self) -> f64 {
        if self.classified == 0 {
            return 0.0;
        }
        self.total_hops as f64 / self.classified as f64
    }

    /// Throughput in classifications per 1k cycles.
    pub fn throughput_per_kcycle(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.classified as f64 * 1000.0 / self.cycles as f64
    }

    /// Mean PE utilization across groves (busy / total cycles).
    pub fn avg_utilization(&self) -> f64 {
        if self.cycles == 0 || self.grove_busy_cycles.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.grove_busy_cycles.iter().sum();
        busy as f64 / (self.cycles as f64 * self.grove_busy_cycles.len() as f64)
    }

    /// Dynamic energy (nJ) of the counted events (the shared
    /// [`event_energy_nj`](crate::energy::model::event_energy_nj) fold —
    /// the serving tier's `ExecReport`s charge the same block energies).
    pub fn dynamic_energy_nj(&self, eb: &EnergyBlocks) -> f64 {
        crate::energy::model::event_energy_nj(
            eb,
            self.comparator_ops as f64,
            self.queue_bytes_read as f64,
            self.queue_bytes_written as f64,
            self.handshakes as f64,
        )
    }

    /// Dynamic energy per classification (nJ).
    pub fn dynamic_energy_per_input_nj(&self, eb: &EnergyBlocks) -> f64 {
        if self.classified == 0 {
            return 0.0;
        }
        self.dynamic_energy_nj(eb) / self.classified as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_handle_zero() {
        let s = SimStats::default();
        assert_eq!(s.avg_latency_cycles(), 0.0);
        assert_eq!(s.avg_hops(), 0.0);
        assert_eq!(s.throughput_per_kcycle(), 0.0);
        assert_eq!(s.avg_utilization(), 0.0);
    }

    #[test]
    fn energy_accumulates() {
        let s = SimStats {
            comparator_ops: 1000,
            queue_bytes_read: 100,
            queue_bytes_written: 100,
            handshakes: 10,
            ..Default::default()
        };
        let e = s.dynamic_energy_nj(&EnergyBlocks::default());
        assert!(e > 0.0);
    }

    #[test]
    fn merge_accumulates_and_saturates() {
        let mut a = SimStats {
            cycles: u64::MAX - 10,
            classified: 4,
            comparator_ops: 100,
            grove_busy_cycles: vec![5],
            ..Default::default()
        };
        let b = SimStats {
            cycles: 100,
            classified: 2,
            comparator_ops: 50,
            grove_busy_cycles: vec![1, 2],
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.cycles, u64::MAX, "cycles must saturate, not wrap");
        assert_eq!(a.classified, 6);
        assert_eq!(a.comparator_ops, 150);
        assert_eq!(a.grove_busy_cycles, vec![6, 2]);
    }

    #[test]
    fn utilization_bounded() {
        let s = SimStats {
            cycles: 100,
            grove_busy_cycles: vec![50, 100],
            ..Default::default()
        };
        let u = s.avg_utilization();
        assert!((u - 0.75).abs() < 1e-9);
    }
}
