//! Event counters collected by the ring simulator, convertible to energy
//! via the PPA block library — the μarch-level counterpart of the
//! analytical model in [`crate::energy::model`].

use crate::energy::blocks::EnergyBlocks;

/// Aggregate simulation statistics.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub cycles: u64,
    pub classified: u64,
    /// Comparator operations across all PEs.
    pub comparator_ops: u64,
    /// Data-queue traffic in bytes (reads + writes tracked separately).
    pub queue_bytes_read: u64,
    pub queue_bytes_written: u64,
    /// Completed inter-grove transfers.
    pub handshakes: u64,
    /// Cycles a sender stalled on a full neighbour queue.
    pub stall_cycles: u64,
    /// Sum over classified inputs of (completion - injection) cycles.
    pub total_latency_cycles: u64,
    /// Sum of hop counts over classified inputs.
    pub total_hops: u64,
    /// Per-grove busy cycles (PE actively evaluating).
    pub grove_busy_cycles: Vec<u64>,
}

impl SimStats {
    pub fn avg_latency_cycles(&self) -> f64 {
        if self.classified == 0 {
            return 0.0;
        }
        self.total_latency_cycles as f64 / self.classified as f64
    }

    pub fn avg_hops(&self) -> f64 {
        if self.classified == 0 {
            return 0.0;
        }
        self.total_hops as f64 / self.classified as f64
    }

    /// Throughput in classifications per 1k cycles.
    pub fn throughput_per_kcycle(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.classified as f64 * 1000.0 / self.cycles as f64
    }

    /// Mean PE utilization across groves (busy / total cycles).
    pub fn avg_utilization(&self) -> f64 {
        if self.cycles == 0 || self.grove_busy_cycles.is_empty() {
            return 0.0;
        }
        let busy: u64 = self.grove_busy_cycles.iter().sum();
        busy as f64 / (self.cycles as f64 * self.grove_busy_cycles.len() as f64)
    }

    /// Dynamic energy (nJ) of the counted events.
    pub fn dynamic_energy_nj(&self, eb: &EnergyBlocks) -> f64 {
        eb.comparisons_nj(self.comparator_ops as f64)
            + eb.sram_read_nj(self.queue_bytes_read as f64)
            + eb.sram_write_nj(self.queue_bytes_written as f64)
            + self.handshakes as f64 * eb.handshake_pj * 1e-3
    }

    /// Dynamic energy per classification (nJ).
    pub fn dynamic_energy_per_input_nj(&self, eb: &EnergyBlocks) -> f64 {
        if self.classified == 0 {
            return 0.0;
        }
        self.dynamic_energy_nj(eb) / self.classified as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_handle_zero() {
        let s = SimStats::default();
        assert_eq!(s.avg_latency_cycles(), 0.0);
        assert_eq!(s.avg_hops(), 0.0);
        assert_eq!(s.throughput_per_kcycle(), 0.0);
        assert_eq!(s.avg_utilization(), 0.0);
    }

    #[test]
    fn energy_accumulates() {
        let s = SimStats {
            comparator_ops: 1000,
            queue_bytes_read: 100,
            queue_bytes_written: 100,
            handshakes: 10,
            ..Default::default()
        };
        let e = s.dynamic_energy_nj(&EnergyBlocks::default());
        assert!(e > 0.0);
    }

    #[test]
    fn utilization_bounded() {
        let s = SimStats {
            cycles: 100,
            grove_busy_cycles: vec![50, 100],
            ..Default::default()
        };
        let u = s.avg_utilization();
        assert!((u - 0.75).abs() < 1e-9);
    }
}
