//! Cycle-level simulator of the FoG micro-architecture (paper §3.2.2,
//! Figure 3).
//!
//! Each grove tile contains a **data queue** (byte-addressable local
//! memory with `$fr`/`$bk` pointers managed by the queue controller),
//! a **processing element** (the grove's decision trees), and a
//! **handshake** port (`req`/`ack`) to the next grove in the ring. Inputs
//! arrive from the processor through the accelerator input queue; results
//! leave through the output queue.
//!
//! The simulator is cycle-stepped: every [`ring::RingSim::step`] advances
//! each tile's FSM by one clock. Functional results (probabilities, hop
//! counts) are computed with the same [`crate::fog::Grove`] code the
//! software path uses, so the simulator's *outputs* provably match
//! Algorithm 2 while its *timing/energy event counts* add the
//! micro-architectural detail (queue traffic, handshake stalls,
//! backpressure) the analytical model cannot see.
//!
//! **Paper anchors:** §3.2.2 and Figure 3 (grove tile: data queue with
//! `$fr`/`$bk` pointers, DQC, PE, req/ack handshake), §3.2.1 (grove as
//! the unit of computation), §4.2 (the cycle/energy observables).
//!
//! Besides whole-run offline simulation (`fog sim`), the ring can be
//! driven tile-by-tile with explicit start groves
//! ([`ring::RingSim::load_batch_with_starts`]) — the hardware-in-the-loop
//! serving path: [`crate::exec::UarchBackend`] streams each replica batch
//! through a ring instance and folds the per-tile [`SimStats`] (which
//! [`SimStats::merge`] accumulates across tiles) into live
//! energy-per-classification estimates.

pub mod handshake;
pub mod pe;
pub mod queue;
pub mod ring;
pub mod stats;

pub use ring::{RingConfig, RingSim};
pub use stats::SimStats;
