//! The inter-grove handshaking protocol (paper §3.2.2 "Handshaking
//! Protocol").
//!
//! Grove `Gi` raises `req` toward `G(i+1)`; when the neighbour has queue
//! space it copies the Γ-byte entry and pulses `ack` for one cycle; `Gi`
//! then drops `req`, completing the handshake. If the neighbour's queue
//! is full, `req` stays high — backpressure stalls the sender's
//! forwarding port (but not its PE, which keeps draining its own queue).

/// Sender-side handshake FSM.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HandshakeState {
    /// No transfer pending.
    Idle,
    /// `req` is high; waiting for the neighbour's `ack`.
    ReqRaised,
    /// `ack` seen this cycle; `req` drops next cycle.
    AckSeen,
}

/// One directed handshake channel (Gi → Gi+1).
#[derive(Clone, Debug)]
pub struct Handshake {
    pub state: HandshakeState,
    /// Completed transfers (for energy accounting: one event each).
    pub transfers: u64,
    /// Cycles spent stalled with `req` high and no `ack`.
    pub stall_cycles: u64,
}

impl Default for Handshake {
    fn default() -> Self {
        Handshake { state: HandshakeState::Idle, transfers: 0, stall_cycles: 0 }
    }
}

impl Handshake {
    /// Sender raises `req` (call when a low-confidence entry is ready to
    /// forward). Only legal from `Idle`.
    pub fn raise_req(&mut self) {
        debug_assert_eq!(self.state, HandshakeState::Idle, "req while busy");
        self.state = HandshakeState::ReqRaised;
    }

    /// One clock at the receiver: `can_accept` is whether the neighbour
    /// queue has space. Returns `true` exactly once per transfer, on the
    /// cycle the copy completes (the `ack` pulse).
    pub fn clock(&mut self, can_accept: bool) -> bool {
        match self.state {
            HandshakeState::Idle => false,
            HandshakeState::ReqRaised => {
                if can_accept {
                    self.state = HandshakeState::AckSeen;
                    true
                } else {
                    self.stall_cycles += 1;
                    false
                }
            }
            HandshakeState::AckSeen => {
                // Sender pulls req low; channel returns to idle.
                self.state = HandshakeState::Idle;
                self.transfers += 1;
                false
            }
        }
    }

    pub fn busy(&self) -> bool {
        self.state != HandshakeState::Idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_handshake_two_cycles() {
        let mut h = Handshake::default();
        h.raise_req();
        assert!(h.clock(true)); // ack pulse
        assert!(!h.clock(true)); // req drops, idle again
        assert_eq!(h.state, HandshakeState::Idle);
        assert_eq!(h.transfers, 1);
        assert_eq!(h.stall_cycles, 0);
    }

    #[test]
    fn backpressure_stalls() {
        let mut h = Handshake::default();
        h.raise_req();
        assert!(!h.clock(false));
        assert!(!h.clock(false));
        assert_eq!(h.stall_cycles, 2);
        assert!(h.clock(true)); // finally accepted
        h.clock(true);
        assert_eq!(h.transfers, 1);
    }

    #[test]
    fn no_spurious_acks_when_idle() {
        let mut h = Handshake::default();
        for _ in 0..10 {
            assert!(!h.clock(true));
        }
        assert_eq!(h.transfers, 0);
    }

    #[test]
    fn busy_reflects_state() {
        let mut h = Handshake::default();
        assert!(!h.busy());
        h.raise_req();
        assert!(h.busy());
        h.clock(true);
        assert!(h.busy()); // ack seen, req not yet dropped
        h.clock(true);
        assert!(!h.busy());
    }
}
