//! The grove ring (paper Figure 3): cycle-stepped simulation of the full
//! FoG accelerator — input queue, grove tiles (data queue + PE +
//! handshake), and output queue.
//!
//! Functional behaviour is bit-identical to Algorithm 2 (verified by the
//! `matches_algorithm2` test): the simulator adds *timing* — PE latency,
//! queue service order, handshake stalls, injection backpressure — and
//! event counts for energy.

use super::handshake::Handshake;
use super::pe::PeModel;
use super::queue::{DataQueue, Entry};
use super::stats::SimStats;
use crate::fog::FieldOfGroves;
use crate::util::rng::Rng;

/// Ring configuration.
#[derive(Clone, Debug)]
pub struct RingConfig {
    /// Confidence stopping threshold (Algorithm 2).
    pub threshold: f32,
    /// Maximum contributing groves per input.
    pub max_hops: usize,
    /// Data-queue capacity per grove, bytes (paper: 6 kB).
    pub queue_bytes: usize,
    /// PE parallelism model.
    pub pe: PeModel,
    /// Cycles between processor injections (1 = one input/cycle offered).
    pub inject_interval: u64,
    /// Seed for the random starting grove of each input.
    pub seed: u64,
    /// Safety limit.
    pub max_cycles: u64,
}

impl Default for RingConfig {
    fn default() -> Self {
        RingConfig {
            threshold: 0.3,
            max_hops: usize::MAX,
            queue_bytes: 6 * 1024,
            pe: PeModel::default(),
            inject_interval: 8,
            seed: 0,
            max_cycles: 50_000_000,
        }
    }
}

/// Completed classification record.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    pub id: u32,
    pub label: usize,
    pub hops: usize,
    pub latency_cycles: u64,
    pub prob: Vec<f32>,
}

/// Per-tile FSM state.
enum TileState {
    Idle,
    /// PE evaluating `entry`; done when `remaining` hits 0.
    Busy { remaining: u64, entry: Entry },
}

struct Tile {
    queue: DataQueue,
    state: TileState,
    /// Entry awaiting transfer to the next grove.
    outbox: Option<Entry>,
    handshake: Handshake,
    busy_cycles: u64,
}

/// The ring simulator. Owns a reference to the functional FoG model.
pub struct RingSim<'a> {
    fog: &'a FieldOfGroves,
    cfg: RingConfig,
    tiles: Vec<Tile>,
    /// (features, injection target) pending injection, plus bookkeeping.
    pending: std::collections::VecDeque<(u32, Vec<f32>, usize)>,
    inject_cooldown: u64,
    /// Injection cycle per input id (dense: ids are 0..n).
    injected_at: Vec<u64>,
    pub outcomes: Vec<SimOutcome>,
    pub stats: SimStats,
}

impl<'a> RingSim<'a> {
    pub fn new(fog: &'a FieldOfGroves, cfg: RingConfig) -> RingSim<'a> {
        let tiles = (0..fog.n_groves())
            .map(|_| Tile {
                queue: DataQueue::new(fog.n_features, fog.n_classes, cfg.queue_bytes),
                state: TileState::Idle,
                outbox: None,
                handshake: Handshake::default(),
                busy_cycles: 0,
            })
            .collect();
        let stats = SimStats { grove_busy_cycles: vec![0; fog.n_groves()], ..Default::default() };
        RingSim {
            fog,
            cfg,
            tiles,
            pending: std::collections::VecDeque::new(),
            inject_cooldown: 0,
            injected_at: Vec::new(),
            outcomes: Vec::new(),
            stats,
        }
    }

    /// Queue a batch for injection; start groves are drawn per input from
    /// the seeded stream (Algorithm 2 line 3).
    pub fn load_batch(&mut self, x: &[f32]) {
        let f = self.fog.n_features;
        assert_eq!(x.len() % f, 0);
        let n = x.len() / f;
        let starts: Vec<usize> = (0..n)
            .map(|i| {
                let mut rng =
                    Rng::new(self.cfg.seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
                rng.gen_range(self.fog.n_groves())
            })
            .collect();
        self.load_batch_with_starts(x, &starts);
    }

    /// Queue a batch with explicit per-input start groves — the
    /// tile-level drive API the serving tier's
    /// [`UarchBackend`](crate::exec::UarchBackend) uses: start groves
    /// come from the model's content hash, so simulated answers are
    /// byte-identical to the software evaluation path. Input ids continue
    /// from previously loaded batches.
    pub fn load_batch_with_starts(&mut self, x: &[f32], starts: &[usize]) {
        let f = self.fog.n_features;
        assert_eq!(x.len() % f, 0);
        let n = x.len() / f;
        assert_eq!(starts.len(), n, "one start grove per input");
        let base = self.injected_at.len();
        self.injected_at.resize(base + n, 0);
        for (i, &start) in starts.iter().enumerate() {
            assert!(start < self.fog.n_groves(), "start grove {start} out of range");
            self.pending.push_back(((base + i) as u32, x[i * f..(i + 1) * f].to_vec(), start));
        }
    }

    /// Run until every loaded input is classified (or `max_cycles`).
    /// Returns outcomes sorted by input id. The target is everything
    /// ever loaded (`injected_at.len()`), not just the currently-pending
    /// queue, so load → run → load → run drives each new batch to
    /// completion instead of returning the first batch's stale outcomes.
    pub fn run(&mut self) -> &[SimOutcome] {
        let total = self.injected_at.len() as u64;
        while (self.outcomes.len() as u64) < total {
            assert!(
                self.stats.cycles < self.cfg.max_cycles,
                "simulation exceeded {} cycles (deadlock?)",
                self.cfg.max_cycles
            );
            self.step();
        }
        self.refresh_queue_counters();
        self.outcomes.sort_by_key(|o| o.id);
        &self.outcomes
    }

    /// Advance one clock.
    pub fn step(&mut self) {
        self.stats.cycles += 1;
        let n = self.tiles.len();

        // Phase 1 — handshake channels: move outbox entries into the next
        // grove's queue front (priority insertion per the paper).
        for i in 0..n {
            if !self.tiles[i].handshake.busy() {
                continue;
            }
            let next = (i + 1) % n;
            let can_accept = !self.tiles[next].queue.is_full();
            let ack = self.tiles[i].handshake.clock(can_accept);
            if ack {
                let entry = self.tiles[i].outbox.take().expect("ack without outbox");
                self.tiles[next]
                    .queue
                    .push_front(entry)
                    .unwrap_or_else(|_| panic!("accepted transfer into full queue"));
                self.stats.handshakes += 1;
            } else if matches!(
                self.tiles[i].handshake.state,
                super::handshake::HandshakeState::ReqRaised
            ) {
                self.stats.stall_cycles += 1;
            }
        }

        // Phase 2 — PEs.
        for i in 0..n {
            let tile = &mut self.tiles[i];
            match std::mem::replace(&mut tile.state, TileState::Idle) {
                TileState::Idle => {
                    // Start the next entry if available — but only when the
                    // outbox is clear: in hardware the PE stalls while a
                    // forwarded entry is still waiting for the neighbour's
                    // ack (it would have nowhere to put a second one).
                    if tile.outbox.is_none() {
                        if let Some(entry) = tile.queue.pop_front() {
                            let lat = self.cfg.pe.latency(&self.fog.groves[i]).max(1);
                            tile.state = TileState::Busy { remaining: lat, entry };
                        }
                    }
                }
                TileState::Busy { remaining, entry } => {
                    tile.busy_cycles += 1;
                    self.stats.grove_busy_cycles[i] += 1;
                    if remaining > 1 {
                        tile.state = TileState::Busy { remaining: remaining - 1, entry };
                    } else {
                        // Evaluation completes this cycle.
                        self.finish_eval(i, entry);
                    }
                }
            }
        }

        // Phase 3 — processor injection (one offered input per interval).
        // Bubble flow control: the ring is unidirectional, so a cycle of
        // full queues + occupied outboxes would deadlock. The injector
        // guarantees at least one free slot ring-wide ("bubble"), which
        // circulates backwards and lets forwarded entries always make
        // progress — the standard deadlock-avoidance rule for rings.
        if self.inject_cooldown > 0 {
            self.inject_cooldown -= 1;
        }
        if self.inject_cooldown == 0 && self.occupancy() + 2 <= self.total_slots() {
            if let Some((id, features, start)) = self.pending.pop_front() {
                let entry = Entry {
                    id,
                    hops: 0,
                    prob: vec![0.0; self.fog.n_classes],
                    features,
                };
                match self.tiles[start].queue.push_back(entry) {
                    Ok(()) => {
                        self.injected_at[id as usize] = self.stats.cycles;
                        self.inject_cooldown = self.cfg.inject_interval;
                    }
                    Err(entry) => {
                        // Target queue full: retry next cycle.
                        self.pending.push_front((entry.id, entry.features, start));
                        self.stats.stall_cycles += 1;
                    }
                }
            }
        }
    }

    fn finish_eval(&mut self, tile_idx: usize, mut entry: Entry) {
        let grove = &self.fog.groves[tile_idx];
        let hops_after = entry.hops + 1;
        let (conf, ops) =
            self.cfg.pe.evaluate(grove, &entry.features, &mut entry.prob, hops_after);
        entry.hops = hops_after;
        self.stats.comparator_ops += ops;

        let max_hops = self.cfg.max_hops.min(self.fog.n_groves());
        let done = conf >= self.cfg.threshold || (entry.hops as usize) >= max_hops;
        if done {
            let inv = 1.0 / entry.hops as f32;
            let prob: Vec<f32> = entry.prob.iter().map(|p| p * inv).collect();
            let label = crate::util::argmax(&prob);
            let injected =
                self.injected_at.get(entry.id as usize).copied().unwrap_or(0);
            self.stats.classified += 1;
            self.stats.total_hops += entry.hops as u64;
            self.stats.total_latency_cycles += self.stats.cycles - injected;
            self.outcomes.push(SimOutcome {
                id: entry.id,
                label,
                hops: entry.hops as usize,
                latency_cycles: self.stats.cycles - injected,
                prob,
            });
        } else {
            // Forward to the next grove. If the outbox is occupied (a
            // previous transfer is still stalled) the PE would stall in
            // hardware; here the occupancy is at most one entry because
            // the PE cannot finish another item before we clear it — we
            // busy-wait by re-queueing at the front (zero-cost retry).
            debug_assert!(self.tiles[tile_idx].outbox.is_none());
            self.tiles[tile_idx].outbox = Some(entry);
            self.tiles[tile_idx].handshake.raise_req();
        }
        // Tile returns to Idle; queue traffic counters live inside each
        // DataQueue and are folded into stats once per run() (§Perf
        // iteration 2: refreshing per completion was O(tiles) each).
    }

    /// Human-readable tile state summary (debugging / verbose mode).
    pub fn debug_state(&self) -> String {
        let mut s = format!(
            "cycle={} classified={} pending={} occ={}/{}\n",
            self.stats.cycles,
            self.outcomes.len(),
            self.pending.len(),
            self.occupancy(),
            self.total_slots()
        );
        for (i, t) in self.tiles.iter().enumerate() {
            let st = match &t.state {
                TileState::Idle => "idle".to_string(),
                TileState::Busy { remaining, entry } => {
                    format!("busy(rem={remaining},id={})", entry.id)
                }
            };
            s += &format!(
                "  G{i}: q={}/{} outbox={:?} hs={:?} {st}\n",
                t.queue.len(),
                t.queue.capacity_entries(),
                t.outbox.as_ref().map(|e| e.id),
                t.handshake.state,
            );
        }
        s
    }

    /// Entries currently inside the ring: queues, outboxes, **and** PE
    /// pipelines — an entry being evaluated will need an outbox slot when
    /// it finishes, so it must count against the bubble invariant.
    fn occupancy(&self) -> usize {
        self.tiles
            .iter()
            .map(|t| {
                t.queue.len()
                    + t.outbox.is_some() as usize
                    + matches!(t.state, TileState::Busy { .. }) as usize
            })
            .sum()
    }

    /// Total ring storage slots (queue capacities + one outbox per tile;
    /// the PE pipeline slot is *not* counted as capacity because a
    /// finishing entry needs the outbox — counting it would allow a state
    /// with every outbox pre-committed and no bubble).
    fn total_slots(&self) -> usize {
        self.tiles
            .iter()
            .map(|t| t.queue.capacity_entries() + 1)
            .sum()
    }

    fn refresh_queue_counters(&mut self) {
        self.stats.queue_bytes_read =
            self.tiles.iter().map(|t| t.queue.bytes_read).sum();
        self.stats.queue_bytes_written =
            self.tiles.iter().map(|t| t.queue.bytes_written).sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::fog::FogParams;
    use crate::forest::{ForestParams, RandomForest};

    fn setup() -> (FieldOfGroves, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 131);
        let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 1);
        (FieldOfGroves::from_forest(&rf, 4), ds)
    }

    #[test]
    fn matches_algorithm2() {
        let (fog, ds) = setup();
        let threshold = 0.35;
        let seed = 17;
        // Software Algorithm 2.
        let sw = fog.evaluate(
            &ds.test.x,
            &FogParams { threshold, max_hops: fog.n_groves(), seed },
        );
        // μarch simulation with the same per-input start-grove stream.
        let cfg = RingConfig { threshold, seed, ..Default::default() };
        let mut sim = RingSim::new(&fog, cfg);
        sim.load_batch(&ds.test.x);
        let outcomes = sim.run().to_vec();
        assert_eq!(outcomes.len(), ds.test.len());
        for (o, s) in outcomes.iter().zip(&sw.outcomes) {
            assert_eq!(o.label, s.label, "id {}", o.id);
            assert_eq!(o.hops, s.hops, "id {}", o.id);
            for (a, b) in o.prob.iter().zip(&s.prob) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn stats_consistent() {
        let (fog, ds) = setup();
        let cfg = RingConfig { threshold: 0.5, seed: 3, ..Default::default() };
        let mut sim = RingSim::new(&fog, cfg);
        sim.load_batch(&ds.test.x);
        sim.run();
        assert_eq!(sim.stats.classified as usize, ds.test.len());
        assert!(sim.stats.avg_hops() >= 1.0);
        assert!(sim.stats.avg_latency_cycles() > 0.0);
        assert!(sim.stats.comparator_ops > 0);
        assert!(sim.stats.queue_bytes_written > 0);
        assert!(sim.stats.avg_utilization() <= 1.0);
        // handshakes = total forwards = total hops - classified
        assert_eq!(
            sim.stats.handshakes,
            sim.stats.total_hops - sim.stats.classified
        );
    }

    #[test]
    fn tiny_queue_backpressure_still_completes() {
        let (fog, ds) = setup();
        // One-entry queues force handshake stalls.
        let gamma = 1 + fog.n_features + 1 + fog.n_classes;
        let cfg = RingConfig {
            threshold: 0.9,
            queue_bytes: gamma, // capacity 1
            inject_interval: 1,
            seed: 5,
            ..Default::default()
        };
        let mut sim = RingSim::new(&fog, cfg);
        let n = 40.min(ds.test.len());
        sim.load_batch(&ds.test.x[..n * fog.n_features]);
        let outcomes = sim.run();
        assert_eq!(outcomes.len(), n);
    }

    #[test]
    fn zero_threshold_single_hop_everywhere() {
        let (fog, ds) = setup();
        let cfg = RingConfig { threshold: 0.0, seed: 7, ..Default::default() };
        let mut sim = RingSim::new(&fog, cfg);
        sim.load_batch(&ds.test.x);
        let outcomes = sim.run();
        assert!(outcomes.iter().all(|o| o.hops == 1));
        assert_eq!(sim.stats.handshakes, 0);
    }

    #[test]
    fn max_hops_cap_respected() {
        let (fog, ds) = setup();
        let cfg = RingConfig { threshold: 2.0, max_hops: 2, seed: 9, ..Default::default() };
        let mut sim = RingSim::new(&fog, cfg);
        sim.load_batch(&ds.test.x);
        let outcomes = sim.run();
        assert!(outcomes.iter().all(|o| o.hops == 2));
    }

    #[test]
    fn sequential_tile_loads_complete_each_batch() {
        // The tile-drive contract: load → run → load → run must simulate
        // every newly loaded input (ids continue across loads), not
        // return the first batch's outcomes again.
        let (fog, ds) = setup();
        let cfg = RingConfig { threshold: 0.4, seed: 13, ..Default::default() };
        let mut sim = RingSim::new(&fog, cfg);
        let f = fog.n_features;
        let (n1, n2) = (10usize, 6usize);
        let starts1 = vec![0usize; n1];
        let starts2 = vec![1usize; n2];
        sim.load_batch_with_starts(&ds.test.x[..n1 * f], &starts1);
        assert_eq!(sim.run().len(), n1);
        sim.load_batch_with_starts(&ds.test.x[n1 * f..(n1 + n2) * f], &starts2);
        let outcomes = sim.run();
        assert_eq!(outcomes.len(), n1 + n2, "second tile not driven to completion");
        assert!(outcomes.iter().enumerate().all(|(i, o)| o.id == i as u32));
    }

    #[test]
    fn faster_injection_higher_utilization() {
        let (fog, ds) = setup();
        let run = |interval| {
            let cfg = RingConfig {
                threshold: 0.6,
                inject_interval: interval,
                seed: 11,
                ..Default::default()
            };
            let mut sim = RingSim::new(&fog, cfg);
            sim.load_batch(&ds.test.x);
            sim.run();
            sim.stats.avg_utilization()
        };
        let fast = run(1);
        let slow = run(64);
        assert!(fast > slow, "fast {fast} slow {slow}");
    }
}
