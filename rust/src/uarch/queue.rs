//! The grove data queue (paper §3.2.2 "Data Queue").
//!
//! A circular byte-addressable memory storing Γ-byte entries:
//! `{hops: 1 B, input payload: n_features B + 1 B id, probability array:
//! n_classes B}`. Two pointers — `$fr` (front: the entry currently being
//! processed) and `$bk` (back: first empty slot) — are maintained by the
//! queue controller (DQC) and advance in Γ steps (Γ is programmable per
//! dataset, §3.2.2 "Reprogrammability").
//!
//! Priority rule from the paper: inputs arriving from the **processor**
//! are placed at the back; inputs from the **neighbouring grove** are
//! placed at the *front*, so partially-computed work is served first.

/// One logical queue entry. Features/probabilities are kept as f32 for
//  functional fidelity; the byte accounting uses Γ.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub id: u32,
    pub hops: u8,
    pub features: Vec<f32>,
    /// Running probability *sum* (one mass unit per contributing grove).
    pub prob: Vec<f32>,
}

/// Fixed-capacity deque emulating the circular grove memory.
#[derive(Debug)]
pub struct DataQueue {
    /// Γ: bytes per entry = 1 (hops) + n_features + 1 (id) + n_classes.
    pub gamma: usize,
    /// Memory size in bytes (paper: 6 kB per grove).
    pub capacity_bytes: usize,
    entries: std::collections::VecDeque<Entry>,
    /// Lifetime counters for energy accounting.
    pub bytes_written: u64,
    pub bytes_read: u64,
}

impl DataQueue {
    pub fn new(n_features: usize, n_classes: usize, capacity_bytes: usize) -> DataQueue {
        let gamma = 1 + n_features + 1 + n_classes;
        assert!(capacity_bytes >= gamma, "queue smaller than one entry");
        DataQueue {
            gamma,
            capacity_bytes,
            entries: std::collections::VecDeque::new(),
            bytes_written: 0,
            bytes_read: 0,
        }
    }

    /// Max entries that fit (the paper's example: 6 kB stores 8 MNIST
    /// entries ≈ 6144 / 796).
    pub fn capacity_entries(&self) -> usize {
        self.capacity_bytes / self.gamma
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.entries.len() >= self.capacity_entries()
    }

    /// Processor-side enqueue at `$bk`. Fails (backpressure) when full.
    pub fn push_back(&mut self, e: Entry) -> Result<(), Entry> {
        if self.is_full() {
            return Err(e);
        }
        self.bytes_written += self.gamma as u64;
        self.entries.push_back(e);
        Ok(())
    }

    /// Neighbour-side enqueue at `$fr` (priority). Fails when full.
    pub fn push_front(&mut self, e: Entry) -> Result<(), Entry> {
        if self.is_full() {
            return Err(e);
        }
        self.bytes_written += self.gamma as u64;
        self.entries.push_front(e);
        Ok(())
    }

    /// DQC routes `$fr` to the PE: dequeue the front entry.
    pub fn pop_front(&mut self) -> Option<Entry> {
        let e = self.entries.pop_front();
        if e.is_some() {
            self.bytes_read += self.gamma as u64;
        }
        e
    }

    /// Invariant: occupancy never exceeds physical capacity (pointers
    /// never cross). Exercised by proptests.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.entries.len() > self.capacity_entries() {
            return Err(format!(
                "occupancy {} > capacity {}",
                self.entries.len(),
                self.capacity_entries()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: u32) -> Entry {
        Entry { id, hops: 0, features: vec![0.0; 5], prob: vec![0.0; 3] }
    }

    #[test]
    fn gamma_matches_paper_example() {
        // 5 features, 3 classes → Γ = 1 + 5 + 1 + 3 = 10 (paper §3.2.2).
        let q = DataQueue::new(5, 3, 6 * 1024);
        assert_eq!(q.gamma, 10);
    }

    #[test]
    fn mnist_capacity_example() {
        // Paper: 6 kB stores 8 MNIST examples per grove.
        // Γ(MNIST) = 1 + 784 + 1 + 10 = 796; 6144/796 = 7.7 → 7 full
        // entries by strict byte math — the paper rounds to 8; we assert
        // the order of magnitude.
        let q = DataQueue::new(784, 10, 6 * 1024);
        assert!(q.capacity_entries() >= 7 && q.capacity_entries() <= 8);
    }

    #[test]
    fn fifo_order_and_priority() {
        let mut q = DataQueue::new(5, 3, 1024);
        q.push_back(entry(1)).unwrap();
        q.push_back(entry(2)).unwrap();
        q.push_front(entry(3)).unwrap(); // neighbour input takes priority
        assert_eq!(q.pop_front().unwrap().id, 3);
        assert_eq!(q.pop_front().unwrap().id, 1);
        assert_eq!(q.pop_front().unwrap().id, 2);
        assert!(q.pop_front().is_none());
    }

    #[test]
    fn backpressure_when_full() {
        let mut q = DataQueue::new(5, 3, 20); // 2 entries
        assert_eq!(q.capacity_entries(), 2);
        q.push_back(entry(1)).unwrap();
        q.push_back(entry(2)).unwrap();
        assert!(q.push_back(entry(3)).is_err());
        assert!(q.push_front(entry(4)).is_err());
        q.check_invariants().unwrap();
    }

    #[test]
    fn byte_accounting() {
        let mut q = DataQueue::new(5, 3, 1024);
        q.push_back(entry(1)).unwrap();
        q.pop_front();
        assert_eq!(q.bytes_written, 10);
        assert_eq!(q.bytes_read, 10);
    }
}
