//! The grove processing element (paper §3.2.2 "Processing Element").
//!
//! "The latency of the PE depends on the number of trees per grove, the
//! maximum depth of each tree and degree of parallelism." We model
//! exactly that: trees are grouped onto `parallelism` physical tree
//! engines; each engine walks one level in [`CYCLES_PER_LEVEL`] cycles
//! (node fetch → compare → next-address). The functional result is
//! delegated to the same [`Grove`] the software path uses — an arena
//! slice since the `exec` refactor, so comparator-op counts
//! (`Grove::ops_per_eval` = trees × padded depth) derive from the arena
//! layout and are numerically identical to the per-tree accounting.
//!
//! Note on the software kernel's live-depth early exit: the PE stays
//! **depth-bound** — a hardware tree engine clocks through every padded
//! level, so `latency` and `ops_per_eval` deliberately do *not* shrink
//! for ragged forests (keeping Table 1 / Fig 4–5 stable). The exit's
//! saving is a software-kernel observable, reported separately as
//! `ExecReport::levels_skipped`.

use crate::fog::confidence::max_diff;
use crate::fog::Grove;

/// Serial cycles per tree level (matches the analytical model's
/// `TREE_CYCLES_PER_LEVEL`).
pub const CYCLES_PER_LEVEL: u64 = 3;
/// Cycles to average the probability array and compute MaxDiff.
pub const COMBINE_CYCLES: u64 = 4;

/// Timing + functional model of one grove PE.
#[derive(Clone, Debug)]
pub struct PeModel {
    /// Physical tree engines evaluating in parallel.
    pub parallelism: usize,
}

impl Default for PeModel {
    fn default() -> Self {
        // One engine per tree: all trees in parallel (the paper's FoG
        // design point; area has already been charged for it).
        PeModel { parallelism: usize::MAX }
    }
}

impl PeModel {
    /// PE latency in cycles for one input on `grove`.
    pub fn latency(&self, grove: &Grove) -> u64 {
        let engines = self.parallelism.min(grove.n_trees()).max(1);
        let rounds = grove.n_trees().div_ceil(engines) as u64;
        rounds * grove.depth() as u64 * CYCLES_PER_LEVEL + COMBINE_CYCLES
    }

    /// Run the grove on an input: accumulate its probability mass into
    /// `prob_sum` (one unit per grove, Algorithm 2 line 7), and return
    /// `(confidence, comparator_ops)` after normalizing by `hops`.
    pub fn evaluate(
        &self,
        grove: &Grove,
        features: &[f32],
        prob_sum: &mut [f32],
        hops_after: u8,
    ) -> (f32, u64) {
        grove.accumulate_proba(features, prob_sum);
        let inv = 1.0 / hops_after as f32;
        let norm: Vec<f32> = prob_sum.iter().map(|p| p * inv).collect();
        let conf = max_diff(&norm);
        (conf, grove.ops_per_eval() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest};

    fn grove() -> (Grove, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 121);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 1);
        (Grove::new(rf.flatten(rf.max_depth())), ds)
    }

    #[test]
    fn latency_scales_with_parallelism() {
        let (g, _) = grove();
        let serial = PeModel { parallelism: 1 };
        let parallel = PeModel::default();
        assert!(serial.latency(&g) > parallel.latency(&g));
        // Fully parallel: one round of `depth` levels.
        assert_eq!(
            parallel.latency(&g),
            g.depth() as u64 * CYCLES_PER_LEVEL + COMBINE_CYCLES
        );
    }

    #[test]
    fn evaluate_matches_grove() {
        let (g, ds) = grove();
        let pe = PeModel::default();
        let mut sum = vec![0.0f32; g.n_classes];
        let (conf, ops) = pe.evaluate(&g, ds.test.row(0), &mut sum, 1);
        let direct = g.predict_proba(ds.test.row(0));
        for (a, b) in sum.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-6);
        }
        assert!((conf - max_diff(&direct)).abs() < 1e-6);
        assert_eq!(ops, g.ops_per_eval() as u64);
    }

    #[test]
    fn two_hop_normalization() {
        let (g, ds) = grove();
        let pe = PeModel::default();
        let mut sum = vec![0.0f32; g.n_classes];
        pe.evaluate(&g, ds.test.row(0), &mut sum, 1);
        let (conf2, _) = pe.evaluate(&g, ds.test.row(0), &mut sum, 2);
        // Same grove twice = same normalized distribution as once.
        let once = g.predict_proba(ds.test.row(0));
        assert!((conf2 - max_diff(&once)).abs() < 1e-5);
    }
}
