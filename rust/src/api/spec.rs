//! [`ModelSpec`] — the concrete [`Estimator`]: a builder over every model
//! family in the crate, constructible by registry name. One construction
//! site replaces the per-type match arms that used to be hand-rolled in
//! the experiment suite, the coordinator and the CLI.

use super::models::{FogModel, RfModel};
use super::{Classifier, Estimator};
use crate::baselines::cnn::CnnParams;
use crate::baselines::mlp::MlpParams;
use crate::baselines::svm_linear::LinearSvmParams;
use crate::baselines::svm_rbf::RbfSvmParams;
use crate::baselines::{Cnn, LinearSvm, Mlp, RbfSvm};
use crate::data::Split;
use crate::dt::TreeParams;
use crate::energy::model::ClassifierKind;
use crate::exec::QuantMode;
use crate::fog::tuner::{accuracy_optimal_threshold, default_grid, threshold_sweep};
use crate::fog::{FieldOfGroves, FogParams};
use crate::forest::{ForestParams, RandomForest, VoteMode};

/// Every model family trainable by name. `"rf"` is the paper's
/// conventional majority-vote forest; `"rf_prob"` the probability-average
/// variant; `"fog_opt"` tunes its threshold on a training holdout
/// (the paper's accuracy-optimal point); `"fog_max"` forces full ring
/// circulation (threshold at maximum).
pub const REGISTRY: &[&str] =
    &["fog_opt", "fog_max", "rf", "rf_prob", "svm_lr", "svm_rbf", "mlp", "cnn"];

/// FoG training configuration (Algorithm 1 split + operating point).
#[derive(Clone, Debug)]
pub struct FogSpec {
    pub forest: ForestParams,
    /// Trees per grove (`b` of the paper's `a×b` topology). Clamped to
    /// the forest size at fit time.
    pub trees_per_grove: usize,
    /// Fixed confidence threshold; `None` tunes the accuracy-optimal
    /// threshold on a holdout carved from the training data.
    pub threshold: Option<f32>,
    /// Hop cap; `None` = the grove count (the paper's Figure-5 setting).
    pub max_hops: Option<usize>,
    /// Fraction of the training data held out for threshold tuning.
    pub holdout_frac: f32,
    /// FoG_max: ignore `threshold` and force full circulation.
    pub force_max: bool,
}

/// Per-family configuration carried by a [`ModelSpec`].
#[derive(Clone, Debug)]
pub enum ModelConfig {
    Fog(FogSpec),
    Rf { forest: ForestParams, mode: VoteMode },
    SvmLinear(LinearSvmParams),
    SvmRbf(RbfSvmParams),
    Mlp(MlpParams),
    Cnn(CnnParams),
}

/// Target-selection policy for the serving tiers: the start grove of the
/// FoG ring, or the replica of a sharded server. Defined here (not in
/// `coordinator`) so the model registry stays below the serving tier in
/// the layering; `coordinator::router` re-exports it next to the
/// [`ShardRouter`](crate::coordinator::ShardRouter) that interprets it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Per-input deterministic random stream (Algorithm 2 line 3).
    Random,
    /// Strict rotation.
    RoundRobin,
    /// Fewest in-flight items (greedy least-loaded, rotating tie-break).
    LeastLoaded,
}

impl RouterPolicy {
    /// Canonical CLI spellings, for friendly unknown-value errors.
    pub const NAMES: &'static [&'static str] = &["random", "round_robin", "least_loaded"];

    /// CLI / BENCH_JSON label.
    pub fn label(&self) -> &'static str {
        match self {
            RouterPolicy::Random => "random",
            RouterPolicy::RoundRobin => "round_robin",
            RouterPolicy::LeastLoaded => "least_loaded",
        }
    }

    /// Parse a CLI spelling (`random | round_robin | least_loaded`, with
    /// `rr`/`least` shorthands).
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "random" => Some(RouterPolicy::Random),
            "round_robin" | "round-robin" | "rr" => Some(RouterPolicy::RoundRobin),
            "least_loaded" | "least-loaded" | "least" => Some(RouterPolicy::LeastLoaded),
            _ => None,
        }
    }
}

/// Execution-backend selection for the serving tier. Defined here next
/// to [`RouterPolicy`] (registry layer below serving); the replicas of a
/// [`ShardedServer`](crate::coordinator::ShardedServer) interpret it by
/// resolving [`Classifier::exec_backend`](super::Classifier::exec_backend)
/// once at start-up.
///
/// `Software` evaluates through the level-synchronous arena kernels
/// unchanged; `Uarch` streams the same tiles through the cycle-level
/// grove-ring simulator, adding per-classification cycle and energy
/// accounting without changing any answer (tree-based models only).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    #[default]
    Software,
    Uarch,
}

impl BackendKind {
    /// Canonical CLI spellings, for friendly unknown-value errors.
    pub const NAMES: &'static [&'static str] = &["software", "uarch"];

    /// CLI / BENCH_JSON label.
    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Software => "software",
            BackendKind::Uarch => "uarch",
        }
    }

    /// Parse a CLI spelling (`software | uarch`, with `sw`/`sim`
    /// shorthands).
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "software" | "sw" => Some(BackendKind::Software),
            "uarch" | "sim" => Some(BackendKind::Uarch),
            _ => None,
        }
    }
}

/// Admission policy of the multi-model fleet tier
/// ([`Fleet`](crate::coordinator::Fleet)): what happens to a request
/// whose model has exhausted its energy/latency budget. Defined here next
/// to [`RouterPolicy`] / [`BackendKind`] (registry layer below serving);
/// `coordinator::fleet` interprets it by building the matching
/// [`FleetPolicy`](crate::coordinator::FleetPolicy) object.
///
/// Paper anchor: Fig 5 frames FoG as the best classifier *under a tight
/// energy budget*; the fleet tier promotes that budget from an offline
/// axis to a live admission signal, and this enum picks what "over
/// budget" means for traffic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FleetPolicyKind {
    /// Shed (reject) requests for an over-budget model outright.
    Strict,
    /// Fall back to another registered model in fleet registration order
    /// (e.g. `fog_max` → `fog_opt`); shed only when every model is over
    /// budget.
    #[default]
    Downgrade,
}

impl FleetPolicyKind {
    /// Canonical CLI spellings, for friendly unknown-value errors.
    pub const NAMES: &'static [&'static str] = &["strict", "downgrade"];

    /// CLI / BENCH_JSON label.
    pub fn label(&self) -> &'static str {
        match self {
            FleetPolicyKind::Strict => "strict",
            FleetPolicyKind::Downgrade => "downgrade",
        }
    }

    /// Parse a CLI spelling (`strict | downgrade`, with `shed`/`fallback`
    /// shorthands).
    pub fn parse(s: &str) -> Option<FleetPolicyKind> {
        match s {
            "strict" | "shed" => Some(FleetPolicyKind::Strict),
            "downgrade" | "fallback" => Some(FleetPolicyKind::Downgrade),
            _ => None,
        }
    }
}

/// Serving-tier knobs carried next to the training config: how many
/// replicas of the trained model a
/// [`ShardedServer`](crate::coordinator::ShardedServer) runs, how
/// replicas are selected, which execution backend evaluates batches, and
/// whether/how coarsely results are cached.
/// Training ignores these; `fog serve` and the sharded examples read
/// them via
/// [`ShardedServerConfig::for_serving`](crate::coordinator::ShardedServerConfig::for_serving).
#[derive(Clone, Copy, Debug)]
pub struct ServingSpec {
    /// Model replicas behind the shared router (1 = unsharded).
    pub replicas: usize,
    /// Replica-selection policy.
    pub router: RouterPolicy,
    /// Execution backend replicas dispatch batches through.
    pub backend: BackendKind,
    /// Kernel-lane quantization: run forest tiles on u8/u16 rank-code
    /// lanes ([`QuantMode::Exact`] is answer-identical to f32; lossy
    /// trades accuracy for width). Forest-backed models only.
    pub quant: QuantMode,
    /// Quantization step of the result-cache keys; `None` disables
    /// caching, `Some(0.0)` caches with exact-bit keys.
    pub cache_quant: Option<f32>,
    /// Total result-cache entry budget.
    pub cache_capacity: usize,
    /// Fleet-tier admission policy when this model is registered in a
    /// [`Fleet`](crate::coordinator::Fleet) (ignored by the single-model
    /// tiers).
    pub fleet_policy: FleetPolicyKind,
    /// Fleet-tier rolling energy budget per classification, nanojoules;
    /// `None` = unlimited (every request admitted).
    pub energy_budget_nj: Option<f64>,
    /// Adaptive confidence early-exit threshold `t ∈ (0, 1]` (Daghero et
    /// al., arXiv 2205.13838): a sample stops accumulating tree votes
    /// once its running margin reaches `t`. `None` or `1.0` = full
    /// evaluation (`1.0` is pinned byte-identical). Tree-family models
    /// only.
    pub adaptive_conf: Option<f32>,
}

impl Default for ServingSpec {
    fn default() -> Self {
        ServingSpec {
            replicas: 1,
            router: RouterPolicy::LeastLoaded,
            backend: BackendKind::Software,
            quant: QuantMode::Off,
            cache_quant: None,
            cache_capacity: 4096,
            fleet_policy: FleetPolicyKind::default(),
            energy_budget_nj: None,
            adaptive_conf: None,
        }
    }
}

/// A named, buildable model configuration — the registry entry.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub config: ModelConfig,
    /// Serving-tier knobs (replicas / router / cache); see [`ServingSpec`].
    pub serving: ServingSpec,
}

// --- hyper-parameter scaling (shared with `experiments::suite`) --------

/// Forest sizing the paper's suite uses, keyed on dataset shape (big
/// profiles like ISOLET/MNIST get deeper, feature-capped trees).
pub fn forest_params_for(n_features: usize, n_classes: usize) -> ForestParams {
    let big = n_features > 100;
    let many_classes = n_classes > 10;
    ForestParams {
        n_trees: 16,
        tree: TreeParams {
            max_depth: if big || many_classes { 12 } else { 8 },
            min_samples_leaf: 2,
            max_features: if big { 64 } else { 0 },
            ..Default::default()
        },
        bootstrap: true,
    }
}

pub fn linear_params_for(n_features: usize) -> LinearSvmParams {
    let big = n_features > 100;
    LinearSvmParams { epochs: if big { 8 } else { 14 }, ..Default::default() }
}

pub fn rbf_params_for(n_features: usize) -> RbfSvmParams {
    let big = n_features > 100;
    RbfSvmParams { max_support: if big { 700 } else { 800 }, ..Default::default() }
}

pub fn mlp_params_for(n_features: usize) -> MlpParams {
    let big = n_features > 100;
    MlpParams {
        hidden: vec![if big { 96 } else { 64 }],
        epochs: if big { 12 } else { 30 },
        ..Default::default()
    }
}

pub fn cnn_params_for(n_features: usize) -> CnnParams {
    let big = n_features > 100;
    // Paper-comparable capacity: the paper's CNN is by far the largest
    // design (2.1 mm², ~0.2-1.3 µJ/classification); channel counts are
    // sized so conv MACs dominate at every feature count.
    CnnParams {
        conv1_channels: if big { 16 } else { 32 },
        conv2_channels: if big { 32 } else { 64 },
        pool1: if big { 4 } else { 2 },
        epochs: if big { 5 } else { 20 },
        ..Default::default()
    }
}

impl ModelSpec {
    pub fn new(name: impl Into<String>, config: ModelConfig) -> ModelSpec {
        ModelSpec { name: name.into(), config, serving: ServingSpec::default() }
    }

    /// Registry lookup with hyper-parameters scaled to the dataset shape
    /// (the rules `experiments::suite` applies to the paper's profiles).
    pub fn for_shape(name: &str, n_features: usize, n_classes: usize) -> Option<ModelSpec> {
        let config = match name {
            "fog_opt" => ModelConfig::Fog(FogSpec {
                forest: forest_params_for(n_features, n_classes),
                trees_per_grove: 2, // the paper's 8x2 working topology
                threshold: None,
                max_hops: None,
                holdout_frac: 0.2,
                force_max: false,
            }),
            "fog_max" => ModelConfig::Fog(FogSpec {
                forest: forest_params_for(n_features, n_classes),
                trees_per_grove: 2,
                threshold: None,
                max_hops: None,
                holdout_frac: 0.2,
                force_max: true,
            }),
            "rf" => ModelConfig::Rf {
                forest: forest_params_for(n_features, n_classes),
                mode: VoteMode::Majority,
            },
            "rf_prob" => ModelConfig::Rf {
                forest: forest_params_for(n_features, n_classes),
                mode: VoteMode::ProbAverage,
            },
            "svm_lr" => ModelConfig::SvmLinear(linear_params_for(n_features)),
            "svm_rbf" => ModelConfig::SvmRbf(rbf_params_for(n_features)),
            "mlp" => ModelConfig::Mlp(mlp_params_for(n_features)),
            "cnn" => ModelConfig::Cnn(cnn_params_for(n_features)),
            _ => return None,
        };
        Some(ModelSpec::new(name, config))
    }

    /// Registry lookup with default (penbase-shaped) hyper-parameters.
    pub fn by_name(name: &str) -> Option<ModelSpec> {
        Self::for_shape(name, 16, 10)
    }

    // --- builder knobs -------------------------------------------------

    /// Set the ensemble size (forest-backed configs only; no-op otherwise).
    pub fn with_trees(mut self, n_trees: usize) -> Self {
        match &mut self.config {
            ModelConfig::Fog(s) => s.forest.n_trees = n_trees,
            ModelConfig::Rf { forest, .. } => forest.n_trees = n_trees,
            _ => {}
        }
        self
    }

    /// Set the FoG grove size (trees per grove; no-op for other families).
    pub fn with_grove_size(mut self, trees_per_grove: usize) -> Self {
        if let ModelConfig::Fog(s) = &mut self.config {
            s.trees_per_grove = trees_per_grove.max(1);
        }
        self
    }

    /// Pin the FoG confidence threshold instead of tuning it.
    pub fn with_threshold(mut self, threshold: f32) -> Self {
        if let ModelConfig::Fog(s) = &mut self.config {
            s.threshold = Some(threshold);
        }
        self
    }

    // --- serving knobs (read by `fog serve` / the sharded tier) --------

    /// Serve this model through `n` replicas (clamped to ≥ 1).
    pub fn with_replicas(mut self, n: usize) -> Self {
        self.serving.replicas = n.max(1);
        self
    }

    /// Replica-selection policy for the sharded tier.
    pub fn with_router(mut self, policy: RouterPolicy) -> Self {
        self.serving.router = policy;
        self
    }

    /// Execution backend the serving replicas dispatch batches through
    /// (`Software` = arena kernels; `Uarch` = hardware-in-the-loop grove
    /// ring with live cycle/energy accounting, tree-based models only).
    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.serving.backend = backend;
        self
    }

    /// Kernel-lane quantization mode for forest-backed models
    /// (`Exact` = u8/u16 rank codes, answer-identical to f32; no-op for
    /// families without an arena).
    pub fn with_quant(mut self, mode: QuantMode) -> Self {
        self.serving.quant = mode;
        self
    }

    /// Enable the serving result cache with the given key-quantization
    /// step (0.0 = exact-bit keys; hits are byte-identical to cold
    /// evaluation).
    pub fn with_cache_quant(mut self, step: f32) -> Self {
        self.serving.cache_quant = Some(step.max(0.0));
        self
    }

    /// Result-cache entry budget (0 disables caching outright).
    pub fn with_cache_capacity(mut self, capacity: usize) -> Self {
        self.serving.cache_capacity = capacity;
        self
    }

    /// Fleet-tier admission policy (what happens to requests for an
    /// over-budget model: `Strict` sheds, `Downgrade` falls back in
    /// fleet registration order).
    pub fn with_fleet_policy(mut self, policy: FleetPolicyKind) -> Self {
        self.serving.fleet_policy = policy;
        self
    }

    /// Fleet-tier rolling energy budget per classification (nJ); pass
    /// `f64::INFINITY` or skip the call for an unlimited budget.
    pub fn with_energy_budget_nj(mut self, budget_nj: f64) -> Self {
        self.serving.energy_budget_nj = Some(budget_nj.max(0.0));
        self
    }

    /// Adaptive confidence early-exit threshold `t ∈ (0, 1]` for the
    /// serving paths of tree-family models (no-op for the dense
    /// baselines). `1.0` = full evaluation, byte-identical to not
    /// setting the knob; the CLI validates the range before calling
    /// this.
    pub fn with_adaptive(mut self, t: f32) -> Self {
        self.serving.adaptive_conf = Some(t);
        self
    }

    /// Shrink training budgets for fast tests and doc examples (smaller
    /// ensembles, fewer epochs, fewer support vectors). Accuracy drops a
    /// little; determinism and interfaces are unchanged.
    pub fn fast(mut self) -> Self {
        match &mut self.config {
            ModelConfig::Fog(s) => {
                s.forest.n_trees = s.forest.n_trees.min(8);
                s.forest.tree.max_depth = s.forest.tree.max_depth.min(6);
            }
            ModelConfig::Rf { forest, .. } => {
                forest.n_trees = forest.n_trees.min(8);
                forest.tree.max_depth = forest.tree.max_depth.min(6);
            }
            ModelConfig::SvmLinear(p) => p.epochs = p.epochs.min(6),
            ModelConfig::SvmRbf(p) => p.max_support = p.max_support.min(200),
            ModelConfig::Mlp(p) => {
                p.epochs = p.epochs.min(8);
                p.hidden = vec![16];
            }
            ModelConfig::Cnn(p) => {
                p.epochs = p.epochs.min(4);
                p.conv1_channels = p.conv1_channels.min(4);
                p.conv2_channels = p.conv2_channels.min(8);
            }
        }
        self
    }

    fn fit_fog(&self, spec: &FogSpec, data: &Split, seed: u64) -> FogModel {
        assert!(data.len() >= 2, "need at least 2 samples to train a FoG");
        let split_fog = |rf: &RandomForest| {
            let k = spec.trees_per_grove.clamp(1, rf.n_trees());
            FieldOfGroves::from_forest_shuffled(rf, k, Some(seed ^ 0x5EED))
        };
        if spec.force_max {
            let rf = RandomForest::fit(data, &spec.forest, seed);
            return FogModel::fog_max(split_fog(&rf), seed)
                .with_adaptive(self.serving.adaptive_conf);
        }
        let threshold = match spec.threshold {
            Some(t) => t,
            None => {
                // Tune on a strided holdout (every `stride`-th row), which
                // stays class-balanced even for label-sorted inputs like
                // UCI CSVs, using a throwaway forest trained without it.
                let n = data.len();
                let frac = spec.holdout_frac.clamp(0.05, 0.5);
                let stride = ((1.0 / frac).round() as usize).clamp(2, n);
                let val_idx: Vec<usize> =
                    (0..n).filter(|i| i % stride == stride - 1).collect();
                let train_idx: Vec<usize> =
                    (0..n).filter(|i| i % stride != stride - 1).collect();
                let train = data.subset(&train_idx);
                let val = data.subset(&val_idx);
                let rf_tune = RandomForest::fit(&train, &spec.forest, seed);
                let fog_tune = split_fog(&rf_tune);
                let sweep = threshold_sweep(&fog_tune, &val, &default_grid(), seed);
                accuracy_optimal_threshold(&sweep, 0.01).threshold
            }
        };
        // The final model always trains on the full split, so registry
        // entries stay comparable (tuning never costs training data).
        let rf = RandomForest::fit(data, &spec.forest, seed);
        let fog = split_fog(&rf);
        let n_groves = fog.n_groves();
        let max_hops = spec.max_hops.unwrap_or(n_groves).clamp(1, n_groves);
        FogModel::new(
            fog,
            FogParams { threshold, max_hops, seed },
            ClassifierKind::FogOpt,
        )
        .with_adaptive(self.serving.adaptive_conf)
    }
}

impl Estimator for ModelSpec {
    fn name(&self) -> &str {
        &self.name
    }

    /// The single model-construction site: everything downstream holds a
    /// `Box<dyn Classifier>` and never matches on the model family again.
    fn fit(&self, data: &Split, seed: u64) -> Box<dyn Classifier> {
        match &self.config {
            ModelConfig::Fog(spec) => Box::new(self.fit_fog(spec, data, seed)),
            ModelConfig::Rf { forest, mode } => Box::new(
                RfModel::new(RandomForest::fit(data, forest, seed), *mode)
                    .with_quant(self.serving.quant)
                    .with_adaptive(self.serving.adaptive_conf),
            ),
            ModelConfig::SvmLinear(p) => Box::new(LinearSvm::fit(data, p, seed)),
            ModelConfig::SvmRbf(p) => Box::new(RbfSvm::fit(data, p, seed)),
            ModelConfig::Mlp(p) => Box::new(Mlp::fit(data, p, seed)),
            ModelConfig::Cnn(p) => Box::new(Cnn::fit(data, p, seed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    #[test]
    fn registry_names_resolve() {
        for name in REGISTRY {
            let spec = ModelSpec::by_name(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(spec.name, *name);
        }
        assert!(ModelSpec::by_name("nope").is_none());
    }

    #[test]
    fn builder_knobs_apply() {
        let spec = ModelSpec::by_name("fog_opt")
            .unwrap()
            .with_trees(8)
            .with_grove_size(4)
            .with_threshold(0.4);
        match &spec.config {
            ModelConfig::Fog(s) => {
                assert_eq!(s.forest.n_trees, 8);
                assert_eq!(s.trees_per_grove, 4);
                assert_eq!(s.threshold, Some(0.4));
            }
            other => panic!("wrong config {other:?}"),
        }
    }

    #[test]
    fn serving_knobs_apply() {
        let spec = ModelSpec::by_name("rf")
            .unwrap()
            .with_replicas(4)
            .with_router(RouterPolicy::RoundRobin)
            .with_backend(BackendKind::Uarch)
            .with_quant(QuantMode::Exact)
            .with_cache_quant(0.25)
            .with_cache_capacity(128)
            .with_fleet_policy(FleetPolicyKind::Strict)
            .with_energy_budget_nj(1.5)
            .with_adaptive(0.7);
        assert_eq!(spec.serving.replicas, 4);
        assert_eq!(spec.serving.router, RouterPolicy::RoundRobin);
        assert_eq!(spec.serving.backend, BackendKind::Uarch);
        assert_eq!(spec.serving.quant, QuantMode::Exact);
        assert_eq!(spec.serving.cache_quant, Some(0.25));
        assert_eq!(spec.serving.cache_capacity, 128);
        assert_eq!(spec.serving.fleet_policy, FleetPolicyKind::Strict);
        assert_eq!(spec.serving.energy_budget_nj, Some(1.5));
        assert_eq!(spec.serving.adaptive_conf, Some(0.7));
        // Defaults: unsharded, software backend, no cache, unlimited
        // fleet budget — training is never affected.
        let plain = ModelSpec::by_name("rf").unwrap();
        assert_eq!(plain.serving.replicas, 1);
        assert_eq!(plain.serving.backend, BackendKind::Software);
        assert_eq!(plain.serving.quant, QuantMode::Off);
        assert!(plain.serving.cache_quant.is_none());
        assert_eq!(plain.serving.fleet_policy, FleetPolicyKind::Downgrade);
        assert!(plain.serving.energy_budget_nj.is_none());
        assert!(plain.serving.adaptive_conf.is_none());
        assert_eq!(ModelSpec::by_name("rf").unwrap().with_replicas(0).serving.replicas, 1);
        // A negative budget is clamped to the shed-everything floor of 0.
        let zero = ModelSpec::by_name("rf").unwrap().with_energy_budget_nj(-2.0);
        assert_eq!(zero.serving.energy_budget_nj, Some(0.0));
    }

    #[test]
    fn fleet_policy_labels_roundtrip() {
        for kind in [FleetPolicyKind::Strict, FleetPolicyKind::Downgrade] {
            assert_eq!(FleetPolicyKind::parse(kind.label()), Some(kind));
            assert!(FleetPolicyKind::NAMES.contains(&kind.label()));
        }
        assert_eq!(FleetPolicyKind::parse("shed"), Some(FleetPolicyKind::Strict));
        assert_eq!(FleetPolicyKind::parse("fallback"), Some(FleetPolicyKind::Downgrade));
        assert_eq!(FleetPolicyKind::parse("nope"), None);
        // The NAMES consts exist so CLI errors can list every valid
        // spelling without hand-maintained strings.
        for name in RouterPolicy::NAMES {
            assert!(RouterPolicy::parse(name).is_some());
        }
        for name in BackendKind::NAMES {
            assert!(BackendKind::parse(name).is_some());
        }
    }

    #[test]
    fn backend_kind_labels_roundtrip() {
        for kind in [BackendKind::Software, BackendKind::Uarch] {
            assert_eq!(BackendKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(BackendKind::parse("sw"), Some(BackendKind::Software));
        assert_eq!(BackendKind::parse("sim"), Some(BackendKind::Uarch));
        assert_eq!(BackendKind::parse("native"), None);
    }

    #[test]
    fn fog_opt_trains_and_predicts() {
        let ds = generate(&DatasetProfile::demo(), 281);
        let spec = ModelSpec::for_shape("fog_opt", ds.n_features(), ds.n_classes())
            .unwrap()
            .fast();
        let model = spec.fit(&ds.train, 7);
        assert_eq!(model.n_classes(), ds.n_classes());
        let acc = model.accuracy(&ds.test);
        assert!(acc > 0.5, "fog_opt acc {acc}");
    }

    #[test]
    fn shape_scaling_matches_profiles() {
        // Big profiles (ISOLET-shaped) get deeper feature-capped trees.
        let big = forest_params_for(617, 26);
        assert_eq!(big.tree.max_depth, 12);
        assert_eq!(big.tree.max_features, 64);
        let small = forest_params_for(16, 10);
        assert_eq!(small.tree.max_depth, 8);
        assert_eq!(small.tree.max_features, 0);
    }
}
