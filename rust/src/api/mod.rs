//! `fog::api` — the unified, batch-first classifier interface.
//!
//! The paper's headline claim (§4.2, Table 1) is a *comparison*: FoG vs.
//! RF, SVM_lr, SVM_rbf, MLP and CNN at matched accuracy and measured
//! energy. This module gives every one of those model families a single
//! interface so the experiment harnesses, the serving coordinator and the
//! CLI dispatch through trait objects instead of per-type match arms:
//!
//! * [`Classifier`] — a trained model: batch-first probability
//!   prediction ([`Classifier::predict_proba_batch`] → [`ProbMatrix`]),
//!   label prediction, accuracy, and a [`CostReport`] hook that feeds the
//!   energy models (op counts / avg hops measured on a probe split).
//! * [`Estimator`] — config → trained model: anything that can train a
//!   [`Classifier`] from a [`Split`] under a seed.
//! * [`ModelSpec`] — the concrete [`Estimator`]: a builder over every
//!   model family in the crate, constructible by registry name
//!   (`"fog_opt" | "fog_max" | "rf" | "rf_prob" | "svm_lr" | "svm_rbf" |
//!   "mlp" | "cnn"`, see [`REGISTRY`]).
//!
//! ```text
//! let spec  = ModelSpec::for_shape("rf", data.n_features, data.n_classes);
//! let model = spec.fit(&data.train, 42);          // Box<dyn Classifier>
//! let probs = model.predict_proba_batch(&data.test.x, data.test.len());
//! let acc   = model.accuracy(&data.test);
//! let cost  = model.cost_report(Some(&data.test), &eb, &ab);
//! ```

pub mod models;
pub mod spec;

pub use models::{measured_fog_stats, measured_rf_stats, FogModel, RfModel};
pub use spec::{
    BackendKind, FleetPolicyKind, FogSpec, ModelConfig, ModelSpec, RouterPolicy, ServingSpec,
    REGISTRY,
};

use crate::data::Split;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{ClassifierKind, CostReport};
use crate::util::threadpool::par_map;
use std::sync::Arc;

/// A row-major `[n, n_classes]` matrix of class-probability rows — the
/// result of one batched prediction.
#[derive(Clone, Debug, PartialEq)]
pub struct ProbMatrix {
    data: Vec<f32>,
    n_classes: usize,
}

impl ProbMatrix {
    /// Wrap a row-major buffer; `data.len()` must divide by `n_classes`.
    pub fn new(data: Vec<f32>, n_classes: usize) -> ProbMatrix {
        assert!(n_classes > 0, "n_classes = 0");
        assert_eq!(data.len() % n_classes, 0, "ragged probability matrix");
        ProbMatrix { data, n_classes }
    }

    /// Collect per-row distributions (all rows must share one length).
    pub fn from_rows(rows: Vec<Vec<f32>>, n_classes: usize) -> ProbMatrix {
        let mut data = Vec::with_capacity(rows.len() * n_classes);
        for r in rows {
            debug_assert_eq!(r.len(), n_classes);
            data.extend_from_slice(&r);
        }
        ProbMatrix::new(data, n_classes)
    }

    pub fn n_rows(&self) -> usize {
        self.data.len() / self.n_classes
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// One row's distribution.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n_classes..(i + 1) * self.n_classes]
    }

    /// Per-row argmax labels (first index wins ties, like
    /// [`crate::util::argmax`]).
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.n_rows()).map(|i| crate::util::argmax(self.row(i))).collect()
    }

    /// The underlying row-major buffer.
    pub fn into_raw(self) -> Vec<f32> {
        self.data
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
}

/// A trained classifier behind the unified batch-first interface.
///
/// Only [`Classifier::predict_proba_batch`] and
/// [`Classifier::cost_report`] (plus the shape accessors) are required;
/// per-sample prediction, label batches and accuracy all derive from the
/// batch path, so batch and per-sample results agree by construction
/// unless an implementation deliberately overrides them.
pub trait Classifier: Send + Sync {
    /// Which Table-1 column this model belongs to.
    fn kind(&self) -> ClassifierKind;

    /// Human-readable name (defaults to the Table-1 column label).
    fn name(&self) -> &'static str {
        self.kind().label()
    }

    fn n_features(&self) -> usize;

    fn n_classes(&self) -> usize;

    /// Class-probability prediction over a row-major batch
    /// `x: [n, n_features]`.
    fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix;

    /// Label prediction over a batch (argmax of the probability rows).
    fn predict_batch(&self, x: &[f32], n: usize) -> Vec<usize> {
        self.predict_proba_batch(x, n).argmax_rows()
    }

    /// Per-sample probability prediction (a batch of one).
    fn predict_proba(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.n_features());
        self.predict_proba_batch(x, 1).into_raw()
    }

    /// Per-sample label prediction (a batch of one).
    fn predict(&self, x: &[f32]) -> usize {
        self.predict_batch(x, 1)[0]
    }

    /// Accuracy over a labelled split (batch path).
    fn accuracy(&self, split: &Split) -> f64 {
        let preds = self.predict_batch(&split.x, split.len());
        crate::util::stats::accuracy(&preds, &split.y)
    }

    /// Hardware PPA of one classification on this trained model.
    ///
    /// When `probe` is given, dynamic op counts (traversed comparisons,
    /// average FoG hops) are *measured* on it — the paper's methodology
    /// for Table 1. Without a probe, static worst-case bounds (padded
    /// depth, full ring circulation) are charged instead.
    fn cost_report(
        &self,
        probe: Option<&Split>,
        eb: &EnergyBlocks,
        ab: &AreaBlocks,
    ) -> CostReport;

    /// The execution backend evaluating this model's batches under
    /// `kind`, or `None` when the family has no arena-backed engine (the
    /// dense baselines) — serving replicas then fall back to
    /// [`Classifier::predict_proba_batch`]. Implementations must keep
    /// every backend answer-identical to the direct batch path: backends
    /// change *accounting*, never *answers* (pinned by
    /// `rust/tests/backend.rs`).
    fn exec_backend(&self, kind: BackendKind) -> Option<Arc<dyn crate::exec::Backend>> {
        let _ = kind;
        None
    }

    /// The per-feature threshold rank tables this model quantizes with,
    /// when a [`QuantMode`](crate::exec::QuantMode) is active — shared so
    /// the serving tier ([`ProbCache`](crate::coordinator::ProbCache))
    /// can key on the same codes the kernel compares on, one
    /// quantization pass per request. `None` for non-quantized models
    /// and families without an arena.
    fn quant_tables(&self) -> Option<Arc<crate::exec::QuantTables>> {
        None
    }

    /// The vector ISA level this model's quantized batch paths dispatch
    /// to ([`SimdLevel`](crate::exec::SimdLevel)) — `Scalar` for f32
    /// lanes, non-arena families, and hosts without a matching kernel.
    /// Observability only: every level is answer-identical by
    /// construction (pinned in `exec::simd` / `rust/tests/quant.rs`).
    fn simd_level(&self) -> crate::exec::SimdLevel {
        crate::exec::SimdLevel::Scalar
    }

    /// The ISA whose index-gather kernel this model's quantized batch
    /// paths dispatch to — `Scalar` wherever a vector gather can't (or
    /// was pinned not to) run: f32 lanes, non-arena families, SSE2-only
    /// hosts, `FOG_FORCE_SCALAR_GATHER=1`. Observability only, like
    /// [`Classifier::simd_level`]: every gather stage is
    /// answer-identical by construction.
    fn gather_level(&self) -> crate::exec::SimdLevel {
        crate::exec::SimdLevel::Scalar
    }

    /// The adaptive confidence early-exit threshold active on this
    /// model's batch paths (Daghero et al., arXiv 2205.13838), already
    /// filtered to the effective range: `None` means full evaluation —
    /// either no knob was set or it was `≥ 1.0`, which is full
    /// evaluation by definition. The serving tier uses this to tag
    /// [`ProbCache`](crate::coordinator::ProbCache) keys so rows
    /// computed under one threshold never answer a request at another.
    fn adaptive_conf(&self) -> Option<f32> {
        None
    }
}

/// Config → trained model: anything that can train a [`Classifier`] from
/// a labelled [`Split`] under a deterministic seed.
pub trait Estimator: Send + Sync {
    /// Registry / display name of the model this estimator produces.
    fn name(&self) -> &str;

    /// Train on `data` with the given seed. Implementations must be
    /// deterministic: equal `(data, seed)` → an identical model.
    fn fit(&self, data: &Split, seed: u64) -> Box<dyn Classifier>;
}

/// Batch helper for score-based models (SVMs, MLP, CNN): evaluate
/// `score` on every row in parallel and normalize each row to a
/// probability distribution via softmax (argmax-preserving, so label
/// predictions equal the raw-score argmax).
pub fn batch_from_scores<F>(
    x: &[f32],
    n: usize,
    n_features: usize,
    n_classes: usize,
    score: F,
) -> ProbMatrix
where
    F: Fn(&[f32]) -> Vec<f32> + Sync,
{
    assert_eq!(x.len(), n * n_features, "batch shape mismatch");
    let rows = par_map(n, |i| {
        let mut s = score(&x[i * n_features..(i + 1) * n_features]);
        softmax_in_place(&mut s);
        s
    });
    ProbMatrix::from_rows(rows, n_classes)
}

/// Numerically-stable in-place softmax over one score row.
pub fn softmax_in_place(scores: &mut [f32]) {
    let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !max.is_finite() {
        // Degenerate row (empty or all -inf): uniform.
        let n = scores.len().max(1);
        scores.iter_mut().for_each(|v| *v = 1.0 / n as f32);
        return;
    }
    let mut sum = 0.0f32;
    for v in scores.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    scores.iter_mut().for_each(|v| *v *= inv);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prob_matrix_rows_and_argmax() {
        let m = ProbMatrix::new(vec![0.1, 0.7, 0.2, 0.5, 0.3, 0.2], 3);
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.row(1), &[0.5, 0.3, 0.2]);
        assert_eq!(m.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn softmax_normalizes_and_preserves_argmax() {
        let mut s = vec![1.0f32, 3.0, 2.0];
        softmax_in_place(&mut s);
        let sum: f32 = s.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert_eq!(crate::util::argmax(&s), 1);
    }

    #[test]
    #[should_panic]
    fn ragged_matrix_rejected() {
        ProbMatrix::new(vec![0.0; 7], 3);
    }
}
