//! [`Classifier`](super::Classifier) implementations for the tree-family
//! models: single decision trees (sparse and flattened), random forests
//! under both vote modes, and the Field of Groves itself (wrapping
//! Algorithm 2's confidence-gated evaluation, surfacing hops as cost).
//!
//! The four baselines implement the trait in their own modules
//! (`baselines::svm_linear` etc.) via [`super::batch_from_scores`].

use super::spec::BackendKind;
use super::{Classifier, ProbMatrix};
use crate::data::Split;
use crate::dt::{DecisionTree, FlatTree};
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{fog_cost, rf_cost, ClassifierKind, CostReport, FogStats, RfStats};
use crate::exec::backend::{fog_tile, forest_tile_adaptive};
use crate::exec::{
    Backend, BatchPlan, ForestArena, QuantMode, QuantTables, Reduce, SimdLevel, SoftwareBackend,
    UarchBackend,
};
use crate::fog::eval::{content_start_grove, InputOutcome};
use crate::fog::{FieldOfGroves, FogParams};
use crate::forest::{RandomForest, VoteMode};
use crate::util::threadpool::par_map;
use std::sync::Arc;

/// Bytes of sparse node storage the hardware provisions: 6 B per node
/// (weight + feature offset + control, §3.2.2 "Reprogrammability") plus
/// one byte per leaf-class slot.
fn sparse_tree_storage(n_nodes: usize, n_leaves: usize, n_classes: usize) -> f64 {
    n_nodes as f64 * 6.0 + (n_leaves * n_classes) as f64
}

// ---------------------------------------------------------------------------
// Single trees
// ---------------------------------------------------------------------------

impl Classifier for DecisionTree {
    fn kind(&self) -> ClassifierKind {
        ClassifierKind::Tree
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix {
        assert_eq!(x.len(), n * self.n_features, "batch shape mismatch");
        let f = self.n_features;
        let rows =
            par_map(n, |i| DecisionTree::predict_proba(self, &x[i * f..(i + 1) * f]).to_vec());
        ProbMatrix::from_rows(rows, self.n_classes)
    }

    fn cost_report(
        &self,
        probe: Option<&Split>,
        eb: &EnergyBlocks,
        ab: &AreaBlocks,
    ) -> CostReport {
        let avg_comparisons = match probe {
            Some(s) if !s.is_empty() => {
                let totals = par_map(s.len(), |i| self.predict_proba_counted(s.row(i)).1);
                totals.iter().sum::<usize>() as f64 / s.len() as f64
            }
            _ => self.depth as f64, // worst case: a full root-to-leaf walk
        };
        let stats = RfStats {
            n_trees: 1,
            avg_comparisons,
            max_depth: self.depth.max(1),
            n_features: self.n_features,
            n_classes: self.n_classes,
            node_storage_bytes: sparse_tree_storage(
                self.n_nodes(),
                self.n_leaves(),
                self.n_classes,
            ),
        };
        CostReport { kind: ClassifierKind::Tree, ..rf_cost(&stats, eb, ab) }
    }
}

impl Classifier for FlatTree {
    fn kind(&self) -> ClassifierKind {
        ClassifierKind::Tree
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix {
        assert_eq!(x.len(), n * self.n_features, "batch shape mismatch");
        let f = self.n_features;
        let rows = par_map(n, |i| FlatTree::predict_proba(self, &x[i * f..(i + 1) * f]).to_vec());
        ProbMatrix::from_rows(rows, self.n_classes)
    }

    fn cost_report(
        &self,
        _probe: Option<&Split>,
        eb: &EnergyBlocks,
        ab: &AreaBlocks,
    ) -> CostReport {
        // A complete tree walks exactly `depth` levels on every input, so
        // the comparison count is exact without a probe. Storage charges
        // only live nodes (finite thresholds below the +inf sentinel).
        let live = self.thr.iter().filter(|v| v.is_finite() && **v < 1e37).count();
        let stats = RfStats {
            n_trees: 1,
            avg_comparisons: self.depth as f64,
            max_depth: self.depth.max(1),
            n_features: self.n_features,
            n_classes: self.n_classes,
            node_storage_bytes: sparse_tree_storage(live, live + 1, self.n_classes),
        };
        CostReport { kind: ClassifierKind::Tree, ..rf_cost(&stats, eb, ab) }
    }
}

// ---------------------------------------------------------------------------
// Random forest (both vote modes)
// ---------------------------------------------------------------------------

/// A trained forest behind the unified interface, with an explicit
/// aggregation mode — the §3.2.1 contrast is part of the model identity
/// (`"rf"` = majority vote, `"rf_prob"` = probability averaging).
///
/// The forest is packed into a shared [`ForestArena`] at construction;
/// both vote modes serve batches through the tiled level-synchronous
/// [`BatchPlan`](crate::exec::BatchPlan) kernel. The arena sits behind an `Arc` so cloning the
/// model — and in particular running it on every replica of a
/// [`ShardedServer`](crate::coordinator::ShardedServer) — shares the one
/// packed allocation instead of copying trees (same discipline as
/// [`FieldOfGroves`], whose groves all slice one arena). The sparse CART
/// trees are retained for training statistics (traversed-depth and
/// node-storage accounting, which charge real nodes rather than
/// complete-tree padding).
#[derive(Clone, Debug)]
pub struct RfModel {
    /// Read-only: the arena is packed from this forest at construction,
    /// so in-place mutation would silently desync the serving path.
    rf: RandomForest,
    pub mode: VoteMode,
    arena: Arc<ForestArena>,
    /// Kernel-lane quantization every prediction path runs under
    /// (`Exact` is answer-identical to f32 by the rank-code argument).
    quant: QuantMode,
    /// Adaptive confidence early-exit threshold, pre-filtered to the
    /// effective range (`None` = full evaluation; thresholds ≥ 1.0 are
    /// full evaluation by definition and filter out at the builder).
    adaptive: Option<f32>,
}

impl RfModel {
    pub fn new(rf: RandomForest, mode: VoteMode) -> RfModel {
        let arena = Arc::new(ForestArena::from_forest(&rf, rf.max_depth()));
        RfModel { rf, mode, arena, quant: QuantMode::Off, adaptive: None }
    }

    /// Run this model's batch paths (direct and backend-served) on
    /// quantized integer lanes.
    pub fn with_quant(mut self, mode: QuantMode) -> RfModel {
        self.quant = mode;
        self
    }

    /// Enable adaptive confidence early exit on this model's batch paths
    /// (Daghero et al., arXiv 2205.13838): a sample stops accumulating
    /// tree votes once its running margin reaches `t`. Thresholds
    /// outside `(0, 1)` (incl. `1.0` and non-finite) are filtered to
    /// `None` — full evaluation — so `t = 1.0` is byte-identical to the
    /// plain model by construction.
    pub fn with_adaptive(mut self, t: Option<f32>) -> RfModel {
        self.adaptive = t.filter(|v| v.is_finite() && *v < 1.0);
        self
    }

    /// The effective adaptive threshold (`None` = full evaluation).
    pub fn adaptive(&self) -> Option<f32> {
        self.adaptive
    }

    /// The active kernel-lane quantization mode.
    pub fn quant(&self) -> QuantMode {
        self.quant
    }

    /// The trained sparse forest (feeds the energy/storage accounting).
    pub fn forest(&self) -> &RandomForest {
        &self.rf
    }

    /// The shared packed SoA forest serving this model's batch path
    /// (clones of this model — replica handles — share it by pointer).
    pub fn arena(&self) -> &Arc<ForestArena> {
        &self.arena
    }

    /// Measured (or depth-bound) statistics feeding the RF energy model.
    pub fn stats(&self, probe: Option<&Split>) -> RfStats {
        measured_rf_stats(&self.rf, probe)
    }

    /// The arena reduction implementing this model's vote mode.
    fn reduce(&self) -> Reduce {
        match self.mode {
            VoteMode::ProbAverage => Reduce::ProbAverage,
            VoteMode::Majority => Reduce::MajorityVote,
        }
    }
}

/// Measured `RfStats` for a trained forest: comparisons measured on
/// `probe` when given, the depth-bound worst case otherwise.
pub fn measured_rf_stats(rf: &RandomForest, probe: Option<&Split>) -> RfStats {
    let avg_comparisons = match probe {
        Some(s) if !s.is_empty() => rf.avg_comparisons(s),
        _ => (rf.n_trees() * rf.max_depth().max(1)) as f64,
    };
    let nodes: usize = rf.trees.iter().map(|t| t.n_nodes()).sum();
    let leaves: usize = rf.trees.iter().map(|t| t.n_leaves()).sum();
    RfStats {
        n_trees: rf.n_trees(),
        avg_comparisons,
        max_depth: rf.max_depth().max(1),
        n_features: rf.n_features,
        n_classes: rf.n_classes,
        node_storage_bytes: sparse_tree_storage(nodes, leaves, rf.n_classes),
    }
}

impl Classifier for RfModel {
    fn kind(&self) -> ClassifierKind {
        ClassifierKind::RandomForest
    }

    fn name(&self) -> &'static str {
        match self.mode {
            VoteMode::Majority => "RF",
            VoteMode::ProbAverage => "RF_prob",
        }
    }

    fn n_features(&self) -> usize {
        self.rf.n_features
    }

    fn n_classes(&self) -> usize {
        self.rf.n_classes
    }

    fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix {
        assert_eq!(x.len(), n * self.rf.n_features, "batch shape mismatch");
        // ProbAverage rows equal `RandomForest::predict_proba` bit-for-bit
        // (same per-tree accumulation order); Majority rows are vote
        // fractions — a valid distribution whose argmax is the
        // majority-vote winner. `forest_tile_adaptive` is the single
        // kernel entry point shared with the execution backends, so
        // direct, software- and uarch-served answers are identical by
        // construction (under the model's one quant mode and adaptive
        // threshold).
        forest_tile_adaptive(&self.arena, self.reduce(), self.quant, self.adaptive, x, n).0
    }

    // `predict_batch` keeps the trait default (argmax of the probability
    // rows, first index wins ties) so batched, per-sample and served
    // labels are always identical. Majority-vote ties therefore resolve
    // to the *first* tied class, where `RandomForest::predict_with`
    // resolves to the last — observable only on exact vote ties.

    fn cost_report(
        &self,
        probe: Option<&Split>,
        eb: &EnergyBlocks,
        ab: &AreaBlocks,
    ) -> CostReport {
        rf_cost(&self.stats(probe), eb, ab)
    }

    fn exec_backend(&self, kind: BackendKind) -> Option<Arc<dyn Backend>> {
        let backend: Arc<dyn Backend> = match kind {
            BackendKind::Software => Arc::new(
                SoftwareBackend::forest(Arc::clone(&self.arena), self.reduce())
                    .with_quant(self.quant)
                    .with_adaptive(self.adaptive),
            ),
            BackendKind::Uarch => Arc::new(
                UarchBackend::forest(Arc::clone(&self.arena), self.reduce())
                    .with_quant(self.quant)
                    .with_adaptive(self.adaptive),
            ),
        };
        Some(backend)
    }

    fn quant_tables(&self) -> Option<Arc<QuantTables>> {
        self.quant.is_on().then(|| Arc::clone(self.arena.quant_tables()))
    }

    fn simd_level(&self) -> SimdLevel {
        // Resolve exactly the plan the prediction paths build, so the
        // reported label always matches the kernel that actually ran.
        BatchPlan::new(&self.arena, self.reduce())
            .with_quant(self.quant)
            .with_adaptive(self.adaptive)
            .simd_level()
    }

    fn gather_level(&self) -> SimdLevel {
        BatchPlan::new(&self.arena, self.reduce())
            .with_quant(self.quant)
            .with_adaptive(self.adaptive)
            .gather_level()
    }

    fn adaptive_conf(&self) -> Option<f32> {
        self.adaptive
    }
}

// ---------------------------------------------------------------------------
// Field of Groves
// ---------------------------------------------------------------------------

/// Measured `FogStats` for a FoG at a given operating point (shared by the
/// experiment harnesses and [`FogModel::cost_report`]).
pub fn measured_fog_stats(fog: &FieldOfGroves, avg_hops: f64, kind: ClassifierKind) -> FogStats {
    let per_grove = fog.groves[0].n_trees();
    // Storage sized to the *sparse* trained trees (the hardware stores
    // real nodes, not the complete-tree padding the kernels use).
    let storage = fog.groves[0].sparse_storage_bytes() as f64;
    FogStats {
        n_groves: fog.n_groves(),
        trees_per_grove: per_grove,
        depth: fog.depth,
        avg_hops,
        n_features: fog.n_features,
        n_classes: fog.n_classes,
        grove_storage_bytes: storage,
        kind,
    }
}

/// A Field of Groves at a fixed operating point (threshold + hop cap),
/// wrapping Algorithm 2's `evaluate` behind the unified interface and
/// surfacing the measured hop count as energy cost.
///
/// Start-grove selection hashes the *input content* (XOR-folded feature
/// bits) rather than the batch index, so per-sample and batched
/// predictions agree exactly — both are valid realizations of
/// Algorithm 2 line 3's "random starting grove".
#[derive(Clone, Debug)]
pub struct FogModel {
    pub fog: FieldOfGroves,
    pub params: FogParams,
    kind: ClassifierKind,
    /// Serving-tier adaptive confidence threshold, pre-filtered to
    /// `t < 1.0` (see [`FogModel::with_adaptive`]); `None` keeps the
    /// operating point's own threshold untouched.
    adaptive: Option<f32>,
}

impl FogModel {
    pub fn new(fog: FieldOfGroves, params: FogParams, kind: ClassifierKind) -> FogModel {
        let mut params = params;
        params.max_hops = params.max_hops.clamp(1, fog.n_groves());
        FogModel { fog, params, kind, adaptive: None }
    }

    /// The FoG_max configuration: threshold above 1 forces every grove to
    /// contribute, reproducing the underlying forest's probability average.
    pub fn fog_max(fog: FieldOfGroves, seed: u64) -> FogModel {
        let n = fog.n_groves();
        FogModel::new(fog, FogParams { seed, ..FogParams::fog_max(n) }, ClassifierKind::FogMax)
    }

    /// Serving-tier adaptive confidence knob (Daghero et al., arXiv
    /// 2205.13838). FoG's hop walk *is* already confidence-gated early
    /// exit, so here the knob composes by lowering the effective hop
    /// threshold to `min(params.threshold, t)` — looser serving
    /// confidence stops sooner; the model's own tighter threshold is
    /// never loosened. Thresholds ≥ 1.0 filter to `None`, leaving the
    /// operating point untouched (crucial for FoG_max, whose threshold
    /// sits just above 1), so `t = 1.0` is byte-identical to the plain
    /// model.
    pub fn with_adaptive(mut self, t: Option<f32>) -> FogModel {
        self.adaptive = t.filter(|v| v.is_finite() && *v < 1.0);
        self
    }

    /// The effective adaptive threshold (`None` = operating point as-is).
    pub fn adaptive(&self) -> Option<f32> {
        self.adaptive
    }

    /// The operating point every evaluation path runs: the model's own
    /// params, with the hop threshold capped by the serving-tier adaptive
    /// knob when one is set.
    fn effective_params(&self) -> FogParams {
        match self.adaptive {
            Some(t) => FogParams { threshold: self.params.threshold.min(t), ..self.params },
            None => self.params,
        }
    }

    /// Content-derived start grove (batch-position independent). Public
    /// so conformance tests can replay Algorithm 2 against independent
    /// per-tree `FlatTree` traversal. Delegates to the shared
    /// [`content_start_grove`] hash so the execution backends (software
    /// kernel and μarch ring) draw identical groves for identical rows.
    pub fn start_grove(&self, row: &[f32]) -> usize {
        content_start_grove(self.params.seed, row, self.fog.n_groves())
    }

    /// Algorithm 2 for one input at this operating point (adaptive knob
    /// applied when set).
    pub fn eval_row(&self, row: &[f32]) -> InputOutcome {
        let p = self.effective_params();
        let start = self.start_grove(row);
        self.fog.evaluate_one(row, start, p.threshold, p.max_hops)
    }

    /// Algorithm 2 over a row-major batch (parallel).
    pub fn eval_batch(&self, x: &[f32], n: usize) -> Vec<InputOutcome> {
        let f = self.fog.n_features;
        assert_eq!(x.len(), n * f, "batch shape mismatch");
        par_map(n, |i| self.eval_row(&x[i * f..(i + 1) * f]))
    }

    /// Mean groves consulted per input on `split` — the energy driver.
    pub fn avg_hops_on(&self, split: &Split) -> f64 {
        if split.is_empty() {
            return self.params.max_hops as f64;
        }
        let outcomes = self.eval_batch(&split.x, split.len());
        outcomes.iter().map(|o| o.hops as f64).sum::<f64>() / outcomes.len() as f64
    }
}

impl Classifier for FogModel {
    fn kind(&self) -> ClassifierKind {
        self.kind
    }

    fn n_features(&self) -> usize {
        self.fog.n_features
    }

    fn n_classes(&self) -> usize {
        self.fog.n_classes
    }

    fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix {
        // `fog_tile` is the single Algorithm-2 kernel entry point shared
        // with the execution backends (content-hashed start groves +
        // `evaluate_one`), so direct, software- and uarch-served answers
        // are identical by construction.
        fog_tile(&self.fog, &self.effective_params(), x, n).0
    }

    fn cost_report(
        &self,
        probe: Option<&Split>,
        eb: &EnergyBlocks,
        ab: &AreaBlocks,
    ) -> CostReport {
        let avg_hops = match probe {
            Some(s) if !s.is_empty() => self.avg_hops_on(s),
            // No probe: charge the hop cap (full circulation bound).
            _ => self.params.max_hops as f64,
        };
        fog_cost(&measured_fog_stats(&self.fog, avg_hops, self.kind), eb, ab)
    }

    fn exec_backend(&self, kind: BackendKind) -> Option<Arc<dyn Backend>> {
        let p = self.effective_params();
        let backend: Arc<dyn Backend> = match kind {
            BackendKind::Software => Arc::new(SoftwareBackend::fog(self.fog.clone(), p)),
            BackendKind::Uarch => Arc::new(UarchBackend::fog(self.fog.clone(), p)),
        };
        Some(backend)
    }

    // `quant_tables` keeps the trait default (`None`): the FoG path stays
    // f32 because `content_start_grove` hashes the raw f32 feature bits —
    // keying the cache on rank codes would collide rows that draw
    // different start groves.

    fn adaptive_conf(&self) -> Option<f32> {
        self.adaptive
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::ForestParams;

    fn setup() -> (RandomForest, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 271);
        let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 3);
        (rf, ds)
    }

    #[test]
    fn rf_model_matches_forest_accuracy() {
        let (rf, ds) = setup();
        // ProbAverage shares the exact argmax path → bit-identical.
        let model = RfModel::new(rf.clone(), VoteMode::ProbAverage);
        let direct = rf.accuracy(&ds.test, VoteMode::ProbAverage);
        assert!((Classifier::accuracy(&model, &ds.test) - direct).abs() < 1e-12);
        // Majority differs from `predict_with` only on exact vote ties
        // (first- vs last-max tie-break), so accuracies stay within the
        // tie mass.
        let model = RfModel::new(rf.clone(), VoteMode::Majority);
        let direct = rf.accuracy(&ds.test, VoteMode::Majority);
        assert!(
            (Classifier::accuracy(&model, &ds.test) - direct).abs() < 0.05,
            "majority-vote accuracy drifted beyond tie mass"
        );
    }

    #[test]
    fn rf_model_clones_share_one_arena() {
        // Replica handles must share the packed forest, not copy it.
        let (rf, _) = setup();
        let model = RfModel::new(rf, VoteMode::ProbAverage);
        let replica = model.clone();
        assert!(Arc::ptr_eq(model.arena(), replica.arena()), "clone copied the arena");
    }

    #[test]
    fn quantized_rf_model_matches_plain_bitwise() {
        // Exact lanes through the full model path (direct batch +
        // quant_tables plumbing): answers equal the f32 model's
        // byte-for-byte, and only quantized models expose tables.
        let (rf, ds) = setup();
        for mode in [VoteMode::ProbAverage, VoteMode::Majority] {
            let plain = RfModel::new(rf.clone(), mode);
            let q = RfModel::new(rf.clone(), mode).with_quant(QuantMode::Exact);
            let a = plain.predict_proba_batch(&ds.test.x, ds.test.len());
            let b = q.predict_proba_batch(&ds.test.x, ds.test.len());
            assert_eq!(a, b, "{mode:?}");
            assert!(plain.quant_tables().is_none());
            let tables = q.quant_tables().expect("quantized model exposes tables");
            assert!(Arc::ptr_eq(&tables, q.arena().quant_tables()), "tables not shared");
        }
    }

    #[test]
    fn adaptive_rf_model_full_threshold_is_plain() {
        // t = 1.0 (and anything out of range) filters to None: same
        // bytes, no adaptive_conf advertised, so the serving tier shares
        // cache rows with the no-flag model.
        let (rf, ds) = setup();
        let plain = RfModel::new(rf.clone(), VoteMode::ProbAverage);
        let one = RfModel::new(rf.clone(), VoteMode::ProbAverage).with_adaptive(Some(1.0));
        assert_eq!(one.adaptive(), None);
        assert_eq!(one.adaptive_conf(), None);
        assert_eq!(
            plain.predict_proba_batch(&ds.test.x, ds.test.len()),
            one.predict_proba_batch(&ds.test.x, ds.test.len()),
        );
        let active = RfModel::new(rf, VoteMode::ProbAverage).with_adaptive(Some(0.6));
        assert_eq!(active.adaptive_conf(), Some(0.6));
    }

    #[test]
    fn adaptive_fog_model_caps_threshold_without_loosening() {
        let (rf, ds) = setup();
        let fog = FieldOfGroves::from_forest(&rf, 4);
        // fog_max's threshold sits above 1.0: t = 1.0 must leave it
        // untouched (byte-identity), while t < 1.0 caps it.
        let fmax = FogModel::fog_max(fog.clone(), 0);
        let fmax_one = FogModel::fog_max(fog.clone(), 0).with_adaptive(Some(1.0));
        assert_eq!(
            fmax.predict_proba_batch(&ds.test.x, ds.test.len()),
            fmax_one.predict_proba_batch(&ds.test.x, ds.test.len()),
        );
        let capped = FogModel::fog_max(fog.clone(), 0).with_adaptive(Some(0.3));
        assert_eq!(capped.effective_params().threshold, 0.3);
        // A model already tighter than the serving knob stays tighter.
        let tight = FogModel::new(
            fog,
            FogParams { threshold: 0.1, max_hops: 4, seed: 9 },
            ClassifierKind::FogOpt,
        )
        .with_adaptive(Some(0.5));
        assert_eq!(tight.effective_params().threshold, 0.1);
    }

    #[test]
    fn adaptive_fog_model_saves_hops() {
        let (rf, ds) = setup();
        let fog = FieldOfGroves::from_forest(&rf, 4);
        let full = FogModel::fog_max(fog.clone(), 2);
        let adaptive = FogModel::fog_max(fog, 2).with_adaptive(Some(0.4));
        let h_full = full.avg_hops_on(&ds.test);
        let h_adapt = adaptive.avg_hops_on(&ds.test);
        assert!(h_adapt <= h_full, "adaptive hops {h_adapt} vs full {h_full}");
        assert!(h_adapt < full.fog.n_groves() as f64, "no sample exited early");
    }

    #[test]
    fn tree_batch_matches_per_sample() {
        let (rf, ds) = setup();
        let tree = &rf.trees[0];
        let batch = Classifier::predict_batch(tree, &ds.test.x, ds.test.len());
        for i in 0..ds.test.len() {
            assert_eq!(batch[i], DecisionTree::predict(tree, ds.test.row(i)));
        }
    }

    #[test]
    fn fog_model_batch_position_independent() {
        let (rf, ds) = setup();
        let fog = FieldOfGroves::from_forest(&rf, 4);
        let model = FogModel::new(
            fog,
            FogParams { threshold: 0.3, max_hops: 4, seed: 9 },
            ClassifierKind::FogOpt,
        );
        let batch = model.predict_batch(&ds.test.x, ds.test.len());
        for i in (0..ds.test.len()).step_by(7) {
            assert_eq!(batch[i], Classifier::predict(&model, ds.test.row(i)), "row {i}");
        }
    }

    #[test]
    fn fog_max_model_matches_rf_prob_average() {
        let (rf, ds) = setup();
        let fog = FieldOfGroves::from_forest(&rf, 4);
        let model = FogModel::fog_max(fog, 0);
        let a = Classifier::accuracy(&model, &ds.test);
        let b = rf.accuracy(&ds.test, VoteMode::ProbAverage);
        assert!((a - b).abs() < 1e-9, "fog_max {a} vs rf {b}");
    }

    #[test]
    fn fog_cost_scales_with_threshold() {
        let (rf, ds) = setup();
        let eb = EnergyBlocks::default();
        let ab = AreaBlocks::default();
        let fog = FieldOfGroves::from_forest(&rf, 4);
        let cheap = FogModel::new(
            fog.clone(),
            FogParams { threshold: 0.05, max_hops: 4, seed: 1 },
            ClassifierKind::FogOpt,
        );
        let full = FogModel::fog_max(fog, 1);
        let e_cheap = cheap.cost_report(Some(&ds.test), &eb, &ab).energy_nj;
        let e_full = full.cost_report(Some(&ds.test), &eb, &ab).energy_nj;
        assert!(e_cheap < e_full, "cheap {e_cheap} full {e_full}");
    }
}
