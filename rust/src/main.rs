//! `fog` — command-line launcher for the Field-of-Groves reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts,
//! plus registry-driven model evaluation and serving over the unified
//! `fog::api` layer:
//!
//! ```text
//! fog table1   [--datasets a,b,c] [--seed N]      Table 1 + headline
//! fog fig4     [--datasets a,b,c] [--seed N]      Figure 4 topology sweep
//! fog fig5     [--topology 8x2] [--datasets ...]  Figure 5 threshold sweep
//! fog headline [--seed N]                          just the §1 ratios
//! fog ablate   [--dataset penbase]                 design-choice ablations
//! fog eval     [--models all|rf,mlp] [--dataset d] any registry model: accuracy + PPA
//!              [--backend software|uarch]          uarch: add hardware-in-the-loop
//!                                                  sim columns (nJ + cycles / class)
//!              [--quant off|u8|u16|lossy8|lossy16] Fig-5-style quantization axis:
//!                                                  run forest-backed rows on the
//!                                                  chosen kernel lanes (dense
//!                                                  baselines ignore the flag)
//!              [--adaptive-sweep] [--model rf_prob] live accuracy-vs-effort sweep of
//!                                                  the adaptive early-exit threshold
//!                                                  (Fig-5 style at the serving tier;
//!                                                  emits eval_adaptive BENCH_JSON)
//! fog sim      [--dataset penbase] [--threshold T] cycle-level μarch sim
//! fog serve    [--dataset demo] [--backend native|pjrt]
//!              [--model <registry name>]           serving demo (FoG ring, or any
//!                                                  registry model via ModelServer)
//!              [--replicas N] [--router random|round_robin|least_loaded]
//!              [--backend software|uarch]          execution backend behind every
//!                                                  replica (uarch = grove-ring
//!                                                  simulator in the loop: live
//!                                                  energy-per-classification)
//!              [--quant off|u8|u16|lossy8|lossy16] kernel-lane quantization for
//!                                                  forest models (u8/u16 = exact
//!                                                  rank codes, answer-identical
//!                                                  to off; lossyN = affine N-bit)
//!              [--adaptive-conf t]                 adaptive confidence early exit,
//!                                                  t in (0, 1]: a sample stops
//!                                                  accumulating tree votes once its
//!                                                  running margin reaches t (1.0 =
//!                                                  full evaluation, byte-identical
//!                                                  to omitting the flag; savings
//!                                                  surface as trees_skipped_per_class)
//!              [--cache-quant q] [--cache-cap N] [--no-cache] [--rounds R]
//!                                                  sharded tier: N replicas of the
//!                                                  model behind a shared router and
//!                                                  a quantized result cache; emits
//!                                                  BENCH_JSON lines (aggregate +
//!                                                  per-replica throughput, cache
//!                                                  hit rate, energy/cycles per
//!                                                  classification, batch p50/p99)
//!              [--fleet fog_opt,fog_max]           fleet tier: several registry
//!                                                  models behind one request path
//!                                                  sharing --replicas capacity
//!              [--energy-budget-nj N] [--p99-budget-us U] [--budget-window T]
//!                                                  live Fig-5 admission budget per
//!                                                  model (rolling energy/p99 gauges)
//!              [--fleet-policy strict|downgrade]   over-budget traffic: shed, or
//!                                                  fall back in registration order
//!              [--loadgen QPS:SECS] [--loadgen-seed S]
//!                                                  seeded open-loop arrival ramp
//!                                                  (QPS/5 -> QPS over SECS); emits
//!                                                  serve_fleet BENCH_JSON lines
//!                                                  (shed rate, per-model p50/p99 +
//!                                                  energy_per_class_nj)
//! fog dse      [--workload trees|gemm]             Aladdin-style DSE sweep
//! ```

use fog::api::{BackendKind, Classifier, Estimator, FleetPolicyKind, ModelSpec, REGISTRY};
use fog::coordinator::{
    loadgen, Backend, CacheConfig, EnergyBudget, Fleet, FleetConfig, FogServer,
    LoadgenConfig, ModelServer, ModelServerConfig, RouterPolicy, ServerConfig,
    ShardedServer, ShardedServerConfig,
};
use fog::data::synthetic::DatasetProfile;
use fog::energy::aladdin;
use fog::energy::blocks::{AreaBlocks, EnergyBlocks};
use fog::exec::{ExecReport, QuantMode};
use fog::experiments::{fig4, fig5, suite, table1};
use fog::fog::FieldOfGroves;
use fog::uarch::{RingConfig, RingSim};
use fog::util::cli::Args;
use std::sync::Arc;

/// Valid `--dataset` names, for error messages.
fn dataset_names() -> String {
    let mut names: Vec<&str> = DatasetProfile::paper_suite().iter().map(|p| p.name).collect();
    names.push("demo");
    names.join(", ")
}

/// Resolve one dataset name or exit with a friendly error listing the
/// valid `DatasetProfile` names.
fn profile_or_exit(name: &str) -> DatasetProfile {
    DatasetProfile::by_name(name).unwrap_or_else(|| {
        eprintln!("error: unknown dataset '{name}'; valid names: {}", dataset_names());
        std::process::exit(2);
    })
}

fn profiles_from(args: &Args) -> Vec<DatasetProfile> {
    match args.get("datasets") {
        None => DatasetProfile::paper_suite(),
        Some(spec) => spec.split(',').map(|name| profile_or_exit(name.trim())).collect(),
    }
}

fn main() {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 42);
    match args.subcommand() {
        Some("table1") => {
            let results = table1::run(&profiles_from(&args), seed);
            table1::print_table(&results);
            table1::print_headline(&results);
        }
        Some("headline") => {
            let results = table1::run(&profiles_from(&args), seed);
            table1::print_headline(&results);
        }
        Some("fig4") => {
            let all = fig4::run(&profiles_from(&args), seed);
            fig4::print_series(&all);
        }
        Some("fig5") => {
            let topo = args.get_topology("topology", (8, 2));
            let all = fig5::run(&profiles_from(&args), topo, seed);
            fig5::print_series(topo, &all);
        }
        Some("ablate") => {
            let profile = profile_or_exit(args.get_or("dataset", "penbase"));
            eprintln!("[ablate] training {} ...", profile.name);
            let s = suite::train_suite(&profile, seed);
            fog::experiments::ablations::print_all(&s, seed);
        }
        Some("eval") => cmd_eval(&args, seed),
        Some("sim") => cmd_sim(&args, seed),
        Some("serve") => cmd_serve(&args, seed),
        Some("dse") => cmd_dse(&args),
        _ => {
            eprintln!(
                "usage: fog <table1|fig4|fig5|headline|ablate|eval|sim|serve|dse> [--flags]\n\
                 see `rust/src/main.rs` docs for the flag list"
            );
            std::process::exit(2);
        }
    }
}

/// Train registry models by name and report accuracy + PPA through the
/// unified `Classifier` interface — one uniform loop, no per-model-type
/// dispatch.
fn cmd_eval(args: &Args, seed: u64) {
    if args.get_bool("adaptive-sweep") {
        return cmd_eval_adaptive_sweep(args, seed);
    }
    let profile = profile_or_exit(args.get_or("dataset", "demo"));
    let spec_names: Vec<String> = match args.get_or("models", "all") {
        "all" => REGISTRY.iter().map(|s| s.to_string()).collect(),
        list => list.split(',').map(|s| s.trim().to_string()).collect(),
    };
    let quant = parse_quant_or_exit(args);
    let specs: Vec<ModelSpec> = spec_names
        .iter()
        .map(|name| {
            ModelSpec::for_shape(name, profile.n_features, profile.n_classes)
                .unwrap_or_else(|| {
                    eprintln!(
                        "error: unknown model '{name}'; valid names: {}",
                        REGISTRY.join(", ")
                    );
                    std::process::exit(2);
                })
                .with_quant(quant)
        })
        .collect();

    let backend = parse_exec_backend(args);
    eprintln!("[eval] generating {} ...", profile.name);
    let data = suite::prepare_data(&profile, seed);
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    println!(
        "== registry eval on '{}' (seed {seed}, quant {}) ==",
        profile.name,
        quant.label()
    );
    print!(
        "{:<10}{:>11}{:>15}{:>13}{:>11}{:>12}{:>9}{:>9}",
        "model", "accuracy%", "energy nJ", "latency ns", "area mm2", "train s", "simd", "gather"
    );
    if backend == BackendKind::Uarch {
        print!("{:>14}{:>14}", "sim nJ/cls", "sim cyc/cls");
    }
    println!();
    for spec in &specs {
        let t0 = std::time::Instant::now();
        let model = spec.fit(&data.train, seed);
        let train_s = t0.elapsed().as_secs_f64();
        let report = model.cost_report(Some(&data.test), &eb, &ab);
        print!(
            "{:<10}{:>11.1}{:>15.2}{:>13.1}{:>11.2}{:>12.2}{:>9}{:>9}",
            spec.name,
            model.accuracy(&data.test) * 100.0,
            report.energy_nj,
            report.latency_ns,
            report.area_mm2,
            train_s,
            model.simd_level().label(),
            model.gather_level().label()
        );
        if backend == BackendKind::Uarch {
            // Hardware in the loop: stream the test split tile-by-tile
            // through the μarch backend and report measured (simulated)
            // per-classification energy and cycles next to the
            // analytical model's numbers.
            match eval_through_backend(model.as_ref(), &data.test, BackendKind::Uarch) {
                Some(total) => print!(
                    "{:>14.3}{:>14.1}",
                    total.energy_per_class_nj(),
                    total.cycles_per_class()
                ),
                None => print!("{:>14}{:>14}", "-", "-"),
            }
        }
        println!();
    }
}

/// `fog eval --adaptive-sweep`: live accuracy-vs-effort trade-off curve
/// for the adaptive confidence early-exit path. Fits one forest-backed
/// model per threshold (same seed → same forest every row, so only the
/// exit policy varies), streams the test split through the chosen
/// execution backend, and reports accuracy next to the trees skipped per
/// classification. The `t=1.00` row is the full-evaluation anchor: its
/// accuracy and accounting must match a run without the flag.
fn cmd_eval_adaptive_sweep(args: &Args, seed: u64) {
    let profile = profile_or_exit(args.get_or("dataset", "demo"));
    let name = args.get_or("model", "rf_prob");
    let quant = parse_quant_or_exit(args);
    let kind = parse_exec_backend(args);
    let spec = ModelSpec::for_shape(name, profile.n_features, profile.n_classes)
        .unwrap_or_else(|| {
            eprintln!(
                "error: unknown model '{name}'; valid names: {}",
                REGISTRY.join(", ")
            );
            std::process::exit(2);
        })
        .with_quant(quant);
    eprintln!("[eval] generating {} ...", profile.name);
    let data = suite::prepare_data(&profile, seed);
    println!(
        "== adaptive early-exit sweep: {} on '{}' (backend {}, quant {}, seed {seed}) ==",
        name, profile.name, kind.label(), quant.label()
    );
    println!(
        "{:<8}{:>11}{:>16}{:>16}{:>14}",
        "t", "accuracy%", "trees skip/cls", "cmp ops/cls", "lvl skip/cls"
    );
    for t in [0.2f32, 0.4, 0.6, 0.8, 1.0] {
        let model = spec.clone().with_adaptive(t).fit(&data.train, seed);
        let acc = model.accuracy(&data.test);
        let report = eval_through_backend(model.as_ref(), &data.test, kind)
            .unwrap_or_else(|| {
                eprintln!("error: model '{name}' has no arena execution backend");
                std::process::exit(2);
            });
        println!(
            "{:<8.2}{:>11.1}{:>16.2}{:>16.1}{:>14.2}",
            t,
            acc * 100.0,
            report.trees_skipped_per_class(),
            report.comparator_ops_per_class(),
            report.levels_skipped_per_class()
        );
        println!(
            "BENCH_JSON {{\"bench\":\"eval_adaptive\",\"dataset\":\"{}\",\"model\":\"{}\",\
             \"backend\":\"{}\",\"quant\":\"{}\",\"adaptive_conf\":{:.4},\"accuracy\":{:.4},\
             \"trees_skipped_per_class\":{:.2},\"comparator_ops_per_class\":{:.1},\
             \"levels_skipped_per_class\":{:.2}}}",
            profile.name,
            name,
            kind.label(),
            quant.label(),
            t,
            acc,
            report.trees_skipped_per_class(),
            report.comparator_ops_per_class(),
            report.levels_skipped_per_class()
        );
    }
}

/// Parse `--backend software|uarch` (execution backend; distinct from
/// the FoG ring's `native|pjrt` serving backends) or exit with a
/// friendly error listing the valid spellings.
fn parse_exec_backend(args: &Args) -> BackendKind {
    let spelled = args.get_or("backend", "software");
    BackendKind::parse(spelled).unwrap_or_else(|| {
        eprintln!(
            "error: unknown execution backend '{spelled}'; valid names: {}",
            BackendKind::NAMES.join(", ")
        );
        std::process::exit(2);
    })
}

/// Parse `--quant off|u8|u16|lossy8|lossy16` (kernel-lane quantization
/// for forest-backed models) or exit with a friendly error listing the
/// valid spellings.
fn parse_quant_or_exit(args: &Args) -> QuantMode {
    let spelled = args.get_or("quant", "off");
    QuantMode::parse(spelled).unwrap_or_else(|| {
        eprintln!(
            "error: unknown quant mode '{spelled}'; valid names: {}",
            QuantMode::NAMES.join(", ")
        );
        std::process::exit(2);
    })
}

/// Parse `--adaptive-conf t` (adaptive confidence early-exit threshold)
/// or exit with a friendly error when the value is not a number in
/// `(0, 1]`. `None` when the flag is absent; `1.0` is accepted and means
/// full evaluation (byte-identical to omitting the flag — the models
/// filter it out downstream).
fn parse_adaptive_or_exit(args: &Args) -> Option<f32> {
    let spelled = args.get("adaptive-conf")?;
    let t = spelled.parse::<f32>().unwrap_or(f32::NAN);
    if !(t > 0.0 && t <= 1.0) {
        eprintln!(
            "error: --adaptive-conf must be a confidence threshold in (0, 1], got \
             '{spelled}' (1.0 = full evaluation; lower = earlier exit)"
        );
        std::process::exit(2);
    }
    Some(t)
}

/// FNV-1a over probability rows' f32 bit patterns in response order — a
/// cheap conformance fingerprint so CI can assert `--quant u8` answers
/// equal `--quant off` byte-for-byte.
fn prob_checksum(responses: &[fog::coordinator::Response]) -> u64 {
    let mut hash = 0xCBF29CE484222325u64;
    for r in responses {
        for &p in &r.prob {
            hash = (hash ^ p.to_bits() as u64).wrapping_mul(0x100000001B3);
        }
    }
    hash
}

/// Parse `--router` or exit with a friendly error listing the valid
/// policies.
fn parse_router_or_exit(args: &Args) -> RouterPolicy {
    let spelled = args.get_or("router", "least_loaded");
    RouterPolicy::parse(spelled).unwrap_or_else(|| {
        eprintln!(
            "error: unknown router '{spelled}'; valid policies: {}",
            RouterPolicy::NAMES.join(", ")
        );
        std::process::exit(2);
    })
}

/// Parse `--fleet-policy` or exit with a friendly error listing the
/// valid policies.
fn parse_fleet_policy_or_exit(args: &Args) -> FleetPolicyKind {
    let spelled = args.get_or("fleet-policy", "downgrade");
    FleetPolicyKind::parse(spelled).unwrap_or_else(|| {
        eprintln!(
            "error: unknown fleet policy '{spelled}'; valid policies: {}",
            FleetPolicyKind::NAMES.join(", ")
        );
        std::process::exit(2);
    })
}

/// Stream a labelled split through the model's μarch execution backend
/// in serving-sized tiles, merging the per-tile reports. `None` when the
/// model family has no arena engine (dense baselines).
fn eval_through_backend(
    model: &dyn Classifier,
    split: &fog::data::Split,
    kind: BackendKind,
) -> Option<ExecReport> {
    let backend = model.exec_backend(kind)?;
    let f = model.n_features();
    let n = split.len();
    let tile = 64;
    let mut total = ExecReport::default();
    let mut i = 0;
    while i < n {
        let j = (i + tile).min(n);
        let (_, report) = backend.evaluate_tile(&split.x[i * f..j * f], j - i);
        total.merge(&report);
        i = j;
    }
    Some(total)
}

/// Cycle-level μarch simulation of the grove ring on one dataset.
fn cmd_sim(args: &Args, seed: u64) {
    let profile = profile_or_exit(args.get_or("dataset", "penbase"));
    let name = profile.name;
    let threshold = args.get_f64("threshold", 0.3) as f32;
    let (groves, per_grove) = args.get_topology("topology", (8, 2));
    eprintln!("[sim] training {} ...", profile.name);
    let s = suite::train_suite(&profile, seed);
    assert_eq!(groves * per_grove, s.rf.n_trees(), "topology must factor the forest");
    let fog = FieldOfGroves::from_forest_shuffled(&s.rf, per_grove, Some(seed));
    let cfg = RingConfig {
        threshold,
        seed,
        inject_interval: args.get_u64("inject-interval", 8),
        ..Default::default()
    };
    let mut sim = RingSim::new(&fog, cfg);
    sim.load_batch(&s.data.test.x);
    let outcomes = sim.run();
    let preds: Vec<usize> = outcomes.iter().map(|o| o.label).collect();
    let acc = fog::util::stats::accuracy(&preds, &s.data.test.y);
    let eb = EnergyBlocks::default();
    println!("== μarch ring simulation: {} @ {}x{} thr={} ==", name, groves, per_grove, threshold);
    println!("inputs               : {}", sim.stats.classified);
    println!("accuracy             : {:.1}%", acc * 100.0);
    println!("cycles               : {}", sim.stats.cycles);
    println!("avg hops             : {:.2}", sim.stats.avg_hops());
    println!("avg latency (cycles) : {:.1}", sim.stats.avg_latency_cycles());
    println!("throughput           : {:.2} class/kcycle", sim.stats.throughput_per_kcycle());
    println!("PE utilization       : {:.1}%", sim.stats.avg_utilization() * 100.0);
    println!("handshakes           : {}", sim.stats.handshakes);
    println!("stall cycles         : {}", sim.stats.stall_cycles);
    println!("dynamic energy/input : {:.3} nJ", sim.stats.dynamic_energy_per_input_nj(&eb));
}

/// Serving demo. Default: the FoG grove ring (native or PJRT backend).
/// With `--model <registry name>`: any unified-API model behind the
/// generic `ModelServer`; add `--replicas N` for the sharded tier
/// (`ShardedServer`: replica router + quantized result cache).
fn cmd_serve(args: &Args, seed: u64) {
    // The fleet tier sits above the sharded one: --fleet takes a model
    // *list* and owns the whole serve invocation.
    if let Some(fleet_spec) = args.get("fleet") {
        return cmd_serve_fleet(args, fleet_spec, seed);
    }
    // Fleet-only knobs without --fleet would otherwise be silently
    // ignored by the lower tiers.
    let fleet_flags =
        ["fleet-policy", "energy-budget-nj", "p99-budget-us", "budget-window", "loadgen", "loadgen-seed"];
    if let Some(flag) = fleet_flags.iter().find(|k| args.get(k).is_some()) {
        eprintln!(
            "error: --{flag} needs --fleet <model,model,...> (the fleet tier registers \
             registry models; valid names: {})",
            REGISTRY.join(", ")
        );
        std::process::exit(2);
    }
    // Any sharded-tier flag selects the sharded path, so no knob is ever
    // silently ignored by the single-queue server or the FoG ring.
    let sharded_flags = [
        "replicas",
        "router",
        "quant",
        "adaptive-conf",
        "cache-quant",
        "cache-cap",
        "no-cache",
        "rounds",
    ];
    let wants_sharded = sharded_flags.iter().any(|k| args.get(k).is_some());
    if let Some(model_name) = args.get("model") {
        // With --model, --backend selects the *execution* backend
        // (software | uarch) and serves through the sharded tier so the
        // per-replica ExecReport aggregates reach BENCH_JSON. (Without
        // --model, --backend keeps its FoG-ring meaning: native | pjrt.)
        if wants_sharded || args.get("backend").is_some() {
            return cmd_serve_sharded(args, model_name, seed);
        }
        return cmd_serve_model(args, model_name, seed);
    }
    if wants_sharded {
        eprintln!(
            "error: --replicas/--router/--quant/--adaptive-conf/--cache-quant/--cache-cap/\
             --no-cache/--rounds need --model <registry name> (the sharded tier serves \
             registry models; valid names: {})",
            REGISTRY.join(", ")
        );
        std::process::exit(2);
    }
    let profile = profile_or_exit(args.get_or("dataset", "demo"));
    let name = profile.name;
    eprintln!("[serve] training {} ...", profile.name);
    let s = suite::train_suite(&profile, seed);
    let per_grove = args.get_topology("topology", (4, 4)).1;
    let mut fog = FieldOfGroves::from_forest_shuffled(&s.rf, per_grove, Some(seed));
    let backend = match args.get_or("backend", "native") {
        "pjrt" => {
            // Artifact shapes are padded to fixed depths; repad to match
            // (rebuilds the shared arena at the deeper padding).
            let depth = args.get_usize("artifact-depth", 6);
            fog = fog.repad(depth);
            Backend::Pjrt { artifacts_dir: fog::runtime::artifacts::default_dir() }
        }
        "native" => Backend::Native,
        other => {
            eprintln!(
                "error: unknown FoG-ring backend '{other}'; valid names: native, pjrt \
                 (the software|uarch execution backends need --model <registry name>)"
            );
            std::process::exit(2);
        }
    };
    let cfg = ServerConfig {
        threshold: args.get_f64("threshold", 0.3) as f32,
        seed,
        backend,
        ..Default::default()
    };
    let mut server = FogServer::start(&fog, &cfg).expect("server start");
    let t0 = std::time::Instant::now();
    let responses = server.classify(&s.data.test.x);
    let wall = t0.elapsed();
    let preds: Vec<usize> = responses.iter().map(|r| r.label).collect();
    let acc = fog::util::stats::accuracy(&preds, &s.data.test.y);
    let lat = FogServer::latency_summary(&responses);
    let snap = server.metrics().snapshot();
    println!("== serving: {} x{} groves, backend={} ==", name, fog.n_groves(), args.get_or("backend", "native"));
    // Host ISA the quantized kernels would dispatch to (the FoG ring's
    // per-sample grove walk itself is scalar by design).
    println!(
        "host simd  : {} (gather {})",
        fog::exec::SimdLevel::detect().label(),
        fog::exec::GatherMode::detect().label()
    );
    println!("requests   : {}", snap.requests);
    println!("accuracy   : {:.1}%", acc * 100.0);
    println!("avg hops   : {:.2}", snap.avg_hops());
    println!("batch size : {:.1} avg", snap.avg_batch_size());
    println!("throughput : {:.0} req/s", responses.len() as f64 / wall.as_secs_f64());
    println!("latency    : p50 {:.0}µs  p95 {:.0}µs  p99 {:.0}µs", lat.p50_us, lat.p95_us, lat.p99_us);
    server.shutdown();
}

/// Serve any registry model through the generic `ModelServer`.
fn cmd_serve_model(args: &Args, model_name: &str, seed: u64) {
    let profile = profile_or_exit(args.get_or("dataset", "demo"));
    let spec = ModelSpec::for_shape(model_name, profile.n_features, profile.n_classes)
        .unwrap_or_else(|| {
            eprintln!(
                "error: unknown model '{model_name}'; valid names: {}",
                REGISTRY.join(", ")
            );
            std::process::exit(2);
        });
    eprintln!("[serve] training {model_name} on {} ...", profile.name);
    let data = suite::prepare_data(&profile, seed);
    let model: Arc<dyn Classifier> = Arc::from(spec.fit(&data.train, seed));
    let cfg = ModelServerConfig {
        batch_size: args.get_usize("batch", 32),
        n_workers: args.get_usize("workers", 2),
        ..Default::default()
    };
    let mut server = ModelServer::start(Arc::clone(&model), &cfg);
    let t0 = std::time::Instant::now();
    let responses = server.classify(&data.test.x).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let wall = t0.elapsed();
    let preds: Vec<usize> = responses.iter().map(|r| r.label).collect();
    let acc = fog::util::stats::accuracy(&preds, &data.test.y);
    let snap = server.metrics().snapshot();
    let lat = FogServer::latency_summary(&responses);
    println!("== serving: {model_name} on {} via ModelServer ==", profile.name);
    println!(
        "simd       : {} (gather {})",
        model.simd_level().label(),
        model.gather_level().label()
    );
    println!("requests   : {}", snap.requests);
    println!("accuracy   : {:.1}%", acc * 100.0);
    println!("batch size : {:.1} avg", snap.avg_batch_size());
    println!("throughput : {:.0} req/s", responses.len() as f64 / wall.as_secs_f64());
    println!("latency    : p50 {:.0}µs  p95 {:.0}µs  p99 {:.0}µs", lat.p50_us, lat.p95_us, lat.p99_us);
    server.shutdown();
}

/// Serve a registry model through the sharded multi-replica tier:
/// `--replicas N` replicas behind `--router` (default least_loaded), an
/// execution backend (`--backend software|uarch`; uarch streams every
/// replica batch through the grove-ring simulator for live
/// energy-per-classification) and a quantized result cache
/// (`--cache-quant`, default 0 = exact keys; `--no-cache` disables).
/// Runs `--rounds` passes over the test split (default 2, so the second
/// pass exercises the cache) and emits one aggregate and one per-replica
/// `BENCH_JSON` line.
fn cmd_serve_sharded(args: &Args, model_name: &str, seed: u64) {
    let profile = profile_or_exit(args.get_or("dataset", "demo"));
    let router = parse_router_or_exit(args);
    let backend = parse_exec_backend(args);
    let quant = parse_quant_or_exit(args);
    let mut spec = ModelSpec::for_shape(model_name, profile.n_features, profile.n_classes)
        .unwrap_or_else(|| {
            eprintln!(
                "error: unknown model '{model_name}'; valid names: {}",
                REGISTRY.join(", ")
            );
            std::process::exit(2);
        })
        .with_replicas(args.get_usize("replicas", 2))
        .with_router(router)
        .with_backend(backend)
        .with_quant(quant)
        .with_cache_capacity(args.get_usize("cache-cap", 4096));
    if let Some(t) = parse_adaptive_or_exit(args) {
        spec = spec.with_adaptive(t);
    }
    if !args.get_bool("no-cache") {
        spec = spec.with_cache_quant(args.get_f64("cache-quant", 0.0) as f32);
    }

    eprintln!("[serve] training {model_name} on {} ...", profile.name);
    let data = suite::prepare_data(&profile, seed);
    let model: Arc<dyn Classifier> = Arc::from(spec.fit(&data.train, seed));
    if backend == BackendKind::Uarch && model.exec_backend(BackendKind::Uarch).is_none() {
        eprintln!(
            "error: model '{model_name}' has no μarch execution backend; \
             tree-based registry models only (fog_opt, fog_max, rf, rf_prob)"
        );
        std::process::exit(2);
    }
    let mut cfg = ShardedServerConfig::for_serving(&spec.serving);
    cfg.worker = ModelServerConfig {
        batch_size: args.get_usize("batch", 32),
        n_workers: args.get_usize("workers", 2),
        backend,
        ..Default::default()
    };
    cfg.router_seed = seed;

    let mut server = ShardedServer::start(Arc::clone(&model), &cfg);
    let rounds = args.get_usize("rounds", 2).max(1);
    let t0 = std::time::Instant::now();
    let mut responses = Vec::new();
    for _ in 0..rounds {
        responses = server.classify(&data.test.x).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        });
    }
    let wall = t0.elapsed().as_secs_f64();
    let preds: Vec<usize> = responses.iter().map(|r| r.label).collect();
    let acc = fog::util::stats::accuracy(&preds, &data.test.y);
    let snap = server.snapshot();
    let n_total = responses.len() * rounds;

    println!(
        "== serving: {model_name} on {} via ShardedServer x{} ({}, backend={}, quant={}, \
         simd={}, gather={}) ==",
        profile.name,
        server.n_replicas(),
        cfg.router.label(),
        backend.label(),
        quant.label(),
        snap.simd_label(),
        snap.gather_label()
    );
    println!("requests   : {} ({} per round x {rounds})", snap.requests, responses.len());
    println!("accuracy   : {:.1}%", acc * 100.0);
    println!("batch size : {:.1} avg", snap.avg_batch_size());
    println!(
        "cache      : {:.1}% hit rate ({} hits / {} misses)",
        snap.cache_hit_rate() * 100.0,
        snap.cache_hits,
        snap.cache_misses
    );
    println!("throughput : {:.0} req/s", n_total as f64 / wall);
    if let Some(t) = spec.serving.adaptive_conf {
        // Paper-faithful accounting is threshold-invariant; the adaptive
        // saving is its own gauge (trees the early exit never evaluated).
        println!(
            "adaptive   : t={t} -> {:.2} trees skipped/classification",
            snap.trees_skipped_per_class()
        );
    }
    if snap.exec_samples > 0 {
        // Hardware in the loop: per-classification dynamic energy and
        // cycles measured by the grove-ring simulator inside every
        // replica (per evaluated classification; per *response* amortizes
        // cache hits to zero evaluation energy).
        println!(
            "energy     : {:.4} nJ/classification ({:.4} nJ/response), {:.1} cycles/classification",
            snap.energy_per_class_nj(),
            snap.energy_per_response_nj(),
            snap.cycles_per_class()
        );
    }
    println!(
        "BENCH_JSON {{\"bench\":\"serve_sharded\",\"model\":\"{model_name}\",\
         \"dataset\":\"{}\",\"replicas\":{},\"router\":\"{}\",\"backend\":\"{}\",\
         \"quant\":\"{}\",\"simd\":\"{}\",\"gather\":\"{}\",\"prob_checksum\":{},\
         \"rounds\":{rounds},\"requests\":{},\"throughput_per_s\":{:.1},\
         \"cache_hit_rate\":{:.4},\"cache_quant\":{:.6},\"accuracy\":{:.4},\
         \"energy_per_class_nj\":{:.6},\"energy_per_response_nj\":{:.6},\
         \"cycles_per_class\":{:.2},\"comparator_ops_per_class\":{:.2},\
         \"levels_skipped_per_class\":{:.2},\"trees_skipped_per_class\":{:.2},\
         \"adaptive_conf\":{:.4}}}",
        profile.name,
        server.n_replicas(),
        cfg.router.label(),
        backend.label(),
        quant.label(),
        snap.simd_label(),
        snap.gather_label(),
        prob_checksum(&responses),
        snap.requests,
        n_total as f64 / wall,
        snap.cache_hit_rate(),
        spec.serving.cache_quant.unwrap_or(-1.0),
        acc,
        snap.energy_per_class_nj(),
        snap.energy_per_response_nj(),
        snap.cycles_per_class(),
        snap.comparator_ops_per_class(),
        snap.levels_skipped_per_class(),
        snap.trees_skipped_per_class(),
        spec.serving.adaptive_conf.unwrap_or(-1.0)
    );
    for r in 0..server.n_replicas() {
        let rs = server.replica_metrics(r).snapshot();
        let lat = server.replica_metrics(r).batch_latency_summary();
        println!(
            "BENCH_JSON {{\"bench\":\"serve_sharded_replica\",\"model\":\"{model_name}\",\
             \"replica\":{r},\"backend\":\"{}\",\"requests\":{},\"responses\":{},\
             \"batches\":{},\"evals\":{},\"avg_batch_size\":{:.2},\"throughput_per_s\":{:.1},\
             \"batch_p50_us\":{:.1},\"batch_p99_us\":{:.1},\
             \"energy_per_class_nj\":{:.6},\"cycles_per_class\":{:.2}}}",
            backend.label(),
            rs.requests,
            rs.responses,
            rs.batches,
            rs.evals,
            rs.avg_batch_size(),
            rs.responses as f64 / wall,
            lat.p50_us,
            lat.p99_us,
            rs.energy_per_class_nj(),
            rs.cycles_per_class()
        );
    }
    server.shutdown();
}

/// Serve several registry models through the multi-model fleet tier
/// (`--fleet fog_opt,fog_max`): one request path over a shared replica
/// pool, with the paper's Fig-5 energy budget enforced live
/// (`--energy-budget-nj`, rolling per-model gauges; over-budget traffic
/// sheds or downgrades per `--fleet-policy`). Driven by a seeded
/// open-loop arrival ramp (`--loadgen QPS:SECS`, deterministic from
/// `--loadgen-seed`); emits one aggregate `serve_fleet` BENCH_JSON line
/// plus one `serve_fleet_model` line per registered model (shed rate,
/// p50/p99, energy_per_class_nj — the live Fig 5 observables).
fn cmd_serve_fleet(args: &Args, fleet_spec: &str, seed: u64) {
    let profile = profile_or_exit(args.get_or("dataset", "demo"));
    let names: Vec<String> = fleet_spec
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        eprintln!(
            "error: --fleet needs at least one registry model (e.g. --fleet fog_opt,fog_max); \
             valid names: {}",
            REGISTRY.join(", ")
        );
        std::process::exit(2);
    }
    let router = parse_router_or_exit(args);
    let backend = parse_exec_backend(args);
    let quant = parse_quant_or_exit(args);
    let adaptive = parse_adaptive_or_exit(args);
    let policy = parse_fleet_policy_or_exit(args);
    let specs: Vec<ModelSpec> = names
        .iter()
        .map(|name| {
            let mut spec =
                ModelSpec::for_shape(name, profile.n_features, profile.n_classes)
                    .unwrap_or_else(|| {
                        eprintln!(
                            "error: unknown model '{name}'; valid names: {}",
                            REGISTRY.join(", ")
                        );
                        std::process::exit(2);
                    })
                    .with_quant(quant);
            if let Some(t) = adaptive {
                spec = spec.with_adaptive(t);
            }
            spec
        })
        .collect();

    eprintln!("[serve] training fleet [{}] on {} ...", names.join(", "), profile.name);
    let data = suite::prepare_data(&profile, seed);
    let models: Vec<(String, Arc<dyn Classifier>)> = specs
        .iter()
        .map(|spec| {
            let model: Arc<dyn Classifier> = Arc::from(spec.fit(&data.train, seed));
            if backend == BackendKind::Uarch && model.exec_backend(BackendKind::Uarch).is_none()
            {
                eprintln!(
                    "error: model '{}' has no μarch execution backend; tree-based registry \
                     models only (fog_opt, fog_max, rf, rf_prob)",
                    spec.name
                );
                std::process::exit(2);
            }
            (spec.name.clone(), model)
        })
        .collect();

    let budget = EnergyBudget {
        energy_per_class_nj: args
            .get("energy-budget-nj")
            .map(|_| args.get_f64("energy-budget-nj", 0.0).max(0.0)),
        p99_us: args.get("p99-budget-us").map(|_| args.get_f64("p99-budget-us", 0.0).max(0.0)),
        window_ticks: args.get_usize("budget-window", 32).max(1),
    };
    let cache = if args.get_bool("no-cache") {
        None
    } else {
        Some(CacheConfig {
            capacity: args.get_usize("cache-cap", 4096),
            quant_step: args.get_f64("cache-quant", 0.0) as f32,
            ..Default::default()
        })
    };
    let cfg = FleetConfig {
        total_replicas: args.get_usize("replicas", 2 * names.len()),
        worker: ModelServerConfig {
            batch_size: args.get_usize("batch", 32),
            n_workers: args.get_usize("workers", 2),
            backend,
            ..Default::default()
        },
        router,
        router_seed: seed,
        cache,
        budget,
        policy,
    };
    let mut fleet = Fleet::start(models, &cfg).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });

    let mut lg = match args.get("loadgen") {
        Some(spec) => LoadgenConfig::parse_spec(spec).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        }),
        // No --loadgen: a short unpaced ramp, enough to exercise the
        // budget and fill every BENCH_JSON field deterministically.
        None => LoadgenConfig {
            qps_start: 200.0,
            qps_end: 1000.0,
            duration_s: 1.0,
            pace: false,
            ..Default::default()
        },
    };
    lg.seed = args.get_u64("loadgen-seed", seed);
    let t0 = std::time::Instant::now();
    let report = loadgen::run(&mut fleet, &data.test.x, &lg).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let wall = t0.elapsed().as_secs_f64();
    let snap = fleet.snapshot();

    let budget_label = match budget.energy_per_class_nj {
        Some(b) => format!("{b} nJ/class"),
        None => "unlimited".to_string(),
    };
    println!(
        "== serving: fleet [{}] on {} x{} replicas ({}, backend={}, simd={}, gather={}, \
         policy={}, budget={}) ==",
        names.join(", "),
        profile.name,
        (0..fleet.n_models()).map(|m| fleet.server(m).n_replicas()).sum::<usize>(),
        cfg.router.label(),
        backend.label(),
        snap.total.simd_label(),
        snap.total.gather_label(),
        fleet.policy_label(),
        budget_label
    );
    println!(
        "offered    : {} over {:.2}s virtual (ramp {:.0}->{:.0} qps, seed {})",
        report.offered, report.duration_s, lg.qps_start, lg.qps_end, lg.seed
    );
    println!(
        "outcomes   : {} served, {} downgraded, {} shed ({:.1}% shed rate)",
        report.served,
        report.downgraded,
        report.shed,
        report.shed_rate * 100.0
    );
    println!("throughput : {:.0} req/s over {} ticks", report.offered as f64 / wall, report.ticks);
    for (m, pm) in report.per_model.iter().enumerate() {
        let stats = &snap.per_model[m];
        print!(
            "  {:<8} : {} asked, {} served, {} away, {} into, {} shed; \
             p50 {:.0}µs p99 {:.0}µs",
            pm.name,
            pm.requested,
            pm.served,
            pm.downgraded_away,
            pm.downgraded_into,
            pm.shed,
            pm.latency.p50_us,
            pm.latency.p99_us
        );
        if stats.snapshot.exec_samples > 0 {
            print!("; {:.4} nJ/class", pm.energy_per_class_nj);
        }
        println!();
    }
    for ((from, to), count) in &snap.downgrades {
        println!(
            "  downgrade: {} -> {} x{count}",
            fleet.model_name(*from),
            fleet.model_name(*to)
        );
    }

    println!(
        "BENCH_JSON {{\"bench\":\"serve_fleet\",\"model\":\"{}\",\"dataset\":\"{}\",\
         \"replicas\":{},\"router\":\"{}\",\"backend\":\"{}\",\"simd\":\"{}\",\
         \"gather\":\"{}\",\"policy\":\"{}\",\
         \"energy_budget_nj\":{:.6},\"loadgen_seed\":{},\"offered\":{},\"served\":{},\
         \"downgraded\":{},\"shed\":{},\"shed_rate\":{:.4},\"throughput_per_s\":{:.1},\
         \"energy_per_class_nj\":{:.6},\"adaptive_conf\":{:.4}}}",
        names.join("+"),
        profile.name,
        (0..fleet.n_models()).map(|m| fleet.server(m).n_replicas()).sum::<usize>(),
        cfg.router.label(),
        backend.label(),
        snap.total.simd_label(),
        snap.total.gather_label(),
        fleet.policy_label(),
        budget.energy_per_class_nj.unwrap_or(-1.0),
        lg.seed,
        report.offered,
        report.served,
        report.downgraded,
        report.shed,
        report.shed_rate,
        report.offered as f64 / wall,
        snap.total.energy_per_class_nj(),
        adaptive.unwrap_or(-1.0)
    );
    for (m, pm) in report.per_model.iter().enumerate() {
        let stats = &snap.per_model[m];
        println!(
            "BENCH_JSON {{\"bench\":\"serve_fleet_model\",\"model\":\"{}\",\"fleet\":\"{}\",\
             \"backend\":\"{}\",\"simd\":\"{}\",\"gather\":\"{}\",\"requested\":{},\
             \"served\":{},\"downgraded_away\":{},\
             \"downgraded_into\":{},\"shed\":{},\"shed_rate\":{:.4},\
             \"req_p50_us\":{:.1},\"req_p99_us\":{:.1},\"batch_p50_us\":{:.1},\
             \"batch_p99_us\":{:.1},\"energy_per_class_nj\":{:.6},\"cycles_per_class\":{:.2},\
             \"trees_skipped_per_class\":{:.2}}}",
            pm.name,
            names.join("+"),
            backend.label(),
            stats.snapshot.simd_label(),
            stats.snapshot.gather_label(),
            pm.requested,
            pm.served,
            pm.downgraded_away,
            pm.downgraded_into,
            pm.shed,
            if pm.requested == 0 { 0.0 } else { pm.shed as f64 / pm.requested as f64 },
            pm.latency.p50_us,
            pm.latency.p99_us,
            stats.batch_latency.p50_us,
            stats.batch_latency.p99_us,
            stats.snapshot.energy_per_class_nj(),
            stats.snapshot.cycles_per_class(),
            stats.snapshot.trees_skipped_per_class()
        );
    }
    fleet.shutdown();
}

/// Aladdin-style design-space exploration printout.
fn cmd_dse(args: &Args) {
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let mix = match args.get_or("workload", "trees") {
        "gemm" => aladdin::OpMix {
            comparisons: 10.0,
            macs: 100_000.0,
            sigmoids: 100.0,
            sram_read_bytes: 100_000.0,
            sram_write_bytes: 100.0,
            storage_bytes: 100_000.0,
            serial_fraction: 0.001,
        },
        _ => aladdin::OpMix {
            comparisons: 128.0,
            macs: 0.0,
            sigmoids: 0.0,
            sram_read_bytes: 1024.0,
            sram_write_bytes: 64.0,
            storage_bytes: 6144.0,
            serial_fraction: 0.3,
        },
    };
    let evals = aladdin::sweep(&mix, &eb, &ab);
    let front = aladdin::pareto_front(&evals);
    let sel = aladdin::select_min_edp(&evals);
    println!("== Aladdin-style DSE ({} configs, {} Pareto-optimal) ==", evals.len(), front.len());
    println!(
        "{:>6} {:>6} {:>5} {:>12} {:>10} {:>9} {:>12}",
        "bits", "lanes", "pipe", "energy nJ", "delay ns", "area mm2", "EDP"
    );
    for e in &front {
        let mark = if e.config.bitwidth == sel.config.bitwidth
            && e.config.lanes == sel.config.lanes
            && e.config.pipeline == sel.config.pipeline
        {
            " <= min-EDP"
        } else {
            ""
        };
        println!(
            "{:>6} {:>6} {:>5} {:>12.3} {:>10.1} {:>9.3} {:>12.1}{mark}",
            e.config.bitwidth,
            e.config.lanes,
            e.config.pipeline,
            e.point.energy_nj,
            e.point.delay_ns,
            e.point.area_mm2,
            e.point.edp()
        );
    }
}
