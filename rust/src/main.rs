//! `fog` — command-line launcher for the Field-of-Groves reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts:
//!
//! ```text
//! fog table1   [--datasets a,b,c] [--seed N]      Table 1 + headline
//! fog fig4     [--datasets a,b,c] [--seed N]      Figure 4 topology sweep
//! fog fig5     [--topology 8x2] [--datasets ...]  Figure 5 threshold sweep
//! fog headline [--seed N]                          just the §1 ratios
//! fog ablate   [--dataset penbase]                 design-choice ablations
//! fog sim      [--dataset penbase] [--threshold T] cycle-level μarch sim
//! fog serve    [--dataset demo] [--backend native|pjrt] serving demo
//! fog dse      [--workload trees|gemm]             Aladdin-style DSE sweep
//! ```

use fog::coordinator::{Backend, FogServer, ServerConfig};
use fog::data::synthetic::DatasetProfile;
use fog::energy::aladdin;
use fog::energy::blocks::{AreaBlocks, EnergyBlocks};
use fog::experiments::{fig4, fig5, suite, table1};
use fog::fog::FieldOfGroves;
use fog::uarch::{RingConfig, RingSim};
use fog::util::cli::Args;

fn profiles_from(args: &Args) -> Vec<DatasetProfile> {
    match args.get("datasets") {
        None => DatasetProfile::paper_suite(),
        Some(spec) => spec
            .split(',')
            .map(|name| {
                DatasetProfile::by_name(name.trim())
                    .unwrap_or_else(|| panic!("unknown dataset '{name}'"))
            })
            .collect(),
    }
}

fn main() {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 42);
    match args.subcommand() {
        Some("table1") => {
            let results = table1::run(&profiles_from(&args), seed);
            table1::print_table(&results);
            table1::print_headline(&results);
        }
        Some("headline") => {
            let results = table1::run(&profiles_from(&args), seed);
            table1::print_headline(&results);
        }
        Some("fig4") => {
            let all = fig4::run(&profiles_from(&args), seed);
            fig4::print_series(&all);
        }
        Some("fig5") => {
            let topo = args.get_topology("topology", (8, 2));
            let all = fig5::run(&profiles_from(&args), topo, seed);
            fig5::print_series(topo, &all);
        }
        Some("ablate") => {
            let name = args.get_or("dataset", "penbase");
            let profile = DatasetProfile::by_name(name).expect("unknown dataset");
            eprintln!("[ablate] training {} ...", profile.name);
            let s = suite::train_suite(&profile, seed);
            fog::experiments::ablations::print_all(&s, seed);
        }
        Some("sim") => cmd_sim(&args, seed),
        Some("serve") => cmd_serve(&args, seed),
        Some("dse") => cmd_dse(&args),
        _ => {
            eprintln!(
                "usage: fog <table1|fig4|fig5|headline|sim|serve|dse> [--flags]\n\
                 see `rust/src/main.rs` docs for the flag list"
            );
            std::process::exit(2);
        }
    }
}

/// Cycle-level μarch simulation of the grove ring on one dataset.
fn cmd_sim(args: &Args, seed: u64) {
    let name = args.get_or("dataset", "penbase");
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    let threshold = args.get_f64("threshold", 0.3) as f32;
    let (groves, per_grove) = args.get_topology("topology", (8, 2));
    eprintln!("[sim] training {} ...", profile.name);
    let s = suite::train_suite(&profile, seed);
    assert_eq!(groves * per_grove, s.rf.n_trees(), "topology must factor the forest");
    let fog = FieldOfGroves::from_forest_shuffled(&s.rf, per_grove, Some(seed));
    let cfg = RingConfig {
        threshold,
        seed,
        inject_interval: args.get_u64("inject-interval", 8),
        ..Default::default()
    };
    let mut sim = RingSim::new(&fog, cfg);
    sim.load_batch(&s.data.test.x);
    let outcomes = sim.run();
    let preds: Vec<usize> = outcomes.iter().map(|o| o.label).collect();
    let acc = fog::util::stats::accuracy(&preds, &s.data.test.y);
    let eb = EnergyBlocks::default();
    println!("== μarch ring simulation: {} @ {}x{} thr={} ==", name, groves, per_grove, threshold);
    println!("inputs               : {}", sim.stats.classified);
    println!("accuracy             : {:.1}%", acc * 100.0);
    println!("cycles               : {}", sim.stats.cycles);
    println!("avg hops             : {:.2}", sim.stats.avg_hops());
    println!("avg latency (cycles) : {:.1}", sim.stats.avg_latency_cycles());
    println!("throughput           : {:.2} class/kcycle", sim.stats.throughput_per_kcycle());
    println!("PE utilization       : {:.1}%", sim.stats.avg_utilization() * 100.0);
    println!("handshakes           : {}", sim.stats.handshakes);
    println!("stall cycles         : {}", sim.stats.stall_cycles);
    println!("dynamic energy/input : {:.3} nJ", sim.stats.dynamic_energy_per_input_nj(&eb));
}

/// Serving demo over the coordinator (native or PJRT backend).
fn cmd_serve(args: &Args, seed: u64) {
    let name = args.get_or("dataset", "demo");
    let profile = DatasetProfile::by_name(name).expect("unknown dataset");
    eprintln!("[serve] training {} ...", profile.name);
    let s = suite::train_suite(&profile, seed);
    let per_grove = args.get_topology("topology", (4, 4)).1;
    let mut fog = FieldOfGroves::from_forest_shuffled(&s.rf, per_grove, Some(seed));
    let backend = match args.get_or("backend", "native") {
        "pjrt" => {
            // Artifact shapes are padded to fixed depths; repad to match.
            let depth = args.get_usize("artifact-depth", 6);
            for g in &mut fog.groves {
                for t in &mut g.trees {
                    *t = t.repad(depth.max(t.depth));
                }
            }
            fog.depth = fog.groves.iter().map(|g| g.depth()).max().unwrap();
            Backend::Pjrt { artifacts_dir: fog::runtime::artifacts::default_dir() }
        }
        _ => Backend::Native,
    };
    let cfg = ServerConfig {
        threshold: args.get_f64("threshold", 0.3) as f32,
        seed,
        backend,
        ..Default::default()
    };
    let mut server = FogServer::start(&fog, &cfg).expect("server start");
    let t0 = std::time::Instant::now();
    let responses = server.classify(&s.data.test.x);
    let wall = t0.elapsed();
    let preds: Vec<usize> = responses.iter().map(|r| r.label).collect();
    let acc = fog::util::stats::accuracy(&preds, &s.data.test.y);
    let lat = FogServer::latency_summary(&responses);
    let snap = server.metrics().snapshot();
    println!("== serving: {} x{} groves, backend={} ==", name, fog.n_groves(), args.get_or("backend", "native"));
    println!("requests   : {}", snap.requests);
    println!("accuracy   : {:.1}%", acc * 100.0);
    println!("avg hops   : {:.2}", snap.avg_hops());
    println!("batch size : {:.1} avg", snap.avg_batch_size());
    println!("throughput : {:.0} req/s", responses.len() as f64 / wall.as_secs_f64());
    println!("latency    : p50 {:.0}µs  p95 {:.0}µs  p99 {:.0}µs", lat.p50_us, lat.p95_us, lat.p99_us);
    server.shutdown();
}

/// Aladdin-style design-space exploration printout.
fn cmd_dse(args: &Args) {
    let eb = EnergyBlocks::default();
    let ab = AreaBlocks::default();
    let mix = match args.get_or("workload", "trees") {
        "gemm" => aladdin::OpMix {
            comparisons: 10.0,
            macs: 100_000.0,
            sigmoids: 100.0,
            sram_read_bytes: 100_000.0,
            sram_write_bytes: 100.0,
            storage_bytes: 100_000.0,
            serial_fraction: 0.001,
        },
        _ => aladdin::OpMix {
            comparisons: 128.0,
            macs: 0.0,
            sigmoids: 0.0,
            sram_read_bytes: 1024.0,
            sram_write_bytes: 64.0,
            storage_bytes: 6144.0,
            serial_fraction: 0.3,
        },
    };
    let evals = aladdin::sweep(&mix, &eb, &ab);
    let front = aladdin::pareto_front(&evals);
    let sel = aladdin::select_min_edp(&evals);
    println!("== Aladdin-style DSE ({} configs, {} Pareto-optimal) ==", evals.len(), front.len());
    println!(
        "{:>6} {:>6} {:>5} {:>12} {:>10} {:>9} {:>12}",
        "bits", "lanes", "pipe", "energy nJ", "delay ns", "area mm2", "EDP"
    );
    for e in &front {
        let mark = if e.config.bitwidth == sel.config.bitwidth
            && e.config.lanes == sel.config.lanes
            && e.config.pipeline == sel.config.pipeline
        {
            " <= min-EDP"
        } else {
            ""
        };
        println!(
            "{:>6} {:>6} {:>5} {:>12.3} {:>10.1} {:>9.3} {:>12.1}{mark}",
            e.config.bitwidth,
            e.config.lanes,
            e.config.pipeline,
            e.point.energy_nj,
            e.point.delay_ns,
            e.point.area_mm2,
            e.point.edp()
        );
    }
}
