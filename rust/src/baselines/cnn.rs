//! Small 1-D convolutional network (the paper's CNN baseline).
//!
//! The paper's datasets are feature vectors, not images; its CNN treats
//! them as signals. We do the same: two conv1d+ReLU+maxpool stages over
//! the feature axis followed by a dense softmax head. The synthetic
//! profiles embed their latent factors with spatially smoothed loadings,
//! so convolutions genuinely help — the CNN tops the accuracy table for
//! the same reason it does in the paper, at the highest MAC count (the
//! energy model counts them exactly).
//!
//! Training is per-sample SGD with momentum, implemented directly (no
//! autograd); gradients flow through maxpool argmaxes and 'same'-padded
//! convolutions.

use crate::api::{batch_from_scores, Classifier, ProbMatrix};
use crate::data::Split;
use crate::energy::model::ClassifierKind;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{cnn_cost, CostReport};
use crate::util::rng::Rng;

/// Architecture + training hyper-parameters.
#[derive(Clone, Debug)]
pub struct CnnParams {
    pub conv1_channels: usize,
    pub conv1_kernel: usize,
    pub pool1: usize,
    pub conv2_channels: usize,
    pub conv2_kernel: usize,
    pub pool2: usize,
    pub epochs: usize,
    pub lr: f32,
    pub momentum: f32,
}

impl Default for CnnParams {
    fn default() -> Self {
        CnnParams {
            conv1_channels: 8,
            conv1_kernel: 5,
            pool1: 4,
            conv2_channels: 16,
            conv2_kernel: 3,
            pool2: 2,
            epochs: 25,
            lr: 0.005,
            momentum: 0.5,
        }
    }
}

/// One conv1d layer, 'same' padding, stride 1.
struct Conv1d {
    in_ch: usize,
    out_ch: usize,
    k: usize,
    /// `[out_ch, in_ch, k]`
    w: Vec<f32>,
    b: Vec<f32>,
    vw: Vec<f32>,
    vb: Vec<f32>,
}

impl Conv1d {
    fn new(in_ch: usize, out_ch: usize, k: usize, rng: &mut Rng) -> Conv1d {
        let std = (2.0 / (in_ch * k) as f32).sqrt();
        Conv1d {
            in_ch,
            out_ch,
            k,
            w: (0..out_ch * in_ch * k).map(|_| rng.gen_normal() * std).collect(),
            b: vec![0.0; out_ch],
            vw: vec![0.0; out_ch * in_ch * k],
            vb: vec![0.0; out_ch],
        }
    }

    /// Forward: `x [in_ch, len]` → `[out_ch, len]` with ReLU.
    fn forward(&self, x: &[f32], len: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.out_ch * len, 0.0);
        let half = self.k / 2;
        for oc in 0..self.out_ch {
            for pos in 0..len {
                let mut s = self.b[oc];
                for ic in 0..self.in_ch {
                    let xrow = &x[ic * len..(ic + 1) * len];
                    let wrow = &self.w[(oc * self.in_ch + ic) * self.k..];
                    for kk in 0..self.k {
                        let src = pos + kk;
                        if src >= half && src - half < len {
                            s += wrow[kk] * xrow[src - half];
                        }
                    }
                }
                out[oc * len + pos] = s.max(0.0); // fused ReLU
            }
        }
    }

    /// Backward: given dL/dout (already masked by ReLU), accumulate
    /// gradient steps (momentum SGD applied immediately, per sample) and
    /// return dL/dx.
    fn backward(
        &mut self,
        x: &[f32],
        len: usize,
        dout: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Vec<f32> {
        let half = self.k / 2;
        let mut dx = vec![0.0f32; self.in_ch * len];
        for oc in 0..self.out_ch {
            let dorow = &dout[oc * len..(oc + 1) * len];
            let mut gb = 0.0f32;
            for &d in dorow {
                gb += d;
            }
            let vb = &mut self.vb[oc];
            *vb = momentum * *vb - lr * gb;
            self.b[oc] += *vb;
            for ic in 0..self.in_ch {
                let xrow = &x[ic * len..(ic + 1) * len];
                let base = (oc * self.in_ch + ic) * self.k;
                for kk in 0..self.k {
                    let mut gw = 0.0f32;
                    for pos in 0..len {
                        let src = pos + kk;
                        if src >= half && src - half < len {
                            gw += dorow[pos] * xrow[src - half];
                        }
                    }
                    let v = &mut self.vw[base + kk];
                    *v = momentum * *v - lr * gw;
                    // dx before the weight update (correct SGD ordering is
                    // negligible at these step sizes; we use updated-minus
                    // -velocity weights for simplicity).
                    for pos in 0..len {
                        let src = pos + kk;
                        if src >= half && src - half < len {
                            dx[ic * len + src - half] += dorow[pos] * self.w[base + kk];
                        }
                    }
                    self.w[base + kk] += *v;
                }
            }
        }
        dx
    }

    fn macs(&self, len: usize) -> f64 {
        (self.out_ch * len * self.in_ch * self.k) as f64
    }

    fn weight_bytes(&self) -> f64 {
        (self.w.len() + self.b.len()) as f64
    }
}

fn maxpool(x: &[f32], ch: usize, len: usize, size: usize) -> (Vec<f32>, Vec<usize>, usize) {
    let out_len = len / size;
    let mut out = vec![f32::NEG_INFINITY; ch * out_len];
    let mut arg = vec![0usize; ch * out_len];
    for c in 0..ch {
        for o in 0..out_len {
            for j in 0..size {
                let idx = c * len + o * size + j;
                if x[idx] > out[c * out_len + o] {
                    out[c * out_len + o] = x[idx];
                    arg[c * out_len + o] = idx;
                }
            }
        }
    }
    (out, arg, out_len)
}

fn maxpool_backward(dout: &[f32], arg: &[usize], ch_len: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; ch_len];
    for (d, &a) in dout.iter().zip(arg) {
        dx[a] += d;
    }
    dx
}

/// A trained CNN.
pub struct Cnn {
    conv1: Conv1d,
    conv2: Conv1d,
    /// Dense head `[flat, classes]` + bias.
    dense_w: Vec<f32>,
    dense_b: Vec<f32>,
    params: CnnParams,
    pub n_features: usize,
    pub n_classes: usize,
    len1: usize,
    flat: usize,
}

impl Cnn {
    pub fn fit(data: &Split, params: &CnnParams, seed: u64) -> Cnn {
        let f = data.n_features;
        let c = data.n_classes;
        let mut rng = Rng::new(seed);
        let len1 = f / params.pool1.max(1);
        let len2 = len1 / params.pool2.max(1);
        assert!(len2 >= 1, "features too few for pooling config");
        let flat = params.conv2_channels * len2;

        let mut cnn = Cnn {
            conv1: Conv1d::new(1, params.conv1_channels, params.conv1_kernel, &mut rng),
            conv2: Conv1d::new(
                params.conv1_channels,
                params.conv2_channels,
                params.conv2_kernel,
                &mut rng,
            ),
            dense_w: (0..flat * c)
                .map(|_| rng.gen_normal() * (2.0 / flat as f32).sqrt())
                .collect(),
            dense_b: vec![0.0; c],
            params: params.clone(),
            n_features: f,
            n_classes: c,
            len1,
            flat,
        };

        let mut dvw = vec![0.0f32; cnn.dense_w.len()];
        let mut dvb = vec![0.0f32; c];
        let n = data.len();
        let mut order: Vec<usize> = (0..n).collect();
        let mut a1 = Vec::new();
        let mut a2 = Vec::new();
        for _epoch in 0..params.epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                let x = data.row(i);
                // ---- forward ----
                cnn.conv1.forward(x, f, &mut a1);
                let (p1, arg1, _l1) = maxpool(&a1, params.conv1_channels, f, params.pool1);
                cnn.conv2.forward(&p1, cnn.len1, &mut a2);
                let (p2, arg2, _l2) = maxpool(&a2, params.conv2_channels, cnn.len1, params.pool2);
                let mut logits = cnn.dense_b.clone();
                for (j, &v) in p2.iter().enumerate() {
                    if v == 0.0 {
                        continue;
                    }
                    for class in 0..c {
                        logits[class] += v * cnn.dense_w[j * c + class];
                    }
                }
                // softmax + CE grad
                let maxv = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut probs: Vec<f32> = logits.iter().map(|&v| (v - maxv).exp()).collect();
                let sum: f32 = probs.iter().sum();
                probs.iter_mut().for_each(|p| *p /= sum);
                let mut dlogits = probs;
                dlogits[data.y[i]] -= 1.0;
                // ---- backward ----
                let mut dp2 = vec![0.0f32; cnn.flat];
                for j in 0..cnn.flat {
                    let mut s = 0.0f32;
                    for class in 0..c {
                        s += dlogits[class] * cnn.dense_w[j * c + class];
                        let g = dlogits[class] * p2[j];
                        let v = &mut dvw[j * c + class];
                        *v = params.momentum * *v - params.lr * g;
                        cnn.dense_w[j * c + class] += *v;
                    }
                    dp2[j] = s;
                }
                for class in 0..c {
                    let v = &mut dvb[class];
                    *v = params.momentum * *v - params.lr * dlogits[class];
                    cnn.dense_b[class] += *v;
                }
                let mut da2 =
                    maxpool_backward(&dp2, &arg2, params.conv2_channels * cnn.len1);
                // ReLU mask of a2.
                for (d, &a) in da2.iter_mut().zip(&a2) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
                let dp1 =
                    cnn.conv2.backward(&p1, cnn.len1, &da2, params.lr, params.momentum);
                let mut da1 = maxpool_backward(&dp1, &arg1, params.conv1_channels * f);
                for (d, &a) in da1.iter_mut().zip(&a1) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
                cnn.conv1.backward(x, f, &da1, params.lr, params.momentum);
            }
        }
        cnn
    }

    /// Class scores for one sample.
    pub fn scores(&self, x: &[f32]) -> Vec<f32> {
        let mut a1 = Vec::new();
        let mut a2 = Vec::new();
        self.conv1.forward(x, self.n_features, &mut a1);
        let (p1, _, _) = maxpool(&a1, self.params.conv1_channels, self.n_features, self.params.pool1);
        self.conv2.forward(&p1, self.len1, &mut a2);
        let (p2, _, _) = maxpool(&a2, self.params.conv2_channels, self.len1, self.params.pool2);
        let mut logits = self.dense_b.clone();
        for (j, &v) in p2.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            for class in 0..self.n_classes {
                logits[class] += v * self.dense_w[j * self.n_classes + class];
            }
        }
        logits
    }

    /// Measured MAC count of one inference (for the energy model).
    pub fn inference_macs(&self) -> f64 {
        self.conv1.macs(self.n_features)
            + self.conv2.macs(self.len1)
            + (self.flat * self.n_classes) as f64
    }

    pub fn weight_bytes(&self) -> f64 {
        self.conv1.weight_bytes()
            + self.conv2.weight_bytes()
            + (self.dense_w.len() + self.dense_b.len()) as f64
    }

    /// Activation traffic bytes (each intermediate written+read once).
    pub fn activation_bytes(&self) -> f64 {
        (self.params.conv1_channels * self.n_features
            + self.params.conv1_channels * self.len1
            + self.params.conv2_channels * self.len1
            + self.flat) as f64
            * 2.0
    }
}

impl Classifier for Cnn {
    fn kind(&self) -> ClassifierKind {
        ClassifierKind::Cnn
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix {
        batch_from_scores(x, n, self.n_features, self.n_classes, |row| self.scores(row))
    }

    fn cost_report(
        &self,
        _probe: Option<&Split>,
        eb: &EnergyBlocks,
        ab: &AreaBlocks,
    ) -> CostReport {
        cnn_cost(self.inference_macs(), self.weight_bytes(), self.activation_bytes(), eb, ab)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    fn small_params() -> CnnParams {
        CnnParams {
            conv1_channels: 4,
            conv1_kernel: 3,
            pool1: 2,
            conv2_channels: 8,
            conv2_kernel: 3,
            pool2: 2,
            epochs: 25,
            lr: 0.005,
            momentum: 0.5,
        }
    }

    #[test]
    fn learns_demo_dataset() {
        let ds = generate(&DatasetProfile::demo(), 171);
        let cnn = Cnn::fit(&ds.train, &small_params(), 1);
        let acc = cnn.accuracy(&ds.test);
        assert!(acc > 0.6, "acc {acc}");
    }

    #[test]
    fn mac_count_positive_and_conv_dominated() {
        let ds = generate(&DatasetProfile::demo(), 172);
        let cnn = Cnn::fit(&ds.train, &CnnParams { epochs: 1, ..small_params() }, 2);
        let macs = cnn.inference_macs();
        let dense = (cnn.flat * cnn.n_classes) as f64;
        assert!(macs > dense, "conv should dominate: {macs} vs dense {dense}");
    }

    #[test]
    fn cost_report_most_expensive_kind() {
        let ds = generate(&DatasetProfile::demo(), 173);
        let cnn = Cnn::fit(&ds.train, &CnnParams { epochs: 1, ..small_params() }, 3);
        let r = cnn.cost_report(None, &EnergyBlocks::default(), &AreaBlocks::default());
        assert!(r.energy_nj > 0.0);
        assert_eq!(r.kind, crate::energy::model::ClassifierKind::Cnn);
    }

    #[test]
    fn maxpool_roundtrip() {
        let x = vec![1.0, 5.0, 2.0, 3.0, 9.0, 0.0, 4.0, 4.0];
        let (out, arg, ol) = maxpool(&x, 2, 4, 2);
        assert_eq!(ol, 2);
        assert_eq!(out, vec![5.0, 3.0, 9.0, 4.0]);
        let dx = maxpool_backward(&[1.0, 1.0, 1.0, 1.0], &arg, 8);
        assert_eq!(dx[1], 1.0); // argmax of first window
        assert_eq!(dx.iter().sum::<f32>(), 4.0);
    }
}
