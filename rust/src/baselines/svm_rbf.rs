//! RBF-kernel SVM (the paper's SVM_rbf), implemented as a least-squares
//! SVM (LS-SVM): solve `(K + λI) A = Y` for dual coefficients over a
//! bounded support set, predict `argmax_c Σ_j A[j,c] · k(x_j, x)`.
//!
//! LS-SVM keeps **every** training point in the support set — which is
//! exactly why kernel SVMs are the energy hogs of Table 1: each
//! classification streams `n_sv × n_features` bytes of support vectors
//! through the distance datapath. The support set is subsampled to
//! [`RbfSvmParams::max_support`] for tractability (stratified, so class
//! balance survives).

use crate::api::{batch_from_scores, Classifier, ProbMatrix};
use crate::data::Split;
use crate::energy::model::ClassifierKind;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{svm_rbf_cost, CostReport};
use crate::util::matrix::sq_dist;
use crate::util::rng::Rng;

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct RbfSvmParams {
    /// Kernel width γ in `exp(-γ‖x−x'‖²)`; 0 = auto (1 / (f · var)).
    pub gamma: f32,
    /// Ridge λ on the kernel diagonal.
    pub lambda: f32,
    /// Max support vectors (training subsample).
    pub max_support: usize,
}

impl Default for RbfSvmParams {
    fn default() -> Self {
        RbfSvmParams { gamma: 0.0, lambda: 1e-3, max_support: 800 }
    }
}

/// Trained LS-SVM with RBF kernel.
#[derive(Clone, Debug)]
pub struct RbfSvm {
    /// Support vectors, row-major `[n_sv, f]`.
    pub sv: Vec<f32>,
    /// Dual coefficients `[n_sv, c]`.
    pub alpha: Vec<f32>,
    pub gamma: f32,
    pub n_sv: usize,
    pub n_features: usize,
    pub n_classes: usize,
}

impl RbfSvm {
    pub fn fit(data: &Split, params: &RbfSvmParams, seed: u64) -> RbfSvm {
        let f = data.n_features;
        let c = data.n_classes;
        // Stratified subsample to max_support.
        let idx = stratified_subsample(data, params.max_support, seed);
        let m = idx.len();
        let mut sv = Vec::with_capacity(m * f);
        for &i in &idx {
            sv.extend_from_slice(data.row(i));
        }
        // Auto kernel width: 1 / (f · mean feature variance) — standard
        // "scale" heuristic.
        let gamma = if params.gamma > 0.0 {
            params.gamma
        } else {
            let var = feature_variance(&sv, m, f).max(1e-6);
            1.0 / (f as f32 * var)
        };

        // Gram matrix K + λI.
        let mut k = vec![0.0f64; m * m];
        for i in 0..m {
            k[i * m + i] = 1.0 + params.lambda as f64;
            for j in (i + 1)..m {
                let d = sq_dist(&sv[i * f..(i + 1) * f], &sv[j * f..(j + 1) * f]);
                let v = (-gamma * d).exp() as f64;
                k[i * m + j] = v;
                k[j * m + i] = v;
            }
        }
        // One-hot targets (±1 encoding improves conditioning of argmax).
        let mut y = vec![0.0f64; m * c];
        for (row, &i) in idx.iter().enumerate() {
            for class in 0..c {
                y[row * c + class] = if data.y[i] == class { 1.0 } else { -1.0 / (c as f64 - 1.0).max(1.0) };
            }
        }
        // Solve (K+λI) A = Y via Cholesky.
        let chol = cholesky(&mut k, m);
        assert!(chol, "kernel matrix not PD — raise lambda");
        let alpha64 = cholesky_solve_multi(&k, m, &y, c);
        let alpha: Vec<f32> = alpha64.iter().map(|&v| v as f32).collect();

        RbfSvm { sv, alpha, gamma, n_sv: m, n_features: f, n_classes: c }
    }

    /// Per-class kernel scores.
    pub fn scores(&self, x: &[f32]) -> Vec<f32> {
        let f = self.n_features;
        let c = self.n_classes;
        let mut out = vec![0.0f32; c];
        for j in 0..self.n_sv {
            let d = sq_dist(&self.sv[j * f..(j + 1) * f], x);
            let kv = (-self.gamma * d).exp();
            if kv < 1e-12 {
                continue;
            }
            let a = &self.alpha[j * c..(j + 1) * c];
            for (o, &av) in out.iter_mut().zip(a) {
                *o += kv * av;
            }
        }
        out
    }
}

impl Classifier for RbfSvm {
    fn kind(&self) -> ClassifierKind {
        ClassifierKind::SvmRbf
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix {
        batch_from_scores(x, n, self.n_features, self.n_classes, |row| self.scores(row))
    }

    fn cost_report(
        &self,
        _probe: Option<&Split>,
        eb: &EnergyBlocks,
        ab: &AreaBlocks,
    ) -> CostReport {
        svm_rbf_cost(self.n_sv, self.n_features, self.n_classes, eb, ab)
    }
}

fn stratified_subsample(data: &Split, max: usize, seed: u64) -> Vec<usize> {
    if data.len() <= max {
        return (0..data.len()).collect();
    }
    let mut rng = Rng::new(seed ^ 0x5BF0);
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); data.n_classes];
    for (i, &y) in data.y.iter().enumerate() {
        buckets[y].push(i);
    }
    let per_class = max / data.n_classes.max(1);
    let mut out = Vec::new();
    for bucket in buckets.iter_mut() {
        rng.shuffle(bucket);
        out.extend_from_slice(&bucket[..per_class.min(bucket.len())]);
    }
    out.sort_unstable();
    out
}

fn feature_variance(x: &[f32], n: usize, f: usize) -> f32 {
    let mut mean = vec![0.0f32; f];
    for row in x.chunks(f) {
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v;
        }
    }
    mean.iter_mut().for_each(|m| *m /= n as f32);
    let mut var = 0.0f32;
    for row in x.chunks(f) {
        for (j, &v) in row.iter().enumerate() {
            let d = v - mean[j];
            var += d * d;
        }
    }
    var / (n * f) as f32
}

/// In-place Cholesky `K = L·Lᵀ` (lower triangle stored in `k`). Returns
/// false if the matrix is not positive definite.
fn cholesky(k: &mut [f64], m: usize) -> bool {
    for i in 0..m {
        for j in 0..=i {
            let mut s = k[i * m + j];
            for p in 0..j {
                s -= k[i * m + p] * k[j * m + p];
            }
            if i == j {
                if s <= 0.0 {
                    return false;
                }
                k[i * m + i] = s.sqrt();
            } else {
                k[i * m + j] = s / k[j * m + j];
            }
        }
    }
    true
}

/// Solve `L·Lᵀ·A = Y` for multi-column `Y` `[m, c]`.
fn cholesky_solve_multi(l: &[f64], m: usize, y: &[f64], c: usize) -> Vec<f64> {
    let mut a = y.to_vec();
    // Forward: L z = y (column-wise over c RHS).
    for i in 0..m {
        for col in 0..c {
            let mut s = a[i * c + col];
            for p in 0..i {
                s -= l[i * m + p] * a[p * c + col];
            }
            a[i * c + col] = s / l[i * m + i];
        }
    }
    // Backward: Lᵀ a = z.
    for i in (0..m).rev() {
        for col in 0..c {
            let mut s = a[i * c + col];
            for p in (i + 1)..m {
                s -= l[p * m + i] * a[p * c + col];
            }
            a[i * c + col] = s / l[i * m + i];
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    #[test]
    fn cholesky_solves_identity() {
        let mut k = vec![0.0f64; 9];
        for i in 0..3 {
            k[i * 3 + i] = 4.0;
        }
        assert!(cholesky(&mut k, 3));
        let y = vec![4.0f64, 8.0, 12.0];
        let a = cholesky_solve_multi(&k, 3, &y, 1);
        for (i, &v) in a.iter().enumerate() {
            assert!((v - (i as f64 + 1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut k = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(!cholesky(&mut k, 2));
    }

    #[test]
    fn rbf_beats_linear_on_multimodal() {
        let ds = generate(&DatasetProfile::demo(), 151);
        let rbf = RbfSvm::fit(&ds.train, &RbfSvmParams::default(), 1);
        let lin = crate::baselines::LinearSvm::fit(
            &ds.train,
            &crate::baselines::svm_linear::LinearSvmParams::default(),
            1,
        );
        let rbf_acc = rbf.accuracy(&ds.test);
        let lin_acc = lin.accuracy(&ds.test);
        assert!(rbf_acc > 0.7, "rbf acc {rbf_acc}");
        assert!(rbf_acc >= lin_acc - 0.02, "rbf {rbf_acc} vs linear {lin_acc}");
    }

    #[test]
    fn support_bounded() {
        let ds = generate(&DatasetProfile::demo(), 152);
        let params = RbfSvmParams { max_support: 60, ..Default::default() };
        let rbf = RbfSvm::fit(&ds.train, &params, 2);
        assert!(rbf.n_sv <= 60);
        assert!(rbf.accuracy(&ds.test) > 0.5);
    }

    #[test]
    fn train_accuracy_high() {
        let ds = generate(&DatasetProfile::demo(), 153);
        let rbf = RbfSvm::fit(&ds.train, &RbfSvmParams::default(), 3);
        // LS-SVM interpolates well on its own support set.
        assert!(rbf.accuracy(&ds.train) > 0.85);
    }
}
