//! Shared classifier interface: every baseline predicts labels and
//! reports the PPA cost of one hardware classification through the
//! energy-model layer.

use crate::data::Split;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::CostReport;
use crate::util::threadpool::par_map;

/// A trained classifier with a hardware cost model.
pub trait Classifier: Sync {
    /// Predict the label of one sample.
    fn predict(&self, x: &[f32]) -> usize;

    /// Hardware PPA of one classification on this trained model.
    fn cost_report(&self, eb: &EnergyBlocks, ab: &AreaBlocks) -> CostReport;

    /// Human-readable name.
    fn name(&self) -> &'static str;

    /// Batch accuracy (parallel).
    fn accuracy(&self, split: &Split) -> f64 {
        let preds = par_map(split.len(), |i| self.predict(split.row(i)));
        crate::util::stats::accuracy(&preds, &split.y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::model::ClassifierKind;

    struct Constant(usize);
    impl Classifier for Constant {
        fn predict(&self, _x: &[f32]) -> usize {
            self.0
        }
        fn cost_report(&self, _eb: &EnergyBlocks, _ab: &AreaBlocks) -> CostReport {
            CostReport {
                kind: ClassifierKind::Mlp,
                energy_nj: 1.0,
                latency_ns: 1.0,
                area_mm2: 1.0,
            }
        }
        fn name(&self) -> &'static str {
            "const"
        }
    }

    #[test]
    fn default_accuracy_impl() {
        let mut s = Split::new(1, 2);
        s.push(&[0.0], 1);
        s.push(&[0.0], 1);
        s.push(&[0.0], 0);
        let c = Constant(1);
        assert!((c.accuracy(&s) - 2.0 / 3.0).abs() < 1e-9);
    }
}
