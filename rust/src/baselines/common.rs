//! Shared classifier interface for the baselines.
//!
//! Historically this module owned a minimal per-sample `Classifier`
//! trait. The crate-wide, batch-first interface now lives in
//! [`crate::api`]; this module re-exports it so existing
//! `baselines::common::Classifier` imports keep working.

pub use crate::api::{Classifier, ProbMatrix};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Split;
    use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
    use crate::energy::model::{ClassifierKind, CostReport};

    /// Minimal conformance check of the trait's derived defaults.
    struct Constant(usize, usize);

    impl Classifier for Constant {
        fn kind(&self) -> ClassifierKind {
            ClassifierKind::Mlp
        }
        fn n_features(&self) -> usize {
            1
        }
        fn n_classes(&self) -> usize {
            self.1
        }
        fn predict_proba_batch(&self, _x: &[f32], n: usize) -> ProbMatrix {
            let mut row = vec![0.0f32; self.1];
            row[self.0] = 1.0;
            ProbMatrix::from_rows(vec![row; n], self.1)
        }
        fn cost_report(
            &self,
            _probe: Option<&Split>,
            _eb: &EnergyBlocks,
            _ab: &AreaBlocks,
        ) -> CostReport {
            CostReport {
                kind: ClassifierKind::Mlp,
                energy_nj: 1.0,
                latency_ns: 1.0,
                area_mm2: 1.0,
            }
        }
    }

    #[test]
    fn default_accuracy_impl() {
        let mut s = Split::new(1, 2);
        s.push(&[0.0], 1);
        s.push(&[0.0], 1);
        s.push(&[0.0], 0);
        let c = Constant(1, 2);
        assert!((c.accuracy(&s) - 2.0 / 3.0).abs() < 1e-9);
        assert_eq!(c.predict(&[0.0]), 1);
        assert_eq!(c.predict_batch(&s.x, 3), vec![1, 1, 1]);
    }
}
