//! Multilayer perceptron: dense layers, ReLU hidden activations, softmax
//! cross-entropy output, mini-batch SGD with momentum. Trained from
//! scratch on the [`crate::util::matrix`] substrate.

use crate::api::{batch_from_scores, Classifier, ProbMatrix};
use crate::data::Split;
use crate::energy::model::ClassifierKind;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{mlp_cost, CostReport};
use crate::util::matrix::{softmax_rows, Matrix};
use crate::util::rng::Rng;

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct MlpParams {
    /// Hidden-layer widths (e.g. `[128]` for one hidden layer).
    pub hidden: Vec<usize>,
    pub epochs: usize,
    pub batch_size: usize,
    pub lr: f32,
    pub momentum: f32,
}

impl Default for MlpParams {
    fn default() -> Self {
        MlpParams { hidden: vec![64], epochs: 30, batch_size: 32, lr: 0.05, momentum: 0.9 }
    }
}

struct Layer {
    w: Matrix, // [in, out]
    b: Vec<f32>,
    vw: Matrix,
    vb: Vec<f32>,
}

/// A trained MLP.
pub struct Mlp {
    layers: Vec<Layer>,
    pub dims: Vec<usize>,
}

impl Mlp {
    pub fn fit(data: &Split, params: &MlpParams, seed: u64) -> Mlp {
        let mut dims = vec![data.n_features];
        dims.extend_from_slice(&params.hidden);
        dims.push(data.n_classes);
        let mut rng = Rng::new(seed);
        let mut layers: Vec<Layer> = dims
            .windows(2)
            .map(|w| {
                let std = (2.0 / w[0] as f32).sqrt(); // He init
                Layer {
                    w: Matrix::randn(w[0], w[1], std, &mut rng),
                    b: vec![0.0; w[1]],
                    vw: Matrix::zeros(w[0], w[1]),
                    vb: vec![0.0; w[1]],
                }
            })
            .collect();

        let n = data.len();
        let mut order: Vec<usize> = (0..n).collect();
        for _epoch in 0..params.epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(params.batch_size) {
                let bs = chunk.len();
                // Assemble batch.
                let mut x = Matrix::zeros(bs, data.n_features);
                for (r, &i) in chunk.iter().enumerate() {
                    x.row_mut(r).copy_from_slice(data.row(i));
                }
                // Forward, keeping activations.
                let mut acts = vec![x];
                for (li, layer) in layers.iter().enumerate() {
                    let mut z = acts[li].matmul(&layer.w);
                    z.add_row_vector(&layer.b);
                    if li + 1 < layers.len() {
                        z.map_inplace(|v| v.max(0.0)); // ReLU
                    }
                    acts.push(z);
                }
                // Softmax + CE gradient at the output.
                let mut probs = acts.last().unwrap().clone();
                softmax_rows(&mut probs);
                let mut delta = probs;
                for (r, &i) in chunk.iter().enumerate() {
                    let t = data.y[i];
                    delta.set(r, t, delta.get(r, t) - 1.0);
                }
                delta.scale(1.0 / bs as f32);
                // Backward.
                for li in (0..layers.len()).rev() {
                    let grad_w = acts[li].matmul_at(&delta);
                    let grad_b: Vec<f32> = (0..delta.cols)
                        .map(|c| (0..delta.rows).map(|r| delta.get(r, c)).sum())
                        .collect();
                    let next_delta = if li > 0 {
                        let mut d = delta.matmul_bt(&layers[li].w);
                        // ReLU mask of the *input* activation of this layer.
                        for (dv, &av) in d.data.iter_mut().zip(&acts[li].data) {
                            if av <= 0.0 {
                                *dv = 0.0;
                            }
                        }
                        Some(d)
                    } else {
                        None
                    };
                    // Momentum SGD.
                    let layer = &mut layers[li];
                    layer.vw.scale(params.momentum);
                    layer.vw.axpy(-params.lr, &grad_w);
                    let vw = layer.vw.clone();
                    layer.w.axpy(1.0, &vw);
                    for ((vb, gb), b) in
                        layer.vb.iter_mut().zip(&grad_b).zip(layer.b.iter_mut())
                    {
                        *vb = params.momentum * *vb - params.lr * gb;
                        *b += *vb;
                    }
                    if let Some(d) = next_delta {
                        delta = d;
                    }
                }
            }
        }
        Mlp { layers, dims }
    }

    /// Forward pass for one sample (no allocation beyond the activations).
    pub fn scores(&self, x: &[f32]) -> Vec<f32> {
        let mut a = x.to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            let mut z = vec![0.0f32; layer.w.cols];
            for (i, &av) in a.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let wrow = layer.w.row(i);
                for (zv, &wv) in z.iter_mut().zip(wrow) {
                    *zv += av * wv;
                }
            }
            for (zv, &bv) in z.iter_mut().zip(&layer.b) {
                *zv += bv;
            }
            if li + 1 < self.layers.len() {
                z.iter_mut().for_each(|v| *v = v.max(0.0));
            }
            a = z;
        }
        a
    }
}

impl Classifier for Mlp {
    fn kind(&self) -> ClassifierKind {
        ClassifierKind::Mlp
    }

    fn n_features(&self) -> usize {
        self.dims[0]
    }

    fn n_classes(&self) -> usize {
        *self.dims.last().expect("mlp has layers")
    }

    fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix {
        batch_from_scores(x, n, self.dims[0], Classifier::n_classes(self), |row| self.scores(row))
    }

    fn cost_report(
        &self,
        _probe: Option<&Split>,
        eb: &EnergyBlocks,
        ab: &AreaBlocks,
    ) -> CostReport {
        mlp_cost(&self.dims, eb, ab)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    #[test]
    fn learns_xor() {
        // XOR: impossible for linear models, easy for one hidden layer.
        let mut s = Split::new(2, 2);
        let mut rng = Rng::new(1);
        for _ in 0..400 {
            let a = rng.gen_range(2);
            let b = rng.gen_range(2);
            let y = a ^ b;
            s.push(
                &[
                    a as f32 * 2.0 - 1.0 + rng.gen_normal() * 0.15,
                    b as f32 * 2.0 - 1.0 + rng.gen_normal() * 0.15,
                ],
                y,
            );
        }
        let params = MlpParams { hidden: vec![16], epochs: 60, ..Default::default() };
        let mlp = Mlp::fit(&s, &params, 2);
        assert!(mlp.accuracy(&s) > 0.95, "acc {}", mlp.accuracy(&s));
    }

    #[test]
    fn beats_chance_on_demo() {
        let ds = generate(&DatasetProfile::demo(), 161);
        let mlp = Mlp::fit(&ds.train, &MlpParams::default(), 3);
        let acc = mlp.accuracy(&ds.test);
        assert!(acc > 0.6, "acc {acc}");
    }

    #[test]
    fn dims_recorded() {
        let ds = generate(&DatasetProfile::demo(), 162);
        let params = MlpParams { hidden: vec![32, 16], epochs: 2, ..Default::default() };
        let mlp = Mlp::fit(&ds.train, &params, 4);
        assert_eq!(mlp.dims, vec![8, 32, 16, 3]);
        let r = mlp.cost_report(None, &EnergyBlocks::default(), &AreaBlocks::default());
        assert!(r.energy_nj > 0.0);
    }

    #[test]
    fn deterministic() {
        let ds = generate(&DatasetProfile::demo(), 163);
        let params = MlpParams { epochs: 3, ..Default::default() };
        let a = Mlp::fit(&ds.train, &params, 9);
        let b = Mlp::fit(&ds.train, &params, 9);
        for i in 0..20 {
            assert_eq!(a.predict(ds.test.row(i)), b.predict(ds.test.row(i)));
        }
    }
}
