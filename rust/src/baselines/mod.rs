//! Baseline classifiers the paper compares against (§4.1): linear SVM,
//! RBF-kernel SVM, multilayer perceptron, and a small CNN — all trained
//! from scratch (the environment has no ML libraries) and all reporting
//! the op-count statistics the energy models consume.

pub mod cnn;
pub mod common;
pub mod mlp;
pub mod svm_linear;
pub mod svm_rbf;

pub use cnn::Cnn;
pub use common::Classifier;
pub use mlp::Mlp;
pub use svm_linear::LinearSvm;
pub use svm_rbf::RbfSvm;
