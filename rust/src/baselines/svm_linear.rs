//! Linear SVM (the paper's SVM_lr) trained with Pegasos — stochastic
//! sub-gradient descent on the hinge loss with 1/(λt) step sizes
//! (Shalev-Shwartz et al.) — in a one-vs-rest arrangement for
//! multiclass.
//!
//! The paper's Table 1 shows SVM_lr as the cheapest classifier (a single
//! `c × f` GEMV) but the least accurate on every dataset — our synthetic
//! profiles are deliberately not linearly separable, so the same gap
//! emerges from training rather than being hard-coded.

use crate::api::{batch_from_scores, Classifier, ProbMatrix};
use crate::data::Split;
use crate::energy::model::ClassifierKind;
use crate::energy::blocks::{AreaBlocks, EnergyBlocks};
use crate::energy::model::{svm_linear_cost, CostReport};
use crate::util::matrix::dot;
use crate::util::rng::Rng;

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct LinearSvmParams {
    /// Regularization λ.
    pub lambda: f32,
    /// Pegasos epochs over the training set.
    pub epochs: usize,
}

impl Default for LinearSvmParams {
    fn default() -> Self {
        LinearSvmParams { lambda: 1e-4, epochs: 12 }
    }
}

/// One-vs-rest linear SVM: weight matrix `[n_classes, n_features]` + bias.
#[derive(Clone, Debug)]
pub struct LinearSvm {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub n_features: usize,
    pub n_classes: usize,
}

impl LinearSvm {
    /// Train with Pegasos, one binary problem per class.
    pub fn fit(data: &Split, params: &LinearSvmParams, seed: u64) -> LinearSvm {
        let f = data.n_features;
        let c = data.n_classes;
        let n = data.len();
        let mut w = vec![0.0f32; c * f];
        let mut b = vec![0.0f32; c];
        let lambda = params.lambda;

        // All classes share the same sample order per epoch (cache-friendly
        // single pass updating every class's weight vector).
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..n).collect();
        let mut t: f32 = 1.0;
        for _epoch in 0..params.epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                let x = data.row(i);
                let eta = 1.0 / (lambda * t);
                for class in 0..c {
                    let y = if data.y[i] == class { 1.0f32 } else { -1.0 };
                    let wc = &mut w[class * f..(class + 1) * f];
                    let margin = y * (dot(wc, x) + b[class]);
                    // w ← (1 − ηλ)w  [+ ηy·x if margin < 1]
                    let shrink = 1.0 - eta * lambda;
                    for v in wc.iter_mut() {
                        *v *= shrink;
                    }
                    if margin < 1.0 {
                        let step = eta * y / n as f32 * n as f32; // ηy
                        for (v, &xi) in wc.iter_mut().zip(x) {
                            *v += step * xi;
                        }
                        b[class] += step * 0.1; // unregularized slow bias
                    }
                }
                t += 1.0;
            }
        }
        LinearSvm { w, b, n_features: f, n_classes: c }
    }

    /// Per-class decision scores.
    pub fn scores(&self, x: &[f32]) -> Vec<f32> {
        (0..self.n_classes)
            .map(|c| dot(&self.w[c * self.n_features..(c + 1) * self.n_features], x) + self.b[c])
            .collect()
    }
}

impl Classifier for LinearSvm {
    fn kind(&self) -> ClassifierKind {
        ClassifierKind::SvmLinear
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_proba_batch(&self, x: &[f32], n: usize) -> ProbMatrix {
        batch_from_scores(x, n, self.n_features, self.n_classes, |row| self.scores(row))
    }

    fn cost_report(
        &self,
        _probe: Option<&Split>,
        eb: &EnergyBlocks,
        ab: &AreaBlocks,
    ) -> CostReport {
        svm_linear_cost(self.n_features, self.n_classes, eb, ab)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    #[test]
    fn separable_problem_high_accuracy() {
        // Linearly separable 2-class data: Pegasos should nail it.
        let mut s = Split::new(2, 2);
        let mut rng = Rng::new(1);
        for i in 0..400 {
            let y = i % 2;
            let off = if y == 0 { -2.0 } else { 2.0 };
            s.push(&[off + rng.gen_normal() * 0.3, rng.gen_normal()], y);
        }
        let svm = LinearSvm::fit(&s, &LinearSvmParams::default(), 2);
        assert!(svm.accuracy(&s) > 0.97, "acc {}", svm.accuracy(&s));
    }

    #[test]
    fn multimodal_data_hurts_linear() {
        // The synthetic profiles are multi-cluster: linear SVM should be
        // well below a random forest (this is the paper's SVM_lr column).
        let ds = generate(&DatasetProfile::demo(), 141);
        let svm = LinearSvm::fit(&ds.train, &LinearSvmParams::default(), 3);
        let rf = crate::forest::RandomForest::fit(
            &ds.train,
            &crate::forest::ForestParams::small(),
            3,
        );
        let svm_acc = svm.accuracy(&ds.test);
        let rf_acc = rf.accuracy(&ds.test, crate::forest::VoteMode::Majority);
        assert!(svm_acc > 1.0 / 3.0, "better than chance: {svm_acc}");
        assert!(rf_acc > svm_acc - 0.05, "rf {rf_acc} vs linear {svm_acc}");
    }

    #[test]
    fn deterministic() {
        let ds = generate(&DatasetProfile::demo(), 142);
        let a = LinearSvm::fit(&ds.train, &LinearSvmParams::default(), 7);
        let b = LinearSvm::fit(&ds.train, &LinearSvmParams::default(), 7);
        assert_eq!(a.w, b.w);
    }

    #[test]
    fn cost_report_shape() {
        let ds = generate(&DatasetProfile::demo(), 143);
        let svm = LinearSvm::fit(&ds.train, &LinearSvmParams::default(), 8);
        let r = svm.cost_report(None, &EnergyBlocks::default(), &AreaBlocks::default());
        assert!(r.energy_nj > 0.0 && r.area_mm2 > 0.0);
    }
}
