//! # Field of Groves (FoG) — an energy-efficient random forest
//!
//! Full-system reproduction of *"Field of Groves: An Energy-Efficient
//! Random Forest"* (Takhirov, Wang, Louis, Saligrama, Joshi; 2017).
//!
//! The paper proposes splitting a random forest into **groves** (disjoint
//! subsets of decision trees) arranged in a ring. An input is classified by
//! one grove; if the confidence (difference between the two largest averaged
//! class probabilities) is below a threshold, the partial result **hops** to
//! the next grove. Easy inputs consume one grove's energy; hard inputs more.
//!
//! This crate provides, from scratch:
//!
//! * [`dt`] — CART decision-tree training and a flattened complete-tree
//!   representation shared with the JAX/Pallas compile path.
//! * [`forest`] — bagged random forests (incl. feature-budgeted training).
//! * [`fog`] — the paper's contribution: grove construction (Algorithm 1)
//!   and confidence-gated hop evaluation (Algorithm 2).
//! * [`uarch`] — a cycle-level simulator of the grove micro-architecture
//!   (data queue with `$fr`/`$bk` pointers, DQC, PE, req/ack handshake).
//! * [`energy`] — a 40 nm PPA library, an Aladdin-style design-space
//!   explorer, and per-classifier energy/EDP models.
//! * [`baselines`] — SVM (linear + RBF), MLP and CNN comparators trained
//!   from scratch.
//! * [`data`] — synthetic UCI-profile dataset generators and a CSV loader.
//! * [`runtime`] — a PJRT client that loads the AOT-compiled (JAX/Pallas)
//!   grove kernel from `artifacts/*.hlo.txt` and executes it.
//! * [`coordinator`] — a threaded serving front-end: request router, grove
//!   ring, batching, metrics.
//! * [`experiments`] — harnesses regenerating every table/figure of the
//!   paper's evaluation (Table 1, Figure 4, Figure 5).
//! * [`util`] — self-contained substrates (PRNG, JSON, thread pool, CLI
//!   parsing, bench harness) so the crate has no heavyweight dependencies.

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod dt;
pub mod energy;
pub mod experiments;
pub mod fog;
pub mod forest;
pub mod runtime;
pub mod uarch;
pub mod util;

pub use crate::fog::{FieldOfGroves, FogParams};
pub use crate::forest::{ForestParams, RandomForest};
