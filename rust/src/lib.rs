//! # Field of Groves (FoG) — an energy-efficient random forest
//!
//! Full-system reproduction of *"Field of Groves: An Energy-Efficient
//! Random Forest"* (Takhirov, Wang, Louis, Saligrama, Joshi; 2017).
//!
//! The paper proposes splitting a random forest into **groves** (disjoint
//! subsets of decision trees) arranged in a ring. An input is classified by
//! one grove; if the confidence (difference between the two largest averaged
//! class probabilities) is below a threshold, the partial result **hops** to
//! the next grove. Easy inputs consume one grove's energy; hard inputs more.
//!
//! **`ARCHITECTURE.md`** at the repository root is the cross-module map:
//! the layer diagram, the request path through the sharded serving tier
//! (`ShardRouter` → replica queue → `BatchPlan` → cache fill), and the
//! invariants the conformance suites pin. Start there for the big
//! picture; the module docs below carry the per-layer detail.
//!
//! ## The unified model API
//!
//! Every model family the paper compares — FoG, conventional RF, linear
//! and RBF SVMs, MLP, CNN — sits behind one batch-first interface in
//! [`api`]: [`api::Classifier`] (probability/label batches, accuracy, and
//! a [`energy::model::CostReport`] hook feeding the energy models) and
//! [`api::Estimator`] (config → trained model). Models are constructed by
//! registry name through [`api::ModelSpec`]:
//!
//! ```
//! use fog::api::{Classifier, Estimator, ModelSpec};
//! use fog::data::synthetic::{generate, DatasetProfile};
//! use fog::energy::blocks::{AreaBlocks, EnergyBlocks};
//!
//! let ds = generate(&DatasetProfile::demo(), 42);
//! let spec = ModelSpec::for_shape("rf", ds.n_features(), ds.n_classes())
//!     .expect("registry name")
//!     .fast(); // small budgets for this doc example
//! let model = spec.fit(&ds.train, 42); // Box<dyn Classifier>
//!
//! // Batch-first prediction + accuracy through the trait.
//! let labels = model.predict_batch(&ds.test.x, ds.test.len());
//! assert_eq!(labels.len(), ds.test.len());
//! assert!(model.accuracy(&ds.test) > 0.5);
//!
//! // The same hook the Table-1 energy models consume.
//! let report = model.cost_report(Some(&ds.test), &EnergyBlocks::default(), &AreaBlocks::default());
//! assert!(report.energy_nj > 0.0);
//! ```
//!
//! Registry names: `"fog_opt"`, `"fog_max"`, `"rf"`, `"rf_prob"`,
//! `"svm_lr"`, `"svm_rbf"`, `"mlp"`, `"cnn"` (see [`api::REGISTRY`]).
//!
//! ## Layers
//!
//! * [`api`] — the unified batch-first `Classifier`/`Estimator` interface,
//!   `ModelSpec` builder and name registry described above.
//! * [`exec`] — the SoA compiled-forest engine: [`exec::ForestArena`]
//!   packs every flat tree into contiguous level-major `feat`/`thr`/`leaf`
//!   arrays (per-tree and per-grove offset tables), and
//!   [`exec::BatchPlan`] traverses sample tiles level-synchronously —
//!   the software twin of the hardware grove PE. Every tree-based
//!   prediction path (`RfModel`, the FoG grove ring, budgeted forests,
//!   the coordinator's grove workers) runs on an arena; op counts and
//!   VMEM/sparse-storage accounting derive from its layout. The engine
//!   behind a serving path is pluggable ([`exec::Backend`]):
//!   [`exec::SoftwareBackend`] runs these kernels unchanged, while
//!   [`exec::UarchBackend`] streams the same tiles through the
//!   cycle-level ring simulator for live per-classification cycle and
//!   energy estimates — byte-identical answers either way.
//! * [`dt`] — CART decision-tree training and a flattened complete-tree
//!   representation shared with the JAX/Pallas compile path.
//! * [`forest`] — bagged random forests (incl. feature-budgeted training).
//! * [`fog`] — the paper's contribution: grove construction (Algorithm 1)
//!   and confidence-gated hop evaluation (Algorithm 2); groves are
//!   disjoint tree-range slices of one shared arena.
//! * [`uarch`] — a cycle-level simulator of the grove micro-architecture
//!   (data queue with `$fr`/`$bk` pointers, DQC, PE, req/ack handshake).
//! * [`energy`] — a 40 nm PPA library, an Aladdin-style design-space
//!   explorer, and per-classifier energy/EDP models.
//! * [`baselines`] — SVM (linear + RBF), MLP and CNN comparators trained
//!   from scratch.
//! * [`data`] — synthetic UCI-profile dataset generators and a CSV loader.
//! * [`runtime`] — a PJRT client that loads the AOT-compiled (JAX/Pallas)
//!   grove kernel from `artifacts/*.hlo.txt` and executes it (behind the
//!   `pjrt` cargo feature; a clean-failing stub otherwise).
//! * [`coordinator`] — the threaded serving front-ends: the FoG grove
//!   ring, a generic [`coordinator::ModelServer`] that serves *any*
//!   [`api::Classifier`] trait object with dynamic batching and metrics,
//!   and the scale-out [`coordinator::ShardedServer`] — N replicas of
//!   one model behind a shared [`coordinator::ShardRouter`] and a
//!   quantized [`coordinator::ProbCache`] of probability rows. Every
//!   replica dispatches batches through its resolved [`exec::Backend`]
//!   (`software | uarch`), so `fog serve --backend uarch` reports live
//!   energy-per-classification alongside throughput. On top sits the
//!   multi-model [`coordinator::Fleet`]: several registry models behind
//!   one request path, held to a live [`coordinator::EnergyBudget`]
//!   (shed / downgrade admission — Fig 5 at runtime) and driven by the
//!   seeded open-loop [`coordinator::loadgen`]
//!   (`fog serve --fleet fog_opt,fog_max --loadgen QPS:SECS`).
//! * [`experiments`] — harnesses regenerating every table/figure of the
//!   paper's evaluation (Table 1, Figure 4, Figure 5), dispatching every
//!   model through [`api`].
//! * [`util`] — self-contained substrates (PRNG, JSON, thread pool, CLI
//!   parsing, bench harness, error type) so the crate has no external
//!   dependencies.

pub mod api;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod dt;
pub mod energy;
pub mod exec;
pub mod experiments;
pub mod fog;
pub mod forest;
pub mod runtime;
pub mod uarch;
pub mod util;

pub use crate::api::{Classifier, Estimator, ModelSpec};
pub use crate::fog::{FieldOfGroves, FogParams};
pub use crate::forest::{ForestParams, RandomForest};
