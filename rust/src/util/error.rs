//! Crate-local error type: a string-message error plus `err!` / `bail!` /
//! `ensure!` macros, replacing the `anyhow` dependency (the build
//! environment vendors no external crates).
//!
//! Fallible crate APIs return [`Result`]; conversions exist for the error
//! types that cross module boundaries (`std::io::Error`, the JSON
//! [`ParseError`](crate::util::json::ParseError)) so `?` works unchanged.

use std::fmt;

/// A message-carrying error.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::new(e.to_string())
    }
}

impl From<crate::util::json::ParseError> for Error {
    fn from(e: crate::util::json::ParseError) -> Error {
        Error::new(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::new(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
    }

    #[test]
    fn io_conversion() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/nonexistent/fog")?)
        }
        assert!(read().is_err());
    }
}
