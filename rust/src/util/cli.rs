//! Tiny command-line argument parser: subcommand + `--flag value` /
//! `--flag=value` / boolean `--flag` options, with typed getters.

use std::collections::BTreeMap;

/// Parsed arguments: a positional subcommand list and a flag map.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // --flag value  |  --flag (boolean)
                    let is_value = iter
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if is_value {
                        let v = iter.next().unwrap();
                        out.flags.insert(stripped.to_string(), v);
                    } else {
                        out.flags.insert(stripped.to_string(), "true".to_string());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Parse an `AxB` topology string (e.g. `8x2` = 8 groves × 2 trees).
    pub fn get_topology(&self, key: &str, default: (usize, usize)) -> (usize, usize) {
        self.get(key)
            .and_then(|s| {
                let (a, b) = s.split_once('x')?;
                Some((a.parse().ok()?, b.parse().ok()?))
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table1 --dataset mnist --threshold 0.3 --verbose");
        assert_eq!(a.subcommand(), Some("table1"));
        assert_eq!(a.get("dataset"), Some("mnist"));
        assert_eq!(a.get_f64("threshold", 0.0), 0.3);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --n=17 --name=foo");
        assert_eq!(a.get_usize("n", 0), 17);
        assert_eq!(a.get("name"), Some("foo"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn topology() {
        let a = parse("fig5 --topology 8x2");
        assert_eq!(a.get_topology("topology", (4, 4)), (8, 2));
        assert_eq!(a.get_topology("nope", (4, 4)), (4, 4));
    }

    #[test]
    fn negative_number_as_value() {
        // "--lr -0.5": "-0.5" doesn't start with "--", so it is a value.
        let a = parse("train --lr -0.5");
        assert_eq!(a.get_f64("lr", 0.0), -0.5);
    }
}
