//! Deterministic, seedable PRNG (xoshiro256** + splitmix64 seeding).
//!
//! Every stochastic component in the crate (bagging, feature subsampling,
//! grove start selection, synthetic data generation, weight init) draws from
//! this generator so experiments are bit-reproducible from a single seed.

/// xoshiro256** generator. Small, fast, passes BigCrush; more than adequate
/// for ML sampling workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that low-entropy seeds (0, 1, 2, ...) still
    /// produce well-distributed initial state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. one per tree / per grove).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough for
    /// sampling; exact rejection is overkill here but cheap, so we do it).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        // rejection sampling on the top bits
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; generation cost is irrelevant at our scales).
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = (self.gen_f64()).max(1e-12);
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k>n");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample with replacement (bootstrap).
    pub fn bootstrap(&mut self, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.gen_range(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.gen_range(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn gen_f32_unit_interval() {
        let mut r = Rng::new(4);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = r.gen_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.gen_normal() as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn bootstrap_in_bounds() {
        let mut r = Rng::new(10);
        let bs = r.bootstrap(33);
        assert_eq!(bs.len(), 33);
        assert!(bs.iter().all(|&i| i < 33));
    }
}
