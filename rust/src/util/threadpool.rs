//! Scoped data-parallel helpers built on `std::thread::scope`.
//!
//! Algorithm 2 in the paper is a `parallel for` over inputs; the training
//! loops (per-tree bagging) are embarrassingly parallel too. We provide a
//! chunked parallel-map rather than a general work-stealing pool — the
//! workloads here are uniform enough that static chunking is within a few
//! percent of optimal and keeps the substrate tiny and allocation-free on
//! the hot path.

/// Default worker-thread count at pool construction: respects
/// `FOG_THREADS`, falls back to the available parallelism, and is clamped
/// to `[1, 64]`. Callers that need a *specific* count (determinism tests,
/// benchmark pinning) pass it explicitly to [`par_map_with`] instead of
/// mutating the env var — env mutation races the parallel test harness.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("FOG_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(1, 64)
}

/// Parallel map over `0..n` with the default thread count (see
/// [`num_threads`]): calls `f(i)` for every index and collects the
/// results in order.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_with(num_threads(), n, f)
}

/// [`par_map`] with an explicit worker count (clamped to `[1, 64]`).
/// Results are identical for every worker count — chunking only changes
/// which thread computes which index. Falls back to a sequential loop for
/// small `n`.
pub fn par_map_with<T, F>(n_threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = n_threads.clamp(1, 64).min(n.max(1));
    if workers <= 1 || n < 2 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, slot_chunk) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = w * chunk;
                for (j, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(base + j));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker panicked")).collect()
}

/// Parallel for-each over row-aligned chunks of a row-major buffer:
/// splits `data` (rows of `row_len` elements) into at most
/// `num_threads()` contiguous chunks whose row counts are multiples of
/// `rows_per_block` (the last chunk may be a partial block), and calls
/// `f(first_row, chunk)` on each from its own thread. Lets a tiled
/// kernel write straight into one preallocated output while each worker
/// reuses its scratch across all blocks of its chunk.
pub fn par_row_chunks_mut<T, F>(data: &mut [T], row_len: usize, rows_per_block: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_len > 0, "row_len = 0");
    debug_assert_eq!(data.len() % row_len, 0, "ragged row buffer");
    let n_rows = data.len() / row_len;
    if n_rows == 0 {
        return;
    }
    let block = rows_per_block.max(1);
    let workers = num_threads().min(n_rows.div_ceil(block));
    let chunk_rows = n_rows.div_ceil(workers).div_ceil(block) * block;
    std::thread::scope(|s| {
        for (w, chunk) in data.chunks_mut(chunk_rows * row_len).enumerate() {
            let f = &f;
            s.spawn(move || f(w * chunk_rows, chunk));
        }
    });
}

/// Parallel for-each over mutable chunks of a slice: splits `data` into
/// `num_threads()` contiguous chunks and calls `f(chunk_start, chunk)` on
/// each from its own thread.
pub fn par_chunks_mut<T, F>(data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, c) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(w * chunk, c));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_sequential() {
        let seq: Vec<usize> = (0..1000).map(|i| i * i).collect();
        let par = par_map(1000, |i| i * i);
        assert_eq!(seq, par);
    }

    #[test]
    fn par_map_empty_and_one() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn par_map_with_explicit_counts_agree() {
        let seq: Vec<usize> = (0..257).map(|i| i * 3).collect();
        for workers in [1, 2, 3, 64, 1000] {
            assert_eq!(par_map_with(workers, 257, |i| i * 3), seq, "workers {workers}");
        }
        assert_eq!(par_map_with(0, 4, |i| i), vec![0, 1, 2, 3]); // clamped to 1
    }

    #[test]
    fn par_row_chunks_mut_covers_all_rows() {
        // 53 rows of 3, blocks of 8 rows: chunk boundaries must stay
        // block-aligned and every row must be visited exactly once.
        let mut v = vec![0usize; 53 * 3];
        par_row_chunks_mut(&mut v, 3, 8, |first_row, chunk| {
            assert_eq!(first_row % 8, 0, "chunk start not block-aligned");
            assert_eq!(chunk.len() % 3, 0, "chunk not row-aligned");
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = first_row * 3 + j + 1;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i + 1);
        }
        // Degenerate shapes.
        let mut empty: Vec<usize> = Vec::new();
        par_row_chunks_mut(&mut empty, 4, 8, |_, _| panic!("no rows"));
        let mut one = vec![0usize; 3];
        par_row_chunks_mut(&mut one, 3, 1000, |r, c| {
            assert_eq!((r, c.len()), (0, 3));
        });
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut v = vec![0usize; 503];
        par_chunks_mut(&mut v, |start, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = start + j + 1;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i + 1);
        }
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }
}
