//! Scoped data-parallel helpers built on `std::thread::scope`.
//!
//! Algorithm 2 in the paper is a `parallel for` over inputs; the training
//! loops (per-tree bagging) are embarrassingly parallel too. We provide a
//! chunked parallel-map rather than a general work-stealing pool — the
//! workloads here are uniform enough that static chunking is within a few
//! percent of optimal and keeps the substrate tiny and allocation-free on
//! the hot path.

/// Number of worker threads to use: respects `FOG_THREADS`, defaults to the
/// available parallelism, and is clamped to `[1, 64]`.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("FOG_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(1, 64)
}

/// Parallel map over `0..n`: calls `f(i)` for every index and collects the
/// results in order. Falls back to a sequential loop for small `n`.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n < 2 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, slot_chunk) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = w * chunk;
                for (j, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(base + j));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker panicked")).collect()
}

/// Parallel for-each over mutable chunks of a slice: splits `data` into
/// `num_threads()` contiguous chunks and calls `f(chunk_start, chunk)` on
/// each from its own thread.
pub fn par_chunks_mut<T, F>(data: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let workers = num_threads().min(n);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, c) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(w * chunk, c));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_sequential() {
        let seq: Vec<usize> = (0..1000).map(|i| i * i).collect();
        let par = par_map(1000, |i| i * i);
        assert_eq!(seq, par);
    }

    #[test]
    fn par_map_empty_and_one() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut v = vec![0usize; 503];
        par_chunks_mut(&mut v, |start, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = start + j + 1;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i + 1);
        }
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }
}
