//! Criterion-lite benchmark harness.
//!
//! The build environment has no criterion crate, so `cargo bench` targets
//! (declared `harness = false`) use this module: warmup, fixed-count sample
//! loop, median/MAD reporting, and a machine-readable one-line-per-bench
//! output that EXPERIMENTS.md quotes.

use std::time::{Duration, Instant};

/// One benchmark measurement summary.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: usize,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub mean_ns: f64,
    pub throughput_per_s: Option<f64>,
}

impl Measurement {
    pub fn report(&self) {
        let (val, unit) = humanize_ns(self.median_ns);
        let (madv, madu) = humanize_ns(self.mad_ns);
        match self.throughput_per_s {
            Some(tp) => println!(
                "bench {:<44} {:>10.3} {}  ±{:.2} {}  ({:.1}/s, n={})",
                self.name, val, unit, madv, madu, tp, self.samples
            ),
            None => println!(
                "bench {:<44} {:>10.3} {}  ±{:.2} {}  (n={})",
                self.name, val, unit, madv, madu, self.samples
            ),
        }
    }
}

fn humanize_ns(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "µs")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s ")
    }
}

/// Benchmark runner with warmup and adaptive sample count.
pub struct Bencher {
    /// Minimum measured wall time to spend per benchmark.
    pub min_time: Duration,
    /// Maximum number of samples to record.
    pub max_samples: usize,
    pub results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        // FOG_BENCH_FAST=1 shrinks budgets so `cargo bench` smoke runs fast.
        let fast = std::env::var("FOG_BENCH_FAST").is_ok();
        Bencher {
            min_time: if fast { Duration::from_millis(50) } else { Duration::from_millis(400) },
            max_samples: if fast { 10 } else { 50 },
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Measure `f`, treating one call as one iteration. `items_per_iter`
    /// (if nonzero) adds a throughput figure (items/s).
    pub fn bench<F: FnMut()>(&mut self, name: &str, items_per_iter: usize, mut f: F) {
        // Warmup: one call minimum, until ~10% of budget.
        let warm_budget = self.min_time / 10;
        let t0 = Instant::now();
        loop {
            f();
            if t0.elapsed() >= warm_budget {
                break;
            }
        }
        // Sampling.
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.max_samples);
        let t1 = Instant::now();
        while samples_ns.len() < self.max_samples
            && (t1.elapsed() < self.min_time || samples_ns.len() < 5)
        {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
        }
        let median = crate::util::stats::median(&samples_ns);
        let m = Measurement {
            name: name.to_string(),
            samples: samples_ns.len(),
            median_ns: median,
            mad_ns: crate::util::stats::mad(&samples_ns),
            mean_ns: crate::util::stats::mean(&samples_ns),
            throughput_per_s: if items_per_iter > 0 && median > 0.0 {
                Some(items_per_iter as f64 * 1e9 / median)
            } else {
                None
            },
        };
        m.report();
        self.results.push(m);
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_measurement() {
        let mut b = Bencher { min_time: Duration::from_millis(5), max_samples: 8, results: vec![] };
        let mut acc = 0u64;
        b.bench("noop-ish", 10, || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert_eq!(b.results.len(), 1);
        let m = &b.results[0];
        assert!(m.samples >= 5);
        assert!(m.median_ns >= 0.0);
        assert!(m.throughput_per_s.unwrap() > 0.0);
    }

    #[test]
    fn humanize() {
        assert_eq!(humanize_ns(500.0).1, "ns");
        assert_eq!(humanize_ns(5e4).1, "µs");
        assert_eq!(humanize_ns(5e7).1, "ms");
        assert_eq!(humanize_ns(5e9).1, "s ");
    }
}
