//! Small statistics helpers used by the bench harness and experiment
//! reporters (mean, median, MAD, percentiles, confusion-matrix accuracy).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1); 0.0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (interpolated for even lengths); 0.0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile in `[0, 100]`; 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median absolute deviation (robust spread estimate used by the bench
/// harness to flag noisy measurements).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Classification accuracy between predicted and true labels.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(truth).filter(|(p, t)| p == t).count();
    hits as f64 / pred.len() as f64
}

/// Confusion matrix `[true][pred]` with `n_classes` rows/cols.
pub fn confusion(pred: &[usize], truth: &[usize], n_classes: usize) -> Vec<Vec<usize>> {
    let mut m = vec![vec![0usize; n_classes]; n_classes];
    for (&p, &t) in pred.iter().zip(truth) {
        if t < n_classes && p < n_classes {
            m[t][p] += 1;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert!((percentile(&xs, 95.0) - 95.0).abs() < 1e-9);
    }

    #[test]
    fn spread() {
        assert!(stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) > 0.0);
        assert_eq!(mad(&[1.0, 1.0, 2.0, 2.0, 4.0, 6.0, 9.0]), 1.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn acc_and_confusion() {
        let pred = vec![0, 1, 1, 2];
        let truth = vec![0, 1, 2, 2];
        assert_eq!(accuracy(&pred, &truth), 0.75);
        let cm = confusion(&pred, &truth, 3);
        assert_eq!(cm[2][1], 1);
        assert_eq!(cm[2][2], 1);
        assert_eq!(cm[0][0], 1);
    }
}
