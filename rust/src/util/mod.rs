//! Self-contained substrates: PRNG, JSON, thread pool, CLI parsing, bench
//! harness and small numeric helpers. The build environment vendors only the
//! `xla` crate closure, so every utility a production crate would normally
//! pull from crates.io is implemented here.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod matrix;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Argmax over a float slice; first index wins ties. Empty slices return 0.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// The two largest values of a slice, `(max1, max2)` with `max1 >= max2`.
/// Mirrors the paper's `TwoMaximumValues` subroutine. Slices with fewer than
/// two elements return the element (or 0.0) twice.
pub fn two_max(xs: &[f32]) -> (f32, f32) {
    let mut m1 = f32::NEG_INFINITY;
    let mut m2 = f32::NEG_INFINITY;
    for &v in xs {
        if v > m1 {
            m2 = m1;
            m1 = v;
        } else if v > m2 {
            m2 = v;
        }
    }
    if !m1.is_finite() {
        return (0.0, 0.0);
    }
    if !m2.is_finite() {
        return (m1, m1);
    }
    (m1, m2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.7, 0.2]), 1);
        assert_eq!(argmax(&[0.5, 0.5]), 0); // first wins ties
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn two_max_basic() {
        assert_eq!(two_max(&[0.1, 0.7, 0.2]), (0.7, 0.2));
        assert_eq!(two_max(&[1.0]), (1.0, 1.0));
        assert_eq!(two_max(&[]), (0.0, 0.0));
    }

    #[test]
    fn two_max_with_duplicates() {
        assert_eq!(two_max(&[0.4, 0.4, 0.2]), (0.4, 0.4));
    }
}
