//! Minimal JSON value model, parser and serializer.
//!
//! Used for the artifact manifest exchanged with the python compile path
//! (`artifacts/manifest.json`), forest export files, and experiment result
//! dumps. Supports the full JSON grammar except `\u` surrogate pairs beyond
//! the BMP (not needed for our ASCII manifests, but lone escapes decode).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for artifact-staleness checks in the Makefile.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["k"]` convenience: returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn arr_i64(xs: &[i64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|f| f as f32).collect())
    }
    pub fn to_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|f| f as i64).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{s}'"))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or(ParseError {
                        pos: self.pos,
                        msg: "bad escape".into(),
                    })?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| ParseError { pos: self.pos, msg: "bad utf8".into() })?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| ParseError { pos: self.pos, msg: "bad hex".into() })?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return self.err("unknown escape"),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return self.err("truncated utf8");
                    }
                    let s = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| ParseError { pos: start, msg: "bad utf8".into() })?;
                    out.push_str(s);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError { pos: start, msg: format!("bad number '{s}'") })
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Parse a JSON document.
pub fn parse(s: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing content");
    }
    Ok(v)
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":[1,2.5,-3e2],"b":"hi\nthere","c":null,"d":true,"e":{}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        let v2 = parse(&out).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn nested() {
        let v = parse(r#"[[1,[2,[3]]]]"#).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn get_missing_is_null() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.get("a").as_f64(), Some(1.0));
    }

    #[test]
    fn helper_vectors() {
        let v = Json::arr_f32(&[1.0, 2.0]);
        assert_eq!(v.to_f32_vec().unwrap(), vec![1.0, 2.0]);
        let v = Json::arr_i64(&[3, -4]);
        assert_eq!(v.to_i64_vec().unwrap(), vec![3, -4]);
    }

    #[test]
    fn deterministic_key_order() {
        let a = parse(r#"{"z":1,"a":2}"#).unwrap().to_string();
        let b = parse(r#"{"a":2,"z":1}"#).unwrap().to_string();
        assert_eq!(a, b);
    }
}
