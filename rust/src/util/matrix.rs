//! Dense row-major f32 matrix with the handful of BLAS-1/2/3 operations the
//! baselines (SVM, MLP, CNN) need. Kept deliberately simple; the heavy
//! lifting on the accelerator path happens in the Pallas kernel / PJRT
//! executable, not here.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Gaussian init scaled by `std` (He/Xavier handled by caller).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut crate::util::rng::Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.gen_normal() * std).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// `self @ other` — blocked i-k-j loop ordering for cache friendliness.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        let n = other.cols;
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[k * n..(k + 1) * n];
                for j in 0..n {
                    out_row[j] += a * b_row[j];
                }
            }
        }
        out
    }

    /// `self @ other^T` (common in backprop).
    pub fn matmul_bt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_bt shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a_row = self.row(i);
            for j in 0..other.rows {
                let b_row = other.row(j);
                let mut s = 0.0f32;
                for k in 0..self.cols {
                    s += a_row[k] * b_row[k];
                }
                out.set(i, j, s);
            }
        }
        out
    }

    /// `self^T @ other` (gradient wrt weights).
    pub fn matmul_at(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_at shape mismatch");
        let mut out = Matrix::zeros(self.cols, other.cols);
        for r in 0..self.rows {
            let a_row = self.row(r);
            let b_row = other.row(r);
            for (i, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (j, &b) in b_row.iter().enumerate() {
                    out_row[j] += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.get(r, c));
            }
        }
        out
    }

    pub fn add_row_vector(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (x, &b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// `self += s * other` (axpy).
    pub fn axpy(&mut self, s: f32, other: &Matrix) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }
}

/// Dot product of two slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Squared Euclidean distance.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Numerically-stable softmax over each row, in place.
pub fn softmax_rows(m: &mut Matrix) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        let inv = 1.0 / sum.max(1e-12);
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_bt_equals_matmul_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(3, 5, 1.0, &mut rng);
        let b = Matrix::randn(4, 5, 1.0, &mut rng);
        let c1 = a.matmul_bt(&b);
        let c2 = a.matmul(&b.transpose());
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_at_equals_transpose_matmul() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(6, 3, 1.0, &mut rng);
        let b = Matrix::randn(6, 4, 1.0, &mut rng);
        let c1 = a.matmul_at(&b);
        let c2 = a.transpose().matmul(&b);
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 100.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(r).iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn dot_and_dist() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(sq_dist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn bias_and_axpy() {
        let mut m = Matrix::zeros(2, 2);
        m.add_row_vector(&[1.0, 2.0]);
        assert_eq!(m.data, vec![1.0, 2.0, 1.0, 2.0]);
        let other = Matrix::from_vec(2, 2, vec![1.0; 4]);
        m.axpy(0.5, &other);
        assert_eq!(m.data, vec![1.5, 2.5, 1.5, 2.5]);
    }
}
