//! Multi-output classification (paper footnote 1 + Algorithm 2's MaxDiff
//! subroutine note): when a sample carries several label heads, the
//! stopping confidence is the **minimum** of the per-head MaxDiffs — the
//! ensemble must be confident about *every* output before releasing the
//! result ("minimum difference of the maximum values").
//!
//! Heads are modelled as disjoint slices of the class axis: a forest
//! trained on the cartesian label space emits one concatenated
//! distribution; `OutputLayout` says where each head begins and ends.
//!
//! Paper anchor: **§3.2 footnote 1** and the MaxDiff subroutine note of
//! **Algorithm 2** — the only part of the paper's evaluation protocol
//! that generalizes beyond single-label classification.

use super::confidence::max_diff;

/// Partition of the class axis into output heads.
#[derive(Clone, Debug)]
pub struct OutputLayout {
    /// Head boundaries: head `h` covers `bounds[h]..bounds[h+1]`.
    bounds: Vec<usize>,
}

impl OutputLayout {
    /// Single-head layout over `n_classes` (the default everywhere else).
    pub fn single(n_classes: usize) -> OutputLayout {
        OutputLayout { bounds: vec![0, n_classes] }
    }

    /// Heads of the given sizes.
    pub fn heads(sizes: &[usize]) -> OutputLayout {
        assert!(!sizes.is_empty());
        assert!(sizes.iter().all(|&s| s >= 2), "head needs >= 2 classes");
        let mut bounds = vec![0usize];
        for &s in sizes {
            bounds.push(bounds.last().unwrap() + s);
        }
        OutputLayout { bounds }
    }

    pub fn n_heads(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn total_classes(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Slice of `prob` for head `h`.
    pub fn head<'a>(&self, prob: &'a [f32], h: usize) -> &'a [f32] {
        &prob[self.bounds[h]..self.bounds[h + 1]]
    }

    /// The paper's multi-output confidence: min over heads of MaxDiff.
    pub fn confidence(&self, prob: &[f32]) -> f32 {
        debug_assert_eq!(prob.len(), self.total_classes());
        (0..self.n_heads())
            .map(|h| max_diff(self.head(prob, h)))
            .fold(f32::INFINITY, f32::min)
    }

    /// Per-head argmax labels.
    pub fn labels(&self, prob: &[f32]) -> Vec<usize> {
        (0..self.n_heads())
            .map(|h| crate::util::argmax(self.head(prob, h)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_head_equals_plain_maxdiff() {
        let layout = OutputLayout::single(4);
        let p = [0.1f32, 0.5, 0.3, 0.1];
        assert!((layout.confidence(&p) - max_diff(&p)).abs() < 1e-7);
        assert_eq!(layout.labels(&p), vec![1]);
    }

    #[test]
    fn min_over_heads() {
        // Head A confident (0.8 gap), head B not (0.1 gap) → min = 0.1.
        let layout = OutputLayout::heads(&[2, 3]);
        let p = [0.9f32, 0.1, 0.4, 0.3, 0.3];
        assert!((layout.confidence(&p) - 0.1).abs() < 1e-6);
        assert_eq!(layout.labels(&p), vec![0, 0]);
    }

    #[test]
    fn geometry() {
        let layout = OutputLayout::heads(&[3, 2, 4]);
        assert_eq!(layout.n_heads(), 3);
        assert_eq!(layout.total_classes(), 9);
        let p: Vec<f32> = (0..9).map(|i| i as f32).collect();
        assert_eq!(layout.head(&p, 1), &[3.0, 4.0]);
        assert_eq!(layout.head(&p, 2), &[5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    #[should_panic]
    fn single_class_head_rejected() {
        OutputLayout::heads(&[3, 1]);
    }
}
