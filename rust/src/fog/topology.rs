//! FoG topology enumeration — the x-axis of the paper's Figure 4.
//!
//! A topology `a×b` is `a` groves of `b` trees; the product is the total
//! forest size. Figure 4 sweeps all factorizations of a fixed tree count
//! (the paper's worked example uses 16 trees: 1×16, 2×8, 4×4, 8×2, 16×1)
//! and reports accuracy and EDP for each.

/// All `(n_groves, trees_per_grove)` factorizations of `n_trees`, sorted
/// by grove count ascending.
pub fn factorizations(n_trees: usize) -> Vec<(usize, usize)> {
    assert!(n_trees > 0);
    let mut out = Vec::new();
    for a in 1..=n_trees {
        if n_trees % a == 0 {
            out.push((a, n_trees / a));
        }
    }
    out
}

/// Format a topology as the paper writes it (`8x2`).
pub fn format_topology(t: (usize, usize)) -> String {
    format!("{}x{}", t.0, t.1)
}

/// Parse `8x2` into `(8, 2)`.
pub fn parse_topology(s: &str) -> Option<(usize, usize)> {
    let (a, b) = s.split_once('x')?;
    Some((a.trim().parse().ok()?, b.trim().parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_trees_five_topologies() {
        let f = factorizations(16);
        assert_eq!(f, vec![(1, 16), (2, 8), (4, 4), (8, 2), (16, 1)]);
    }

    #[test]
    fn prime_count_two_topologies() {
        assert_eq!(factorizations(7), vec![(1, 7), (7, 1)]);
    }

    #[test]
    fn products_match() {
        for (a, b) in factorizations(24) {
            assert_eq!(a * b, 24);
        }
    }

    #[test]
    fn format_and_parse_roundtrip() {
        for t in factorizations(16) {
            assert_eq!(parse_topology(&format_topology(t)), Some(t));
        }
        assert_eq!(parse_topology("bad"), None);
    }
}
