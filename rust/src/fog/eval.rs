//! Algorithm 2 — evaluating the Field of Groves classifier.
//!
//! For every input: start at a random grove (avoiding bias), accumulate
//! grove probability estimates around the ring, and stop as soon as the
//! normalized distribution's `MaxDiff` confidence reaches the threshold or
//! `max_hops` groves have contributed. The per-input hop count is the
//! quantity that makes FoG energy-proportional: easy inputs stop after one
//! grove. Hop evaluation composes with a second, orthogonal work-saver:
//! each grove walk runs on the shared arena's live-depth early exit
//! (`exec::ForestArena`), so confidence gating prunes *groves* while the
//! kernel prunes each tree's dead padded *levels* — both byte-identical
//! to full evaluation, both pure comparator-op savings (paper §4,
//! Table 1).

use super::confidence::max_diff;
use super::split::FieldOfGroves;
use crate::util::rng::Rng;
use crate::util::threadpool::par_map;

/// Content-derived start grove (Algorithm 2 line 3, batch-position
/// independent): hash the input's feature bit patterns under `seed`, so
/// per-sample, batched and simulated evaluations of the same row all
/// draw the same grove. Shared by [`crate::api::FogModel`] and the
/// execution backends in [`crate::exec::backend`].
pub fn content_start_grove(seed: u64, row: &[f32], n_groves: usize) -> usize {
    let mut h = seed ^ 0x9E3779B97F4A7C15;
    for &v in row {
        h = (h ^ v.to_bits() as u64).wrapping_mul(0x100000001B3);
    }
    Rng::new(h).gen_range(n_groves)
}

/// Run-time tunables (paper §3.2.2 "Run-time Tunability").
#[derive(Clone, Copy, Debug)]
pub struct FogParams {
    /// Stopping threshold `0 < thresh ≤ 1`; `≥ 1` forces full evaluation
    /// (the paper's FoG_max configuration).
    pub threshold: f32,
    /// Upper limit on contributing groves; clamped to `n_groves`.
    pub max_hops: usize,
    /// Seed for the random starting grove of each input.
    pub seed: u64,
}

impl FogParams {
    /// FoG_max: threshold at maximum forces every grove to contribute,
    /// making FoG behave exactly like the underlying RF (§4.2).
    pub fn fog_max(n_groves: usize) -> FogParams {
        FogParams { threshold: 1.0 + 1e-6, max_hops: n_groves, seed: 0 }
    }
}

/// Per-input evaluation record.
#[derive(Clone, Debug)]
pub struct InputOutcome {
    /// Normalized probability distribution at stop time.
    pub prob: Vec<f32>,
    /// Number of groves that contributed (≥ 1).
    pub hops: usize,
    /// Predicted label.
    pub label: usize,
    /// Confidence at stop time.
    pub confidence: f32,
}

/// Batch evaluation result.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub outcomes: Vec<InputOutcome>,
    pub n_groves: usize,
}

impl EvalResult {
    pub fn predictions(&self) -> Vec<usize> {
        self.outcomes.iter().map(|o| o.label).collect()
    }

    pub fn accuracy(&self, truth: &[usize]) -> f64 {
        crate::util::stats::accuracy(&self.predictions(), truth)
    }

    /// Mean groves consulted per input — proportional to FoG energy.
    pub fn avg_hops(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(|o| o.hops as f64).sum::<f64>() / self.outcomes.len() as f64
    }

    /// Histogram of hop counts (1..=n_groves).
    pub fn hop_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.n_groves + 1];
        for o in &self.outcomes {
            h[o.hops.min(self.n_groves)] += 1;
        }
        h
    }
}

impl FieldOfGroves {
    /// Algorithm 2 over a row-major batch `x: [n, n_features]`. The
    /// paper's `parallel for` is realized with the thread pool; each input
    /// draws its starting grove from a per-input deterministic stream so
    /// results are independent of thread scheduling.
    pub fn evaluate(&self, x: &[f32], params: &FogParams) -> EvalResult {
        let f = self.n_features;
        assert_eq!(x.len() % f, 0, "ragged batch");
        let n = x.len() / f;
        let n_groves = self.n_groves();
        let max_hops = params.max_hops.clamp(1, n_groves);

        let outcomes = par_map(n, |i| {
            let mut rng = Rng::new(params.seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let start = rng.gen_range(n_groves); // line 3: random start grove
            self.evaluate_one(&x[i * f..(i + 1) * f], start, params.threshold, max_hops)
        });
        EvalResult { outcomes, n_groves }
    }

    /// Algorithm 2 body for a single input with an explicit start grove.
    pub fn evaluate_one(
        &self,
        x: &[f32],
        start: usize,
        threshold: f32,
        max_hops: usize,
    ) -> InputOutcome {
        let n_groves = self.n_groves();
        let max_hops = max_hops.clamp(1, n_groves);
        let mut prob = vec![0.0f32; self.n_classes]; // line 4
        let mut norm = vec![0.0f32; self.n_classes];
        let mut hops = 0usize;
        for j in 0..max_hops {
            let index = (start + j) % n_groves; // line 6
            self.groves[index].accumulate_proba(x, &mut prob); // line 7
            hops = j + 1;
            let inv = 1.0 / hops as f32; // line 8
            for (nm, &p) in norm.iter_mut().zip(&prob) {
                *nm = p * inv;
            }
            if max_diff(&norm) >= threshold {
                break; // line 9-10
            }
        }
        let label = crate::util::argmax(&norm);
        let confidence = max_diff(&norm);
        InputOutcome { prob: norm, hops, label, confidence }
    }

    /// Full-forest reference: every grove contributes (what FoG_max
    /// computes); equals the RF probability average over all trees when
    /// all groves have equal size.
    pub fn full_proba(&self, x: &[f32]) -> Vec<f32> {
        let mut prob = vec![0.0f32; self.n_classes];
        for g in &self.groves {
            g.accumulate_proba(x, &mut prob);
        }
        let inv = 1.0 / self.n_groves() as f32;
        prob.iter_mut().for_each(|p| *p *= inv);
        prob
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest, VoteMode};

    fn setup() -> (FieldOfGroves, crate::data::Dataset, RandomForest) {
        let ds = generate(&DatasetProfile::demo(), 101);
        let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 1);
        let fog = FieldOfGroves::from_forest(&rf, 4); // 4x4
        (fog, ds, rf)
    }

    #[test]
    fn threshold_one_visits_all_groves() {
        let (fog, ds, _) = setup();
        let params = FogParams::fog_max(fog.n_groves());
        let res = fog.evaluate(&ds.test.x, &params);
        assert!(res.outcomes.iter().all(|o| o.hops == fog.n_groves()));
    }

    #[test]
    fn fog_max_matches_rf_prob_average() {
        let (fog, ds, rf) = setup();
        let params = FogParams::fog_max(fog.n_groves());
        let res = fog.evaluate(&ds.test.x, &params);
        for (i, o) in res.outcomes.iter().enumerate().take(50) {
            let rf_p = rf.predict_proba(ds.test.row(i));
            for (a, b) in o.prob.iter().zip(&rf_p) {
                assert!((a - b).abs() < 1e-5, "{:?} vs {:?}", o.prob, rf_p);
            }
        }
    }

    #[test]
    fn zero_threshold_single_hop() {
        let (fog, ds, _) = setup();
        let params = FogParams { threshold: 0.0, max_hops: 4, seed: 2 };
        let res = fog.evaluate(&ds.test.x, &params);
        assert!(res.outcomes.iter().all(|o| o.hops == 1));
        assert!((res.avg_hops() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn hops_monotone_in_threshold() {
        let (fog, ds, _) = setup();
        let mut last = 0.0;
        for thr in [0.0f32, 0.2, 0.4, 0.6, 0.8, 1.01] {
            let params = FogParams { threshold: thr, max_hops: 4, seed: 3 };
            let res = fog.evaluate(&ds.test.x, &params);
            let h = res.avg_hops();
            assert!(h + 1e-9 >= last, "thr {thr}: hops {h} < {last}");
            last = h;
        }
    }

    #[test]
    fn max_hops_respected() {
        let (fog, ds, _) = setup();
        let params = FogParams { threshold: 2.0, max_hops: 2, seed: 4 };
        let res = fog.evaluate(&ds.test.x, &params);
        assert!(res.outcomes.iter().all(|o| o.hops <= 2));
    }

    #[test]
    fn accuracy_reasonable_and_close_to_rf() {
        let (fog, ds, rf) = setup();
        let rf_acc = rf.accuracy(&ds.test, VoteMode::ProbAverage);
        let params = FogParams { threshold: 0.5, max_hops: 4, seed: 5 };
        let res = fog.evaluate(&ds.test.x, &params);
        let fog_acc = res.accuracy(&ds.test.y);
        assert!(fog_acc > rf_acc - 0.15, "fog {fog_acc} rf {rf_acc}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (fog, ds, _) = setup();
        let params = FogParams { threshold: 0.3, max_hops: 4, seed: 6 };
        let a = fog.evaluate(&ds.test.x, &params);
        let b = fog.evaluate(&ds.test.x, &params);
        assert_eq!(a.predictions(), b.predictions());
        assert_eq!(a.avg_hops(), b.avg_hops());
    }

    #[test]
    fn hop_histogram_sums_to_n() {
        let (fog, ds, _) = setup();
        let params = FogParams { threshold: 0.4, max_hops: 4, seed: 7 };
        let res = fog.evaluate(&ds.test.x, &params);
        let h = res.hop_histogram();
        assert_eq!(h.iter().sum::<usize>(), ds.test.len());
        assert_eq!(h[0], 0, "no input can take zero hops");
    }

    #[test]
    fn probabilities_normalized_at_stop() {
        let (fog, ds, _) = setup();
        let params = FogParams { threshold: 0.35, max_hops: 4, seed: 8 };
        let res = fog.evaluate(&ds.test.x, &params);
        for o in res.outcomes.iter().take(100) {
            let s: f32 = o.prob.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "sum {s}");
        }
    }
}
