//! Field of Groves — the paper's contribution.
//!
//! * [`grove`] — a grove: a disjoint subset of the forest's trees that
//!   produces a class-probability estimate.
//! * [`confidence`] — the `MaxDiff` confidence score (Algorithm 2's
//!   subroutine, including the multi-output `Min` variant of footnote 1).
//! * [`split`] — Algorithm 1: split a pre-trained RF into groves.
//! * [`eval`] — Algorithm 2: confidence-gated hop evaluation.
//! * [`topology`] — enumerate `a×b` factorizations (Figure 4's axis).
//! * [`tuner`] — threshold sweeps and the accuracy-optimal operating
//!   point (the paper's FoG_opt).

pub mod confidence;
pub mod dropout;
pub mod eval;
pub mod grove;
pub mod multi_output;
pub mod split;
pub mod topology;
pub mod tuner;

pub use eval::{content_start_grove, EvalResult, FogParams};
pub use grove::Grove;
pub use split::FieldOfGroves;
