//! `MaxDiff` — the paper's confidence score (Algorithm 2, lines 16–19).
//!
//! Confidence of a (normalized) probability array is the difference between
//! its two largest values: a grove that answers `{0.32, 0.35, 0.33}` is
//! nearly clueless (0.02), one that answers `{0.9, 0.05, 0.05}` is sure
//! (0.85). For multi-output classification the paper takes the **minimum**
//! of the per-output differences ("minimum difference of maximum values",
//! footnote 1) — the ensemble must be confident about *every* output.
//!
//! The same margin also drives the serving tier's **adaptive early-exit**
//! mode (Daghero et al., "Dynamic Decision Tree Ensembles", arXiv
//! 2205.13838): the batch kernel
//! ([`BatchPlan::with_adaptive`](crate::exec::BatchPlan::with_adaptive))
//! evaluates [`max_diff`] on a sample's *running* tree-vote average and
//! stops accumulating once it reaches the threshold. Exit uses the same
//! `>=` comparison as Algorithm 2 line 9, so a margin landing exactly on
//! the threshold exits deterministically, and raising the threshold can
//! only move a sample's exit later (the margin sequence per sample is
//! fixed) — both properties are pinned by the tests below and
//! `rust/tests/adaptive.rs`.

use crate::util::two_max;

/// Confidence of one probability array.
#[inline]
pub fn max_diff(prob: &[f32]) -> f32 {
    let (m1, m2) = two_max(prob);
    (m1 - m2).abs()
}

/// Multi-output confidence: minimum `max_diff` across outputs, where
/// `probs` holds one probability array per output head.
pub fn max_diff_multi(probs: &[&[f32]]) -> f32 {
    probs
        .iter()
        .map(|p| max_diff(p))
        .fold(f32::INFINITY, f32::min)
        .min(f32::MAX)
}

/// True when the confidence meets the stopping threshold (Algorithm 2,
/// line 9: `MaxDiff(prob_norm) >= thresh`).
#[inline]
pub fn confident(prob: &[f32], threshold: f32) -> bool {
    max_diff(prob) >= threshold
}

/// [`max_diff`] with input validation for untrusted probability rows
/// (request ingress, test fixtures): rejects empty rows and rows with a
/// non-finite entry with a friendly message instead of silently
/// propagating a NaN margin into an exit decision.
pub fn checked_max_diff(prob: &[f32]) -> crate::util::error::Result<f32> {
    crate::ensure!(
        !prob.is_empty(),
        "confidence undefined for an empty probability row"
    );
    if let Some((i, v)) = prob.iter().enumerate().find(|(_, v)| !v.is_finite()) {
        crate::bail!(
            "probability row is degenerate: entry {i} is {v} (every entry must be finite)"
        );
    }
    Ok(max_diff(prob))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worked_example() {
        // §3.2.2: G0 returns {0.32, 0.35, 0.33} → confidence 0.02 < 0.1.
        let g0 = [0.32f32, 0.35, 0.33];
        assert!((max_diff(&g0) - 0.02).abs() < 1e-6);
        assert!(!confident(&g0, 0.1));
        // After averaging with G1: {0.3, 0.4, 0.3} → 0.1 ≥ 0.1 → done.
        // (f32 rounding makes the diff 0.09999999…, so compare with an
        // epsilon-adjusted threshold as the fixed-point hardware would.)
        let avg = [0.3f32, 0.4, 0.3];
        assert!((max_diff(&avg) - 0.1).abs() < 1e-6);
        assert!(confident(&avg, 0.1 - 1e-6));
    }

    #[test]
    fn certain_distribution() {
        assert!((max_diff(&[1.0, 0.0, 0.0]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn uniform_distribution_zero() {
        assert!(max_diff(&[0.25; 4]) < 1e-6);
    }

    #[test]
    fn multi_output_takes_min() {
        let out_a = [0.9f32, 0.1]; // diff 0.8
        let out_b = [0.55f32, 0.45]; // diff 0.1
        let c = max_diff_multi(&[&out_a, &out_b]);
        assert!((c - 0.1).abs() < 1e-6);
    }

    #[test]
    fn two_class_edge() {
        assert!((max_diff(&[0.7, 0.3]) - 0.4).abs() < 1e-6);
        // single-class degenerate array: confidence 0 (max1 == max2)
        assert_eq!(max_diff(&[1.0]), 0.0);
    }

    #[test]
    fn threshold_tie_exits_deterministically() {
        // Algorithm 2 line 9 is `>=`: a margin landing *exactly* on the
        // threshold is confident — every run, every backend. Exact f32
        // values (0.75 - 0.25 = 0.5 exactly) make this a true tie.
        let row = [0.75f32, 0.25];
        assert_eq!(max_diff(&row), 0.5);
        assert!(confident(&row, 0.5), "exact tie must exit");
        assert!(!confident(&row, f32::from_bits(0.5f32.to_bits() + 1)));
        for _ in 0..3 {
            assert!(confident(&row, 0.5), "tie resolution must be deterministic");
        }
    }

    #[test]
    fn exit_index_monotone_in_threshold() {
        // The property the adaptive kernel leans on: for a fixed margin
        // sequence, the first index where `confident` holds never moves
        // *earlier* as the threshold rises — raising `t` can only
        // increase trees evaluated.
        let margins: Vec<[f32; 2]> = [0.1f32, 0.3, 0.25, 0.6, 0.8, 0.95]
            .iter()
            .map(|&d| [(1.0 + d) / 2.0, (1.0 - d) / 2.0])
            .collect();
        let exit_at = |t: f32| margins.iter().position(|m| confident(m, t));
        let mut last = 0usize;
        for t in [0.05f32, 0.2, 0.4, 0.7, 0.9] {
            let k = exit_at(t).expect("grid tops out below the max margin");
            assert!(k >= last, "t {t}: exit moved earlier ({k} < {last})");
            last = k;
        }
        assert_eq!(exit_at(0.99), None, "unreachable threshold must never exit");
    }

    #[test]
    fn checked_max_diff_rejects_degenerate_rows() {
        // Friendly errors, not NaN margins, for untrusted rows.
        let e = checked_max_diff(&[]).unwrap_err();
        assert!(e.to_string().contains("empty"), "unhelpful message: {e}");
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let e = checked_max_diff(&[0.5, bad, 0.2]).unwrap_err();
            assert!(e.to_string().contains("entry 1"), "unhelpful message: {e}");
        }
        // The happy path is exactly max_diff.
        let row = [0.32f32, 0.35, 0.33];
        assert_eq!(checked_max_diff(&row).unwrap(), max_diff(&row));
    }
}
