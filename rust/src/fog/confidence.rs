//! `MaxDiff` — the paper's confidence score (Algorithm 2, lines 16–19).
//!
//! Confidence of a (normalized) probability array is the difference between
//! its two largest values: a grove that answers `{0.32, 0.35, 0.33}` is
//! nearly clueless (0.02), one that answers `{0.9, 0.05, 0.05}` is sure
//! (0.85). For multi-output classification the paper takes the **minimum**
//! of the per-output differences ("minimum difference of maximum values",
//! footnote 1) — the ensemble must be confident about *every* output.

use crate::util::two_max;

/// Confidence of one probability array.
#[inline]
pub fn max_diff(prob: &[f32]) -> f32 {
    let (m1, m2) = two_max(prob);
    (m1 - m2).abs()
}

/// Multi-output confidence: minimum `max_diff` across outputs, where
/// `probs` holds one probability array per output head.
pub fn max_diff_multi(probs: &[&[f32]]) -> f32 {
    probs
        .iter()
        .map(|p| max_diff(p))
        .fold(f32::INFINITY, f32::min)
        .min(f32::MAX)
}

/// True when the confidence meets the stopping threshold (Algorithm 2,
/// line 9: `MaxDiff(prob_norm) >= thresh`).
#[inline]
pub fn confident(prob: &[f32], threshold: f32) -> bool {
    max_diff(prob) >= threshold
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_worked_example() {
        // §3.2.2: G0 returns {0.32, 0.35, 0.33} → confidence 0.02 < 0.1.
        let g0 = [0.32f32, 0.35, 0.33];
        assert!((max_diff(&g0) - 0.02).abs() < 1e-6);
        assert!(!confident(&g0, 0.1));
        // After averaging with G1: {0.3, 0.4, 0.3} → 0.1 ≥ 0.1 → done.
        // (f32 rounding makes the diff 0.09999999…, so compare with an
        // epsilon-adjusted threshold as the fixed-point hardware would.)
        let avg = [0.3f32, 0.4, 0.3];
        assert!((max_diff(&avg) - 0.1).abs() < 1e-6);
        assert!(confident(&avg, 0.1 - 1e-6));
    }

    #[test]
    fn certain_distribution() {
        assert!((max_diff(&[1.0, 0.0, 0.0]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn uniform_distribution_zero() {
        assert!(max_diff(&[0.25; 4]) < 1e-6);
    }

    #[test]
    fn multi_output_takes_min() {
        let out_a = [0.9f32, 0.1]; // diff 0.8
        let out_b = [0.55f32, 0.45]; // diff 0.1
        let c = max_diff_multi(&[&out_a, &out_b]);
        assert!((c - 0.1).abs() < 1e-6);
    }

    #[test]
    fn two_class_edge() {
        assert!((max_diff(&[0.7, 0.3]) - 0.4).abs() < 1e-6);
        // single-class degenerate array: confidence 0 (max1 == max2)
        assert_eq!(max_diff(&[1.0]), 0.0);
    }
}
