//! Grove/tree disabling — the paper's graceful-degradation claim.
//!
//! §3.1: "Turning off DT blocks generally leads to a graceful degradation
//! of accuracy, as the predicted label for a new test example is
//! independent [per tree], in contrast to CNN and MLP where each node is
//! connected to many other nodes." This module makes that claim testable:
//! disable a subset of groves (power-gated tiles) or individual trees and
//! re-evaluate; the ring simply skips dead groves when forwarding.
//!
//! Paper anchor: **§3.1**'s graceful-degradation argument (no figure in
//! the paper quantifies it; the `ablate` experiment's dropout curve is
//! this reproduction's version of that missing plot).

use super::eval::{EvalResult, FogParams};
use super::split::FieldOfGroves;
use crate::dt::FlatTree;
use crate::util::rng::Rng;
use std::sync::Arc;

impl FieldOfGroves {
    /// A copy of this FoG with the given groves removed (power-gated
    /// tiles are skipped by the ring; evaluation-wise they simply don't
    /// exist). The surviving groves keep slicing the *same* shared arena
    /// — gating a tile moves no tree storage. Panics if all groves would
    /// be disabled.
    pub fn with_groves_disabled(&self, disabled: &[usize]) -> FieldOfGroves {
        let groves: Vec<_> = self
            .groves
            .iter()
            .enumerate()
            .filter(|(i, _)| !disabled.contains(i))
            .map(|(_, g)| g.clone())
            .collect();
        assert!(!groves.is_empty(), "all groves disabled");
        FieldOfGroves {
            groves,
            n_features: self.n_features,
            n_classes: self.n_classes,
            depth: self.depth,
            arena: Arc::clone(&self.arena),
        }
    }

    /// A copy with `fraction` of all trees removed at random (deterministic
    /// in `seed`); empty groves are dropped. Models random DT-block
    /// failures rather than whole-tile gating.
    pub fn with_tree_dropout(&self, fraction: f64, seed: u64) -> FieldOfGroves {
        assert!((0.0..1.0).contains(&fraction));
        let mut rng = Rng::new(seed);
        let total: usize = self.groves.iter().map(|g| g.n_trees()).sum();
        let drop = ((total as f64) * fraction).round() as usize;
        let mut kill: Vec<usize> = rng.sample_indices(total, drop.min(total - 1));
        kill.sort_unstable();
        let mut groups: Vec<Vec<FlatTree>> = Vec::new();
        let mut idx = 0usize;
        for g in &self.groves {
            let mut trees = Vec::new();
            for i in 0..g.n_trees() {
                let dead = kill.binary_search(&idx).is_ok();
                idx += 1;
                if !dead {
                    trees.push(g.tree(i));
                }
            }
            if !trees.is_empty() {
                groups.push(trees);
            }
        }
        assert!(!groups.is_empty());
        // Survivors are re-packed into a fresh shared arena.
        FieldOfGroves::from_groves(groups)
    }
}

/// Accuracy as a function of disabled-grove count (the degradation curve).
pub fn degradation_curve(
    fog: &FieldOfGroves,
    x: &[f32],
    y: &[usize],
    params: &FogParams,
    seed: u64,
) -> Vec<(usize, f64)> {
    let mut rng = Rng::new(seed);
    let n = fog.n_groves();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut out = Vec::with_capacity(n);
    for k in 0..n {
        let disabled = &order[..k];
        let sub = fog.with_groves_disabled(disabled);
        let res: EvalResult = sub.evaluate(x, params);
        out.push((k, res.accuracy(y)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest};

    fn setup() -> (FieldOfGroves, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 211);
        let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 1);
        (FieldOfGroves::from_forest(&rf, 2), ds) // 8 groves of 2
    }

    #[test]
    fn disabling_groves_degrades_gracefully() {
        let (fog, ds) = setup();
        let params = FogParams { threshold: 0.5, max_hops: 8, seed: 2 };
        let curve = degradation_curve(&fog, &ds.test.x, &ds.test.y, &params, 3);
        assert_eq!(curve.len(), 8);
        let full = curve[0].1;
        let half = curve[4].1;
        // Half the groves gone: accuracy degrades but stays usable — the
        // paper's "graceful" claim (no cliff to chance level).
        assert!(full > 0.7, "full acc {full}");
        assert!(half > full - 0.25, "half {half} vs full {full}");
        assert!(half > 1.5 / 3.0, "half {half} should beat chance comfortably");
    }

    #[test]
    fn tree_dropout_partitions_shrink() {
        let (fog, _) = setup();
        let dropped = fog.with_tree_dropout(0.25, 4);
        let total: usize = dropped.groves.iter().map(|g| g.n_trees()).sum();
        assert_eq!(total, 12); // 16 * 0.75
    }

    #[test]
    fn tree_dropout_accuracy_degrades_smoothly() {
        let (fog, ds) = setup();
        let params = FogParams { threshold: 0.5, max_hops: 8, seed: 5 };
        let full = fog.evaluate(&ds.test.x, &params).accuracy(&ds.test.y);
        let half = fog
            .with_tree_dropout(0.5, 6)
            .evaluate(&ds.test.x, &params)
            .accuracy(&ds.test.y);
        assert!(half > full - 0.3, "half {half} vs full {full}");
    }

    #[test]
    #[should_panic]
    fn disabling_everything_panics() {
        let (fog, _) = setup();
        fog.with_groves_disabled(&(0..8).collect::<Vec<_>>());
    }

    #[test]
    fn disabled_grove_set_respected() {
        let (fog, _) = setup();
        let sub = fog.with_groves_disabled(&[0, 3, 7]);
        assert_eq!(sub.n_groves(), 5);
        sub.validate_partition(10).unwrap(); // 5 groves × 2 trees
    }
}
