//! Algorithm 1 — constructing the Field of Groves classifier.
//!
//! `GCTrain(n, k, X, y)`: pre-train a random forest of `n` estimators,
//! then `Split(RF, k)`: carve its trees into consecutive groups of `k`
//! (the paper splits randomly into non-overlapping subsets; since bagged
//! trees are exchangeable, consecutive grouping after an optional shuffle
//! is the same distribution — we shuffle for fidelity).
//!
//! Paper anchor: **§3.1, Algorithm 1**; the `a×b` topologies this builds
//! are the x-axis of **Figure 4** (grove-count sweep at fixed forest
//! size).

use super::grove::Grove;
use crate::data::Split as DataSplit;
use crate::dt::FlatTree;
use crate::exec::ForestArena;
use crate::forest::{ForestParams, RandomForest};
use crate::util::rng::Rng;
use std::sync::Arc;

/// A field of groves: the forest's trees partitioned into groves arranged
/// in a ring (grove `i` hands off to grove `(i+1) mod n`). All trees live
/// in one shared [`ForestArena`]; every grove is a disjoint tree-range
/// slice of it, so hop traversal and batched evaluation walk the same
/// level-major arrays.
#[derive(Clone, Debug)]
pub struct FieldOfGroves {
    pub groves: Vec<Grove>,
    pub n_features: usize,
    pub n_classes: usize,
    /// Padded tree depth shared by every tree in the arena.
    pub depth: usize,
    /// The shared SoA arena every grove slices.
    pub(crate) arena: Arc<ForestArena>,
}

impl FieldOfGroves {
    /// Algorithm 1, `GCTrain`: train an RF of `n_trees` and split into
    /// groves of `grove_size`.
    pub fn train(
        data: &DataSplit,
        params: &ForestParams,
        grove_size: usize,
        seed: u64,
    ) -> FieldOfGroves {
        let rf = RandomForest::fit(data, params, seed);
        Self::from_forest_shuffled(&rf, grove_size, Some(seed ^ 0x5EED))
    }

    /// Algorithm 1, `Split`: consecutive groups of `k` trees from a
    /// pre-trained forest. Trailing remainder (when `k ∤ n`) forms a
    /// smaller final grove, matching the `RF.estimators[i..i+k]` slice.
    pub fn from_forest(rf: &RandomForest, grove_size: usize) -> FieldOfGroves {
        Self::from_forest_shuffled(rf, grove_size, None)
    }

    /// `Split` with an optional random shuffle first ("Each grove is
    /// composed of a random, non-overlapping subset of the trees", §3.2.1).
    pub fn from_forest_shuffled(
        rf: &RandomForest,
        grove_size: usize,
        shuffle_seed: Option<u64>,
    ) -> FieldOfGroves {
        assert!(grove_size > 0, "grove_size = 0");
        assert!(grove_size <= rf.n_trees(), "grove larger than forest");
        let depth = rf.max_depth().max(1);
        let mut flats: Vec<FlatTree> = rf.flatten(depth);
        if let Some(seed) = shuffle_seed {
            let mut rng = Rng::new(seed);
            rng.shuffle(&mut flats);
        }
        let mut sizes = Vec::new();
        let mut i = 0;
        while i < flats.len() {
            let hi = (i + grove_size).min(flats.len());
            sizes.push(hi - i);
            i = hi;
        }
        Self::assemble(flats, &sizes)
    }

    /// Build a FoG from explicit per-grove tree groups (used by the
    /// dropout/degradation paths and [`FieldOfGroves::repad`]): all trees
    /// are packed into one shared arena, each group becoming a
    /// consecutive tree-range grove.
    pub fn from_groves(groups: Vec<Vec<FlatTree>>) -> FieldOfGroves {
        let sizes: Vec<usize> = groups.iter().map(|g| g.len()).collect();
        assert!(!sizes.is_empty() && sizes.iter().all(|&s| s > 0), "empty grove");
        let flats: Vec<FlatTree> = groups.into_iter().flatten().collect();
        Self::assemble(flats, &sizes)
    }

    /// Pack `flats` into one shared arena partitioned by `sizes` and
    /// slice out the groves.
    fn assemble(flats: Vec<FlatTree>, sizes: &[usize]) -> FieldOfGroves {
        assert!(!flats.is_empty(), "empty fog");
        let n_features = flats[0].n_features;
        let n_classes = flats[0].n_classes;
        let arena = Arc::new(ForestArena::from_flat_trees(&flats).with_grove_sizes(sizes));
        let depth = arena.depth();
        let groves = (0..arena.n_groves())
            .map(|g| {
                let (lo, hi) = arena.grove_range(g);
                Grove::from_arena(Arc::clone(&arena), lo, hi)
            })
            .collect();
        FieldOfGroves { groves, n_features, n_classes, depth, arena }
    }

    /// The shared arena behind every grove.
    pub fn arena(&self) -> &Arc<ForestArena> {
        &self.arena
    }

    /// Re-pad every tree to at least `depth` levels (function-preserving;
    /// see [`FlatTree::repad`]) — needed when binding trained trees to a
    /// deeper AOT-compiled artifact shape. Rebuilds the shared arena.
    pub fn repad(&self, depth: usize) -> FieldOfGroves {
        let depth = depth.max(self.depth);
        Self::from_groves(
            self.groves
                .iter()
                .map(|g| g.trees().iter().map(|t| t.repad(depth)).collect())
                .collect(),
        )
    }

    pub fn n_groves(&self) -> usize {
        self.groves.len()
    }

    pub fn total_trees(&self) -> usize {
        self.groves.iter().map(|g| g.n_trees()).sum()
    }

    /// The `a×b` topology label used throughout the paper (a groves of b
    /// trees).
    pub fn topology(&self) -> (usize, usize) {
        (self.n_groves(), self.groves.first().map(|g| g.n_trees()).unwrap_or(0))
    }

    /// Partition invariant: every tree appears in exactly one grove and
    /// the total matches the source forest (used by tests/proptests).
    pub fn validate_partition(&self, expected_total: usize) -> Result<(), String> {
        let total = self.total_trees();
        if total != expected_total {
            return Err(format!("{total} trees in groves, expected {expected_total}"));
        }
        for (i, g) in self.groves.iter().enumerate() {
            if g.n_trees() == 0 {
                return Err(format!("grove {i} empty"));
            }
            if g.n_features != self.n_features || g.n_classes != self.n_classes {
                return Err(format!("grove {i} shape mismatch"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    fn forest() -> (RandomForest, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 91);
        let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 1); // 16 trees
        (rf, ds)
    }

    #[test]
    fn split_is_partition() {
        let (rf, _) = forest();
        for k in [1, 2, 4, 8, 16] {
            let fog = FieldOfGroves::from_forest(&rf, k);
            assert_eq!(fog.n_groves(), 16 / k);
            fog.validate_partition(16).unwrap();
            assert!(fog.groves.iter().all(|g| g.n_trees() == k));
        }
    }

    #[test]
    fn remainder_forms_small_grove() {
        let (rf, _) = forest();
        let fog = FieldOfGroves::from_forest(&rf, 5); // 16 = 5+5+5+1
        assert_eq!(fog.n_groves(), 4);
        assert_eq!(fog.groves[3].n_trees(), 1);
        fog.validate_partition(16).unwrap();
    }

    #[test]
    fn shuffled_split_still_partitions() {
        let (rf, _) = forest();
        let fog = FieldOfGroves::from_forest_shuffled(&rf, 4, Some(9));
        fog.validate_partition(16).unwrap();
        assert_eq!(fog.topology(), (4, 4));
    }

    #[test]
    fn train_end_to_end() {
        let ds = generate(&DatasetProfile::demo(), 92);
        let fog = FieldOfGroves::train(&ds.train, &ForestParams::small(), 2, 3);
        assert_eq!(fog.topology(), (4, 2));
        assert_eq!(fog.n_classes, 3);
    }

    #[test]
    #[should_panic]
    fn zero_grove_size_panics() {
        let (rf, _) = forest();
        FieldOfGroves::from_forest(&rf, 0);
    }

    #[test]
    fn groves_share_one_arena() {
        let (rf, _) = forest();
        let fog = FieldOfGroves::from_forest(&rf, 4);
        for g in &fog.groves {
            assert!(std::sync::Arc::ptr_eq(g.arena(), fog.arena()), "grove has its own arena");
        }
        assert_eq!(fog.arena().n_trees(), 16);
        assert_eq!(fog.arena().n_groves(), 4);
    }

    #[test]
    fn repad_preserves_predictions_and_sparse_storage() {
        let (rf, ds) = forest();
        let fog = FieldOfGroves::from_forest(&rf, 4);
        let deeper = fog.repad(fog.depth + 2);
        assert_eq!(deeper.depth, fog.depth + 2);
        deeper.validate_partition(16).unwrap();
        let params = crate::fog::FogParams { threshold: 0.4, max_hops: 4, seed: 9 };
        let a = fog.evaluate(&ds.test.x, &params);
        let b = deeper.evaluate(&ds.test.x, &params);
        assert_eq!(a.predictions(), b.predictions());
        for (ga, gb) in fog.groves.iter().zip(&deeper.groves) {
            assert!(gb.vmem_bytes() > ga.vmem_bytes());
            assert_eq!(gb.sparse_storage_bytes(), ga.sparse_storage_bytes());
        }
    }
}
