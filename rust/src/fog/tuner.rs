//! Run-time tuning: threshold sweeps and the accuracy-optimal operating
//! point the paper calls FoG_opt — "a threshold point above which accuracy
//! does not increase with threshold but below which accuracy decreases
//! with decrease in threshold" (§4.2).
//!
//! Paper anchor: this module reproduces the **Figure 5** x-axis sweep
//! (accuracy and average hops vs confidence threshold) and the FoG_opt
//! column of **Table 1** (the swept operating point every energy
//! comparison quotes).

use super::eval::{EvalResult, FogParams};
use super::split::FieldOfGroves;
use crate::data::Split;

/// One point of a threshold sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub threshold: f32,
    pub accuracy: f64,
    pub avg_hops: f64,
}

/// Sweep the confidence threshold over `thresholds` on `split`,
/// holding `max_hops` at the grove count (the paper's Figure 5 setting).
pub fn threshold_sweep(
    fog: &FieldOfGroves,
    split: &Split,
    thresholds: &[f32],
    seed: u64,
) -> Vec<SweepPoint> {
    thresholds
        .iter()
        .map(|&threshold| {
            let params = FogParams { threshold, max_hops: fog.n_groves(), seed };
            let res: EvalResult = fog.evaluate(&split.x, &params);
            SweepPoint { threshold, accuracy: res.accuracy(&split.y), avg_hops: res.avg_hops() }
        })
        .collect()
}

/// The default threshold grid used by the figures (0.05 .. 1.0).
pub fn default_grid() -> Vec<f32> {
    (1..=20).map(|i| i as f32 * 0.05).collect()
}

/// Find FoG_opt: the smallest threshold whose accuracy is within
/// `tolerance` of the maximum accuracy over the sweep. Smaller thresholds
/// mean fewer hops, so this is the cheapest accuracy-preserving point.
pub fn accuracy_optimal_threshold(sweep: &[SweepPoint], tolerance: f64) -> &SweepPoint {
    assert!(!sweep.is_empty());
    let best_acc = sweep.iter().map(|p| p.accuracy).fold(f64::NEG_INFINITY, f64::max);
    // Sweep points sorted by threshold ascending; pick the first that is
    // within tolerance of the best.
    let mut sorted: Vec<&SweepPoint> = sweep.iter().collect();
    sorted.sort_by(|a, b| a.threshold.partial_cmp(&b.threshold).unwrap());
    sorted
        .into_iter()
        .find(|p| p.accuracy >= best_acc - tolerance)
        .expect("at least the max point qualifies")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest};

    fn setup() -> (FieldOfGroves, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 111);
        let rf = RandomForest::fit(&ds.train, &ForestParams::default(), 1);
        (FieldOfGroves::from_forest(&rf, 4), ds)
    }

    #[test]
    fn sweep_hops_monotone() {
        let (fog, ds) = setup();
        let sweep = threshold_sweep(&fog, &ds.test, &[0.1, 0.3, 0.5, 0.7, 0.9], 1);
        for w in sweep.windows(2) {
            assert!(w[1].avg_hops + 1e-9 >= w[0].avg_hops);
        }
    }

    #[test]
    fn opt_is_cheapest_near_best() {
        let (fog, ds) = setup();
        let sweep = threshold_sweep(&fog, &ds.test, &default_grid(), 2);
        let opt = accuracy_optimal_threshold(&sweep, 0.01);
        let best = sweep.iter().map(|p| p.accuracy).fold(f64::NEG_INFINITY, f64::max);
        assert!(opt.accuracy >= best - 0.01);
        // No cheaper qualifying point exists.
        for p in &sweep {
            if p.threshold < opt.threshold {
                assert!(p.accuracy < best - 0.01);
            }
        }
    }

    #[test]
    fn grid_shape() {
        let g = default_grid();
        assert_eq!(g.len(), 20);
        assert!((g[0] - 0.05).abs() < 1e-6);
        assert!((g[19] - 1.0).abs() < 1e-6);
    }
}
