//! A grove: a disjoint subset of the forest's trees acting as one
//! probability estimator (paper §3.2.1). The grove is the unit of
//! computation in FoG — the PE of one hardware tile evaluates all its
//! trees on an input and emits the *sum* of leaf distributions (the hop
//! loop divides by the number of contributing groves, Algorithm 2 line 8;
//! keeping sums avoids re-scaling on every hop).

use crate::dt::FlatTree;

/// One grove of flattened trees (homogeneous depth).
#[derive(Clone, Debug)]
pub struct Grove {
    pub trees: Vec<FlatTree>,
    pub n_features: usize,
    pub n_classes: usize,
}

impl Grove {
    pub fn new(trees: Vec<FlatTree>) -> Grove {
        assert!(!trees.is_empty(), "empty grove");
        let f = trees[0].n_features;
        let c = trees[0].n_classes;
        for t in &trees {
            assert_eq!((t.n_features, t.n_classes), (f, c));
        }
        Grove { trees, n_features: f, n_classes: c }
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    pub fn depth(&self) -> usize {
        self.trees.iter().map(|t| t.depth).max().unwrap_or(0)
    }

    /// Add this grove's *averaged* distribution into `acc` (so `acc`
    /// accumulates one unit of probability mass per grove, and the hop
    /// loop's `prob / (j+1)` normalization matches Algorithm 2 exactly).
    #[inline]
    pub fn accumulate_proba(&self, x: &[f32], acc: &mut [f32]) {
        debug_assert_eq!(acc.len(), self.n_classes);
        let inv = 1.0 / self.trees.len() as f32;
        for t in &self.trees {
            let leaf = t.predict_proba(x);
            for (a, &p) in acc.iter_mut().zip(leaf) {
                *a += p * inv;
            }
        }
    }

    /// This grove's own normalized estimate (fresh buffer).
    pub fn predict_proba(&self, x: &[f32]) -> Vec<f32> {
        let mut acc = vec![0.0f32; self.n_classes];
        self.accumulate_proba(x, &mut acc);
        acc
    }

    /// Comparator ops per evaluation: each flat tree walks exactly `depth`
    /// levels (complete-tree layout), matching the hardware PE whose
    /// latency is depth-bound (paper §3.2.2 "Processing Element").
    pub fn ops_per_eval(&self) -> usize {
        self.trees.iter().map(|t| t.depth).sum()
    }

    /// Total VMEM bytes for the grove's node tables (perf estimates).
    pub fn vmem_bytes(&self) -> usize {
        self.trees.iter().map(|t| t.vmem_bytes()).sum()
    }

    /// Bytes of *sparse* node storage the hardware would provision: live
    /// internal nodes (finite threshold) at 6 B each + one byte per
    /// leaf-class slot of the live leaves (complete-tree padding is a
    /// kernel-layout artifact, not real storage).
    pub fn sparse_storage_bytes(&self) -> usize {
        self.trees
            .iter()
            .map(|t| {
                let live = t.thr.iter().filter(|v| v.is_finite() && **v < 1e37).count();
                live * 6 + (live + 1) * t.n_classes
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest};

    fn grove() -> (Grove, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 81);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 1);
        let flats = rf.flatten(rf.max_depth());
        (Grove::new(flats), ds)
    }

    #[test]
    fn proba_normalized() {
        let (g, ds) = grove();
        for i in 0..10 {
            let p = g.predict_proba(ds.test.row(i));
            let s: f32 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "sum {s}");
        }
    }

    #[test]
    fn accumulate_adds_one_unit() {
        let (g, ds) = grove();
        let mut acc = vec![0.0f32; g.n_classes];
        g.accumulate_proba(ds.test.row(0), &mut acc);
        g.accumulate_proba(ds.test.row(0), &mut acc);
        let s: f32 = acc.iter().sum();
        assert!((s - 2.0).abs() < 1e-4, "two groves add two units, got {s}");
    }

    #[test]
    fn ops_metric() {
        let (g, _) = grove();
        assert_eq!(g.ops_per_eval(), g.trees.iter().map(|t| t.depth).sum());
        assert!(g.vmem_bytes() > 0);
    }

    #[test]
    #[should_panic]
    fn empty_grove_panics() {
        Grove::new(vec![]);
    }
}
