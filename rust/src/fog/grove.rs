//! A grove: a disjoint subset of the forest's trees acting as one
//! probability estimator (paper §3.2.1). The grove is the unit of
//! computation in FoG — the PE of one hardware tile evaluates all its
//! trees on an input and emits the *sum* of leaf distributions (the hop
//! loop divides by the number of contributing groves, Algorithm 2 line 8;
//! keeping sums avoids re-scaling on every hop).
//!
//! Since the `exec` refactor a grove owns no tree storage of its own: it
//! is a contiguous tree-range *slice* of a shared
//! [`ForestArena`](crate::exec::ForestArena) (every grove of a
//! [`FieldOfGroves`](super::FieldOfGroves) slices the same arena), so hop
//! traversal, the coordinator's grove workers and the batch kernel all
//! walk the same level-major arrays. Op counts and storage accounting are
//! derived from the arena layout and are numerically identical to the
//! per-`FlatTree` accounting they replaced. Every grove walk inherits the
//! arena's live-depth early exit (dead padded levels of mixed-depth trees
//! are never touched, results byte-identical); the `ops_per_eval` charge
//! stays depth-bound like the hardware PE, with the saving surfaced via
//! [`Grove::skipped_ops_per_eval`].

use crate::dt::FlatTree;
use crate::exec::ForestArena;
use std::sync::Arc;

/// One grove of flattened trees (homogeneous padded depth), viewed as a
/// tree range `[lo, hi)` of a shared arena.
#[derive(Clone, Debug)]
pub struct Grove {
    arena: Arc<ForestArena>,
    lo: usize,
    hi: usize,
    pub n_features: usize,
    pub n_classes: usize,
}

impl Grove {
    /// Pack a standalone grove from owned trees (builds a private
    /// single-grove arena; trees shallower than the deepest are re-padded,
    /// which preserves the computed function).
    pub fn new(trees: Vec<FlatTree>) -> Grove {
        assert!(!trees.is_empty(), "empty grove");
        let f = trees[0].n_features;
        let c = trees[0].n_classes;
        for t in &trees {
            assert_eq!((t.n_features, t.n_classes), (f, c));
        }
        let arena = Arc::new(ForestArena::from_flat_trees(&trees));
        let hi = arena.n_trees();
        Grove { arena, lo: 0, hi, n_features: f, n_classes: c }
    }

    /// View the tree range `[lo, hi)` of a shared arena as a grove.
    pub fn from_arena(arena: Arc<ForestArena>, lo: usize, hi: usize) -> Grove {
        assert!(lo < hi && hi <= arena.n_trees(), "bad grove range {lo}..{hi}");
        let f = arena.n_features();
        let c = arena.n_classes();
        Grove { arena, lo, hi, n_features: f, n_classes: c }
    }

    /// The shared arena this grove slices.
    pub fn arena(&self) -> &Arc<ForestArena> {
        &self.arena
    }

    /// This grove's tree range `[lo, hi)` within the arena.
    pub fn tree_range(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    pub fn n_trees(&self) -> usize {
        self.hi - self.lo
    }

    /// Padded depth (uniform across the arena).
    pub fn depth(&self) -> usize {
        self.arena.depth()
    }

    /// Materialize one tree as a standalone [`FlatTree`] (cold path:
    /// export, dropout, PJRT bundle snapshots, tests).
    pub fn tree(&self, i: usize) -> FlatTree {
        assert!(i < self.n_trees(), "tree {i} out of grove range");
        self.arena.tree(self.lo + i)
    }

    /// Materialize every tree of the grove.
    pub fn trees(&self) -> Vec<FlatTree> {
        (self.lo..self.hi).map(|t| self.arena.tree(t)).collect()
    }

    /// Add this grove's *averaged* distribution into `acc` (so `acc`
    /// accumulates one unit of probability mass per grove, and the hop
    /// loop's `prob / (j+1)` normalization matches Algorithm 2 exactly).
    #[inline]
    pub fn accumulate_proba(&self, x: &[f32], acc: &mut [f32]) {
        debug_assert_eq!(acc.len(), self.n_classes);
        let inv = 1.0 / self.n_trees() as f32;
        for t in self.lo..self.hi {
            let leaf = self.arena.leaf_dist(t, x);
            for (a, &p) in acc.iter_mut().zip(leaf) {
                *a += p * inv;
            }
        }
    }

    /// One hop's compute for a whole tile: add this grove's averaged
    /// distribution into every row of `acc` (row-major `[n, n_classes]`)
    /// via the level-synchronous arena kernel. Row results are
    /// bit-identical to per-sample [`Grove::accumulate_proba`] — the
    /// per-tree adds happen in the same order with the same scaling.
    pub fn accumulate_proba_tile(&self, x: &[f32], n: usize, acc: &mut [f32]) {
        let c = self.n_classes;
        assert_eq!(x.len(), n * self.n_features, "tile shape mismatch");
        assert_eq!(acc.len(), n * c, "accumulator shape mismatch");
        let t_cnt = self.n_trees();
        let mut cursors = vec![0u32; t_cnt * n];
        self.arena.traverse_tile(self.lo, self.hi, x, n, &mut cursors);
        let inv = 1.0 / t_cnt as f32;
        for j in 0..t_cnt {
            for s in 0..n {
                let leaf = self.arena.leaf_slice(self.lo + j, cursors[j * n + s] as usize);
                for (a, &p) in acc[s * c..(s + 1) * c].iter_mut().zip(leaf) {
                    *a += p * inv;
                }
            }
        }
    }

    /// This grove's own normalized estimate (fresh buffer).
    pub fn predict_proba(&self, x: &[f32]) -> Vec<f32> {
        let mut acc = vec![0.0f32; self.n_classes];
        self.accumulate_proba(x, &mut acc);
        acc
    }

    /// Comparator ops per evaluation: each packed tree is *charged*
    /// exactly `depth` levels (complete-tree layout), matching the
    /// hardware PE whose latency is depth-bound (paper §3.2.2
    /// "Processing Element"). This accounting number is independent of
    /// the software kernel's live-depth early exit — see
    /// [`Grove::skipped_ops_per_eval`] for what the exit saves.
    pub fn ops_per_eval(&self) -> usize {
        self.arena.ops_per_eval_range(self.lo, self.hi)
    }

    /// Comparator ops the ragged software kernel actually executes per
    /// evaluation: Σ live_depth over this grove's trees.
    pub fn live_ops_per_eval(&self) -> usize {
        self.arena.live_ops_per_eval_range(self.lo, self.hi)
    }

    /// Dead padded levels the live-depth early exit skips per evaluation
    /// of this grove (`ops_per_eval − live_ops_per_eval`).
    pub fn skipped_ops_per_eval(&self) -> usize {
        self.arena.skipped_ops_per_eval_range(self.lo, self.hi)
    }

    /// Total VMEM bytes for the grove's node tables (perf estimates).
    pub fn vmem_bytes(&self) -> usize {
        self.arena.vmem_bytes_range(self.lo, self.hi)
    }

    /// Bytes of *sparse* node storage the hardware would provision: live
    /// internal nodes (finite threshold) at 6 B each + one byte per
    /// leaf-class slot of the live leaves (complete-tree padding is a
    /// kernel-layout artifact, not real storage).
    pub fn sparse_storage_bytes(&self) -> usize {
        self.arena.sparse_storage_bytes_range(self.lo, self.hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::forest::{ForestParams, RandomForest};

    fn grove() -> (Grove, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 81);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 1);
        let flats = rf.flatten(rf.max_depth());
        (Grove::new(flats), ds)
    }

    #[test]
    fn proba_normalized() {
        let (g, ds) = grove();
        for i in 0..10 {
            let p = g.predict_proba(ds.test.row(i));
            let s: f32 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "sum {s}");
        }
    }

    #[test]
    fn accumulate_adds_one_unit() {
        let (g, ds) = grove();
        let mut acc = vec![0.0f32; g.n_classes];
        g.accumulate_proba(ds.test.row(0), &mut acc);
        g.accumulate_proba(ds.test.row(0), &mut acc);
        let s: f32 = acc.iter().sum();
        assert!((s - 2.0).abs() < 1e-4, "two groves add two units, got {s}");
    }

    #[test]
    fn tile_matches_per_sample_bitwise() {
        let (g, ds) = grove();
        let n = 13;
        let f = g.n_features;
        let c = g.n_classes;
        let mut tile_acc = vec![0.0f32; n * c];
        g.accumulate_proba_tile(&ds.test.x[..n * f], n, &mut tile_acc);
        for i in 0..n {
            let mut acc = vec![0.0f32; c];
            g.accumulate_proba(ds.test.row(i), &mut acc);
            assert_eq!(&tile_acc[i * c..(i + 1) * c], &acc[..], "row {i}");
        }
    }

    #[test]
    fn ops_metric() {
        let (g, _) = grove();
        assert_eq!(g.ops_per_eval(), g.n_trees() * g.depth());
        assert!(g.vmem_bytes() > 0);
        // Live + skipped partition the padded charge exactly.
        assert_eq!(g.live_ops_per_eval() + g.skipped_ops_per_eval(), g.ops_per_eval());
        assert!(g.live_ops_per_eval() > 0);
    }

    #[test]
    fn ragged_grove_tile_matches_per_sample_bitwise() {
        // A grove mixing a depth-capped tree with deep ones: the tiled
        // hop kernel (early exit) still equals per-sample accumulation,
        // and the skip accounting is nonzero.
        let ds = generate(&DatasetProfile::demo(), 83);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 7);
        let mut flats = rf.flatten(rf.max_depth());
        let deep_ref = flats[0].clone();
        let capped = RandomForest::fit(
            &ds.train,
            &ForestParams {
                n_trees: 1,
                tree: crate::dt::builder::TreeParams {
                    max_depth: 2,
                    ..crate::dt::builder::TreeParams::default()
                },
                bootstrap: true,
            },
            8,
        );
        flats.push(capped.flatten(capped.max_depth()).remove(0));
        assert!(flats.last().unwrap().depth < deep_ref.depth);
        let g = Grove::new(flats);
        assert!(g.skipped_ops_per_eval() > 0, "fixture must be ragged");
        let n = 11;
        let f = g.n_features;
        let c = g.n_classes;
        let mut tile_acc = vec![0.0f32; n * c];
        g.accumulate_proba_tile(&ds.test.x[..n * f], n, &mut tile_acc);
        for i in 0..n {
            let mut acc = vec![0.0f32; c];
            g.accumulate_proba(ds.test.row(i), &mut acc);
            assert_eq!(&tile_acc[i * c..(i + 1) * c], &acc[..], "row {i}");
        }
    }

    #[test]
    fn materialized_trees_roundtrip() {
        let (g, ds) = grove();
        let trees = g.trees();
        assert_eq!(trees.len(), g.n_trees());
        for i in 0..5 {
            let x = ds.test.row(i);
            let mut acc = vec![0.0f32; g.n_classes];
            let inv = 1.0 / trees.len() as f32;
            for t in &trees {
                for (a, &p) in acc.iter_mut().zip(t.predict_proba(x)) {
                    *a += p * inv;
                }
            }
            assert_eq!(acc, g.predict_proba(x), "row {i}");
        }
    }

    #[test]
    fn repad_grows_vmem_but_not_sparse_storage() {
        // Satellite invariant at the grove level: deeper padding adds
        // dead slots (VMEM grows) but no real (live-node) storage.
        let (g, _) = grove();
        let deeper =
            Grove::new(g.trees().iter().map(|t| t.repad(g.depth() + 2)).collect());
        assert!(deeper.vmem_bytes() > g.vmem_bytes());
        assert_eq!(deeper.sparse_storage_bytes(), g.sparse_storage_bytes());
        assert_eq!(deeper.depth(), g.depth() + 2);
    }

    #[test]
    fn arena_slice_groves_match_standalone() {
        let ds = generate(&DatasetProfile::demo(), 82);
        let rf = RandomForest::fit(&ds.train, &ForestParams::small(), 3);
        let flats = rf.flatten(rf.max_depth());
        let arena = Arc::new(ForestArena::from_flat_trees(&flats));
        let shared = Grove::from_arena(Arc::clone(&arena), 2, 6);
        let standalone = Grove::new(flats[2..6].to_vec());
        for i in 0..10 {
            let x = ds.test.row(i);
            assert_eq!(shared.predict_proba(x), standalone.predict_proba(x), "row {i}");
        }
        assert_eq!(shared.vmem_bytes(), standalone.vmem_bytes());
        assert_eq!(shared.sparse_storage_bytes(), standalone.sparse_storage_bytes());
    }

    #[test]
    #[should_panic]
    fn empty_grove_panics() {
        Grove::new(vec![]);
    }

    #[test]
    #[should_panic(expected = "bad grove range")]
    fn empty_arena_slice_rejected() {
        // A grove must never be an empty tree-range slice (lo == hi) —
        // its probability average would divide by zero trees.
        let (g, _) = grove();
        let _ = Grove::from_arena(std::sync::Arc::clone(g.arena()), 2, 2);
    }
}
