//! Train/validation splitting utilities (stratified, deterministic).
//!
//! Paper anchor: **§4.1 step 2** — budgeted training and the FoG_opt
//! threshold tuning both select design points on cross-validation data;
//! these helpers carve validation folds out of the training split
//! without ever touching the test set Table 1 reports on.

use super::Split;
use crate::util::rng::Rng;

/// Stratified split of `s` into `(train, holdout)` where the holdout gets
/// `holdout_frac` of each class (rounded down, at least 1 where possible).
pub fn stratified_holdout(s: &Split, holdout_frac: f64, seed: u64) -> (Split, Split) {
    assert!((0.0..1.0).contains(&holdout_frac));
    let mut rng = Rng::new(seed);
    // Bucket indices by class, shuffle each bucket.
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); s.n_classes];
    for (i, &y) in s.y.iter().enumerate() {
        buckets[y].push(i);
    }
    let mut train_idx = Vec::new();
    let mut hold_idx = Vec::new();
    for bucket in buckets.iter_mut() {
        rng.shuffle(bucket);
        let k = ((bucket.len() as f64) * holdout_frac).floor() as usize;
        let k = if bucket.len() > 1 { k.max(1).min(bucket.len() - 1) } else { 0 };
        hold_idx.extend_from_slice(&bucket[..k]);
        train_idx.extend_from_slice(&bucket[k..]);
    }
    // Deterministic order.
    train_idx.sort_unstable();
    hold_idx.sort_unstable();
    (s.subset(&train_idx), s.subset(&hold_idx))
}

/// `k`-fold cross-validation index sets: returns `k` (train, val) pairs.
pub fn kfold(s: &Split, k: usize, seed: u64) -> Vec<(Split, Split)> {
    assert!(k >= 2, "kfold needs k >= 2");
    let mut rng = Rng::new(seed);
    let mut idx: Vec<usize> = (0..s.len()).collect();
    rng.shuffle(&mut idx);
    let fold_size = s.len().div_ceil(k);
    let mut out = Vec::with_capacity(k);
    for f in 0..k {
        let lo = f * fold_size;
        let hi = ((f + 1) * fold_size).min(s.len());
        if lo >= hi {
            break;
        }
        let val: Vec<usize> = idx[lo..hi].to_vec();
        let train: Vec<usize> =
            idx[..lo].iter().chain(idx[hi..].iter()).copied().collect();
        out.push((s.subset(&train), s.subset(&val)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    #[test]
    fn holdout_partitions() {
        let ds = generate(&DatasetProfile::demo(), 20);
        let n = ds.train.len();
        let (tr, ho) = stratified_holdout(&ds.train, 0.25, 1);
        assert_eq!(tr.len() + ho.len(), n);
        assert!(ho.len() > 0 && tr.len() > 0);
    }

    #[test]
    fn holdout_stratified() {
        let ds = generate(&DatasetProfile::demo(), 21);
        let (_, ho) = stratified_holdout(&ds.train, 0.3, 2);
        let counts = ho.class_counts();
        // demo has 3 balanced classes: holdout should contain each class.
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 2, "{counts:?}");
    }

    #[test]
    fn holdout_deterministic() {
        let ds = generate(&DatasetProfile::demo(), 22);
        let (a1, b1) = stratified_holdout(&ds.train, 0.2, 7);
        let (a2, b2) = stratified_holdout(&ds.train, 0.2, 7);
        assert_eq!(a1.y, a2.y);
        assert_eq!(b1.x, b2.x);
    }

    #[test]
    fn kfold_covers_everything_once() {
        let ds = generate(&DatasetProfile::demo(), 23);
        let folds = kfold(&ds.train, 5, 3);
        assert_eq!(folds.len(), 5);
        let total_val: usize = folds.iter().map(|(_, v)| v.len()).sum();
        assert_eq!(total_val, ds.train.len());
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), ds.train.len());
        }
    }
}
