//! Datasets: core containers, synthetic UCI-profile generators, a CSV
//! loader for real UCI data, splits and normalization.
//!
//! The paper evaluates on five UCI datasets (ISOLET, Pendigits, MNIST,
//! Letter, Segmentation). The build environment has no network access, so
//! [`synthetic`] provides deterministic generators matched to each
//! dataset's (features, classes, sizes) with controlled class-boundary
//! nonlinearity; [`csv`] loads the real files unchanged when present
//! (drop them under `data/` and pass `--data-dir`).

pub mod csv;
pub mod normalize;
pub mod split;
pub mod synthetic;

/// A labelled design matrix: `x` is row-major `[n, d]`, `y` are class ids.
#[derive(Clone, Debug)]
pub struct Split {
    pub x: Vec<f32>,
    pub y: Vec<usize>,
    pub n_features: usize,
    pub n_classes: usize,
}

impl Split {
    pub fn new(n_features: usize, n_classes: usize) -> Self {
        Split { x: Vec::new(), y: Vec::new(), n_features, n_classes }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Row accessor.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.n_features..(i + 1) * self.n_features]
    }

    pub fn push(&mut self, features: &[f32], label: usize) {
        assert_eq!(features.len(), self.n_features);
        assert!(label < self.n_classes, "label {label} >= n_classes {}", self.n_classes);
        self.x.extend_from_slice(features);
        self.y.push(label);
    }

    /// Subset by row indices (bootstrap / CV folds).
    pub fn subset(&self, idx: &[usize]) -> Split {
        let mut out = Split::new(self.n_features, self.n_classes);
        for &i in idx {
            out.push(self.row(i), self.y[i]);
        }
        out
    }

    /// Class frequencies (used by stratified split and gini root checks).
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &y in &self.y {
            counts[y] += 1;
        }
        counts
    }
}

/// A train/test pair plus provenance.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub train: Split,
    pub test: Split,
}

impl Dataset {
    pub fn n_features(&self) -> usize {
        self.train.n_features
    }
    pub fn n_classes(&self) -> usize {
        self.train.n_classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_push_and_row() {
        let mut s = Split::new(3, 2);
        s.push(&[1.0, 2.0, 3.0], 0);
        s.push(&[4.0, 5.0, 6.0], 1);
        assert_eq!(s.len(), 2);
        assert_eq!(s.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(s.class_counts(), vec![1, 1]);
    }

    #[test]
    fn subset_selects_rows() {
        let mut s = Split::new(1, 3);
        for i in 0..5 {
            s.push(&[i as f32], i % 3);
        }
        let sub = s.subset(&[4, 0]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.row(0), &[4.0]);
        assert_eq!(sub.y, vec![1, 0]);
    }

    #[test]
    #[should_panic]
    fn push_bad_label_panics() {
        let mut s = Split::new(1, 2);
        s.push(&[0.0], 5);
    }
}
