//! CSV loader for real UCI datasets.
//!
//! When the actual UCI files are available (no network in the default build
//! environment), drop them under a directory and load with
//! [`load_csv`] — the synthetic profiles are then bypassed unchanged.
//! Format: one sample per line, comma-separated floats, label last (the
//! UCI convention for ISOLET/Pendigits/Letter); `label_first` flips it.
//!
//! Paper anchor: **§4.1 / Table 1** — the five UCI datasets every
//! accuracy and energy number in the paper is reported on; this loader
//! is how the real files replace the synthetic stand-ins.

use super::Split;
use crate::util::error::Result;
use std::collections::BTreeMap;
use std::io::BufRead;
use std::path::Path;

/// Options for CSV parsing.
#[derive(Clone, Debug)]
pub struct CsvOptions {
    /// Label in column 0 instead of the last column.
    pub label_first: bool,
    /// Skip this many header lines.
    pub skip_lines: usize,
    /// Field separator.
    pub sep: char,
}

impl Default for CsvOptions {
    fn default() -> Self {
        CsvOptions { label_first: false, skip_lines: 0, sep: ',' }
    }
}

/// Load a labelled CSV into a [`Split`]. Labels may be arbitrary tokens
/// (e.g. `A`..`Z` for Letter); they are mapped to dense class ids in order
/// of first appearance, sorted for determinism at the end.
pub fn load_csv(path: &Path, opts: &CsvOptions) -> Result<Split> {
    let file = std::fs::File::open(path)
        .map_err(|e| crate::err!("open {}: {e}", path.display()))?;
    let reader = std::io::BufReader::new(file);

    let mut rows: Vec<(Vec<f32>, String)> = Vec::new();
    let mut n_features = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if lineno < opts.skip_lines || line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(opts.sep).map(|f| f.trim()).collect();
        if fields.len() < 2 {
            crate::bail!("line {}: need >= 2 fields", lineno + 1);
        }
        let (label, feats) = if opts.label_first {
            (fields[0].to_string(), &fields[1..])
        } else {
            (fields[fields.len() - 1].to_string(), &fields[..fields.len() - 1])
        };
        let parsed: std::result::Result<Vec<f32>, _> =
            feats.iter().map(|f| f.parse::<f32>()).collect();
        let parsed =
            parsed.map_err(|e| crate::err!("line {}: bad feature: {e}", lineno + 1))?;
        match n_features {
            None => n_features = Some(parsed.len()),
            Some(n) if n != parsed.len() => {
                crate::bail!("line {}: {} features, expected {n}", lineno + 1, parsed.len())
            }
            _ => {}
        }
        rows.push((parsed, label));
    }
    crate::ensure!(!rows.is_empty(), "empty csv {}", path.display());

    // Dense, deterministic label ids (sorted lexicographically).
    let mut labels: Vec<&String> = rows.iter().map(|(_, l)| l).collect();
    labels.sort();
    labels.dedup();
    let label_map: BTreeMap<&String, usize> =
        labels.iter().enumerate().map(|(i, l)| (*l, i)).collect();

    let n_features = n_features.unwrap();
    let mut split = Split::new(n_features, label_map.len());
    for (feats, label) in &rows {
        split.push(feats, label_map[label]);
    }
    Ok(split)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("fog_csv_test_{}.csv", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn loads_label_last() {
        let p = write_tmp("1.0,2.0,A\n3.0,4.0,B\n5.0,6.0,A\n");
        let s = load_csv(&p, &CsvOptions::default()).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(s.len(), 3);
        assert_eq!(s.n_features, 2);
        assert_eq!(s.n_classes, 2);
        assert_eq!(s.y, vec![0, 1, 0]); // A=0, B=1 sorted
        assert_eq!(s.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn loads_label_first() {
        let p = write_tmp("7,0.5,0.25\n3,1.5,1.25\n");
        let s = load_csv(
            &p,
            &CsvOptions { label_first: true, ..Default::default() },
        )
        .unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(s.n_features, 2);
        assert_eq!(s.y, vec![1, 0]); // "3" < "7"
    }

    #[test]
    fn rejects_ragged() {
        let p = write_tmp("1,2,A\n1,2,3,B\n");
        let r = load_csv(&p, &CsvOptions::default());
        std::fs::remove_file(&p).ok();
        assert!(r.is_err());
    }

    #[test]
    fn missing_file_errors() {
        let r = load_csv(Path::new("/nonexistent/x.csv"), &CsvOptions::default());
        assert!(r.is_err());
    }
}
