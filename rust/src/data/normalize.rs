//! Feature normalization: z-score parameters are estimated on the training
//! split and applied to both splits (no test-set leakage). The hardware
//! path quantizes normalized features to fixed point; [`quantize_q`]
//! mirrors the accelerator's byte-addressable input format (paper §3.2.2:
//! one byte per feature in the data queue).

use super::{Dataset, Split};

/// Per-feature affine normalization parameters.
#[derive(Clone, Debug)]
pub struct Standardizer {
    pub mean: Vec<f32>,
    pub inv_std: Vec<f32>,
}

impl Standardizer {
    /// Estimate mean/std per feature from a split.
    pub fn fit(split: &Split) -> Standardizer {
        let d = split.n_features;
        let n = split.len().max(1);
        let mut mean = vec![0.0f32; d];
        for i in 0..split.len() {
            for (m, &x) in mean.iter_mut().zip(split.row(i)) {
                *m += x;
            }
        }
        mean.iter_mut().for_each(|m| *m /= n as f32);
        let mut var = vec![0.0f32; d];
        for i in 0..split.len() {
            for (j, &x) in split.row(i).iter().enumerate() {
                let dif = x - mean[j];
                var[j] += dif * dif;
            }
        }
        let inv_std = var
            .iter()
            .map(|&v| {
                let s = (v / n as f32).sqrt();
                if s > 1e-6 {
                    1.0 / s
                } else {
                    1.0 // constant feature: leave centred only
                }
            })
            .collect();
        Standardizer { mean, inv_std }
    }

    /// Apply in place.
    pub fn transform(&self, split: &mut Split) {
        let d = split.n_features;
        assert_eq!(d, self.mean.len());
        for row in split.x.chunks_mut(d) {
            for (j, x) in row.iter_mut().enumerate() {
                *x = (*x - self.mean[j]) * self.inv_std[j];
            }
        }
    }
}

/// Standardize a whole dataset using train statistics only.
pub fn standardize(ds: &mut Dataset) -> Standardizer {
    let st = Standardizer::fit(&ds.train);
    st.transform(&mut ds.train);
    st.transform(&mut ds.test);
    st
}

/// Quantize a normalized feature value to a signed Q3.4 byte, the format
/// the grove data queue stores (one byte per feature, paper §3.2.2). The
/// returned value is the *dequantized* f32 so software and the μarch
/// simulator see exactly the precision the hardware would.
pub fn quantize_q(x: f32) -> f32 {
    const SCALE: f32 = 16.0; // 4 fractional bits
    let q = (x * SCALE).round().clamp(-128.0, 127.0);
    q / SCALE
}

/// Quantize an entire split in place (hardware input conditioning).
pub fn quantize_split(split: &mut Split) {
    for x in &mut split.x {
        *x = quantize_q(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut ds = generate(&DatasetProfile::demo(), 11);
        standardize(&mut ds);
        let d = ds.train.n_features;
        let n = ds.train.len() as f32;
        for j in 0..d {
            let mut s = 0.0f32;
            let mut s2 = 0.0f32;
            for i in 0..ds.train.len() {
                let x = ds.train.row(i)[j];
                s += x;
                s2 += x * x;
            }
            let m = s / n;
            let v = s2 / n - m * m;
            assert!(m.abs() < 1e-3, "mean {m}");
            assert!((v - 1.0).abs() < 1e-2, "var {v}");
        }
    }

    #[test]
    fn constant_feature_no_nan() {
        let mut s = Split::new(2, 2);
        s.push(&[5.0, 1.0], 0);
        s.push(&[5.0, 2.0], 1);
        let st = Standardizer::fit(&s);
        st.transform(&mut s);
        assert!(s.x.iter().all(|x| x.is_finite()));
        assert_eq!(s.row(0)[0], 0.0); // centred constant
    }

    #[test]
    fn quantize_properties() {
        assert_eq!(quantize_q(0.0), 0.0);
        // representable exactly at 1/16 steps
        assert_eq!(quantize_q(0.25), 0.25);
        // clamps
        assert_eq!(quantize_q(100.0), 127.0 / 16.0);
        assert_eq!(quantize_q(-100.0), -8.0);
        // rounding error bounded by half a step
        for i in -50..50 {
            let x = i as f32 * 0.037;
            assert!((quantize_q(x) - x).abs() <= 0.5 / 16.0 + 1e-6);
        }
    }
}
