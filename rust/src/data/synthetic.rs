//! Synthetic stand-ins for the paper's five UCI datasets (the **§4.1 /
//! Table 1** evaluation suite; Figures 4 and 5 sweep the same five).
//!
//! The paper evaluates on ISOLET, Pendigits (called "Penbase" in Table 1),
//! MNIST, Letter and Segmentation from the UCI repository. This build
//! environment has no network access, so we generate deterministic
//! synthetic datasets matched to each original's dimensionality:
//!
//! | profile      | features | classes | paper dataset            |
//! |--------------|----------|---------|--------------------------|
//! | isolet       | 617      | 26      | ISOLET spoken letters    |
//! | penbase      | 16       | 10      | Pen-based digits         |
//! | mnist        | 784      | 10      | MNIST digits             |
//! | letter       | 16       | 26      | Letter recognition       |
//! | segmentation | 19       | 7       | Image segmentation       |
//!
//! The generator produces a multi-modal Gaussian mixture: each class owns
//! `clusters_per_class` prototype centers in an informative subspace, with
//! antipodal cluster placement so classes are **not linearly separable**
//! (linear SVM degrades, matching the paper's SVM-LR column), while
//! remaining well-separated for locally-adaptive models (RF, RBF-SVM, CNN).
//! The informative subspace is embedded through a random rotation with
//! spatial smoothing so neighbouring features correlate (giving convs an
//! edge, matching the paper's CNN column). The remaining features carry
//! attenuated noise — random forests' feature subsampling shrugs these off.

use super::{Dataset, Split};
use crate::util::rng::Rng;

/// Generation parameters for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct DatasetProfile {
    pub name: &'static str,
    pub n_features: usize,
    /// Dimension of the informative latent subspace.
    pub n_informative: usize,
    pub n_classes: usize,
    pub n_train: usize,
    pub n_test: usize,
    /// Gaussian clusters per class (≥2 defeats linear separation).
    pub clusters_per_class: usize,
    /// Distance between cluster centers in latent units.
    pub class_sep: f32,
    /// Observation noise added to every feature.
    pub noise: f32,
}

impl DatasetProfile {
    /// Tiny fast profile for doc examples and unit tests.
    pub fn demo() -> Self {
        DatasetProfile {
            name: "demo",
            n_features: 8,
            n_informative: 4,
            n_classes: 3,
            n_train: 300,
            n_test: 120,
            clusters_per_class: 2,
            class_sep: 3.0,
            noise: 0.3,
        }
    }

    /// The five profiles of the paper's Table 1.
    pub fn paper_suite() -> Vec<DatasetProfile> {
        vec![
            DatasetProfile {
                name: "isolet",
                n_features: 617,
                n_informative: 26,
                n_classes: 26,
                n_train: 2600,
                n_test: 780,
                clusters_per_class: 2,
                class_sep: 5.2,
                noise: 0.45,
            },
            DatasetProfile {
                name: "penbase",
                n_features: 16,
                n_informative: 10,
                n_classes: 10,
                n_train: 2500,
                n_test: 750,
                clusters_per_class: 2,
                class_sep: 4.6,
                noise: 0.35,
            },
            DatasetProfile {
                name: "mnist",
                n_features: 784,
                n_informative: 20,
                n_classes: 10,
                n_train: 3000,
                n_test: 900,
                clusters_per_class: 3,
                class_sep: 4.4,
                noise: 0.5,
            },
            DatasetProfile {
                name: "letter",
                n_features: 16,
                n_informative: 14,
                n_classes: 26,
                n_train: 3900,
                n_test: 1040,
                clusters_per_class: 2,
                class_sep: 3.6,
                noise: 0.4,
            },
            DatasetProfile {
                name: "segmentation",
                n_features: 19,
                n_informative: 12,
                n_classes: 7,
                n_train: 1470,
                n_test: 490,
                clusters_per_class: 2,
                class_sep: 4.0,
                noise: 0.4,
            },
        ]
    }

    /// Look up a paper profile by name (or `demo`).
    pub fn by_name(name: &str) -> Option<DatasetProfile> {
        if name == "demo" {
            return Some(DatasetProfile::demo());
        }
        DatasetProfile::paper_suite().into_iter().find(|p| p.name == name)
    }
}

/// A frozen generative model: cluster centers in latent space plus the
/// latent→feature embedding. Kept so tests can draw extra i.i.d. samples.
struct Generator {
    profile: DatasetProfile,
    /// `[class][cluster][latent_dim]`
    centers: Vec<Vec<Vec<f32>>>,
    /// Row-major `[n_informative, n_features]` embedding with smoothing.
    embed: Vec<f32>,
}

impl Generator {
    fn new(profile: DatasetProfile, rng: &mut Rng) -> Self {
        let d = profile.n_informative;
        let f = profile.n_features;
        // Class centers: per class, clusters placed antipodally around a
        // *sparse* direction (a few active latent dims) so that (a) a
        // single hyperplane cannot isolate a class — the antipodal pair
        // defeats linear SVM — while (b) individual latent dims (hence
        // individual feature blocks) stay discriminative, which is what
        // lets axis-aligned tree splits work on the real UCI datasets.
        // Enumerate distinct (dim, dim, sign) combinations so every class
        // owns a unique 2-sparse signature even when classes outnumber
        // latent dims.
        // One signature per (class, cluster): every cluster of a class
        // lives in its own 2-sparse quadrant, so the class is a union of
        // distant unimodal blobs — not linearly one-vs-rest separable
        // (defeating SVM-LR as in the paper), yet each blob is isolated
        // by two axis-aligned splits (trees and RBF models stay strong).
        let needed = profile.n_classes * profile.clusters_per_class;
        let mut signatures = Vec::with_capacity(needed);
        'outer: for sign in [1.0f32, -1.0] {
            for stride in 1..d.max(2) {
                for i in 0..d.saturating_sub(stride) {
                    signatures.push((i, i + stride, sign));
                    if signatures.len() >= needed {
                        break 'outer;
                    }
                }
            }
        }
        let mut centers = Vec::with_capacity(profile.n_classes);
        for c in 0..profile.n_classes {
            let mut cluster_centers = Vec::with_capacity(profile.clusters_per_class);
            for k in 0..profile.clusters_per_class {
                // Interleave so cluster 0 of every class is allocated
                // before any cluster 1: early signatures are the most
                // dim-disjoint ones.
                let sig = signatures[(k * profile.n_classes + c) % signatures.len()];
                let (i, j, sj) = sig;
                let scale = profile.class_sep / 2.0f32.sqrt();
                let center: Vec<f32> = (0..d)
                    .map(|dim| {
                        let v = if dim == i {
                            scale
                        } else if dim == j {
                            sj * scale
                        } else {
                            0.0
                        };
                        v + rng.gen_normal() * profile.class_sep * 0.08
                    })
                    .collect();
                cluster_centers.push(center);
            }
            centers.push(cluster_centers);
        }
        // Embedding: each latent factor loads on a *localized smooth bump*
        // of features (its own contiguous block of the feature axis) plus
        // a small dense background. Locality keeps per-feature SNR high
        // enough for axis-aligned tree splits (the real UCI sets have
        // individually-informative features too), while the smooth bump
        // gives adjacent features the correlation a 1-D CNN exploits.
        let mut embed = vec![0.0f32; d * f];
        let block = f as f32 / d as f32;
        for r in 0..d {
            let center = (r as f32 + 0.5) * block + rng.gen_normal() * block * 0.1;
            // Sharp bumps: most of a factor's energy lands on a handful of
            // features, so single features carry tree-splittable SNR (like
            // the real UCI sets); the few-feature width still gives the
            // CNN local correlation to exploit.
            let sigma = (block / 6.0).clamp(0.8, 3.0);
            let row = &mut embed[r * f..(r + 1) * f];
            for (c, v) in row.iter_mut().enumerate() {
                let z = (c as f32 - center) / sigma;
                *v = (-0.5 * z * z).exp() + rng.gen_normal() * 0.02;
            }
            // Unit signal power per latent factor.
            let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            row.iter_mut().for_each(|x| *x /= norm);
        }
        Generator { profile, centers, embed }
    }

    fn sample(&self, rng: &mut Rng, split: &mut Split, n: usize) {
        let p = &self.profile;
        let d = p.n_informative;
        let f = p.n_features;
        let mut latent = vec![0.0f32; d];
        let mut feat = vec![0.0f32; f];
        for i in 0..n {
            let class = i % p.n_classes; // balanced classes
            let cluster = rng.gen_range(p.clusters_per_class);
            let center = &self.centers[class][cluster];
            // Tight clusters (σ = 0.5 latent units): the real UCI classes
            // are compact relative to their separation.
            for j in 0..d {
                latent[j] = center[j] + rng.gen_normal() * 0.5;
            }
            // feat = latent @ embed + noise
            feat.iter_mut().for_each(|x| *x = 0.0);
            for (j, &l) in latent.iter().enumerate() {
                let row = &self.embed[j * f..(j + 1) * f];
                for (x, &e) in feat.iter_mut().zip(row) {
                    *x += l * e;
                }
            }
            for x in feat.iter_mut() {
                *x += rng.gen_normal() * p.noise;
            }
            split.push(&feat, class);
        }
    }
}

/// Generate a full dataset (train + test drawn i.i.d. from one frozen
/// generative model) for `profile`, deterministically from `seed`.
pub fn generate(profile: &DatasetProfile, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ fnv(profile.name));
    let g = Generator::new(profile.clone(), &mut rng);
    let mut train = Split::new(profile.n_features, profile.n_classes);
    let mut test = Split::new(profile.n_features, profile.n_classes);
    g.sample(&mut rng, &mut train, profile.n_train);
    g.sample(&mut rng, &mut test, profile.n_test);
    Dataset { name: profile.name.to_string(), train, test }
}

/// FNV-1a of the profile name so equal seeds give distinct streams per
/// dataset.
fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let p = DatasetProfile::demo();
        let a = generate(&p, 1);
        let b = generate(&p, 1);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.test.y, b.test.y);
    }

    #[test]
    fn seed_changes_data() {
        let p = DatasetProfile::demo();
        let a = generate(&p, 1);
        let b = generate(&p, 2);
        assert_ne!(a.train.x, b.train.x);
    }

    #[test]
    fn shapes_match_profile() {
        let p = DatasetProfile::demo();
        let d = generate(&p, 3);
        assert_eq!(d.train.len(), p.n_train);
        assert_eq!(d.test.len(), p.n_test);
        assert_eq!(d.train.x.len(), p.n_train * p.n_features);
        assert!(d.train.y.iter().all(|&y| y < p.n_classes));
    }

    #[test]
    fn classes_balanced() {
        let p = DatasetProfile::demo();
        let d = generate(&p, 4);
        let counts = d.train.class_counts();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 1, "balanced generator: {counts:?}");
    }

    #[test]
    fn paper_suite_has_five() {
        let suite = DatasetProfile::paper_suite();
        assert_eq!(suite.len(), 5);
        assert!(DatasetProfile::by_name("mnist").is_some());
        assert!(DatasetProfile::by_name("nope").is_none());
        // Dimensions match the real UCI datasets.
        let mnist = DatasetProfile::by_name("mnist").unwrap();
        assert_eq!((mnist.n_features, mnist.n_classes), (784, 10));
        let isolet = DatasetProfile::by_name("isolet").unwrap();
        assert_eq!((isolet.n_features, isolet.n_classes), (617, 26));
    }

    #[test]
    fn not_linearly_trivial_but_learnable() {
        // A nearest-class-mean classifier should beat chance comfortably
        // (the data is learnable) — the multi-cluster structure is probed
        // by the baseline tests instead.
        let p = DatasetProfile::demo();
        let d = generate(&p, 5);
        let f = p.n_features;
        // class means on train
        let mut means = vec![vec![0.0f32; f]; p.n_classes];
        let counts = d.train.class_counts();
        for i in 0..d.train.len() {
            let y = d.train.y[i];
            for (m, &x) in means[y].iter_mut().zip(d.train.row(i)) {
                *m += x;
            }
        }
        for (c, m) in means.iter_mut().enumerate() {
            m.iter_mut().for_each(|v| *v /= counts[c].max(1) as f32);
        }
        let mut hits = 0;
        for i in 0..d.test.len() {
            let row = d.test.row(i);
            let mut best = 0;
            let mut best_d = f32::INFINITY;
            for (c, m) in means.iter().enumerate() {
                let dist = crate::util::matrix::sq_dist(row, m);
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            if best == d.test.y[i] {
                hits += 1;
            }
        }
        let acc = hits as f64 / d.test.len() as f64;
        assert!(acc > 1.5 / p.n_classes as f64, "acc={acc}");
    }
}
