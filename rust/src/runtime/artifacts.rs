//! The artifact manifest: shape metadata for every HLO module the python
//! compile path produced (`artifacts/manifest.json`). The rust runtime
//! validates its inputs against these shapes before touching PJRT, so a
//! stale artifact directory fails loudly instead of mis-executing.
//!
//! Paper anchor: **§3.2.2 "Reprogrammability"** — the manifest's
//! `(t, depth, n_features, n_classes, batch)` tuple is the compile-time
//! contract a reprogrammed grove tile must re-match, exactly like the
//! hardware's fixed node/leaf store shapes.

use crate::util::error::Result;
use crate::util::json::parse;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One artifact's metadata (mirrors aot.py's manifest entries).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub t: usize,
    pub depth: usize,
    pub n_features: usize,
    pub n_classes: usize,
    pub batch: usize,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

impl ArtifactMeta {
    pub fn n_internal(&self) -> usize {
        (1usize << self.depth) - 1
    }
    pub fn n_leaves(&self) -> usize {
        1usize << self.depth
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| crate::err!("read {}: {e} (run `make artifacts`)", path.display()))?;
        Self::from_json_str(&text, dir)
    }

    pub fn from_json_str(text: &str, dir: &Path) -> Result<Manifest> {
        let v = parse(text)?;
        let obj = v.as_obj().ok_or_else(|| crate::err!("manifest not an object"))?;
        let mut entries = BTreeMap::new();
        for (name, meta) in obj {
            let strings = |key: &str| -> Vec<String> {
                meta.get(key)
                    .as_arr()
                    .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                    .unwrap_or_default()
            };
            entries.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: meta
                        .get("file")
                        .as_str()
                        .ok_or_else(|| crate::err!("{name}: missing file"))?
                        .to_string(),
                    kind: meta.get("kind").as_str().unwrap_or("unknown").to_string(),
                    t: meta.get("t").as_usize().unwrap_or(0),
                    depth: meta.get("depth").as_usize().unwrap_or(0),
                    n_features: meta.get("n_features").as_usize().unwrap_or(0),
                    n_classes: meta.get("n_classes").as_usize().unwrap_or(0),
                    batch: meta.get("batch").as_usize().unwrap_or(0),
                    inputs: strings("inputs"),
                    outputs: strings("outputs"),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.entries
            .get(name)
            .ok_or_else(|| crate::err!("artifact '{name}' not in manifest"))
    }

    /// Find the grove_step artifact matching a shape, if any.
    pub fn find_grove_step(
        &self,
        t: usize,
        depth: usize,
        n_features: usize,
        n_classes: usize,
    ) -> Option<&ArtifactMeta> {
        self.entries.values().find(|m| {
            m.kind == "grove_step"
                && m.t == t
                && m.depth == depth
                && m.n_features == n_features
                && m.n_classes == n_classes
        })
    }

    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

/// Default artifact directory: `$FOG_ARTIFACTS` or `artifacts/` relative
/// to the crate root / current dir.
pub fn default_dir() -> PathBuf {
    if let Ok(d) = std::env::var("FOG_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    // Fall back to the manifest dir relative to CARGO_MANIFEST_DIR (tests).
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "grove_step_x": {"file":"grove_step_x.hlo.txt","kind":"grove_step",
        "t":2,"depth":8,"n_features":16,"n_classes":10,"batch":32,
        "inputs":["feat","thr","leaf","x","prob_sum","hops"],
        "outputs":["new_sum","norm","conf"]},
      "maxdiff_x": {"file":"maxdiff_x.hlo.txt","kind":"maxdiff",
        "t":2,"depth":8,"n_features":16,"n_classes":10,"batch":32,
        "inputs":["prob"],"outputs":["conf"]}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json_str(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let g = m.get("grove_step_x").unwrap();
        assert_eq!(g.batch, 32);
        assert_eq!(g.n_internal(), 255);
        assert_eq!(g.n_leaves(), 256);
        assert_eq!(g.inputs.len(), 6);
    }

    #[test]
    fn find_by_shape() {
        let m = Manifest::from_json_str(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.find_grove_step(2, 8, 16, 10).is_some());
        assert!(m.find_grove_step(2, 8, 16, 11).is_none());
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::from_json_str(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn path_resolution() {
        let m = Manifest::from_json_str(SAMPLE, Path::new("/x/y")).unwrap();
        let g = m.get("maxdiff_x").unwrap();
        assert_eq!(m.path_of(g), PathBuf::from("/x/y/maxdiff_x.hlo.txt"));
    }
}
