//! PJRT execution: compile HLO-text artifacts once, run batches from the
//! L3 hot path.
//!
//! `Runtime` wraps the PJRT CPU client; `GroveStepExec` is the typed
//! front-end for the `grove_step` artifact (one Algorithm-2 hop for a
//! whole batch: probabilities, normalized distribution, confidence).
//! Inputs are validated against the manifest shapes; batches smaller than
//! the compiled batch size are zero-padded (the compiled shape is static).
//!
//! The implementation needs the vendored `xla` crate and is gated behind
//! the `pjrt` cargo feature. Without the feature (the default — this
//! build environment ships no `xla` closure) the same API is exported as
//! a stub whose constructors return errors, so the serving coordinator
//! degrades to the native backend instead of failing to compile.
//!
//! Paper anchor: **§3.2**'s grove processing element — one compiled
//! `grove_step` is the software stand-in for the hardware PE's
//! level-synchronous tree walk plus the Algorithm 2 confidence update,
//! executed per hop of the ring.

use super::artifacts::{ArtifactMeta, Manifest};
use crate::dt::export::FlatBundle;
use crate::util::error::Result;

/// Output of one grove step over a batch.
#[derive(Clone, Debug)]
pub struct StepOutput {
    /// Updated probability sums `[b, c]` (row-major).
    pub new_sum: Vec<f32>,
    /// Normalized distributions `[b, c]`.
    pub norm: Vec<f32>,
    /// MaxDiff confidence `[b]`.
    pub conf: Vec<f32>,
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::*;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` feature (needs the vendored `xla` crate); use the native backend";

    /// Stub PJRT client handle (the `pjrt` feature is off).
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        /// Always fails: the PJRT path is compiled out.
        pub fn cpu() -> Result<Runtime> {
            crate::bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }

    /// Stub typed executor (the `pjrt` feature is off).
    pub struct GroveStepExec {
        pub meta: ArtifactMeta,
    }

    impl GroveStepExec {
        pub fn new(
            _rt: &Runtime,
            _manifest: &Manifest,
            _meta: &ArtifactMeta,
            _bundle: &FlatBundle,
        ) -> Result<GroveStepExec> {
            crate::bail!("{UNAVAILABLE}")
        }

        pub fn step(&self, _x: &[f32], _prob_sum: &[f32], _hops: &[f32]) -> Result<StepOutput> {
            crate::bail!("{UNAVAILABLE}")
        }
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::*;

    /// Owns the PJRT client. NOTE: PJRT handles are thread-affine in the
    /// `xla` crate (raw pointers, no `Send`), so a `Runtime` and everything
    /// loaded from it must stay on the thread that created it — the serving
    /// coordinator therefore runs one dedicated accelerator thread
    /// (`coordinator::accel`).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// PJRT CPU client (the only backend in this environment).
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(|e| crate::err!("pjrt cpu: {e:?}"))?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact.
        pub fn compile(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| crate::err!("non-utf8 path"))?,
            )
            .map_err(|e| crate::err!("hlo parse: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client.compile(&comp).map_err(|e| crate::err!("compile: {e:?}"))
        }
    }

    /// Typed executor for a `grove_step` artifact bound to one grove's trees.
    pub struct GroveStepExec {
        exe: xla::PjRtLoadedExecutable,
        pub meta: ArtifactMeta,
        /// Pre-built tree-table literals for this grove (constant per grove).
        feat: xla::Literal,
        thr: xla::Literal,
        leaf: xla::Literal,
    }

    impl GroveStepExec {
        /// Compile the artifact and bind `bundle` (one grove's flat trees).
        pub fn new(
            rt: &Runtime,
            manifest: &Manifest,
            meta: &ArtifactMeta,
            bundle: &FlatBundle,
        ) -> Result<GroveStepExec> {
            crate::ensure!(meta.kind == "grove_step", "artifact kind {}", meta.kind);
            crate::ensure!(
                bundle.depth == meta.depth,
                "bundle depth {} != artifact depth {}",
                bundle.depth,
                meta.depth
            );
            crate::ensure!(
                bundle.n_features == meta.n_features && bundle.n_classes == meta.n_classes,
                "bundle shape mismatch"
            );
            // Padding with *copies* of existing trees would bias the
            // average; require exact t (aot emits the exact topology).
            crate::ensure!(
                bundle.trees.len() == meta.t,
                "bundle trees {} != artifact t {} (regenerate artifacts)",
                bundle.trees.len(),
                meta.t
            );

            let (feat_v, thr_v, leaf_v) = bundle.stacked();
            let n_int = meta.n_internal() as i64;
            let t = meta.t as i64;
            let lit = |e: xla::Error| crate::err!("literal: {e:?}");
            let feat = xla::Literal::vec1(&feat_v).reshape(&[t, n_int]).map_err(lit)?;
            let thr = xla::Literal::vec1(&thr_v).reshape(&[t, n_int]).map_err(lit)?;
            let leaf = xla::Literal::vec1(&leaf_v)
                .reshape(&[t, meta.n_leaves() as i64, meta.n_classes as i64])
                .map_err(lit)?;
            let exe = rt.compile(&manifest.path_of(meta))?;
            Ok(GroveStepExec { exe, meta: meta.clone(), feat, thr, leaf })
        }

        /// One hop for a batch. `x: [n, f]`, `prob_sum: [n, c]`, `hops[i]` =
        /// groves contributed including this one. `n` may be ≤ the compiled
        /// batch; rows beyond `n` are zero-padded and dropped from the output.
        pub fn step(&self, x: &[f32], prob_sum: &[f32], hops: &[f32]) -> Result<StepOutput> {
            let f = self.meta.n_features;
            let c = self.meta.n_classes;
            let b = self.meta.batch;
            let n = hops.len();
            crate::ensure!(n > 0 && n <= b, "batch {n} out of range 1..={b}");
            crate::ensure!(x.len() == n * f, "x len {} != {}", x.len(), n * f);
            crate::ensure!(prob_sum.len() == n * c, "prob_sum len");

            // Zero-pad to the compiled batch.
            let mut xp = vec![0.0f32; b * f];
            xp[..n * f].copy_from_slice(x);
            let mut pp = vec![0.0f32; b * c];
            pp[..n * c].copy_from_slice(prob_sum);
            let mut hp = vec![1.0f32; b]; // avoid div-by-zero in padding rows
            hp[..n].copy_from_slice(hops);

            let lit = |e: xla::Error| crate::err!("literal: {e:?}");
            let xl = xla::Literal::vec1(&xp).reshape(&[b as i64, f as i64]).map_err(lit)?;
            let pl = xla::Literal::vec1(&pp).reshape(&[b as i64, c as i64]).map_err(lit)?;
            let hl = xla::Literal::vec1(&hp).reshape(&[b as i64]).map_err(lit)?;

            let run = |e: xla::Error| crate::err!("execute: {e:?}");
            let result = self
                .exe
                .execute::<xla::Literal>(&[
                    self.feat.clone(),
                    self.thr.clone(),
                    self.leaf.clone(),
                    xl,
                    pl,
                    hl,
                ])
                .map_err(run)?[0][0]
                .to_literal_sync()
                .map_err(run)?;
            let (s, m, cf) = result.to_tuple3().map_err(run)?;
            let mut new_sum = s.to_vec::<f32>().map_err(run)?;
            let mut norm = m.to_vec::<f32>().map_err(run)?;
            let mut conf = cf.to_vec::<f32>().map_err(run)?;
            new_sum.truncate(n * c);
            norm.truncate(n * c);
            conf.truncate(n);
            Ok(StepOutput { new_sum, norm, conf })
        }
    }
}

pub use imp::{GroveStepExec, Runtime};

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_runtime_errors_cleanly() {
        let e = Runtime::cpu().unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetProfile};
    use crate::dt::export::sanitize_inf;
    use crate::fog::FieldOfGroves;
    use crate::forest::{ForestParams, RandomForest};
    use crate::runtime::artifacts::default_dir;

    /// Integration tests need `make artifacts` to have run; skip (but
    /// don't fail) otherwise so `cargo test` works before the first
    /// artifact build.
    fn manifest_or_skip() -> Option<Manifest> {
        let dir = default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
            return None;
        }
        Some(Manifest::load(&dir).unwrap())
    }

    /// Build a demo-shaped FoG matching the `grove_step_demo` artifact:
    /// t=4 trees per grove, depth 6, f=8, c=3.
    fn demo_fog() -> (FieldOfGroves, crate::data::Dataset) {
        let ds = generate(&DatasetProfile::demo(), 181);
        let params = ForestParams {
            n_trees: 8,
            tree: crate::dt::TreeParams { max_depth: 6, ..Default::default() },
            bootstrap: true,
        };
        let rf = RandomForest::fit(&ds.train, &params, 1);
        let fog = FieldOfGroves::from_forest(&rf, 4); // 2 groves of 4
        (fog, ds)
    }

    #[test]
    fn pjrt_grove_step_matches_native() {
        let Some(manifest) = manifest_or_skip() else { return };
        let (fog, ds) = demo_fog();
        // Force the padded depth to the artifact's depth.
        let meta = match manifest.find_grove_step(4, 6, 8, 3) {
            Some(m) => m.clone(),
            None => {
                // Trees may be shallower than 6; repad.
                manifest.get("grove_step_demo").unwrap().clone()
            }
        };
        let rt = Runtime::cpu().unwrap();
        // Re-pad grove trees to the artifact depth.
        let grove = &fog.groves[0];
        let repadded: Vec<crate::dt::FlatTree> =
            grove.trees().iter().map(|t| t.repad(meta.depth)).collect();
        let mut bundle = FlatBundle::new(repadded);
        sanitize_inf(&mut bundle);
        let exec = GroveStepExec::new(&rt, &manifest, &meta, &bundle).unwrap();

        let n = 16usize;
        let x = &ds.test.x[..n * 8];
        let prob_sum = vec![0.0f32; n * 3];
        let hops = vec![1.0f32; n];
        let out = exec.step(x, &prob_sum, &hops).unwrap();

        // Native reference.
        let native_grove = crate::fog::Grove::new(bundle.trees.clone());
        for i in 0..n {
            let native = native_grove.predict_proba(&x[i * 8..(i + 1) * 8]);
            for (a, b) in out.norm[i * 3..(i + 1) * 3].iter().zip(&native) {
                assert!((a - b).abs() < 1e-4, "row {i}: pjrt {a} native {b}");
            }
            let conf = crate::fog::confidence::max_diff(&native);
            assert!((out.conf[i] - conf).abs() < 1e-4);
        }
    }

    #[test]
    fn partial_batch_padding() {
        let Some(manifest) = manifest_or_skip() else { return };
        let (fog, ds) = demo_fog();
        let meta = manifest.get("grove_step_demo").unwrap().clone();
        let rt = Runtime::cpu().unwrap();
        let repadded: Vec<crate::dt::FlatTree> =
            fog.groves[0].trees().iter().map(|t| t.repad(meta.depth)).collect();
        let mut bundle = FlatBundle::new(repadded);
        sanitize_inf(&mut bundle);
        let exec = GroveStepExec::new(&rt, &manifest, &meta, &bundle).unwrap();
        // n=3 ≪ compiled batch 32.
        let x = &ds.test.x[..3 * 8];
        let out = exec.step(x, &vec![0.0; 9], &[1.0, 1.0, 1.0]).unwrap();
        assert_eq!(out.norm.len(), 9);
        assert_eq!(out.conf.len(), 3);
        let full = exec.step(&ds.test.x[..16 * 8], &vec![0.0; 48], &vec![1.0; 16]).unwrap();
        for j in 0..9 {
            assert!((out.norm[j] - full.norm[j]).abs() < 1e-5);
        }
    }
}
