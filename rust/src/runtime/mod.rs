//! PJRT runtime: load the AOT-compiled (JAX/Pallas) HLO-text artifacts
//! produced by `python/compile/aot.py` and execute them from rust.
//!
//! Python never runs on this path — `make artifacts` is a build step; the
//! rust binary loads `artifacts/*.hlo.txt` (HLO **text**, the interchange
//! format that survives the jax≥0.5 ↔ xla_extension 0.5.1 proto-id
//! mismatch), compiles once per process via the PJRT CPU client, and
//! executes with concrete inputs.

pub mod artifacts;
pub mod executable;

pub use artifacts::{ArtifactMeta, Manifest};
pub use executable::{GroveStepExec, Runtime, StepOutput};
