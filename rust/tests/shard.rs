//! Conformance tests for the sharded serving tier: a `ShardedServer`
//! with N replicas must be *observationally identical* to a single
//! `ModelServer` (byte-identical probability rows for every registry
//! model), the quantized result cache must be invisible at step 0
//! (exact-bit keys), and the `LeastLoaded` router must not starve
//! high-index replicas under uniform load.

use fog::api::{Classifier, Estimator, ModelSpec, REGISTRY};
use fog::coordinator::{
    CacheConfig, ModelServer, ModelServerConfig, RouterPolicy, ShardedServer,
    ShardedServerConfig,
};
use fog::data::synthetic::{generate, DatasetProfile};
use fog::data::Dataset;
use std::sync::Arc;

fn small_data() -> Dataset {
    generate(&DatasetProfile::demo(), 501)
}

fn fit_fast(name: &str, ds: &Dataset, seed: u64) -> Arc<dyn Classifier> {
    Arc::from(
        ModelSpec::for_shape(name, ds.n_features(), ds.n_classes())
            .unwrap_or_else(|| panic!("registry name '{name}' missing"))
            .fast()
            .fit(&ds.train, seed),
    )
}

/// (a) For every registry model, N replicas behind every router policy
/// return byte-identical probability rows to one `ModelServer` over the
/// same trained model.
#[test]
fn sharded_matches_single_server_for_every_registry_model() {
    let ds = small_data();
    for name in REGISTRY {
        let model = fit_fast(name, &ds, 21);

        let mut single = ModelServer::start(Arc::clone(&model), &ModelServerConfig::default());
        let reference = single.classify(&ds.test.x).expect("aligned batch");
        single.shutdown();

        let cfg = ShardedServerConfig {
            replicas: 3,
            router: RouterPolicy::RoundRobin,
            ..Default::default()
        };
        let mut sharded = ShardedServer::start(Arc::clone(&model), &cfg);
        let responses = sharded.classify(&ds.test.x).expect("aligned batch");
        assert_eq!(responses.len(), reference.len(), "{name}");
        for (r, s) in reference.iter().zip(&responses) {
            assert_eq!(r.id, s.id, "{name}");
            assert_eq!(r.label, s.label, "{name} id {}", r.id);
            assert_eq!(
                r.prob, s.prob,
                "{name} id {}: sharded prob row is not byte-identical",
                r.id
            );
        }
        let snap = sharded.snapshot();
        assert_eq!(snap.responses as usize, ds.test.len(), "{name}");
        sharded.shutdown();
    }
}

/// (b) At quantization step 0 the cache is exact: a warm pass returns
/// rows byte-identical to the cold evaluation, entirely from cache.
#[test]
fn cache_hits_identical_to_cold_eval_at_step_zero() {
    let ds = small_data();
    for name in ["rf", "fog_opt", "mlp"] {
        let model = fit_fast(name, &ds, 22);
        let cfg = ShardedServerConfig {
            replicas: 2,
            cache: Some(CacheConfig { quant_step: 0.0, ..Default::default() }),
            ..Default::default()
        };
        let mut server = ShardedServer::start(model, &cfg);
        let cold = server.classify(&ds.test.x).expect("aligned batch");
        let warm = server.classify(&ds.test.x).expect("aligned batch");
        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.label, w.label, "{name}");
            assert_eq!(c.prob, w.prob, "{name}: cache hit differs from cold evaluation");
        }
        assert!(
            warm.iter().all(|r| r.hops == 0),
            "{name}: warm pass should be answered entirely from cache"
        );
        let snap = server.metrics().snapshot();
        assert_eq!(snap.cache_hits as usize, ds.test.len(), "{name}");
        assert_eq!(snap.cache_misses as usize, ds.test.len(), "{name}");
        server.shutdown();
    }
}

/// A coarse quantization step still yields valid (identical-shape)
/// answers and buckets near-identical inputs together.
#[test]
fn quantized_cache_buckets_nearby_rows() {
    let ds = small_data();
    let model = fit_fast("rf", &ds, 23);
    let f = ds.n_features();
    let cfg = ShardedServerConfig {
        replicas: 2,
        cache: Some(CacheConfig { quant_step: 1.0, ..Default::default() }),
        ..Default::default()
    };
    let mut server = ShardedServer::start(model, &cfg);
    // Hand-built rows far from every bucket boundary (boundaries sit at
    // half-integers under step 1.0), so the perturbation below can never
    // flip a bucket.
    let row = vec![0.25f32; f];
    let nudged = vec![0.26f32; f];
    let cold = server.classify(&row).expect("aligned");
    let warm = server.classify(&nudged).expect("aligned");
    assert_eq!(warm[0].hops, 0, "sub-bucket perturbation should hit the cache");
    assert_eq!(cold[0].prob, warm[0].prob);
    server.shutdown();
}

/// Load-balance regression for the `LeastLoaded` tie-break fix: under
/// uniform (mostly-idle) load every replica must see traffic — the old
/// lowest-index tie resolution starved every replica but 0 whenever the
/// queues drained between requests.
#[test]
fn least_loaded_does_not_starve_high_index_replicas() {
    let ds = small_data();
    let model = fit_fast("svm_lr", &ds, 24);
    let cfg = ShardedServerConfig {
        replicas: 4,
        router: RouterPolicy::LeastLoaded,
        ..Default::default()
    };
    let mut server = ShardedServer::start(model, &cfg);
    for _ in 0..3 {
        server.classify(&ds.test.x).expect("aligned batch");
    }
    let per_replica: Vec<u64> =
        (0..server.n_replicas()).map(|r| server.replica_metrics(r).snapshot().evals).collect();
    assert!(
        per_replica.iter().all(|&e| e > 0),
        "replica starved under LeastLoaded uniform load: {per_replica:?}"
    );
    server.shutdown();
}

/// The sharded tier composes with multiple sequential batches and keeps
/// globally unique, per-batch-ordered ids (same contract as
/// `ModelServer`).
#[test]
fn sequential_batches_keep_id_contract() {
    let ds = small_data();
    let model = fit_fast("svm_lr", &ds, 25);
    let f = ds.n_features();
    let mut server = ShardedServer::start(model, &ShardedServerConfig::default());
    let r1 = server.classify(&ds.test.x[..6 * f]).expect("aligned");
    let r2 = server.classify(&ds.test.x[6 * f..12 * f]).expect("aligned");
    assert!(r1.iter().enumerate().all(|(i, r)| r.id == i as u64));
    assert!(r2.iter().enumerate().all(|(i, r)| r.id == 6 + i as u64));
    server.shutdown();
}
